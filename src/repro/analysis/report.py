"""Gopher Sentinel: shared finding/report types.

Every pass (collectives, semiring, kernels) reports through the same
:class:`Violation` record so the CLI can merge them into one machine-readable
report and the engine hook can raise one :class:`SentinelError` naming every
offending equation/kernel — diagnostics are sentences with a locus, not
booleans.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

ERROR = "error"
WARNING = "warning"
INFO = "info"


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding: ``code`` is the stable machine id, ``where`` the locus
    (jaxpr path / kernel name / plan field / file:line), ``detail`` the
    actionable sentence."""
    pass_name: str               # 'collectives' | 'semiring' | 'kernels'
    code: str                    # e.g. 'COND_COLLECTIVE_MISMATCH'
    where: str
    detail: str
    severity: str = ERROR        # 'error' | 'warning' | 'info'

    def __str__(self) -> str:
        return (f"[{self.pass_name}:{self.code}] ({self.severity}) "
                f"{self.where}: {self.detail}")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def errors(violations) -> List[Violation]:
    return [v for v in violations if v.severity == ERROR]


def split_severity(violations) -> Tuple[List[Violation], List[Violation]]:
    errs = errors(violations)
    rest = [v for v in violations if v.severity != ERROR]
    return errs, rest


class SentinelError(RuntimeError):
    """Raised by ``engine.validate=True`` / ``assert_clean`` when a pass
    finds error-severity violations. Carries the structured findings."""

    def __init__(self, violations):
        self.violations = list(violations)
        lines = [str(v) for v in self.violations]
        super().__init__(
            "Gopher Sentinel found %d violation(s):\n  %s"
            % (len(lines), "\n  ".join(lines)))


def assert_clean(violations) -> None:
    """Raise :class:`SentinelError` if any error-severity violation exists
    (warnings and infos pass)."""
    errs = errors(violations)
    if errs:
        raise SentinelError(errs)
