"""Gopher Sentinel Pass 1: the SPMD collective verifier.

Walks the ClosedJaxpr of a compiled BSP loop (the exact function the engine
jits — ``_run_batched`` on the local backend, the shard_map'd loop on the
mesh backend) and checks the three invariants the exchange stack's
deadlock-freedom and cache correctness rest on:

1. **cond-branch collective agreement.** Both branches of every ``lax.cond``
   must issue the same collective sequence — otherwise devices whose
   predicate disagrees post mismatched collectives and the mesh deadlocks.
   The phased exchange's dense-retry cond (engine.make_exchange_stages) is
   the deliberate exception: its branches differ (one dense ``all_to_all``
   vs. the tiered ``all_to_all`` + ``ppermute`` round-robin), which is only
   safe because the predicate is REPLICATED — it derives from a full-mesh
   ``psum``, so every device takes the same branch. The verifier therefore
   accepts a mismatched cond iff its predicate is provably uniform: a
   dataflow pass marks values produced from constants, or from full
   mesh-axis reductions (``psum``/``pmax``/``pmin`` with no
   ``axis_index_groups``), or from pure functions of already-uniform values;
   ``axis_index`` and the shard-local loop carries are the non-uniform
   sources. (Single-axis meshes: a psum over the one mesh axis of size > 1
   replicates fully.)

2. **axis binding.** Every collective's named axes must be bound by the
   enclosing ``shard_map`` mesh (vmap-bound names like the engine's
   ``vparts`` are resolved at trace time and never reach the jaxpr, so any
   surviving unknown name is a real bug). Collectives over a size-1 axis
   are trivially safe and excluded from the branch-agreement traces.

3. **trace-time-constant tier tables.** ``TierPlan``/``PhasedTierPlan`` key
   the module-level compiled-loop cache, so their fields must be concrete
   hashable host values — a tracer or device array smuggled into a plan
   silently breaks cache keying (unhashable → every run re-traces; worse, a
   leaked tracer fails at trace time with an opaque error far from the
   plan). :func:`check_plan_static` validates field types, hashability and
   geometry before the engine ever traces.

The walk runs on :class:`jax.sharding.AbstractMesh` shapes — no devices, no
subprocess — so the whole exchange×algorithm×mesh matrix is checkable on a
single-core CI box (see launch/sentinel.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.analysis.report import ERROR, Violation

# jaxpr primitive -> post-compile HLO opcode (the hloparse cross-check's
# translation table). pmax/pmin lower through the same all-reduce.
HLO_KIND = {
    "psum": "all-reduce", "pmax": "all-reduce", "pmin": "all-reduce",
    "ppermute": "collective-permute", "all_to_all": "all-to-all",
    "all_gather": "all-gather", "reduce_scatter": "reduce-scatter",
    "psum_invariant": "all-reduce",
}
_REDUCE_PRIMS = ("psum", "pmax", "pmin", "psum_invariant")
_COLLECTIVE_PRIMS = frozenset(HLO_KIND)


def _source_line(eqn) -> str:
    """file:line of the user frame that created this equation (best-effort —
    jax keeps it on eqn.source_info, a private-but-stable surface)."""
    try:
        from jax._src import source_info_util
        frame = source_info_util.user_frame(eqn.source_info)
        if frame is not None:
            return f"{frame.file_name}:{frame.start_line}"
    except Exception:
        pass
    return "<unknown>"


def _named_axes(eqn) -> Tuple[str, ...]:
    """The collective's named (mesh/vmap) axes; positional vmap axes (ints)
    are excluded — they reduce device-locally."""
    p = eqn.params
    if eqn.primitive.name in _REDUCE_PRIMS:
        raw = p.get("axes", ())
    else:
        raw = p.get("axis_name", ())
    if not isinstance(raw, (tuple, list)):
        raw = (raw,)
    return tuple(a for a in raw if isinstance(a, str))


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """One collective equation, located by its jaxpr path."""
    kind: str                            # jaxpr primitive name
    axes: Tuple[str, ...]                # named mesh axes it runs over
    shape: Tuple[int, ...]               # first result shape
    dtype: str
    perm: Optional[Tuple[Tuple[int, int], ...]]  # ppermute only
    path: str
    source: str = "<unknown>"

    def signature(self):
        """What both cond branches must agree on: everything except the
        location."""
        return (self.kind, self.axes, self.shape, self.dtype, self.perm)


@dataclasses.dataclass(frozen=True)
class CondReport:
    """One ``lax.cond`` whose branches were compared."""
    path: str
    source: str
    branch_traces: Tuple[Tuple[tuple, ...], ...]  # per-branch signatures
    branches_equal: bool
    predicate_uniform: bool

    @property
    def safe(self) -> bool:
        return self.branches_equal or self.predicate_uniform


@dataclasses.dataclass
class CollectiveSummary:
    """Pass 1 output: the loop's full collective inventory plus every cond
    verdict. ``counts`` covers only MESH-EFFECTIVE collectives (named axis
    of size > 1) — what actually hits the interconnect."""
    collectives: List[CollectiveOp]
    conds: List[CondReport]
    mesh_axes: Dict[str, int]

    @property
    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for c in self.collectives:
            out[c.kind] = out.get(c.kind, 0) + 1
        return out

    def expected_hlo_kinds(self) -> Tuple[str, ...]:
        """The HLO collective opcodes the compiled module must contain —
        the jaxpr-level half of the sentinel↔HLO cross-check."""
        return tuple(sorted({HLO_KIND[c.kind] for c in self.collectives}))

    def to_json(self) -> dict:
        return {
            "mesh_axes": dict(self.mesh_axes),
            "counts": self.counts,
            "expected_hlo_kinds": list(self.expected_hlo_kinds()),
            "conds": [
                {"path": c.path, "source": c.source,
                 "branches_equal": c.branches_equal,
                 "predicate_uniform": c.predicate_uniform,
                 "safe": c.safe,
                 "branch_traces": [[list(map(str, sig)) for sig in t]
                                   for t in c.branch_traces]}
                for c in self.conds],
        }


def _sub_jaxprs(params: dict, skip=()):
    """(key, open Jaxpr) pairs for every sub-jaxpr in an eqn's params —
    duck-typed so pjit/while/scan/shard_map/custom_* all walk the same way."""
    for k, v in params.items():
        if k in skip:
            continue
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for i, item in enumerate(vs):
            name = k if len(vs) == 1 else f"{k}[{i}]"
            if hasattr(item, "jaxpr") and hasattr(item.jaxpr, "eqns"):
                yield name, item.jaxpr       # ClosedJaxpr (delegates .eqns)
            elif hasattr(item, "eqns"):
                yield name, item             # open Jaxpr


def _is_literal(v) -> bool:
    return not hasattr(v, "count") and hasattr(v, "val") or \
        type(v).__name__ == "Literal"


class _Walker:
    """Recursive jaxpr visitor accumulating collectives, cond verdicts and
    violations. One instance per verified loop."""

    def __init__(self, mesh_axes: Dict[str, int]):
        self.mesh_axes = dict(mesh_axes)
        self.collectives: List[CollectiveOp] = []
        self.conds: List[CondReport] = []
        self.violations: List[Violation] = []

    # ---------------- uniformity dataflow ----------------
    def _uniform_vars(self, jaxpr, seed_uniform=frozenset()):
        """Forward pass over one (open) jaxpr: the set of vars provably
        REPLICATED across the mesh. Sources of non-uniformity: the jaxpr's
        invars (shard-local data, unless seeded), ``axis_index``, and
        ``iota``-free primitives never add any. Uniformity propagates
        through any primitive whose inputs are all uniform (a pure function
        of replicated values is replicated), and is CREATED by a full
        mesh-axis reduction (psum/pmax/pmin, no axis_index_groups)."""
        live_axes = {a for a, s in self.mesh_axes.items() if s > 1}
        uniform = set(v for v in jaxpr.constvars)
        uniform |= set(seed_uniform)

        def invar_uniform(v):
            return _is_literal(v) or v in uniform

        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            out_uniform = False
            if name == "axis_index":
                out_uniform = False
            elif (name in _REDUCE_PRIMS
                  and eqn.params.get("axis_index_groups") is None
                  and live_axes <= set(_named_axes(eqn))):
                out_uniform = True
            elif name == "pjit" or name == "closed_call":
                # propagate through the call: seed the callee's invars with
                # the call-site uniformity, lift its outvar verdicts back
                sub = dict(eqn.params).get("jaxpr")
                inner = getattr(sub, "jaxpr", sub)
                if inner is not None and hasattr(inner, "eqns"):
                    seed = {iv for iv, cv in zip(inner.invars, eqn.invars)
                            if invar_uniform(cv)}
                    inner_uniform = self._uniform_vars(inner, seed)
                    for ov, co in zip(inner.outvars, eqn.outvars):
                        if _is_literal(ov) or ov in inner_uniform:
                            uniform.add(co)
                    continue
                out_uniform = all(invar_uniform(v) for v in eqn.invars)
            else:
                out_uniform = all(invar_uniform(v) for v in eqn.invars)
            if out_uniform:
                uniform.update(eqn.outvars)
        return uniform

    # ---------------- collective trace extraction ----------------
    def _effective(self, eqn) -> bool:
        """Does this collective move data across devices? (named axis with
        size > 1 — size-1 axes are trace-time no-ops)."""
        axes = _named_axes(eqn)
        return any(self.mesh_axes.get(a, 0) > 1 for a in axes)

    def _record(self, eqn, path: str) -> CollectiveOp:
        shape = ()
        dtype = "?"
        if eqn.outvars:
            aval = getattr(eqn.outvars[0], "aval", None)
            if aval is not None:
                shape = tuple(getattr(aval, "shape", ()))
                dtype = str(getattr(aval, "dtype", "?"))
        perm = eqn.params.get("perm")
        if perm is not None:
            perm = tuple(tuple(p) for p in perm)
        return CollectiveOp(kind=eqn.primitive.name, axes=_named_axes(eqn),
                            shape=shape, dtype=dtype, perm=perm, path=path,
                            source=_source_line(eqn))

    def _branch_trace(self, jaxpr, path: str) -> Tuple[tuple, ...]:
        """The ordered mesh-effective collective signatures a branch issues,
        recursing through nested calls/loops (a while body's collectives
        run a data-dependent number of times; for agreement purposes the
        static sequence is what both branches must share)."""
        sigs: List[tuple] = []
        for i, eqn in enumerate(jaxpr.eqns):
            name = eqn.primitive.name
            if name in _COLLECTIVE_PRIMS:
                if self._effective(eqn):
                    sigs.append(self._record(eqn, f"{path}/{name}[{i}]")
                                .signature())
                continue
            if name == "cond":
                # nested cond: the branch's contribution is itself
                # branch-dependent; fold each nested branch trace in as a
                # structured element so outer comparison still works
                sub = tuple(self._branch_trace(b.jaxpr, f"{path}/cond[{i}]")
                            for b in eqn.params["branches"])
                sigs.append(("cond", sub))
                continue
            for key, sj in _sub_jaxprs(eqn.params):
                inner = self._branch_trace(sj, f"{path}/{name}[{i}].{key}")
                if name == "while" and inner:
                    sigs.append(("while", tuple(inner)))
                else:
                    sigs.extend(inner)
        return tuple(sigs)

    # ---------------- main walk ----------------
    def walk(self, jaxpr, path: str = "") -> None:
        uniform = self._uniform_vars(jaxpr)
        for i, eqn in enumerate(jaxpr.eqns):
            name = eqn.primitive.name
            here = f"{path}/{name}[{i}]"
            if name in _COLLECTIVE_PRIMS:
                op = self._record(eqn, here)
                unknown = [a for a in op.axes if a not in self.mesh_axes]
                if unknown:
                    self.violations.append(Violation(
                        pass_name="collectives", code="UNBOUND_AXIS",
                        where=f"{here} ({op.source})",
                        detail=(f"{name} over axis {unknown} is not bound "
                                "by the enclosing shard_map mesh "
                                f"{dict(self.mesh_axes)}; a vmap axis "
                                "should have been resolved at trace time "
                                "— this collective cannot lower"),
                        severity=ERROR))
                if self._effective(eqn):
                    self.collectives.append(op)
                continue
            if name == "cond":
                self._check_cond(eqn, here, uniform)
                # still walk branches for axis-binding + inventory
                for bi, br in enumerate(eqn.params["branches"]):
                    self.walk(br.jaxpr, f"{here}.branch[{bi}]")
                continue
            if name == "shard_map":
                mesh = eqn.params.get("mesh")
                inner_axes = dict(getattr(mesh, "shape", {}) or {})
                outer = self.mesh_axes
                self.mesh_axes = {**outer, **inner_axes}
                for key, sj in _sub_jaxprs(eqn.params, skip=("mesh",)):
                    self.walk(sj, f"{here}.{key}")
                self.mesh_axes = outer
                continue
            for key, sj in _sub_jaxprs(eqn.params):
                self.walk(sj, f"{here}.{key}")

    def _check_cond(self, eqn, path: str, uniform) -> None:
        branches = eqn.params["branches"]
        traces = tuple(self._branch_trace(b.jaxpr, f"{path}.branch[{bi}]")
                       for bi, b in enumerate(branches))
        equal = all(t == traces[0] for t in traces[1:])
        # the predicate is the cond's first invar (the branch index)
        pred = eqn.invars[0]
        pred_uniform = _is_literal(pred) or pred in uniform
        src = _source_line(eqn)
        if any(traces):  # only conds that issue collectives matter
            self.conds.append(CondReport(
                path=path, source=src, branch_traces=traces,
                branches_equal=equal, predicate_uniform=pred_uniform))
            if not equal and not pred_uniform:
                pretty = [" ; ".join(str(s) for s in t) or "<none>"
                          for t in traces]
                self.violations.append(Violation(
                    pass_name="collectives",
                    code="COND_COLLECTIVE_MISMATCH",
                    where=f"{path} ({src})",
                    detail=("lax.cond branches issue different collective "
                            "sequences and the predicate is not provably "
                            "replicated (no full mesh-axis psum on its "
                            "dataflow path): devices that disagree on the "
                            "predicate would post mismatched collectives "
                            "and deadlock the mesh. branch traces: "
                            + " || ".join(f"[{bi}] {p}"
                                          for bi, p in enumerate(pretty))),
                    severity=ERROR))


# ---------------- plan staticness (check c) ----------------

def _static_field_ok(value) -> bool:
    if isinstance(value, (int, float, str, bytes, bool, type(None))):
        return True
    if isinstance(value, tuple):
        return all(_static_field_ok(v) for v in value)
    return False


def check_plan_static(plan, where: str = "tier_plan") -> List[Violation]:
    """Verify a TierPlan/PhasedTierPlan is a trace-time constant fit to key
    the compiled-loop cache: every field a concrete hashable host value (no
    tracers, no device/NumPy arrays), hash() stable under copy, and the
    tier-table geometry self-consistent."""
    out: List[Violation] = []
    if plan is None:
        return out
    name = type(plan).__name__

    for f in dataclasses.fields(plan):
        v = getattr(plan, f.name)
        if isinstance(v, jax.core.Tracer):
            out.append(Violation(
                pass_name="collectives", code="PLAN_TRACER_LEAK",
                where=f"{where}.{f.name}",
                detail=(f"{name}.{f.name} holds a jax tracer ({v!r}): the "
                        "plan was built inside a traced function, so its "
                        "tables are not trace-time constants — the "
                        "compiled-loop cache cannot key on it and the "
                        "routing tables would bake a tracer into the "
                        "schedule. Build plans on the host, outside jit."),
                severity=ERROR))
            continue
        if isinstance(v, (np.ndarray, jax.Array)):
            out.append(Violation(
                pass_name="collectives", code="PLAN_UNHASHABLE_FIELD",
                where=f"{where}.{f.name}",
                detail=(f"{name}.{f.name} is a {type(v).__name__} — arrays "
                        "are unhashable, so this plan cannot key the "
                        "compiled-loop cache (every run would re-trace). "
                        "Store tables as bytes/tuples (see "
                        "TierPlan.tier_bytes)."),
                severity=ERROR))
            continue
        if not _static_field_ok(v):
            out.append(Violation(
                pass_name="collectives", code="PLAN_NON_STATIC_FIELD",
                where=f"{where}.{f.name}",
                detail=(f"{name}.{f.name} has non-static type "
                        f"{type(v).__name__}; plan fields must be concrete "
                        "hashable host values (int/bytes/str/tuple)"),
                severity=ERROR))
    if out:
        return out

    try:
        h1 = hash(plan)
        h2 = hash(dataclasses.replace(plan))
        if h1 != h2 or plan != dataclasses.replace(plan):
            raise ValueError("hash/eq not stable under copy")
    except Exception as e:
        out.append(Violation(
            pass_name="collectives", code="PLAN_UNHASHABLE",
            where=where,
            detail=(f"{name} is not stably hashable ({e}); the "
                    "compiled-loop cache keys on the plan"),
            severity=ERROR))
        return out

    # geometry self-consistency (cheap, catches byte-table corruption)
    P = plan.num_parts
    tables = (plan.phase_tier_bytes if hasattr(plan, "phase_tier_bytes")
              else (plan.tier_bytes,))
    for k, tb in enumerate(tables):
        if len(tb) != P * P:
            out.append(Violation(
                pass_name="collectives", code="PLAN_BAD_GEOMETRY",
                where=f"{where}.phase[{k}]" if len(tables) > 1 else where,
                detail=(f"tier table has {len(tb)} bytes, expected "
                        f"P*P = {P * P}"),
                severity=ERROR))
    if hasattr(plan, "boundaries"):
        b = plan.boundaries
        if len(b) != len(tables):
            out.append(Violation(
                pass_name="collectives", code="PLAN_BAD_GEOMETRY",
                where=f"{where}.boundaries",
                detail=(f"{len(tables)} phases but {len(b)} boundaries"),
                severity=ERROR))
        elif any(int(b[i]) >= int(b[i + 1]) for i in range(len(b) - 1)):
            out.append(Violation(
                pass_name="collectives", code="PLAN_BAD_GEOMETRY",
                where=f"{where}.boundaries",
                detail=f"phase boundaries must be strictly increasing: {b}",
                severity=ERROR))
    return out


# ---------------- engine-level entry points ----------------

def trace_loop(engine, num_queries: Optional[int] = None, gb_example=None):
    """The ClosedJaxpr of the exact BSP loop the engine would compile for
    this configuration — traced with shape-only inputs (works on
    AbstractMesh: no devices needed)."""
    from repro.core.blocks import graph_block
    if gb_example is not None:
        gb_shapes = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                     for k, v in gb_example.items()}
    else:
        gb_shapes = graph_block(engine.pg, as_spec=True)
    if engine.backend == "local":
        import functools
        fn = functools.partial(engine._run_batched, num_queries=num_queries)
    else:
        fn = engine._sharded_fn(num_queries=num_queries,
                                gb_example=gb_example)
    return jax.make_jaxpr(fn)(gb_shapes)


def verify_jaxpr(closed_jaxpr, mesh_axes: Optional[Dict[str, int]] = None):
    """Run the Pass 1 walk over a ClosedJaxpr. ``mesh_axes`` seeds the
    bound-axis environment for jaxprs NOT wrapped in a shard_map eqn (a
    shard_map inside the jaxpr binds its own mesh on entry).

    Returns (CollectiveSummary, [Violation])."""
    w = _Walker(mesh_axes or {})
    w.walk(closed_jaxpr.jaxpr)
    return (CollectiveSummary(collectives=w.collectives, conds=w.conds,
                              mesh_axes=dict(mesh_axes or {})),
            w.violations)


def verify_collectives(engine, num_queries: Optional[int] = None,
                       gb_example=None):
    """Pass 1 over one engine configuration: trace the loop, walk the
    jaxpr, and check the tier plan's staticness. Returns
    (CollectiveSummary, [Violation])."""
    violations = check_plan_static(getattr(engine, "tier_plan", None))
    if violations:
        # a non-static plan cannot be traced meaningfully — report it
        # instead of crashing inside make_jaxpr with an opaque error
        return CollectiveSummary([], [], {}), violations
    mesh_axes = {}
    if engine.backend == "shard_map" and engine.mesh is not None:
        mesh_axes = dict(engine.mesh.shape)
    jaxpr = trace_loop(engine, num_queries=num_queries,
                       gb_example=gb_example)
    summary, vs = verify_jaxpr(jaxpr, mesh_axes=mesh_axes)
    summary.mesh_axes = mesh_axes
    return summary, violations + vs
