"""Gopher Sentinel Pass 3: the Pallas kernel linter.

AST-level checks over the repo's Pallas kernels (``kernels/ops.py``,
``outbox_compact.py``, ``semiring_spmv.py``) for the four failure modes
that bit the pack/sweep path during development and that no runtime test
catches reliably (they only corrupt the padded tail, which the wrapper
slice usually hides — until a block boundary moves):

- **grid divisibility** (``PALLAS_GRID_DIVISIBILITY``): every grid
  dimension fed to ``pl.pallas_call`` must be an exact multiple count —
  the repo's idiom is the ceil-pad ``r_pad = -(-r // br) * br`` followed
  by ``grid = (r_pad // br,)``. A grid built from an *unpadded* size
  silently drops the ragged tail rows (Pallas truncates the last block's
  index map, it does not mask it).
- **unmasked stores** (``PALLAS_UNMASKED_STORE``): an output ref written
  only under ``@pl.when(c)`` with no complementary ``@pl.when(~c)`` or
  unconditional store leaves every lane of a predicated-off block
  uninitialized VMEM garbage, which escapes through the wrapper's
  ``[:r]`` slice whenever the garbage block is not the last one.
- **mask-multiply on values** (``PALLAS_MASK_MULTIPLY``): ``mask * vals``
  where ``vals`` came out of a ref is NOT a select — an active ±inf
  message (legal under min/max ⊕) times a 0.0 mask lane is NaN, and NaN
  poisons every reduction it meets. The pack kernels select with
  ``jnp.where(mask, vals, ident)`` instead; multiplying a mask into an
  iota (slot ids) is exempt — those are finite by construction.
- **reductions over unselected ref data** (``REDUCE_UNMASKED``, warning):
  ``jnp.min/max/sum`` over values gathered from a ref without a
  ``jnp.where`` select lets pad lanes (±inf / stale VMEM) into the fold.
- **input/output aliasing races** (``IO_ALIAS``): ``input_output_aliases``
  makes an input ref and an output ref the same buffer; a read of the
  input after the first write to its aliased output observes clobbered
  data within the block (and across blocks for any non-identity index
  map). No repo kernel aliases today; the rule keeps it that way unless
  someone proves the ordering.

The linter is intraprocedural with a small provenance lattice (``refread``
/ ``mask`` / ``iota`` / ``selected`` tags flowing through assignments), so
it stays exact on the repo's branch-free kernels while catching each
seeded negative with the offending file:line and kernel name.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Set

from repro.analysis.report import ERROR, WARNING, Violation

_REDUCES = {"min", "max", "sum", "amin", "amax", "nanmin", "nanmax"}
_IOTA_FNS = {"broadcasted_iota", "iota", "arange"}


def _unparse(node) -> str:
    try:
        return ast.unparse(node)
    except Exception:
        return ast.dump(node)


def _is_ceil_pad(expr, divisor) -> bool:
    """Match ``-(-X // B) * B`` with B == divisor (textually)."""
    if not (isinstance(expr, ast.BinOp) and isinstance(expr.op, ast.Mult)):
        return False
    left, right = expr.left, expr.right
    if _unparse(right) != _unparse(divisor):
        return False
    if not (isinstance(left, ast.UnaryOp) and isinstance(left.op, ast.USub)):
        return False
    inner = left.operand
    return (isinstance(inner, ast.BinOp)
            and isinstance(inner.op, ast.FloorDiv)
            and isinstance(inner.left, ast.UnaryOp)
            and isinstance(inner.left.op, ast.USub)
            and _unparse(inner.right) == _unparse(divisor))


def _call_attr(node) -> Optional[str]:
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
        return node.func.attr
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id
    return None


class _KernelLinter:
    """Lints one kernel function: provenance tags + store coverage."""

    def __init__(self, fn: ast.FunctionDef, filename: str):
        self.fn = fn
        self.filename = filename
        self.ref_params = {a.arg for a in fn.args.posonlyargs + fn.args.args
                           if a.arg.endswith("_ref")}
        self.env: Dict[str, Set[str]] = {}
        self.violations: List[Violation] = []
        # ref name -> list of (when_cond_src | None, lineno)
        self.stores: Dict[str, List] = {}

    def _where(self, node) -> str:
        return f"{self.filename}:{node.lineno} (kernel {self.fn.name})"

    # -------- provenance --------
    def tags(self, e) -> Set[str]:
        if isinstance(e, ast.Name):
            return set(self.env.get(e.id, ()))
        if isinstance(e, ast.Subscript):
            base = e.value
            if isinstance(base, ast.Name) and base.id in self.ref_params:
                return {"refread"}
            return self.tags(base)
        if isinstance(e, ast.Compare):
            return {"mask"}
        if isinstance(e, (ast.Tuple, ast.List)):
            out = set()
            for el in e.elts:
                out |= self.tags(el)
            return out
        if isinstance(e, ast.UnaryOp):
            return self.tags(e.operand)
        if isinstance(e, ast.BinOp):
            t = self.tags(e.left) | self.tags(e.right)
            if isinstance(e.op, (ast.BitAnd, ast.BitOr)):
                t |= {"mask"}
            return t
        if isinstance(e, ast.Call):
            attr = _call_attr(e)
            if attr in _IOTA_FNS:
                return {"iota"}
            if attr == "where" and len(e.args) >= 3:
                return ({"selected"} | self.tags(e.args[1])
                        | self.tags(e.args[2])) - {"mask"}
            if attr in ("astype", "reshape", "take"):
                t = set()
                if isinstance(e.func, ast.Attribute):
                    t |= self.tags(e.func.value)
                for a in e.args:
                    t |= self.tags(a)
                return t
            t = set()
            for a in e.args:
                t |= self.tags(a)
            if isinstance(e.func, ast.Attribute):
                t |= self.tags(e.func.value)
            return t
        if isinstance(e, ast.IfExp):
            return self.tags(e.body) | self.tags(e.orelse)
        return set()

    # -------- statement walk --------
    def run(self) -> List[Violation]:
        self._walk_body(self.fn.body, when_cond=None)
        self._check_store_coverage()
        return self.violations

    def _walk_body(self, body, when_cond) -> None:
        for stmt in body:
            if isinstance(stmt, ast.FunctionDef):
                cond = self._when_cond(stmt)
                self._walk_body(stmt.body,
                                when_cond=cond if cond is not None
                                else when_cond)
                continue
            if isinstance(stmt, (ast.If, ast.For, ast.While, ast.With)):
                self._walk_body(stmt.body, when_cond)
                self._walk_body(getattr(stmt, "orelse", []), when_cond)
                continue
            if isinstance(stmt, ast.Assign):
                self._check_expr(stmt.value)
                t = self.tags(stmt.value)
                for tgt in stmt.targets:
                    if isinstance(tgt, ast.Name):
                        self.env[tgt.id] = t
                    elif isinstance(tgt, ast.Subscript):
                        base = tgt.value
                        if (isinstance(base, ast.Name)
                                and base.id in self.ref_params):
                            self.stores.setdefault(base.id, []).append(
                                (when_cond, stmt.lineno))
                    elif isinstance(tgt, ast.Tuple):
                        for el in tgt.elts:
                            if isinstance(el, ast.Name):
                                self.env[el.id] = t
            elif isinstance(stmt, ast.Expr):
                self._check_expr(stmt.value)

    def _when_cond(self, fn: ast.FunctionDef) -> Optional[str]:
        """The pl.when predicate this inner function runs under (source
        text), or None if it is not a pl.when body."""
        for dec in fn.decorator_list:
            if isinstance(dec, ast.Call) and _call_attr(dec) == "when":
                return _unparse(dec.args[0]) if dec.args else ""
        return None

    # -------- expression rules --------
    def _check_expr(self, e) -> None:
        for node in ast.walk(e):
            if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult):
                lt, rt = self.tags(node.left), self.tags(node.right)
                for mt, vt, vnode in ((lt, rt, node.right),
                                      (rt, lt, node.left)):
                    if ("mask" in mt and "refread" in vt
                            and "iota" not in vt and "selected" not in vt):
                        self.violations.append(Violation(
                            pass_name="kernels", code="PALLAS_MASK_MULTIPLY",
                            where=self._where(node),
                            detail=(f"`{_unparse(node)}` multiplies a 0/1 "
                                    "mask into values read from a ref: if "
                                    "a masked-out lane holds ±inf (legal "
                                    "under min/max ⊕) the product is NaN "
                                    "and poisons the reduction. Select "
                                    "instead: jnp.where(mask, "
                                    f"{_unparse(vnode)}, identity)"),
                            severity=ERROR))
                        break
            elif isinstance(node, ast.Call):
                attr = _call_attr(node)
                if attr in _REDUCES and node.args:
                    t = self.tags(node.args[0])
                    if "refread" in t and "selected" not in t:
                        self.violations.append(Violation(
                            pass_name="kernels", code="REDUCE_UNMASKED",
                            where=self._where(node),
                            detail=(f"`{_unparse(node)[:80]}` reduces over "
                                    "values gathered from a ref with no "
                                    "jnp.where select on the reduced "
                                    "operand — pad/invalid lanes (±inf, "
                                    "stale VMEM) enter the fold; mask "
                                    "with jnp.where(valid, x, identity) "
                                    "first"),
                            severity=WARNING))

    # -------- store coverage rule --------
    def _check_store_coverage(self) -> None:
        for ref, events in self.stores.items():
            if any(cond is None for cond, _ in events):
                continue                        # unconditional write exists
            conds = [c for c, _ in events]
            covered = False
            for c in conds:
                neg = f"~{c}" if not c.startswith("~") else c[1:]
                # accept ~(c) spelled with or without parens
                alts = {neg, f"~({c})" if not c.startswith("~") else neg}
                if any(o in alts or o.replace("(", "").replace(")", "")
                       in {a.replace("(", "").replace(")", "")
                           for a in alts} for o in conds if o != c):
                    covered = True
                    break
            if not covered:
                lines = ", ".join(str(ln) for _, ln in events)
                self.violations.append(Violation(
                    pass_name="kernels", code="PALLAS_UNMASKED_STORE",
                    where=(f"{self.filename}:{events[0][1]} "
                           f"(kernel {self.fn.name}, output {ref})"),
                    detail=(f"{ref} is written only under "
                            f"@pl.when({conds[0]}) (lines {lines}) with no "
                            "complementary @pl.when(~...) or "
                            "unconditional store: blocks where the "
                            "predicate is false leave the output lanes "
                            "as uninitialized VMEM, which escapes the "
                            "wrapper's [:r] slice for any non-final "
                            "block. Add the complementary branch writing "
                            "the ⊕ identity"),
                    severity=ERROR))


class _WrapperLinter:
    """Lints one wrapper function's pallas_call sites: grid divisibility
    and input/output aliasing."""

    def __init__(self, fn: ast.FunctionDef, filename: str,
                 module_fns: Dict[str, ast.FunctionDef]):
        self.fn = fn
        self.filename = filename
        self.module_fns = module_fns
        self.assigns: Dict[str, ast.expr] = {}
        self.violations: List[Violation] = []

    def run(self) -> List[Violation]:
        for stmt in ast.walk(self.fn):
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name):
                self.assigns[stmt.targets[0].id] = stmt.value
        for node in ast.walk(self.fn):
            if (isinstance(node, ast.Call)
                    and _call_attr(node) == "pallas_call"):
                self._check_site(node)
        return self.violations

    def _where(self, node) -> str:
        return f"{self.filename}:{node.lineno} (wrapper {self.fn.name})"

    def _resolve(self, e):
        seen = set()
        while isinstance(e, ast.Name) and e.id in self.assigns \
                and e.id not in seen:
            seen.add(e.id)
            e = self.assigns[e.id]
        return e

    def _kwarg(self, call, name):
        for kw in call.keywords:
            if kw.arg == name:
                return kw.value
        return None

    def _check_site(self, call: ast.Call) -> None:
        self._check_grid(call)
        self._check_alias(call)

    def _check_grid(self, call: ast.Call) -> None:
        grid = self._kwarg(call, "grid")
        if grid is None:
            return
        grid = self._resolve(grid)
        dims = grid.elts if isinstance(grid, (ast.Tuple, ast.List)) else [grid]
        for dim in dims:
            dim_r = self._resolve(dim)
            if isinstance(dim_r, ast.Constant):
                continue                # static grid: shapes are literal too
            ok = False
            if (isinstance(dim_r, ast.BinOp)
                    and isinstance(dim_r.op, ast.FloorDiv)):
                num = self._resolve(dim_r.left)
                div = dim_r.right
                if _is_ceil_pad(num, div):
                    ok = True
                elif (isinstance(num, ast.Constant)
                      and isinstance(self._resolve(div), ast.Constant)
                      and isinstance(num.value, int)):
                    d = self._resolve(div).value
                    ok = isinstance(d, int) and d > 0 and num.value % d == 0
            if not ok:
                self.violations.append(Violation(
                    pass_name="kernels", code="PALLAS_GRID_DIVISIBILITY",
                    where=self._where(call),
                    detail=(f"grid dimension `{_unparse(dim)}` is not "
                            "provably an exact block count: the numerator "
                            "is not the ceil-pad of its divisor "
                            "(`x_pad = -(-x // b) * b` then "
                            "`grid = (x_pad // b,)`). A ragged size "
                            "silently truncates the trailing rows — pad "
                            "the operands to x_pad and slice [:x] after "
                            "the call"),
                    severity=ERROR))

    def _check_alias(self, call: ast.Call) -> None:
        alias = self._kwarg(call, "input_output_aliases")
        if alias is None:
            return
        pairs = []
        if isinstance(alias, ast.Dict):
            for k, v in zip(alias.keys, alias.values):
                if isinstance(k, ast.Constant) and isinstance(v, ast.Constant):
                    pairs.append((k.value, v.value))
        kern = self._kernel_fn(call)
        in_specs = self._kwarg(call, "in_specs")
        n_in = (len(in_specs.elts)
                if isinstance(in_specs, (ast.Tuple, ast.List)) else None)
        if kern is None or n_in is None or not pairs:
            self.violations.append(Violation(
                pass_name="kernels", code="IO_ALIAS",
                where=self._where(call),
                detail=("input_output_aliases present but the kernel/spec "
                        "mapping could not be resolved statically; aliased "
                        "buffers share memory across the grid — verify "
                        "read-before-write ordering by hand"),
                severity=WARNING))
            return
        params = [a.arg for a in kern.args.posonlyargs + kern.args.args]
        for in_idx, out_idx in pairs:
            if in_idx >= len(params) or n_in + out_idx >= len(params):
                continue
            in_ref, out_ref = params[in_idx], params[n_in + out_idx]
            reads = [n.lineno for n in ast.walk(kern)
                     if isinstance(n, ast.Subscript)
                     and isinstance(n.value, ast.Name)
                     and n.value.id == in_ref
                     and isinstance(n.ctx, ast.Load)]
            writes = [n.lineno for n in ast.walk(kern)
                      if isinstance(n, ast.Subscript)
                      and isinstance(n.value, ast.Name)
                      and n.value.id == out_ref
                      and isinstance(n.ctx, ast.Store)]
            late = [r for r in reads if writes and r > min(writes)]
            if late:
                self.violations.append(Violation(
                    pass_name="kernels", code="IO_ALIAS",
                    where=(f"{self.filename}:{late[0]} "
                           f"(kernel {kern.name})"),
                    detail=(f"{in_ref} is aliased onto {out_ref} "
                            "(input_output_aliases) but is read at line "
                            f"{late[0]} AFTER {out_ref} is first written "
                            f"at line {min(writes)} — the read observes "
                            "the clobbered output buffer. Read the input "
                            "fully before the first aliased store, or "
                            "drop the alias"),
                    severity=ERROR))

    def _kernel_fn(self, call: ast.Call) -> Optional[ast.FunctionDef]:
        if not call.args:
            return None
        target = call.args[0]
        if isinstance(target, ast.Call) and target.args:
            # functools.partial(_kern, ...)
            target = target.args[0]
        if isinstance(target, ast.Name):
            return self.module_fns.get(target.id)
        return None


def lint_source(src: str, filename: str = "<string>") -> List[Violation]:
    """Run Pass 3 over one module's source. Kernel functions are those
    with ``*_ref`` parameters; wrapper functions are those containing a
    ``pallas_call``."""
    try:
        tree = ast.parse(src, filename=filename)
    except SyntaxError as e:
        return [Violation(
            pass_name="kernels", code="PARSE_ERROR",
            where=f"{filename}:{e.lineno or 0}",
            detail=f"cannot parse: {e.msg}", severity=ERROR)]
    module_fns = {n.name: n for n in ast.walk(tree)
                  if isinstance(n, ast.FunctionDef)}
    out: List[Violation] = []
    for fn in module_fns.values():
        params = {a.arg for a in fn.args.posonlyargs + fn.args.args}
        if any(p.endswith("_ref") for p in params):
            out.extend(_KernelLinter(fn, filename).run())
        if any(isinstance(n, ast.Call) and _call_attr(n) == "pallas_call"
               for n in ast.walk(fn)):
            out.extend(_WrapperLinter(fn, filename, module_fns).run())
    return out


def lint_kernel_file(path: str) -> List[Violation]:
    with open(path, "r") as f:
        return lint_source(f.read(), filename=os.path.basename(path))


def lint_kernels(paths: Optional[List[str]] = None) -> List[Violation]:
    """Pass 3 over the repo's Pallas kernel modules (default: ops.py,
    outbox_compact.py, semiring_spmv.py, megastep.py)."""
    if paths is None:
        import repro.kernels as _k
        base = os.path.dirname(_k.__file__)
        paths = [os.path.join(base, n)
                 for n in ("ops.py", "outbox_compact.py", "semiring_spmv.py",
                           "megastep.py")]
    out: List[Violation] = []
    for p in paths:
        out.extend(lint_kernel_file(p))
    return out
