"""Gopher Sentinel Pass 2: the semiring law checker.

The exchange stack's correctness claims lean on algebra the code never
states in one place:

- **⊕ idempotence** (``a ⊕ a = a``) is what makes the tiered/phased
  dense-retry *unconditionally exact*: an overflowing superstep re-delivers
  every message through the dense route, so values already folded in by the
  partial tiered delivery get folded in twice — harmless iff ⊕ is
  idempotent. ``min`` (SSSP/BFS) and ``max`` (CC) are; ``sum`` (PageRank)
  is NOT, which is why the engine never retries a sum-combine superstep and
  why PageRank parity across exchange modes is allclose-only.
- **⊗ right-distributivity over ⊕** and **identity annihilation**
  (``extend(0̄, w) = 0̄``) are what let the local-fixpoint sweep reorder
  relaxations and pad ELL rows with the identity without changing fixpoints.
- **bitwise exactness**: ``min``/``max`` over float32 are order-independent
  bit-for-bit (the cross-mode bit-identical CI gates rely on this); float
  ``+`` is only associative to rounding, so ``plus_times`` programs get an
  ``allclose``-only exactness class.

This pass validates each registered semiring's *declared* properties
against exhaustive probes over a small adversarial domain (identities, ±,
zero, the actual ``COMBINE_IDENTITY`` pad values) at registration /
validate time — so a new semiring whose declaration overclaims (say,
declaring ``sum`` idempotent to sneak it onto the retry path) fails loudly
with the law and the counterexample, before anything compiles.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Callable, Dict, List, Tuple

from repro.analysis.report import ERROR, INFO, Violation

BITWISE = "bitwise"
ALLCLOSE = "allclose"


@dataclasses.dataclass(frozen=True)
class SemiringSpec:
    """One ⊕/⊗ pair as the execution path uses it: ``plus`` folds messages
    (inbox combine, outbox pack reduce), ``extend(value, weight)`` relaxes
    along an edge. ``plus_identity`` must equal the pad value routed for
    absent messages (messages.COMBINE_IDENTITY). The ``declares_*`` flags
    are the contract the probes check."""
    name: str
    combine: str                       # engine-side name: 'min'|'max'|'sum'
    plus: Callable[[float, float], float]
    extend: Callable[[float, float], float]
    plus_identity: float
    declares_idempotent: bool
    exactness: str                     # BITWISE | ALLCLOSE
    # probe domains — small but adversarial (identities, signs, zero)
    values: Tuple[float, ...]
    weights: Tuple[float, ...]


def _min(a, b):
    return a if a <= b else b


def _max(a, b):
    return a if a >= b else b


REGISTRY: Dict[str, SemiringSpec] = {
    "min_plus": SemiringSpec(
        name="min_plus", combine="min",
        plus=_min, extend=lambda v, w: v + w,
        plus_identity=math.inf, declares_idempotent=True,
        exactness=BITWISE,
        values=(math.inf, 0.0, 1.0, 2.5, 7.0, -3.0),
        weights=(0.0, 1.0, 2.5, 7.0)),
    "max_first": SemiringSpec(
        name="max_first", combine="max",
        plus=_max, extend=lambda v, w: v,   # left projection: labels hop
        plus_identity=-math.inf, declares_idempotent=True,
        exactness=BITWISE,
        values=(-math.inf, -3.0, 0.0, 1.0, 7.0, 512.0),
        weights=(0.0, 1.0, 2.5)),
    "plus_times": SemiringSpec(
        name="plus_times", combine="sum",
        plus=lambda a, b: a + b, extend=lambda v, w: v * w,
        plus_identity=0.0, declares_idempotent=False,
        exactness=ALLCLOSE,
        values=(0.0, 1.0, 2.5, -3.0, 0.5),
        weights=(0.0, 1.0, 0.5, 2.0)),
}

COMBINE_TO_SEMIRING = {s.combine: s.name for s in REGISTRY.values()}


def _eq(spec: SemiringSpec, a: float, b: float) -> bool:
    if a == b:
        return True
    if math.isnan(a) and math.isnan(b):
        return True
    if spec.exactness == ALLCLOSE:
        return abs(a - b) <= 1e-6 * max(1.0, abs(a), abs(b))
    return False


def _law(spec, code, law, lhs_desc, rhs_desc, lhs, rhs, binding, out):
    if not _eq(spec, lhs, rhs):
        out.append(Violation(
            pass_name="semiring", code=code,
            where=f"semiring '{spec.name}'",
            detail=(f"{law} fails: {lhs_desc} = {lhs!r} but {rhs_desc} = "
                    f"{rhs!r} at {binding} (exactness={spec.exactness}) — "
                    "the sweep/exchange path assumes this law; fix the "
                    "operator or its declaration in analysis.semiring"
                    ".REGISTRY"),
            severity=ERROR))
        return False
    return True


def probe_laws(spec: SemiringSpec) -> List[Violation]:
    """Exhaustively probe the algebraic laws the engine relies on over the
    spec's value/weight domain. Every failure names the law AND the
    counterexample binding."""
    out: List[Violation] = []
    V, W = spec.values, spec.weights
    p, x = spec.plus, spec.extend
    e = spec.plus_identity

    for a, b in itertools.product(V, repeat=2):
        _law(spec, "PLUS_NOT_COMMUTATIVE", "⊕ commutativity",
             f"({a} ⊕ {b})", f"({b} ⊕ {a})", p(a, b), p(b, a),
             f"a={a}, b={b}", out)
    for a, b, c in itertools.product(V, repeat=3):
        _law(spec, "PLUS_NOT_ASSOCIATIVE", "⊕ associativity",
             f"(({a} ⊕ {b}) ⊕ {c})", f"({a} ⊕ ({b} ⊕ {c}))",
             p(p(a, b), c), p(a, p(b, c)), f"a={a}, b={b}, c={c}", out)
    for a in V:
        _law(spec, "PLUS_IDENTITY_WRONG", "⊕ identity",
             f"({a} ⊕ 0̄)", f"{a}", p(a, e), a, f"a={a}, 0̄={e}", out)
    if spec.declares_idempotent:
        for a in V:
            ok = _law(spec, "PLUS_NOT_IDEMPOTENT", "⊕ idempotence",
                      f"({a} ⊕ {a})", f"{a}", p(a, a), a, f"a={a}", out)
            if not ok:
                # idempotence is THE dense-retry precondition — say so once
                out[-1] = dataclasses.replace(out[-1], detail=(
                    out[-1].detail + " [idempotent ⊕ is required for the "
                    "tiered/phased dense-retry exactness claim: retried "
                    "supersteps re-fold already-delivered messages]"))
                break
    for b, c in itertools.product(V, repeat=2):
        for w in W:
            _law(spec, "EXTEND_NOT_DISTRIBUTIVE",
                 "⊗ right-distributivity over ⊕",
                 f"extend({b} ⊕ {c}, {w})",
                 f"extend({b},{w}) ⊕ extend({c},{w})",
                 x(p(b, c), w), p(x(b, w), x(c, w)),
                 f"b={b}, c={c}, w={w}", out)
    for w in W:
        _law(spec, "IDENTITY_NOT_ANNIHILATING", "0̄ annihilation under ⊗",
             f"extend(0̄, {w})", "0̄", x(e, w), e, f"0̄={e}, w={w}", out)
    return out


def check_semiring(name: str) -> List[Violation]:
    """Pass 2 for one registered semiring: probe its laws and cross-check
    its ⊕ identity against the pad value the message plumbing routes
    (messages.COMBINE_IDENTITY) and the Pallas kernels' _IDENT table."""
    if name not in REGISTRY:
        return [Violation(
            pass_name="semiring", code="UNKNOWN_SEMIRING",
            where=f"semiring '{name}'",
            detail=(f"no SemiringSpec registered for '{name}' (known: "
                    f"{sorted(REGISTRY)}); register one in analysis."
                    "semiring.REGISTRY so its laws can be checked"),
            severity=ERROR)]
    spec = REGISTRY[name]
    out = probe_laws(spec)

    from repro.core.messages import COMBINE_IDENTITY
    routed = float(COMBINE_IDENTITY[spec.combine])
    if routed != spec.plus_identity:
        out.append(Violation(
            pass_name="semiring", code="IDENTITY_MISMATCH",
            where=f"semiring '{name}'",
            detail=(f"messages.COMBINE_IDENTITY['{spec.combine}'] = "
                    f"{routed} but the semiring's ⊕ identity is "
                    f"{spec.plus_identity}: absent-message pad slots would "
                    "perturb folded values"),
            severity=ERROR))
    try:
        from repro.kernels.semiring_spmv import _IDENT
        if name in _IDENT and float(_IDENT[name]) != spec.plus_identity:
            out.append(Violation(
                pass_name="semiring", code="IDENTITY_MISMATCH",
                where=f"semiring '{name}'",
                detail=(f"kernels.semiring_spmv._IDENT['{name}'] = "
                        f"{_IDENT[name]} disagrees with the ⊕ identity "
                        f"{spec.plus_identity}"),
                severity=ERROR))
    except ImportError:
        pass
    return out


def check_program(program, exchange: str = "auto") -> List[Violation]:
    """Pass 2 for one engine program: resolve its semiring (SemiringProgram
    declares one; PageRank-style programs are resolved via their ``combine``
    op), probe the laws, and — when the program rides an exchange mode with
    a dense-retry path (tiered/phased/auto) — record the exactness class
    the retry actually delivers."""
    name = getattr(program, "semiring", None)
    if name is None:
        combine = getattr(program, "combine", None)
        name = COMBINE_TO_SEMIRING.get(combine)
        if name is None:
            return [Violation(
                pass_name="semiring", code="UNKNOWN_SEMIRING",
                where=type(program).__name__,
                detail=("program declares neither .semiring nor a known "
                        f".combine (got {combine!r}); cannot check laws"),
                severity=ERROR)]
    out = check_semiring(name)
    spec = REGISTRY.get(name)
    if (spec is not None and not spec.declares_idempotent
            and exchange in ("tiered", "phased", "auto", "megastep")):
        out.append(Violation(
            pass_name="semiring", code="ALLCLOSE_ONLY",
            where=f"{type(program).__name__} (semiring '{name}')",
            detail=(f"⊕ = '{spec.combine}' is not idempotent, so the "
                    f"{exchange} path cannot re-deliver or re-associate "
                    "messages exactly — cross-mode parity for this "
                    "program is allclose-only, not bit-identical (the "
                    "engine never retries sum-combine supersteps, and the "
                    "fused megastep route re-associates the ⊕ reduction; "
                    "this is informational)"),
            severity=INFO))
    return out
