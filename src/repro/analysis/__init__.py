"""Gopher Sentinel — static analysis over the engine's riskiest constructs.

Three passes (see each module's docstring for the invariants):

- :mod:`repro.analysis.collectives` — Pass 1, the SPMD collective
  verifier: cond-branch collective agreement (or proven-replicated
  predicates), mesh axis binding, trace-time-constant tier plans.
- :mod:`repro.analysis.semiring` — Pass 2, the semiring law checker:
  ⊕/⊗ laws the sweep and the dense-retry exactness claim assume.
- :mod:`repro.analysis.kernel_lint` — Pass 3, the Pallas kernel linter:
  grid divisibility, store masking, ±inf-safe selects, aliasing races.

``GopherEngine(..., validate=True)`` runs Passes 1–2 on every compiled-loop
cache MISS (a hit means an identical configuration already passed);
``python -m repro.launch.sentinel`` runs the whole matrix plus Pass 3 and
the HLO cross-check in CI.
"""
from repro.analysis.collectives import (
    HLO_KIND,
    CollectiveOp,
    CollectiveSummary,
    CondReport,
    check_plan_static,
    trace_loop,
    verify_collectives,
    verify_jaxpr,
)
from repro.analysis.kernel_lint import (
    lint_kernel_file,
    lint_kernels,
    lint_source,
)
from repro.analysis.report import (
    ERROR,
    INFO,
    WARNING,
    SentinelError,
    Violation,
    assert_clean,
    errors,
    split_severity,
)
from repro.analysis.semiring import (
    REGISTRY,
    SemiringSpec,
    check_program,
    check_semiring,
    probe_laws,
)

__all__ = [
    "ERROR", "INFO", "WARNING", "HLO_KIND", "REGISTRY",
    "CollectiveOp", "CollectiveSummary", "CondReport", "SemiringSpec",
    "SentinelError", "Violation",
    "assert_clean", "check_plan_static", "check_program", "check_semiring",
    "errors", "lint_kernel_file", "lint_kernels", "lint_source",
    "probe_laws", "split_severity", "trace_loop", "validate_engine",
    "verify_collectives", "verify_jaxpr",
]


def validate_engine(engine, num_queries=None, gb_example=None):
    """Passes 1–2 for one engine configuration: collective verification
    over the exact loop about to be compiled, plan staticness, and the
    program's semiring laws. Raises :class:`SentinelError` naming every
    offending equation/field/law on error-severity findings; returns the
    full violation list (incl. warnings/infos) when clean."""
    violations = list(check_program(engine.program, engine.exchange))
    summary, vs = verify_collectives(engine, num_queries=num_queries,
                                     gb_example=gb_example)
    violations += vs
    assert_clean(violations)
    return violations
