"""Gopher Sentinel — static analysis over the engine's riskiest constructs.

Three passes (see each module's docstring for the invariants):

- :mod:`repro.analysis.collectives` — Pass 1, the SPMD collective
  verifier: cond-branch collective agreement (or proven-replicated
  predicates), mesh axis binding, trace-time-constant tier plans.
- :mod:`repro.analysis.semiring` — Pass 2, the semiring law checker:
  ⊕/⊗ laws the sweep and the dense-retry exactness claim assume.
- :mod:`repro.analysis.kernel_lint` — Pass 3, the Pallas kernel linter:
  grid divisibility, store masking, ±inf-safe selects, aliasing races.

``GopherEngine(..., validate=True)`` runs Passes 1–2 on every compiled-loop
cache MISS (a hit means an identical configuration already passed);
``python -m repro.launch.sentinel`` runs the whole matrix plus Pass 3 and
the HLO cross-check in CI.
"""
from repro.analysis.collectives import (
    HLO_KIND,
    CollectiveOp,
    CollectiveSummary,
    CondReport,
    check_plan_static,
    trace_loop,
    verify_collectives,
    verify_jaxpr,
)
from repro.analysis.kernel_lint import (
    lint_kernel_file,
    lint_kernels,
    lint_source,
)
from repro.analysis.report import (
    ERROR,
    INFO,
    WARNING,
    SentinelError,
    Violation,
    assert_clean,
    errors,
    split_severity,
)
from repro.analysis.semiring import (
    REGISTRY,
    SemiringSpec,
    check_program,
    check_semiring,
    probe_laws,
)

__all__ = [
    "ERROR", "INFO", "WARNING", "HLO_KIND", "REGISTRY",
    "CollectiveOp", "CollectiveSummary", "CondReport", "SemiringSpec",
    "SentinelError", "Violation",
    "assert_clean", "check_plan_static", "check_program", "check_semiring",
    "errors", "lint_kernel_file", "lint_kernels", "lint_source",
    "probe_laws", "split_severity", "trace_loop", "validate_engine",
    "validate_service", "validate_stage_fns",
    "verify_collectives", "verify_jaxpr",
]


def validate_engine(engine, num_queries=None, gb_example=None):
    """Passes 1–2 for one engine configuration: collective verification
    over the exact loop about to be compiled, plan staticness, and the
    program's semiring laws. Raises :class:`SentinelError` naming every
    offending equation/field/law on error-severity findings; returns the
    full violation list (incl. warnings/infos) when clean."""
    violations = list(check_program(engine.program, engine.exchange))
    summary, vs = verify_collectives(engine, num_queries=num_queries,
                                     gb_example=gb_example)
    violations += vs
    assert_clean(violations)
    return violations


def validate_stage_fns(engine, num_queries=None, gb_example=None,
                       phase=None):
    """Pass 1 over the STAGED STEPPED DRIVER's stage programs — the
    init/sweep/pack/route jits the checkpointed, traced, and recovery
    paths dispatch per superstep (Gopher Shield replays ride these, so
    their collectives must verify exactly like the fused loops'). Each
    stage is traced with shape-only inputs chained through ``eval_shape``
    (state from init, payload from pack, inbox from route) and walked by
    the Pass 1 verifier. Raises :class:`SentinelError` on error-severity
    findings; returns ({stage: CollectiveSummary}, [Violation])."""
    import jax
    import jax.numpy as jnp
    from repro.core.blocks import graph_block
    # recovery replays ride the COMPACT staged loop on these configurations
    # (engine._run_checkpointed drops to it: megastep has no staged
    # exchange at all, tiered/phased replay equivalent-bits over compact) —
    # verify the loop that actually runs
    if engine.exchange in ("megastep", "tiered", "phased"):
        prev = engine.exchange
        engine.exchange = "compact"
        try:
            return validate_stage_fns(engine, num_queries=num_queries,
                                      gb_example=gb_example, phase=phase)
        finally:
            engine.exchange = prev
    if gb_example is not None:
        gb_shapes = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                     for k, v in gb_example.items()}
    else:
        gb_shapes = graph_block(engine.pg, as_spec=True)
    fns = engine._traced_stage_fns(num_queries, phase)
    state_s = jax.eval_shape(fns["init"], gb_shapes)
    pack_s = jax.eval_shape(fns["pack"], gb_shapes, state_s)
    payload_s = pack_s[0]
    inbox_s, _ = jax.eval_shape(fns["route"], gb_shapes, payload_s)
    step_s = jax.ShapeDtypeStruct((), jnp.int32)
    mesh_axes = (dict(engine.mesh.shape)
                 if engine.backend == "shard_map" and engine.mesh is not None
                 else {})
    violations = list(check_plan_static(getattr(engine, "tier_plan", None)))
    summaries = {}
    for name, jaxpr in (
            ("init", jax.make_jaxpr(fns["init"])(gb_shapes)),
            ("sweep", jax.make_jaxpr(fns["sweep"])(
                gb_shapes, state_s, inbox_s, step_s)),
            ("pack", jax.make_jaxpr(fns["pack"])(gb_shapes, state_s)),
            ("route", jax.make_jaxpr(fns["route"])(gb_shapes, payload_s))):
        summary, vs = verify_jaxpr(jaxpr, mesh_axes=mesh_axes)
        summaries[name] = summary
        violations += vs
    assert_clean(violations)
    return summaries, violations


def validate_service(svc, graphs=None, families=("reach",), qs=(1,),
                     stage_fns: bool = True):
    """Sentinel over a GraphQueryService's pooled BATCHED serving loops:
    for every (graph, family, Q-bucket) the exact query-batched engine
    configuration ``drain()`` would dispatch is validated (collective
    agreement, plan staticness, semiring laws), with the real query-array
    entries (``qseed``/``qinit``) in the traced block so the jaxpr matches
    the serving shapes bit-for-bit. With ``stage_fns=True`` the staged
    stepped driver each engine's recovery replay would use is verified
    too. Raises :class:`SentinelError` on any error-severity finding;
    returns {(graph, family, Q): [Violation]}."""
    import jax.numpy as jnp
    from repro.serving.batched import (ppr_query_seed,
                                       reachability_query_init)
    out = {}
    for name in (sorted(svc.graphs) if graphs is None else graphs):
        pg = svc.graphs[name]
        for family in families:
            for Q in qs:
                eng = svc._engine(name, family, Q)
                gb = dict(svc._graph_block(name))
                if family == "ppr":
                    gb["qseed"] = jnp.asarray(ppr_query_seed(pg, [0] * Q))
                else:
                    gb["qinit"] = jnp.asarray(
                        reachability_query_init(pg, [[0]] * Q))
                vs = validate_engine(eng, num_queries=Q, gb_example=gb)
                if stage_fns:
                    _, svs = validate_stage_fns(eng, num_queries=Q,
                                                gb_example=gb)
                    vs = vs + svs
                out[(name, family, Q)] = vs
    return out
