"""Gopher Scope: a labeled metrics registry (counters, gauges, histograms).

Prometheus-shaped but dependency-free: a metric is ``(name, sorted label
items)``; counters accumulate, gauges overwrite, histograms keep a bounded
sample window plus exact count/sum so percentiles stay O(window) and a
long-running service can't grow without limit.

Producers (all host-side, all O(1) per run/request — there is nothing to
disable because nothing touches compiled code):

  * the engine feeds per-run superstep/wire/spill/retry/escalation totals
    (``GopherEngine._finish``);
  * ``core.tiers`` feeds plan-build counts and EWMA-drift gauges
    (how far observations moved the traffic profile — the signal that a
    plan rebuild is due);
  * ``core.blocks.patch_host_block`` feeds zero-repack patch counters;
  * the serving loop feeds QPS, per-query latency, cache hits, landmark
    staleness and delta-apply latency (``GraphQueryService.stats()``).

``snapshot()`` renders the whole registry as a plain dict (JSON-ready);
``launch/scope.py`` and the BENCH drivers persist it next to their JSON.
"""
from __future__ import annotations

import dataclasses
import json
import threading
from collections import deque
from typing import Dict, Optional, Tuple

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "default_registry", "set_default_registry", "validate_metrics"]

_Key = Tuple[str, Tuple[Tuple[str, str], ...]]


def _key(name: str, labels: Optional[dict]) -> _Key:
    if not labels:
        return (name, ())
    return (name, tuple(sorted((str(k), str(v)) for k, v in labels.items())))


def _render(key: _Key) -> str:
    name, items = key
    if not items:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in items) + "}"


@dataclasses.dataclass
class Counter:
    value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


@dataclasses.dataclass
class Gauge:
    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Bounded-window histogram: exact count/sum forever, percentiles over
    the most recent ``window`` observations."""

    def __init__(self, window: int = 8192):
        self.count = 0
        self.sum = 0.0
        self.window: deque = deque(maxlen=window)

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.window.append(v)

    def percentile(self, pct: float) -> float:
        if not self.window:
            return 0.0
        return float(np.percentile(np.asarray(self.window), pct))

    def summary(self) -> dict:
        return dict(count=self.count, sum=self.sum,
                    mean=self.sum / self.count if self.count else 0.0,
                    p50=self.percentile(50), p95=self.percentile(95),
                    p99=self.percentile(99))


class MetricsRegistry:
    """Thread-safe named metric store. Metrics are created on first touch;
    repeated lookups return the same object, so hot paths can cache the
    handle (``m = reg.counter(...)`` once, ``m.inc()`` per event)."""

    def __init__(self, histogram_window: int = 8192):
        self._lock = threading.Lock()
        self._histogram_window = histogram_window
        self._counters: Dict[_Key, Counter] = {}
        self._gauges: Dict[_Key, Gauge] = {}
        self._histograms: Dict[_Key, Histogram] = {}

    def counter(self, name: str, labels: Optional[dict] = None) -> Counter:
        k = _key(name, labels)
        with self._lock:
            m = self._counters.get(k)
            if m is None:
                m = self._counters[k] = Counter()
            return m

    def gauge(self, name: str, labels: Optional[dict] = None) -> Gauge:
        k = _key(name, labels)
        with self._lock:
            m = self._gauges.get(k)
            if m is None:
                m = self._gauges[k] = Gauge()
            return m

    def histogram(self, name: str, labels: Optional[dict] = None) -> Histogram:
        k = _key(name, labels)
        with self._lock:
            m = self._histograms.get(k)
            if m is None:
                m = self._histograms[k] = Histogram(self._histogram_window)
            return m

    # ---------------- export ----------------
    def snapshot(self) -> dict:
        """The whole registry as a plain JSON-ready dict."""
        with self._lock:
            return {
                "format": "gopher-metrics-v1",
                "counters": {_render(k): c.value
                             for k, c in sorted(self._counters.items())},
                "gauges": {_render(k): g.value
                           for k, g in sorted(self._gauges.items())},
                "histograms": {_render(k): h.summary()
                               for k, h in sorted(self._histograms.items())},
            }

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.snapshot(), indent=indent)

    def write_json(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json(indent=1))
        return path

    def clear(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


_default = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process default registry every producer writes to unless handed
    its own (the engine/service take a ``metrics=`` override)."""
    return _default


def set_default_registry(reg: Optional[MetricsRegistry]) -> MetricsRegistry:
    global _default
    _default = reg if reg is not None else MetricsRegistry()
    return _default


def validate_metrics(obj: dict) -> None:
    """Assert ``obj`` is a structurally valid gopher-metrics snapshot (the
    CI smoke's schema check)."""
    assert isinstance(obj, dict), "metrics snapshot must be a JSON object"
    assert obj.get("format") == "gopher-metrics-v1", \
        f"bad format tag {obj.get('format')!r}"
    for sect in ("counters", "gauges", "histograms"):
        assert sect in obj and isinstance(obj[sect], dict), \
            f"missing section {sect!r}"
    for k, v in obj["counters"].items():
        assert isinstance(v, (int, float)), f"counter {k}: non-numeric"
        assert v >= 0, f"counter {k}: negative ({v})"
    for k, v in obj["gauges"].items():
        assert isinstance(v, (int, float)), f"gauge {k}: non-numeric"
    for k, h in obj["histograms"].items():
        for f in ("count", "sum", "mean", "p50", "p95", "p99"):
            assert f in h and isinstance(h[f], (int, float)), \
                f"histogram {k}: missing/bad {f!r}"
        assert h["count"] >= 0
        assert h["p50"] <= h["p95"] <= h["p99"], \
            f"histogram {k}: percentiles not monotone"
