"""Gopher Scope: unified tracing, metrics and skew analytics.

Three host-side layers with one rule — zero cost when disabled, and never
a sync inside compiled loops:

  trace.py    nested-span tracer (run → phase → superstep → stage) with
              Chrome-trace/Perfetto + JSONL export; the engine's traced
              stepped driver emits into it
  metrics.py  labeled counters/gauges/histograms; engine, tier planner,
              block patcher and serving loop all feed the process default
              registry; snapshottable as a plain dict
  skew.py     partition imbalance / straggler scores off live telemetry —
              the input ROADMAP's Gopher Balance consumes
"""
from repro.obs.metrics import (MetricsRegistry, default_registry,
                               set_default_registry, validate_metrics)
from repro.obs.skew import (SkewTracker, imbalance_score, pair_skew,
                            skew_report)
from repro.obs.trace import (NOOP, Span, Tracer, get_tracer, set_tracer,
                             validate_chrome_trace)

__all__ = [
    "Tracer", "Span", "NOOP", "get_tracer", "set_tracer",
    "validate_chrome_trace",
    "MetricsRegistry", "default_registry", "set_default_registry",
    "validate_metrics",
    "imbalance_score", "pair_skew", "skew_report", "SkewTracker",
]
