"""Gopher Scope: lightweight host-side span tracing.

The engine's BSP loop is normally ONE compiled ``lax.while_loop`` — nothing
host-side can see where a run's time goes, which is exactly the blind spot
ROADMAP's Gopher Hot (plan-pass overhead at small frontiers) and Gopher
Balance (straggler attribution) both hit. A :class:`Tracer` gives the host
a nested-span clock:

    run → phase → superstep → {plan, pack, exchange, sweep, halt-vote}

with wall-clock durations, per-span attributes (dispatch counts, wire
slots, changed counts), and three export formats:

  * ``chrome_trace()`` — Chrome-trace / Perfetto JSON (``ph: "X"`` complete
    events; load in ``ui.perfetto.dev`` or ``chrome://tracing``);
  * ``jsonl()`` / ``write_jsonl()`` — one event per line for ad-hoc grep;
  * ``Span`` objects directly (``tracer.spans``) for the text timeline in
    ``launch/scope.py``.

Cost model — the part that must hold for the engine to thread a tracer
through its dispatch points unconditionally:

  * DISABLED (``Tracer(enabled=False)`` or the module ``NOOP`` singleton):
    ``span()`` returns one shared no-op context manager; entering/exiting
    it is two attribute-free method calls and no allocation. The engine
    additionally never switches off the compiled fused loop unless the
    tracer is enabled, so the hot path keeps zero host syncs inside
    compiled loops.
  * ENABLED: each span costs one ``perf_counter_ns`` pair and one small
    object append. ``boundary_sync=True`` additionally calls
    ``jax.block_until_ready`` on stage outputs so per-stage wall-clock is
    honest (otherwise a span measures dispatch time and the halt-vote
    span — the host read of the vote — absorbs the device queue).

``jax_profiler_dir`` arms the optional device-side capture: the run span
wraps itself in ``jax.profiler.trace`` so a Perfetto-compatible XLA trace
lands next to the host spans.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, List, Optional

__all__ = ["Span", "Tracer", "NOOP", "get_tracer", "set_tracer",
           "validate_chrome_trace"]


@dataclasses.dataclass
class Span:
    """One closed span. Times are ns from the tracer's epoch."""
    name: str
    t0_ns: int
    dur_ns: int
    depth: int
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_chrome(self) -> dict:
        return {"name": self.name, "ph": "X", "pid": 0, "tid": 0,
                "ts": self.t0_ns / 1e3, "dur": self.dur_ns / 1e3,
                "cat": "gopher", "args": self.args}


class _NoopSpan:
    """Shared no-op context manager: the disabled tracer's entire cost."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **kw):                      # attribute writes vanish too
        return self


_NOOP_SPAN = _NoopSpan()


class _LiveSpan:
    __slots__ = ("tracer", "name", "t0_ns", "depth", "args")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.args = args
        self.t0_ns = 0
        self.depth = 0

    def __enter__(self):
        t = self.tracer
        self.depth = len(t._stack)
        t._stack.append(self)
        self.t0_ns = time.perf_counter_ns() - t._epoch_ns
        return self

    def __exit__(self, *exc):
        now = time.perf_counter_ns() - self.tracer._epoch_ns
        top = self.tracer._stack.pop()
        assert top is self, f"span {self.name!r} closed out of order"
        self.tracer.spans.append(Span(name=self.name, t0_ns=self.t0_ns,
                                      dur_ns=now - self.t0_ns,
                                      depth=self.depth, args=self.args))
        return False

    def set(self, **kw):
        """Attach attributes mid-span (wire counts known only after the
        stage ran)."""
        self.args.update(kw)
        return self


class Tracer:
    """Nested-span tracer. ``enabled=False`` degenerates every call to the
    shared no-op span — the engine can hold a tracer unconditionally."""

    def __init__(self, enabled: bool = True, boundary_sync: bool = False,
                 jax_profiler_dir: Optional[str] = None):
        self.enabled = enabled
        self.boundary_sync = boundary_sync
        self.jax_profiler_dir = jax_profiler_dir
        self.spans: List[Span] = []
        self.counts: Dict[str, int] = {}
        self._stack: List[_LiveSpan] = []
        self._epoch_ns = time.perf_counter_ns()

    # ---------------- recording ----------------
    def span(self, name: str, **args):
        if not self.enabled:
            return _NOOP_SPAN
        return _LiveSpan(self, name, args)

    def count(self, name: str, n: int = 1) -> None:
        """Dispatch counters (host-side calls into jit'd stages)."""
        if self.enabled:
            self.counts[name] = self.counts.get(name, 0) + n

    def sync(self, x):
        """Boundary mode: block on a stage's outputs so the enclosing span's
        wall-clock covers device execution, not just dispatch. Identity when
        boundary_sync is off."""
        if self.enabled and self.boundary_sync and x is not None:
            import jax
            jax.block_until_ready(x)
        return x

    def profile_ctx(self):
        """The optional device-side jax.profiler capture around a run span
        (no-op context unless ``jax_profiler_dir`` was armed)."""
        if self.enabled and self.jax_profiler_dir:
            import jax
            return jax.profiler.trace(self.jax_profiler_dir)
        import contextlib
        return contextlib.nullcontext()

    # ---------------- invariants ----------------
    @property
    def balanced(self) -> bool:
        """True iff every opened span has been closed."""
        return not self._stack

    def open_spans(self) -> List[str]:
        return [s.name for s in self._stack]

    # ---------------- export ----------------
    def chrome_trace(self) -> dict:
        """Chrome-trace JSON object (Perfetto-loadable)."""
        return {
            "displayTimeUnit": "ms",
            "traceEvents": [s.to_chrome() for s in self.spans],
            "otherData": {"format": "gopher-scope-v1",
                          "counts": dict(self.counts)},
        }

    def write_chrome_trace(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path

    def jsonl(self) -> str:
        lines = [json.dumps({"name": s.name, "t0_us": s.t0_ns / 1e3,
                             "dur_us": s.dur_ns / 1e3, "depth": s.depth,
                             "args": s.args})
                 for s in self.spans]
        return "\n".join(lines)

    def write_jsonl(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.jsonl() + ("\n" if self.spans else ""))
        return path

    def clear(self) -> None:
        assert self.balanced, f"clear with open spans: {self.open_spans()}"
        self.spans.clear()
        self.counts.clear()
        self._epoch_ns = time.perf_counter_ns()


#: the module no-op tracer — what the engine holds when no tracer is given.
NOOP = Tracer(enabled=False)

_default: Tracer = NOOP


def get_tracer() -> Tracer:
    """The process default tracer (NOOP unless set_tracer armed one)."""
    return _default


def set_tracer(tracer: Optional[Tracer]) -> Tracer:
    """Install (or, with None, disarm) the process default tracer."""
    global _default
    _default = tracer if tracer is not None else NOOP
    return _default


# ---------------- schema validation (CI smoke) ----------------

def validate_chrome_trace(obj: dict) -> None:
    """Assert ``obj`` is a structurally valid gopher-scope Chrome trace:
    the envelope keys exist, every event is a complete ('X') event with
    numeric ts/dur, and span nesting is consistent (children lie inside
    their parents). Raises AssertionError with a pointed message."""
    assert isinstance(obj, dict), "trace must be a JSON object"
    assert "traceEvents" in obj, "missing traceEvents"
    evs = obj["traceEvents"]
    assert isinstance(evs, list) and evs, "traceEvents empty"
    for i, e in enumerate(evs):
        for k in ("name", "ph", "ts", "dur", "pid", "tid"):
            assert k in e, f"event {i} missing {k!r}"
        assert e["ph"] == "X", f"event {i}: ph {e['ph']!r} != 'X'"
        assert isinstance(e["ts"], (int, float)), f"event {i}: ts not numeric"
        assert isinstance(e["dur"], (int, float)) and e["dur"] >= 0, \
            f"event {i}: bad dur"
    # nesting: sort by start; a later-starting span either nests inside or
    # begins after every currently-open span (no partial overlap on a tid)
    spans = sorted(((e["ts"], e["ts"] + e["dur"], e["name"]) for e in evs),
                   key=lambda s: (s[0], -s[1]))
    stack: list = []
    eps = 1e-3   # µs slack: ns->µs rounding in the exporter
    for t0, t1, name in spans:
        while stack and t0 >= stack[-1][1] - eps:
            stack.pop()
        assert not stack or t1 <= stack[-1][1] + eps, \
            f"span {name!r} [{t0},{t1}] overlaps parent " \
            f"{stack[-1][2]!r} [{stack[-1][0]},{stack[-1][1]}]"
        stack.append((t0, t1, name))
