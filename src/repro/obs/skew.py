"""Gopher Scope: partition skew & straggler analytics.

GoFFish's central empirical claim is that time-to-completion is gated by
the SLOWEST sub-graph per superstep (paper Fig. 5; the partitioning-
strategies follow-up attacks exactly this). The engine already accumulates
the raw signals — per-partition cumulative local sweep iterations
(``Telemetry.local_iters``), per-pair packed slot counts
(``Telemetry.pair_slots``) and the host block's ``wire_ewma`` traffic
profile — this module turns them into the scores Gopher Balance will
consume to decide WHICH sub-graphs to migrate:

  * :func:`imbalance_score` — the classic straggler ratio max/mean of the
    per-partition load vector (1.0 = perfectly balanced; the superstep
    barrier makes makespan ∝ max while resources ∝ mean, so the score IS
    the wasted-speedup factor);
  * :func:`skew_report` — per-run report off a Telemetry: compute skew from
    local_iters, wire skew from the per-pair counts (row = send load,
    column = receive load), and the argmax partitions to migrate from;
  * :class:`SkewTracker` — the serving-loop accumulator: folds every
    batch's Telemetry and answers with a live report
    (``GraphQueryService.stats()`` exposes it per graph).

Everything here is O(P²) numpy on post-run host telemetry — nothing
touches compiled code.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

__all__ = ["imbalance_score", "pair_skew", "skew_report", "SkewTracker"]


def imbalance_score(load: Optional[np.ndarray]) -> float:
    """max/mean of a per-partition load vector; 1.0 when balanced, and the
    factor by which the superstep barrier stretches makespan past the
    balanced ideal. 0.0 for empty/all-zero load (nothing ran)."""
    if load is None:
        return 0.0
    v = np.asarray(load, np.float64).reshape(-1)
    if v.size == 0 or not np.any(v > 0):
        return 0.0
    return float(v.max() / v.mean())


def pair_skew(pair_slots: Optional[np.ndarray]) -> dict:
    """Wire-side skew off a (P, P) per-pair slot matrix (Telemetry.pair_slots
    or a block's wire_ewma): send/receive imbalance scores and the heaviest
    pair's share of total traffic."""
    if pair_slots is None:
        return dict(send_imbalance=0.0, recv_imbalance=0.0,
                    max_pair_frac=0.0)
    m = np.asarray(pair_slots, np.float64)
    total = float(m.sum())
    return dict(
        send_imbalance=round(imbalance_score(m.sum(1)), 4),
        recv_imbalance=round(imbalance_score(m.sum(0)), 4),
        max_pair_frac=round(float(m.max()) / total, 4) if total > 0 else 0.0)


def skew_report(telemetry=None, local_iters: Optional[np.ndarray] = None,
                pair_slots: Optional[np.ndarray] = None,
                part_seconds: Optional[np.ndarray] = None) -> dict:
    """The per-run skew report. Pass a ``Telemetry`` (preferred — reads
    local_iters + pair_slots + part_seconds off it) or the raw arrays.

    Keys:
      imbalance       max/mean of per-partition sweep iterations — the
                      straggler score (Telemetry.skew() returns this dict)
      straggler       partition index carrying the max load
      cv              coefficient of variation of the load vector
      mean_iters / max_iters
      wire            pair_skew() of the per-pair slot matrix (None-safe)
      time_imbalance  max/mean of per-partition WALL seconds (Gopher
      time_straggler  Balance's channel: an injected or physical straggler
                      shows up here even when iteration counts stay flat).
                      0.0 / -1 when the run carried no time channel (fused
                      single-dispatch loops).
    """
    if telemetry is not None:
        local_iters = telemetry.local_iters
        pair_slots = telemetry.pair_slots if pair_slots is None \
            else pair_slots
        if part_seconds is None:
            part_seconds = getattr(telemetry, "part_seconds", None)
    li = (np.asarray(local_iters, np.float64).reshape(-1)
          if local_iters is not None else np.zeros(0))
    if li.size and np.any(li > 0):
        rep = dict(imbalance=round(float(li.max() / li.mean()), 4),
                   straggler=int(li.argmax()),
                   cv=round(float(li.std() / max(li.mean(), 1e-12)), 4),
                   mean_iters=round(float(li.mean()), 2),
                   max_iters=int(li.max()))
    else:
        rep = dict(imbalance=0.0, straggler=-1, cv=0.0, mean_iters=0.0,
                   max_iters=0)
    ps = (np.asarray(part_seconds, np.float64).reshape(-1)
          if part_seconds is not None else np.zeros(0))
    if ps.size and np.any(ps > 0):
        rep["time_imbalance"] = round(float(ps.max() / ps.mean()), 4)
        rep["time_straggler"] = int(ps.argmax())
        rep["part_seconds"] = [round(float(x), 6) for x in ps]
    else:
        rep["time_imbalance"] = 0.0
        rep["time_straggler"] = -1
    rep["wire"] = pair_skew(pair_slots)
    return rep


class SkewTracker:
    """Accumulates per-run telemetry into a live per-partition load picture
    — the serving loop keeps one per graph and Gopher Balance's migration
    policy reads it. Loads ACCUMULATE (cumulative sweep iterations are the
    makespan currency); ``decay`` < 1 lets a long-lived service forget old
    shape so a migrated hotspot stops dominating the score."""

    def __init__(self, num_parts: Optional[int] = None, decay: float = 1.0):
        self.decay = float(decay)
        self.runs = 0
        self.liters: Optional[np.ndarray] = (
            np.zeros(num_parts, np.float64) if num_parts else None)
        self.pair_slots: Optional[np.ndarray] = None
        # wall-seconds channel (Telemetry.part_seconds): Gopher Balance's
        # straggler evidence — None until a host-stepped run reports it
        self.seconds: Optional[np.ndarray] = None

    def observe(self, telemetry) -> None:
        li = np.asarray(telemetry.local_iters, np.float64).reshape(-1)
        if self.liters is None:
            self.liters = np.zeros(li.size, np.float64)
        if li.size == self.liters.size:          # a repartition resets shape
            self.liters = self.decay * self.liters + li
        else:
            self.liters = li.copy()
            self.pair_slots = None
            self.seconds = None
        if telemetry.pair_slots is not None:
            ps = np.asarray(telemetry.pair_slots, np.float64)
            if self.pair_slots is None or self.pair_slots.shape != ps.shape:
                self.pair_slots = np.zeros_like(ps)
            self.pair_slots = self.decay * self.pair_slots + ps
        sec = getattr(telemetry, "part_seconds", None)
        if sec is not None:
            sec = np.asarray(sec, np.float64).reshape(-1)
            if self.seconds is None or self.seconds.size != sec.size:
                self.seconds = np.zeros_like(sec)
            self.seconds = self.decay * self.seconds + sec
        self.runs += 1

    def imbalance(self) -> float:
        return round(imbalance_score(self.liters), 4)

    def time_imbalance(self) -> float:
        return round(imbalance_score(self.seconds), 4)

    def report(self) -> dict:
        rep = skew_report(local_iters=self.liters,
                          pair_slots=self.pair_slots,
                          part_seconds=self.seconds)
        rep["runs"] = self.runs
        if self.liters is not None:
            rep["per_partition_iters"] = [round(float(x), 1)
                                          for x in self.liters]
        return rep
