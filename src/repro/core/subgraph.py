"""Sub-graph (meta-graph) structure utilities.

The paper's central object: treat each partition-local weakly-connected
component as a *meta-vertex*; remote edges connect meta-vertices across
partitions. Traversal algorithms then take O(meta-graph diameter) supersteps
instead of O(vertex diameter) — these helpers compute both quantities so the
tests and benchmarks can verify that claim (paper §3.3, Fig 4c).
"""
from __future__ import annotations

import numpy as np
import scipy.sparse as sp
import scipy.sparse.csgraph as csgraph

from repro.gofs.formats import PAD, Graph, PartitionedGraph


def meta_graph(pg: PartitionedGraph):
    """Build the sub-graph meta-graph: nodes = (partition, sg_id), edges from
    remote edges. Returns (num_meta, csr_adjacency, meta_of[p, v] -> meta id).
    """
    offsets = np.zeros(pg.num_parts + 1, np.int64)
    np.cumsum(pg.num_subgraphs, out=offsets[1:])
    num_meta = int(offsets[-1])
    meta_of = np.full((pg.num_parts, pg.v_max), -1, np.int64)
    valid = pg.sg_id != PAD
    meta_of[valid] = pg.sg_id[valid] + offsets[:-1, None].repeat(pg.v_max, 1)[valid]

    src_m, dst_m = [], []
    for p in range(pg.num_parts):
        m = pg.re_src[p] != PAD
        if not m.any():
            continue
        s = meta_of[p, pg.re_src[p][m]]
        d = meta_of[pg.re_dst_part[p][m], pg.re_dst_local[p][m]]
        src_m.append(s)
        dst_m.append(d)
    if src_m:
        src_m = np.concatenate(src_m)
        dst_m = np.concatenate(dst_m)
    else:
        src_m = np.zeros(0, np.int64)
        dst_m = np.zeros(0, np.int64)
    a = sp.csr_matrix((np.ones(src_m.size, np.int8), (src_m, dst_m)),
                      shape=(num_meta, num_meta))
    a = ((a + a.T) > 0).astype(np.int8)
    return num_meta, a.tocsr(), meta_of


def graph_diameter(adj: sp.csr_matrix, sample: int = 64, seed: int = 0) -> int:
    """(Approximate for big graphs) diameter: max finite BFS eccentricity over
    a vertex sample; exact when n <= sample. Disconnected pairs are ignored,
    matching the paper's per-component diameter usage."""
    n = adj.shape[0]
    if n == 0:
        return 0
    rng = np.random.default_rng(seed)
    sources = np.arange(n) if n <= sample else rng.choice(n, sample, replace=False)
    d = csgraph.shortest_path(adj, method="D", unweighted=True, indices=sources)
    d[~np.isfinite(d)] = -1
    return int(d.max())


def meta_diameter(pg: PartitionedGraph, sample: int = 64) -> int:
    _, a, _ = meta_graph(pg)
    return graph_diameter(a, sample=sample)


def vertex_diameter(g: Graph, sample: int = 64) -> int:
    return graph_diameter(g.undirected_csr(), sample=sample)


def subgraph_sizes(pg: PartitionedGraph) -> list:
    """Per-partition list of sub-graph vertex counts — straggler telemetry
    (paper Fig 5: LJ has one mega sub-graph per partition)."""
    out = []
    for p in range(pg.num_parts):
        ids = pg.sg_id[p][pg.sg_id[p] != PAD]
        out.append(np.bincount(ids, minlength=int(pg.num_subgraphs[p])))
    return out
