"""Mailbox message routing — the superstep-boundary exchange.

The paper's Gopher workers aggregate messages per destination host and ship
them over TCP while compute proceeds. The TPU-native analogue is a fixed
capacity mailbox tensor routed with a single ``all_to_all`` per superstep
(or a transpose on the single-device/local backend), then a segment-combine
into each partition's inbox. Capacity = max messages between any partition
pair, precomputed by GoFS at build time — padding slots carry the combine
identity so they are no-ops.

These same primitives back the MoE token-dispatch in repro.models (the
framework's mailbox IS the expert all_to_all), per DESIGN.md §6.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.gofs.formats import PAD

COMBINE_IDENTITY = {"min": jnp.inf, "max": -jnp.inf, "sum": 0.0}
_SEGMENT = {
    "min": jax.ops.segment_min,
    "max": jax.ops.segment_max,
    "sum": jax.ops.segment_sum,
}


def build_outbox(vals: jnp.ndarray, re_src: jnp.ndarray, re_dst_part: jnp.ndarray,
                 re_dst_local: jnp.ndarray, re_slot: jnp.ndarray, send_mask: jnp.ndarray,
                 num_parts: int, cap: int, combine: str
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scatter per-remote-edge values into the (P_dst, cap) outbox of ONE
    source partition.

    vals: (r_max,) message value per remote edge (already ⊗-combined with the
    edge weight by the program). send_mask masks out pad slots / unchanged
    sources. Returns (out_vals, out_idx) of shape (num_parts, cap).
    """
    ident = COMBINE_IDENTITY[combine]
    valid = (re_src != PAD) & send_mask
    dst_p = jnp.where(valid, re_dst_part, 0)
    slot = jnp.where(valid, re_slot, 0)
    flat = dst_p * cap + slot
    flat = jnp.where(valid, flat, num_parts * cap)  # OOB -> dropped
    out_vals = jnp.full((num_parts * cap,), ident, vals.dtype)
    out_idx = jnp.full((num_parts * cap,), PAD, jnp.int32)
    out_vals = out_vals.at[flat].set(jnp.where(valid, vals, ident), mode="drop")
    out_idx = out_idx.at[flat].set(jnp.where(valid, re_dst_local, PAD), mode="drop")
    return out_vals.reshape(num_parts, cap), out_idx.reshape(num_parts, cap)


def combine_inbox(in_vals: jnp.ndarray, in_idx: jnp.ndarray, v_max: int,
                  combine: str) -> jnp.ndarray:
    """Segment-⊕ received messages into a dense (v_max,) inbox.

    in_vals/in_idx: (num_src, cap) from all source partitions. PAD indices map
    out-of-range and are dropped by the scatter.
    """
    idx = in_idx.reshape(-1)
    idx = jnp.where(idx == PAD, v_max, idx).astype(jnp.int32)
    seg = _SEGMENT[combine](in_vals.reshape(-1), idx, num_segments=v_max + 1)
    inbox = seg[:v_max]
    if combine in ("min", "max"):
        return inbox
    return inbox  # sum: empty segments are already 0


# ---------------- gather-form mailbox (the engine's hot path) ----------------
# The routing plan is fixed at GoFS build time, so both mailbox endpoints can
# be expressed as pure gathers through precomputed INVERSE maps (see
# engine._mailbox_inverse) instead of runtime scatters — scatter is the
# dominant superstep cost on XLA:CPU and serializes badly under a query axis.
# A further win: the destination indices never travel — only values are
# routed, halving mailbox traffic. The scatter forms above are kept as the
# reference oracles the gather forms are tested against.

_REDUCE = {"min": jnp.min, "max": jnp.max, "sum": jnp.sum}


def _at_combine(y, idx, vals, combine: str):
    ref = y.at[idx]
    if combine == "min":
        return ref.min(vals, mode="drop")
    if combine == "max":
        return ref.max(vals, mode="drop")
    return ref.add(vals, mode="drop")


def build_outbox_gather(vals: jnp.ndarray, send_mask: jnp.ndarray,
                        ob_inv: jnp.ndarray, num_parts: int, cap: int,
                        combine: str) -> jnp.ndarray:
    """Gather-form outbox for ONE source partition: each of the num_parts*cap
    slots pulls its remote edge's value (or the identity when empty/masked).
    The send mask is folded into vals BEFORE the slot gather — masking at
    r_max size beats masking at slot size, and only one gather runs."""
    ident = COMBINE_IDENTITY[combine]
    masked = jnp.where(send_mask, vals, ident)
    valid = ob_inv != PAD
    safe = jnp.where(valid, ob_inv, 0)
    return jnp.where(valid, masked[safe], ident).reshape(num_parts, cap)


def build_outbox_gather_batched(vals: jnp.ndarray, send_mask: jnp.ndarray,
                                ob_inv: jnp.ndarray, num_parts: int, cap: int,
                                combine: str) -> jnp.ndarray:
    """Q-query gather-form outbox, QUERY-TRAILING: vals/send are (r_max, Q)
    and each mailbox slot pulls its edge's contiguous Q-vector in one go —
    slot index arithmetic amortizes over the whole query batch. Returns
    (num_parts, cap*Q) with slot-major layout slot*Q + q per pair row."""
    ident = COMBINE_IDENTITY[combine]
    masked = jnp.where(send_mask, vals, ident)      # (r_max, Q)
    valid = ob_inv != PAD
    safe = jnp.where(valid, ob_inv, 0)
    out = jnp.where(valid[:, None], masked[safe, :], ident)
    return out.reshape(num_parts, cap * vals.shape[1])


def combine_inbox_gather(in_vals: jnp.ndarray, ib_lo: jnp.ndarray,
                         ib_hub_idx: jnp.ndarray, ib_hub: jnp.ndarray,
                         v_max: int, combine: str) -> jnp.ndarray:
    """Gather-form inbox combine: (num_src, cap) received values -> (v_max,).
    Each vertex pulls its (two-binned) feed list and reduces it densely; the
    handful of hub receivers merge back via a tiny hr_max-sized scatter."""
    ident = COMBINE_IDENTITY[combine]
    red = _REDUCE[combine]
    flat = in_vals.reshape(-1)

    def pull(m):
        valid = m != PAD
        return jnp.where(valid, flat[jnp.where(valid, m, 0)], ident)

    y = red(pull(ib_lo), axis=-1)                   # (v_max,)
    yh = red(pull(ib_hub), axis=-1)                 # (hr_max,)
    idx = jnp.where(ib_hub_idx != PAD, ib_hub_idx, v_max)
    return _at_combine(y, idx, yh, combine)


def combine_inbox_gather_batched(in_vals: jnp.ndarray, ib_lo: jnp.ndarray,
                                 ib_hub_idx: jnp.ndarray, ib_hub: jnp.ndarray,
                                 v_max: int, cap: int, combine: str
                                 ) -> jnp.ndarray:
    """Q-query gather-form combine, QUERY-TRAILING:
    (num_src, cap*Q) received -> (v_max, Q) inbox. Each vertex's feed slots
    pull contiguous Q-vectors; the reduce runs over the feed axis with Q on
    the lanes."""
    ident = COMBINE_IDENTITY[combine]
    red = _REDUCE[combine]
    num_src = in_vals.shape[0]
    Q = in_vals.shape[1] // cap
    flat = in_vals.reshape(num_src * cap, Q)

    def pull(m):
        valid = m != PAD
        safe = jnp.where(valid, m, 0)
        return jnp.where(valid[..., None], flat[safe, :], ident)

    y = red(pull(ib_lo), axis=1)                    # (v_max, m_lo, Q) -> (v_max, Q)
    yh = red(pull(ib_hub), axis=1)                  # (hr_max, Q)
    idx = jnp.where(ib_hub_idx != PAD, ib_hub_idx, v_max)
    return _at_combine(y, idx, yh, combine)


# ---------------- frontier-compacted sparse exchange (Gopher Wire) ----------
# The dense mailbox above ships every (src, dst) pair's full cap-slot row
# every superstep — identity-filled when the pair is quiescent. The compact
# forms below PACK each pair row to a dense prefix of its active slots
# (source vertex in the send set) plus a per-destination count header, so
# the payload that travels scales with |frontier| instead of P·cap. The
# compaction plan (kernels.ops.outbox_compact_plan: jnp oracle + Pallas
# kernel) yields inverse permutations pfwd/pinv; the sender packs by
# gathering through pfwd and the receiver reconstructs fixed slot positions
# by gathering through pinv — the O(count) dual of scattering the prefix
# back, so neither endpoint runs a runtime scatter. A real transport would
# ship the count-length prefix + its slot ids and rebuild pinv in O(count)
# on arrival; the byte model (core.engine.Telemetry.model_bytes) charges
# exactly that. Reconstruction is exact, so every downstream bit — combine,
# halt, results — is identical to the dense path.


def active_slots(send_mask: jnp.ndarray, ob_inv: jnp.ndarray,
                 num_parts: int, cap: int) -> jnp.ndarray:
    """(num_parts, cap) bool: mailbox slots of ONE source partition whose
    source vertex is in the send set this superstep. Q-batched send masks
    ((r_max, Q)) activate a slot when ANY lane sends — the contiguous
    Q-vector ships (or doesn't) as one unit."""
    valid = ob_inv != PAD
    safe = jnp.where(valid, ob_inv, 0)
    sm = send_mask if send_mask.ndim == 1 else jnp.any(send_mask, axis=-1)
    return (valid & sm[safe]).reshape(num_parts, cap)


def build_outbox_compact(vals: jnp.ndarray, send_mask: jnp.ndarray,
                         ob_inv: jnp.ndarray, num_parts: int, cap: int,
                         combine: str, backend=None):
    """Frontier-compacted outbox for ONE source partition. Returns
    (pvals (num_parts, cap), pinv (num_parts, cap) int32,
    counts (num_parts,) int32): per destination row, the packed prefix of
    active slot values, the slot->prefix-position map, and the prefix
    length (the wire header — Σ counts is this partition's payload).

    Since Gopher Mesh the compaction plan is FUSED into the pack
    (kernels.ops.outbox_pack): packed positions fall out of the activity
    mask's prefix sum, so no argsort/one-hot plan pass runs."""
    from repro.kernels import ops
    ident = COMBINE_IDENTITY[combine]
    # the dense gather-form outbox IS the slot-value oracle; compaction only
    # adds the activity mask + the fused pack on top of it
    slot_vals = build_outbox_gather(vals, send_mask, ob_inv, num_parts, cap,
                                    combine)
    active = active_slots(send_mask, ob_inv, num_parts, cap)
    full = jnp.full((num_parts,), cap, jnp.int32)
    pvals, _, pinv, counts, _ = ops.outbox_pack(slot_vals, active, full,
                                                ident, backend=backend)
    return pvals, pinv, counts


def build_outbox_compact_batched(vals: jnp.ndarray, send_mask: jnp.ndarray,
                                 ob_inv: jnp.ndarray, num_parts: int,
                                 cap: int, combine: str, backend=None):
    """Q-query compacted outbox, QUERY-TRAILING: vals/send are (r_max, Q);
    plan fused into the pack as in build_outbox_compact. Returns
    (pvals (num_parts, cap*Q), pinv (num_parts, cap), counts (num_parts,))."""
    from repro.kernels import ops
    ident = COMBINE_IDENTITY[combine]
    Q = vals.shape[1]
    slot_vals = build_outbox_gather_batched(
        vals, send_mask, ob_inv, num_parts, cap,
        combine).reshape(num_parts, cap, Q)
    active = active_slots(send_mask, ob_inv, num_parts, cap)
    full = jnp.full((num_parts,), cap, jnp.int32)
    pvals, _, pinv, counts, _ = ops.outbox_pack(slot_vals, active, full,
                                                ident, backend=backend)
    return pvals.reshape(num_parts, cap * Q), pinv, counts


def unpack_slots(pvals: jnp.ndarray, pinv: jnp.ndarray,
                 combine: str) -> jnp.ndarray:
    """Receiver side: (num_src, cap) packed prefixes + slot->position maps
    -> the dense slot-value array the gather-form inbox combine expects.
    A pure gather (each fixed slot pulls its packed value or the identity);
    bit-identical to what the dense exchange would have delivered."""
    ident = COMBINE_IDENTITY[combine]
    valid = pinv != PAD
    got = jnp.take_along_axis(pvals, jnp.where(valid, pinv, 0), axis=1)
    return jnp.where(valid, got, ident)


def unpack_slots_batched(pvals: jnp.ndarray, pinv: jnp.ndarray,
                         combine: str) -> jnp.ndarray:
    """Q-query receiver reconstruction: (num_src, cap*Q) packed + (num_src,
    cap) maps -> (num_src, cap*Q) dense, each slot pulling its contiguous
    Q-vector."""
    ident = COMBINE_IDENTITY[combine]
    num_src, cap = pinv.shape
    Q = pvals.shape[1] // cap
    pv = pvals.reshape(num_src, cap, Q)
    valid = pinv != PAD
    got = jnp.take_along_axis(pv, jnp.where(valid, pinv, 0)[..., None],
                              axis=1)
    return jnp.where(valid[..., None], got, ident).reshape(num_src, cap * Q)


def route_local(outbox_vals: jnp.ndarray) -> jnp.ndarray:
    """Local backend: outbox (P_src, P_dst, cap) -> inbox-side (P_dst, P_src, cap).
    A transpose IS the all_to_all when every partition lives on one device."""
    return outbox_vals.transpose(1, 0, 2)


def route_shard_map(outbox_vals: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """shard_map backend: per-device block is (v_local_src, P, cap) where
    P = D * v_local. Rearranged so ``all_to_all`` over the device axis delivers
    each device-pair payload, then reassembled as (v_local_dst, P_src, cap)."""
    v, P, cap = outbox_vals.shape
    D = P // v
    # (v_src, D*v_dst, cap) -> (D, v_src, v_dst, cap) -> a2a -> received
    x = outbox_vals.reshape(v, D, v, cap).transpose(1, 0, 2, 3)
    x = jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0, tiled=True)
    # now x[d_src, v_src, v_dst, cap] on each destination device
    return x.reshape(D, v, v, cap).transpose(2, 0, 1, 3).reshape(v, D * v, cap)


# ---------------- capacity-tiered physical exchange (Gopher Mesh) -----------
# The compact exchange above shrinks the modeled PROTOCOL payload but its
# physical buffers keep the dense (P, cap) geometry (static shapes). The
# tiered router below makes the buffers XLA actually routes track the
# frontier: hot pairs ship their full dense cap row through one all_to_all
# over per-device-pair row blocks, warm/cold pairs ship a packed tier-width
# prefix (values + int32 slot ids) through a ppermute round-robin over only
# the nonzero device shifts, and structurally-empty pairs ship NOTHING.
# Every table is a trace-time constant (core.tiers.TierSchedule), so the
# routed shapes — the physical wire — are fixed per tier plan. The receiver
# rebuilds the exact dense slot array (each occupied slot is written once
# with its exact value, everything else holds the ⊕-identity), so as long
# as no pair overflowed its tier width every downstream bit is identical to
# the dense exchange; overflow is detected upstream (ops.outbox_pack) and
# repaired by the engine's dense fallback retry.


def route_tiered(dense_vals: jnp.ndarray, pvals: jnp.ndarray,
                 sids: jnp.ndarray, sched, combine: str,
                 axis_name=None) -> jnp.ndarray:
    """Physically route one superstep's outboxes along the tier schedule.

    dense_vals (v, P, cap, Qg)  gather-form dense slot values (hot rows
                                ship these as-is — no slot ids travel)
    pvals      (v, P, cap, Qg)  packed prefixes (warm/cold rows ship the
                                first tier-width columns)
    sids       (v, P, cap)      packed position -> slot id maps
    sched                       core.tiers.TierSchedule built for this mesh
    axis_name                   mesh axis ('shard_map' backend) or None
                                ('local' backend — D == 1, no collectives)

    Returns the received dense slot array (v, P, cap, Qg), bit-identical to
    what route_local/route_shard_map would have delivered when no pair
    overflowed its tier budget.
    """
    ident = COMBINE_IDENTITY[combine]
    v, P, cap, Qg = dense_vals.shape
    D = sched.D
    me = jax.lax.axis_index(axis_name) if (axis_name and D > 1) else 0
    dflat = dense_vals.reshape(v * P, cap, Qg)
    pflat = pvals.reshape(v * P, cap, Qg)
    iflat = sids.reshape(v * P, cap)
    out = jnp.full((v * P, cap, Qg), ident, dense_vals.dtype)

    # hot tier: one all_to_all over (D, h, cap) row blocks
    if sched.hot_h:
        st = jnp.asarray(sched.hot_send)[me]            # (D, h)
        buf = dflat[jnp.where(st == PAD, 0, st)]        # (D, h, cap, Qg)
        if axis_name is not None and D > 1:
            buf = jax.lax.all_to_all(buf, axis_name, split_axis=0,
                                     concat_axis=0, tiled=True)
        rt = jnp.asarray(sched.hot_recv)[me]            # (D, h)
        tgt = jnp.where(rt == PAD, v * P, rt).reshape(-1)
        out = out.at[tgt].set(buf.reshape(-1, cap, Qg), mode="drop")

    # residual hot rows (pair counts past the uniform all_to_all block):
    # same dense-row geometry — full cap, no slot ids — shipped by one
    # ppermute per device shift, so a skewed mesh pads only the devices
    # that own the excess instead of every all_to_all block
    for k, g, send_tab, recv_tab in sched.hot_res_shifts:
        st = jnp.asarray(send_tab)[me]                  # (g,)
        buf = dflat[jnp.where(st == PAD, 0, st)]        # (g, cap, Qg)
        if axis_name is not None and k % D != 0:
            perm = [(i, (i + k) % D) for i in range(D)]
            buf = jax.lax.ppermute(buf, axis_name, perm)
        rt = jnp.asarray(recv_tab)[me]                  # (g,)
        tgt = jnp.where(rt == PAD, v * P, rt)
        out = out.at[tgt].set(buf, mode="drop")

    # warm/cold tiers: ppermute round-robin over the nonzero device shifts
    flat = out.reshape(v * P * cap, Qg)
    for width, shifts in ((sched.warm_cap, sched.warm_shifts),
                          (1, sched.cold_shifts)):
        for k, g, send_tab, recv_tab in shifts:
            st = jnp.asarray(send_tab)[me]              # (g,)
            rows = jnp.where(st == PAD, 0, st)
            bv = pflat[rows][:, :width]                 # (g, width, Qg)
            bi = iflat[rows][:, :width]                 # (g, width)
            if axis_name is not None and k % D != 0:
                perm = [(i, (i + k) % D) for i in range(D)]
                bv = jax.lax.ppermute(bv, axis_name, perm)
                bi = jax.lax.ppermute(bi, axis_name, perm)
            rt = jnp.asarray(recv_tab)[me]              # (g,)
            ok = (rt != PAD)[:, None] & (bi != PAD)
            pos = jnp.where(ok, rt[:, None] * cap + bi, v * P * cap)
            flat = flat.at[pos.reshape(-1)].set(bv.reshape(-1, Qg),
                                                mode="drop")
    return flat.reshape(v, P, cap, Qg)
