"""Mailbox message routing — the superstep-boundary exchange.

The paper's Gopher workers aggregate messages per destination host and ship
them over TCP while compute proceeds. The TPU-native analogue is a fixed
capacity mailbox tensor routed with a single ``all_to_all`` per superstep
(or a transpose on the single-device/local backend), then a segment-combine
into each partition's inbox. Capacity = max messages between any partition
pair, precomputed by GoFS at build time — padding slots carry the combine
identity so they are no-ops.

These same primitives back the MoE token-dispatch in repro.models (the
framework's mailbox IS the expert all_to_all), per DESIGN.md §6.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.gofs.formats import PAD

COMBINE_IDENTITY = {"min": jnp.inf, "max": -jnp.inf, "sum": 0.0}
_SEGMENT = {
    "min": jax.ops.segment_min,
    "max": jax.ops.segment_max,
    "sum": jax.ops.segment_sum,
}


def build_outbox(vals: jnp.ndarray, re_src: jnp.ndarray, re_dst_part: jnp.ndarray,
                 re_dst_local: jnp.ndarray, re_slot: jnp.ndarray, send_mask: jnp.ndarray,
                 num_parts: int, cap: int, combine: str
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scatter per-remote-edge values into the (P_dst, cap) outbox of ONE
    source partition.

    vals: (r_max,) message value per remote edge (already ⊗-combined with the
    edge weight by the program). send_mask masks out pad slots / unchanged
    sources. Returns (out_vals, out_idx) of shape (num_parts, cap).
    """
    ident = COMBINE_IDENTITY[combine]
    valid = (re_src != PAD) & send_mask
    dst_p = jnp.where(valid, re_dst_part, 0)
    slot = jnp.where(valid, re_slot, 0)
    flat = dst_p * cap + slot
    flat = jnp.where(valid, flat, num_parts * cap)  # OOB -> dropped
    out_vals = jnp.full((num_parts * cap,), ident, vals.dtype)
    out_idx = jnp.full((num_parts * cap,), PAD, jnp.int32)
    out_vals = out_vals.at[flat].set(jnp.where(valid, vals, ident), mode="drop")
    out_idx = out_idx.at[flat].set(jnp.where(valid, re_dst_local, PAD), mode="drop")
    return out_vals.reshape(num_parts, cap), out_idx.reshape(num_parts, cap)


def combine_inbox(in_vals: jnp.ndarray, in_idx: jnp.ndarray, v_max: int,
                  combine: str) -> jnp.ndarray:
    """Segment-⊕ received messages into a dense (v_max,) inbox.

    in_vals/in_idx: (num_src, cap) from all source partitions. PAD indices map
    out-of-range and are dropped by the scatter.
    """
    ident = COMBINE_IDENTITY[combine]
    idx = in_idx.reshape(-1)
    idx = jnp.where(idx == PAD, v_max, idx).astype(jnp.int32)
    seg = _SEGMENT[combine](in_vals.reshape(-1), idx, num_segments=v_max + 1)
    inbox = seg[:v_max]
    if combine in ("min", "max"):
        return inbox
    return inbox  # sum: empty segments are already 0


def route_local(outbox_vals: jnp.ndarray, outbox_idx: jnp.ndarray):
    """Local backend: outbox (P_src, P_dst, cap) -> inbox-side (P_dst, P_src, cap).
    A transpose IS the all_to_all when every partition lives on one device."""
    return outbox_vals.transpose(1, 0, 2), outbox_idx.transpose(1, 0, 2)


def route_shard_map(outbox_vals: jnp.ndarray, outbox_idx: jnp.ndarray,
                    axis_name: str):
    """shard_map backend: per-device block is (v_local_src, P, cap) where
    P = D * v_local. Rearranged so ``all_to_all`` over the device axis delivers
    each device-pair payload, then reassembled as (v_local_dst, P_src, cap)."""
    v, P, cap = outbox_vals.shape
    D = P // v

    def _route(x):
        # (v_src, D*v_dst, cap) -> (D, v_src, v_dst, cap) -> a2a -> received
        x = x.reshape(v, D, v, cap).transpose(1, 0, 2, 3)
        x = jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0, tiled=True)
        # now x[d_src, v_src, v_dst, cap] on each destination device
        return x.reshape(D, v, v, cap).transpose(2, 0, 1, 3).reshape(v, D * v, cap)

    return _route(outbox_vals), _route(outbox_idx)
