"""Gopher Mesh: capacity-tiered physical exchange planning.

PR 3's compact exchange made the *modeled* protocol payload track the
frontier, but the physical ``all_to_all`` still routed the dense
``P² · cap · Q`` buffer (plus a slot map) every superstep — on real
hardware the interconnect moved MORE bytes than the dense path. This module
plans the buffers XLA actually routes so their geometry tracks the
frontier:

  * every partition pair carries a per-pair **traffic profile** — an EWMA of
    the packed slot counts the compact/tiered exchange already computes
    (``wire_ewma`` on the host graph block, seeded with the structural slot
    occupancy, updated by :func:`update_profile` after each run and patched
    through ``gofs.temporal.apply_delta`` so a delta's dirty frontier is
    pre-announced as expected traffic);
  * :meth:`TierPlan.build` classifies pairs into static capacity **tiers**
    — hot pairs keep the full ``cap``-slot row, warm pairs ship a packed
    ``cap/8``-slot prefix, cold pairs ship a single width-1 slot, and pairs
    with zero structural occupancy ship **nothing** (true pairwise skip);
  * :meth:`TierPlan.schedule` lays the tiers out on a concrete device mesh:
    the hot tier rides one ``all_to_all`` over per-device-pair row blocks,
    the warm/cold tiers ride a ``ppermute`` round-robin over only the
    nonzero device shifts. Every table is a static constant, so the routed
    buffer shapes — and therefore the physical wire — are known at compile
    time (:meth:`TierSchedule.round_slots`).

Correctness is never bet on the profile: the pack kernel reports per-pair
**overflow** (a pair whose active slot count exceeded its tier width had
messages truncated), the engine retries the run on the dense exchange —
results stay bit-identical to ``exchange='dense'`` unconditionally — and
:meth:`TierPlan.escalate` promotes the overflowed pairs one tier for the
next version.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.gofs.formats import PAD
from repro.obs import metrics as obs_metrics

# tier codes, ordered so escalation is "+1 and clamp"
EXCLUDED = 0    # zero structural occupancy: the pair can never carry a slot
COLD = 1        # width-1 row: historically silent pair, count-only headroom
WARM = 2        # packed cap/8 prefix
HOT = 3         # the full cap-slot row (the dense geometry, per pair)

TIER_NAMES = {EXCLUDED: "excluded", COLD: "cold", WARM: "warm", HOT: "hot"}

# classification thresholds (see TierPlan.build)
COLD_THRESH = 0.5   # expected slots/round at or below this -> cold
PROFILE_DECAY = 0.25  # update_profile: weight kept on the OLD ewma

# Gopher Phases: the changed-histogram EWMA persisted on the graph block —
# per-ROUND expected frontier width (changed slots per exchange round; round 0
# is the inbox prime, superstep s ships round s+1), folded across runs by
# update_changed_profile. Phase boundaries, the announce-floor horizon and the
# per-phase width scaling all derive from it.
PHASE_HIST_LEN = 64   # rounds of history kept (EWMA truncates past this)
MAX_PHASES = 3        # bands a phased plan can carry (and the per-band pair
                      # profile ``phase_pair_ewma`` persists on the block)
CHANGED_EPS = 0.5     # expected slots/round below this counts as quiesced
WIDE_FRAC = 0.25      # frontier >= this fraction of peak -> the wide phase
NARROW_FRAC = 0.05    # frontier < this fraction of peak -> the narrow phase
DEMOTE_STREAK = 2     # consecutive fitting supersteps before a phase demotes


def occupancy_from_ob_inv(ob_inv: np.ndarray) -> np.ndarray:
    """(P, P*cap) outbox slot map -> (P, P) live-slot count per pair: the
    structural ceiling on any superstep's packed count."""
    P = ob_inv.shape[0]
    cap = ob_inv.shape[1] // P
    return (ob_inv.reshape(P, P, cap) != PAD).sum(-1).astype(np.int64)


def occupancy_from_graph(pg) -> np.ndarray:
    """(P, P) live remote-edge count per pair straight from the GoFS fields
    (no block needed)."""
    P = pg.num_parts
    occ = np.zeros((P, P), np.int64)
    live = pg.re_src != PAD
    sp, e = np.nonzero(live)
    np.add.at(occ, (sp, pg.re_dst_part[sp, e]), 1)
    return occ


@dataclasses.dataclass(frozen=True)
class TierPlan:
    """Static per-pair tier assignment. Hashable — the engine's compiled-loop
    cache keys on it, so two engines with the same plan share one compile.

    Invariants (enforced by ``repro.analysis.check_plan_static``, run by the
    Gopher Sentinel and by ``GopherEngine(validate=True)`` before a plan may
    key ``_RUNNER_CACHE``):

    * every field is a TRACE-TIME CONSTANT — plain ``int``/``bytes``, never
      a jax tracer or array. The tier table selects which collectives the
      loop emits, so a non-constant table would bake one engine's routing
      into a cache entry other engines silently share (or fail to hash);
    * ``tier_bytes`` has exactly ``num_parts**2`` entries — the (P, P)
      row-major pair table the pack/exchange stages index;
    * the instance hashes and compares equal under
      ``dataclasses.replace(plan)`` — value semantics, not identity."""
    num_parts: int
    cap: int
    warm_cap: int
    tier_bytes: bytes            # (P*P,) int8 row-major tier codes

    @property
    def tiers(self) -> np.ndarray:
        P = self.num_parts
        return np.frombuffer(self.tier_bytes, np.int8).reshape(P, P)

    def limits(self) -> np.ndarray:
        """(P, P) int32 slot budget per pair: the tier width the pack stage
        truncates to (and the overflow detector compares counts against)."""
        w = np.array([0, 1, self.warm_cap, self.cap], np.int32)
        return w[self.tiers]

    def counts(self) -> dict:
        t = self.tiers
        return {name: int((t == code).sum()) for code, name in TIER_NAMES.items()}

    # ---------------- construction ----------------
    @staticmethod
    def build(expected: np.ndarray, occupancy: np.ndarray, cap: int,
              warm_div: int = 8) -> "TierPlan":
        """Classify pairs from ``expected`` (EWMA slots/round, (P, P) float)
        clamped by ``occupancy`` (structural live slots, (P, P) int):

          occupancy == 0      -> EXCLUDED  (nothing can ever ship)
          occupancy == 1      -> COLD      (width 1 covers the worst case)
          ew >  warm_cap      -> HOT       (full cap row)
          ew <= COLD_THRESH   -> COLD      (width 1)
          otherwise           -> WARM      (cap / warm_div prefix)

        where ``ew = min(expected, occupancy)``. With ``expected ==
        occupancy`` (the structural prior a cold-built block carries) no
        pair's width can be below its maximum possible count, so the plan
        provably never overflows; a learned profile trades that guarantee
        for geometry, backstopped by the dense fallback retry."""
        P = occupancy.shape[0]
        warm_cap = min(max(1, -(-cap // warm_div)), cap)
        ew = np.minimum(np.asarray(expected, np.float64), occupancy)
        t = np.full((P, P), WARM, np.int8)
        t[ew <= COLD_THRESH] = COLD
        t[ew > warm_cap] = HOT
        t[occupancy <= 1] = COLD
        t[occupancy <= 0] = EXCLUDED
        obs_metrics.default_registry().counter(
            "tiers_plans_built_total", labels={"kind": "static"}).inc()
        return TierPlan(num_parts=P, cap=int(cap), warm_cap=int(warm_cap),
                        tier_bytes=t.tobytes())

    @staticmethod
    def from_block(host_gb: dict, warm_div: int = 8) -> "TierPlan":
        """Plan from a host graph block: structural occupancy from its
        outbox slot map, expected traffic from its ``wire_ewma`` profile."""
        occ = occupancy_from_ob_inv(host_gb["ob_inv"])
        ew = host_gb.get("wire_ewma")
        if ew is None:
            ew = occ
        cap = host_gb["ob_inv"].shape[1] // host_gb["ob_inv"].shape[0]
        return TierPlan.build(ew, occ, cap, warm_div=warm_div)

    @staticmethod
    def from_graph(pg, warm_div: int = 8) -> "TierPlan":
        """Structural plan (no history): expected = occupancy, so every
        pair's width covers its worst case — never overflows. The engine's
        default when ``exchange='tiered'`` is requested without a plan."""
        occ = occupancy_from_graph(pg)
        return TierPlan.build(occ, occ, pg.mailbox_cap, warm_div=warm_div)

    # ---------------- escalation ----------------
    def escalate(self, pair_mask: np.ndarray) -> "TierPlan":
        """Promote overflowed pairs one tier (COLD->WARM->HOT); a pair that
        overflowed while EXCLUDED signals a plan/block mismatch and jumps
        straight to HOT. Returns a new plan (self is frozen)."""
        t = self.tiers.copy()
        m = np.asarray(pair_mask, bool)
        t[m & (t == EXCLUDED)] = HOT
        t[m & (t > EXCLUDED)] = np.minimum(t[m & (t > EXCLUDED)] + 1, HOT)
        return dataclasses.replace(self, tier_bytes=t.tobytes())

    def escalations_from(self, old: "TierPlan") -> int:
        return int((self.tiers > old.tiers).sum())

    # ---------------- physical schedule ----------------
    def schedule(self, num_devices: int = 1) -> "TierSchedule":
        return TierSchedule(self, num_devices)


# sentinel boundary for a plan's last phase: it runs to quiescence
_NO_BOUNDARY = 1 << 30


def phase_bands(changed_ewma: Optional[np.ndarray],
                max_phases: int = 3) -> Tuple[Tuple[int, int, float], ...]:
    """Derive up to ``max_phases`` frontier bands from the changed-histogram
    EWMA: ``[(end_round, span, mean_width), ...]`` in ROUND units (round 0
    is the inbox prime, superstep s ships round s+1). A band ends at the
    first round after which the expected width STAYS below its threshold
    (``WIDE_FRAC`` / ``NARROW_FRAC`` of the peak) — robust to a frontier
    that briefly dips and rebounds. With no usable history (cold block,
    all-zero EWMA) there is a single unbounded band."""
    if changed_ewma is None:
        return ((_NO_BOUNDARY, _NO_BOUNDARY, 1.0),)
    ch = np.asarray(changed_ewma, np.float64).reshape(-1)
    peak = float(ch.max()) if ch.size else 0.0
    if peak <= CHANGED_EPS:
        return ((_NO_BOUNDARY, _NO_BOUNDARY, 1.0),)
    horizon = int(np.flatnonzero(ch >= CHANGED_EPS).max()) + 1
    # suffix maxima: band k ends where the rest of the run never widens back
    suf = np.maximum.accumulate(ch[::-1])[::-1]
    bands = []
    start = 0
    fracs = [WIDE_FRAC, NARROW_FRAC] if max_phases >= 3 else [NARROW_FRAC]
    for frac in fracs[:max_phases - 1]:
        below = np.flatnonzero(suf < frac * peak)
        end = int(below.min()) if below.size else horizon
        end = min(end, horizon)
        if end - start >= 1:
            bands.append((end, end - start, float(ch[start:end].mean())))
            start = end
    tail = ch[start:horizon]
    bands.append((_NO_BOUNDARY, max(horizon - start, 1),
                  float(tail.mean()) if tail.size else 0.0))
    return tuple(bands)


def expected_horizon(changed_ewma: Optional[np.ndarray]) -> Optional[int]:
    """Expected round horizon of the next run: the last round the
    changed-histogram EWMA still expects activity at (plus one). ``None``
    when there is no usable history — callers must fall back to their
    unbounded/conservative behavior."""
    if changed_ewma is None:
        return None
    ch = np.asarray(changed_ewma, np.float64).reshape(-1)
    live = np.flatnonzero(ch >= CHANGED_EPS)
    if live.size == 0:
        return None
    return int(live.max()) + 1


@dataclasses.dataclass(frozen=True)
class PhasedTierPlan:
    """Gopher Phases: K per-pair tier tables, one per frontier band of the
    run, each with a PREDICTED switch superstep. A static :class:`TierPlan`
    fixes one interconnect geometry for the whole compiled loop even though
    the frontier contracts by orders of magnitude between round 1 and
    convergence; a phased plan lets the engine compile one SEGMENTED loop
    per phase (trace-time-constant tables per segment) and ride the
    contraction within a single run.

    Derivation (:meth:`from_block`): phase boundaries come from the
    changed-histogram EWMA persisted on the graph block
    (``changed_ewma``, fed by :func:`update_changed_profile`); phase k's
    per-pair expectation is the pair profile scaled by the band's relative
    frontier width,

        expected_k = min(wire_ewma, occupancy) · mean_k / mean_run

    so the wide band is at least as wide as the static PR 4 plan (on a cold
    block that degenerates to the structural prior — provably
    overflow-free) while the narrow tail drops to the converged-frontier
    geometry a static cold plan only reaches on the NEXT version.

    Hashable — the engine's compiled-loop cache keys on it. ``boundaries``
    holds each phase's predicted END round in ROUND units (round 0 is the
    inbox prime, superstep s ships round s+1; phase k's segment stops
    before shipping round ``boundaries[k]``). The last phase carries the
    ``_NO_BOUNDARY`` sentinel: it runs to quiescence. The engine may leave
    a phase EARLY — global halt, or the dynamic demotion trigger (observed
    per-pair counts under the next phase's caps for ``DEMOTE_STREAK``
    consecutive supersteps) — and repairs any phase that truncated with a
    per-superstep dense retry plus a per-phase escalation
    (:meth:`escalate_phase`).

    Shares :class:`TierPlan`'s staticness invariants (checked by
    ``repro.analysis.check_plan_static``): all fields trace-time-constant
    and hashable, each ``phase_tier_bytes[k]`` exactly ``num_parts**2``
    long, one boundary per phase with predicted ends strictly increasing
    and only the last phase open-ended (``_NO_BOUNDARY``). The dense-retry
    repair path additionally requires an IDEMPOTENT ⊕ for bit-exactness —
    re-delivering a truncated round must not double-count — which the
    sentinel's semiring pass checks against each program's declared
    algebra (non-idempotent ⊕ like pagerank's ``sum`` is flagged
    allclose-only)."""
    num_parts: int
    cap: int
    warm_cap: int
    phase_tier_bytes: Tuple[bytes, ...]
    boundaries: Tuple[int, ...]

    @property
    def num_phases(self) -> int:
        return len(self.phase_tier_bytes)

    def phase_plans(self) -> Tuple[TierPlan, ...]:
        return tuple(TierPlan(num_parts=self.num_parts, cap=self.cap,
                              warm_cap=self.warm_cap, tier_bytes=b)
                     for b in self.phase_tier_bytes)

    def counts(self) -> list:
        return [p.counts() for p in self.phase_plans()]

    # ---------------- construction ----------------
    @staticmethod
    def build(expected: np.ndarray, occupancy: np.ndarray, cap: int,
              changed_ewma: Optional[np.ndarray] = None, warm_div: int = 8,
              max_phases: int = MAX_PHASES,
              phase_pair_ewma: Optional[np.ndarray] = None
              ) -> "PhasedTierPlan":
        """``phase_pair_ewma`` (K, P, P), when taught (any band nonzero),
        gives band k its OWN observed per-pair profile — the per-band EWMA
        :func:`update_phase_profile` persists on the block — instead of the
        single run-wide profile scaled by the band's relative frontier
        width. A scaled global profile smears the wide band's hub pairs
        into the narrow tail (and vice versa); the per-band record keeps a
        pair that only fires early out of the tail's geometry entirely.
        Untaught bands (all-zero) keep the scaled-global fallback, and an
        under-taught band still costs at most a dense retry, never
        correctness."""
        bands = phase_bands(changed_ewma, max_phases=max_phases)
        ew = np.minimum(np.asarray(expected, np.float64), occupancy)
        spans = np.array([s for _, s, _ in bands], np.float64)
        means = np.array([m for _, _, m in bands], np.float64)
        mean_run = float((spans * means).sum() / max(spans.sum(), 1.0))
        ppe = (np.asarray(phase_pair_ewma, np.float64)
               if phase_pair_ewma is not None else None)
        plans = []
        for k, (_, _, mean_k) in enumerate(bands):
            if ppe is not None and k < ppe.shape[0] and np.any(ppe[k] > 0):
                ek = np.minimum(ppe[k], occupancy)
            else:
                scale = mean_k / mean_run if mean_run > 0 else 1.0
                ek = ew * max(scale, 0.0)
            plans.append(TierPlan.build(ek, occupancy, cap,
                                        warm_div=warm_div))
        ref = plans[0]
        obs_metrics.default_registry().counter(
            "tiers_plans_built_total", labels={"kind": "phased"}).inc()
        return PhasedTierPlan(
            num_parts=ref.num_parts, cap=ref.cap, warm_cap=ref.warm_cap,
            phase_tier_bytes=tuple(p.tier_bytes for p in plans),
            boundaries=tuple(b for b, _, _ in bands))

    @staticmethod
    def from_block(host_gb: dict, warm_div: int = 8,
                   max_phases: int = MAX_PHASES) -> "PhasedTierPlan":
        """Phased plan from a host graph block: structural occupancy from
        the outbox slot map, pair profile from ``wire_ewma``, phase
        boundaries from ``changed_ewma``, per-band pair profiles from
        ``phase_pair_ewma`` when runs have taught them (see
        :func:`update_phase_profile`). On a block with no taught
        changed histogram this degenerates to a single-phase plan identical
        to ``TierPlan.from_block``."""
        occ = occupancy_from_ob_inv(host_gb["ob_inv"])
        ew = host_gb.get("wire_ewma")
        if ew is None:
            ew = occ
        cap = host_gb["ob_inv"].shape[1] // host_gb["ob_inv"].shape[0]
        return PhasedTierPlan.build(ew, occ, cap,
                                    changed_ewma=host_gb.get("changed_ewma"),
                                    warm_div=warm_div, max_phases=max_phases,
                                    phase_pair_ewma=host_gb.get(
                                        "phase_pair_ewma"))

    @staticmethod
    def from_graph(pg, warm_div: int = 8) -> "PhasedTierPlan":
        """Single structural phase (no history): identical geometry to
        ``TierPlan.from_graph`` — never overflows."""
        occ = occupancy_from_graph(pg)
        return PhasedTierPlan.build(occ, occ, pg.mailbox_cap,
                                    changed_ewma=None, warm_div=warm_div)

    @staticmethod
    def from_tier_plan(plan: TierPlan) -> "PhasedTierPlan":
        return PhasedTierPlan(num_parts=plan.num_parts, cap=plan.cap,
                              warm_cap=plan.warm_cap,
                              phase_tier_bytes=(plan.tier_bytes,),
                              boundaries=(_NO_BOUNDARY,))

    @staticmethod
    def for_resume(host_gb: dict, warm_div: int = 8,
                   max_phases: int = 3) -> "PhasedTierPlan":
        """Phased plan for a POST-DELTA RESTART (an incremental resume from
        the previous fixpoint). A restart is narrow from round 0 — its
        traffic is the delta's dirty frontier, not the run-shape history —
        and apply_delta pre-announced that frontier EXACTLY
        (``announce_ewma``: per-pair prime-round counts plus the
        horizon-bounded warm floor). Building phase 0 from the announce
        record instead of the pair EWMA is what makes a COLD replica's
        restart cheap: the structural prior (wire_ewma on an untaught
        block) covers the worst case of ANY run, while the announce covers
        exactly this one — the prime round provably fits (announced counts
        are exact, and TierPlan.build gives every pair at least its
        expected width), and later supersteps ride the warm floor plus the
        per-superstep dense-retry backstop. Tail phases scale the announce
        down by the changed-histogram bands' relative widths. Falls back
        to :meth:`from_block` when no announce is pending (e.g. a re-run
        with no intervening delta)."""
        ann = host_gb.get("announce_ewma")
        if ann is None or not np.any(np.asarray(ann) > 0):
            return PhasedTierPlan.from_block(host_gb, warm_div=warm_div,
                                             max_phases=max_phases)
        occ = occupancy_from_ob_inv(host_gb["ob_inv"])
        cap = host_gb["ob_inv"].shape[1] // host_gb["ob_inv"].shape[0]
        ew = np.minimum(np.asarray(ann, np.float64), occ)
        bands = phase_bands(host_gb.get("changed_ewma"),
                            max_phases=max_phases)
        plans = [TierPlan.build(ew, occ, cap, warm_div=warm_div)]
        mean0 = max(bands[0][2], 1e-9)
        for _, _, mean_k in bands[1:]:
            plans.append(TierPlan.build(ew * (mean_k / mean0), occ, cap,
                                        warm_div=warm_div))
        ref = plans[0]
        obs_metrics.default_registry().counter(
            "tiers_plans_built_total", labels={"kind": "resume"}).inc()
        return PhasedTierPlan(
            num_parts=ref.num_parts, cap=ref.cap, warm_cap=ref.warm_cap,
            phase_tier_bytes=tuple(p.tier_bytes for p in plans),
            boundaries=tuple(b for b, _, _ in bands))

    @staticmethod
    def narrow_resume(host_gb: dict, warm_div: int = 8) -> "PhasedTierPlan":
        """Single-phase plan at the resume geometry — for runs that are
        narrow-frontier resumes from superstep 0 and stay narrow (the
        landmark refresh path: a handful of stale query lanes re-relaxing
        a small dirty region never sees the wide band). The widths come
        from the announce record (:meth:`for_resume`'s phase 0); with no
        announce pending (a resume with no intervening delta is quiesced)
        they fall back to the profile plan's NARROW tail. Overflow is
        repaired by the phased engine's per-superstep dense retry, so
        underestimating a resume's width costs a retried round, never
        correctness."""
        ann = host_gb.get("announce_ewma")
        announced = ann is not None and bool(np.any(np.asarray(ann) > 0))
        full = (PhasedTierPlan.for_resume(host_gb, warm_div=warm_div)
                if announced
                else PhasedTierPlan.from_block(host_gb, warm_div=warm_div))
        pick = 0 if announced else -1
        return PhasedTierPlan(
            num_parts=full.num_parts, cap=full.cap, warm_cap=full.warm_cap,
            phase_tier_bytes=(full.phase_tier_bytes[pick],),
            boundaries=(_NO_BOUNDARY,))

    # ---------------- escalation ----------------
    def escalate_phase(self, phase: int, pair_mask: np.ndarray
                       ) -> "PhasedTierPlan":
        """Promote the overflowed pairs of ONE phase one tier — the other
        phases' geometry is untouched (a spill in the narrow tail says
        nothing about the wide band's widths)."""
        plans = list(self.phase_plans())
        plans[phase] = plans[phase].escalate(pair_mask)
        return dataclasses.replace(
            self, phase_tier_bytes=tuple(p.tier_bytes for p in plans))

    def escalations_from(self, old: "PhasedTierPlan") -> int:
        return sum(p.escalations_from(q) for p, q in
                   zip(self.phase_plans(), old.phase_plans()))


class TierSchedule:
    """The tier plan laid out on a concrete mesh of ``D`` devices (``v =
    P / D`` partitions each). All tables are numpy constants consumed at
    trace time; the leading axis is the device id, selected per shard with
    ``lax.axis_index`` (SPMD-uniform program, per-device constants).

      hot_send (D, D, h)  sender i, destination-device block j, row r ->
                          flat local outbox row ``(s % v) * P + d`` (PAD pads)
      hot_recv (D, D, h)  receiver j, source-device block i, row r ->
                          flat local inbox pair ``(d % v) * P + s``
      hot_res_shifts      [(k, g, send (D, g), recv (D, g)), ...] — hot rows
                          BEYOND the uniform all_to_all block, shipped dense
                          (full cap, no ids) by one ppermute per shift
      warm/cold shifts    [(k, g, send (D, g), recv (D, g)), ...] — shift k
                          ships rows whose destination device is ``(i + k) %
                          D`` via one ppermute; shifts with zero pairs on
                          every device are skipped entirely (the round-robin
                          covers only the nonzero device pairs).

    The hot tier is TWO-LEVEL: the all_to_all row block ``h`` is sized to
    the MINIMUM per-device-pair hot count (uniform — every pair contributes
    ``h`` full rows, so nothing inside it is padding), and the rows beyond
    it ride a residual ppermute schedule. A skewed mesh therefore stops
    padding every device's tables to the global max pair count: only the
    devices that actually own the excess ship it. At D == 1 (or any
    perfectly balanced mesh) min == max and the residual is empty, so the
    layout — and every routed bit — is unchanged.
    """

    def __init__(self, plan: TierPlan, num_devices: int):
        P, D = plan.num_parts, num_devices
        assert P % D == 0, "partitions must tile the device mesh"
        v = P // D
        self.plan = plan
        self.D, self.v, self.P = D, v, P
        self.cap, self.warm_cap = plan.cap, plan.warm_cap
        tiers = plan.tiers

        # hot tier, two-level: a uniform all_to_all block sized to the
        # MINIMUM per-device-pair count, plus a residual ppermute schedule
        # for the rows beyond it (dense rows — same geometry, no ids)
        hs, hd = np.nonzero(tiers == HOT)
        di, dj = hs // v, hd // v
        m = np.zeros((D, D), np.int64)
        np.add.at(m, (di, dj), 1)
        self.hot_h = hb = int(m.min()) if m.size else 0
        self.hot_send = np.full((D, D, max(hb, 1)), PAD, np.int32)
        self.hot_recv = np.full((D, D, max(hb, 1)), PAD, np.int32)
        fill = np.zeros((D, D), np.int64)
        res = []            # residual hot rows past the uniform block
        for s, d in zip(hs, hd):
            i, j = s // v, d // v
            r = fill[i, j]
            fill[i, j] = r + 1
            if r < hb:
                self.hot_send[i, j, r] = (s % v) * P + d
                self.hot_recv[j, i, r] = (d % v) * P + s
            else:
                res.append((int((j - i) % D), int(i), int(s), int(d)))
        shifts = []
        for k in sorted({k for k, _, _, _ in res}):
            rows = [(i, s, d) for kk, i, s, d in res if kk == k]
            cnt = np.zeros(D, np.int64)
            for i, _, _ in rows:
                cnt[i] += 1
            g = int(cnt.max())
            send = np.full((D, g), PAD, np.int32)
            recv = np.full((D, g), PAD, np.int32)
            fillr = np.zeros(D, np.int64)
            for i, s, d in rows:
                j = (i + k) % D
                r = fillr[i]
                fillr[i] = r + 1
                send[i, r] = (s % v) * P + d
                recv[j, r] = (d % v) * P + s
            shifts.append((k, g, send, recv))
        self.hot_res_shifts = tuple(shifts)

        # warm/cold tiers: ppermute round-robin over device shifts
        def shifts_for(code):
            ss, dd = np.nonzero(tiers == code)
            out = []
            for k in range(D):
                sel = (dd // v) == ((ss // v) + k) % D
                if not sel.any():
                    continue
                cnt = np.zeros(D, np.int64)
                np.add.at(cnt, ss[sel] // v, 1)
                g = int(cnt.max())
                send = np.full((D, g), PAD, np.int32)
                recv = np.full((D, g), PAD, np.int32)
                fill = np.zeros(D, np.int64)
                for s, d in zip(ss[sel], dd[sel]):
                    i = s // v
                    j = (i + k) % D
                    r = fill[i]
                    fill[i] = r + 1
                    send[i, r] = (s % v) * P + d
                    recv[j, r] = (d % v) * P + s
                out.append((k, g, send, recv))
            return tuple(out)

        self.warm_shifts = shifts_for(WARM)
        self.cold_shifts = shifts_for(COLD)

    # ---------------- static wire accounting ----------------
    def round_slots(self) -> int:
        """Value slots (Q-groups) physically routed per exchange round —
        the buffer geometry, data-independent. Dense ships P²·cap."""
        hot = self.D * self.D * self.hot_h * self.cap
        hot += sum(self.D * g * self.cap for _, g, _, _ in self.hot_res_shifts)
        warm = sum(self.D * g * self.warm_cap for _, g, _, _ in self.warm_shifts)
        cold = sum(self.D * g for _, g, _, _ in self.cold_shifts)
        return hot + warm + cold

    def round_index_slots(self) -> int:
        """int32 slot-id lanes riding beside the warm/cold value slots (hot
        rows are dense — no ids travel)."""
        warm = sum(self.D * g * self.warm_cap for _, g, _, _ in self.warm_shifts)
        cold = sum(self.D * g for _, g, _, _ in self.cold_shifts)
        return warm + cold

    def round_bytes(self, num_queries: Optional[int]) -> int:
        q = num_queries or 1
        return self.round_slots() * 4 * q + self.round_index_slots() * 4

    def device_round_slots(self) -> int:
        """Per-device share of round_slots (what one shard reports before
        the cross-device psum)."""
        return self.round_slots() // self.D

    def kind_byte_budgets(self, num_queries: Optional[int]) -> dict:
        """Per-HLO-collective-kind, PER-DEVICE byte ceilings of one exchange
        round — what the Gopher Sentinel holds compiled wire collectives to.

        ``all-to-all`` is the hot tier's uniform row block: every device
        ships D destination blocks of ``hot_h`` dense rows, ``cap`` value
        slots each. ``collective-permute`` is everything shifted — hot
        residual rows (dense, no ids), warm rows (values + int32 slot-id
        lanes) and cold singles (one value + one id) — summed over the
        round's shifts, so the ceiling holds even when XLA combines several
        ppermutes of a round into one instruction. The two budgets sum to
        ``round_bytes(q) // D``: the per-kind split is a refinement of the
        round total, not a second accounting."""
        q = num_queries or 1
        a2a = self.D * self.hot_h * self.cap * 4 * q
        cp = sum(g * self.cap * 4 * q for _, g, _, _ in self.hot_res_shifts)
        cp += sum(g * self.warm_cap * (4 * q + 4)
                  for _, g, _, _ in self.warm_shifts)
        cp += sum(g * (4 * q + 4) for _, g, _, _ in self.cold_shifts)
        return {"all-to-all": a2a, "collective-permute": cp}


def announce_frontier(host_gb: dict, pg, dirty: np.ndarray) -> None:
    """Pre-announce a delta's dirty frontier into the block's ``wire_ewma``
    (in place), two layers deep:

      1. pairs whose SOURCE VERTEX is dirty rise to their exact live-slot
         count — precisely what the next incremental run's inbox-prime
         round ships;
      2. every pair of a partition within the restart's EXPECTED SUPERSTEP
         HORIZON of the dirty set (meta-graph hops) rises to a WARM floor
         (``min(occupancy, COLD_THRESH·2 + 1)``): an incremental
         superstep's senders can only be partitions the dirty seeds reach
         through meta-edges, and in an h-superstep restart they can reach
         at most h hops — so the floor warms exactly the pairs that CAN
         fire before the predicted quiescence, not the whole closure. The
         horizon comes from the block's changed-histogram EWMA
         (:func:`expected_horizon`); with no taught history the floor
         falls back to the full meta-closure (PR 4's conservative
         behavior), and a horizon the history underestimates costs at most
         an overflow retry, never correctness.

    ``max``, not ``+=`` — idempotent across event replays on block
    replicas. Called by gofs.temporal.apply_delta on the zero-repack block
    path; the overflow/escalation retry backstops whatever this floor still
    underestimates."""
    ew = host_gb.get("wire_ewma")
    if ew is None:
        return
    P = pg.num_parts
    expect = np.zeros((P, P), np.float64)
    live = pg.re_src != PAD
    sp, e = np.nonzero(live)
    src_dirty = np.asarray(dirty, bool)[sp, pg.re_src[sp, e]]
    np.add.at(expect, (sp[src_dirty], pg.re_dst_part[sp[src_dirty],
                                                     e[src_dirty]]), 1)
    # meta-closure warm floor, bounded by the expected superstep horizon
    occ = occupancy_from_graph(pg)
    reach = np.asarray(dirty, bool).any(1)
    adj = occ > 0
    horizon = expected_horizon(host_gb.get("changed_ewma"))
    hops = 0
    while horizon is None or hops < horizon:
        grown = reach | adj[reach].any(0)
        if (grown == reach).all():
            break
        reach = grown
        hops += 1
    floor = np.where(reach[:, None], np.minimum(occ, 2 * COLD_THRESH + 1),
                     0.0)
    announced = np.maximum(expect, floor)
    host_gb["wire_ewma"] = np.maximum(
        np.asarray(ew, np.float64), announced).astype(np.float32)
    # the announce record itself, kept SEPARATE from the EWMA: the exact
    # per-pair expectation of the NEXT restart's traffic. On a fresh
    # replica the EWMA still sits at the structural prior (the max above is
    # a no-op), but the restart's prime round ships exactly ``expect`` —
    # PhasedTierPlan.for_resume builds from this record, which is how a
    # COLD block still gets restart-narrow geometry. max-combined so
    # stacked deltas before one run stay covered; consumed (cleared) by
    # update_profile once a run has folded its observation.
    prev = host_gb.get("announce_ewma")
    if prev is not None:
        announced = np.maximum(np.asarray(prev, np.float64), announced)
    host_gb["announce_ewma"] = announced.astype(np.float32)


def update_profile(host_gb: dict, pair_slots: np.ndarray, rounds: int,
                   decay: float = PROFILE_DECAY) -> np.ndarray:
    """Fold one run's observed per-pair packed counts into the block's
    ``wire_ewma`` profile (in place):

        ewma' = decay * ewma + (1 - decay) * pair_slots / rounds

    ``pair_slots`` is ``Telemetry.pair_slots`` — the (P, P) sum of packed
    counts over the run's exchange rounds (compact and tiered modes record
    it; the tiered counts are pre-truncation, so an overflowing pair's true
    demand raises its profile even while its messages were clipped). After
    a dense fallback retry, normalize by ``Telemetry.pair_rounds`` — the
    aborted tiered attempt's round count, which the counts actually cover —
    not ``supersteps + 1``. A block with no profile (not built by
    host_graph_block) is left untouched.

    Folding an observation also CONSUMES the pending announce record
    (``announce_ewma``): the run it pre-announced has happened, and the
    observation now carries the real counts."""
    ew = host_gb.get("wire_ewma")
    if ew is None:
        return None
    old = np.asarray(ew, np.float64)
    obs = np.asarray(pair_slots, np.float64) / max(int(rounds), 1)
    out = (decay * old + (1.0 - decay) * obs).astype(np.float32)
    host_gb["wire_ewma"] = out
    if host_gb.get("announce_ewma") is not None:
        host_gb["announce_ewma"] = np.zeros_like(out)
    reg = obs_metrics.default_registry()
    reg.counter("tiers_profile_updates_total", labels={"profile": "wire"}).inc()
    reg.gauge("tiers_profile_drift", labels={"profile": "wire"}).set(
        float(np.abs(out - old).sum()) / max(float(np.abs(old).sum()), 1.0))
    return out


def update_changed_profile(host_gb: dict, count_hist,
                           decay: float = PROFILE_DECAY) -> Optional[np.ndarray]:
    """Fold one run's per-ROUND changed-slot histogram into the block's
    ``changed_ewma`` (in place):

        ewma' = decay * ewma + (1 - decay) * count_hist (zero-extended)

    ``count_hist`` is ``Telemetry.count_hist`` — the Σ of packed per-pair
    counts each exchange round shipped, indexed in round units: entry 0 is
    the inbox prime, entry s+1 is superstep s's exchange (the frontier
    width in mailbox slots; compact, tiered and phased runs all record
    it). Observations are ZERO-extended past the run's realized rounds: a
    run that converged early is evidence the tail is quiet, exactly what
    the phase boundaries and the announce-floor horizon should learn.
    Entries past ``PHASE_HIST_LEN`` are truncated (a run that long pins
    its tail phase anyway). A block with no ``changed_ewma`` is left
    untouched."""
    ch = host_gb.get("changed_ewma")
    if ch is None or count_hist is None:
        return None
    obs = np.zeros(PHASE_HIST_LEN, np.float64)
    hist = np.asarray(count_hist, np.float64).reshape(-1)[:PHASE_HIST_LEN]
    obs[:hist.size] = hist
    old = np.asarray(ch, np.float64)
    out = (decay * old + (1.0 - decay) * obs).astype(np.float32)
    host_gb["changed_ewma"] = out
    reg = obs_metrics.default_registry()
    reg.counter("tiers_profile_updates_total",
                labels={"profile": "changed"}).inc()
    reg.gauge("tiers_profile_drift", labels={"profile": "changed"}).set(
        float(np.abs(out - old).sum()) / max(float(np.abs(old).sum()), 1.0))
    return out


def update_phase_profile(host_gb: dict, phase_pair_slots, phase_hist,
                         decay: float = PROFILE_DECAY
                         ) -> Optional[np.ndarray]:
    """Fold one phased run's PER-BAND pair observations into the block's
    ``phase_pair_ewma`` (in place), band by band:

        ewma'[k] = decay * ewma[k]
                   + (1 - decay) * phase_pair_slots[k] / rounds_in_band_k

    ``phase_pair_slots`` is ``Telemetry.phase_pair_slots`` — the (K, P, P)
    per-phase sum of packed counts — and ``phase_hist`` is
    ``Telemetry.phase_hist``, the per-round phase index, whose bincount
    gives each band's realized round count (the normalizer). A band the
    run never entered (zero rounds — e.g. an early global halt skipped the
    narrow tail) is LEFT ALONE rather than decayed toward zero: absence of
    rounds is absence of evidence, not evidence of silence. Bands past the
    stored profile's depth (``MAX_PHASES``) are dropped. A block without
    the profile (not built by host_graph_block) is left untouched.

    :meth:`PhasedTierPlan.build` consumes the taught profile per band, so
    each band's geometry tracks the pairs that actually fire IN that band
    instead of one global EWMA rescaled by frontier width."""
    ppe = host_gb.get("phase_pair_ewma")
    if ppe is None or phase_pair_slots is None or phase_hist is None:
        return None
    obs = np.asarray(phase_pair_slots, np.float64)
    old = np.asarray(ppe, np.float64)
    K = min(obs.shape[0], old.shape[0])
    rounds_k = np.bincount(np.asarray(phase_hist, np.int64).reshape(-1),
                           minlength=K)
    out = old.copy()
    for k in range(K):
        if rounds_k[k] <= 0:
            continue
        out[k] = (decay * old[k]
                  + (1.0 - decay) * obs[k] / int(rounds_k[k]))
    host_gb["phase_pair_ewma"] = out.astype(np.float32)
    reg = obs_metrics.default_registry()
    reg.counter("tiers_profile_updates_total",
                labels={"profile": "phase_pair"}).inc()
    reg.gauge("tiers_profile_drift", labels={"profile": "phase_pair"}).set(
        float(np.abs(out - old).sum()) / max(float(np.abs(old).sum()), 1.0))
    return host_gb["phase_pair_ewma"]
