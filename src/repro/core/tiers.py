"""Gopher Mesh: capacity-tiered physical exchange planning.

PR 3's compact exchange made the *modeled* protocol payload track the
frontier, but the physical ``all_to_all`` still routed the dense
``P² · cap · Q`` buffer (plus a slot map) every superstep — on real
hardware the interconnect moved MORE bytes than the dense path. This module
plans the buffers XLA actually routes so their geometry tracks the
frontier:

  * every partition pair carries a per-pair **traffic profile** — an EWMA of
    the packed slot counts the compact/tiered exchange already computes
    (``wire_ewma`` on the host graph block, seeded with the structural slot
    occupancy, updated by :func:`update_profile` after each run and patched
    through ``gofs.temporal.apply_delta`` so a delta's dirty frontier is
    pre-announced as expected traffic);
  * :meth:`TierPlan.build` classifies pairs into static capacity **tiers**
    — hot pairs keep the full ``cap``-slot row, warm pairs ship a packed
    ``cap/8``-slot prefix, cold pairs ship a single width-1 slot, and pairs
    with zero structural occupancy ship **nothing** (true pairwise skip);
  * :meth:`TierPlan.schedule` lays the tiers out on a concrete device mesh:
    the hot tier rides one ``all_to_all`` over per-device-pair row blocks,
    the warm/cold tiers ride a ``ppermute`` round-robin over only the
    nonzero device shifts. Every table is a static constant, so the routed
    buffer shapes — and therefore the physical wire — are known at compile
    time (:meth:`TierSchedule.round_slots`).

Correctness is never bet on the profile: the pack kernel reports per-pair
**overflow** (a pair whose active slot count exceeded its tier width had
messages truncated), the engine retries the run on the dense exchange —
results stay bit-identical to ``exchange='dense'`` unconditionally — and
:meth:`TierPlan.escalate` promotes the overflowed pairs one tier for the
next version.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.gofs.formats import PAD

# tier codes, ordered so escalation is "+1 and clamp"
EXCLUDED = 0    # zero structural occupancy: the pair can never carry a slot
COLD = 1        # width-1 row: historically silent pair, count-only headroom
WARM = 2        # packed cap/8 prefix
HOT = 3         # the full cap-slot row (the dense geometry, per pair)

TIER_NAMES = {EXCLUDED: "excluded", COLD: "cold", WARM: "warm", HOT: "hot"}

# classification thresholds (see TierPlan.build)
COLD_THRESH = 0.5   # expected slots/round at or below this -> cold
PROFILE_DECAY = 0.25  # update_profile: weight kept on the OLD ewma


def occupancy_from_ob_inv(ob_inv: np.ndarray) -> np.ndarray:
    """(P, P*cap) outbox slot map -> (P, P) live-slot count per pair: the
    structural ceiling on any superstep's packed count."""
    P = ob_inv.shape[0]
    cap = ob_inv.shape[1] // P
    return (ob_inv.reshape(P, P, cap) != PAD).sum(-1).astype(np.int64)


def occupancy_from_graph(pg) -> np.ndarray:
    """(P, P) live remote-edge count per pair straight from the GoFS fields
    (no block needed)."""
    P = pg.num_parts
    occ = np.zeros((P, P), np.int64)
    live = pg.re_src != PAD
    sp, e = np.nonzero(live)
    np.add.at(occ, (sp, pg.re_dst_part[sp, e]), 1)
    return occ


@dataclasses.dataclass(frozen=True)
class TierPlan:
    """Static per-pair tier assignment. Hashable — the engine's compiled-loop
    cache keys on it, so two engines with the same plan share one compile."""
    num_parts: int
    cap: int
    warm_cap: int
    tier_bytes: bytes            # (P*P,) int8 row-major tier codes

    @property
    def tiers(self) -> np.ndarray:
        P = self.num_parts
        return np.frombuffer(self.tier_bytes, np.int8).reshape(P, P)

    def limits(self) -> np.ndarray:
        """(P, P) int32 slot budget per pair: the tier width the pack stage
        truncates to (and the overflow detector compares counts against)."""
        w = np.array([0, 1, self.warm_cap, self.cap], np.int32)
        return w[self.tiers]

    def counts(self) -> dict:
        t = self.tiers
        return {name: int((t == code).sum()) for code, name in TIER_NAMES.items()}

    # ---------------- construction ----------------
    @staticmethod
    def build(expected: np.ndarray, occupancy: np.ndarray, cap: int,
              warm_div: int = 8) -> "TierPlan":
        """Classify pairs from ``expected`` (EWMA slots/round, (P, P) float)
        clamped by ``occupancy`` (structural live slots, (P, P) int):

          occupancy == 0      -> EXCLUDED  (nothing can ever ship)
          occupancy == 1      -> COLD      (width 1 covers the worst case)
          ew >  warm_cap      -> HOT       (full cap row)
          ew <= COLD_THRESH   -> COLD      (width 1)
          otherwise           -> WARM      (cap / warm_div prefix)

        where ``ew = min(expected, occupancy)``. With ``expected ==
        occupancy`` (the structural prior a cold-built block carries) no
        pair's width can be below its maximum possible count, so the plan
        provably never overflows; a learned profile trades that guarantee
        for geometry, backstopped by the dense fallback retry."""
        P = occupancy.shape[0]
        warm_cap = min(max(1, -(-cap // warm_div)), cap)
        ew = np.minimum(np.asarray(expected, np.float64), occupancy)
        t = np.full((P, P), WARM, np.int8)
        t[ew <= COLD_THRESH] = COLD
        t[ew > warm_cap] = HOT
        t[occupancy <= 1] = COLD
        t[occupancy <= 0] = EXCLUDED
        return TierPlan(num_parts=P, cap=int(cap), warm_cap=int(warm_cap),
                        tier_bytes=t.tobytes())

    @staticmethod
    def from_block(host_gb: dict, warm_div: int = 8) -> "TierPlan":
        """Plan from a host graph block: structural occupancy from its
        outbox slot map, expected traffic from its ``wire_ewma`` profile."""
        occ = occupancy_from_ob_inv(host_gb["ob_inv"])
        ew = host_gb.get("wire_ewma")
        if ew is None:
            ew = occ
        cap = host_gb["ob_inv"].shape[1] // host_gb["ob_inv"].shape[0]
        return TierPlan.build(ew, occ, cap, warm_div=warm_div)

    @staticmethod
    def from_graph(pg, warm_div: int = 8) -> "TierPlan":
        """Structural plan (no history): expected = occupancy, so every
        pair's width covers its worst case — never overflows. The engine's
        default when ``exchange='tiered'`` is requested without a plan."""
        occ = occupancy_from_graph(pg)
        return TierPlan.build(occ, occ, pg.mailbox_cap, warm_div=warm_div)

    # ---------------- escalation ----------------
    def escalate(self, pair_mask: np.ndarray) -> "TierPlan":
        """Promote overflowed pairs one tier (COLD->WARM->HOT); a pair that
        overflowed while EXCLUDED signals a plan/block mismatch and jumps
        straight to HOT. Returns a new plan (self is frozen)."""
        t = self.tiers.copy()
        m = np.asarray(pair_mask, bool)
        t[m & (t == EXCLUDED)] = HOT
        t[m & (t > EXCLUDED)] = np.minimum(t[m & (t > EXCLUDED)] + 1, HOT)
        return dataclasses.replace(self, tier_bytes=t.tobytes())

    def escalations_from(self, old: "TierPlan") -> int:
        return int((self.tiers > old.tiers).sum())

    # ---------------- physical schedule ----------------
    def schedule(self, num_devices: int = 1) -> "TierSchedule":
        return TierSchedule(self, num_devices)


class TierSchedule:
    """The tier plan laid out on a concrete mesh of ``D`` devices (``v =
    P / D`` partitions each). All tables are numpy constants consumed at
    trace time; the leading axis is the device id, selected per shard with
    ``lax.axis_index`` (SPMD-uniform program, per-device constants).

      hot_send (D, D, h)  sender i, destination-device block j, row r ->
                          flat local outbox row ``(s % v) * P + d`` (PAD pads)
      hot_recv (D, D, h)  receiver j, source-device block i, row r ->
                          flat local inbox pair ``(d % v) * P + s``
      warm/cold shifts    [(k, g, send (D, g), recv (D, g)), ...] — shift k
                          ships rows whose destination device is ``(i + k) %
                          D`` via one ppermute; shifts with zero pairs on
                          every device are skipped entirely (the round-robin
                          covers only the nonzero device pairs).
    """

    def __init__(self, plan: TierPlan, num_devices: int):
        P, D = plan.num_parts, num_devices
        assert P % D == 0, "partitions must tile the device mesh"
        v = P // D
        self.plan = plan
        self.D, self.v, self.P = D, v, P
        self.cap, self.warm_cap = plan.cap, plan.warm_cap
        tiers = plan.tiers

        # hot tier: per-device-pair row blocks for one all_to_all
        hs, hd = np.nonzero(tiers == HOT)
        di, dj = hs // v, hd // v
        m = np.zeros((D, D), np.int64)
        np.add.at(m, (di, dj), 1)
        self.hot_h = h = int(m.max()) if m.size else 0
        self.hot_send = np.full((D, D, max(h, 1)), PAD, np.int32)
        self.hot_recv = np.full((D, D, max(h, 1)), PAD, np.int32)
        fill = np.zeros((D, D), np.int64)
        for s, d in zip(hs, hd):
            i, j = s // v, d // v
            r = fill[i, j]
            fill[i, j] = r + 1
            self.hot_send[i, j, r] = (s % v) * P + d
            self.hot_recv[j, i, r] = (d % v) * P + s

        # warm/cold tiers: ppermute round-robin over device shifts
        def shifts_for(code):
            ss, dd = np.nonzero(tiers == code)
            out = []
            for k in range(D):
                sel = (dd // v) == ((ss // v) + k) % D
                if not sel.any():
                    continue
                cnt = np.zeros(D, np.int64)
                np.add.at(cnt, ss[sel] // v, 1)
                g = int(cnt.max())
                send = np.full((D, g), PAD, np.int32)
                recv = np.full((D, g), PAD, np.int32)
                fill = np.zeros(D, np.int64)
                for s, d in zip(ss[sel], dd[sel]):
                    i = s // v
                    j = (i + k) % D
                    r = fill[i]
                    fill[i] = r + 1
                    send[i, r] = (s % v) * P + d
                    recv[j, r] = (d % v) * P + s
                out.append((k, g, send, recv))
            return tuple(out)

        self.warm_shifts = shifts_for(WARM)
        self.cold_shifts = shifts_for(COLD)

    # ---------------- static wire accounting ----------------
    def round_slots(self) -> int:
        """Value slots (Q-groups) physically routed per exchange round —
        the buffer geometry, data-independent. Dense ships P²·cap."""
        hot = self.D * self.D * self.hot_h * self.cap
        warm = sum(self.D * g * self.warm_cap for _, g, _, _ in self.warm_shifts)
        cold = sum(self.D * g for _, g, _, _ in self.cold_shifts)
        return hot + warm + cold

    def round_index_slots(self) -> int:
        """int32 slot-id lanes riding beside the warm/cold value slots (hot
        rows are dense — no ids travel)."""
        warm = sum(self.D * g * self.warm_cap for _, g, _, _ in self.warm_shifts)
        cold = sum(self.D * g for _, g, _, _ in self.cold_shifts)
        return warm + cold

    def round_bytes(self, num_queries: Optional[int]) -> int:
        q = num_queries or 1
        return self.round_slots() * 4 * q + self.round_index_slots() * 4

    def device_round_slots(self) -> int:
        """Per-device share of round_slots (what one shard reports before
        the cross-device psum)."""
        return self.round_slots() // self.D


def announce_frontier(host_gb: dict, pg, dirty: np.ndarray) -> None:
    """Pre-announce a delta's dirty frontier into the block's ``wire_ewma``
    (in place), two layers deep:

      1. pairs whose SOURCE VERTEX is dirty rise to their exact live-slot
         count — precisely what the next incremental run's inbox-prime
         round ships;
      2. every pair of a partition in the META-GRAPH CLOSURE of the dirty
         set rises to a WARM floor (``min(occupancy, COLD_THRESH·2 + 1)``):
         an incremental superstep's senders can only be partitions the
         dirty seeds reach through meta-edges, so this keeps every pair
         that CAN fire during the restart out of the width-1 cold tier —
         without touching unreachable pairs, and only until quiet runs
         decay the profile back down.

    ``max``, not ``+=`` — idempotent across event replays on block
    replicas. Called by gofs.temporal.apply_delta on the zero-repack block
    path; the overflow/escalation retry backstops whatever this floor still
    underestimates."""
    ew = host_gb.get("wire_ewma")
    if ew is None:
        return
    P = pg.num_parts
    expect = np.zeros((P, P), np.float64)
    live = pg.re_src != PAD
    sp, e = np.nonzero(live)
    src_dirty = np.asarray(dirty, bool)[sp, pg.re_src[sp, e]]
    np.add.at(expect, (sp[src_dirty], pg.re_dst_part[sp[src_dirty],
                                                     e[src_dirty]]), 1)
    # meta-closure warm floor
    occ = occupancy_from_graph(pg)
    reach = np.asarray(dirty, bool).any(1)
    adj = occ > 0
    while True:
        grown = reach | adj[reach].any(0)
        if (grown == reach).all():
            break
        reach = grown
    floor = np.where(reach[:, None], np.minimum(occ, 2 * COLD_THRESH + 1),
                     0.0)
    host_gb["wire_ewma"] = np.maximum(
        np.asarray(ew, np.float64), np.maximum(expect, floor)
        ).astype(np.float32)


def update_profile(host_gb: dict, pair_slots: np.ndarray, rounds: int,
                   decay: float = PROFILE_DECAY) -> np.ndarray:
    """Fold one run's observed per-pair packed counts into the block's
    ``wire_ewma`` profile (in place):

        ewma' = decay * ewma + (1 - decay) * pair_slots / rounds

    ``pair_slots`` is ``Telemetry.pair_slots`` — the (P, P) sum of packed
    counts over the run's exchange rounds (compact and tiered modes record
    it; the tiered counts are pre-truncation, so an overflowing pair's true
    demand raises its profile even while its messages were clipped). After
    a dense fallback retry, normalize by ``Telemetry.pair_rounds`` — the
    aborted tiered attempt's round count, which the counts actually cover —
    not ``supersteps + 1``. A block with no profile (not built by
    host_graph_block) is left untouched."""
    ew = host_gb.get("wire_ewma")
    if ew is None:
        return None
    obs = np.asarray(pair_slots, np.float64) / max(int(rounds), 1)
    out = (decay * np.asarray(ew, np.float64)
           + (1.0 - decay) * obs).astype(np.float32)
    host_gb["wire_ewma"] = out
    return out
