"""Version-spanning wrappers for the handful of jax APIs that moved.

The repo targets the current jax surface (``jax.shard_map``,
``jax.make_mesh(axis_types=...)``); the pinned toolchain in some containers
ships 0.4.x where shard_map lives in ``jax.experimental.shard_map`` (with
``check_rep`` instead of ``check_vma``) and ``make_mesh`` takes no
``axis_types``. Everything engine/launch-side goes through these two helpers
so the BSP core has exactly one place that knows about the skew.
"""
from __future__ import annotations

import jax

try:  # modern surface
    from jax.sharding import AxisType as _AxisType
except ImportError:  # jax < 0.4.38
    _AxisType = None


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, on any jax version.

    The mailbox all_to_all produces per-device blocks whose replication the
    checker cannot infer (same reason the upstream code passes
    ``check_vma=False``), so the check is always disabled.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def make_mesh(shape, axes, devices=None):
    """``jax.make_mesh`` with Auto axis types where the kwarg exists."""
    shape = tuple(shape)
    axes = tuple(axes)
    if devices is None:
        n = 1
        for s in shape:
            n *= s
        devices = jax.devices()[:n]
    if _AxisType is not None:
        return jax.make_mesh(shape, axes, devices=devices,
                             axis_types=(_AxisType.Auto,) * len(shape))
    return jax.make_mesh(shape, axes, devices=devices)
