"""Graph blocks: the device-side pytree the engine runs over, and its
zero-repack versioned patch path.

A *graph block* is the per-partition array bundle (leading axis P) derived
from a PartitionedGraph: the raw GoFS fields, the two-binned ELL adjacency
(``_binned_adjacency``) and the gather-form mailbox inverse maps
(``_mailbox_inverse``). Building it cold is O(E) host work — fine once, but
the temporal path (gofs.temporal.apply_delta) produces a new graph VERSION
per delta batch, and re-packing the derived arrays per version used to cost
about half the incremental path's fixed time at RN scale.

``patch_host_block`` instead edits the previous version's HOST block in
O(|delta|): touched local ELL rows are re-binned individually (hubs grow
monotonically; the w_lo / m_lo lane widths are FROZEN at the base build so
almost no delta changes any array shape), freed mailbox slots are PAD-ed out
of ``ob_inv`` and the destination feed lists, and new remote edges splice
into both sides of the routing plan. Shapes only change when a delta
overflows a frozen budget (hub rows, feed width, mailbox cap) — each growth
is lane-padded so the compiled-loop cache isn't thrashed by every version.

Host blocks (numpy) are the patchable representation; ``device_block``
uploads one to jnp for the engine. The cold build stays the oracle the
patched block is tested against (results must match bit-for-bit for
idempotent ⊕ — see tests/test_wire.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.gofs.formats import PAD, PartitionedGraph, grow_last_axis
from repro.obs import metrics as obs_metrics
from repro.resilience import faults as _faults

_GB_FIELDS = ["nbr", "wgt", "vmask", "out_degree", "global_id", "sg_id",
              "re_src", "re_wgt", "re_dst_part", "re_dst_local", "re_slot"]

# host-block feed-position encoding: src_part * _SLOT_STRIDE + slot. The
# stride is FIXED (not the mailbox cap), so cap growth never invalidates
# stored positions; device_block re-bases onto the runtime cap at upload.
_SLOT_STRIDE = 1 << 16

# host-only block entries: profile/planning metadata the compiled loop never
# reads. They stay off the device block — their shapes don't follow the
# per-partition leading-axis convention the shard_map in_specs assume (and
# uploading pure planning state would thrash the gb-signature-keyed
# compiled-loop cache).
_HOST_ONLY = ("changed_ewma", "announce_ewma", "phase_pair_ewma")


def _binned_adjacency(pg: PartitionedGraph, lane_pad: int = 8):
    """Two-bin the local ELL by degree (kernels.ops.binned_ell_spmv_multi
    layout):
    a narrow (P, v_max, w_lo) block for the bulk plus a full-width
    (P, ah_max, d_max) block for the few hub rows. One mega-hub otherwise
    forces every row's sweep lane to its width."""
    P, v_max, d_pad = pg.nbr.shape
    deg = (pg.nbr != PAD).sum(2)
    bulk = deg[deg > 0]
    p95 = int(np.percentile(bulk, 95)) if bulk.size else 1
    w_lo = min(((max(p95, 1) + lane_pad - 1) // lane_pad) * lane_pad, d_pad)
    # hub = degree past the narrow width OR any live entry parked past it —
    # post-delta ELL rows can carry holes (apply_delta pokes PAD mid-row),
    # so a row whose degree shrank back under w_lo may still have a live
    # neighbor at a column >= w_lo; truncating it to [:w_lo] would silently
    # drop edges
    is_hub = (deg > w_lo) | (pg.nbr[:, :, w_lo:] != PAD).any(2)
    ah_max = max(int(is_hub.sum(1).max()) if is_hub.size else 0, 1)
    nbr_lo = pg.nbr[:, :, :w_lo].copy()
    wgt_lo = pg.wgt[:, :, :w_lo].copy()
    nbr_lo[is_hub] = PAD
    wgt_lo[is_hub] = 0.0
    hub_idx = np.full((P, ah_max), PAD, np.int32)
    hub_nbr = np.full((P, ah_max, d_pad), PAD, np.int32)
    hub_wgt = np.zeros((P, ah_max, d_pad), np.float32)
    for p in range(P):
        hv = np.flatnonzero(is_hub[p])
        hub_idx[p, :hv.size] = hv
        hub_nbr[p, :hv.size] = pg.nbr[p, hv]
        hub_wgt[p, :hv.size] = pg.wgt[p, hv]
    return nbr_lo, wgt_lo, hub_idx, hub_nbr, hub_wgt


def _mailbox_inverse(pg: PartitionedGraph, lane_pad: int = 8):
    """Precompute the mailbox routing plan's INVERSE maps so both sides of
    the superstep exchange are pure gathers (XLA:CPU/TPU scatter is the
    dominant superstep cost otherwise; the plan is static, so nothing needs
    to be scattered at runtime — GoFS already fixed every slot at build).

      ob_inv   (P, P*cap)        outbox slot -> remote-edge index (PAD empty)
      ib_lo    (P, v_max, m_lo)  vertex -> received positions, PAD fill
      ib_hub_idx (P, hr_max)     vertices receiving > m_lo messages
      ib_hub   (P, hr_max, m_hi) their (wider) feed lists

    The inbox side is two-binned by in-message count for the same reason the
    ELL sweep degree-bins: one hub receiver would otherwise pad every
    vertex's feed list to the hub's width.

    HOST blocks store feed positions CAP-INDEPENDENTLY as
    ``src_part * _SLOT_STRIDE + slot`` so a sticky-cap growth (zero-repack
    patching) never rewrites them; ``device_block`` decodes to the runtime
    flat index ``src_part * cap + slot`` in one fused pass at upload.
    """
    from repro.gofs.formats import _cumcount
    P, _ = pg.re_src.shape
    cap = pg.mailbox_cap
    v_max = pg.v_max
    # encoding bounds: slot ids share an int32 with src_part at _SLOT_STRIDE;
    # overflow would silently bleed slot bits into the partition field
    assert cap < _SLOT_STRIDE, \
        f"mailbox cap {cap} >= slot stride {_SLOT_STRIDE}"
    assert P * _SLOT_STRIDE < 2 ** 31, \
        f"{P} partitions overflow the int32 feed-position encoding"
    sp_all, e_all = np.nonzero(pg.re_src != PAD)
    d_all = pg.re_dst_part[sp_all, e_all].astype(np.int64)
    v_all = pg.re_dst_local[sp_all, e_all].astype(np.int64)
    c_all = pg.re_slot[sp_all, e_all].astype(np.int64)

    ob_inv = np.full((P, P * cap), PAD, np.int32)
    ob_inv[sp_all, d_all * cap + c_all] = e_all

    counts = np.zeros((P, v_max), np.int64)
    np.add.at(counts, (d_all, v_all), 1)
    m_hi = max(int(counts.max()) if counts.size else 1, 1)
    bulk = counts[counts > 0]
    p95 = int(np.percentile(bulk, 95)) if bulk.size else 1
    m_lo = min(((max(p95, 1) + lane_pad - 1) // lane_pad) * lane_pad, m_hi)
    m_hi = ((m_hi + lane_pad - 1) // lane_pad) * lane_pad
    is_hub = counts > m_lo
    hr_max = max(int(is_hub.sum(1).max()) if is_hub.size else 0, 1)

    ib_lo = np.full((P, v_max, m_lo), PAD, np.int32)
    ib_hub_idx = np.full((P, hr_max), PAD, np.int32)
    ib_hub = np.full((P, hr_max, m_hi), PAD, np.int32)
    hub_row = np.full((P, v_max), -1, np.int64)
    for d in range(P):
        hv = np.flatnonzero(is_hub[d])
        hub_row[d, hv] = np.arange(hv.size)
        ib_hub_idx[d, :hv.size] = hv
    k_all = _cumcount(d_all * v_max + v_all)
    f_all = (sp_all * _SLOT_STRIDE + c_all).astype(np.int32)
    hub_msg = is_hub[d_all, v_all]
    ib_lo[d_all[~hub_msg], v_all[~hub_msg], k_all[~hub_msg]] = f_all[~hub_msg]
    ib_hub[d_all[hub_msg], hub_row[d_all[hub_msg], v_all[hub_msg]],
           k_all[hub_msg]] = f_all[hub_msg]
    return ob_inv, ib_lo, ib_hub_idx, ib_hub


def host_graph_block(pg: PartitionedGraph) -> dict:
    """Cold-build the HOST (numpy) graph block: raw fields + binned adjacency
    + mailbox inverse maps. This is the representation ``patch_host_block``
    edits in O(|delta|) per version.

    The block also carries the Gopher Mesh per-pair traffic profile
    ``wire_ewma`` (P, P float32) — an EWMA of observed packed slot counts
    per exchange round, seeded here with the STRUCTURAL slot occupancy (the
    worst case any round can ship, so a plan built from a fresh block never
    overflows) — and the Gopher Phases changed-histogram EWMA
    ``changed_ewma`` (PHASE_HIST_LEN, float32; host-only) — the expected
    frontier width per superstep, seeded ZERO (no history: phased plans
    degenerate to one structural phase until runs teach it via
    core.tiers.update_changed_profile). Runs fold observations in via
    core.tiers.update_profile / update_changed_profile;
    gofs.temporal.apply_delta pre-announces a delta's dirty frontier into
    the pair profile; patch_host_block carries both across versions
    untouched."""
    from repro.core.tiers import (MAX_PHASES, PHASE_HIST_LEN,
                                  occupancy_from_ob_inv)
    gb = {k: np.asarray(getattr(pg, k)) for k in _GB_FIELDS}
    gb["part_index"] = np.arange(pg.num_parts, dtype=np.int32)
    (gb["nbr_lo"], gb["wgt_lo"], gb["adj_hub_idx"],
     gb["adj_hub_nbr"], gb["adj_hub_wgt"]) = _binned_adjacency(pg)
    (gb["ob_inv"], gb["ib_lo"],
     gb["ib_hub_idx"], gb["ib_hub"]) = _mailbox_inverse(pg)
    gb["wire_ewma"] = occupancy_from_ob_inv(gb["ob_inv"]).astype(np.float32)
    gb["changed_ewma"] = np.zeros(PHASE_HIST_LEN, np.float32)
    # pending announce record (core.tiers.announce_frontier): the exact
    # per-pair expectation of the NEXT restart's traffic; zero = no delta
    # pending. Host-only, like changed_ewma.
    gb["announce_ewma"] = np.zeros_like(gb["wire_ewma"])
    # per-band pair profiles (core.tiers.update_phase_profile): band k's own
    # observed (P, P) packed-count EWMA, consumed by PhasedTierPlan.build in
    # place of the scaled-global fallback once taught. Host-only.
    gb["phase_pair_ewma"] = np.zeros(
        (MAX_PHASES,) + gb["wire_ewma"].shape, np.float32)
    for name, arr in pg.attrs.items():
        gb[f"attr_{name}"] = np.asarray(arr)
    return gb


def _decode_feeds(host_gb: dict):
    """Re-base the cap-independent feed positions onto the runtime mailbox
    cap: src_part * _SLOT_STRIDE + slot  ->  src_part * cap + slot."""
    P = host_gb["ob_inv"].shape[0]
    cap = host_gb["ob_inv"].shape[1] // P

    def dec(arr):
        q, r = np.divmod(arr, _SLOT_STRIDE)
        return np.where(arr == PAD, PAD, q * cap + r).astype(np.int32)

    return dec(host_gb["ib_lo"]), dec(host_gb["ib_hub"])


def device_block(host_gb: dict) -> dict:
    """Upload a host block to device (jnp) arrays, decoding the feed maps
    to runtime flat indices (see _SLOT_STRIDE). Host-only metadata
    (_HOST_ONLY) stays behind."""
    ib_lo, ib_hub = _decode_feeds(host_gb)
    out = {}
    for k, v in host_gb.items():
        if k in _HOST_ONLY:
            continue
        if k == "ib_lo":
            v = ib_lo
        elif k == "ib_hub":
            v = ib_hub
        out[k] = jnp.asarray(v)
    return out


def graph_block(pg: PartitionedGraph, as_spec: bool = False) -> dict:
    """The device-side pytree of per-partition arrays (leading axis P).
    ``as_spec=True`` returns ShapeDtypeStructs (dry-run lowering)."""
    gb = host_graph_block(pg)
    if as_spec:
        return {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                for k, v in gb.items() if k not in _HOST_ONLY}
    return device_block(gb)


def verify_host_block(host_gb: dict) -> list:
    """Cheap structural audit of a host graph block — Gopher Shield's
    corrupted-block detector. Returns a list of human-readable problems
    (empty == structurally sound). Vectorized O(block size): catches the
    corruption classes the fault injector (and real bit-rot) produce —
    missing keys, shape drift between paired arrays, out-of-range ids,
    non-finite weights on live lanes — without re-deriving the layout."""
    need = set(_GB_FIELDS) | {"nbr_lo", "wgt_lo", "adj_hub_idx",
                              "adj_hub_nbr", "adj_hub_wgt", "ob_inv",
                              "ib_lo", "ib_hub_idx", "ib_hub", "part_index"}
    missing = sorted(need - set(host_gb))
    if missing:
        return [f"missing block keys: {missing}"]
    problems = []
    nbr = np.asarray(host_gb["nbr"])
    P, v_max = nbr.shape[0], nbr.shape[1]

    def adj(name_n, name_w, bound):
        a = np.asarray(host_gb[name_n])
        w = np.asarray(host_gb[name_w])
        if w.shape != a.shape:
            problems.append(f"{name_w} shape {w.shape} != "
                            f"{name_n} shape {a.shape}")
            return
        live = a != PAD
        if live.any():
            if not np.isfinite(w[live]).all():
                problems.append(f"non-finite weight on live {name_n} lane")
            bad = live & ((a < 0) | (a >= bound))
            if bad.any():
                problems.append(f"{int(bad.sum())} {name_n} ids outside "
                                f"[0, {bound})")

    adj("nbr", "wgt", v_max)
    adj("nbr_lo", "wgt_lo", v_max)
    adj("adj_hub_nbr", "adj_hub_wgt", v_max)
    adj("re_src", "re_wgt", v_max)
    for name, bound in (("re_dst_part", P), ("re_dst_local", v_max)):
        a = np.asarray(host_gb[name])
        live = np.asarray(host_gb["re_src"]) != PAD
        if a.shape == live.shape and live.any():
            bad = live & ((a < 0) | (a >= bound))
            if bad.any():
                problems.append(f"{int(bad.sum())} {name} ids outside "
                                f"[0, {bound})")
    ob_inv = np.asarray(host_gb["ob_inv"])
    if ob_inv.ndim != 2 or ob_inv.shape[0] != P or ob_inv.shape[1] % P:
        problems.append(f"ob_inv shape {ob_inv.shape} is not (P, P*cap) "
                        f"for P={P}")
    return problems


# ---------------- zero-repack versioned patch ----------------

def _grow_axis1(arr: np.ndarray, extra: int, fill):
    pad = [(0, 0), (0, extra)] + [(0, 0)] * (arr.ndim - 2)
    return np.pad(arr, pad, constant_values=fill)


def patch_host_block(gb: dict, new_pg: PartitionedGraph,
                     touched_rows, rdel, radd, lane_pad: int = 8) -> dict:
    """Patch the previous version's host block into ``new_pg``'s block in
    O(|delta|) — no re-bin, no inverse-map rebuild.

    ``touched_rows``  (T, 2) int (p, v) pairs     local ELL rows whose
                      (or any iterable of pairs)  nbr/wgt changed
    ``rdel``          [(sp, dp, dv, slot)]        freed remote-edge slots
    ``radd``          [(sp, dp, dv, slot, eidx)]  spliced remote edges

    Invariants preserved (the cold build's contract):
      - non-hub adjacency rows keep every live entry inside [:w_lo]
        (apply_delta fills the first PAD hole, so a row only spills past
        w_lo the moment its degree exceeds w_lo — at which point it is
        promoted); hubs never demote, so the hub set grows monotonically;
      - a destination vertex's feed positions live in EITHER ib_lo or its
        ib_hub row, never both (⊕ = sum would double-count otherwise);
      - the mailbox cap is STICKY: it grows (lane-padded) when a new slot
        overflows it and never shrinks, so the compiled-loop cache survives
        almost every version; feed positions are stride-encoded
        (_SLOT_STRIDE), so growth re-lays only ob_inv, in O(P²·cap).
    """
    from repro.gofs.formats import _cumcount
    _faults.fire("blocks.patch", version=getattr(new_pg, "version", None),
                 parts=new_pg.num_parts)
    out = dict(gb)                               # copy-on-write per array
    for k in _GB_FIELDS:
        out[k] = np.asarray(getattr(new_pg, k))
    P, v_max = new_pg.num_parts, new_pg.v_max
    nbr, wgt = out["nbr"], out["wgt"]
    d_pad = nbr.shape[2]

    # ---- binned adjacency: re-bin only the touched rows (vectorized over
    # the touch set; only the rare hub PROMOTION falls back to a loop) ----
    touched_rows = np.asarray(
        touched_rows if isinstance(touched_rows, np.ndarray)
        else sorted(touched_rows), np.int64).reshape(-1, 2)
    if len(touched_rows):
        nbr_lo = gb["nbr_lo"].copy()
        wgt_lo = gb["wgt_lo"].copy()
        hub_idx = gb["adj_hub_idx"].copy()
        hub_nbr = gb["adj_hub_nbr"]
        hub_wgt = gb["adj_hub_wgt"]
        if hub_nbr.shape[2] < d_pad:             # local ELL widened this delta
            hub_nbr = grow_last_axis(hub_nbr, d_pad - hub_nbr.shape[2], PAD)
            hub_wgt = grow_last_axis(hub_wgt, d_pad - hub_wgt.shape[2], 0.0)
        else:
            hub_nbr, hub_wgt = hub_nbr.copy(), hub_wgt.copy()
        w_lo = nbr_lo.shape[2]
        rows = touched_rows
        ps, vs = rows[:, 0], rows[:, 1]
        hub_eq = hub_idx[ps] == vs[:, None]               # (T, ah_max)
        was_hub = hub_eq.any(1)
        hrow = np.argmax(hub_eq, 1)
        hub_nbr[ps[was_hub], hrow[was_hub]] = nbr[ps[was_hub], vs[was_hub]]
        hub_wgt[ps[was_hub], hrow[was_hub]] = wgt[ps[was_hub], vs[was_hub]]
        fits = (np.all(nbr[ps, vs][:, w_lo:] == PAD, axis=1)
                if w_lo < d_pad else np.ones(ps.size, bool))
        ok = ~was_hub & fits                              # stays narrow-bin
        nbr_lo[ps[ok], vs[ok]] = nbr[ps[ok], vs[ok], :w_lo]
        wgt_lo[ps[ok], vs[ok]] = wgt[ps[ok], vs[ok], :w_lo]
        for p, v in rows[~was_hub & ~fits]:               # promote to hub
            free = np.flatnonzero(hub_idx[p] == PAD)
            if free.size == 0:
                hub_idx = grow_last_axis(hub_idx, lane_pad, PAD)
                hub_nbr = _grow_axis1(hub_nbr, lane_pad, PAD)
                hub_wgt = _grow_axis1(hub_wgt, lane_pad, 0.0)
                free = np.flatnonzero(hub_idx[p] == PAD)
            hub_idx[p, free[0]] = v
            hub_nbr[p, free[0]] = nbr[p, v]
            hub_wgt[p, free[0]] = wgt[p, v]
            nbr_lo[p, v] = PAD
            wgt_lo[p, v] = 0.0
        out["nbr_lo"], out["wgt_lo"] = nbr_lo, wgt_lo
        out["adj_hub_idx"] = hub_idx
        out["adj_hub_nbr"], out["adj_hub_wgt"] = hub_nbr, hub_wgt

    # ---- mailbox inverse maps: splice the remote-edge events ----
    if rdel or radd:
        ib_lo = gb["ib_lo"].copy()
        ib_hub_idx = gb["ib_hub_idx"].copy()
        ib_hub = gb["ib_hub"].copy()
        ob_inv = gb["ob_inv"]
        cap_old = ob_inv.shape[1] // P
        cap = new_pg.mailbox_cap
        assert cap < _SLOT_STRIDE, \
            f"mailbox cap {cap} >= slot stride {_SLOT_STRIDE}"
        # a cap SMALLER than the block's would mis-stride every ob_inv splice
        # below (and leave the engine's exchange shapes inconsistent with the
        # graph): replaying DeltaResult.events on a replica block requires
        # the originating apply_delta to have run with block= (sticky cap) —
        # an exact-fit apply_delta can shrink cap and its events are then
        # not replayable onto a wider block.
        assert cap >= cap_old, \
            f"graph cap {cap} < block cap {cap_old}: events not replayable"
        if cap > cap_old:                        # sticky cap overflowed: grow
            # feed positions are cap-independent (_SLOT_STRIDE), so only the
            # outbox slot map itself needs re-laying
            ob_inv = grow_last_axis(ob_inv.reshape(P, P, cap_old),
                                cap - cap_old, PAD).reshape(P, P * cap)
        else:
            ob_inv = ob_inv.copy()
        m_lo = ib_lo.shape[2]

        def _feed_add(dp, dv, fpos):
            # slow path: hub append / promotion / width growth (rare)
            nonlocal ib_hub, ib_hub_idx
            hr = np.flatnonzero(ib_hub_idx[dp] == dv)
            if hr.size:
                free = np.flatnonzero(ib_hub[dp, hr[0]] == PAD)
                if free.size == 0:               # hub feed width overflowed
                    ib_hub = grow_last_axis(ib_hub, lane_pad, PAD)
                    free = np.flatnonzero(ib_hub[dp, hr[0]] == PAD)
                ib_hub[dp, hr[0], free[0]] = fpos
                return
            free = np.flatnonzero(ib_lo[dp, dv] == PAD)
            if free.size:
                ib_lo[dp, dv, free[0]] = fpos
                return
            # promote dv to hub receiver: MOVE its feed list (exclusive
            # membership — ⊕ = sum must not see a position twice)
            hfree = np.flatnonzero(ib_hub_idx[dp] == PAD)
            if hfree.size == 0:
                ib_hub_idx = grow_last_axis(ib_hub_idx, lane_pad, PAD)
                ib_hub = _grow_axis1(ib_hub, lane_pad, PAD)
                hfree = np.flatnonzero(ib_hub_idx[dp] == PAD)
            h = hfree[0]
            ib_hub_idx[dp, h] = dv
            if ib_hub.shape[2] <= m_lo:          # hub width == m_lo: widen so
                ib_hub = grow_last_axis(ib_hub, lane_pad, PAD)  # the moved list +
            ib_hub[dp, h, :m_lo] = ib_lo[dp, dv]            # new pos fit
            ib_hub[dp, h, m_lo] = fpos
            ib_lo[dp, dv] = PAD

        if rdel:
            ev = np.asarray(rdel, np.int64)               # (E, 4)
            sp, dp, dv, slot = ev.T
            fpos = (sp * _SLOT_STRIDE + slot).astype(np.int32)
            ob_inv[sp, dp * cap + slot] = PAD
            # each fpos occurs exactly once in its destination's feed list;
            # distinct events hit distinct positions, so one fancy scatter
            # clears them all (hub and narrow receivers separately)
            hub_eq = ib_hub_idx[dp] == dv[:, None]
            in_hub = hub_eq.any(1)
            hr = np.argmax(hub_eq, 1)
            nh = ~in_hub
            if nh.any():
                j = np.argmax(ib_lo[dp[nh], dv[nh]] == fpos[nh][:, None], 1)
                ib_lo[dp[nh], dv[nh], j] = PAD
            if in_hub.any():
                j = np.argmax(ib_hub[dp[in_hub], hr[in_hub]]
                              == fpos[in_hub][:, None], 1)
                ib_hub[dp[in_hub], hr[in_hub], j] = PAD

        if radd:
            ev = np.asarray(radd, np.int64)               # (E, 5)
            sp, dp, dv, slot, eidx = ev.T
            ob_inv[sp, dp * cap + slot] = eidx
            fpos = (sp * _SLOT_STRIDE + slot).astype(np.int32)
            # k-th add to the same feed row takes the row's (k+1)-th PAD
            # hole — vectorized over all events whose row has room; hub
            # appends, overflow and promotion take the slow path
            k = _cumcount(dp * v_max + dv)
            hub_eq = ib_hub_idx[dp] == dv[:, None]
            in_hub = hub_eq.any(1)
            nh = ~in_hub
            holes = np.cumsum(ib_lo[dp, dv] == PAD, 1)    # (E, m_lo)
            room = nh & (holes[:, -1] >= k + 1)
            if room.any():
                j = np.argmax(holes[room] == (k[room] + 1)[:, None], 1)
                ib_lo[dp[room], dv[room], j] = fpos[room]
            rest = ~room
            for i in np.flatnonzero(rest):
                _feed_add(int(dp[i]), int(dv[i]), int(fpos[i]))
        out["ob_inv"] = ob_inv
        out["ib_lo"] = ib_lo
        out["ib_hub_idx"] = ib_hub_idx
        out["ib_hub"] = ib_hub
    else:
        assert new_pg.mailbox_cap == gb["ob_inv"].shape[1] // P, \
            "mailbox cap changed without remote-edge events"
    reg = obs_metrics.default_registry()
    reg.counter("blocks_patches_total").inc()
    reg.counter("blocks_rows_rebinned_total").inc(len(touched_rows))
    reg.counter("blocks_remote_slots_freed_total").inc(
        len(rdel) if rdel else 0)
    reg.counter("blocks_remote_slots_spliced_total").inc(
        len(radd) if radd else 0)
    return out
