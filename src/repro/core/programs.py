"""Sub-graph centric programs — the user-facing Compute abstraction.

The paper's ``Compute(Subgraph, Iterator<Message>)`` runs an arbitrary
shared-memory algorithm over the sub-graph per superstep. The TPU-idiomatic
equivalent is a *local-fixpoint sweep*: a vectorized semiring relaxation
iterated until the partition's state quiesces (information provably cannot
cross sub-graph boundaries through local edges, so the fixpoint IS the
"traverse the whole sub-graph in one superstep" semantics of §3.2).

``max_local_iters`` selects the execution model:
    None -> run to local fixpoint  (sub-graph centric, Gopher)
    1    -> one sweep per superstep (vertex centric, the Giraph baseline)
    k    -> bounded local work      (beyond-paper straggler mitigation)

Programs expose:
    init(gb)                -> state pytree of (v_max,) leaves
    superstep(state, inbox, gb, step) -> (state, changed_scalar, local_iters)
    messages(state, gb)     -> (vals (r_max,), send_mask (r_max,))
    combine                 -> inbox ⊕: 'min' | 'max' | 'sum'
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.gofs.formats import PAD
from repro.kernels import ops


def _ew_combine(combine: str, a, b):
    return jnp.minimum(a, b) if combine == "min" else jnp.maximum(a, b)


@dataclasses.dataclass(frozen=True)
class SemiringProgram:
    """Idempotent-semiring fixpoint programs: CC, SSSP, BFS, MaxVertex."""
    semiring: str                       # min_plus | max_first
    init_fn: Callable                   # gb -> x0 (v_max,)
    max_local_iters: Optional[int] = None
    spmv_backend: Optional[str] = None
    fixpoint_unroll: int = 1            # sweeps fused per loop iteration (perf knob)

    @property
    def combine(self) -> str:
        return "min" if self.semiring == "min_plus" else "max"

    def init(self, gb) -> dict:
        x0 = self.init_fn(gb)
        return {"x": x0, "changed_v": gb["vmask"]}

    def _sweep(self, x, gb):
        y = ops.semiring_spmv(x, gb["nbr"], gb["wgt"], self.semiring,
                              backend=self.spmv_backend)
        return _ew_combine(self.combine, x, y)

    def superstep(self, state, inbox, gb, step):
        x0 = state["x"]
        vmask = gb["vmask"]
        x = _ew_combine(self.combine, x0, inbox)
        max_it = self.max_local_iters
        if max_it == 1:
            x2 = self._sweep(x, gb)
            iters = jnp.int32(1)
        else:
            cap = jnp.int32(max_it if max_it is not None else 2**30)

            def cond(c):
                _, ch, it = c
                return ch & (it < cap)

            def body(c):
                xc, _, it = c
                y = xc
                for _ in range(self.fixpoint_unroll):
                    y = self._sweep(y, gb)
                ch = jnp.any((y != xc) & vmask)
                return y, ch, it + self.fixpoint_unroll

            x2, _, iters = jax.lax.while_loop(cond, body, (x, jnp.bool_(True), jnp.int32(0)))
        changed_v = (x2 != x0) & vmask
        # superstep 1: everything counts as changed so initial messages flow
        changed_v = jnp.where(step == 0, vmask, changed_v)
        changed = jnp.any(changed_v)
        return {"x": x2, "changed_v": changed_v}, changed, iters

    def messages(self, state, gb):
        src = gb["re_src"]
        valid = src != PAD
        safe = jnp.where(valid, src, 0)
        xv = state["x"][safe]
        vals = xv + gb["re_wgt"] if self.semiring == "min_plus" else xv
        send = valid & state["changed_v"][safe]
        return vals, send


@dataclasses.dataclass(frozen=True)
class PageRankProgram:
    """Classic PageRank (paper §5.3): one Jacobi iteration per superstep,
    fixed ``num_iters`` supersteps (the paper runs 30), pull formulation.
    Remote in-edges deliver contributions through the mailbox (⊕ = sum)."""
    n_global: int
    num_iters: int = 30
    damping: float = 0.85
    tol: Optional[float] = None         # if set, halt early on L1 delta (BlockRank phase 3)
    spmv_backend: Optional[str] = None
    init_fn: Optional[Callable] = None  # gb -> r0 (BlockRank seeds phase 3 with this)
    teleport_fn: Optional[Callable] = None  # gb -> (v_max,) personalization
                                            # distribution; uniform when None

    combine = "sum"

    def init(self, gb) -> dict:
        vmask = gb["vmask"]
        if self.init_fn is not None:
            r0 = jnp.where(vmask, self.init_fn(gb), 0.0)
        else:
            r0 = jnp.where(vmask, 1.0 / self.n_global, 0.0)
        return {"r": r0, "delta": jnp.float32(jnp.inf)}

    def _contrib(self, r, gb):
        deg = gb["out_degree"].astype(jnp.float32)
        return jnp.where(deg > 0, r / jnp.maximum(deg, 1.0), 0.0)

    def superstep(self, state, inbox, gb, step):
        vmask = gb["vmask"]
        r = state["r"]
        ones = jnp.ones_like(gb["wgt"])
        pull = ops.semiring_spmv(self._contrib(r, gb), gb["nbr"], ones,
                                 "plus_times", backend=self.spmv_backend)
        tele = (self.teleport_fn(gb) if self.teleport_fn is not None
                else 1.0 / self.n_global)
        r_new = jnp.where(
            vmask, (1.0 - self.damping) * tele + self.damping * (pull + inbox), 0.0)
        delta = jnp.sum(jnp.abs(r_new - r))
        if self.tol is not None:
            changed = (delta > self.tol) & (step + 1 < self.num_iters)
        else:
            changed = step + 1 < self.num_iters
        return {"r": r_new, "delta": delta}, changed, jnp.int32(1)

    def messages(self, state, gb):
        src = gb["re_src"]
        valid = src != PAD
        safe = jnp.where(valid, src, 0)
        vals = self._contrib(state["r"], gb)[safe]
        return vals, valid


# ---------------- init helpers ----------------

def init_max_vertex(gb):
    """MaxVertex / CC seed: each vertex starts at its own global id (paper's
    HCC: propagate the largest vertex id)."""
    return jnp.where(gb["vmask"], gb["global_id"].astype(jnp.float32), -jnp.inf)


def make_sssp_init(source_part: int, source_local: int):
    def init(gb):
        x = jnp.where(gb["vmask"], jnp.inf, jnp.inf)
        is_here = gb["part_index"] == source_part
        x = x.at[source_local].set(jnp.where(is_here, 0.0, jnp.inf))
        return x
    return init


def make_bfs_init(source_part: int, source_local: int):
    return make_sssp_init(source_part, source_local)  # BFS = SSSP with unit wgt
