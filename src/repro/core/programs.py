"""Sub-graph centric programs — the user-facing Compute abstraction.

The paper's ``Compute(Subgraph, Iterator<Message>)`` runs an arbitrary
shared-memory algorithm over the sub-graph per superstep. The TPU-idiomatic
equivalent is a *local-fixpoint sweep*: a vectorized semiring relaxation
iterated until the partition's state quiesces (information provably cannot
cross sub-graph boundaries through local edges, so the fixpoint IS the
"traverse the whole sub-graph in one superstep" semantics of §3.2).

``max_local_iters`` selects the execution model:
    None -> run to local fixpoint  (sub-graph centric, Gopher)
    1    -> one sweep per superstep (vertex centric, the Giraph baseline)
    k    -> bounded local work      (beyond-paper straggler mitigation)

Programs expose:
    init(gb)                -> state pytree of (v_max,) leaves
    superstep(state, inbox, gb, step) -> (state, changed_scalar, local_iters)
    messages(state, gb)     -> (vals (r_max,), send_mask (r_max,))
    combine                 -> inbox ⊕: 'min' | 'max' | 'sum'
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.gofs.formats import PAD
from repro.kernels import ops


def _ew_combine(combine: str, a, b):
    return jnp.minimum(a, b) if combine == "min" else jnp.maximum(a, b)


@dataclasses.dataclass(frozen=True)
class SemiringProgram:
    """Idempotent-semiring fixpoint programs: CC, SSSP, BFS, MaxVertex.

    Frontier-driven (paper §4.2 VoteToHalt, done properly): the state carries
    an active-frontier mask seeded by ``init`` — all of ``vmask`` on a cold
    start, ``gb["frontier0"]`` on an incremental resume — and the local
    fixpoint is a *masked* sweep gated on it. A partition whose frontier is
    empty runs ZERO sweep iterations that superstep (its while-loop condition
    is false on entry) instead of recomputing everything to discover nothing
    changed; within an active partition, rows with no active in-neighbor cost
    ~0 (kernels.semiring_spmv_frontier). For idempotent ⊕ the masked fixpoint
    is bitwise identical to the unmasked one.

    ``resume=True`` starts from a previous fixpoint: ``gb["x0"]`` is the prior
    state and ``gb["frontier0"]`` the dirty seed set (see gofs.temporal /
    algorithms.incremental); both arrive via ``GopherEngine.run(extra=...)``.
    """
    semiring: str                       # min_plus | max_first
    init_fn: Optional[Callable] = None  # gb -> x0 (v_max,); unused when resume
    max_local_iters: Optional[int] = None
    spmv_backend: Optional[str] = None
    fixpoint_unroll: int = 1            # sweeps fused per loop iteration (perf knob)
    resume: bool = False                # start from gb["x0"] / gb["frontier0"]

    @property
    def combine(self) -> str:
        return "min" if self.semiring == "min_plus" else "max"

    @property
    def megastep_kind(self) -> Optional[str]:
        """Gopher Hot eligibility: the fused megastep route replays the
        run-to-local-fixpoint schedule, so only the sub-graph centric mode
        (max_local_iters=None) qualifies — a bounded fixpoint's leftover
        frontier is already exact on the staged path and the fused loop
        would have to replicate its cap bookkeeping for no win."""
        return "semiring" if self.max_local_iters is None else None

    def init(self, gb) -> dict:
        # state: x — vertex values; changed_v — the send set (messages gate on
        # it); frontier — vertices whose local consequences are NOT yet
        # settled (the seed at step 0; afterwards only nonempty when a
        # bounded fixpoint hit max_local_iters mid-propagation)
        if self.resume:
            seed = gb["frontier0"] & gb["vmask"]
            return {"x": gb["x0"], "changed_v": seed, "frontier": seed}
        x0 = self.init_fn(gb)
        return {"x": x0, "changed_v": gb["vmask"], "frontier": gb["vmask"]}

    def _sweep(self, x, gb):
        y = ops.semiring_spmv(x, gb["nbr"], gb["wgt"], self.semiring,
                              backend=self.spmv_backend)
        return _ew_combine(self.combine, x, y)

    def _masked_sweep(self, x, f, gb):
        """One frontier-masked relaxation: recompute only rows with an active
        in-neighbor; the next frontier is the rows that actually changed."""
        y, _ = ops.semiring_spmv_frontier(x, f, gb["nbr"], gb["wgt"],
                                          self.semiring,
                                          backend=self.spmv_backend)
        x2 = _ew_combine(self.combine, x, y)
        return x2, (x2 != x) & gb["vmask"]

    def superstep(self, state, inbox, gb, step, axes=()):
        x0 = state["x"]
        vmask = gb["vmask"]
        x = _ew_combine(self.combine, x0, inbox)
        improved = (x != x0) & vmask        # vertices the mailbox moved
        # active set = carried frontier (the seed at step 0; leftover work
        # when a bounded fixpoint hit its cap) ∪ inbox improvements. A
        # quiesced partition enters the while loop with f0 empty and runs
        # ZERO sweeps this superstep.
        f0 = state["frontier"] | improved
        max_it = self.max_local_iters
        if max_it == 1:
            # vertex-centric baseline (Giraph): one full sweep, unmasked
            x2 = self._sweep(x, gb)
            iters = jnp.int32(1)
            f_left = jnp.zeros_like(vmask)
        else:
            cap = jnp.int32(max_it if max_it is not None else 2**30)

            def cond(c):
                _, f, it = c
                return jnp.any(f) & (it < cap)

            def body(c):
                xc, f, it = c
                for _ in range(self.fixpoint_unroll):
                    xc, f = self._masked_sweep(xc, f, gb)
                return xc, f, it + self.fixpoint_unroll

            x2, f_left, iters = jax.lax.while_loop(cond, body,
                                                   (x, f0, jnp.int32(0)))
        # the send set: vertices with news this superstep. The SEED frontier
        # needs no step-0 override here — the engine PRIMES the first inbox
        # from the init state's messages (gated on init's changed_v = seed),
        # so seed values, including incremental boundary announcements, were
        # already delivered before this superstep ran.
        changed_v = (x2 != x0) & vmask
        changed = jnp.any(changed_v)
        return {"x": x2, "changed_v": changed_v, "frontier": f_left}, \
            changed, iters

    def messages(self, state, gb):
        src = gb["re_src"]
        valid = src != PAD
        safe = jnp.where(valid, src, 0)
        xv = state["x"][safe]
        vals = xv + gb["re_wgt"] if self.semiring == "min_plus" else xv
        send = valid & state["changed_v"][safe]
        return vals, send


@dataclasses.dataclass(frozen=True)
class PageRankProgram:
    """Classic PageRank (paper §5.3): one Jacobi iteration per superstep,
    fixed ``num_iters`` supersteps (the paper runs 30), pull formulation.
    Remote in-edges deliver contributions through the mailbox (⊕ = sum).

    Dangling vertices (global out-degree 0) cannot forward rank through
    edges; their mass is redistributed by the teleport distribution every
    iteration — the standard G = d(A + dangling·teleᵀ) + (1-d)·1·teleᵀ
    formulation — so ranks sum to 1 on graphs with sinks. The dangling mass
    and the ``tol`` halt criterion are GLOBAL sums: ``axes`` names the
    collective axes the engine runs this program under (the vmap partition
    axis, plus the mesh axis on shard_map), so every partition sees the same
    totals and the early-halt decision is graph-wide, not per-partition.
    """
    n_global: int
    num_iters: int = 30
    damping: float = 0.85
    tol: Optional[float] = None         # if set, halt early on GLOBAL L1 delta
    spmv_backend: Optional[str] = None
    init_fn: Optional[Callable] = None  # gb -> r0 (BlockRank seeds phase 3 with this)
    teleport_fn: Optional[Callable] = None  # gb -> (v_max,) personalization
                                            # distribution; uniform when None

    combine = "sum"

    @property
    def megastep_kind(self) -> Optional[str]:
        """Fused-route eligibility: only the fixed-iteration schedule. With
        ``tol`` set the halt compares a GLOBAL float sum against a
        threshold, and the fused route's flat ⊕=sum association could flip
        that comparison on the margin — the staged and fused runs would
        disagree on the STEP COUNT, not just low-order bits."""
        return "pagerank" if self.tol is None else None

    def init(self, gb) -> dict:
        vmask = gb["vmask"]
        if self.init_fn is not None:
            r0 = jnp.where(vmask, self.init_fn(gb), 0.0)
        else:
            r0 = jnp.where(vmask, 1.0 / self.n_global, 0.0)
        return {"r": r0, "delta": jnp.float32(jnp.inf)}

    def _contrib(self, r, gb):
        deg = gb["out_degree"].astype(jnp.float32)
        return jnp.where(deg > 0, r / jnp.maximum(deg, 1.0), 0.0)

    def superstep(self, state, inbox, gb, step, axes=()):
        vmask = gb["vmask"]
        r = state["r"]
        ones = jnp.ones_like(gb["wgt"])
        pull = ops.semiring_spmv(self._contrib(r, gb), gb["nbr"], ones,
                                 "plus_times", backend=self.spmv_backend)
        tele = (self.teleport_fn(gb) if self.teleport_fn is not None
                else 1.0 / self.n_global)
        dangling = jnp.sum(jnp.where(vmask & (gb["out_degree"] == 0), r, 0.0))
        if axes:
            dangling = jax.lax.psum(dangling, axes)
        r_new = jnp.where(
            vmask,
            (1.0 - self.damping) * tele
            + self.damping * (pull + inbox + dangling * tele), 0.0)
        delta = jnp.sum(jnp.abs(r_new - r))
        if axes:
            delta = jax.lax.psum(delta, axes)
        if self.tol is not None:
            changed = (delta > self.tol) & (step + 1 < self.num_iters)
        else:
            changed = step + 1 < self.num_iters
        return {"r": r_new, "delta": delta}, changed, jnp.int32(1)

    def messages(self, state, gb):
        src = gb["re_src"]
        valid = src != PAD
        safe = jnp.where(valid, src, 0)
        vals = self._contrib(state["r"], gb)[safe]
        return vals, valid


# ---------------- init helpers ----------------

def init_max_vertex(gb):
    """MaxVertex / CC seed: each vertex starts at its own global id (paper's
    HCC: propagate the largest vertex id)."""
    return jnp.where(gb["vmask"], gb["global_id"].astype(jnp.float32), -jnp.inf)


def make_sssp_init(source_part: int, source_local: int):
    def init(gb):
        x = jnp.where(gb["vmask"], jnp.inf, jnp.inf)
        is_here = gb["part_index"] == source_part
        x = x.at[source_local].set(jnp.where(is_here, 0.0, jnp.inf))
        return x
    return init


def make_bfs_init(source_part: int, source_local: int):
    return make_sssp_init(source_part, source_local)  # BFS = SSSP with unit wgt
