"""Gopher: the sub-graph centric BSP execution engine.

Faithful mapping of the paper's §4.2 runtime onto SPMD JAX:

  paper                               here
  -----                               ----
  worker per machine                  mesh device along the 'parts' axis
  thread pool over sub-graphs         vectorized (vmap) partitions + the
                                      local-fixpoint sweep (programs.py)
  async TCP message flush             all_to_all mailbox at superstep boundary
                                      (XLA overlaps it with the sweep tail)
  manager sync/resume/terminate       psum of per-partition 'changed' flags
                                      inside a lax.while_loop — the manager
                                      degenerates to an all-reduce
  VoteToHalt + no input messages      changed == False (see programs.py for
                                      why this is equivalent for idempotent ⊕)

Two backends share every line of superstep logic:
  'local'     — all P partitions as a (P, ...) batch on one device (CPU tests,
                virtual partitions)
  'shard_map' — partitions sharded over a mesh axis; mailbox routed with a
                real all_to_all; halt via psum (multi-chip / dry-run path)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core import messages as msg
from repro.gofs.formats import PAD, PartitionedGraph

_GB_FIELDS = ["nbr", "wgt", "vmask", "out_degree", "global_id", "sg_id",
              "re_src", "re_wgt", "re_dst_part", "re_dst_local", "re_slot"]

# the vmapped partition axis gets a collective name so programs can take
# GLOBAL reductions (PageRank dangling mass / L1 halt) with a plain psum —
# the engine hands each program the axes it runs under (this one, plus the
# mesh axis on the shard_map backend)
_VPART_AXIS = "vparts"

# compiled BSP loops shared ACROSS engine instances (see _runner); FIFO-bounded
# so a churny fleet can't pin unbounded trace closures
_RUNNER_CACHE: dict = {}
_RUNNER_CACHE_CAP = 64


@dataclasses.dataclass(frozen=True)
class _PgScalars:
    """The only pg fields the compiled BSP loop reads — cached runners hold
    these instead of a full PartitionedGraph (see _runner)."""
    num_parts: int
    v_max: int
    mailbox_cap: int


@dataclasses.dataclass
class Telemetry:
    supersteps: int
    local_iters: np.ndarray        # (P,) cumulative sweep iterations (straggler signal)
    changed_hist: np.ndarray       # (supersteps,) #partitions changed per superstep
    messages_sent: int
    # query-batched runs only: per-query superstep at which the query last
    # changed (its individual convergence point — it stops sending after this)
    query_supersteps: Optional[np.ndarray] = None


def _binned_adjacency(pg: PartitionedGraph, lane_pad: int = 8):
    """Two-bin the local ELL by degree (kernels.ops.binned_ell_spmv_multi
    layout):
    a narrow (P, v_max, w_lo) block for the bulk plus a full-width
    (P, ah_max, d_max) block for the few hub rows. One mega-hub otherwise
    forces every row's sweep lane to its width."""
    P, v_max, d_pad = pg.nbr.shape
    deg = (pg.nbr != PAD).sum(2)
    bulk = deg[deg > 0]
    p95 = int(np.percentile(bulk, 95)) if bulk.size else 1
    w_lo = min(((max(p95, 1) + lane_pad - 1) // lane_pad) * lane_pad, d_pad)
    is_hub = deg > w_lo
    ah_max = max(int(is_hub.sum(1).max()) if is_hub.size else 0, 1)
    nbr_lo = pg.nbr[:, :, :w_lo].copy()
    wgt_lo = pg.wgt[:, :, :w_lo].copy()
    nbr_lo[is_hub] = PAD
    wgt_lo[is_hub] = 0.0
    hub_idx = np.full((P, ah_max), PAD, np.int32)
    hub_nbr = np.full((P, ah_max, d_pad), PAD, np.int32)
    hub_wgt = np.zeros((P, ah_max, d_pad), np.float32)
    for p in range(P):
        hv = np.flatnonzero(is_hub[p])
        hub_idx[p, :hv.size] = hv
        hub_nbr[p, :hv.size] = pg.nbr[p, hv]
        hub_wgt[p, :hv.size] = pg.wgt[p, hv]
    return nbr_lo, wgt_lo, hub_idx, hub_nbr, hub_wgt


def _mailbox_inverse(pg: PartitionedGraph, lane_pad: int = 8):
    """Precompute the mailbox routing plan's INVERSE maps so both sides of
    the superstep exchange are pure gathers (XLA:CPU/TPU scatter is the
    dominant superstep cost otherwise; the plan is static, so nothing needs
    to be scattered at runtime — GoFS already fixed every slot at build).

      ob_inv   (P, P*cap)        outbox slot -> remote-edge index (PAD empty)
      ib_lo    (P, v_max, m_lo)  vertex -> flat received positions
                                 (src_part*cap + slot), PAD fill
      ib_hub_idx (P, hr_max)     vertices receiving > m_lo messages
      ib_hub   (P, hr_max, m_hi) their (wider) feed lists

    The inbox side is two-binned by in-message count for the same reason the
    ELL sweep degree-bins: one hub receiver would otherwise pad every
    vertex's feed list to the hub's width.
    """
    from repro.gofs.formats import _cumcount
    P, _ = pg.re_src.shape
    cap = pg.mailbox_cap
    v_max = pg.v_max
    sp_all, e_all = np.nonzero(pg.re_src != PAD)
    d_all = pg.re_dst_part[sp_all, e_all].astype(np.int64)
    v_all = pg.re_dst_local[sp_all, e_all].astype(np.int64)
    c_all = pg.re_slot[sp_all, e_all].astype(np.int64)

    ob_inv = np.full((P, P * cap), PAD, np.int32)
    ob_inv[sp_all, d_all * cap + c_all] = e_all

    counts = np.zeros((P, v_max), np.int64)
    np.add.at(counts, (d_all, v_all), 1)
    m_hi = max(int(counts.max()) if counts.size else 1, 1)
    bulk = counts[counts > 0]
    p95 = int(np.percentile(bulk, 95)) if bulk.size else 1
    m_lo = min(((max(p95, 1) + lane_pad - 1) // lane_pad) * lane_pad, m_hi)
    m_hi = ((m_hi + lane_pad - 1) // lane_pad) * lane_pad
    is_hub = counts > m_lo
    hr_max = max(int(is_hub.sum(1).max()) if is_hub.size else 0, 1)

    ib_lo = np.full((P, v_max, m_lo), PAD, np.int32)
    ib_hub_idx = np.full((P, hr_max), PAD, np.int32)
    ib_hub = np.full((P, hr_max, m_hi), PAD, np.int32)
    hub_row = np.full((P, v_max), -1, np.int64)
    for d in range(P):
        hv = np.flatnonzero(is_hub[d])
        hub_row[d, hv] = np.arange(hv.size)
        ib_hub_idx[d, :hv.size] = hv
    k_all = _cumcount(d_all * v_max + v_all)
    f_all = (sp_all * cap + c_all).astype(np.int32)
    hub_msg = is_hub[d_all, v_all]
    ib_lo[d_all[~hub_msg], v_all[~hub_msg], k_all[~hub_msg]] = f_all[~hub_msg]
    ib_hub[d_all[hub_msg], hub_row[d_all[hub_msg], v_all[hub_msg]],
           k_all[hub_msg]] = f_all[hub_msg]
    return ob_inv, ib_lo, ib_hub_idx, ib_hub


def graph_block(pg: PartitionedGraph, as_spec: bool = False) -> dict:
    """The device-side pytree of per-partition arrays (leading axis P).
    ``as_spec=True`` returns ShapeDtypeStructs (dry-run lowering)."""
    gb = {k: np.asarray(getattr(pg, k)) for k in _GB_FIELDS}
    gb["part_index"] = np.arange(pg.num_parts, dtype=np.int32)
    (gb["nbr_lo"], gb["wgt_lo"], gb["adj_hub_idx"],
     gb["adj_hub_nbr"], gb["adj_hub_wgt"]) = _binned_adjacency(pg)
    (gb["ob_inv"], gb["ib_lo"],
     gb["ib_hub_idx"], gb["ib_hub"]) = _mailbox_inverse(pg)
    for name, arr in pg.attrs.items():
        gb[f"attr_{name}"] = np.asarray(arr)
    if as_spec:
        return {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in gb.items()}
    return {k: jnp.asarray(v) for k, v in gb.items()}


class GopherEngine:
    """Runs a program over a PartitionedGraph to global quiescence."""

    def __init__(self, pg: PartitionedGraph, program, backend: str = "local",
                 mesh=None, axis_name: str = "parts",
                 max_supersteps: int = 4096, gb: Optional[dict] = None):
        assert backend in ("local", "shard_map")
        if backend == "shard_map":
            assert mesh is not None
            d = mesh.shape[axis_name]
            assert pg.num_parts % d == 0, "partitions must tile the mesh axis"
        self.pg = pg
        self.program = program
        self.backend = backend
        self.mesh = mesh
        self.axis_name = axis_name
        self.max_supersteps = max_supersteps
        self._gb = gb                # cached device-side graph block; pass a
                                     # shared one so many engines (a serving
                                     # fleet) reuse a single device copy

    def _graph_block(self):
        """The device graph block, built once per engine — every query batch
        served by this engine shares it (and the jit cache entries keyed on
        its shapes)."""
        if self._gb is None:
            self._gb = graph_block(self.pg)
        return self._gb

    # ---------------- superstep body (backend-shared) ----------------
    def make_superstep(self, gb, num_queries: Optional[int] = None):
        """One BSP superstep over a partition batch gb (leading axis = local
        partition count). Returns (state, inbox, changed, liters(P,), nsent).

        With ``num_queries=Q`` the program is query-batched: state/inbox
        leaves carry a QUERY-TRAILING (v_max, Q) shape per partition (Q rides
        the contiguous lane dimension), `changed` is per-partition per-query
        (P, Q), and the mailbox carries cap*Q slots per partition pair —
        routing is identical on both backends.
        """
        prog = self.program
        Q = num_queries
        axes = ((_VPART_AXIS,) if self.backend == "local"
                else (_VPART_AXIS, self.axis_name))

        exchange = self.make_exchange(gb, num_queries=Q)

        def sstep(state, inbox, step):
            new_state, changed, liters = jax.vmap(
                lambda s, i, g: prog.superstep(s, i, g, step, axes=axes),
                in_axes=(0, 0, 0), axis_name=_VPART_AXIS)(state, inbox, gb)
            inbox, nsent = exchange(new_state)
            return new_state, inbox, changed, liters, nsent

        return sstep

    def make_exchange(self, gb, num_queries: Optional[int] = None):
        """The mailbox half of a superstep: state -> (inbox, nsent). Split
        out so the BSP loop can PRIME the first inbox from the INITIAL state
        — without priming, superstep 0 computes with an empty inbox and
        treats every remote in-edge as contributing the ⊕-identity. For
        idempotent programs that only delays information one superstep, but
        for PageRank it silently dropped all remote mass from the first
        Jacobi iteration (an error that decays only as damping^k)."""
        prog = self.program
        cap = self.pg.mailbox_cap
        v_max = self.pg.v_max
        combine = prog.combine
        num_parts = self.pg.num_parts
        Q = num_queries

        def exchange(state):
            vals, send = jax.vmap(prog.messages)(state, gb)
            # gather-form mailbox: slots PULL through the precomputed inverse
            # routing plan — no runtime scatter, and only values travel
            if Q is None:
                build = functools.partial(msg.build_outbox_gather,
                                          num_parts=num_parts, cap=cap,
                                          combine=combine)
            else:
                build = functools.partial(msg.build_outbox_gather_batched,
                                          num_parts=num_parts, cap=cap,
                                          combine=combine)
            ov = jax.vmap(build)(vals, send, gb["ob_inv"])
            if self.backend == "local":
                iv = msg.route_local(ov)
            else:
                iv = msg.route_shard_map(ov, self.axis_name)
            if Q is None:
                comb = functools.partial(msg.combine_inbox_gather,
                                         v_max=v_max, combine=combine)
            else:
                comb = functools.partial(msg.combine_inbox_gather_batched,
                                         v_max=v_max, cap=cap, combine=combine)
            inbox = jax.vmap(comb)(iv, gb["ib_lo"], gb["ib_hub_idx"],
                                   gb["ib_hub"])
            nsent = jnp.sum(send).astype(jnp.int32)
            return inbox, nsent

        return exchange

    def _run_batched(self, gb, num_queries: Optional[int] = None):
        """The full BSP loop over a partition batch. Runs as-is on the local
        backend; runs per-shard (with collectives) under shard_map.

        Query-batched runs halt when NO query changed anywhere; a query whose
        own flags went quiet stops producing messages (its send mask is gated
        on per-query changed_v) while the rest of the batch keeps moving.
        """
        prog = self.program
        Q = num_queries
        sstep = self.make_superstep(gb, num_queries=Q)
        p_local = gb["vmask"].shape[0]
        state0 = jax.vmap(prog.init)(gb)
        # prime the mailbox with the INITIAL state's messages so superstep 0
        # computes against a consistent inbox (see make_exchange)
        inbox0, nsent0 = self.make_exchange(gb, num_queries=Q)(state0)
        if self.backend == "shard_map":
            nsent0 = jax.lax.psum(nsent0, self.axis_name)
        tele0 = dict(liters=jnp.zeros((p_local,), jnp.int32),
                     hist=jnp.zeros((self.max_supersteps,), jnp.int32),
                     sent=nsent0)
        if Q is not None:
            tele0["qsteps"] = jnp.zeros((Q,), jnp.int32)

        def cond(c):
            _, _, step, done, _ = c
            return (~done) & (step < self.max_supersteps)

        def body(c):
            state, inbox, step, _, tele = c
            state, inbox, changed, liters, nsent = sstep(state, inbox, step)
            if Q is None:
                any_changed = jnp.any(changed)
                nchanged = jnp.sum(changed.astype(jnp.int32))
                if self.backend == "shard_map":
                    any_changed = jax.lax.psum(any_changed.astype(jnp.int32),
                                               self.axis_name) > 0
                    nchanged = jax.lax.psum(nchanged, self.axis_name)
                    nsent = jax.lax.psum(nsent, self.axis_name)
            else:
                changed_q = jnp.any(changed, axis=0).astype(jnp.int32)  # (Q,)
                nchanged = jnp.sum(jnp.any(changed, axis=-1).astype(jnp.int32))
                if self.backend == "shard_map":
                    changed_q = jax.lax.psum(changed_q, self.axis_name)
                    nchanged = jax.lax.psum(nchanged, self.axis_name)
                    nsent = jax.lax.psum(nsent, self.axis_name)
                any_changed = jnp.any(changed_q > 0)
            new_tele = dict(liters=tele["liters"] + liters,
                            hist=tele["hist"].at[step].set(nchanged),
                            sent=tele["sent"] + nsent)
            if Q is not None:
                new_tele["qsteps"] = jnp.where(changed_q > 0, step + 1,
                                               tele["qsteps"])
            return state, inbox, step + 1, ~any_changed, new_tele

        state, _, steps, _, tele = jax.lax.while_loop(
            cond, body, (state0, inbox0, jnp.int32(0), jnp.bool_(False), tele0))
        return state, steps, tele

    # ---------------- drivers ----------------
    def run(self, checkpointer=None, checkpoint_every: int = 0,
            resume: bool = False, extra: Optional[dict] = None):
        """Run to quiescence. With a `training.checkpoint.Checkpointer` and
        checkpoint_every=N, the BSP loop snapshots (state, inbox, superstep)
        every N supersteps and can restart from the last committed snapshot
        after a failure (BSP makes the cut trivially consistent — paper §4.2's
        synchronization points ARE the recovery lines).

        ``extra`` carries per-run dynamic (P, ...) graph-block entries — e.g.
        ``x0`` / ``frontier0`` for an incremental resume (SemiringProgram
        with resume=True) — without invalidating the shared cached block.
        """
        if checkpointer is not None and checkpoint_every > 0:
            assert not extra, "checkpointed runs don't take extra blocks yet"
            return self._run_checkpointed(checkpointer, checkpoint_every, resume)
        gb = self._graph_block()
        if extra:
            gb = dict(gb)
            for k, v in extra.items():
                gb[k] = jnp.asarray(v)
        state, steps, tele = self._runner(gb_example=gb)(gb)
        return jax.tree.map(np.asarray, state), self._telemetry(steps, tele)

    def run_queries(self, extra: Optional[dict] = None):
        """Run a query-batched program (``program.num_queries`` = Q) to global
        quiescence of ALL queries in ONE BSP run.

        ``extra`` carries the per-request dynamic inputs (query init values,
        PPR seed vectors, ...) as additional (P, ...) graph-block entries, so
        the compiled loop is reused across request batches of the same shape
        — only the query arrays are re-transferred.

        Returns (state, Telemetry) where state leaves are (P, v_max, Q)
        (query-trailing) and ``telemetry.query_supersteps[q]`` is the
        superstep at which query q last changed.
        """
        Q = getattr(self.program, "num_queries", None)
        assert Q is not None, "run_queries requires a query-batched program"
        gb = dict(self._graph_block())
        for k, v in (extra or {}).items():
            gb[k] = jnp.asarray(v)
        state, steps, tele = self._runner(num_queries=Q, gb_example=gb)(gb)
        return jax.tree.map(np.asarray, state), self._telemetry(steps, tele)

    def _telemetry(self, steps, tele) -> Telemetry:
        return Telemetry(
            supersteps=int(steps),
            local_iters=np.asarray(tele["liters"]).reshape(-1),
            changed_hist=np.asarray(tele["hist"])[:int(steps)],
            messages_sent=int(tele["sent"]) if np.ndim(tele["sent"]) == 0 else int(np.max(tele["sent"])),
            query_supersteps=(np.asarray(tele["qsteps"])
                              if "qsteps" in tele else None),
        )

    def _runner(self, num_queries: Optional[int] = None, gb_example=None):
        """The compiled BSP loop, cached so repeated runs hit the same jit
        entry instead of re-tracing.

        The cache is MODULE-level and keyed on everything the trace depends
        on — program (frozen dataclass; init_fn compares by identity),
        backend/mesh, loop bounds, partition-batch shapes, and the gb
        entry signature (shard_map in_specs are baked from the block
        structure) — so SHORT-LIVED ENGINES SHARE COMPILED LOOPS: a
        temporal-serving fleet that rebuilds its engines after every
        apply_delta re-enters the compiled loop as long as the delta didn't
        change any padded shape, instead of paying a full XLA compile per
        graph version."""
        gb_sig = (tuple(sorted((k, v.shape, str(v.dtype))
                               for k, v in gb_example.items()))
                  if gb_example is not None else None)
        key = (self.program, self.backend, num_queries, self.max_supersteps,
               self.axis_name, self.mesh, self.pg.num_parts, self.pg.v_max,
               self.pg.mailbox_cap, gb_sig)
        cached = _RUNNER_CACHE.get(key)
        if cached is None:
            # build the runner on a DETACHED engine holding only the scalars
            # the trace reads (graph data flows in through the gb argument):
            # a cached closure over `self` would pin this engine's device
            # graph block — and its host pg — for the cache entry's lifetime
            slim = GopherEngine.__new__(GopherEngine)
            slim.pg = _PgScalars(num_parts=self.pg.num_parts,
                                 v_max=self.pg.v_max,
                                 mailbox_cap=self.pg.mailbox_cap)
            slim.program = self.program
            slim.backend = self.backend
            slim.mesh = self.mesh
            slim.axis_name = self.axis_name
            slim.max_supersteps = self.max_supersteps
            slim._gb = None
            if self.backend == "local":
                cached = jax.jit(functools.partial(
                    slim._run_batched, num_queries=num_queries))
            else:
                cached = slim._sharded_fn(
                    num_queries=num_queries, gb_example=gb_example)
            if len(_RUNNER_CACHE) >= _RUNNER_CACHE_CAP:
                _RUNNER_CACHE.pop(next(iter(_RUNNER_CACHE)))
            _RUNNER_CACHE[key] = cached
        return cached

    def _run_checkpointed(self, ck, every: int, resume: bool):
        """Chunked BSP: jitted inner loop of <= `every` supersteps, snapshot
        between chunks (local backend). Reuses the engine's cached graph
        block — a checkpointed run must not build a second device copy —
        and carries the same telemetry counters as a normal run (after a
        resume, counters cover the current process's supersteps; the hist
        slots before the restored step are zero)."""
        assert self.backend == "local", "checkpointed runs use the local backend"
        gb = self._graph_block()
        prog = self.program
        sstep = self.make_superstep(gb)

        @jax.jit
        def chunk(state, inbox, step0, tele):
            def cond(c):
                _, _, step, done, _ = c
                return (~done) & (step < step0 + every) & (step < self.max_supersteps)

            def body(c):
                state, inbox, step, _, tele = c
                state, inbox, changed, li, nsent = sstep(state, inbox, step)
                nchanged = jnp.sum(changed.astype(jnp.int32))
                tele = dict(liters=tele["liters"] + li,
                            hist=tele["hist"].at[step].set(nchanged),
                            sent=tele["sent"] + nsent)
                return state, inbox, step + 1, ~jnp.any(changed), tele

            return jax.lax.while_loop(
                cond, body, (state, inbox, step0, jnp.bool_(False), tele))

        if resume and ck.latest_step() is not None:
            snap_like = {
                "state": jax.eval_shape(lambda g: jax.vmap(prog.init)(g), gb),
                "inbox": jax.ShapeDtypeStruct(
                    (self.pg.num_parts, self.pg.v_max), np.float32),
            }
            snap, step = ck.restore(snap_like)
            state, inbox = snap["state"], snap["inbox"]
            step = jnp.int32(step)
        else:
            state = jax.vmap(prog.init)(gb)
            inbox, nsent0 = jax.jit(self.make_exchange(gb))(state)
            step = jnp.int32(0)

        tele = dict(liters=jnp.zeros((self.pg.num_parts,), jnp.int32),
                    hist=jnp.zeros((self.max_supersteps,), jnp.int32),
                    sent=(nsent0 if int(step) == 0 else jnp.int32(0)))
        done = False
        while not done and int(step) < self.max_supersteps:
            state, inbox, step, done_flag, tele = chunk(state, inbox, step, tele)
            done = bool(done_flag)
            ck.save({"state": state, "inbox": inbox}, int(step))
        return jax.tree.map(np.asarray, state), self._telemetry(step, tele)

    def _sharded_fn(self, num_queries: Optional[int] = None, gb_example=None):
        spec = P(self.axis_name)
        rep = P()

        def body(gb_shard):
            state, steps, tele = self._run_batched(gb_shard,
                                                   num_queries=num_queries)
            return state, steps, tele

        gb_shapes = (graph_block(self.pg, as_spec=True) if gb_example is None
                     else {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                           for k, v in gb_example.items()})
        gb_spec = jax.tree.map(lambda _: spec, gb_shapes)
        # state leaves shard over parts; steps + hist + sent (+ per-query
        # qsteps, already psum'd) are replicated; liters shard over parts.
        state_spec = jax.tree.map(lambda _: spec,
                                  jax.eval_shape(lambda g: jax.vmap(self.program.init)(g),
                                                 gb_shapes))
        tele_spec = dict(liters=spec, hist=rep, sent=rep)
        if num_queries is not None:
            tele_spec["qsteps"] = rep
        out_specs = (state_spec, rep, tele_spec)
        f = compat.shard_map(body, mesh=self.mesh, in_specs=(gb_spec,),
                             out_specs=out_specs)
        return jax.jit(f)

    # ---------------- lowering entry point (dry-run / roofline) ----------------
    def lowerable_superstep(self):
        """A (fn, example_specs) pair: one shard_map'd BSP superstep suitable
        for ``jax.jit(fn).lower(*specs).compile()`` at production mesh scale.
        Used by launch/dryrun.py for the paper-side roofline."""
        assert self.backend == "shard_map"
        spec = P(self.axis_name)
        gb_specs = graph_block(self.pg, as_spec=True)
        gb_pspec = jax.tree.map(lambda _: spec, gb_specs)
        prog = self.program
        ident = msg.COMBINE_IDENTITY[prog.combine]

        state_shapes = jax.eval_shape(
            lambda g: jax.vmap(prog.init)(g), gb_specs)
        state_pspec = jax.tree.map(lambda _: spec, state_shapes)
        inbox_spec = jax.ShapeDtypeStruct((self.pg.num_parts, self.pg.v_max), np.float32)

        def one_step(gb, state, inbox, step):
            sstep = self.make_superstep(gb)
            st, ib, ch, li, ns = sstep(state, inbox, step)
            return st, ib, ch

        f = compat.shard_map(one_step, mesh=self.mesh,
                             in_specs=(gb_pspec, state_pspec, spec, P()),
                             out_specs=(state_pspec, spec, spec))
        step_spec = jax.ShapeDtypeStruct((), np.int32)
        return f, (gb_specs, state_shapes, inbox_spec, step_spec)
