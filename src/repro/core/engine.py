"""Gopher: the sub-graph centric BSP execution engine.

Faithful mapping of the paper's §4.2 runtime onto SPMD JAX:

  paper                               here
  -----                               ----
  worker per machine                  mesh device along the 'parts' axis
  thread pool over sub-graphs         vectorized (vmap) partitions + the
                                      local-fixpoint sweep (programs.py)
  async TCP message flush             all_to_all mailbox at superstep boundary
                                      (XLA overlaps it with the sweep tail)
  manager sync/resume/terminate       psum of per-partition 'changed' flags
                                      inside a lax.while_loop — the manager
                                      degenerates to an all-reduce
  VoteToHalt + no input messages      changed == False (see programs.py for
                                      why this is equivalent for idempotent ⊕)

Two backends share every line of superstep logic:
  'local'     — all P partitions as a (P, ...) batch on one device (CPU tests,
                virtual partitions)
  'shard_map' — partitions sharded over a mesh axis; mailbox routed with a
                real all_to_all; halt via psum (multi-chip / dry-run path)

Six wire disciplines share both backends (``exchange=``, see make_exchange):
  'dense'     every pair ships its full cap row (the parity oracle; also the
              baseline where the physical wire is a single-host transpose)
  'compact'   frontier-compacted protocol payload over the dense physical
              buffer (Gopher Wire)
  'tiered'    capacity-tiered PHYSICAL buffers routed per pair tier (Gopher
              Mesh): the geometry XLA moves tracks the frontier
  'phased'    frontier-PHASED tier schedules (Gopher Phases): one segmented
              BSP loop per frontier band, so a single run's geometry rides
              the contraction — wide early rounds, narrow converged tail
  'megastep'  Gopher Hot (local backend only): the whole superstep — mailbox
              delivery, inbox combine, masked local fixpoint, halt
              reduction — fused into ONE dispatch over flat state
              (kernels.megastep); with a PhasedTierPlan whose narrow bands
              fit VMEM, multiple supersteps run resident inside one launch
  'auto'      the default: 'megastep' on 'local' when the program is
              eligible (program.megastep_kind is not None — the sub-graph
              centric fixpoint schedule, or fixed-iteration PageRank),
              'dense' otherwise on 'local' and on a 1-device shard_map mesh
              (where the "wire" is the same single-host transpose),
              'tiered' on a multi-device 'shard_map' mesh
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core import messages as msg
from repro.resilience import faults as _faults
from repro.core.blocks import graph_block  # noqa: F401 (re-exported API)
from repro.core.tiers import DEMOTE_STREAK, PhasedTierPlan, TierPlan
from repro.gofs.formats import PartitionedGraph
from repro.kernels import megastep as mega
from repro.kernels import ops
from repro.obs import metrics as obs_metrics
from repro.obs import skew as obs_skew
from repro.obs import trace as obs_trace

# the vmapped partition axis gets a collective name so programs can take
# GLOBAL reductions (PageRank dangling mass / L1 halt) with a plain psum —
# the engine hands each program the axes it runs under (this one, plus the
# mesh axis on the shard_map backend)
_VPART_AXIS = "vparts"

# compiled BSP loops shared ACROSS engine instances (see _runner); FIFO-bounded
# so a churny fleet can't pin unbounded trace closures
_RUNNER_CACHE: dict = {}
_RUNNER_CACHE_CAP = 64


@dataclasses.dataclass(frozen=True)
class _PgScalars:
    """The only pg fields the compiled BSP loop reads — cached runners hold
    these instead of a full PartitionedGraph (see _runner)."""
    num_parts: int
    v_max: int
    mailbox_cap: int


@dataclasses.dataclass
class Telemetry:
    supersteps: int
    local_iters: np.ndarray        # (P,) cumulative sweep iterations (straggler signal)
    changed_hist: np.ndarray       # (supersteps,) #partitions changed per superstep
    messages_sent: int
    # query-batched runs only: per-query superstep at which the query last
    # changed (its individual convergence point — it stops sending after this)
    query_supersteps: Optional[np.ndarray] = None
    # wire accounting, per exchange discipline:
    #   'dense'   PHYSICAL: the constant P²·cap buffer geometry per round.
    #   'tiered'  PHYSICAL: the tier schedule's routed buffer geometry per
    #             round (core.tiers.TierSchedule.round_slots) — what the
    #             interconnect actually carries; static per tier plan, and
    #             it tracks the frontier through the traffic profile.
    #   'compact' MODELED protocol payload (Σ packed counts): what a
    #             count-prefixed transport would ship. The compact mode's
    #             PHYSICAL buffers keep the dense geometry plus a slot map
    #             (that gap is exactly what the tiered mode closes).
    # Histograms are ROUND-indexed (length supersteps + 1): round 0 is the
    # pre-loop inbox PRIME (the initial state's messages) and round s + 1 is
    # the exchange at the END of superstep s — so wire_hist.sum() equals
    # wire_slots with no unaccounted round (the prime used to be counted in
    # wire_slots only, leaving the per-round histograms one short).
    wire_hist: Optional[np.ndarray] = None     # (supersteps + 1,) int
    wire_slots: int = 0                        # total slots shipped (incl. prime)
    bytes_on_wire: int = 0                     # wire bytes under the same model
    # Gopher Mesh: per-pair packed-count totals (the traffic profile's
    # observation — feed to core.tiers.update_profile) and the tiered run's
    # overflow record
    exchange: str = ""                         # resolved discipline of the run
    pair_slots: Optional[np.ndarray] = None    # (P, P) Σ packed counts
    pair_rounds: int = 0                       # exchange rounds pair_slots
                                               # covers (≠ supersteps+1 after
                                               # a dense fallback retry)
    pair_overflow: Optional[np.ndarray] = None # (P, P) #supersteps overflowed
    spills: int = 0                            # Σ pair_overflow (tier misses)
    escalations: int = 0                       # pairs promoted after spills
    retried: bool = False                      # dense fallback retry ran
    # Gopher Phases (phased runs; count_hist also on compact/tiered —
    # 'dense' measures no packed counts, so its count_hist stays None):
    count_hist: Optional[np.ndarray] = None    # (supersteps + 1,) Σ packed
                                               # counts per round — the
                                               # frontier width (feed to
                                               # tiers.update_changed_profile)
    phase_hist: Optional[np.ndarray] = None    # (supersteps + 1,) phase index
                                               # of each round's exchange
                                               # (round 0 = prime, phase 0)
    phase_switch_steps: Optional[np.ndarray] = None  # supersteps at which the
                                               # run crossed into a new phase
    phase_wire: Optional[np.ndarray] = None    # (K,) routed slots per phase
                                               # (phase 0 includes the prime)
    phase_pair_slots: Optional[np.ndarray] = None    # (K, P, P) Σ packed
                                               # counts per phase
    dense_retry_steps: int = 0                 # supersteps whose exchange
                                               # fell back to the dense route
                                               # after an in-phase overflow
    # Gopher Balance: wall-clock seconds attributed per partition by the
    # host-stepped drivers (checkpointed/traced loops) — the TIME channel of
    # the skew report. Injected straggler stalls land on their targeted
    # partition; the remaining superstep time spreads evenly (one host
    # process can't see real per-partition compute splits). None on the
    # fused single-dispatch loops, which have no per-superstep host clock.
    part_seconds: Optional[np.ndarray] = None  # (P,) float64

    @staticmethod
    def model_bytes(slots: int, num_parts: int, rounds: int, cap: int,
                    num_queries: Optional[int], compact: bool) -> int:
        """The dense/compact comm-volume model: per round the dense exchange
        ships every pair row — P² · cap · Q values at 4 B — while the
        compact exchange ships, per pair, a count header (4 B) plus count
        packed slots at (4·Q value bytes + 4 slot-id bytes) each; payload ∝
        |frontier|. (Tiered runs use TierSchedule.round_bytes instead.)"""
        q = num_queries or 1
        if not compact:
            return rounds * num_parts * num_parts * cap * q * 4
        return slots * (4 * q + 4) + rounds * num_parts * num_parts * 4

    def skew(self) -> dict:
        """Gopher Scope: the run's partition-imbalance report (straggler
        score off local_iters, wire skew off pair_slots) — see
        repro.obs.skew.skew_report."""
        return obs_skew.skew_report(self)


class GopherEngine:
    """Runs a program over a PartitionedGraph to global quiescence."""

    def __init__(self, pg: PartitionedGraph, program, backend: str = "local",
                 mesh=None, axis_name: str = "parts",
                 max_supersteps: int = 4096, gb: Optional[dict] = None,
                 exchange: str = "auto", tier_plan: Optional[TierPlan] = None,
                 tracer: Optional["obs_trace.Tracer"] = None,
                 metrics: Optional["obs_metrics.MetricsRegistry"] = None,
                 validate: bool = False):
        assert backend in ("local", "shard_map")
        assert exchange in ("auto", "compact", "dense", "tiered", "phased",
                            "megastep")
        if backend == "shard_map":
            assert mesh is not None
            d = mesh.shape[axis_name]
            assert pg.num_parts % d == 0, "partitions must tile the mesh axis"
        self.pg = pg
        self.program = program
        self.backend = backend
        self.mesh = mesh
        self.axis_name = axis_name
        self.max_supersteps = max_supersteps
        # wire discipline. 'auto' resolves per backend + program:
        #   * 'local' + an ELIGIBLE program (program.megastep_kind not None,
        #     i.e. the sub-graph centric run-to-fixpoint schedule or
        #     fixed-iteration PageRank) -> 'megastep' (Gopher Hot): there is
        #     no physical wire to route, so the winning move is to stop
        #     dispatching the staged sweep/pack/route/halt stages at all and
        #     fuse the superstep into one launch — this beats even the
        #     dense single-host transpose at small frontiers (BENCH_comm's
        #     small-frontier gate holds it to that claim);
        #   * 'local' with an ineligible program — and a DEGENERATE 1-device
        #     shard_map mesh, where every partition shares one chip — the
        #     physical "wire" is a single-device transpose, so the dense
        #     path is the smallest remaining choice: any compaction plan is
        #     pure overhead there;
        #   * a multi-device 'shard_map' mesh -> 'tiered': the routed
        #     buffers track the frontier.
        # 'dense' stays the parity / benchmark oracle; 'compact' is Gopher
        # Wire's protocol-payload compaction over dense physical buffers;
        # 'phased' (Gopher Phases) is requested explicitly with a
        # PhasedTierPlan; 'megastep' may also be requested explicitly.
        self.exchange_requested = exchange
        if exchange == "auto":
            if (backend == "local"
                    and getattr(program, "megastep_kind", None) is not None):
                exchange = "megastep"
            else:
                local_wire = (backend == "local"
                              or int(mesh.shape[axis_name]) == 1)
                exchange = "dense" if local_wire else "tiered"
        self.exchange = exchange
        if self.exchange == "megastep":
            assert backend == "local", \
                "the megastep exchange is a local-backend route (flat state " \
                "spans every partition; shard_map meshes route tiered/phased)"
            assert getattr(program, "megastep_kind", None) is not None, \
                "program is not megastep-eligible (megastep_kind is None)"
        # plan/mode normalization, both directions: a PhasedTierPlan under
        # 'tiered' (e.g. a narrow_resume plan handed to exchange='auto' that
        # resolved tiered) upgrades the mode to 'phased' — a K=1 phased loop
        # is the tiered exchange plus the per-superstep dense retry — and a
        # plain TierPlan under 'phased' wraps as a single phase.
        if self.exchange == "tiered" and isinstance(tier_plan, PhasedTierPlan):
            self.exchange = "phased"
        if self.exchange == "tiered" and tier_plan is None:
            # structural default plan: every pair's width covers its maximum
            # possible slot count, so it can never overflow (see TierPlan)
            tier_plan = TierPlan.from_graph(pg)
        if self.exchange == "phased":
            if tier_plan is None:
                tier_plan = PhasedTierPlan.from_graph(pg)
            elif isinstance(tier_plan, TierPlan):
                tier_plan = PhasedTierPlan.from_tier_plan(tier_plan)
        # the megastep route keeps a provided plan too: a PhasedTierPlan's
        # band geometry gates the resident narrow-phase mode (None = pure
        # per-superstep fused BSP, still one dispatch per superstep)
        self.tier_plan = (tier_plan
                          if self.exchange in ("tiered", "phased", "megastep")
                          else None)
        self._gb = gb                # cached device-side graph block; pass a
                                     # shared one so many engines (a serving
                                     # fleet) reuse a single device copy
        self._mega_cm = None         # lazily composed megastep mailbox
                                     # arrays (see _gb_for_run)
        self._runner_memo = {}       # per-engine front of _RUNNER_CACHE
        # Gopher Scope: host-side observability. None defers to the process
        # defaults at run time (so launch/scope can arm a tracer AFTER
        # engines were built). A disabled tracer keeps the compiled fused
        # loop untouched — the traced stepped driver only replaces it when
        # the tracer is enabled.
        self._tracer = tracer
        self._metrics = metrics
        # Gopher Sentinel: validate=True runs the static passes (SPMD
        # collective verification + semiring laws + plan staticness, see
        # repro.analysis) on every compiled-loop cache MISS, before the
        # loop enters the cache — a cache hit means an identical
        # configuration already passed, so warm paths pay nothing.
        self.validate = validate

    @property
    def tracer(self) -> "obs_trace.Tracer":
        return (self._tracer if self._tracer is not None
                else obs_trace.get_tracer())

    @property
    def metrics(self) -> "obs_metrics.MetricsRegistry":
        return (self._metrics if self._metrics is not None
                else obs_metrics.default_registry())

    def _graph_block(self):
        """The device graph block, built once per engine — every query batch
        served by this engine shares it (and the jit cache entries keyed on
        its shapes)."""
        if self._gb is None:
            self._gb = graph_block(self.pg)
        return self._gb

    def _gb_for_run(self, gb):
        """The graph block a compiled run actually receives. On the megastep
        exchange this merges the COMPOSED MAILBOX (kernels.megastep
        .compose_mailbox) into the block as ``mcm_*`` entries, built once
        per engine OUTSIDE the compiled loop. The staged paths gather
        through inverse maps precomputed in blocks.py; composing the fused
        path's maps inside jit instead re-materializes them on every call —
        measured at ~⅓ of a warm small-frontier run, which is exactly the
        launch-overhead budget the megastep exists to reclaim. Python-int
        statics are NOT shipped — _run_megastep re-derives them from shapes.
        Callers that trace with a bare block (sentinel's trace_loop, the
        traced stepped driver) skip this and compose inline."""
        if self.exchange != "megastep":
            return gb
        if self._mega_cm is None:
            kind = self.program.megastep_kind
            cm = mega.compose_mailbox(
                self._graph_block(),
                adjacency="binned" if kind == "batched_semiring" else "full")
            self._mega_cm = {**self._graph_block(),
                             **{"mcm_" + k: v for k, v in cm.items()
                                if k not in mega.MAILBOX_STATICS}}
        if gb is self._gb:
            return self._mega_cm
        return {**self._mega_cm,
                **{k: v for k, v in gb.items() if not k.startswith("mcm_")}}

    # ---------------- superstep body (backend-shared) ----------------
    def make_superstep(self, gb, num_queries: Optional[int] = None,
                       phase: Optional[int] = None):
        """One BSP superstep over a partition batch gb (leading axis = local
        partition count). Returns (state, inbox, changed, liters(P,), nsent,
        wire, extras) — ``wire`` is the superstep's shipped-slot count under
        the engine's exchange mode and ``extras`` carries the per-pair wire
        telemetry the mode produces (see make_exchange). ``phase`` selects
        the tier table on a phased plan (one superstep body is traced per
        loop segment).

        With ``num_queries=Q`` the program is query-batched: state/inbox
        leaves carry a QUERY-TRAILING (v_max, Q) shape per partition (Q rides
        the contiguous lane dimension), `changed` is per-partition per-query
        (P, Q), and the mailbox carries cap*Q slots per partition pair —
        routing is identical on both backends.
        """
        prog = self.program
        Q = num_queries
        axes = ((_VPART_AXIS,) if self.backend == "local"
                else (_VPART_AXIS, self.axis_name))

        exchange = self.make_exchange(gb, num_queries=Q, phase=phase)

        def sstep(state, inbox, step):
            new_state, changed, liters = jax.vmap(
                lambda s, i, g: prog.superstep(s, i, g, step, axes=axes),
                in_axes=(0, 0, 0), axis_name=_VPART_AXIS)(state, inbox, gb)
            inbox, nsent, wire, extras = exchange(new_state)
            return new_state, inbox, changed, liters, nsent, wire, extras

        return sstep

    def make_exchange(self, gb, num_queries: Optional[int] = None,
                      phase: Optional[int] = None):
        """The mailbox half of a superstep: state -> (inbox, nsent, wire,
        extras). Split out so the BSP loop can PRIME the first inbox from the
        INITIAL state — without priming, superstep 0 computes with an empty
        inbox and treats every remote in-edge as contributing the ⊕-identity.
        For idempotent programs that only delays information one superstep,
        but for PageRank it silently dropped all remote mass from the first
        Jacobi iteration (an error that decays only as damping^k).

        Four wire disciplines (``self.exchange``; 'auto' resolved at
        construction to 'dense' on local / 1-device meshes, 'tiered' on
        multi-device shard_map):

        'dense'    every (src, dst) pair ships its full cap-slot row every
                   superstep — identity-filled when the pair is quiescent.
                   wire = P · cap per local source row, unconditionally
                   (PHYSICAL: that IS the routed buffer geometry).
        'compact'  frontier-compacted protocol (Gopher Wire): each pair row
                   is PACKED to a dense prefix of its active slots plus a
                   per-destination count vector; quiesced pairs ship
                   count = 0. The receiver rebuilds fixed slot positions
                   with a pure gather, so the combine — and every
                   downstream bit — is IDENTICAL to the dense path.
                   wire = Σ counts ∝ |frontier| — the MODELED count-prefixed
                   payload; the physical buffers keep the dense geometry
                   plus a slot map.
        'tiered'   Gopher Mesh: the PHYSICAL buffers track the frontier. A
                   static TierPlan (per-pair traffic profile, core.tiers)
                   routes hot pairs' full cap rows through one all_to_all
                   over per-device-pair row blocks, warm (cap/8) and cold
                   (width-1) pairs' packed prefixes through a ppermute
                   round-robin over only the nonzero device shifts, and
                   ships NOTHING for structurally-empty pairs. wire = the
                   routed geometry, static per plan. A pair whose active
                   slots exceed its tier width is truncated and flagged
                   (extras['over']); the run driver repairs that with a
                   dense fallback retry and escalates the pair for the next
                   version — results are bit-identical to 'dense'
                   unconditionally.
        'phased'   Gopher Phases: the tiered exchange at ONE phase's tier
                   table (``phase`` selects it from the PhasedTierPlan; the
                   segmented BSP loop traces one body per phase). Overflow
                   handling is PER-SUPERSTEP: the pack's overflow flags are
                   all-reduced BEFORE routing and the whole superstep's
                   exchange falls back to the dense route (lax.cond) when
                   any pair truncated — no messages are ever lost, so the
                   run needs no whole-run retry; the spilled phase (not the
                   whole plan) is escalated afterwards. Costs one extra
                   scalar all-reduce per superstep on shard_map.

        ``extras`` is the mode's per-pair telemetry: {} for dense,
        {'pairs': (v, P) packed counts} for compact, plus {'over': (v, P)
        overflow flags} for tiered, plus {'dstep': scalar 0/1 dense-retry
        flag} for phased. The BSP loop accumulates them into
        Telemetry.pair_slots / pair_overflow — the observations
        core.tiers.update_profile folds into the traffic profile.
        """
        pack, route = self.make_exchange_stages(gb, num_queries=num_queries,
                                                phase=phase)

        def exchange(state):
            payload, nsent, wire, extras = pack(state)
            inbox, rex = route(payload)
            if rex:
                wire = rex.get("wire", wire)
                extras = dict(extras, **{k: v for k, v in rex.items()
                                         if k != "wire"})
            return inbox, nsent, wire, extras

        return exchange

    def make_exchange_stages(self, gb, num_queries: Optional[int] = None,
                             phase: Optional[int] = None):
        """The exchange split at its NETWORK BOUNDARY into two closures —
        ``pack(state) -> (payload, nsent, wire, extras)`` (pure device-local
        message build / frontier compaction; payload is the pytree that
        would cross the wire) and ``route(payload) -> (inbox, route_extras)``
        (the collective transpose plus inbox combine). ``make_exchange``
        composes them, so the compiled fused loop's math is exactly the
        per-stage math; Gopher Scope's traced stepped driver dispatches the
        stages individually to clock pack vs. exchange wall-clock.

        ``route_extras`` is {} except on 'phased', where the per-superstep
        dense-retry decision lives on the route side: {'wire': the corrected
        shipped-slot count, 'dstep': the 0/1 retry flag}.
        """
        prog = self.program
        cap = self.pg.mailbox_cap
        v_max = self.pg.v_max
        combine = prog.combine
        num_parts = self.pg.num_parts
        Q = num_queries
        mode = self.exchange
        assert mode != "megastep", \
            "the megastep route has no staged exchange (see _run_megastep)"

        if mode in ("tiered", "phased"):
            plan = self.tier_plan
            assert plan is not None
            if mode == "phased":
                assert phase is not None, "phased exchange needs a phase index"
                plan = plan.phase_plans()[phase]
            assert plan.num_parts == num_parts and plan.cap == cap, \
                "tier plan was built for a different graph geometry"
            D = (1 if self.backend == "local"
                 else int(self.mesh.shape[self.axis_name]))
            sched = plan.schedule(D)
            limits_np = plan.limits()
            axis = self.axis_name if self.backend == "shard_map" else None

        def phys(x):
            if self.backend == "local":
                return msg.route_local(x)
            return msg.route_shard_map(x, self.axis_name)

        if Q is None:
            comb = functools.partial(msg.combine_inbox_gather,
                                     v_max=v_max, combine=combine)
        else:
            comb = functools.partial(msg.combine_inbox_gather_batched,
                                     v_max=v_max, cap=cap, combine=combine)

        def finish(iv):
            return jax.vmap(comb)(iv, gb["ib_lo"], gb["ib_hub_idx"],
                                  gb["ib_hub"])

        def send_messages(state):
            vals, send = jax.vmap(prog.messages)(state, gb)
            return vals, send, jnp.sum(send).astype(jnp.int32)

        if mode == "dense":
            # gather-form dense mailbox: slots PULL through the inverse
            # routing plan — no runtime scatter, only values travel
            build = functools.partial(
                msg.build_outbox_gather if Q is None
                else msg.build_outbox_gather_batched,
                num_parts=num_parts, cap=cap, combine=combine)

            def pack(state):
                vals, send, nsent = send_messages(state)
                slot_vals = jax.vmap(build)(vals, send, gb["ob_inv"])
                p_local = gb["vmask"].shape[0]
                wire = jnp.int32(p_local * num_parts * cap)
                return (slot_vals,), nsent, wire, {}

            def route(payload):
                (slot_vals,) = payload
                return finish(phys(slot_vals)), {}

        elif mode == "compact":
            build = functools.partial(
                msg.build_outbox_compact if Q is None
                else msg.build_outbox_compact_batched,
                num_parts=num_parts, cap=cap, combine=combine)
            unpack = functools.partial(
                msg.unpack_slots if Q is None
                else msg.unpack_slots_batched, combine=combine)

            def pack(state):
                vals, send, nsent = send_messages(state)
                pvals, pinv, counts = jax.vmap(build)(vals, send,
                                                      gb["ob_inv"])
                # count-prefixed exchange: the packed prefixes and their
                # slot-position maps travel; counts[d] is the header a real
                # transport would read each prefix length from (here the
                # PAD entries of pinv mark inactivity, so the header itself
                # isn't routed — it feeds the wire telemetry and the
                # piggybacked halt vote)
                wire = jnp.sum(counts).astype(jnp.int32)
                return (pvals, pinv), nsent, wire, {"pairs": counts}

            def route(payload):
                pvals, pinv = payload
                iv = jax.vmap(unpack)(phys(pvals), phys(pinv))
                return finish(iv), {}

        else:  # tiered / phased
            ident = msg.COMBINE_IDENTITY[combine]
            build = functools.partial(
                msg.build_outbox_gather if Q is None
                else msg.build_outbox_gather_batched,
                num_parts=num_parts, cap=cap, combine=combine)
            Qg = 1 if Q is None else Q

            def pack(state):
                vals, send, nsent = send_messages(state)
                slot_vals = jax.vmap(build)(vals, send, gb["ob_inv"])
                v_local = slot_vals.shape[0]
                sv4 = slot_vals.reshape(v_local, num_parts, cap, Qg)
                act = jax.vmap(functools.partial(
                    msg.active_slots, num_parts=num_parts,
                    cap=cap))(send, gb["ob_inv"])
                lim = jnp.asarray(limits_np)
                if axis is not None and D > 1:
                    lim = jax.lax.dynamic_slice(
                        lim, (jax.lax.axis_index(axis) * v_local, 0),
                        (v_local, num_parts))
                else:
                    lim = lim[:v_local]
                # fused pack (plan + tier truncation + spill detection) over
                # the flat row batch — rows are independent, no vmap needed
                R = v_local * num_parts
                sv_rows = (sv4.reshape(R, cap) if Q is None
                           else sv4.reshape(R, cap, Qg))
                pvals, sids, _, counts, over = ops.outbox_pack(
                    sv_rows, act.reshape(R, cap), lim.reshape(R), ident)
                extras = {"pairs": counts.reshape(v_local, num_parts),
                          "over": over.reshape(v_local, num_parts)}
                wire = jnp.int32(sched.device_round_slots())
                return (sv4, pvals, sids, over), nsent, wire, extras

            def route(payload):
                sv4, pvals, sids, over = payload
                v_local = sv4.shape[0]

                def tier_route(sv4):
                    return msg.route_tiered(
                        sv4, pvals.reshape(v_local, num_parts, cap, Qg),
                        sids.reshape(v_local, num_parts, cap), sched,
                        combine, axis_name=axis)

                if mode == "tiered":
                    iv4 = tier_route(sv4)
                    rex = {}
                else:  # phased: per-superstep dense retry on overflow
                    over_any = jnp.any(over > 0).astype(jnp.int32)
                    if axis is not None and D > 1:
                        over_any = jax.lax.psum(over_any, axis)
                    retry = over_any > 0

                    def dense_route(sv4):
                        flat = phys(sv4.reshape(v_local, num_parts,
                                                cap * Qg))
                        return flat.reshape(v_local, num_parts, cap, Qg)

                    iv4 = jax.lax.cond(retry, dense_route, tier_route, sv4)
                    rex = {"wire": jnp.where(
                               retry, jnp.int32(v_local * num_parts * cap),
                               jnp.int32(sched.device_round_slots())),
                           "dstep": retry.astype(jnp.int32)}
                iv = iv4.reshape(v_local, num_parts,
                                 cap if Q is None else cap * Qg)
                return finish(iv), rex

        return pack, route

    def _run_batched(self, gb, num_queries: Optional[int] = None):
        """The full BSP loop over a partition batch. Runs as-is on the local
        backend; runs per-shard (with collectives) under shard_map.

        Query-batched runs halt when NO query changed anywhere; a query whose
        own flags went quiet stops producing messages (its send mask is gated
        on per-query changed_v) while the rest of the batch keeps moving.
        """
        if self.exchange == "phased":
            return self._run_phased(gb, num_queries=num_queries)
        if self.exchange == "megastep":
            return self._run_megastep(gb, num_queries=num_queries)
        prog = self.program
        Q = num_queries
        mode = self.exchange
        sstep = self.make_superstep(gb, num_queries=Q)
        p_local = gb["vmask"].shape[0]
        state0 = jax.vmap(prog.init)(gb)
        # prime the mailbox with the INITIAL state's messages so superstep 0
        # computes against a consistent inbox (see make_exchange)
        inbox0, nsent0, wire0, ex0 = self.make_exchange(gb,
                                                        num_queries=Q)(state0)
        cnt0 = (jnp.sum(ex0["pairs"]).astype(jnp.int32)
                if "pairs" in ex0 else jnp.int32(0))
        if self.backend == "shard_map":
            s0 = jax.lax.psum(jnp.stack([nsent0, wire0, cnt0]),
                              self.axis_name)
            nsent0, wire0, cnt0 = s0[0], s0[1], s0[2]
        # histograms are ROUND-indexed (see Telemetry): slot 0 carries the
        # prime, the body writes superstep s's exchange at slot s + 1
        tele0 = dict(liters=jnp.zeros((p_local,), jnp.int32),
                     hist=jnp.zeros((self.max_supersteps,), jnp.int32),
                     whist=jnp.zeros((self.max_supersteps + 1,),
                                     jnp.int32).at[0].set(wire0),
                     sent=nsent0, wire=wire0)
        if mode in ("compact", "tiered"):
            # per-round Σ packed counts — the frontier-width histogram
            # the changed-profile EWMA (Gopher Phases) learns from
            tele0["chist"] = jnp.zeros((self.max_supersteps + 1,),
                                       jnp.int32).at[0].set(cnt0)
        # per-pair wire telemetry (compact/tiered): rows stay device-local,
        # the out_specs shard them back to the full (P, P) matrices
        for k, v in ex0.items():
            tele0[k] = v
        if Q is not None:
            tele0["qsteps"] = jnp.zeros((Q,), jnp.int32)

        def cond(c):
            _, _, step, done, _ = c
            return (~done) & (step < self.max_supersteps)

        def body(c):
            state, inbox, step, _, tele = c
            state, inbox, changed, liters, nsent, wire, ex = sstep(state,
                                                                   inbox, step)
            # the halt vote rides the same reduction as the wire counters:
            # ONE fused psum per superstep carries [pairs-changed?, nsent,
            # wire, counts(, per-query changed)] — the count vector the
            # compact exchange produces anyway — instead of a separate
            # all-reduce round per counter.
            cnt = (jnp.sum(ex["pairs"]).astype(jnp.int32)
                   if "pairs" in ex else jnp.int32(0))
            if Q is None:
                nchanged = jnp.sum(changed.astype(jnp.int32))
                stats = jnp.stack([nchanged, nsent, wire, cnt])
                if self.backend == "shard_map":
                    stats = jax.lax.psum(stats, self.axis_name)
                nchanged, nsent, wire, cnt = (stats[0], stats[1], stats[2],
                                              stats[3])
                any_changed = nchanged > 0
            else:
                changed_q = jnp.any(changed, axis=0).astype(jnp.int32)  # (Q,)
                nchanged = jnp.sum(jnp.any(changed, axis=-1).astype(jnp.int32))
                stats = jnp.concatenate(
                    [jnp.stack([nchanged, nsent, wire, cnt]), changed_q])
                if self.backend == "shard_map":
                    stats = jax.lax.psum(stats, self.axis_name)
                nchanged, nsent, wire, cnt = (stats[0], stats[1], stats[2],
                                              stats[3])
                changed_q = stats[4:]
                any_changed = jnp.any(changed_q > 0)
            new_tele = dict(liters=tele["liters"] + liters,
                            hist=tele["hist"].at[step].set(nchanged),
                            whist=tele["whist"].at[step + 1].set(wire),
                            sent=tele["sent"] + nsent,
                            wire=tele["wire"] + wire)
            if "chist" in tele:
                new_tele["chist"] = tele["chist"].at[step + 1].set(cnt)
            for k, v in ex.items():
                new_tele[k] = tele[k] + v
            if Q is not None:
                new_tele["qsteps"] = jnp.where(changed_q > 0, step + 1,
                                               tele["qsteps"])
            return state, inbox, step + 1, ~any_changed, new_tele

        state, _, steps, _, tele = jax.lax.while_loop(
            cond, body, (state0, inbox0, jnp.int32(0), jnp.bool_(False), tele0))
        return state, steps, tele

    def _run_megastep(self, gb, num_queries: Optional[int] = None):
        """Gopher Hot: the BSP loop with the whole superstep — mailbox
        delivery, inbox ⊕-combine, masked local fixpoint, halt reduction —
        fused into ONE dispatch over flat (P·v_max,) state
        (kernels.megastep). The staged loop's three routing hops are
        composed once per run into direct gather maps; delivery happens at
        the TOP of each superstep from the previous round's send set, which
        is the same message multiset one loop-carry shorter (and the prime
        falls out of init's changed_v seed with no special case). Results
        are bit-identical to the staged dense path for idempotent ⊕ and
        allclose for PageRank — the same parity classes the exchange stack
        already guarantees.

        Telemetry mirrors the compact layout: ``pairs``/``chist`` are the
        LOGICAL frontier observation (identical counts to the compact
        path's active_slots, so the tier-profile EWMAs keep learning), and
        ``wire``/``whist`` are zero — nothing ships through buffers.

        With a PhasedTierPlan whose narrow band suffix fits
        MEGASTEP_VMEM_BUDGET (scalar semiring programs), the tail runs in
        RESIDENT mode: chaotic-relaxation rounds with the mailbox held
        on chip — one sweep per delivery, every improvement rebroadcast
        next round — which converges to the same bitwise fixpoint and, on
        TPU, executes as a single multi-superstep Pallas launch
        (per-round hist/chist entries are coarse there: the launch reports
        totals, not rounds)."""
        prog = self.program
        kind = prog.megastep_kind
        Q = num_queries
        p_local = gb["vmask"].shape[0]
        v_max = self.pg.v_max
        max_s = self.max_supersteps
        if "mcm_vmask" in gb:
            # pre-composed by _gb_for_run; statics re-derived from shapes
            cm = {k[4:]: v for k, v in gb.items() if k.startswith("mcm_")}
            cm.update(num_parts=p_local, v_max=v_max,
                      cap=gb["ob_inv"].shape[1] // p_local,
                      n=p_local * v_max)
            # the flat (n,)-shaped mailbox entries must not reach the
            # per-partition vmaps below
            gb = {k: v for k, v in gb.items() if not k.startswith("mcm_")}
        else:
            cm = mega.compose_mailbox(
                gb,
                adjacency="binned" if kind == "batched_semiring" else "full")
        state0 = jax.vmap(prog.init)(gb)

        def base_tele(pairs0, nsent0):
            tele = dict(
                liters=jnp.zeros((p_local,), jnp.int32),
                hist=jnp.zeros((max_s,), jnp.int32),
                whist=jnp.zeros((max_s + 1,), jnp.int32),
                chist=jnp.zeros((max_s + 1,), jnp.int32)
                    .at[0].set(jnp.sum(pairs0).astype(jnp.int32)),
                sent=nsent0, wire=jnp.int32(0), pairs=pairs0)
            if Q is not None:
                tele["qsteps"] = jnp.zeros((Q,), jnp.int32)
            return tele

        def fold(tele, step, pairs, nsent, li, nchanged):
            new = dict(liters=tele["liters"] + li,
                       hist=tele["hist"].at[step].set(nchanged),
                       whist=tele["whist"],
                       chist=tele["chist"].at[step + 1]
                           .set(jnp.sum(pairs).astype(jnp.int32)),
                       sent=tele["sent"] + nsent,
                       wire=tele["wire"],
                       pairs=tele["pairs"] + pairs)
            if Q is not None:
                new["qsteps"] = tele["qsteps"]
            return new

        if kind == "pagerank":
            r = state0["r"].reshape(-1)
            deg = gb["out_degree"].astype(jnp.float32).reshape(-1)
            telep = (jax.vmap(prog.teleport_fn)(gb).reshape(-1)
                     if prog.teleport_fn is not None
                     else 1.0 / prog.n_global)
            pairs0, nsent0 = mega.round_stats(None, cm)
            tele0 = base_tele(pairs0, nsent0)

            def cond(c):
                _, _, step, done, _ = c
                return (~done) & (step < max_s)

            def body(c):
                r, _, step, _, tele = c
                r2, delta, chg = mega.megastep_pagerank(
                    r, cm, deg, telep, prog.n_global, prog.damping,
                    prog.num_iters, step)
                # PageRank sends unconditionally, so every round's logical
                # observation is the full slot occupancy — including the
                # final round, matching the staged loop's last exchange
                pairs, nsent = mega.round_stats(None, cm)
                nch = chg.astype(jnp.int32) * jnp.int32(p_local)
                tele = fold(tele, step, pairs, nsent,
                            jnp.ones((p_local,), jnp.int32), nch)
                return r2, delta, step + 1, ~chg, tele

            r, delta, steps, _, tele = jax.lax.while_loop(
                cond, body,
                (r, jnp.float32(jnp.inf), jnp.int32(0), jnp.bool_(False),
                 tele0))
            state = {"r": r.reshape(p_local, v_max),
                     "delta": jnp.full((p_local,), delta)}
            return state, steps, tele

        semiring = prog.semiring
        unroll = prog.fixpoint_unroll

        if kind == "batched_semiring":
            x = state0["x"].reshape(-1, Q)
            ch = state0["changed_v"].reshape(-1, Q)
            fr = state0["frontier"].reshape(-1, Q)
            pairs0, nsent0 = mega.round_stats(ch, cm)
            tele0 = base_tele(pairs0, nsent0)

            def cond(c):
                _, _, _, step, done, _ = c
                return (~done) & (step < max_s)

            def body(c):
                x, ch, fr, step, _, tele = c
                x2, ch2, fl, li = mega.megastep_semiring_batched(
                    x, ch, fr, cm, semiring, unroll=unroll)
                pairs, nsent = mega.round_stats(ch2, cm)
                chpq = jnp.any(ch2.reshape(p_local, v_max, Q), axis=1)
                changed_q = jnp.any(chpq, axis=0)
                nch = jnp.sum(jnp.any(chpq, axis=-1).astype(jnp.int32))
                tele = fold(tele, step, pairs, nsent, li, nch)
                tele["qsteps"] = jnp.where(changed_q, step + 1,
                                           tele["qsteps"])
                return x2, ch2, fl, step + 1, ~jnp.any(changed_q), tele

            x, ch, fr, steps, _, tele = jax.lax.while_loop(
                cond, body,
                (x, ch, fr, jnp.int32(0), jnp.bool_(False), tele0))
            state = {"x": x.reshape(p_local, v_max, Q),
                     "changed_v": ch.reshape(p_local, v_max, Q),
                     "frontier": fr.reshape(p_local, v_max, Q)}
            return state, steps, tele

        # scalar semiring
        x = state0["x"].reshape(-1)
        ch = state0["changed_v"].reshape(-1)
        fr = state0["frontier"].reshape(-1)
        pairs0, nsent0 = mega.round_stats(ch, cm)
        tele0 = base_tele(pairs0, nsent0)

        def sem_fold(tele, step, ch2, li):
            pairs, nsent = mega.round_stats(ch2, cm)
            nch = jnp.sum(jnp.any(ch2.reshape(p_local, v_max),
                                  axis=1).astype(jnp.int32))
            return fold(tele, step, pairs, nsent, li, nch), nch

        def cond(c):
            _, _, _, step, done, _ = c
            return (~done) & (step < max_s)

        def bsp_body(c):
            x, ch, fr, step, _, tele = c
            x2, ch2, fl, li = mega.megastep_semiring(x, ch, fr, cm, semiring,
                                                     unroll=unroll)
            tele, nch = sem_fold(tele, step, ch2, li)
            return x2, ch2, fl, step + 1, nch == 0, tele

        # resident narrow-phase gate: the earliest superstep from which
        # every remaining phase band's predicted round geometry fits the
        # VMEM budget (None without a PhasedTierPlan, or when no suffix
        # fits — pure per-superstep fused BSP then)
        enter = None
        if isinstance(self.tier_plan, PhasedTierPlan):
            plans = self.tier_plan.phase_plans()
            rb = [p.schedule(1).round_bytes(Q) for p in plans]
            enter = mega.resident_enter_round(rb, self.tier_plan.boundaries)

        carry = (x, ch, fr, jnp.int32(0), jnp.bool_(False), tele0)
        if enter is None or enter >= max_s:
            carry = jax.lax.while_loop(cond, bsp_body, carry)
        else:
            if enter > 0:
                def pre_cond(c, _enter=jnp.int32(enter)):
                    _, _, _, step, done, _ = c
                    return (~done) & (step < _enter)

                carry = jax.lax.while_loop(pre_cond, bsp_body, carry)
            if mega._default_backend() == "pallas":
                # one multi-superstep launch, mailbox on chip; telemetry is
                # coarse for these rounds (totals, no per-round histograms)
                x, ch, fr, step, done, tele = carry
                x2, ch2, fr2, it, li = mega.resident_megastep_pallas(
                    x, ch, fr, cm, semiring, max_steps=max_s - enter,
                    interpret=jax.default_backend() != "tpu")
                pairs, nsent = mega.round_stats(ch2, cm)
                tele = dict(tele, liters=tele["liters"] + li,
                            sent=tele["sent"] + nsent,
                            pairs=tele["pairs"] + pairs)
                carry = (x2, ch2, fr2, step + it,
                         done | ~jnp.any(ch2), tele)
            else:
                def res_body(c):
                    x, ch, fr, step, _, tele = c
                    x2, ch2, fr2, ap = mega.resident_step_semiring(
                        x, ch, fr, cm, semiring)
                    tele, nch = sem_fold(tele, step, ch2,
                                         ap.astype(jnp.int32))
                    return x2, ch2, fr2, step + 1, nch == 0, tele

                carry = jax.lax.while_loop(cond, res_body, carry)

        x, ch, fr, steps, _, tele = carry
        state = {"x": x.reshape(p_local, v_max),
                 "changed_v": ch.reshape(p_local, v_max),
                 "frontier": fr.reshape(p_local, v_max)}
        return state, steps, tele

    def _run_phased(self, gb, num_queries: Optional[int] = None):
        """Gopher Phases: the BSP loop as K SEGMENTED while-loops, one per
        phase of the PhasedTierPlan — each segment's exchange tables are
        trace-time constants at that phase's geometry, and the (state,
        inbox, halt-vote) carry flows straight across segment boundaries,
        so the run switches geometry WITHOUT retracing or re-priming.

        A segment ends when any of three things happens:
          * the predicted switch superstep (``plan.boundaries[k]``) arrives;
          * the DEMOTION trigger fires — the observed per-pair packed
            counts fit under the NEXT phase's caps for ``DEMOTE_STREAK``
            consecutive supersteps (the frontier contracted ahead of
            prediction: jump to the narrower geometry now);
          * the global halt vote lands (a phase that quiesces before its
            boundary early-exits, and every later segment's loop runs ZERO
            iterations — the compiled segments are still traced, but cost
            nothing at run time).

        Per-superstep overflow falls back to the dense route inside the
        segment (see make_exchange 'phased'), so results are exact
        unconditionally and only the spilling phase is escalated afterwards.
        """
        prog = self.program
        Q = num_queries
        plan: PhasedTierPlan = self.tier_plan
        phases = plan.phase_plans()
        K = plan.num_phases
        bounds = plan.boundaries
        num_parts = self.pg.num_parts
        p_local = gb["vmask"].shape[0]
        ssteps = [self.make_superstep(gb, num_queries=Q, phase=k)
                  for k in range(K)]
        state0 = jax.vmap(prog.init)(gb)
        inbox0, nsent0, wire0, ex0 = self.make_exchange(
            gb, num_queries=Q, phase=0)(state0)
        cnt0 = jnp.sum(ex0["pairs"]).astype(jnp.int32)
        if self.backend == "shard_map":
            s0 = jax.lax.psum(jnp.stack([nsent0, wire0, cnt0]),
                              self.axis_name)
            nsent0, wire0, cnt0 = s0[0], s0[1], s0[2]
        # round-indexed histograms: the prime lands at slot 0 under phase 0
        tele0 = dict(
            liters=jnp.zeros((p_local,), jnp.int32),
            hist=jnp.zeros((self.max_supersteps,), jnp.int32),
            whist=jnp.zeros((self.max_supersteps + 1,),
                            jnp.int32).at[0].set(wire0),
            chist=jnp.zeros((self.max_supersteps + 1,),
                            jnp.int32).at[0].set(cnt0),
            phist=jnp.zeros((self.max_supersteps + 1,), jnp.int32),
            sent=nsent0, wire=wire0,
            # per-pair phase buckets keep the local-parts axis LEADING so
            # the shard_map out_specs reassemble them like every other
            # per-pair matrix: (v_local, K, P) -> (P, K, P)
            pairs=jnp.zeros((p_local, K, num_parts), jnp.int32
                            ).at[:, 0].add(ex0["pairs"]),
            over=jnp.zeros((p_local, K, num_parts), jnp.int32
                           ).at[:, 0].add(ex0["over"]),
            dsteps=ex0["dstep"],
            seg_end=jnp.zeros((K,), jnp.int32))
        if Q is not None:
            tele0["qsteps"] = jnp.zeros((Q,), jnp.int32)

        carry = (state0, inbox0, jnp.int32(0), jnp.bool_(False),
                 jnp.int32(0), tele0)
        for k in range(K):
            nlim_np = phases[k + 1].limits() if k < K - 1 else None
            sstep = ssteps[k]

            def cond(c, _k=k):
                _, _, step, done, streak, _ = c
                go = (~done) & (step < self.max_supersteps)
                if _k < K - 1:
                    # boundaries are in ROUND units (the changed-profile's
                    # index space): superstep s ships round s + 1, so the
                    # segment keeps going while that round is in-band
                    go &= (step + 1 < bounds[_k]) & (streak < DEMOTE_STREAK)
                return go

            def body(c, _k=k, _nlim=nlim_np, _sstep=sstep):
                state, inbox, step, _, streak, tele = c
                state, inbox, changed, liters, nsent, wire, ex = _sstep(
                    state, inbox, step)
                cnt = jnp.sum(ex["pairs"]).astype(jnp.int32)
                if _nlim is None:
                    viol = jnp.int32(0)
                else:
                    nl = jnp.asarray(_nlim)
                    v_local = ex["pairs"].shape[0]
                    if self.backend == "shard_map" and p_local < num_parts:
                        nl = jax.lax.dynamic_slice(
                            nl, (jax.lax.axis_index(self.axis_name)
                                 * v_local, 0), (v_local, num_parts))
                    else:
                        nl = nl[:v_local]
                    viol = jnp.sum((ex["pairs"] > nl).astype(jnp.int32))
                if Q is None:
                    nchanged = jnp.sum(changed.astype(jnp.int32))
                    stats = jnp.stack([nchanged, nsent, wire, cnt, viol])
                    if self.backend == "shard_map":
                        stats = jax.lax.psum(stats, self.axis_name)
                    nchanged, nsent, wire, cnt, viol = (
                        stats[0], stats[1], stats[2], stats[3], stats[4])
                    any_changed = nchanged > 0
                else:
                    changed_q = jnp.any(changed, axis=0).astype(jnp.int32)
                    nchanged = jnp.sum(jnp.any(changed,
                                               axis=-1).astype(jnp.int32))
                    stats = jnp.concatenate(
                        [jnp.stack([nchanged, nsent, wire, cnt, viol]),
                         changed_q])
                    if self.backend == "shard_map":
                        stats = jax.lax.psum(stats, self.axis_name)
                    nchanged, nsent, wire, cnt, viol = (
                        stats[0], stats[1], stats[2], stats[3], stats[4])
                    changed_q = stats[5:]
                    any_changed = jnp.any(changed_q > 0)
                # demotion streak: a dense-retried superstep's counts are
                # real demand, so they participate like any other round
                streak = jnp.where(viol == 0, streak + 1, jnp.int32(0))
                new_tele = dict(
                    liters=tele["liters"] + liters,
                    hist=tele["hist"].at[step].set(nchanged),
                    whist=tele["whist"].at[step + 1].set(wire),
                    chist=tele["chist"].at[step + 1].set(cnt),
                    phist=tele["phist"].at[step + 1].set(_k),
                    sent=tele["sent"] + nsent,
                    wire=tele["wire"] + wire,
                    pairs=tele["pairs"].at[:, _k].add(ex["pairs"]),
                    over=tele["over"].at[:, _k].add(ex["over"]),
                    dsteps=tele["dsteps"] + ex["dstep"],
                    seg_end=tele["seg_end"])
                if Q is not None:
                    new_tele["qsteps"] = jnp.where(changed_q > 0, step + 1,
                                                   tele["qsteps"])
                return state, inbox, step + 1, ~any_changed, streak, new_tele

            state, inbox, step, done, streak, tele = jax.lax.while_loop(
                cond, body, carry)
            tele = dict(tele, seg_end=tele["seg_end"].at[k].set(step))
            carry = (state, inbox, step, done, jnp.int32(0), tele)

        state, _, steps, _, _, tele = carry
        return state, steps, tele

    # ---------------- drivers ----------------
    def run(self, checkpointer=None, checkpoint_every: int = 0,
            resume: bool = False, extra: Optional[dict] = None,
            superstep_budget: Optional[int] = None):
        """Run to quiescence. With a `training.checkpoint.Checkpointer` and
        checkpoint_every=N, the BSP loop snapshots (state, inbox, superstep)
        every N supersteps and can restart from the last committed snapshot
        after a failure (BSP makes the cut trivially consistent — paper §4.2's
        synchronization points ARE the recovery lines).

        ``extra`` carries per-run dynamic (P, ...) graph-block entries — e.g.
        ``x0`` / ``frontier0`` for an incremental resume (SemiringProgram
        with resume=True) — without invalidating the shared cached block.

        ``superstep_budget`` (checkpointed runs only) caps THIS call at N
        supersteps and snapshots at the cut, so a supervisor (Gopher
        Balance's run_with_rebalance) can interleave decisions between
        segments of one logical run and resume exactly where it stopped.
        """
        if checkpointer is not None and checkpoint_every > 0:
            assert not self.tracer.enabled, \
                "traced runs don't compose with checkpointing yet"
            return self._run_checkpointed(checkpointer, checkpoint_every,
                                          resume, extra=extra,
                                          superstep_budget=superstep_budget)
        assert superstep_budget is None, \
            "superstep_budget requires a checkpointed run"
        gb = (self._graph_block() if self.tracer.enabled
              else self._gb_for_run(self._graph_block()))
        if extra:
            gb = dict(gb)
            for k, v in extra.items():
                gb[k] = jnp.asarray(v)
        if self.tracer.enabled:
            state, steps, tele = self._run_traced(gb, num_queries=None)
        else:
            state, steps, tele = self._runner(gb_example=gb)(gb)
        state, t = self._finish(state, steps, tele, gb, num_queries=None)
        self._record_run_metrics(t)
        return state, t

    def run_queries(self, extra: Optional[dict] = None):
        """Run a query-batched program (``program.num_queries`` = Q) to global
        quiescence of ALL queries in ONE BSP run.

        ``extra`` carries the per-request dynamic inputs (query init values,
        PPR seed vectors, ...) as additional (P, ...) graph-block entries, so
        the compiled loop is reused across request batches of the same shape
        — only the query arrays are re-transferred.

        Returns (state, Telemetry) where state leaves are (P, v_max, Q)
        (query-trailing) and ``telemetry.query_supersteps[q]`` is the
        superstep at which query q last changed.
        """
        Q = getattr(self.program, "num_queries", None)
        assert Q is not None, "run_queries requires a query-batched program"
        gb = dict(self._graph_block() if self.tracer.enabled
                  else self._gb_for_run(self._graph_block()))
        for k, v in (extra or {}).items():
            gb[k] = jnp.asarray(v)
        if self.tracer.enabled:
            state, steps, tele = self._run_traced(gb, num_queries=Q)
        else:
            state, steps, tele = self._runner(num_queries=Q,
                                              gb_example=gb)(gb)
        state, t = self._finish(state, steps, tele, gb, num_queries=Q)
        self._record_run_metrics(t)
        return state, t

    def _finish(self, state, steps, tele, gb, num_queries):
        """Close out a run: on the tiered exchange, check the overflow
        record — a pair whose active slots exceeded its tier width had
        messages TRUNCATED, so the results cannot be trusted. The repair is
        a DENSE FALLBACK RETRY (bit-identical by construction) plus a tier
        escalation of the overflowed pairs, so the engine's next run — and,
        through the profile, the next graph version's plan — has the width
        this pair just demonstrated it needs.

        Phased runs never need the whole-run retry — an overflowing
        superstep already routed dense inside the loop — so the close-out
        only ESCALATES the phases that spilled (each phase's overflow
        record promotes that phase's pairs; the other phases keep their
        geometry)."""
        if self.exchange == "phased":
            t = self._telemetry(steps, tele, num_queries=num_queries)
            if t.spills:
                over_k = np.transpose(np.asarray(tele["over"]), (1, 0, 2))
                old = self.tier_plan
                plan = old
                for k in range(plan.num_phases):
                    if over_k[k].any():
                        plan = plan.escalate_phase(k, over_k[k] > 0)
                self.tier_plan = plan
                t.escalations = plan.escalations_from(old)
            return jax.tree.map(np.asarray, state), t
        if self.exchange != "tiered" or "over" not in tele:
            return (jax.tree.map(np.asarray, state),
                    self._telemetry(steps, tele, num_queries=num_queries))
        over = np.asarray(tele["over"])
        spills = int(over.sum())
        if spills == 0:
            return (jax.tree.map(np.asarray, state),
                    self._telemetry(steps, tele, num_queries=num_queries))
        old = self.tier_plan
        self.tier_plan = old.escalate(over > 0)
        tiered_wire = int(tele["wire"])
        tiered_rounds = int(steps) + 1
        with self.tracer.span("dense-retry", spills=spills):
            state2, steps2, tele2 = self._runner(num_queries=num_queries,
                                                 gb_example=gb,
                                                 exchange="dense")(gb)
        t = self._telemetry(steps2, tele2, num_queries=num_queries,
                            exchange="dense")
        t.exchange = "tiered"
        t.retried = True
        t.spills = spills
        t.escalations = self.tier_plan.escalations_from(old)
        t.pair_overflow = over
        # the profile observation comes from the ABORTED tiered attempt —
        # pair_rounds records ITS round count so consumers normalize by the
        # rounds the counts actually cover, not the dense retry's
        t.pair_slots = np.asarray(tele["pairs"])
        t.pair_rounds = tiered_rounds
        # the failed tiered attempt's geometry still crossed the wire
        t.wire_slots += tiered_wire
        D = (1 if self.backend == "local"
             else int(self.mesh.shape[self.axis_name]))
        t.bytes_on_wire += (old.schedule(D).round_bytes(num_queries)
                            * tiered_rounds)
        return jax.tree.map(np.asarray, state2), t

    def _record_run_metrics(self, t: Telemetry) -> None:
        """Gopher Scope: fold a finished run's telemetry into the metrics
        registry. Host-side and O(P²) on data the run already pulled to the
        host — it runs on every run, traced or not (there is nothing to
        disable: no compiled code is touched)."""
        m = self.metrics
        lab = {"exchange": t.exchange or self.exchange,
               "backend": self.backend}
        m.counter("engine_runs_total", lab).inc()
        m.counter("engine_supersteps_total", lab).inc(t.supersteps)
        m.counter("engine_messages_sent_total", lab).inc(t.messages_sent)
        m.counter("engine_wire_slots_total", lab).inc(t.wire_slots)
        m.counter("engine_wire_bytes_total", lab).inc(t.bytes_on_wire)
        m.counter("engine_spills_total", lab).inc(t.spills)
        m.counter("engine_escalations_total", lab).inc(t.escalations)
        if t.retried:
            m.counter("engine_dense_retries_total", lab).inc()
        m.counter("engine_dense_retry_steps_total",
                  lab).inc(t.dense_retry_steps)
        m.histogram("engine_run_supersteps", lab).observe(t.supersteps)
        m.gauge("engine_partition_imbalance", lab).set(
            obs_skew.imbalance_score(t.local_iters))

    # ---------------- Gopher Scope: traced stepped driver ----------------
    def _traced_stage_fns(self, num_queries: Optional[int],
                          phase: Optional[int]):
        """Jitted per-stage functions for ONE phase (or the run's single
        exchange): init / sweep / pack / route, each taking the graph block
        as an argument so the jit cache keys on shapes. On shard_map every
        stage is its own shard_map'd program — replicated scalars (nsent,
        wire, dstep) are psum'd INSIDE the stage, per-partition arrays come
        back as global (P, ...) arrays — so the host driver sees exactly the
        values the fused loop's stats psum would have produced.

        Cached per (num_queries, phase, exchange, tier_plan): repeated
        traced runs re-enter the same jit entries, and a tier escalation
        (which changes self.tier_plan) rebuilds the closures."""
        cache = self.__dict__.setdefault("_traced_cache", {})
        key = (num_queries, phase, self.exchange, self.tier_plan)
        fns = cache.get(key)
        if fns is not None:
            return fns
        prog = self.program
        Q = num_queries
        axes = ((_VPART_AXIS,) if self.backend == "local"
                else (_VPART_AXIS, self.axis_name))

        def init_fn(gb):
            return jax.vmap(prog.init)(gb)

        def sweep_fn(gb, state, inbox, step):
            return jax.vmap(
                lambda s, i, g: prog.superstep(s, i, g, step, axes=axes),
                in_axes=(0, 0, 0), axis_name=_VPART_AXIS)(state, inbox, gb)

        def pack_fn(gb, state):
            pack, _ = self.make_exchange_stages(gb, num_queries=Q,
                                                phase=phase)
            payload, nsent, wire, extras = pack(state)
            if self.backend == "shard_map":
                s = jax.lax.psum(jnp.stack([nsent, wire]), self.axis_name)
                nsent, wire = s[0], s[1]
            return payload, nsent, wire, extras

        def route_fn(gb, payload):
            _, route = self.make_exchange_stages(gb, num_queries=Q,
                                                 phase=phase)
            inbox, rex = route(payload)
            if self.backend == "shard_map" and "wire" in rex:
                rex = dict(rex,
                           wire=jax.lax.psum(rex["wire"], self.axis_name))
            return inbox, rex

        if self.backend == "local":
            fns = dict(init=jax.jit(init_fn), sweep=jax.jit(sweep_fn),
                       pack=jax.jit(pack_fn), route=jax.jit(route_fn))
        else:
            # pytree-prefix specs: parts-sharded unless provably replicated
            spec, rep = P(self.axis_name), P()
            fns = dict(
                init=jax.jit(compat.shard_map(
                    init_fn, mesh=self.mesh, in_specs=(spec,),
                    out_specs=spec)),
                sweep=jax.jit(compat.shard_map(
                    sweep_fn, mesh=self.mesh,
                    in_specs=(spec, spec, spec, rep), out_specs=spec)),
                pack=jax.jit(compat.shard_map(
                    pack_fn, mesh=self.mesh, in_specs=(spec, spec),
                    out_specs=(spec, rep, rep, spec))),
                route=jax.jit(compat.shard_map(
                    route_fn, mesh=self.mesh, in_specs=(spec, spec),
                    out_specs=(spec, rep))))
        cache[key] = fns
        return fns

    def _run_traced(self, gb, num_queries: Optional[int] = None):
        """The host-stepped BSP driver behind an ENABLED tracer: the fused
        compiled while_loop unrolled into per-superstep jitted stage
        dispatches, so the tracer can clock every
        run → phase → superstep → {plan, pack, exchange, sweep, halt-vote}
        span. Semantics are identical to the fused loop — same stage math
        (the stages ARE make_exchange's halves), same halt rule, same
        telemetry layout — the halt vote just becomes a host read of the
        global changed flags, which is the per-superstep sync a trace needs
        anyway. The disabled path never comes here (see run())."""
        tr = self.tracer
        with tr.profile_ctx():
            with tr.span("run", exchange=self.exchange,
                         backend=self.backend,
                         queries=num_queries or 0) as rs:
                state, steps, tele = self._traced_loop(gb, num_queries)
                rs.set(supersteps=steps, wire_slots=int(tele["wire"]))
        return state, steps, tele

    def _traced_loop(self, gb, num_queries: Optional[int]):
        tr = self.tracer
        Q = num_queries
        mode = self.exchange
        if mode == "megastep":
            return self._traced_loop_megastep(gb, Q)
        phased = mode == "phased"
        num_parts = self.pg.num_parts
        max_s = self.max_supersteps
        if phased:
            plan: PhasedTierPlan = self.tier_plan
            K = plan.num_phases
            bounds = plan.boundaries
            nlims = [np.asarray(p.limits())
                     for p in plan.phase_plans()[1:]] + [None]
        else:
            K, bounds, nlims = 1, (None,), [None]

        stages = []
        for k in range(K):
            # the plan span charges stage construction + first-dispatch
            # compile to the phase it belongs to (Gopher Hot's plan-pass
            # attribution)
            with tr.span("plan", phase=k, exchange=mode,
                         backend=self.backend):
                stages.append(self._traced_stage_fns(
                    Q, k if phased else None))
        tr.count("stage_builds", K)

        with tr.span("init"):
            state = tr.sync(stages[0]["init"](gb))

        # host-side telemetry accumulators in the exact layout the compiled
        # loop produces, so _finish/_telemetry are shared verbatim
        liters = np.zeros(num_parts, np.int64)
        hist = np.zeros(max_s, np.int64)
        whist = np.zeros(max_s + 1, np.int64)
        chist = np.zeros(max_s + 1, np.int64)
        phist = np.zeros(max_s + 1, np.int64)
        pairs_acc = (np.zeros((num_parts, K, num_parts), np.int64) if phased
                     else np.zeros((num_parts, num_parts), np.int64))
        over_acc = np.zeros_like(pairs_acc)
        seg_end = np.zeros(K, np.int64)
        qsteps = np.zeros(Q, np.int64) if Q is not None else None
        sent = wire_total = dsteps = 0
        psec = np.zeros(num_parts, np.float64)
        part_verts = tuple(int(x) for x in
                           np.asarray(self.pg.vmask, bool).sum(1))
        nd = (1 if self.backend == "local"
              else int(self.mesh.shape[self.axis_name]))

        def fold_pairs(ex, rex, k, rnd):
            """One round's per-pair telemetry into the host accumulators;
            returns (wire, Σcounts) as host ints."""
            nonlocal dsteps, pairs_acc, over_acc
            wire_i = int(rex["wire"]) if "wire" in rex else None
            cnt = 0
            if "pairs" in ex:
                p = np.asarray(ex["pairs"], np.int64)
                cnt = int(p.sum())
                chist[rnd] = cnt
                if phased:
                    pairs_acc[:, k] += p
                else:
                    pairs_acc += p
            if "over" in ex:
                o = np.asarray(ex["over"], np.int64)
                if phased:
                    over_acc[:, k] += o
                else:
                    over_acc += o
            if "dstep" in rex:
                dsteps += int(rex["dstep"])
            return wire_i, cnt

        with tr.span("prime") as sp:
            payload, nsent0, wire0, ex0 = stages[0]["pack"](gb, state)
            inbox, rex = stages[0]["route"](gb, payload)
            tr.sync(inbox)
            w, _ = fold_pairs(ex0, rex, 0, 0)
            wire_i = w if w is not None else int(wire0)
            sent += int(nsent0)
            wire_total += wire_i
            whist[0] = wire_i
            sp.set(wire=wire_i, nsent=int(nsent0))
        tr.count("dispatches", 3)

        step = 0
        done = False
        for k in range(K):
            streak = 0
            with tr.span("phase", index=k,
                         boundary=(int(bounds[k])
                                   if phased and k < K - 1 else -1)):
                while not done and step < max_s:
                    if phased and k < K - 1 and (
                            step + 1 >= bounds[k]
                            or streak >= DEMOTE_STREAK):
                        break
                    with tr.span("superstep", step=step) as ss:
                        t0 = time.perf_counter()
                        eff = _faults.fire("engine.superstep", step=step,
                                           backend=self.backend,
                                           part_verts=part_verts,
                                           num_devices=nd)
                        with tr.span("sweep"):
                            state, changed, li = stages[k]["sweep"](
                                gb, state, inbox, jnp.int32(step))
                            tr.sync(changed)
                        with tr.span("pack"):
                            payload, nsent, wire, ex = stages[k]["pack"](
                                gb, state)
                            tr.sync(payload)
                        _faults.fire("exchange.route", step=step + 1,
                                     backend=self.backend)
                        with tr.span("exchange"):
                            inbox, rex = stages[k]["route"](gb, payload)
                            tr.sync(inbox)
                        with tr.span("halt-vote"):
                            # the one host sync a trace needs: read the
                            # global changed flags and decide on the host
                            # (the fused loop's psum vote, host-side)
                            ch = np.asarray(changed)
                            li_np = np.asarray(li, np.int64)
                            nsent_i = int(nsent)
                            w, cnt = fold_pairs(ex, rex, k, step + 1)
                            wire_i = w if w is not None else int(wire)
                            if Q is None:
                                nchanged = int(ch.sum())
                                any_changed = nchanged > 0
                            else:
                                changed_q = ch.any(axis=0)
                                nchanged = int(ch.any(axis=-1).sum())
                                any_changed = bool(changed_q.any())
                                qsteps[changed_q] = step + 1
                        tr.count("dispatches", 3)
                        dt = time.perf_counter() - t0
                        stalls = (eff or {}).get("stalls", [])
                        inj = sum(s for p, s in stalls
                                  if 0 <= p < num_parts)
                        psec += max(dt - inj, 0.0) / num_parts
                        for p, s in stalls:
                            if 0 <= p < num_parts:
                                psec[p] += s
                        liters += li_np
                        hist[step] = nchanged
                        whist[step + 1] = wire_i
                        sent += nsent_i
                        wire_total += wire_i
                        if phased:
                            phist[step + 1] = k
                            if nlims[k] is not None:
                                viol = int((np.asarray(ex["pairs"])
                                            > nlims[k]).sum())
                                streak = streak + 1 if viol == 0 else 0
                        ss.set(changed=nchanged, wire=wire_i,
                               nsent=nsent_i)
                        step += 1
                        done = not any_changed
            seg_end[k] = step

        tele = dict(liters=liters, hist=hist, whist=whist,
                    sent=sent, wire=wire_total, psec=psec)
        if mode in ("compact", "tiered", "phased"):
            tele["chist"] = chist
            tele["pairs"] = pairs_acc
        if mode in ("tiered", "phased"):
            tele["over"] = over_acc
        if phased:
            tele["phist"] = phist
            tele["seg_end"] = seg_end
            tele["dsteps"] = dsteps
        if Q is not None:
            tele["qsteps"] = qsteps
        return state, step, tele

    def _traced_stage_fns_megastep(self, num_queries: Optional[int]):
        """Jitted stages for the traced megastep driver: prep (compose the
        mailbox gather maps once per run), init (flat state + the prime
        round's logical observation), and step — ONE fused dispatch per
        superstep. The composed-mailbox dict carries static ints
        (num_parts/v_max/cap/n); they are stripped before crossing the jit
        boundary and re-injected from the partition scalars inside each
        stage, so the arrays flow device-to-device without re-composition
        and the ints never become tracers."""
        cache = self.__dict__.setdefault("_traced_cache", {})
        key = (num_queries, "megastep")
        fns = cache.get(key)
        if fns is not None:
            return fns
        prog = self.program
        kind = prog.megastep_kind
        Q = num_queries
        p_local = self.pg.num_parts
        v_max = self.pg.v_max
        statics = dict(num_parts=p_local, v_max=v_max,
                       cap=self.pg.mailbox_cap, n=p_local * v_max)

        def with_statics(cma):
            return dict(cma, **statics)

        adj = "binned" if kind == "batched_semiring" else "full"

        def prep_fn(gb):
            cm = mega.compose_mailbox(gb, adjacency=adj)
            return {k: v for k, v in cm.items() if k not in statics}

        if kind == "pagerank":
            def init_fn(gb, cma):
                cm = with_statics(cma)
                st = jax.vmap(prog.init)(gb)
                pairs0, nsent0 = mega.round_stats(None, cm)
                return (st["r"].reshape(-1), jnp.float32(jnp.inf)), \
                    pairs0, nsent0

            def step_fn(gb, cma, flat, step):
                cm = with_statics(cma)
                r, _ = flat
                deg = gb["out_degree"].astype(jnp.float32).reshape(-1)
                telep = (jax.vmap(prog.teleport_fn)(gb).reshape(-1)
                         if prog.teleport_fn is not None
                         else 1.0 / prog.n_global)
                r2, delta, chg = mega.megastep_pagerank(
                    r, cm, deg, telep, prog.n_global, prog.damping,
                    prog.num_iters, step)
                pairs, nsent = mega.round_stats(None, cm)
                chinfo = jnp.broadcast_to(chg, (p_local,))
                return ((r2, delta), jnp.ones((p_local,), jnp.int32),
                        pairs, nsent, chinfo)

            def finish(flat):
                return {"r": flat[0].reshape(p_local, v_max),
                        "delta": jnp.full((p_local,), flat[1])}
        else:
            semiring = prog.semiring
            unroll = prog.fixpoint_unroll
            batched = kind == "batched_semiring"
            mk = (mega.megastep_semiring_batched if batched
                  else mega.megastep_semiring)
            tail = (Q,) if batched else ()

            def init_fn(gb, cma):
                cm = with_statics(cma)
                st = jax.vmap(prog.init)(gb)
                flat = tuple(st[k].reshape((-1,) + tail)
                             for k in ("x", "changed_v", "frontier"))
                pairs0, nsent0 = mega.round_stats(flat[1], cm)
                return flat, pairs0, nsent0

            def step_fn(gb, cma, flat, step):
                cm = with_statics(cma)
                x, ch, fr = flat
                x2, ch2, fl, li = mk(x, ch, fr, cm, semiring,
                                     unroll=unroll)
                pairs, nsent = mega.round_stats(ch2, cm)
                chinfo = jnp.any(
                    ch2.reshape((p_local, v_max) + tail), axis=1)
                return (x2, ch2, fl), li, pairs, nsent, chinfo

            def finish(flat):
                return {k: v.reshape((p_local, v_max) + tail)
                        for k, v in zip(("x", "changed_v", "frontier"),
                                        flat)}

        fns = dict(prep=jax.jit(prep_fn), init=jax.jit(init_fn),
                   step=jax.jit(step_fn), finish=finish)
        cache[key] = fns
        return fns

    def _traced_loop_megastep(self, gb, num_queries: Optional[int]):
        """Gopher Hot behind an enabled tracer: the fused while_loop
        unrolled into ONE jitted dispatch per superstep (plus prep + init
        at the prime), so the trace exhibits the launch-count contraction
        this route exists for — each superstep span carries a single
        'megastep' child instead of the staged sweep/pack/exchange trio,
        and the 'dispatches' counter reads supersteps + 2 instead of
        3·supersteps + 3. Resident narrow-phase mode is NOT entered here:
        a trace wants per-superstep spans, and the resident launch hides
        its rounds inside one kernel."""
        tr = self.tracer
        Q = num_queries
        num_parts = self.pg.num_parts
        max_s = self.max_supersteps
        with tr.span("plan", phase=0, exchange="megastep",
                     backend=self.backend):
            fns = self._traced_stage_fns_megastep(Q)
        tr.count("stage_builds", 1)

        with tr.span("init"):
            cma = fns["prep"](gb)
            flat, pairs0, nsent0 = fns["init"](gb, cma)
            tr.sync(pairs0)

        liters = np.zeros(num_parts, np.int64)
        hist = np.zeros(max_s, np.int64)
        whist = np.zeros(max_s + 1, np.int64)
        chist = np.zeros(max_s + 1, np.int64)
        pairs_acc = np.asarray(pairs0, np.int64)
        chist[0] = int(pairs_acc.sum())
        sent = int(nsent0)
        qsteps = np.zeros(Q, np.int64) if Q is not None else None
        psec = np.zeros(num_parts, np.float64)
        part_verts = tuple(int(x) for x in
                           np.asarray(self.pg.vmask, bool).sum(1))

        with tr.span("prime") as sp:
            # no routed prime on the fused route: round 0's sends are
            # delivered by the FIRST megastep dispatch, so the span only
            # records the logical observation the compact prime would see
            sp.set(wire=0, nsent=sent)
        tr.count("dispatches", 2)            # prep + init

        step = 0
        done = False
        with tr.span("phase", index=0, boundary=-1):
            while not done and step < max_s:
                with tr.span("superstep", step=step) as ss:
                    t0 = time.perf_counter()
                    eff = _faults.fire("engine.superstep", step=step,
                                       backend=self.backend,
                                       part_verts=part_verts,
                                       num_devices=1)
                    with tr.span("megastep"):
                        flat, li, pairs, nsent, chinfo = fns["step"](
                            gb, cma, flat, jnp.int32(step))
                        tr.sync(li)
                    with tr.span("halt-vote"):
                        ch = np.asarray(chinfo)
                        li_np = np.asarray(li, np.int64)
                        nsent_i = int(nsent)
                        p = np.asarray(pairs, np.int64)
                        if Q is None:
                            nchanged = int(ch.sum())
                            any_changed = nchanged > 0
                        else:
                            changed_q = ch.any(axis=0)
                            nchanged = int(ch.any(axis=-1).sum())
                            any_changed = bool(changed_q.any())
                            qsteps[changed_q] = step + 1
                    tr.count("dispatches", 1)   # whole superstep: 1 launch
                    dt = time.perf_counter() - t0
                    stalls = (eff or {}).get("stalls", [])
                    inj = sum(s for p, s in stalls if 0 <= p < num_parts)
                    psec += max(dt - inj, 0.0) / num_parts
                    for p, s in stalls:
                        if 0 <= p < num_parts:
                            psec[p] += s
                    liters += li_np
                    hist[step] = nchanged
                    chist[step + 1] = int(p.sum())
                    pairs_acc += p
                    sent += nsent_i
                    ss.set(changed=nchanged, wire=0, nsent=nsent_i)
                    step += 1
                    done = not any_changed

        tele = dict(liters=liters, hist=hist, whist=whist, sent=sent,
                    wire=0, chist=chist, pairs=pairs_acc, psec=psec)
        if Q is not None:
            tele["qsteps"] = qsteps
        return fns["finish"](flat), step, tele

    def _telemetry(self, steps, tele, num_queries: Optional[int] = None,
                   rounds: Optional[int] = None,
                   exchange: Optional[str] = None) -> Telemetry:
        steps = int(steps)
        exchange = exchange or self.exchange
        wire = int(tele["wire"]) if "wire" in tele else 0
        if rounds is None:
            rounds = steps + 1                   # supersteps + inbox prime
        D = (1 if self.backend == "local"
             else int(self.mesh.shape[self.axis_name]))
        phased = exchange == "phased" and "phist" in tele
        if phased:
            # per-round geometry varies: charge the routed value slots per
            # round (wire already totals them, dense-retried rounds at
            # dense geometry) plus each phase's index lanes for its rounds
            # (a slight overcount on retried rounds — dense ships no ids).
            # phist is round-indexed, so the prime (round 0, phase 0) is
            # already in the bincount.
            K = self.tier_plan.num_phases
            phist = np.asarray(tele["phist"])[:steps + 1]
            scheds = [p.schedule(D) for p in self.tier_plan.phase_plans()]
            rounds_k = np.bincount(phist, minlength=K)
            q = num_queries or 1
            bytes_on_wire = int(
                wire * 4 * q
                + sum(scheds[k].round_index_slots() * int(rounds_k[k]) * 4
                      for k in range(K)))
        elif exchange == "tiered":
            bytes_on_wire = (self.tier_plan.schedule(D)
                             .round_bytes(num_queries) * rounds)
        elif exchange == "megastep":
            # fused route: messages move through on-chip gathers, never a
            # routed buffer — the LOGICAL observation (pairs/chist) still
            # feeds the tier profiles, but no bytes hit a wire
            bytes_on_wire = 0
        else:
            bytes_on_wire = Telemetry.model_bytes(
                wire, self.pg.num_parts, rounds=rounds,
                cap=self.pg.mailbox_cap, num_queries=num_queries,
                compact=exchange == "compact")
        pair_over = (np.asarray(tele["over"]) if "over" in tele else None)
        pair_slots = np.asarray(tele["pairs"]) if "pairs" in tele else None
        t = Telemetry(
            supersteps=steps,
            local_iters=np.asarray(tele["liters"]).reshape(-1),
            changed_hist=np.asarray(tele["hist"])[:steps],
            messages_sent=int(tele["sent"]) if np.ndim(tele["sent"]) == 0 else int(np.max(tele["sent"])),
            query_supersteps=(np.asarray(tele["qsteps"])
                              if "qsteps" in tele else None),
            wire_hist=(np.asarray(tele["whist"])[:steps + 1]
                       if "whist" in tele else None),
            wire_slots=wire,
            bytes_on_wire=bytes_on_wire,
            exchange=exchange,
            count_hist=(np.asarray(tele["chist"])[:steps + 1]
                        if "chist" in tele else None),
        )
        if "psec" in tele:
            t.part_seconds = np.asarray(tele["psec"], np.float64).reshape(-1)
        if phased:
            # phase buckets travel parts-leading (P, K, P); report (K, P, P)
            by_phase = np.transpose(pair_slots, (1, 0, 2))
            over_k = np.transpose(pair_over, (1, 0, 2))
            t.phase_pair_slots = by_phase
            t.pair_slots = by_phase.sum(0)
            t.pair_overflow = over_k.sum(0)
            t.pair_rounds = rounds
            t.spills = int(over_k.sum())
            t.phase_hist = phist
            whist = np.asarray(tele["whist"])[:steps + 1]
            seg_end = np.asarray(tele["seg_end"])
            t.phase_switch_steps = np.unique(seg_end[:-1][seg_end[:-1] < steps])
            pw = np.zeros(K, np.int64)
            np.add.at(pw, phist, whist)          # round 0 (the prime) included
            t.phase_wire = pw
            t.dense_retry_steps = int(tele["dsteps"])
        else:
            t.pair_slots = pair_slots
            t.pair_rounds = rounds if pair_slots is not None else 0
            t.pair_overflow = pair_over
            t.spills = int(pair_over.sum()) if pair_over is not None else 0
        return t

    def _runner(self, num_queries: Optional[int] = None, gb_example=None,
                exchange: Optional[str] = None):
        """The compiled BSP loop, cached so repeated runs hit the same jit
        entry instead of re-tracing.

        The cache is MODULE-level and keyed on everything the trace depends
        on — program (frozen dataclass; init_fn compares by identity),
        backend/mesh, loop bounds, partition-batch shapes, and the gb
        entry signature (shard_map in_specs are baked from the block
        structure) — so SHORT-LIVED ENGINES SHARE COMPILED LOOPS: a
        temporal-serving fleet that rebuilds its engines after every
        apply_delta re-enters the compiled loop as long as the delta didn't
        change any padded shape, instead of paying a full XLA compile per
        graph version.

        A PER-ENGINE memo sits in front of it: the module key's signature
        walk (sorted shape/dtype tuples over ~a hundred block entries)
        costs real per-run time on millisecond-scale warm runs, and for a
        given engine the resolved runner only varies with (Q, exchange,
        block key set, tier plan) — the plan compared by IDENTITY, so a
        post-run escalation that swaps self.tier_plan misses the memo and
        re-resolves."""
        exchange = exchange or self.exchange
        tier_plan = (self.tier_plan
                     if exchange in ("tiered", "phased", "megastep")
                     else None)
        mkey = (num_queries, exchange,
                None if gb_example is None else frozenset(gb_example))
        hit = self._runner_memo.get(mkey)
        if hit is not None and hit[0] is tier_plan:
            return hit[1]
        if tier_plan is not None and getattr(self, "validate", False):
            # a non-static plan would blow up the cache-key hash below
            # with a bare TypeError — vet it first so the failure names
            # the offending field instead
            from repro.analysis import assert_clean, check_plan_static
            assert_clean(check_plan_static(tier_plan))
        gb_sig = (tuple(sorted((k, v.shape, str(v.dtype))
                               for k, v in gb_example.items()))
                  if gb_example is not None else None)
        key = (self.program, self.backend, exchange, tier_plan, num_queries,
               self.max_supersteps, self.axis_name, self.mesh,
               self.pg.num_parts, self.pg.v_max, self.pg.mailbox_cap, gb_sig)
        cached = _RUNNER_CACHE.get(key)
        if cached is None:
            # build the runner on a DETACHED engine holding only the scalars
            # the trace reads (graph data flows in through the gb argument):
            # a cached closure over `self` would pin this engine's device
            # graph block — and its host pg — for the cache entry's lifetime
            slim = GopherEngine.__new__(GopherEngine)
            slim.pg = _PgScalars(num_parts=self.pg.num_parts,
                                 v_max=self.pg.v_max,
                                 mailbox_cap=self.pg.mailbox_cap)
            slim.program = self.program
            slim.backend = self.backend
            slim.exchange = exchange
            slim.tier_plan = tier_plan
            slim.mesh = self.mesh
            slim.axis_name = self.axis_name
            slim.max_supersteps = self.max_supersteps
            slim._gb = None
            if getattr(self, "validate", False):
                # Gopher Sentinel gate: verify the exact loop about to be
                # compiled (the slim engine IS that loop's closure) before
                # it can enter the cache. Raises SentinelError on findings.
                from repro.analysis import validate_engine
                validate_engine(slim, num_queries=num_queries,
                                gb_example=gb_example)
            if self.backend == "local":
                cached = jax.jit(functools.partial(
                    slim._run_batched, num_queries=num_queries))
            else:
                cached = slim._sharded_fn(
                    num_queries=num_queries, gb_example=gb_example)
            if len(_RUNNER_CACHE) >= _RUNNER_CACHE_CAP:
                _RUNNER_CACHE.pop(next(iter(_RUNNER_CACHE)))
            _RUNNER_CACHE[key] = cached
        self._runner_memo[mkey] = (tier_plan, cached)
        return cached

    def _run_checkpointed(self, ck, every: int, resume: bool,
                          extra: Optional[dict] = None,
                          superstep_budget: Optional[int] = None):
        """Checkpointable BSP: a host-stepped driver over the STAGED stage
        functions (Gopher Scope's init/sweep/pack/route jits — bit-identical
        to the fused loops), snapshotting (state, inbox, superstep) every
        `every` supersteps on BOTH backends. Tiered/phased/megastep configs
        drop to the compact staged loop — same results (bitwise for
        idempotent ⊕) per the cross-mode identity tests: tier overflow
        repair and phase segmentation don't span snapshot boundaries, and
        the fused megastep route carries no staged (state, inbox) pair to
        snapshot. Reuses the engine's cached graph block — a checkpointed
        run must not build a second device copy — and carries the same
        telemetry counters as a normal run (after a resume, counters cover
        the current process's supersteps; the hist slots before the
        restored step are zero).

        Restore goes through the newest snapshot that passes checksum
        verification (Checkpointer.latest_good_step): a corrupt/truncated
        snapshot automatically falls back to the previous good one. Gopher
        Shield fault sites `engine.superstep` / `exchange.route` fire in
        this host loop — never inside compiled code."""
        if self.exchange in ("megastep", "tiered", "phased"):
            prev = self.exchange
            self.exchange = "compact"
            try:
                return self._run_checkpointed(
                    ck, every, resume, extra,
                    superstep_budget=superstep_budget)
            finally:
                self.exchange = prev
        gb = self._graph_block()
        if extra:
            gb = dict(gb)
            for k, v in extra.items():
                gb[k] = jnp.asarray(v)
        prog = self.program
        num_parts, v_max = self.pg.num_parts, self.pg.v_max
        max_s = self.max_supersteps
        fns = self._traced_stage_fns(None, None)

        # host telemetry accumulators in the fused loop's exact layout
        liters = np.zeros(num_parts, np.int64)
        hist = np.zeros(max_s, np.int64)
        whist = np.zeros(max_s + 1, np.int64)
        chist = np.zeros(max_s + 1, np.int64)
        pairs_acc = np.zeros((num_parts, num_parts), np.int64)
        sent = wire_total = 0
        # Gopher Balance time channel: injected stalls land on their target
        # partition, the rest of each superstep's wall time spreads evenly
        psec = np.zeros(num_parts, np.float64)
        part_verts = tuple(int(x) for x in
                           np.asarray(self.pg.vmask, bool).sum(1))
        D = (1 if self.backend == "local"
             else int(self.mesh.shape[self.axis_name]))

        good = None
        if resume:
            good = (ck.latest_good_step() if hasattr(ck, "latest_good_step")
                    else ck.latest_step())
        if good is not None:
            snap_like = {
                "state": jax.eval_shape(lambda g: jax.vmap(prog.init)(g), gb),
                "inbox": jax.ShapeDtypeStruct((num_parts, v_max),
                                              np.float32),
            }
            shardings = None
            if self.backend == "shard_map":
                sh = jax.sharding.NamedSharding(self.mesh, P(self.axis_name))
                shardings = jax.tree.map(lambda _: sh, snap_like)
            snap, step = ck.restore(snap_like, step=good,
                                    shardings=shardings)
            state, inbox = snap["state"], snap["inbox"]
            step = int(step)
            primed = False
        else:
            state = fns["init"](gb)
            payload, nsent0, wire0, ex0 = fns["pack"](gb, state)
            _faults.fire("exchange.route", step=0, backend=self.backend)
            inbox, rex0 = fns["route"](gb, payload)
            wire_i = int(rex0["wire"]) if "wire" in rex0 else int(wire0)
            sent += int(nsent0)
            wire_total += wire_i
            whist[0] = wire_i                    # round 0 = the prime
            if "pairs" in ex0:
                p0 = np.asarray(ex0["pairs"], np.int64)
                pairs_acc += p0
                chist[0] = int(p0.sum())
            step = 0
            primed = True

        start = step
        budget = superstep_budget
        done = False
        while not done and step < max_s and (budget is None
                                             or step - start < budget):
            t0 = time.perf_counter()
            eff = _faults.fire("engine.superstep", step=step,
                               backend=self.backend,
                               part_verts=part_verts, num_devices=D)
            state, changed, li = fns["sweep"](gb, state, inbox,
                                              jnp.int32(step))
            payload, nsent, wire, ex = fns["pack"](gb, state)
            _faults.fire("exchange.route", step=step + 1,
                         backend=self.backend)
            inbox, rex = fns["route"](gb, payload)
            ch = np.asarray(changed)
            nchanged = int(ch.sum())
            wire_i = int(rex["wire"]) if "wire" in rex else int(wire)
            dt = time.perf_counter() - t0
            stalls = (eff or {}).get("stalls", [])
            inj = sum(s for p, s in stalls if 0 <= p < num_parts)
            psec += max(dt - inj, 0.0) / num_parts
            for p, s in stalls:
                if 0 <= p < num_parts:
                    psec[p] += s
            liters += np.asarray(li, np.int64)
            hist[step] = nchanged
            whist[step + 1] = wire_i
            sent += int(nsent)
            wire_total += wire_i
            if "pairs" in ex:
                p = np.asarray(ex["pairs"], np.int64)
                pairs_acc += p
                chist[step + 1] = int(p.sum())
            step += 1
            done = nchanged == 0
            cut = budget is not None and step - start >= budget
            if done or cut or (step - start) % every == 0 or step >= max_s:
                ck.save({"state": state, "inbox": inbox}, step)
        # after a resume the wire counters cover only THIS process's
        # exchanges, so the byte model must count the same rounds (no prime
        # ran, and pre-resume supersteps shipped in the previous process)
        rounds = step - start + (1 if primed else 0)
        tele = dict(liters=liters, hist=hist, whist=whist, sent=sent,
                    wire=wire_total, psec=psec)
        if self.exchange == "compact":
            tele["chist"] = chist
            tele["pairs"] = pairs_acc
        t = self._telemetry(step, tele, rounds=rounds)
        self._record_run_metrics(t)
        return jax.tree.map(np.asarray, state), t

    def _sharded_fn(self, num_queries: Optional[int] = None, gb_example=None):
        spec = P(self.axis_name)
        rep = P()

        def body(gb_shard):
            state, steps, tele = self._run_batched(gb_shard,
                                                   num_queries=num_queries)
            return state, steps, tele

        gb_shapes = (graph_block(self.pg, as_spec=True) if gb_example is None
                     else {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                           for k, v in gb_example.items()})
        gb_spec = jax.tree.map(lambda _: spec, gb_shapes)
        # state leaves shard over parts; steps + hist + sent (+ per-query
        # qsteps, already psum'd) are replicated; liters shard over parts.
        state_spec = jax.tree.map(lambda _: spec,
                                  jax.eval_shape(lambda g: jax.vmap(self.program.init)(g),
                                                 gb_shapes))
        tele_spec = dict(liters=spec, hist=rep, whist=rep, sent=rep, wire=rep)
        # per-pair wire telemetry shards over parts like liters: each
        # device owns its local source rows of the (P, P) matrices (phased:
        # of the (P, K, P) per-phase buckets)
        if self.exchange in ("compact", "tiered", "phased"):
            tele_spec["pairs"] = spec
            tele_spec["chist"] = rep
        if self.exchange in ("tiered", "phased"):
            tele_spec["over"] = spec
        if self.exchange == "phased":
            tele_spec["phist"] = rep
            tele_spec["seg_end"] = rep
            tele_spec["dsteps"] = rep
        if num_queries is not None:
            tele_spec["qsteps"] = rep
        out_specs = (state_spec, rep, tele_spec)
        f = compat.shard_map(body, mesh=self.mesh, in_specs=(gb_spec,),
                             out_specs=out_specs)
        return jax.jit(f)

    # ---------------- lowering entry point (dry-run / roofline) ----------------
    def lowerable_superstep(self):
        """A (fn, example_specs) pair: one shard_map'd BSP superstep suitable
        for ``jax.jit(fn).lower(*specs).compile()`` at production mesh scale.
        Used by launch/dryrun.py for the paper-side roofline."""
        assert self.backend == "shard_map"
        spec = P(self.axis_name)
        gb_specs = graph_block(self.pg, as_spec=True)
        gb_pspec = jax.tree.map(lambda _: spec, gb_specs)
        prog = self.program

        state_shapes = jax.eval_shape(
            lambda g: jax.vmap(prog.init)(g), gb_specs)
        state_pspec = jax.tree.map(lambda _: spec, state_shapes)
        inbox_spec = jax.ShapeDtypeStruct((self.pg.num_parts, self.pg.v_max), np.float32)

        def one_step(gb, state, inbox, step):
            sstep = self.make_superstep(gb)
            st, ib, ch, li, ns, wire, ex = sstep(state, inbox, step)
            return st, ib, ch

        f = compat.shard_map(one_step, mesh=self.mesh,
                             in_specs=(gb_pspec, state_pspec, spec, P()),
                             out_specs=(state_pspec, spec, spec))
        step_spec = jax.ShapeDtypeStruct((), np.int32)
        return f, (gb_specs, state_shapes, inbox_spec, step_spec)
