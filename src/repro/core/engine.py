"""Gopher: the sub-graph centric BSP execution engine.

Faithful mapping of the paper's §4.2 runtime onto SPMD JAX:

  paper                               here
  -----                               ----
  worker per machine                  mesh device along the 'parts' axis
  thread pool over sub-graphs         vectorized (vmap) partitions + the
                                      local-fixpoint sweep (programs.py)
  async TCP message flush             all_to_all mailbox at superstep boundary
                                      (XLA overlaps it with the sweep tail)
  manager sync/resume/terminate       psum of per-partition 'changed' flags
                                      inside a lax.while_loop — the manager
                                      degenerates to an all-reduce
  VoteToHalt + no input messages      changed == False (see programs.py for
                                      why this is equivalent for idempotent ⊕)

Two backends share every line of superstep logic:
  'local'     — all P partitions as a (P, ...) batch on one device (CPU tests,
                virtual partitions)
  'shard_map' — partitions sharded over a mesh axis; mailbox routed with a
                real all_to_all; halt via psum (multi-chip / dry-run path)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import messages as msg
from repro.gofs.formats import PAD, PartitionedGraph

_GB_FIELDS = ["nbr", "wgt", "vmask", "out_degree", "global_id", "sg_id",
              "re_src", "re_wgt", "re_dst_part", "re_dst_local", "re_slot"]


@dataclasses.dataclass
class Telemetry:
    supersteps: int
    local_iters: np.ndarray        # (P,) cumulative sweep iterations (straggler signal)
    changed_hist: np.ndarray       # (max_supersteps,) #partitions changed per superstep
    messages_sent: int


def graph_block(pg: PartitionedGraph, as_spec: bool = False) -> dict:
    """The device-side pytree of per-partition arrays (leading axis P).
    ``as_spec=True`` returns ShapeDtypeStructs (dry-run lowering)."""
    gb = {k: np.asarray(getattr(pg, k)) for k in _GB_FIELDS}
    gb["part_index"] = np.arange(pg.num_parts, dtype=np.int32)
    for name, arr in pg.attrs.items():
        gb[f"attr_{name}"] = np.asarray(arr)
    if as_spec:
        return {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in gb.items()}
    return {k: jnp.asarray(v) for k, v in gb.items()}


class GopherEngine:
    """Runs a program over a PartitionedGraph to global quiescence."""

    def __init__(self, pg: PartitionedGraph, program, backend: str = "local",
                 mesh=None, axis_name: str = "parts",
                 max_supersteps: int = 4096):
        assert backend in ("local", "shard_map")
        if backend == "shard_map":
            assert mesh is not None
            d = mesh.shape[axis_name]
            assert pg.num_parts % d == 0, "partitions must tile the mesh axis"
        self.pg = pg
        self.program = program
        self.backend = backend
        self.mesh = mesh
        self.axis_name = axis_name
        self.max_supersteps = max_supersteps

    # ---------------- superstep body (backend-shared) ----------------
    def make_superstep(self, gb):
        """One BSP superstep over a partition batch gb (leading axis = local
        partition count). Returns (state, inbox, changed(P,), liters(P,), nsent)."""
        prog = self.program
        cap = self.pg.mailbox_cap
        v_max = self.pg.v_max
        combine = prog.combine
        num_parts = self.pg.num_parts

        def sstep(state, inbox, step):
            new_state, changed, liters = jax.vmap(
                prog.superstep, in_axes=(0, 0, 0, None))(state, inbox, gb, step)
            vals, send = jax.vmap(prog.messages)(new_state, gb)
            ov, oi = jax.vmap(
                functools.partial(msg.build_outbox, num_parts=num_parts,
                                  cap=cap, combine=combine))(
                vals, gb["re_src"], gb["re_dst_part"], gb["re_dst_local"],
                gb["re_slot"], send)
            if self.backend == "local":
                iv, ii = msg.route_local(ov, oi)
            else:
                iv, ii = msg.route_shard_map(ov, oi, self.axis_name)
            inbox = jax.vmap(
                functools.partial(msg.combine_inbox, v_max=v_max, combine=combine))(iv, ii)
            nsent = jnp.sum(send).astype(jnp.int32)
            return new_state, inbox, changed, liters, nsent

        return sstep

    def _run_batched(self, gb):
        """The full BSP loop over a partition batch. Runs as-is on the local
        backend; runs per-shard (with collectives) under shard_map."""
        prog = self.program
        ident = msg.COMBINE_IDENTITY[prog.combine]
        sstep = self.make_superstep(gb)
        p_local = gb["vmask"].shape[0]
        state0 = jax.vmap(prog.init)(gb)
        inbox0 = jnp.full((p_local, self.pg.v_max), ident, jnp.float32)
        tele0 = dict(liters=jnp.zeros((p_local,), jnp.int32),
                     hist=jnp.zeros((self.max_supersteps,), jnp.int32),
                     sent=jnp.int32(0))

        def cond(c):
            _, _, step, done, _ = c
            return (~done) & (step < self.max_supersteps)

        def body(c):
            state, inbox, step, _, tele = c
            state, inbox, changed, liters, nsent = sstep(state, inbox, step)
            any_changed = jnp.any(changed)
            nchanged = jnp.sum(changed.astype(jnp.int32))
            if self.backend == "shard_map":
                any_changed = jax.lax.psum(any_changed.astype(jnp.int32),
                                           self.axis_name) > 0
                nchanged = jax.lax.psum(nchanged, self.axis_name)
                nsent = jax.lax.psum(nsent, self.axis_name)
            tele = dict(liters=tele["liters"] + liters,
                        hist=tele["hist"].at[step].set(nchanged),
                        sent=tele["sent"] + nsent)
            return state, inbox, step + 1, ~any_changed, tele

        state, _, steps, _, tele = jax.lax.while_loop(
            cond, body, (state0, inbox0, jnp.int32(0), jnp.bool_(False), tele0))
        return state, steps, tele

    # ---------------- drivers ----------------
    def run(self, checkpointer=None, checkpoint_every: int = 0,
            resume: bool = False):
        """Run to quiescence. With a `training.checkpoint.Checkpointer` and
        checkpoint_every=N, the BSP loop snapshots (state, inbox, superstep)
        every N supersteps and can restart from the last committed snapshot
        after a failure (BSP makes the cut trivially consistent — paper §4.2's
        synchronization points ARE the recovery lines)."""
        if checkpointer is not None and checkpoint_every > 0:
            return self._run_checkpointed(checkpointer, checkpoint_every, resume)
        if self.backend == "local":
            gb = graph_block(self.pg)
            state, steps, tele = jax.jit(lambda g: self._run_batched(g))(gb)
        else:
            state, steps, tele = self._sharded_fn()(graph_block(self.pg))
        telemetry = Telemetry(
            supersteps=int(steps),
            local_iters=np.asarray(tele["liters"]).reshape(-1),
            changed_hist=np.asarray(tele["hist"]),
            messages_sent=int(tele["sent"]) if np.ndim(tele["sent"]) == 0 else int(np.max(tele["sent"])),
        )
        return jax.tree.map(np.asarray, state), telemetry

    def _run_checkpointed(self, ck, every: int, resume: bool):
        """Chunked BSP: jitted inner loop of <= `every` supersteps, snapshot
        between chunks (local backend)."""
        assert self.backend == "local", "checkpointed runs use the local backend"
        gb = graph_block(self.pg)
        prog = self.program
        ident = msg.COMBINE_IDENTITY[prog.combine]
        sstep = self.make_superstep(gb)

        @jax.jit
        def chunk(state, inbox, step0):
            def cond(c):
                _, _, step, done, _ = c
                return (~done) & (step < step0 + every) & (step < self.max_supersteps)

            def body(c):
                state, inbox, step, _, liters = c
                state, inbox, changed, li, _ = sstep(state, inbox, step)
                return state, inbox, step + 1, ~jnp.any(changed), liters + li

            return jax.lax.while_loop(
                cond, body, (state, inbox, step0, jnp.bool_(False),
                             jnp.zeros((self.pg.num_parts,), jnp.int32)))

        if resume and ck.latest_step() is not None:
            snap_like = {
                "state": jax.eval_shape(lambda g: jax.vmap(prog.init)(g), gb),
                "inbox": jax.ShapeDtypeStruct(
                    (self.pg.num_parts, self.pg.v_max), np.float32),
            }
            snap, step = ck.restore(snap_like)
            state, inbox = snap["state"], snap["inbox"]
            step = jnp.int32(step)
        else:
            state = jax.vmap(prog.init)(gb)
            inbox = jnp.full((self.pg.num_parts, self.pg.v_max), ident, jnp.float32)
            step = jnp.int32(0)

        total_liters = np.zeros((self.pg.num_parts,), np.int64)
        done = False
        while not done and int(step) < self.max_supersteps:
            state, inbox, step, done_flag, liters = chunk(state, inbox, step)
            total_liters += np.asarray(liters)
            done = bool(done_flag)
            ck.save({"state": state, "inbox": inbox}, int(step))
        tele = Telemetry(supersteps=int(step), local_iters=total_liters,
                         changed_hist=np.zeros(0, np.int32), messages_sent=-1)
        return jax.tree.map(np.asarray, state), tele

    def _sharded_fn(self):
        spec = P(self.axis_name)
        rep = P()

        def body(gb_shard):
            state, steps, tele = self._run_batched(gb_shard)
            return state, steps, tele

        gb_spec = jax.tree.map(lambda _: spec,
                               graph_block(self.pg, as_spec=True))
        # state leaves shard over parts; steps + hist + sent are replicated;
        # liters shard over parts.
        state_spec = jax.tree.map(lambda _: spec,
                                  jax.eval_shape(lambda g: jax.vmap(self.program.init)(g),
                                                 graph_block(self.pg, as_spec=True)))
        out_specs = (state_spec, rep,
                     dict(liters=spec, hist=rep, sent=rep))
        f = jax.shard_map(body, mesh=self.mesh, in_specs=(gb_spec,),
                          out_specs=out_specs, check_vma=False)
        return jax.jit(f)

    # ---------------- lowering entry point (dry-run / roofline) ----------------
    def lowerable_superstep(self):
        """A (fn, example_specs) pair: one shard_map'd BSP superstep suitable
        for ``jax.jit(fn).lower(*specs).compile()`` at production mesh scale.
        Used by launch/dryrun.py for the paper-side roofline."""
        assert self.backend == "shard_map"
        spec = P(self.axis_name)
        gb_specs = graph_block(self.pg, as_spec=True)
        gb_pspec = jax.tree.map(lambda _: spec, gb_specs)
        prog = self.program
        ident = msg.COMBINE_IDENTITY[prog.combine]

        state_shapes = jax.eval_shape(
            lambda g: jax.vmap(prog.init)(g), gb_specs)
        state_pspec = jax.tree.map(lambda _: spec, state_shapes)
        inbox_spec = jax.ShapeDtypeStruct((self.pg.num_parts, self.pg.v_max), np.float32)

        def one_step(gb, state, inbox, step):
            sstep = self.make_superstep(gb)
            st, ib, ch, li, ns = sstep(state, inbox, step)
            return st, ib, ch

        f = jax.shard_map(one_step, mesh=self.mesh,
                          in_specs=(gb_pspec, state_pspec, spec, P()),
                          out_specs=(state_pspec, spec, spec),
                          check_vma=False)
        step_spec = jax.ShapeDtypeStruct((), np.int32)
        return f, (gb_specs, state_shapes, inbox_spec, step_spec)
