"""Gopher: sub-graph centric BSP engine (the paper's core contribution)."""
from repro.core.blocks import (device_block, graph_block, host_graph_block,
                               patch_host_block, verify_host_block)
from repro.core.engine import GopherEngine, Telemetry
from repro.core.programs import (PageRankProgram, SemiringProgram,
                                 init_max_vertex, make_bfs_init, make_sssp_init)
from repro.core.subgraph import (meta_diameter, meta_graph, subgraph_sizes,
                                 vertex_diameter)
from repro.core.tiers import (PhasedTierPlan, TierPlan, TierSchedule,
                              announce_frontier, expected_horizon,
                              update_changed_profile, update_phase_profile,
                              update_profile)

__all__ = [
    "GopherEngine", "Telemetry", "graph_block",
    "host_graph_block", "device_block", "patch_host_block",
    "verify_host_block",
    "TierPlan", "PhasedTierPlan", "TierSchedule", "update_profile",
    "update_changed_profile", "update_phase_profile", "expected_horizon",
    "announce_frontier",
    "SemiringProgram", "PageRankProgram",
    "init_max_vertex", "make_sssp_init", "make_bfs_init",
    "meta_graph", "meta_diameter", "vertex_diameter", "subgraph_sizes",
]
