"""Gopher: sub-graph centric BSP engine (the paper's core contribution)."""
from repro.core.blocks import (device_block, graph_block, host_graph_block,
                               patch_host_block)
from repro.core.engine import GopherEngine, Telemetry
from repro.core.programs import (PageRankProgram, SemiringProgram,
                                 init_max_vertex, make_bfs_init, make_sssp_init)
from repro.core.subgraph import (meta_diameter, meta_graph, subgraph_sizes,
                                 vertex_diameter)
from repro.core.tiers import (TierPlan, TierSchedule, announce_frontier,
                              update_profile)

__all__ = [
    "GopherEngine", "Telemetry", "graph_block",
    "host_graph_block", "device_block", "patch_host_block",
    "TierPlan", "TierSchedule", "update_profile", "announce_frontier",
    "SemiringProgram", "PageRankProgram",
    "init_max_vertex", "make_sssp_init", "make_bfs_init",
    "meta_graph", "meta_diameter", "vertex_diameter", "subgraph_sizes",
]
