"""Pure-SSM family (falcon-mamba-7b): Mamba1 (S6) blocks, attention-free.

O(1) decode state per layer -> the long_500k cell is this family's home turf.
Training materializes nothing bigger than a chunk: lax.scan over chunks
carries the (B, d_inner, N) state; within-chunk recurrence is an associative
scan (DESIGN.md §6 hardware adaptation of the CUDA selective-scan kernel).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.sharding import shard, shard_params


def _layer_params(key, cfg):
    k1, _ = jax.random.split(key)
    return {"mixer": L.mamba1_params(k1, cfg), "ln": jnp.zeros((cfg.d_model,))}


def init_params(key, cfg, max_seq: int = 0):
    ke, kl = jax.random.split(key)
    keys = jax.random.split(kl, cfg.n_layers)
    return {
        "embed": L.embed_params(ke, cfg),
        "blocks": [jax.vmap(lambda k: _layer_params(k, cfg))(keys)],
        "final_norm": jnp.zeros((cfg.d_model,)),
    }


def forward(params, tokens, cfg, positions=None, return_kv: bool = False):
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = L.embed(tokens, params["embed"], dtype)

    def body(x, p):
        p = shard_params(p)
        x = shard(x, "batch", "seq", "actd")  # TP-sharded residual save (§Perf F2)
        fn = lambda xc, pp: xc + L.mamba1_mixer(
            L.rms_norm(xc, pp["ln"], cfg.norm_eps), pp["mixer"], cfg)[0]
        if cfg.remat:
            fn = jax.checkpoint(fn)
        return fn(x, p), None

    x, _ = jax.lax.scan(body, x, params["blocks"][0])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(x, params["embed"], cfg)
    if return_kv:
        return logits, jnp.float32(0), []
    return logits, jnp.float32(0)


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    Lyr = cfg.n_layers
    return {
        "conv": jnp.zeros((Lyr, batch, s.d_conv - 1, di), dtype),
        "ssm": jnp.zeros((Lyr, batch, di, s.d_state), jnp.float32),
        "len": jnp.int32(0),
    }


def decode_step(params, token, cache, cfg, positions=None):
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = L.embed(token[:, None], params["embed"], dtype)

    def body(x, inp):
        p, conv, ssm = inp
        h = L.rms_norm(x, p["ln"], cfg.norm_eps)
        y, st = L.mamba1_mixer(h, p["mixer"], cfg,
                               state={"conv": conv, "ssm": ssm})
        return x + y, (st["conv"], st["ssm"])

    x, (conv, ssm) = jax.lax.scan(body, x, (params["blocks"][0],
                                            cache["conv"], cache["ssm"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(x, params["embed"], cfg)[:, 0]
    return logits, {"conv": conv, "ssm": ssm, "len": cache["len"] + 1}


def prefill(params, tokens, cfg, max_seq=None, positions=None):
    """SSM prefill: run the sequence through, capturing the final recurrent
    state per layer. (States come from re-running the last d_conv-1 tokens +
    a chunked state pass inside the mixer — here we simply re-scan with state
    capture, which the chunked mixer gives for free.)"""
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = L.embed(tokens, params["embed"], dtype)

    def body(x, p):
        h = L.rms_norm(x, p["ln"], cfg.norm_eps)
        y, st = L.mamba1_mixer(h, p["mixer"], cfg)
        return x + y, (st["conv"], st["ssm"])

    x, (conv, ssm) = jax.lax.scan(body, x, params["blocks"][0])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(x, params["embed"], cfg)
    cache = {"conv": conv.astype(dtype), "ssm": ssm,
             "len": jnp.int32(tokens.shape[1])}
    return logits, cache, jnp.float32(0)
