"""Logical-axis sharding rules (MaxText-style) resolved against the active mesh.

Logical axes used by the model code:
    batch   -> ('pod', 'data') when a pod axis exists, else ('data',)
    fsdp    -> 'data'   (parameter + optimizer-state sharding)
    tp      -> 'model'  (tensor parallel: heads / ffn hidden / vocab / experts)
    seq     -> None by default; 'data' under sequence parallelism (prefill opt)
    none    -> replicated

``shard(x, *logical)`` applies a with_sharding_constraint when a mesh is
active and is a no-op otherwise (CPU smoke tests).
"""
from __future__ import annotations

import threading
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()

DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),
    "tp": ("model",),
    "seq": (),
    # layer-boundary residual saves: ZeRO-R style activation partitioning —
    # the remat stack shards its d_model dim over TP and re-gathers once per
    # layer in the backward (16x smaller residual stack; §Perf iteration F2)
    "actd": ("model",),
    # attention fallback when n_heads < TP (gemma3 h=8, whisper h=12): run
    # attention data-parallel over BOTH axes — batch folds onto
    # ('pod','data','model') so no device idles (§Perf W2)
    "batch_tp": ("pod", "data", "model"),
    "none": (),
}

# base (unstacked) PartitionSpec per parameter leaf name — shared with
# training.shardspec. FSDP='data', TP='model'.
PARAM_RULES = {
    "tok": ("model", "data"), "unembed": ("data", "model"),
    "pos_enc": (None, None), "pos_dec": (None, None),
    "wq": ("data", "model", None), "wk": ("data", "model", None),
    "wv": ("data", "model", None), "wo": ("model", None, "data"),
    "bq": ("model", None), "bk": ("model", None), "bv": ("model", None),
    "q_norm": (None,), "k_norm": (None,),
    "w_gate": ("data", "model"), "w_up": ("data", "model"),
    "w_down": ("model", "data"),
    # router is tiny (d×E) and must be whole for local routing decisions in
    # the EP mailbox dispatch — replicate it
    "router": (None, None),
    "we_gate": ("model", "data", None), "we_up": ("model", "data", None),
    "we_down": ("model", None, "data"),
    "in_proj": ("data", "model"), "out_proj": ("model", "data"),
    "x_proj": ("model", None), "dt_proj_w": (None, "model"),
    "dt_proj_b": ("model",), "conv_w": (None, "model"), "conv_b": ("model",),
    "D": ("model",), "dt_bias": ("model",), "norm": ("model",),
    "a_log2": ("model",),   # mamba2 per-head decay (H,)
}


def base_param_spec(name: str, ndim: int, shape=None, sizes=None):
    if name == "A_log":  # mamba1 (di, N) vs mamba2 (H,)
        return ("model", None) if ndim >= 2 else ("model",)
    if name in ("wk", "wv") and shape is not None and sizes:
        # GQA: kv heads may not divide TP — fall back to row-parallel over
        # d_model, TP axis ONLY (k/v become TP-replicated after a small psum):
        # the classic KV-replication scheme. Never contract over 'data' — that
        # would conflict with the batch sharding and force GSPMD to replicate
        # activations (measured: 1 TB/dev of all-gather; EXPERIMENTS.md §Perf).
        kv = shape[-2]
        if kv % max(sizes.get("model", 1), 1) != 0:
            return ("model", None, None)
    return PARAM_RULES.get(name)


def fit_axes(entry, dim: int, sizes: dict):
    """Drop mesh axes that don't divide `dim` (GQA kv<TP, odd vocabs, ...)."""
    if entry is None:
        return None
    axes = entry if isinstance(entry, tuple) else (entry,)
    kept, prod = [], 1
    for a in axes:
        s = sizes.get(a, 0)
        if s and dim % (prod * s) == 0:
            kept.append(a)
            prod *= s
    if not kept:
        return None
    return tuple(kept) if len(kept) > 1 else kept[0]


def set_rules(mesh_or_names, overrides: Optional[dict] = None):
    """Activate sharding for model code. Call before tracing train/serve
    steps. Accepts a Mesh (captures axis sizes for divisibility checks) or a
    tuple of axis names."""
    if hasattr(mesh_or_names, "axis_names"):
        names = mesh_or_names.axis_names
        sizes = {a: int(s) for a, s in
                 zip(names, mesh_or_names.devices.shape)}
        _state.mesh = mesh_or_names
    else:
        names = tuple(mesh_or_names)
        sizes = {}
        _state.mesh = None
    rules = {}
    for k, axes in {**DEFAULT_RULES, **(overrides or {})}.items():
        rules[k] = tuple(a for a in axes if a in names)
    _state.rules = rules
    _state.sizes = sizes
    _state.active = True


def active_mesh():
    return getattr(_state, "mesh", None) if getattr(_state, "active", False) else None


def rule_axes(name: str):
    rules = getattr(_state, "rules", None)
    return rules.get(name, ()) if rules else ()


def clear_rules():
    _state.active = False


def resolve(*logical) -> P:
    rules = getattr(_state, "rules", None)
    if rules is None:
        return P(*[None for _ in logical])
    out = []
    for name in logical:
        if name is None:
            out.append(None)
            continue
        axes = rules.get(name, ())
        out.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    return P(*out)


def shard(x, *logical):
    """Constrain x's sharding by logical axis names (one per dim).

    Axes that do not divide the dim are dropped (-> explicitly replicated):
    a silently-failing constraint would leave GSPMD free to scatter e.g. a
    GQA kv head dim's batch over 'model' and re-gather it inside the
    attention loop (measured 1.1 TB/dev; EXPERIMENTS.md §Perf)."""
    if not getattr(_state, "active", False):
        return x
    spec = resolve(*logical)
    sizes = getattr(_state, "sizes", {})
    if sizes and hasattr(x, "shape") and len(spec) == len(x.shape):
        spec = P(*(fit_axes(e, d, sizes) for e, d in zip(spec, x.shape)))
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def param_spec(*logical) -> P:
    return resolve(*logical)


def shard_params(tree):
    """Re-constrain (unstacked) layer params to their FSDP×TP specs INSIDE a
    scan body. Without this, GSPMD hoists the FSDP all-gather of the whole
    stacked parameter array out of the layer loop — 17 GB of gathered weights
    resident per device instead of one layer's worth (measured: llama3-8b
    train_4k temp 48.9 GiB -> see EXPERIMENTS.md §Perf)."""
    if not getattr(_state, "active", False):
        return tree
    sizes = getattr(_state, "sizes", {})

    def constrain(path, leaf):
        name = ""
        for e in reversed(path):
            if isinstance(e, jax.tree_util.DictKey):
                name = str(e.key)
                break
        base = base_param_spec(name, leaf.ndim, leaf.shape, sizes)
        if base is None:
            return leaf
        pad = leaf.ndim - len(base)
        if pad < 0:
            base = base[-leaf.ndim:] if leaf.ndim else ()
            pad = 0
        full = (None,) * pad + tuple(base)
        if sizes:
            full = tuple(fit_axes(e, d, sizes) for e, d in zip(full, leaf.shape))
        try:
            return jax.lax.with_sharding_constraint(leaf, P(*full))
        except (ValueError, RuntimeError):
            return leaf

    return jax.tree_util.tree_map_with_path(constrain, tree)
