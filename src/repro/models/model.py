"""Family dispatcher: one API over all 10 architectures.

    init_params(key, cfg, max_seq)      parameter pytree
    forward(params, inputs, cfg, ...)   (logits, aux_loss)
    prefill(params, inputs, cfg, ...)   (logits, cache, aux)
    decode_step(params, token, cache, cfg) (logits, cache)
    init_cache(cfg, batch, max_seq)     decode cache/state
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import encdec, hybrid, ssm, transformer


def _mod(cfg):
    return {
        "dense": transformer, "moe": transformer, "vlm": transformer,
        "ssm": ssm, "hybrid": hybrid, "encdec": encdec,
    }[cfg.family]


def init_params(key, cfg, max_seq: int = 4096):
    return _mod(cfg).init_params(key, cfg, max_seq=max_seq)


def forward(params, inputs, cfg, positions=None, **kw):
    return _mod(cfg).forward(params, inputs, cfg, positions=positions, **kw)


def prefill(params, inputs, cfg, max_seq=None, positions=None, **kw):
    return _mod(cfg).prefill(params, inputs, cfg, max_seq=max_seq,
                             positions=positions, **kw)


def decode_step(params, token, cache, cfg, positions=None):
    return _mod(cfg).decode_step(params, token, cache, cfg, positions=positions)


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    return _mod(cfg).init_cache(cfg, batch, max_seq, dtype)


def param_count(params) -> int:
    import jax
    return sum(x.size for x in jax.tree.leaves(params))
