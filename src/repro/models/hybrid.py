"""Hybrid family (zamba2-1.2b): Mamba2 (SSD) backbone with ONE shared
attention+MLP block applied every ``attn_every`` layers (weights reused across
applications — Zamba2's parameter sharing; each application keeps its own KV
cache)."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.sharding import shard, shard_params


def _mamba_layer_params(key, cfg):
    return {"mixer": L.mamba2_params(key, cfg), "ln": jnp.zeros((cfg.d_model,))}


def _shared_attn_params(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"attn": L.attn_proj_params(k1, cfg),
            "mlp": L.mlp_params(k2, cfg.d_model, cfg.d_ff),
            "ln1": jnp.zeros((cfg.d_model,)),
            "ln2": jnp.zeros((cfg.d_model,))}


def _groups(cfg):
    """(n_groups, tail): n_groups full groups of attn_every mamba layers, each
    followed by the shared block; `tail` trailing mamba layers."""
    g = cfg.n_layers // cfg.attn_every
    return g, cfg.n_layers - g * cfg.attn_every


def init_params(key, cfg, max_seq: int = 0):
    ke, km, ka = jax.random.split(key, 3)
    keys = jax.random.split(km, cfg.n_layers)
    stack = jax.vmap(lambda k: _mamba_layer_params(k, cfg))(keys)
    return {
        "embed": L.embed_params(ke, cfg),
        "blocks": [stack],
        "shared_attn": _shared_attn_params(ka, cfg),
        "final_norm": jnp.zeros((cfg.d_model,)),
    }


def _mamba_scan(x, stack, cfg, states=None):
    """Scan mamba layers; returns (x, states_out)."""
    def body(x, inp):
        if states is None:
            p = shard_params(inp)
            x = shard(x, "batch", "seq", "actd")  # §Perf F2
            fn = lambda xc, pp: xc + L.mamba2_mixer(
                L.rms_norm(xc, pp["ln"], cfg.norm_eps), pp["mixer"], cfg)[0]
            if cfg.remat:
                fn = jax.checkpoint(fn)
            return fn(x, p), None
        p, conv, ssm = inp
        h = L.rms_norm(x, p["ln"], cfg.norm_eps)
        y, st = L.mamba2_mixer(h, p["mixer"], cfg,
                               state={"conv": conv, "ssm": ssm})
        return x + y, (st["conv"], st["ssm"])

    xs = stack if states is None else (stack, states["conv"], states["ssm"])
    return jax.lax.scan(body, x, xs)


def _shared_block(x, p, cfg, pos, cache=None, slot=None, pos_scalar=None):
    """One application of the shared attention block. With cache: decode."""
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = L.qkv(h, p["attn"], cfg)
    q = L.apply_rope(q, pos, cfg.rope_theta)
    k = L.apply_rope(k, pos, cfg.rope_theta)
    if cache is None:
        o = L.flash_attention(q, k, v, causal=True)
        new_cache = (k, v)
    else:
        kc, vc = cache
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), slot, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), slot, 1)
        o = L.decode_attention(q[:, 0], kc, vc, pos_scalar + 1)[:, None]
        new_cache = (kc, vc)
    x = x + L.attn_out(o, p["attn"], x.dtype)
    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + L.mlp(h2, p["mlp"], cfg.act).astype(x.dtype)
    return x, new_cache


def _split_groups(stack, cfg):
    g, tail = _groups(cfg)
    head = jax.tree.map(lambda a: a[: g * cfg.attn_every].reshape(
        (g, cfg.attn_every) + a.shape[1:]), stack)
    rest = jax.tree.map(lambda a: a[g * cfg.attn_every:], stack)
    return g, head, rest


def forward(params, tokens, cfg, positions=None, return_kv: bool = False):
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = L.embed(tokens, params["embed"], dtype)
    B, S = tokens.shape
    pos = jnp.arange(S)[None, :].repeat(B, 0) if positions is None else positions
    g, head, rest = _split_groups(params["blocks"][0], cfg)
    kvs = []
    for gi in range(g):
        grp = jax.tree.map(lambda a: a[gi], head)
        x, _ = _mamba_scan(x, grp, cfg)
        x, kv = _shared_block(x, params["shared_attn"], cfg, pos)
        kvs.append(kv)
    x, _ = _mamba_scan(x, rest, cfg)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(x, params["embed"], cfg)
    if return_kv:
        return logits, jnp.float32(0), kvs
    return logits, jnp.float32(0)


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    di = s.n_heads * s.head_dim
    Lyr = cfg.n_layers
    g, _ = _groups(cfg)
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "conv": jnp.zeros((Lyr, batch, s.d_conv - 1, di + 2 * s.d_state), dtype),
        "ssm": jnp.zeros((Lyr, batch, s.n_heads, s.head_dim, s.d_state), jnp.float32),
        "attn_k": jnp.zeros((g, batch, max_seq, kv, dh), dtype),
        "attn_v": jnp.zeros((g, batch, max_seq, kv, dh), dtype),
        "len": jnp.int32(0),
    }


def decode_step(params, token, cache, cfg, positions=None):
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = L.embed(token[:, None], params["embed"], dtype)
    B = x.shape[0]
    pos_scalar = cache["len"]
    pos = jnp.full((B, 1), pos_scalar, jnp.int32)
    g, head, rest = _split_groups(params["blocks"][0], cfg)
    n_h = g * cfg.attn_every
    conv_h = cache["conv"][:n_h].reshape((g, cfg.attn_every) + cache["conv"].shape[1:])
    ssm_h = cache["ssm"][:n_h].reshape((g, cfg.attn_every) + cache["ssm"].shape[1:])
    convs, ssms, aks, avs = [], [], [], []
    for gi in range(g):
        grp = jax.tree.map(lambda a: a[gi], head)
        x, (cv, sm) = _mamba_scan(x, grp, cfg,
                                  states={"conv": conv_h[gi], "ssm": ssm_h[gi]})
        x, (ak, av) = _shared_block(
            x, params["shared_attn"], cfg, pos,
            cache=(cache["attn_k"][gi], cache["attn_v"][gi]),
            slot=pos_scalar, pos_scalar=pos_scalar)
        convs.append(cv)
        ssms.append(sm)
        aks.append(ak)
        avs.append(av)
    x, (cv_t, sm_t) = _mamba_scan(x, rest, cfg,
                                  states={"conv": cache["conv"][n_h:],
                                          "ssm": cache["ssm"][n_h:]})
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(x, params["embed"], cfg)[:, 0]
    new_cache = {
        "conv": jnp.concatenate([jnp.stack(convs).reshape((-1,) + cv_t.shape[1:]), cv_t]),
        "ssm": jnp.concatenate([jnp.stack(ssms).reshape((-1,) + sm_t.shape[1:]), sm_t]),
        "attn_k": jnp.stack(aks), "attn_v": jnp.stack(avs),
        "len": pos_scalar + 1,
    }
    return logits, new_cache


def prefill(params, tokens, cfg, max_seq=None, positions=None):
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = L.embed(tokens, params["embed"], dtype)
    B, S = tokens.shape
    max_seq = max_seq or S
    pos = jnp.arange(S)[None, :].repeat(B, 0)
    g, head, rest = _split_groups(params["blocks"][0], cfg)
    cache = init_cache(cfg, B, max_seq, dtype)
    convs, ssms = [], []
    ak = cache["attn_k"]
    av = cache["attn_v"]
    for gi in range(g):
        grp = jax.tree.map(lambda a: a[gi], head)
        x, (cv, sm) = _mamba_scan(x, grp, cfg, states={
            "conv": jnp.zeros_like(cache["conv"][:cfg.attn_every]),
            "ssm": jnp.zeros_like(cache["ssm"][:cfg.attn_every])})
        x, (k, v) = _shared_block(x, params["shared_attn"], cfg, pos)
        ak = ak.at[gi, :, :S].set(k.astype(dtype))
        av = av.at[gi, :, :S].set(v.astype(dtype))
        convs.append(cv)
        ssms.append(sm)
    x, (cv_t, sm_t) = _mamba_scan(x, rest, cfg, states={
        "conv": jnp.zeros_like(cache["conv"][g * cfg.attn_every:]),
        "ssm": jnp.zeros_like(cache["ssm"][g * cfg.attn_every:])})
    convs.append(cv_t)
    ssms.append(sm_t)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(x, params["embed"], cfg)
    cache.update(
        conv=jnp.concatenate([c.reshape((-1,) + c.shape[-3:]) for c in convs]),
        ssm=jnp.concatenate([s.reshape((-1,) + s.shape[-4:]) for s in ssms]),
        attn_k=ak, attn_v=av, len=jnp.int32(S))
    return logits, cache, jnp.float32(0)
