"""Model building blocks: norms, RoPE/M-RoPE, flash-style attention, MLP,
MoE (mailbox-dispatch), Mamba1 (S6) and Mamba2 (SSD) mixers.

All blocks are pure functions over explicit param pytrees; layer stacking and
scan live in the per-family model files. Sharding is steered with logical-axis
constraints from repro.models.sharding.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.sharding import shard

# ---------------------------------------------------------------- norms

def rms_norm(x, scale, eps: float = 1e-6):
    # full f32 upcast: measured BETTER than bf16-elementwise scaling (the
    # f32 chain fuses into one kernel; §Perf L2 refuted — see EXPERIMENTS.md)
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


# ---------------------------------------------------------------- RoPE

def rope_freqs(dh: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, dh, 2, dtype=np.float32) / dh))


def apply_rope(x, pos, theta: float):
    """x: (..., S, H, dh); pos: broadcastable to (..., S)."""
    dh = x.shape[-1]
    inv = jnp.asarray(rope_freqs(dh, theta))
    ang = pos[..., None].astype(jnp.float32) * inv          # (..., S, dh/2)
    ang = ang[..., None, :]                                  # add head dim
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, pos3, theta: float, sections=(0.25, 0.375, 0.375)):
    """Qwen2-VL M-RoPE: rotary frequency dims split into (t, h, w) sections,
    each rotated by its own position stream. pos3: (3, ..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    cuts = np.cumsum([int(half * s) for s in sections])[:-1]
    inv = jnp.asarray(rope_freqs(dh, theta))                 # (half,)
    angs = pos3[..., None].astype(jnp.float32) * inv         # (3, ..., S, half)
    pieces = jnp.split(angs, cuts, axis=-1)
    ang = jnp.concatenate([pieces[i][i] for i in range(3)], axis=-1)  # (..., S, half)
    ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention

def _pick_block(s: int, pref: int) -> int:
    b = min(pref, s)
    while s % b:
        b -= 1
    return b


def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    q_offset=0, q_block: int = 512, kv_block: int = 1024,
                    use_kernel: Optional[bool] = None):
    """Blockwise streaming attention (online softmax) — O(S) memory.

    q: (B, Sq, H, dh); k, v: (B, Sk, KV, dh) with H % KV == 0 (GQA).
    ``q_offset`` is the absolute position of q[0] (prefill continuation).
    ``window``: sliding-window size (keys with q_pos - k_pos >= window masked).

    On TPU this dispatches to the fused Pallas kernel
    (repro.kernels.flash_attention) — the XLA-level loop below streams score
    tiles through HBM, which the dry-run roofline shows is the dominant
    memory term for dense-attention training cells.
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu" and isinstance(q_offset, int)
    if use_kernel:
        from repro.kernels.flash_attention import flash_attention_pallas
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            interpret=jax.default_backend() != "tpu")
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    g = H // KV
    scale = 1.0 / math.sqrt(dh)
    qb = _pick_block(Sq, q_block)
    kb = _pick_block(Sk, kv_block)
    nq, nk = Sq // qb, Sk // kb

    # K/V stay in the compute dtype (bf16) in HBM; the MXU contracts
    # bf16×bf16 -> f32 natively (preferred_element_type), halving attention
    # HBM traffic and K/V collective bytes (§Perf L1)
    qr = q.reshape(B, nq, qb, KV, g, dh)
    kr = k.reshape(B, nk, kb, KV, dh)
    vr = v.reshape(B, nk, kb, KV, dh)

    def q_step(_, qi):
        qblk = qr[:, qi]                                     # (B, qb, KV, g, dh)
        qpos = q_offset + qi * qb + jnp.arange(qb)

        # checkpoint: flash-bwd semantics — recompute scores/masks per block
        # in the backward instead of stashing (nq, nk, B, ...) residuals
        @jax.checkpoint
        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk = kr[:, ki], vr[:, ki]
            kpos = ki * kb + jnp.arange(kb)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((qb, kb), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows (m_new = -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = corr * l + jnp.sum(p, axis=-1)
            acc_new = corr[..., None] * acc + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, g, qb), -jnp.inf)
        l0 = jnp.zeros((B, KV, g, qb))
        a0 = jnp.zeros((B, KV, g, qb, dh))
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.transpose(0, 3, 1, 2, 4).reshape(B, qb, H, dh)  # (B,qb,H,dh)

    _, blocks = jax.lax.scan(q_step, None, jnp.arange(nq))   # (nq, B, qb, H, dh)
    out = blocks.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, dh)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: Optional[int] = None):
    """Single-token attention against a KV cache.

    q: (B, H, dh); caches: (B, S, KV, dh); cache_len: scalar — #valid entries
    (the new token's k/v must already be written at cache_len - 1).
    """
    B, H, dh = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    g = H // KV
    scale = 1.0 / math.sqrt(dh)
    qr = (q.reshape(B, KV, g, dh).astype(jnp.float32)) * scale
    s = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache.astype(jnp.float32))
    kpos = jnp.arange(S)
    mask = kpos < cache_len
    if window is not None:
        mask &= kpos >= (cache_len - window)
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, dh).astype(q.dtype)


# ---------------------------------------------------------------- attention block

def attn_proj_params(key, cfg, cross: bool = False):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d, h, dh)) * std,
        "wk": jax.random.normal(k2, (d, kv, dh)) * std,
        "wv": jax.random.normal(k3, (d, kv, dh)) * std,
        "wo": jax.random.normal(k4, (h, dh, d)) * (h * dh) ** -0.5,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh))
        p["bk"] = jnp.zeros((kv, dh))
        p["bv"] = jnp.zeros((kv, dh))
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,))
        p["k_norm"] = jnp.zeros((dh,))
    return p


def qkv(x, p, cfg):
    from repro.models.sharding import _state
    tp_sz = getattr(_state, "sizes", {}).get("model", 1)
    n_heads = p["wq"].shape[1]
    fold = (cfg.attn_batch_fold and tp_sz > 1 and n_heads % tp_sz != 0
            and x.shape[1] > 1)
    if fold:
        # heads < TP (gemma3 h=8, whisper h=12): batch-fold the attention
        # block's INPUT over ('pod','data','model') so projections +
        # attention run data-parallel on all chips instead of replicated
        # across the model axis (§Perf W2)
        x = shard(x, "batch_tp", None, None)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if not fold:
        q = shard(q, "batch", "seq", "tp", None)
        k = shard(k, "batch", "seq", "tp", None)
    return q, k, v


def attn_out(o, p, x_dtype):
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    return shard(y, "batch", "seq", None).astype(x_dtype)


# ---------------------------------------------------------------- MLP

def mlp_params(key, d: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": jax.random.normal(k1, (d, d_ff)) * d ** -0.5,
        "w_up": jax.random.normal(k2, (d, d_ff)) * d ** -0.5,
        "w_down": jax.random.normal(k3, (d_ff, d)) * d_ff ** -0.5,
    }


def mlp(x, p, act: str = "silu"):
    fn = jax.nn.silu if act == "silu" else jax.nn.gelu
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    h = shard(fn(g) * u, "batch", "seq", "tp")
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
    return shard(y, "batch", "seq", None)


# ---------------------------------------------------------------- MoE

def moe_params(key, cfg):
    e = cfg.moe
    d = cfg.d_model
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    E = e.n_experts
    p = {
        "router": jax.random.normal(k1, (d, E)) * d ** -0.5,
        "we_gate": jax.random.normal(k2, (E, d, e.d_expert)) * d ** -0.5,
        "we_up": jax.random.normal(k3, (E, d, e.d_expert)) * d ** -0.5,
        "we_down": jax.random.normal(k4, (E, e.d_expert, d)) * e.d_expert ** -0.5,
    }
    if e.n_shared:
        p["shared"] = mlp_params(k5, d, e.d_expert * e.n_shared)
    return p


def _positions_within_expert(flat_e, E):
    """Rank of each (token,k) entry within its expert — the mailbox slot
    assignment (same construction as GoFS's _cumcount, in jnp)."""
    Nk = flat_e.shape[0]
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))
    rank_sorted = jnp.arange(Nk) - starts[sorted_e]
    pos = jnp.zeros(Nk, jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    return pos


def moe_block(x, p, cfg, capacity: Optional[int] = None):
    """Top-k routed experts with capacity-bounded mailbox dispatch.

    x: (B, S, d) -> (y, aux_loss). Dispatch is the sorted-scatter version of
    the Gopher mailbox: tokens are messages, experts are partitions, capacity
    is mailbox_cap, overflow drops (standard MoE token dropping).

    Under an active mesh this routes through the shard_map expert-parallel
    mailbox (_moe_block_ep): tokens never leave their data shard, each
    model-rank serves its resident experts, one psum combines — the global
    argsort formulation costs ~3.4 TB/dev of collectives at 256 chips
    (EXPERIMENTS.md §Perf iteration M1).
    """
    from repro.models.sharding import active_mesh
    mesh = active_mesh()
    if mesh is not None and "model" in mesh.axis_names \
            and cfg.moe.n_experts % mesh.shape["model"] == 0:
        return _moe_block_ep(x, p, cfg, mesh, capacity)
    e = cfg.moe
    B, S, d = x.shape
    N = B * S
    E, K = e.n_experts, e.top_k
    # capacity: the usual N*K/E * factor, floored so tiny token counts
    # (decode steps, smoke tests) never drop — keeps decode == forward parity
    C = capacity or max(int(N * K / E * e.capacity_factor), 1, min(N, 32))
    xf = x.reshape(N, d)
    logits = jnp.einsum("nd,de->ne", xf, p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, K)               # (N, K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    flat_e = gate_idx.reshape(-1)
    flat_w = gate_w.reshape(-1)
    tok = jnp.repeat(jnp.arange(N), K)
    pos = _positions_within_expert(flat_e, E)
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + pos, E * C)          # OOB -> dropped

    buf = jnp.zeros((E * C, d), x.dtype).at[slot].set(xf[tok], mode="drop")
    buf = shard(buf.reshape(E, C, d), "tp", None, None)
    # expert FFN (E sharded over tp => expert parallelism)
    fn = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    g = jnp.einsum("ecd,edf->ecf", buf, p["we_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["we_up"].astype(x.dtype))
    h = fn(g) * u
    yb = jnp.einsum("ecf,efd->ecd", h, p["we_down"].astype(x.dtype)).reshape(E * C, d)

    gathered = yb[jnp.where(keep, slot, 0)] * (keep * flat_w)[:, None].astype(x.dtype)
    y = jnp.zeros((N, d), x.dtype).at[tok].add(gathered)
    if e.n_shared:
        y = y + mlp(xf[None], p["shared"], cfg.act)[0]
    # switch-style load-balance aux loss
    frac_tokens = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], E), axis=0)
    frac_prob = jnp.mean(probs, axis=0)
    aux = jnp.sum(frac_tokens * frac_prob) * E * e.aux_loss_coef
    return shard(y.reshape(B, S, d), "batch", "seq", None), aux


def _moe_block_ep(x, p, cfg, mesh, capacity: Optional[int] = None):
    """Expert-parallel mailbox dispatch under shard_map (§Perf M1).

    Token activations are replicated across 'model' (TP) at block entry, so
    every model-rank already holds the tokens — it routes them to its OWN
    resident experts locally (zero dispatch communication, the degenerate
    all_to_all), runs the expert FFNs, and contributes a partial combine that
    a single psum over 'model' finishes. This is the Gopher mailbox with the
    happy property that the topology makes sends local.
    """
    from jax.sharding import PartitionSpec as P
    from repro.models.sharding import resolve

    e = cfg.moe
    Bb, Sb, d = x.shape
    E, K = e.n_experts, e.top_k
    tp = mesh.shape["model"]
    E_loc = E // tp
    batch_spec = resolve("batch")[0]
    x_spec = P(batch_spec, None, None)
    ew_spec = P("model", None, None)
    fn = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    shared_p = p.get("shared")

    def block(xb, router, wg, wu, wd):
        B_, S_, _ = xb.shape
        N = B_ * S_
        C = capacity or max(int(N * K / E * e.capacity_factor), 1, min(N, 32))
        xf = xb.reshape(N, d)
        logits = jnp.einsum("nd,de->ne", xf, router.astype(xb.dtype)
                            ).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, gate_idx = jax.lax.top_k(probs, K)
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

        flat_e = gate_idx.reshape(-1)
        flat_w = gate_w.reshape(-1)
        tok = jnp.repeat(jnp.arange(N), K)
        pos = _positions_within_expert(flat_e, E)
        my_lo = jax.lax.axis_index("model") * E_loc
        local_e = flat_e - my_lo
        mine = (local_e >= 0) & (local_e < E_loc) & (pos < C)
        slot = jnp.where(mine, local_e * C + pos, E_loc * C)
        buf = jnp.zeros((E_loc * C, d), xb.dtype).at[slot].set(
            xf[tok], mode="drop").reshape(E_loc, C, d)
        g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(xb.dtype))
        u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(xb.dtype))
        yb = jnp.einsum("ecf,efd->ecd", fn(g) * u, wd.astype(xb.dtype)
                        ).reshape(E_loc * C, d)
        gathered = yb[jnp.where(mine, slot, 0)] * \
            (mine * flat_w)[:, None].astype(xb.dtype)
        y = jnp.zeros((N, d), xb.dtype).at[tok].add(gathered)
        y = jax.lax.psum(y, "model")
        frac_tokens = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], E), axis=0)
        frac_prob = jnp.mean(probs, axis=0)
        aux = jnp.sum(frac_tokens * frac_prob) * E * e.aux_loss_coef
        baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        if baxes:
            n_sh = 1
            for a in baxes:
                n_sh *= mesh.shape[a]
            aux = jax.lax.psum(aux, baxes) / n_sh
        return y.reshape(B_, S_, d), aux

    from repro.core import compat
    y, aux = compat.shard_map(
        block, mesh=mesh,
        in_specs=(x_spec, P(None, None), ew_spec, ew_spec, ew_spec),
        out_specs=(x_spec, P()))(
        x, p["router"], p["we_gate"], p["we_up"], p["we_down"])
    if e.n_shared:
        y = y + mlp(x.reshape(-1, d)[None], shared_p, cfg.act)[0].reshape(x.shape)
    return shard(y, "batch", "seq", None), aux


# ---------------------------------------------------------------- Mamba1 (S6)

def mamba1_params(key, cfg):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    ks = jax.random.split(key, 6)
    dt_rank = max(d // 16, 1)
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di)) * d ** -0.5,
        "conv_w": jax.random.normal(ks[1], (s.d_conv, di)) * s.d_conv ** -0.5,
        "conv_b": jnp.zeros((di,)),
        "x_proj": jax.random.normal(ks[2], (di, dt_rank + 2 * s.d_state)) * di ** -0.5,
        "dt_proj_w": jax.random.normal(ks[3], (dt_rank, di)) * dt_rank ** -0.5,
        "dt_proj_b": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (di,)) *
                    (math.log(0.1) - math.log(0.001)) + math.log(0.001)))),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,)),
        "out_proj": jax.random.normal(ks[5], (di, d)) * di ** -0.5,
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv1d. x: (B, L, C); w: (K, C). state: (B, K-1, C)
    carries context across calls (decode). Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    return y + b.astype(x.dtype), xp[:, -(K - 1):] if K > 1 else state


def _ssm_chunk_scan(a, b, h0):
    """Within-chunk linear recurrence h_t = a_t h_{t-1} + b_t via associative
    scan. a, b: (B, Q, D, N); h0: (B, D, N). Returns (h_seq (B,Q,D,N), h_last)."""
    def comb(x, y):
        return (x[0] * y[0], y[0] * x[1] + y[1])
    A_cum, b_cum = jax.lax.associative_scan(comb, (a, b), axis=1)
    h = A_cum * h0[:, None] + b_cum
    return h, h[:, -1]


def mamba1_mixer(x, p, cfg, state=None, chunk: Optional[int] = None):
    """Selective SSM (S6). x: (B, L, d). state: None (train/prefill) or
    dict(conv, ssm) for stepwise decode. Returns (y, new_state)."""
    s = cfg.ssm
    B, L, d = x.shape
    di = s.expand * d
    N = s.d_state
    xz = jnp.einsum("bld,de->ble", x, p["in_proj"].astype(x.dtype))
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard(xin, "batch", "seq", "tp")
    conv_state = state["conv"] if state is not None else None
    xc, conv_state = _causal_conv(xin, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)

    dt_rank = p["dt_proj_w"].shape[0]
    proj = jnp.einsum("ble,ef->blf", xc, p["x_proj"].astype(x.dtype))
    dt, Bs, Cs = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("blr,re->ble", dt, p["dt_proj_w"].astype(x.dtype))
        + p["dt_proj_b"].astype(x.dtype))                       # (B, L, di)
    A = -jnp.exp(p["A_log"]).astype(jnp.float32)                # (di, N)

    # sequence-length tensors stay in the compute dtype (bf16 on TPU); the
    # f32 upcast happens per-chunk inside the loop (§Perf F3)
    deltaf, xcf = delta, xc
    Bf, Cf = Bs, Cs

    h_prev = (state["ssm"] if state is not None
              else jnp.zeros((B, di, N), jnp.float32))
    if L == 1:  # decode fast path: one recurrence step, no scan
        da = jnp.exp(deltaf[:, 0, :, None] * A)                 # (B, di, N)
        db = (deltaf[:, 0] * xcf[:, 0])[..., None] * Bf[:, 0, :, None].transpose(0, 2, 1)
        h = da * h_prev + db
        y = jnp.einsum("bdn,bn->bd", h, Cf[:, 0])[:, None]
        h_last = h
    else:
        Q = chunk or s.chunk
        Q = _pick_block(L, Q)
        nc = L // Q
        # expand exp(δ⊗A) INSIDE the chunk loop: working set per step is
        # (B, Q, di, N) instead of (B, L, di, N) — nc× less HBM traffic and
        # peak temp (EXPERIMENTS.md §Perf, falcon-mamba iteration F1)
        d_cs = deltaf.reshape(B, nc, Q, di).transpose(1, 0, 2, 3)
        bx_cs = (deltaf * xcf).reshape(B, nc, Q, di).transpose(1, 0, 2, 3)
        B_cs = Bf.reshape(B, nc, Q, N).transpose(1, 0, 2, 3)
        C_cs = Cf.reshape(B, nc, Q, N).transpose(1, 0, 2, 3)

        @jax.checkpoint
        def chunk_step(h0, inp):
            # checkpointed: bwd recomputes the (B,Q,di,N) expansion instead of
            # stashing it per chunk (§Perf F4)
            d_c, bx_c, b_c, c_c = [t.astype(jnp.float32) for t in inp]
            a_c = jnp.exp(d_c[..., None] * A)            # (B,Q,di,N) f32
            rhs = bx_c[..., None] * b_c[:, :, None, :]
            h_seq, h_last = _ssm_chunk_scan(a_c, rhs, h0)
            y_c = jnp.einsum("bqdn,bqn->bqd", h_seq, c_c)
            return h_last, y_c

        h_last, y = jax.lax.scan(chunk_step, h_prev, (d_cs, bx_cs, B_cs, C_cs))
        y = y.transpose(1, 0, 2, 3).reshape(B, L, di)
    y = (y + xcf * p["D"].astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("ble,ed->bld", y, p["out_proj"].astype(x.dtype))
    new_state = {"conv": conv_state, "ssm": h_last}
    return shard(out, "batch", "seq", None), new_state


# ---------------------------------------------------------------- Mamba2 (SSD)

def mamba2_params(key, cfg):
    s = cfg.ssm
    d = cfg.d_model
    H, Pd, N = s.n_heads, s.head_dim, s.d_state
    di = H * Pd
    ks = jax.random.split(key, 4)
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di + 2 * N + H)) * d ** -0.5,
        "conv_w": jax.random.normal(ks[1], (s.d_conv, di + 2 * N)) * s.d_conv ** -0.5,
        "conv_b": jnp.zeros((di + 2 * N,)),
        "a_log2": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((H,)),
        "D": jnp.ones((H,)),
        "norm": jnp.zeros((di,)),
        "out_proj": jax.random.normal(ks[2], (di, d)) * di ** -0.5,
    }


def mamba2_mixer(x, p, cfg, state=None, chunk: Optional[int] = None):
    """Mamba2 SSD (scalar decay per head, G=1 B/C group). x: (B, L, d)."""
    s = cfg.ssm
    B, L, d = x.shape
    H, Pd, N = s.n_heads, s.head_dim, s.d_state
    di = H * Pd
    z_xBC_dt = jnp.einsum("bld,de->ble", x, p["in_proj"].astype(x.dtype))
    z, xBC, dt = jnp.split(z_xBC_dt, [di, 2 * di + 2 * N], axis=-1)
    # xBC: (B, L, di + 2N) -> conv -> silu
    conv_state = state["conv"] if state is not None else None
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xBC = jax.nn.silu(xBC)
    xin, Bs, Cs = jnp.split(xBC, [di, di + N], axis=-1)
    xin = shard(xin, "batch", "seq", "tp")
    delta = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, L, H)
    A = -jnp.exp(p["a_log2"])                                       # (H,)

    # (B, L, ·) tensors stay in compute dtype; per-chunk f32 upcast (§Perf F3)
    Xh = xin.reshape(B, L, H, Pd)
    Bf, Cf = Bs, Cs                                                 # (B, L, N)
    da = (delta * A).astype(x.dtype)                                # (B, L, H)
    dX = Xh * delta.astype(Xh.dtype)[..., None]                     # (B, L, H, P)

    h_prev = (state["ssm"] if state is not None
              else jnp.zeros((B, H, Pd, N), jnp.float32))
    if L == 1:
        a0 = jnp.exp(da[:, 0])                                      # (B, H)
        h = a0[..., None, None] * h_prev + \
            dX[:, 0][..., None] * Bf[:, 0, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", h, Cf[:, 0])[:, None]        # (B,1,H,P)
        h_last = h
    else:
        Q = chunk or s.chunk
        Q = _pick_block(L, Q)
        nc = L // Q
        # all per-chunk tensors (incl. the (Q,Q) decay matrix) are built
        # INSIDE the chunk loop — peak working set (B,Q,Q,H) not (B,L,Q,H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        da_cs = da.reshape(B, nc, Q, H).transpose(1, 0, 2, 3)
        B_cs = Bf.reshape(B, nc, Q, N).transpose(1, 0, 2, 3)
        C_cs = Cf.reshape(B, nc, Q, N).transpose(1, 0, 2, 3)
        dX_cs = dX.reshape(B, nc, Q, H, Pd).transpose(1, 0, 2, 3, 4)

        @jax.checkpoint
        def chunk_step(h0, inp):
            da_c, b_c, c_c, dx_c = [t.astype(jnp.float32) for t in inp]
            cum = jnp.cumsum(da_c, axis=1)                          # (B,Q,H)
            seg = cum[:, :, None, :] - cum[:, None, :, :]           # (B,Q,K,H)
            decay = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
            scores = jnp.einsum("bqn,bkn->bqk", c_c, b_c)
            y_diag = jnp.einsum("bqk,bqkh,bkhp->bqhp", scores, decay, dx_c)
            decay_to_end = jnp.exp(cum[:, -1:, :] - cum)            # (B,Q,H)
            state_in = jnp.einsum("bqh,bqn,bqhp->bhpn", decay_to_end, b_c, dx_c)
            chunk_decay = jnp.exp(cum[:, -1, :])                    # (B,H)
            decay_from_start = jnp.exp(cum)
            y_inter = jnp.einsum("bqn,bhpn,bqh->bqhp", c_c, h0, decay_from_start)
            h1 = chunk_decay[..., None, None] * h0 + state_in
            return h1, y_diag + y_inter

        h_last, y = jax.lax.scan(chunk_step, h_prev,
                                 (da_cs, B_cs, C_cs, dX_cs))
        y = y.transpose(1, 0, 2, 3, 4).reshape(B, L, H, Pd)
    y = y + Xh * p["D"][None, None, :, None]
    y = y.reshape(B, L, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, p["out_proj"].astype(x.dtype))
    new_state = {"conv": conv_state, "ssm": h_last}
    return shard(out, "batch", "seq", None), new_state


# ---------------------------------------------------------------- embedding

def embed_params(key, cfg):
    k1, k2 = jax.random.split(key)
    p = {"tok": jax.random.normal(k1, (cfg.vocab, cfg.d_model)) * 0.02}
    if not cfg.tie_embeddings:
        p["unembed"] = jax.random.normal(k2, (cfg.d_model, cfg.vocab)) * cfg.d_model ** -0.5
    return p


def embed(tokens, p, dtype):
    return shard(p["tok"].astype(dtype)[tokens], "batch", "seq", None)


def unembed(x, p, cfg):
    from repro.models.sharding import _state
    w = p["unembed"] if not cfg.tie_embeddings else p["tok"].T
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    # odd vocabs (whisper 51865) can't shard over TP — shard the SEQ dim
    # instead, or the full per-device logits buffer is V·S·B_loc sized
    sizes = getattr(_state, "sizes", {})
    tp = sizes.get("model", 1)
    if tp > 1 and cfg.vocab % tp != 0 and logits.shape[1] % tp == 0 \
            and logits.shape[1] > 1:
        return shard(logits, "batch", "tp", None)
    return shard(logits, "batch", "seq", "tp")
