"""Encoder-decoder family (whisper-small): 12-layer bidirectional encoder over
precomputed frame embeddings (conv frontend STUB per the brief), 12-layer
decoder with causal self-attention + cross-attention. LayerNorm + GELU + learned
positions (whisper-style), biases on projections."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.sharding import shard, shard_params


def _enc_layer_params(key, cfg):
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {"attn": L.attn_proj_params(k1, cfg),
            "mlp": L.mlp_params(k2, d, cfg.d_ff),
            "ln1_s": jnp.ones((d,)), "ln1_b": jnp.zeros((d,)),
            "ln2_s": jnp.ones((d,)), "ln2_b": jnp.zeros((d,))}


def _dec_layer_params(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    return {"self": L.attn_proj_params(k1, cfg),
            "cross": L.attn_proj_params(k2, cfg),
            "mlp": L.mlp_params(k3, d, cfg.d_ff),
            "ln1_s": jnp.ones((d,)), "ln1_b": jnp.zeros((d,)),
            "lnx_s": jnp.ones((d,)), "lnx_b": jnp.zeros((d,)),
            "ln2_s": jnp.ones((d,)), "ln2_b": jnp.zeros((d,))}


def init_params(key, cfg, max_seq: int = 4096):
    ke, kp, kenc, kdec = jax.random.split(key, 4)
    enc_keys = jax.random.split(kenc, cfg.n_enc_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    d = cfg.d_model
    return {
        "embed": L.embed_params(ke, cfg),
        "pos_enc": jax.random.normal(kp, (cfg.enc_seq, d)) * 0.01,
        "pos_dec": jax.random.normal(kp, (max(max_seq, 8), d)) * 0.01,
        "enc_blocks": [jax.vmap(lambda k: _enc_layer_params(k, cfg))(enc_keys)],
        "blocks": [jax.vmap(lambda k: _dec_layer_params(k, cfg))(dec_keys)],
        "enc_norm_s": jnp.ones((d,)), "enc_norm_b": jnp.zeros((d,)),
        "final_norm_s": jnp.ones((d,)), "final_norm_b": jnp.zeros((d,)),
    }


def encode(params, frames, cfg):
    """frames: (B, enc_seq, d_model) precomputed frame embeddings (stub)."""
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = frames.astype(dtype) + params["pos_enc"][: frames.shape[1]].astype(dtype)
    x = shard(x, "batch", "seq", None)

    def body(x, p):
        p = shard_params(p)
        def fn(xc, pp):
            h = L.layer_norm(xc, pp["ln1_s"], pp["ln1_b"])
            q, k, v = L.qkv(h, pp["attn"], cfg)
            o = L.flash_attention(q, k, v, causal=False)
            xc = xc + L.attn_out(o, pp["attn"], xc.dtype)
            h2 = L.layer_norm(xc, pp["ln2_s"], pp["ln2_b"])
            return xc + L.mlp(h2, pp["mlp"], cfg.act).astype(xc.dtype)
        if cfg.remat:
            fn = jax.checkpoint(fn)
        return fn(x, p), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"][0])
    return L.layer_norm(x, params["enc_norm_s"], params["enc_norm_b"])


def _cross_kv(enc_out, p, cfg):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(enc_out.dtype))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(enc_out.dtype)
        v = v + p["bv"].astype(enc_out.dtype)
    return k, v


def _dec_layer(x, p, cfg, enc_out, pos_q=0, self_kv=None, slot=None, plen=None):
    """One decoder layer; train mode (self_kv None) or decode (cached)."""
    h = L.layer_norm(x, p["ln1_s"], p["ln1_b"])
    q, k, v = L.qkv(h, p["self"], cfg)
    if self_kv is None:
        o = L.flash_attention(q, k, v, causal=True)
        new_kv = (k, v)
    else:
        kc, vc = self_kv
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), slot, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), slot, 1)
        o = L.decode_attention(q[:, 0], kc, vc, plen + 1)[:, None]
        new_kv = (kc, vc)
    x = x + L.attn_out(o, p["self"], x.dtype)
    # cross attention
    hx = L.layer_norm(x, p["lnx_s"], p["lnx_b"])
    qx = jnp.einsum("bsd,dhk->bshk", hx, p["cross"]["wq"].astype(x.dtype))
    if cfg.qkv_bias:
        qx = qx + p["cross"]["bq"].astype(x.dtype)
    kx, vx = _cross_kv(enc_out, p["cross"], cfg)
    ox = L.flash_attention(qx, kx, vx, causal=False)
    x = x + L.attn_out(ox, p["cross"], x.dtype)
    h2 = L.layer_norm(x, p["ln2_s"], p["ln2_b"])
    x = x + L.mlp(h2, p["mlp"], cfg.act).astype(x.dtype)
    return x, new_kv


def forward(params, tokens, cfg, positions=None, frames=None, return_kv=False):
    """Teacher-forced decode over `tokens` attending to encoded `frames`.
    When frames is None a zero stub (B, enc_seq, d) is used."""
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    B, S = tokens.shape
    if frames is None:
        frames = jnp.zeros((B, cfg.enc_seq, cfg.d_model), dtype)
    enc_out = encode(params, frames, cfg)
    x = L.embed(tokens, params["embed"], dtype)
    x = x + params["pos_dec"][:S].astype(dtype)

    def body(x, p):
        p = shard_params(p)
        fn = lambda xc, pp: _dec_layer(xc, pp, cfg, enc_out)[0]
        if cfg.remat:
            fn = jax.checkpoint(fn)
        return fn(x, p), None

    x, _ = jax.lax.scan(body, x, params["blocks"][0])
    x = L.layer_norm(x, params["final_norm_s"], params["final_norm_b"])
    logits = L.unembed(x, params["embed"], cfg)
    if return_kv:
        return logits, jnp.float32(0), []
    return logits, jnp.float32(0)


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    Lyr = cfg.n_layers
    return {
        "k": jnp.zeros((Lyr, batch, max_seq, kv, dh), dtype),
        "v": jnp.zeros((Lyr, batch, max_seq, kv, dh), dtype),
        # cross K/V precomputed once per request
        "xk": jnp.zeros((Lyr, batch, cfg.enc_seq, kv, dh), dtype),
        "xv": jnp.zeros((Lyr, batch, cfg.enc_seq, kv, dh), dtype),
        "len": jnp.int32(0),
    }


def decode_step(params, token, cache, cfg, positions=None):
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = L.embed(token[:, None], params["embed"], dtype)
    plen = cache["len"]
    x = x + jax.lax.dynamic_slice_in_dim(params["pos_dec"], plen, 1).astype(dtype)

    def body(x, inp):
        p, kc, vc, xk, xv = inp
        h = L.layer_norm(x, p["ln1_s"], p["ln1_b"])
        q, k, v = L.qkv(h, p["self"], cfg)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), plen, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), plen, 1)
        o = L.decode_attention(q[:, 0], kc, vc, plen + 1)[:, None]
        x = x + L.attn_out(o, p["self"], x.dtype)
        hx = L.layer_norm(x, p["lnx_s"], p["lnx_b"])
        qx = jnp.einsum("bsd,dhk->bshk", hx, p["cross"]["wq"].astype(x.dtype))
        if cfg.qkv_bias:
            qx = qx + p["cross"]["bq"].astype(x.dtype)
        ox = L.decode_attention(qx[:, 0], xk, xv, jnp.int32(xk.shape[1]))[:, None]
        x = x + L.attn_out(ox, p["cross"], x.dtype)
        h2 = L.layer_norm(x, p["ln2_s"], p["ln2_b"])
        x = x + L.mlp(h2, p["mlp"], cfg.act).astype(x.dtype)
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["blocks"][0], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = L.layer_norm(x, params["final_norm_s"], params["final_norm_b"])
    logits = L.unembed(x, params["embed"], cfg)[:, 0]
    return logits, {**cache, "k": ks, "v": vs, "len": plen + 1}


def prefill(params, tokens, cfg, max_seq=None, positions=None, frames=None):
    """Encode frames + teacher-forced pass over prompt tokens, building the
    self-attention cache and the per-layer cross K/V."""
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    B, S = tokens.shape
    max_seq = max_seq or S
    if frames is None:
        frames = jnp.zeros((B, cfg.enc_seq, cfg.d_model), dtype)
    enc_out = encode(params, frames, cfg)
    x = L.embed(tokens, params["embed"], dtype)
    x = x + params["pos_dec"][:S].astype(dtype)

    def body(x, p):
        xn, (k, v) = _dec_layer(x, p, cfg, enc_out)
        xk, xv = _cross_kv(enc_out, p["cross"], cfg)
        return xn, (k, v, xk, xv)

    x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["blocks"][0])
    x = L.layer_norm(x, params["final_norm_s"], params["final_norm_b"])
    logits = L.unembed(x, params["embed"], cfg)
    cache = init_cache(cfg, B, max_seq, dtype)
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], ks.astype(dtype), 0, 2)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], vs.astype(dtype), 0, 2)
    cache["xk"] = xks.astype(dtype)
    cache["xv"] = xvs.astype(dtype)
    cache["len"] = jnp.int32(S)
    return logits, cache, jnp.float32(0)
