"""LM model zoo for the assigned architecture pool."""
from repro.models import model
from repro.models.model import (decode_step, forward, init_cache, init_params,
                                param_count, prefill)
from repro.models.sharding import clear_rules, set_rules, shard

__all__ = ["model", "init_params", "forward", "prefill", "decode_step",
           "init_cache", "param_count", "set_rules", "clear_rules", "shard"]
