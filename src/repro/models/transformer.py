"""Decoder-only transformer family: dense (llama3, qwen110b, danube, gemma3),
MoE (deepseek-moe, qwen3-moe), VLM backbone (qwen2-vl).

Layers are lax.scan-stacked to bound HLO size at 28-80 layers. gemma3's 5:1
local:global pattern is handled by splitting the stack into local/global
sub-stacks scanned per cycle (no cond branches -> cost_analysis stays honest).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.sharding import shard, shard_params


# ---------------------------------------------------------------- params

def _layer_params(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"attn": L.attn_proj_params(k1, cfg),
         "ln1": jnp.zeros((cfg.d_model,)),
         "ln2": jnp.zeros((cfg.d_model,))}
    if cfg.moe is not None:
        p["moe"] = L.moe_params(k2, cfg)
    else:
        p["mlp"] = L.mlp_params(k3, cfg.d_model, cfg.d_ff)
    return p


def _stack(key, cfg, n):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _layer_params(k, cfg))(keys)


def _plan(cfg):
    """Layer grouping: [(count, is_global)] segments. Uniform archs are one
    segment; gemma3 (5 local : 1 global) builds per-cycle segments."""
    if cfg.swa_pattern is None:
        return [(cfg.n_layers, cfg.swa_window is None)]
    loc, glob = cfg.swa_pattern
    segs = []
    n = cfg.n_layers
    while n > 0:
        take = min(loc, n)
        segs.append((take, False))
        n -= take
        if n > 0:
            g = min(glob, n)
            segs.append((g, True))
            n -= g
    return segs


def init_params(key, cfg, max_seq: int = 0):
    ke, kl = jax.random.split(key)
    params = {"embed": L.embed_params(ke, cfg),
              "final_norm": jnp.zeros((cfg.d_model,))}
    segs = _plan(cfg)
    keys = jax.random.split(kl, len(segs))
    params["blocks"] = [_stack(k, cfg, n) for k, (n, _) in zip(keys, segs)]
    return params


# ---------------------------------------------------------------- forward

def _attn_block(x, p, cfg, pos, is_global: bool, q_offset=0):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = L.qkv(h, p["attn"], cfg)
    if cfg.mrope:
        q = L.apply_mrope(q, pos, cfg.rope_theta)
        k = L.apply_mrope(k, pos, cfg.rope_theta)
    else:
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)
    win = None if is_global else cfg.swa_window
    o = L.flash_attention(q, k, v, causal=True, window=win, q_offset=q_offset)
    return x + L.attn_out(o, p["attn"], x.dtype), k, v


def _ffn_block(x, p, cfg):
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        y, aux = L.moe_block(h, p["moe"], cfg)
    else:
        y, aux = L.mlp(h, p["mlp"], cfg.act), jnp.float32(0)
    return x + y.astype(x.dtype), aux


def _one_layer(x, p, cfg, pos, is_global, q_offset=0):
    x, k, v = _attn_block(x, p, cfg, pos, is_global, q_offset)
    x, aux = _ffn_block(x, p, cfg)
    return x, aux, k, v


def forward(params, inputs, cfg, positions=None, return_kv: bool = False):
    """inputs: (B, S) int tokens, or (B, S, d) embeddings (vlm/audio stubs).
    positions: (B, S) or (3, B, S) for mrope. Returns (logits, aux_loss)
    (+ per-segment stacked K/V when return_kv — prefill cache building)."""
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.embed_inputs and inputs.ndim == 3:
        x = inputs.astype(dtype)
    else:
        x = L.embed(inputs, params["embed"], dtype)
    B, S = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.arange(S)[None, :].repeat(B, 0)
        if cfg.mrope:
            positions = jnp.broadcast_to(positions, (3, B, S))
    x = shard(x, "batch", "seq", None)

    aux_total = jnp.float32(0)
    segs = _plan(cfg)

    def seg_scan(x, stack, is_global):
        def body(carry, p):
            xc, aux = carry
            # keep the per-layer param shard INSIDE the loop, or GSPMD hoists
            # the FSDP all-gather of the whole stack (see sharding.shard_params)
            p = shard_params(p)
            # residual saved for bwd lives TP-sharded on d (ZeRO-R, §Perf F2)
            xc = shard(xc, "batch", "seq", "actd")
            fn = functools.partial(_one_layer, cfg=cfg, pos=positions,
                                   is_global=is_global)
            if cfg.remat:
                fn = jax.checkpoint(fn)
            xn, a, k, v = fn(xc, p)
            return (xn, aux + a), ((k, v) if return_kv else None)

        (x, aux), kv = jax.lax.scan(body, (x, jnp.float32(0)), stack)
        return x, aux, kv

    seg_kv = []
    for (n, is_global), stack in zip(segs, params["blocks"]):
        x, aux, kv = seg_scan(x, stack, is_global)
        aux_total = aux_total + aux
        seg_kv.append(kv)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(x, params["embed"], cfg)
    if return_kv:
        return logits, aux_total, seg_kv
    return logits, aux_total


# ---------------------------------------------------------------- serving

def cache_len_for(cfg, is_global: bool, max_seq: int) -> int:
    if is_global or cfg.swa_window is None:
        return max_seq
    return min(cfg.swa_window, max_seq)


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """Per-segment KV caches; window segments use ring buffers of window size."""
    caches = []
    for n, is_global in _plan(cfg):
        s = cache_len_for(cfg, is_global, max_seq)
        kv, dh = cfg.n_kv_heads, cfg.head_dim
        caches.append({
            "k": jnp.zeros((n, batch, s, kv, dh), dtype),
            "v": jnp.zeros((n, batch, s, kv, dh), dtype),
        })
    return {"segs": caches, "len": jnp.int32(0)}


def decode_step(params, token, cache, cfg, positions=None):
    """token: (B,) int32 (or (B, d) embedding). Returns (logits (B, V), cache)."""
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.embed_inputs and token.ndim == 2:
        x = token[:, None, :].astype(dtype)
    else:
        x = L.embed(token[:, None], params["embed"], dtype)
    B = x.shape[0]
    pos_scalar = cache["len"]
    if positions is None:
        positions = jnp.full((B, 1), pos_scalar, jnp.int32)
        if cfg.mrope:
            positions = jnp.broadcast_to(positions, (3, B, 1))

    new_segs = []
    for (n, is_global), stack, c in zip(_plan(cfg), params["blocks"], cache["segs"]):
        s_cache = c["k"].shape[2]
        slot = jnp.where(jnp.int32(s_cache) >= pos_scalar + 1,
                         pos_scalar, pos_scalar % s_cache)

        def body(x, inp):
            p, kc, vc = inp
            h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
            q, k, v = L.qkv(h, p["attn"], cfg)
            if cfg.mrope:
                q = L.apply_mrope(q, positions, cfg.rope_theta)
                k = L.apply_mrope(k, positions, cfg.rope_theta)
            else:
                q = L.apply_rope(q, positions, cfg.rope_theta)
                k = L.apply_rope(k, positions, cfg.rope_theta)
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), slot, 1)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), slot, 1)
            valid = jnp.minimum(pos_scalar + 1, s_cache)
            o = L.decode_attention(q[:, 0], kc, vc, valid,
                                   window=None)  # ring buffer already bounds window
            x = x + L.attn_out(o[:, None], p["attn"], x.dtype)
            x, _ = _ffn_block(x, p, cfg)
            return x, (kc, vc)

        x, (ks, vs) = jax.lax.scan(body, x, (stack, c["k"], c["v"]))
        new_segs.append({"k": ks, "v": vs})

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(x, params["embed"], cfg)[:, 0]
    return logits, {"segs": new_segs, "len": pos_scalar + 1}


def prefill(params, inputs, cfg, max_seq: Optional[int] = None, positions=None):
    """Full-sequence forward + decode-ready cache (ring-packed for SWA segs)."""
    logits, aux, seg_kv = forward(params, inputs, cfg, positions, return_kv=True)
    B, S = inputs.shape[0], inputs.shape[1]
    max_seq = max_seq or S
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    cache = init_cache(cfg, B, max_seq, dtype)
    for c, (k, v) in zip(cache["segs"], seg_kv):
        s_cache = c["k"].shape[2]
        if s_cache >= S:  # plain cache: positions 0..S-1 at slots 0..S-1
            c["k"] = jax.lax.dynamic_update_slice_in_dim(
                c["k"], k.astype(dtype), 0, 2)
            c["v"] = jax.lax.dynamic_update_slice_in_dim(
                c["v"], v.astype(dtype), 0, 2)
        else:  # ring: keep last s_cache positions at slot pos % s_cache
            last_pos = jnp.arange(S - s_cache, S)
            slots = last_pos % s_cache
            c["k"] = c["k"].at[:, :, slots].set(k[:, :, -s_cache:].astype(dtype))
            c["v"] = c["v"].at[:, :, slots].set(v[:, :, -s_cache:].astype(dtype))
    cache["len"] = jnp.int32(S)
    return logits, cache, aux
