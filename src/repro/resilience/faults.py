"""Gopher Shield — deterministic fault injection.

A :class:`FaultPlan` is a seeded, replayable schedule of faults fired at
NAMED SITES — host-side hook points the engine's stepped drivers, the block
patcher, and the serving loop already pass through:

    engine.superstep    once per superstep of a stepped (checkpointed or
                        traced) BSP driver, before the sweep dispatch
    exchange.route      once per mailbox routing round, before the route
                        dispatch
    blocks.patch        on entry to core.blocks.patch_host_block
    svc.apply_delta     on entry of a GraphQueryService delta-apply attempt
    svc.query           on entry of a GraphQueryService batch run attempt

Hooks are a single function call into :func:`fire`, which is a no-op unless
a plan is actively injected (``with faults.inject(plan): ...``) — the
compiled loops are NEVER touched, so bit-identity of the math and the
<2%-overhead observability budget are preserved by construction.

Determinism: a spec either names the exact visit index it fires at (``at=``)
or draws per-visit Bernoulli trials from its own ``np.random.default_rng``
stream derived from ``(plan.seed, spec index)`` — two runs of the same plan
against the same workload fire the same faults at the same visits, which is
what makes chaos scenarios assertable (recovered state must be bit-identical
to the fault-free run).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Optional

import numpy as np

SITES = ("engine.superstep", "exchange.route", "blocks.patch",
         "svc.apply_delta", "svc.query")

#: fault kind -> exception raised (straggler sleeps instead of raising)
KINDS = ("device_loss", "corrupt_block", "failed_delta", "straggler",
         "poisoned_query", "crash")


class InjectedFault(RuntimeError):
    """Base of every injected failure; carries the site and fire context."""

    def __init__(self, site: str, kind: str, visit: int, payload: dict,
                 ctx: dict):
        super().__init__(f"injected {kind} at {site} (visit {visit})")
        self.site = site
        self.kind = kind
        self.visit = visit
        self.payload = dict(payload)
        self.ctx = dict(ctx)


class DeviceLossFault(InjectedFault):
    """A device (or several: ``payload['lost']``) dropped out of the mesh."""


class BlockCorruptionFault(InjectedFault):
    """The patched graph block is corrupt/truncated and must not be trusted."""


class DeltaApplyFault(InjectedFault):
    """A delta-apply attempt failed before the new version was installed."""


class PoisonedQueryFault(InjectedFault):
    """A query batch poisoned its engine run (malformed input, OOM, ...)."""


class CrashFault(InjectedFault):
    """Generic process crash at a superstep boundary (checkpoint/replay
    scenarios that are not device loss)."""


_RAISES = {
    "device_loss": DeviceLossFault,
    "corrupt_block": BlockCorruptionFault,
    "failed_delta": DeltaApplyFault,
    "poisoned_query": PoisonedQueryFault,
    "crash": CrashFault,
}


@dataclasses.dataclass
class FaultSpec:
    """One fault to fire: WHERE (site), WHAT (kind), WHEN (at= exact visit
    index, else per-visit probability), and HOW OFTEN (times, then the spec
    disarms). ``delay_s`` is the stall for straggler faults; ``payload``
    rides on the raised exception (e.g. ``lost=1`` devices)."""
    site: str
    kind: str
    at: Optional[int] = None
    prob: float = 0.0
    times: int = 1
    delay_s: float = 0.0
    payload: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        assert self.site in SITES, f"unknown fault site {self.site!r}"
        assert self.kind in KINDS, f"unknown fault kind {self.kind!r}"


class FaultPlan:
    """A seeded schedule of :class:`FaultSpec`s plus the record of what
    actually fired (``plan.fired``). Replayable: visit counters reset with
    :meth:`reset`, so the same plan object drives the reference and the
    chaos run of a scenario."""

    def __init__(self, specs, seed: int = 0):
        self.specs = list(specs)
        self.seed = int(seed)
        self.reset()

    def reset(self) -> None:
        self._visits = {s: 0 for s in SITES}
        self._remaining = [s.times for s in self.specs]
        self._rngs = [np.random.default_rng((self.seed, i))
                      for i in range(len(self.specs))]
        self.fired: list = []

    def visits(self, site: str) -> int:
        return self._visits[site]

    def fire(self, site: str, **ctx) -> None:
        """One visit to `site`: decide per armed spec whether it fires.
        Stragglers sleep; every other kind raises its typed fault (the
        FIRST matching spec wins the raise; its shot is spent either way)."""
        visit = self._visits[site]
        self._visits[site] = visit + 1
        for i, spec in enumerate(self.specs):
            if spec.site != site or self._remaining[i] <= 0:
                continue
            if spec.at is not None:
                hit = visit == spec.at
            else:
                hit = (spec.prob > 0.0
                       and float(self._rngs[i].random()) < spec.prob)
            if not hit:
                continue
            self._remaining[i] -= 1
            self.fired.append(dict(site=site, kind=spec.kind, visit=visit,
                                   payload=dict(spec.payload),
                                   ctx={k: v for k, v in ctx.items()
                                        if isinstance(v, (int, float, str,
                                                          bool))}))
            if spec.kind == "straggler":
                time.sleep(spec.delay_s)
                continue
            raise _RAISES[spec.kind](site, spec.kind, visit, spec.payload,
                                     ctx)

    def record(self) -> list:
        """What fired so far, JSON-serializable."""
        return list(self.fired)


# ---------------------------------------------------------------- injection
_local = threading.local()


def active() -> Optional[FaultPlan]:
    stack = getattr(_local, "stack", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def inject(plan: Optional[FaultPlan]):
    """Arm `plan` for the dynamic extent of the block. Nestable (innermost
    plan wins); ``inject(None)`` is a no-op pass-through so scenario drivers
    can take an optional plan."""
    if plan is None:
        yield None
        return
    stack = getattr(_local, "stack", None)
    if stack is None:
        stack = _local.stack = []
    stack.append(plan)
    try:
        yield plan
    finally:
        stack.pop()


def fire(site: str, **ctx) -> None:
    """The hook entry compiled into NOTHING when no plan is armed: sites
    call this unconditionally; it returns immediately unless a FaultPlan is
    active on this thread."""
    plan = active()
    if plan is not None:
        plan.fire(site, **ctx)
