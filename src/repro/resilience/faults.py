"""Gopher Shield — deterministic fault injection.

A :class:`FaultPlan` is a seeded, replayable schedule of faults fired at
NAMED SITES — host-side hook points the engine's stepped drivers, the block
patcher, and the serving loop already pass through:

    engine.superstep    once per superstep of a stepped (checkpointed or
                        traced) BSP driver, before the sweep dispatch
    exchange.route      once per mailbox routing round, before the route
                        dispatch
    blocks.patch        on entry to core.blocks.patch_host_block
    svc.apply_delta     on entry of a GraphQueryService delta-apply attempt
    svc.query           on entry of a GraphQueryService batch run attempt

Hooks are a single function call into :func:`fire`, which is a no-op unless
a plan is actively injected (``with faults.inject(plan): ...``) — the
compiled loops are NEVER touched, so bit-identity of the math and the
<2%-overhead observability budget are preserved by construction.

Determinism: a spec either names the exact visit index it fires at (``at=``)
or draws per-visit Bernoulli trials from its own ``np.random.default_rng``
stream derived from ``(plan.seed, spec index)`` — two runs of the same plan
against the same workload fire the same faults at the same visits, which is
what makes chaos scenarios assertable (recovered state must be bit-identical
to the fault-free run).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Optional

import numpy as np

SITES = ("engine.superstep", "exchange.route", "blocks.patch",
         "svc.apply_delta", "svc.query")

#: fault kind -> exception raised (straggler sleeps instead of raising)
KINDS = ("device_loss", "corrupt_block", "failed_delta", "straggler",
         "poisoned_query", "crash")


class InjectedFault(RuntimeError):
    """Base of every injected failure; carries the site and fire context."""

    def __init__(self, site: str, kind: str, visit: int, payload: dict,
                 ctx: dict):
        super().__init__(f"injected {kind} at {site} (visit {visit})")
        self.site = site
        self.kind = kind
        self.visit = visit
        self.payload = dict(payload)
        self.ctx = dict(ctx)


class DeviceLossFault(InjectedFault):
    """A device (or several: ``payload['lost']``) dropped out of the mesh."""


class BlockCorruptionFault(InjectedFault):
    """The patched graph block is corrupt/truncated and must not be trusted."""


class DeltaApplyFault(InjectedFault):
    """A delta-apply attempt failed before the new version was installed."""


class PoisonedQueryFault(InjectedFault):
    """A query batch poisoned its engine run (malformed input, OOM, ...)."""


class CrashFault(InjectedFault):
    """Generic process crash at a superstep boundary (checkpoint/replay
    scenarios that are not device loss)."""


_RAISES = {
    "device_loss": DeviceLossFault,
    "corrupt_block": BlockCorruptionFault,
    "failed_delta": DeltaApplyFault,
    "poisoned_query": PoisonedQueryFault,
    "crash": CrashFault,
}


def _straggler_stalls(spec: "FaultSpec", ctx: dict) -> list:
    """Sleep out one straggler firing and return its [(part, seconds)]
    attribution. Targeted specs (payload ``part``/``device``) stall
    ``delay_s`` per live vertex of each targeted partition — read from the
    ``part_verts`` tuple in the fire context (the engine's stepped drivers
    pass it; ``num_devices`` maps a device target onto its contiguous
    partition rows, the same P//D tiling failover uses). Untargeted specs,
    or sites that don't carry ``part_verts``, keep the flat legacy sleep
    attributed to no partition (part -1)."""
    pv = ctx.get("part_verts")
    t_part = spec.payload.get("part")
    t_dev = spec.payload.get("device")
    if pv is None or (t_part is None and t_dev is None):
        time.sleep(spec.delay_s)
        return [(-1, float(spec.delay_s))]
    P = len(pv)
    if t_part is not None:
        parts = [int(t_part) % P]
    else:
        D = max(int(ctx.get("num_devices", 1)), 1)
        per = max(P // D, 1)
        d = int(t_dev) % D
        parts = list(range(d * per, min((d + 1) * per, P)))
    stalls = [(p, float(spec.delay_s) * float(pv[p])) for p in parts]
    time.sleep(sum(s for _, s in stalls))
    return stalls


@dataclasses.dataclass
class FaultSpec:
    """One fault to fire: WHERE (site), WHAT (kind), WHEN (at= exact visit
    index, else per-visit probability), and HOW OFTEN (times, then the spec
    disarms). ``delay_s`` is the stall for straggler faults; ``payload``
    rides on the raised exception (e.g. ``lost=1`` devices).

    Straggler payloads may target ``{"part": p}`` (one partition) or
    ``{"device": d}`` (that device's contiguous partition rows). A targeted
    straggler's stall is LOAD-PROPORTIONAL — ``delay_s`` seconds PER LIVE
    VERTEX on the targeted partitions (read from the ``part_verts`` fire
    context) — so migrating sub-graphs off the victim physically shrinks
    the injected delay, the way a real per-device slowdown would respond.
    An untargeted straggler keeps the legacy flat ``delay_s`` sleep."""
    site: str
    kind: str
    at: Optional[int] = None
    prob: float = 0.0
    times: int = 1
    delay_s: float = 0.0
    payload: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        assert self.site in SITES, f"unknown fault site {self.site!r}"
        assert self.kind in KINDS, f"unknown fault kind {self.kind!r}"


class FaultPlan:
    """A seeded schedule of :class:`FaultSpec`s plus the record of what
    actually fired (``plan.fired``). Replayable: visit counters reset with
    :meth:`reset`, so the same plan object drives the reference and the
    chaos run of a scenario."""

    def __init__(self, specs, seed: int = 0):
        self.specs = list(specs)
        self.seed = int(seed)
        self.reset()

    def reset(self) -> None:
        self._visits = {s: 0 for s in SITES}
        self._remaining = [s.times for s in self.specs]
        self._rngs = [np.random.default_rng((self.seed, i))
                      for i in range(len(self.specs))]
        self.fired: list = []

    def visits(self, site: str) -> int:
        return self._visits[site]

    def fire(self, site: str, **ctx) -> Optional[dict]:
        """One visit to `site`: decide per armed spec whether it fires.
        Stragglers sleep; every other kind raises its typed fault (the
        FIRST matching spec wins the raise; its shot is spent either way).

        Returns an EFFECTS dict for non-raising faults so the host driver
        can account for them — ``{"stalls": [(part, seconds), ...]}`` with
        ``part == -1`` for an untargeted stall — or None when nothing
        non-raising fired. The stall record is what makes injected skew
        VISIBLE to the time channel of ``obs.skew`` (Gopher Balance)."""
        visit = self._visits[site]
        self._visits[site] = visit + 1
        effects: Optional[dict] = None
        for i, spec in enumerate(self.specs):
            if spec.site != site or self._remaining[i] <= 0:
                continue
            if spec.at is not None:
                hit = visit == spec.at
            else:
                hit = (spec.prob > 0.0
                       and float(self._rngs[i].random()) < spec.prob)
            if not hit:
                continue
            self._remaining[i] -= 1
            rec = dict(site=site, kind=spec.kind, visit=visit,
                       payload=dict(spec.payload),
                       ctx={k: v for k, v in ctx.items()
                            if isinstance(v, (int, float, str, bool))})
            self.fired.append(rec)
            if spec.kind == "straggler":
                stalls = _straggler_stalls(spec, ctx)
                rec["stall_s"] = round(sum(s for _, s in stalls), 6)
                if effects is None:
                    effects = {"stalls": []}
                effects["stalls"].extend(stalls)
                continue
            raise _RAISES[spec.kind](site, spec.kind, visit, spec.payload,
                                     ctx)
        return effects

    def record(self) -> list:
        """What fired so far, JSON-serializable."""
        return list(self.fired)


# ---------------------------------------------------------------- injection
_local = threading.local()


def active() -> Optional[FaultPlan]:
    stack = getattr(_local, "stack", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def inject(plan: Optional[FaultPlan]):
    """Arm `plan` for the dynamic extent of the block. Nestable (innermost
    plan wins); ``inject(None)`` is a no-op pass-through so scenario drivers
    can take an optional plan."""
    if plan is None:
        yield None
        return
    stack = getattr(_local, "stack", None)
    if stack is None:
        stack = _local.stack = []
    stack.append(plan)
    try:
        yield plan
    finally:
        stack.pop()


def fire(site: str, **ctx) -> Optional[dict]:
    """The hook entry compiled into NOTHING when no plan is armed: sites
    call this unconditionally; it returns immediately unless a FaultPlan is
    active on this thread. Forwards the plan's effects dict (straggler
    stall attributions) so the host driver can charge injected delay to the
    right partition's time channel."""
    plan = active()
    if plan is not None:
        return plan.fire(site, **ctx)
    return None
