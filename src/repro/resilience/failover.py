"""Gopher Shield — mesh-shrink failover for the shard_map backend.

Device loss on a 'parts' mesh is survivable WITHOUT repartitioning: GoFS
virtual partitions are decoupled from devices, so the surviving devices
re-tile the SAME P partitions over a smaller mesh (P % D must still hold —
the shrink clamps to a divisor of P). The lost device's partitions are
treated as a SYNTHETIC MIGRATION through the block-patch machinery's
announce path: their rows are marked dirty and pre-announced into the
block's traffic profile (core.tiers.announce_frontier — the announce-floor
restart), the tier plans are rebuilt for the surviving mesh, and the run
resumes from the newest checksum-verified snapshot. The math never saw the
mesh — only the tiling changed — so the recovered fixpoint is bit-identical
to the uninterrupted run for idempotent ⊕ (allclose for PageRank).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.launch import elastic
from repro.resilience import faults as _faults
from repro.resilience.recovery import (RecoveryExhausted, RecoveryReport,
                                       _latest_good)


def _largest_divisor_at_most(p: int, d: int) -> int:
    for k in range(min(p, max(d, 1)), 0, -1):
        if p % k == 0:
            return k
    return 1


def shrink_parts_mesh(mesh, lost: Sequence[int], num_parts: int,
                      axis_name: str = "parts"):
    """Rebuild a 1-axis 'parts' mesh after losing the device INDICES in
    ``lost``. elastic.shrink_after_failure sizes the surviving mesh; the
    size is then clamped down to the largest divisor of ``num_parts`` so
    the engine's P % D == 0 tiling invariant still holds. Survivors keep
    their relative order, so partition rows re-tile contiguously."""
    from repro.core import compat
    devs = list(np.asarray(mesh.devices).reshape(-1))
    lost_set = set(int(i) for i in lost)
    survivors = [d for i, d in enumerate(devs) if i not in lost_set]
    assert survivors, "every device was lost; nothing to fail over to"
    plan = elastic.MeshPlan((len(devs),), (axis_name,))
    shrunk = elastic.shrink_after_failure(plan, len(devs) - len(survivors))
    d_new = _largest_divisor_at_most(num_parts, shrunk.shape[0])
    return compat.make_mesh((d_new,), (axis_name,),
                            devices=survivors[:d_new])


@dataclasses.dataclass
class FailoverReport(RecoveryReport):
    """RecoveryReport plus the mesh-change record."""
    lost_devices: list = dataclasses.field(default_factory=list)
    lost_partitions: list = dataclasses.field(default_factory=list)
    old_num_devices: Optional[int] = None
    new_num_devices: Optional[int] = None


def run_with_failover(engine, checkpointer, every: int = 1,
                      extra: Optional[dict] = None,
                      host_gb: Optional[dict] = None,
                      max_restarts: int = 2):
    """Run checkpointed on a shard_map engine; on an injected device loss,
    shrink the mesh, re-announce the lost partitions, rebuild the tier
    plans, and resume from the newest good snapshot.

    Returns ``(engine, state, telemetry, FailoverReport)`` — the ENGINE is
    returned because failover rebuilds it (new mesh, new plans); callers
    must serve subsequent runs from the returned engine, not the one they
    passed in. Plain crashes restart the current engine in place (same
    policy as recovery.run_with_recovery)."""
    from repro.core import (GopherEngine, PhasedTierPlan, TierPlan,
                            host_graph_block)
    from repro.core.tiers import announce_frontier

    report = FailoverReport()
    last = None
    for attempt in range(max_restarts + 1):
        report.attempts = attempt + 1
        try:
            state, tele = engine.run(checkpointer=checkpointer,
                                     checkpoint_every=every,
                                     resume=attempt > 0, extra=extra)
            report.final_step = int(tele.supersteps)
            return engine, state, tele, report
        except _faults.CrashFault as e:
            last = e
            report.restarts += 1
            report.faults.append(dict(site=e.site, kind=e.kind,
                                      visit=e.visit))
            report.resumed_steps.append(_latest_good(checkpointer))
        except _faults.DeviceLossFault as e:
            last = e
            report.restarts += 1
            report.faults.append(dict(site=e.site, kind=e.kind,
                                      visit=e.visit))
            report.resumed_steps.append(_latest_good(checkpointer))
            assert engine.backend == "shard_map", \
                "device-loss failover needs a shard_map mesh"
            pg = engine.pg
            P = pg.num_parts
            D = int(engine.mesh.shape[engine.axis_name])
            lost = e.payload.get("lost", 1)
            lost = ([int(lost)] if np.isscalar(lost)
                    else [int(i) for i in lost])
            # block sharding of the leading (P,) axis: device d owns the
            # contiguous partition rows [d*P/D, (d+1)*P/D)
            per = P // D
            lost_parts = [p for d in lost
                          for p in range(d * per, (d + 1) * per)]
            report.lost_devices = lost
            report.lost_partitions = lost_parts
            report.old_num_devices = D
            new_mesh = shrink_parts_mesh(engine.mesh, lost, P,
                                         axis_name=engine.axis_name)
            report.new_num_devices = int(new_mesh.shape[engine.axis_name])
            # synthetic migration of the lost rows: announce their live
            # vertices as the dirty frontier so rebuilt plans give the
            # re-homed partitions' pairs enough width from round 0
            hb = host_gb if host_gb is not None else host_graph_block(pg)
            dirty = np.zeros((P, pg.v_max), bool)
            dirty[lost_parts] = np.asarray(hb["vmask"],
                                           bool)[lost_parts]
            announce_frontier(hb, pg, dirty)
            plan = engine.tier_plan
            if isinstance(plan, PhasedTierPlan):
                plan = PhasedTierPlan.for_resume(hb)
            elif isinstance(plan, TierPlan):
                plan = TierPlan.from_block(hb)
            engine.metrics.counter(
                "failover_events_total",
                labels={"backend": engine.backend}).inc()
            engine = GopherEngine(
                pg, engine.program, backend="shard_map", mesh=new_mesh,
                axis_name=engine.axis_name,
                max_supersteps=engine.max_supersteps,
                exchange=engine.exchange_requested, tier_plan=plan,
                tracer=engine._tracer, metrics=engine._metrics,
                validate=engine.validate)
    raise RecoveryExhausted(report, last)
