"""Gopher Shield — superstep checkpoint/replay recovery drivers.

BSP makes the recovery line trivial: the superstep barrier IS a consistent
cut (the paper's §4.2 synchronization points), so a snapshot of
(state, inbox, superstep) replayed through the same staged stage functions
finishes bit-identical to the uninterrupted run. These drivers wrap
GopherEngine's checkpointed loop with restart-on-fault: a crash rolls back
to the newest snapshot that passes checksum verification
(Checkpointer.latest_good_step — a corrupt latest snapshot falls back one
further) and replays forward.

Device loss is NOT handled here — that is a mesh change, not a replay; see
:mod:`repro.resilience.failover`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.resilience import faults as _faults


@dataclasses.dataclass
class RecoveryReport:
    """What the restart loop actually did, for assertions and chaos logs."""
    attempts: int = 0
    restarts: int = 0
    resumed_steps: list = dataclasses.field(default_factory=list)
    faults: list = dataclasses.field(default_factory=list)
    final_step: Optional[int] = None

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class RecoveryExhausted(RuntimeError):
    """Every allowed restart was consumed and the run still faulted."""

    def __init__(self, report: RecoveryReport, last: BaseException):
        super().__init__(
            f"recovery exhausted after {report.attempts} attempts "
            f"({report.restarts} restarts): {last}")
        self.report = report
        self.last_error = last


def recover(engine, checkpointer, every: int = 1, extra: Optional[dict] = None
            ) -> Tuple[object, object]:
    """One restore-and-continue: resume from the newest GOOD snapshot and
    run to quiescence. Returns (state, telemetry) — bit-identical to what
    the interrupted run would have produced (the checkpointed driver's
    staged stages are the same jits either way)."""
    return engine.run(checkpointer=checkpointer, checkpoint_every=every,
                      resume=True, extra=extra)


def _latest_good(ck) -> Optional[int]:
    return (ck.latest_good_step() if hasattr(ck, "latest_good_step")
            else ck.latest_step())


def run_with_recovery(engine, checkpointer, every: int = 1,
                      extra: Optional[dict] = None, max_restarts: int = 3,
                      recoverable: tuple = (_faults.CrashFault,)):
    """Run checkpointed; on a recoverable fault, roll back and replay.

    The first attempt starts cold (or resumes, if the checkpoint directory
    already holds committed snapshots and the fault fires before any new
    save — latest_good_step of an empty directory is None, which the
    checkpointed driver treats as a cold start). Each restart resumes from
    the newest checksum-verified snapshot. Returns
    ``(state, telemetry, RecoveryReport)``; raises :class:`RecoveryExhausted`
    when ``max_restarts`` is spent. ``DeviceLossFault`` is deliberately NOT
    recoverable here — pass the engine to
    :func:`repro.resilience.failover.run_with_failover` instead."""
    report = RecoveryReport()
    last: Optional[BaseException] = None
    for attempt in range(max_restarts + 1):
        report.attempts = attempt + 1
        try:
            state, tele = engine.run(checkpointer=checkpointer,
                                     checkpoint_every=every,
                                     resume=attempt > 0, extra=extra)
            report.final_step = int(tele.supersteps)
            return state, tele, report
        except recoverable as e:
            last = e
            report.restarts += 1
            if isinstance(e, _faults.InjectedFault):
                report.faults.append(dict(site=e.site, kind=e.kind,
                                          visit=e.visit))
            report.resumed_steps.append(_latest_good(checkpointer))
            engine.metrics.counter(
                "recovery_restarts_total",
                labels={"backend": engine.backend}).inc()
    raise RecoveryExhausted(report, last)
