"""Gopher Balance — skew-healing live sub-graph migration.

GoFFish's documented weakness is partition skew: the superstep barrier makes
makespan ∝ the SLOWEST partition while resources ∝ the mean, so one
straggler gates the whole BSP pipeline (paper Fig. 5; the sub-graph-centric
algorithms follow-up attacks exactly this imbalance, and Mizan-style dynamic
migration is the vertex-centric world's standard remedy). This module closes
the telemetry → decision → migration → verify loop around signals that
already exist:

  telemetry   ``Telemetry.part_seconds`` (the host-stepped drivers' wall
              clock, where injected straggler stalls land) + the iteration
              channel, scored by ``obs.skew`` / ``SkewTracker``;
  decision    ``launch/elastic.rebalance_hint`` (threshold + hysteresis
              floor) names the victim; :func:`plan_migration` picks WHICH of
              its sub-graphs move WHERE, bounded by a per-step budget;
  migration   :func:`apply_migration` executes the move as a SYNTHETIC DELTA
              through the existing O(|delta|) machinery: only the moved
              sub-graphs' ELL rows and remote-slot entries are rewritten and
              ``core.blocks.patch_host_block`` patches the serving block in
              place — never a full re-partition. Sub-graphs are weakly
              connected components of the LOCAL adjacency, so no local edge
              crosses a sub-graph boundary and a whole sub-graph moves with
              ONLY its cut edges re-routed — the GoFFish representation
              makes migration O(moved sub-graphs' cut), which is the point;
  verify      ``verify_host_block`` audits the patched block BEFORE the new
              engine exists (failed audit = rollback, the pre-migration
              block keeps serving), and :func:`migrate_and_resume` re-homes
              the snapshot so the run resumes BIT-IDENTICAL to the
              unmigrated run for idempotent ⊕ (allclose for PageRank, whose
              ⊕ is a float sum and the move reorders it).

Resume correctness hangs on the cut's PENDING DELIVERIES: the saved inbox
carries messages whose senders changed in the last superstep before the
snapshot and whose receivers only learn of them from the next mailbox. An
edge the migration converts from remote to local loses that channel (local
edges deliver DURING the superstep their source changes — already passed),
so for idempotent ⊕ the resume RE-HOMES the saved inbox (pending news
preserved; double delivery over now-local edges is harmless on a monotone
lattice), while for ⊕ = sum it RECOMPUTES ``route(pack(state))`` on the new
topology (re-homing would double-count converted edges; sum-programs resend
unconditionally, so the recompute is complete and exact).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.launch import elastic
from repro.resilience import faults as _faults


@dataclasses.dataclass(frozen=True)
class BalancePolicy:
    """Knobs of the rebalance actuator. ``threshold``/``floor`` gate
    :func:`elastic.rebalance_hint` (trip above threshold, keep healing until
    below floor — the hysteresis band); ``max_verts_per_step`` bounds one
    migration's live vertices (the per-step budget); ``cooldown_segments``
    idles the actuator after each move so two consecutive decisions never
    react to the same pre-move telemetry (no oscillation); ``check_every``
    is the superstep budget of one run segment between decisions."""
    threshold: float = 1.5
    floor: float = 1.1
    max_verts_per_step: int = 64
    cooldown_segments: int = 1
    check_every: int = 4
    max_migrations: int = 8


@dataclasses.dataclass(frozen=True)
class MigrationPlan:
    """Move the named sub-graphs (ids in ``src``'s CURRENT local numbering)
    from partition ``src`` to partition ``dst``. ``verts`` is the live
    vertex count the plan moves (the spent budget)."""
    src: int
    dst: int
    subgraphs: tuple
    verts: int


@dataclasses.dataclass
class MigrationResult:
    """One executed migration: the new graph version, its patched host
    block (None when no block was passed), and the move record needed to
    re-home a snapshot (old/new local slots of the moved vertices)."""
    pg: object
    block: Optional[dict]
    plan: MigrationPlan
    moved_gids: np.ndarray     # (m,) global ids moved
    old_slots: np.ndarray      # (m,) vacated src-local slots
    new_slots: np.ndarray      # (m,) filled dst-local slots
    stats: dict
    events: Optional[tuple] = None


def plan_migration(pg, src: int, budget: int = 64,
                   load: Optional[np.ndarray] = None,
                   dst: Optional[int] = None) -> Optional[MigrationPlan]:
    """Pick which of ``src``'s sub-graphs to shed and where. Destination
    defaults to the LIGHTEST partition by ``load`` (per-partition seconds or
    iterations; live vertex count when absent) that has free vertex slots —
    v_max never grows under migration, so capacity is a hard constraint.
    Sub-graphs are chosen largest-first while they fit both the budget and
    the destination's free slots (a sub-graph is atomic: local edges never
    cross one, so splitting is not an option). Returns None when nothing
    movable fits — a single sub-graph larger than the budget stays put."""
    vmask = np.asarray(pg.vmask, bool)
    P = pg.num_parts
    src = int(src)
    if not (0 <= src < P) or not vmask[src].any():
        return None
    sg = np.asarray(pg.sg_id[src])
    ids, counts = np.unique(sg[vmask[src]], return_counts=True)
    free = (~vmask).sum(1)
    if dst is None:
        ld = (np.asarray(load, np.float64).reshape(-1) if load is not None
              else vmask.sum(1).astype(np.float64))
        cand = [int(p) for p in np.argsort(ld, kind="stable")
                if int(p) != src and free[p] > 0]
        if not cand:
            return None
        dst = cand[0]
    dst = int(dst)
    if dst == src or not (0 <= dst < P):
        return None
    room = min(int(free[dst]), int(budget))
    pick, verts = [], 0
    for i in np.argsort(-counts, kind="stable"):
        c = int(counts[i])
        if verts + c <= room:
            pick.append(int(ids[i]))
            verts += c
    if not pick:
        return None
    return MigrationPlan(src=src, dst=dst, subgraphs=tuple(sorted(pick)),
                         verts=verts)


def apply_migration(pg, plan: MigrationPlan, host_gb: Optional[dict] = None,
                    lane_pad: int = 8) -> MigrationResult:
    """Execute a :class:`MigrationPlan` as a synthetic delta: rewrite
    ownership (part_of/local_of/global_id/vmask), the moved sub-graphs' ELL
    rows (local ids remap through a slot LUT — sub-graph closure guarantees
    every local neighbor of a moved vertex also moved), and the remote-slot
    layout (out-edges of moved vertices re-allocate at ``dst``; in-edges
    retarget their stored (dst_part, dst_local) in place; edges with both
    ends landing in ``dst`` CONVERT to local ELL entries). With ``host_gb`` the
    serving block is patched through ``core.blocks.patch_host_block`` using
    the same (touched_rows, rdel, radd) event protocol as
    ``gofs.temporal.apply_delta`` — O(moved cut), no re-bin, no re-pack —
    and the dirty frontier is pre-announced (``core.tiers.announce_frontier``)
    so restart plans give the re-homed pairs width from round 0."""
    from repro.gofs.formats import PAD, PartitionedGraph, grow_last_axis

    P, v_max = pg.num_parts, pg.v_max
    src, dst = int(plan.src), int(plan.dst)
    assert src != dst
    vmask = np.asarray(pg.vmask, bool).copy()
    sgid = np.asarray(pg.sg_id)
    moved = vmask[src] & np.isin(sgid[src],
                                 np.asarray(plan.subgraphs, np.int32))
    old_l = np.flatnonzero(moved)
    assert old_l.size, "plan names no live sub-graph vertices"
    free_dst = np.flatnonzero(~vmask[dst])
    assert free_dst.size >= old_l.size, \
        (f"partition {dst} has {free_dst.size} free slots for "
         f"{old_l.size} moved vertices (v_max is fixed under migration)")
    new_l = free_dst[:old_l.size].astype(np.int32)
    lut = np.full(v_max, PAD, np.int32)
    lut[old_l] = new_l
    moved_local = np.zeros(v_max, bool)
    moved_local[old_l] = True

    # ---- identity re-home
    gids = np.asarray(pg.global_id)[src, old_l]
    assert (gids >= 0).all()
    part_of = pg.part_of.copy()
    local_of = pg.local_of.copy()
    part_of[gids] = dst
    local_of[gids] = new_l
    global_id = pg.global_id.copy()
    global_id[dst, new_l] = gids
    global_id[src, old_l] = -1
    vmask[dst, new_l] = True
    vmask[src, old_l] = False
    out_degree = pg.out_degree.copy()
    out_degree[dst, new_l] = out_degree[src, old_l]
    out_degree[src, old_l] = 0
    attrs = {}
    for name, arr in pg.attrs.items():
        a = np.asarray(arr).copy()
        a[dst, new_l] = a[src, old_l]
        a[src, old_l] = 0
        attrs[name] = a

    # ---- local ELL rows (pull in-edges, local ids): remap through the LUT
    nbr = pg.nbr.copy()
    wgt = pg.wgt.copy()
    rows = nbr[src, old_l]
    live_e = rows != PAD
    assert (lut[np.where(live_e, rows, 0)][live_e] != PAD).all(), \
        "local edge crosses a sub-graph boundary (broken GoFS invariant)"
    nbr[dst, new_l] = np.where(live_e, lut[np.where(live_e, rows, 0)], PAD)
    wgt[dst, new_l] = wgt[src, old_l]
    nbr[src, old_l] = PAD
    wgt[src, old_l] = 0.0
    touched = np.zeros((P, v_max), bool)
    touched[src, old_l] = True
    touched[dst, new_l] = True

    re_src = pg.re_src.copy()
    re_wgt = pg.re_wgt.copy()
    re_dp = pg.re_dst_part.copy()
    re_dl = pg.re_dst_local.copy()
    re_slot = pg.re_slot.copy()
    ev_rdel = []               # [(src_p, dst_p, dst_v, slot)]
    ev_radd = []               # [(src_p, dst_p, dst_v, slot, edge_idx)]
    dirty = np.zeros((P, v_max), bool)   # announce by SOURCE vertex
    dirty[dst, new_l] = True
    stats = dict(moved_verts=int(old_l.size), out_moved=0, in_retargeted=0,
                 converted_local=0)

    def ell_insert(p, v, u, w):
        nonlocal nbr, wgt
        row = nbr[p, v]
        holes = np.flatnonzero(row == PAD)
        if holes.size == 0:
            nbr = grow_last_axis(nbr, lane_pad, PAD)
            wgt = grow_last_axis(wgt, lane_pad, 0.0)
            holes = np.flatnonzero(nbr[p, v] == PAD)
        nbr[p, v, holes[0]] = u
        wgt[p, v, holes[0]] = w
        touched[p, v] = True

    def alloc_remote(p):
        nonlocal re_src, re_wgt, re_dp, re_dl, re_slot
        holes = np.flatnonzero(re_src[p] == PAD)
        if holes.size == 0:
            re_src = grow_last_axis(re_src, lane_pad, PAD)
            re_wgt = grow_last_axis(re_wgt, lane_pad, 0.0)
            re_dp = grow_last_axis(re_dp, lane_pad, 0)
            re_dl = grow_last_axis(re_dl, lane_pad, 0)
            re_slot = grow_last_axis(re_slot, lane_pad, 0)
            holes = np.flatnonzero(re_src[p] == PAD)
        return int(holes[0])

    def recycled_slot(p, pv):
        # smallest slot unused by live edges of the (p, pv) pair — the same
        # recycling rule apply_delta uses, so the mailbox doesn't creep
        pair = (re_src[p] != PAD) & (re_dp[p] == pv)
        used = np.zeros(int(pair.sum()) + 1, bool)
        in_range = re_slot[p][pair]
        used[in_range[in_range < used.size]] = True
        return int(np.flatnonzero(~used)[0])

    # ---- out-edges OF moved vertices (stored source-side at src)
    srow = re_src[src]
    out_e = np.flatnonzero((srow != PAD)
                           & moved_local[np.where(srow != PAD, srow, 0)])
    for e in out_e:
        lu = int(re_src[src, e])
        pv = int(re_dp[src, e])
        lv = int(re_dl[src, e])
        w = float(re_wgt[src, e])
        ev_rdel.append((src, pv, lv, int(re_slot[src, e])))
        re_src[src, e] = PAD
        re_wgt[src, e] = 0.0
        nlu = int(lut[lu])
        if pv == dst:                    # both ends now in dst: goes local
            ell_insert(dst, lv, nlu, w)
            stats["converted_local"] += 1
        else:
            e2 = alloc_remote(dst)
            slot = recycled_slot(dst, pv)
            re_src[dst, e2] = nlu
            re_wgt[dst, e2] = w
            re_dp[dst, e2] = pv
            re_dl[dst, e2] = lv
            re_slot[dst, e2] = slot
            ev_radd.append((dst, pv, lv, slot, e2))
            stats["out_moved"] += 1

    # ---- in-edges INTO moved vertices (stored at their source partitions)
    for r in range(P):
        if r == src:                     # remote edges never stay in-part
            continue
        rrow = re_src[r]
        hit = np.flatnonzero(
            (rrow != PAD) & (re_dp[r] == src)
            & moved_local[np.where(re_dl[r] >= 0, re_dl[r], 0)]
            & (re_dl[r] >= 0))
        for e in hit:
            lu = int(re_src[r, e])
            lv_old = int(re_dl[r, e])
            w = float(re_wgt[r, e])
            nlv = int(lut[lv_old])
            ev_rdel.append((r, src, lv_old, int(re_slot[r, e])))
            if r == dst:                 # both ends now in dst: goes local
                re_src[r, e] = PAD
                re_wgt[r, e] = 0.0
                ell_insert(dst, nlv, lu, w)
                stats["converted_local"] += 1
            else:                        # retarget the stored entry in place
                slot = recycled_slot(r, dst)
                re_dp[r, e] = dst
                re_dl[r, e] = nlv
                re_slot[r, e] = slot
                ev_radd.append((r, dst, nlv, slot, int(e)))
                dirty[r, lu] = True
                stats["in_retargeted"] += 1

    # ---- mailbox capacity: exact fit, STICKY against the block's width
    live = re_src != PAD
    cap = int(re_slot[live].max()) + 1 if live.any() else 1
    if host_gb is not None:
        cap_block = host_gb["ob_inv"].shape[1] // P
        if cap > cap_block:
            cap = ((cap + lane_pad - 1) // lane_pad) * lane_pad
        cap = max(cap, cap_block)

    # ---- sub-graph rediscovery on the two touched partitions only
    from repro.gofs.temporal import _local_subgraphs
    sg_new = sgid.copy()
    num_sg = pg.num_subgraphs.copy()
    for p, sg_p, n_p in _local_subgraphs(nbr, vmask, [src, dst]):
        sg_new[p], num_sg[p] = sg_p, n_p

    new_pg = PartitionedGraph(
        n_global=pg.n_global, num_parts=P, v_max=v_max,
        nbr=nbr, wgt=wgt, vmask=vmask, out_degree=out_degree,
        global_id=global_id, part_of=part_of, local_of=local_of,
        sg_id=sg_new, num_subgraphs=num_sg,
        re_src=re_src, re_wgt=re_wgt, re_dst_part=re_dp, re_dst_local=re_dl,
        re_slot=re_slot, mailbox_cap=cap, attrs=attrs,
        version=pg.version + 1,
    )
    touched_rows = np.argwhere(touched)
    new_block = None
    if host_gb is not None:
        from repro.core.blocks import patch_host_block
        from repro.core.tiers import announce_frontier
        new_block = patch_host_block(host_gb, new_pg, touched_rows,
                                     ev_rdel, ev_radd, lane_pad=lane_pad)
        # patch carries attr_* keys across untouched; ownership moved, so
        # refresh them from the re-homed attrs
        for name, arr in attrs.items():
            new_block[f"attr_{name}"] = np.asarray(arr)
        announce_frontier(new_block, new_pg, dirty)
    return MigrationResult(pg=new_pg, block=new_block, plan=plan,
                           moved_gids=np.asarray(gids),
                           old_slots=old_l.astype(np.int64),
                           new_slots=new_l.astype(np.int64), stats=stats,
                           events=(touched_rows, ev_rdel, ev_radd))


def remap_state(state, res: MigrationResult, num_parts: int, v_max: int):
    """Re-home a snapshot's state pytree onto the migrated layout: every
    (P, v_max, ...)-leading leaf copies the moved vertices' values from
    their old src slots to their new dst slots (vacated slots keep stale
    values — every consumer masks by vmask). Other leaves pass through."""
    import jax
    src, dst = res.plan.src, res.plan.dst
    old_l, new_l = res.old_slots, res.new_slots

    def leaf(x):
        a = np.asarray(x)
        if a.ndim >= 2 and a.shape[0] == num_parts and a.shape[1] == v_max:
            out = a.copy()
            out[dst, new_l] = a[src, old_l]
            return out
        return a
    return jax.tree.map(leaf, state)


def to_global(state, pg):
    """Scatter (P, v_max, ...)-leading state leaves into global vertex order
    — the layout-independent view two runs with different partition layouts
    are compared in (raw leaf equality is meaningless after a migration)."""
    import jax
    gid = np.asarray(pg.global_id)
    m = np.asarray(pg.vmask, bool)

    def leaf(x):
        a = np.asarray(x)
        if (a.ndim >= 2 and a.shape[0] == pg.num_parts
                and a.shape[1] == pg.v_max):
            out = np.zeros((pg.n_global,) + a.shape[2:], a.dtype)
            out[gid[m]] = a[m]
            return out
        return a
    return jax.tree.map(leaf, state)


def migrate_and_resume(engine, checkpointer, plan: MigrationPlan,
                       host_gb: Optional[dict] = None,
                       extra: Optional[dict] = None):
    """The live-migration step: patch graph + block, AUDIT, rebuild the
    engine on the patched block with a narrow restart plan, re-home the
    newest good snapshot and recompute its inbox on the new topology, and
    re-commit it at the SAME superstep so ``engine.run(resume=True)``
    continues the run bit-identical to the unmigrated execution.

    Raises :class:`faults.BlockCorruptionFault` BEFORE anything is
    installed when the patched block fails ``verify_host_block`` — the
    caller's engine, block, and snapshot are untouched (rollback is free).
    Returns ``(new_engine, MigrationResult, resumed_step)``."""
    import jax
    import jax.numpy as jnp

    from repro.core import (GopherEngine, PhasedTierPlan, TierPlan,
                            host_graph_block)
    from repro.core.blocks import device_block, verify_host_block

    pg = engine.pg
    hb = host_gb if host_gb is not None else host_graph_block(pg)
    res = apply_migration(pg, plan, host_gb=hb)
    problems = verify_host_block(res.block)
    if problems:
        raise _faults.BlockCorruptionFault(
            "blocks.patch", "corrupt_block", -1,
            {"migration": True},
            {"problems": "; ".join(problems[:3])})

    tier_plan = engine.tier_plan
    if isinstance(tier_plan, PhasedTierPlan):
        tier_plan = PhasedTierPlan.for_resume(res.block)
    elif isinstance(tier_plan, TierPlan):
        tier_plan = TierPlan.from_block(res.block)
    ne = GopherEngine(
        res.pg, engine.program, backend=engine.backend, mesh=engine.mesh,
        axis_name=engine.axis_name, max_supersteps=engine.max_supersteps,
        gb=device_block(res.block), exchange=engine.exchange_requested,
        tier_plan=tier_plan, tracer=engine._tracer, metrics=engine._metrics,
        validate=engine.validate)

    # re-home the snapshot: restore → remap state → re-home or recompute the
    # inbox (see below) → re-commit at the same step
    ck = checkpointer
    good = (ck.latest_good_step() if hasattr(ck, "latest_good_step")
            else ck.latest_step())
    assert good is not None, "migration needs a committed snapshot to re-home"
    P_, v_max = pg.num_parts, pg.v_max
    gb = ne._graph_block()
    if extra:
        gb = dict(gb)
        for k, v in extra.items():
            gb[k] = jnp.asarray(v)
    snap_like = {
        "state": jax.eval_shape(lambda g: jax.vmap(ne.program.init)(g), gb),
        "inbox": jax.ShapeDtypeStruct((P_, v_max), np.float32),
    }
    shardings = None
    if ne.backend == "shard_map":
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as _P
        sh = NamedSharding(ne.mesh, _P(ne.axis_name))
        shardings = jax.tree.map(lambda _: sh, snap_like)
    snap, step = ck.restore(snap_like, step=good, shardings=shardings)
    state = remap_state(snap["state"], res, P_, v_max)
    # The saved inbox carries the cut's PENDING DELIVERIES — messages whose
    # senders changed in the last superstep and whose receivers only learn
    # of them from the next mailbox. An edge the migration converted from
    # remote to local loses that channel (local edges deliver DURING the
    # superstep their source changes — which has passed), so the pending
    # news must survive the move:
    #   idempotent ⊕ (min/max): RE-HOME the saved inbox — moved rows copy
    #     to their new slots, everything pending is preserved, and the
    #     double delivery over now-local edges (inbox now + local pull
    #     later) is harmless on a monotone lattice;
    #   ⊕ = sum (PageRank): re-homing would DOUBLE-COUNT converted edges,
    #     but these programs resend unconditionally every superstep, so
    #     recomputing route(pack(state)) on the new topology is complete
    #     AND exact.
    if getattr(ne.program, "combine", None) in ("min", "max"):
        inbox = np.asarray(snap["inbox"]).copy()
        inbox[res.plan.dst, res.new_slots] = \
            inbox[res.plan.src, res.old_slots]
    else:
        prev = ne.exchange
        if prev in ("megastep", "tiered", "phased"):
            ne.exchange = "compact"      # the checkpointed driver's own drop
        try:
            fns = ne._traced_stage_fns(None, None)
            payload = fns["pack"](gb, jax.tree.map(jnp.asarray, state))[0]
            inbox = fns["route"](gb, payload)[0]
        finally:
            ne.exchange = prev
    ck.save({"state": jax.tree.map(np.asarray, state),
             "inbox": np.asarray(inbox)}, int(step))
    ne.metrics.counter("rebalance_migrations_total",
                       labels={"backend": ne.backend}).inc()
    return ne, res, int(step)


@dataclasses.dataclass
class RebalanceReport:
    """What the actuator did across one run: every migration (step, route,
    sub-graphs, vertex count), the skew score when it first tripped and at
    the end, and any audited-and-rolled-back patches."""
    migrations: list = dataclasses.field(default_factory=list)
    rollbacks: int = 0
    segments: int = 0
    imbalance_before: float = 0.0
    imbalance_after: float = 0.0
    final_step: Optional[int] = None
    faults: list = dataclasses.field(default_factory=list)

    def moved_verts(self) -> int:
        return sum(m["verts"] for m in self.migrations)


def _segment_score(skew: dict) -> float:
    return max(float(skew.get("imbalance", 0.0)),
               float(skew.get("time_imbalance", 0.0)))


def run_with_rebalance(engine, checkpointer, every: int = 1,
                       policy: Optional[BalancePolicy] = None,
                       extra: Optional[dict] = None,
                       host_gb: Optional[dict] = None):
    """Run checkpointed in ``policy.check_every``-superstep segments; after
    each segment read the skew report, ask ``elastic.rebalance_hint``
    (threshold to trip, hysteresis floor while acting, cooldown after every
    move), and heal stragglers by migrating sub-graphs off the victim
    partition through :func:`migrate_and_resume` — the mirror of
    ``run_with_failover``, driven by telemetry instead of failure.

    Returns ``(engine, state, telemetry, RebalanceReport)`` — the ENGINE is
    returned because every migration rebuilds it (new graph version, new
    block, new plans); callers must keep serving from the returned engine.
    A patch that fails its ``verify_host_block`` audit rolls back for free
    (nothing was installed) and is counted in ``report.rollbacks``."""
    from repro.core import host_graph_block

    pol = policy or BalancePolicy()
    report = RebalanceReport()
    hb = host_gb
    cooldown = 0
    acting = False
    resume = False
    state = tele = None
    while True:
        report.segments += 1
        state, tele = engine.run(checkpointer=checkpointer,
                                 checkpoint_every=every, resume=resume,
                                 extra=extra,
                                 superstep_budget=pol.check_every)
        resume = True
        step = int(tele.supersteps)
        converged = (tele.changed_hist.size > 0
                     and int(tele.changed_hist[-1]) == 0)
        skew = tele.skew()
        if converged or step >= engine.max_supersteps:
            report.final_step = step
            report.imbalance_after = _segment_score(skew)
            return engine, state, tele, report
        if cooldown > 0:
            cooldown -= 1
            continue
        hint = elastic.rebalance_hint(skew, threshold=pol.threshold,
                                      floor=pol.floor, acting=acting)
        if hint is None or len(report.migrations) >= pol.max_migrations:
            acting = False
            continue
        load = (tele.part_seconds
                if tele.part_seconds is not None
                and np.any(np.asarray(tele.part_seconds) > 0)
                else tele.local_iters)
        plan = plan_migration(engine.pg, src=int(hint["migrate_from"]),
                              budget=pol.max_verts_per_step, load=load)
        if plan is None:
            acting = False
            continue
        if not report.migrations:
            report.imbalance_before = float(hint["imbalance"])
        if hb is None:
            hb = host_graph_block(engine.pg)
        try:
            engine, res, at = migrate_and_resume(engine, checkpointer, plan,
                                                 host_gb=hb, extra=extra)
        except _faults.BlockCorruptionFault as e:
            # failed patch audit: nothing was installed — the pre-migration
            # engine/block/snapshot keep running untouched
            report.rollbacks += 1
            report.faults.append(dict(site=e.site, kind=e.kind,
                                      visit=e.visit))
            acting = False
            cooldown = pol.cooldown_segments
            continue
        hb = res.block
        acting = True
        cooldown = pol.cooldown_segments
        report.migrations.append(dict(
            step=at, src=plan.src, dst=plan.dst,
            subgraphs=[int(g) for g in plan.subgraphs],
            verts=int(plan.verts), signal=hint.get("signal", ""),
            imbalance=float(hint["imbalance"])))
