"""Gopher Shield — graceful-degradation primitives for the serving loop.

:class:`CircuitBreaker` is the standard three-state machine, per graph:

    CLOSED     normal serving; consecutive failures are counted
    OPEN       after ``threshold`` consecutive failures: engine runs are
               refused for ``cooldown_s`` — queries fall back to
               caches/landmarks (stale-serving) or are rejected cheaply
               instead of burning retries on a broken graph
    HALF_OPEN  cooldown elapsed: ONE trial batch is admitted; success
               closes the breaker, failure re-opens it

The clock is injectable so tests drive the cooldown deterministically
instead of sleeping.
"""
from __future__ import annotations

import time
from typing import Callable, Sequence

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


class CircuitBreaker:
    def __init__(self, threshold: int = 3, cooldown_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self.clock = clock
        self.state = CLOSED
        self.failures = 0          # consecutive failures while CLOSED
        self.opens = 0             # lifetime open transitions
        self._opened_at = 0.0

    def allow(self) -> bool:
        """May an engine run be attempted right now? An OPEN breaker whose
        cooldown elapsed moves to HALF_OPEN and admits the one trial."""
        if self.state == OPEN:
            if self.clock() - self._opened_at >= self.cooldown_s:
                self.state = HALF_OPEN
                return True
            return False
        return True

    def record_ok(self) -> None:
        self.state = CLOSED
        self.failures = 0

    def record_failure(self) -> None:
        if self.state == HALF_OPEN:
            self._open()
            return
        self.failures += 1
        if self.failures >= self.threshold:
            self._open()

    def _open(self) -> None:
        self.state = OPEN
        self.opens += 1
        self.failures = 0
        self._opened_at = self.clock()


def backoff_delays(base_s: float, retries: int,
                   cap_s: float = 5.0) -> Sequence[float]:
    """Exponential backoff schedule: base, 2·base, 4·base, ... capped."""
    return [min(base_s * (2 ** i), cap_s) for i in range(max(retries, 0))]
