"""Gopher Shield — the robustness layer (fault injection, checkpoint/replay
recovery, mesh-shrink failover, serving degradation).

Leaf modules (:mod:`.faults`, :mod:`.degrade`) import eagerly — the engine
and serving hooks depend on them. The drivers (:mod:`.recovery`,
:mod:`.failover`) import :mod:`repro.core` and load lazily so the package
stays importable from inside core modules without a cycle.
"""
from repro.resilience import faults
from repro.resilience.degrade import CircuitBreaker, backoff_delays
from repro.resilience.faults import (
    BlockCorruptionFault,
    CrashFault,
    DeltaApplyFault,
    DeviceLossFault,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    PoisonedQueryFault,
)

__all__ = [
    "BlockCorruptionFault", "CircuitBreaker", "CrashFault",
    "DeltaApplyFault", "DeviceLossFault", "FaultPlan", "FaultSpec",
    "InjectedFault", "PoisonedQueryFault", "backoff_delays", "faults",
    "recover", "run_with_recovery", "run_with_failover", "shrink_parts_mesh",
    "RecoveryExhausted", "RecoveryReport",
    "BalancePolicy", "MigrationPlan", "MigrationResult", "RebalanceReport",
    "apply_migration", "migrate_and_resume", "plan_migration",
    "run_with_rebalance", "to_global",
]

_LAZY = {
    "recover": "recovery", "run_with_recovery": "recovery",
    "RecoveryExhausted": "recovery", "RecoveryReport": "recovery",
    "run_with_failover": "failover", "shrink_parts_mesh": "failover",
    "BalancePolicy": "balance", "MigrationPlan": "balance",
    "MigrationResult": "balance", "RebalanceReport": "balance",
    "apply_migration": "balance", "migrate_and_resume": "balance",
    "plan_migration": "balance", "run_with_rebalance": "balance",
    "to_global": "balance",
}


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(name)
    import importlib
    return getattr(importlib.import_module(f"repro.resilience.{mod}"), name)
