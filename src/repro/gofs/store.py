"""GoFS slice-file store: write-once / read-many partitioned graph storage.

Layout (mirrors the paper's GoFS: per-partition slice files, topology and
attributes in SEPARATE slices so an algorithm loads only what it touches):

    <root>/<graph>/meta.json                     graph + partition metadata
    <root>/<graph>/part_<i>/topology.npz         ELL + remote edges + sub-graph ids
    <root>/<graph>/part_<i>/attr_<name>.npz      one slice per attribute

``load_partitioned`` reassembles the (P, ...) device-ready batch, optionally
loading only a subset of attributes (the paper's "load only the edge-weight
slice" optimization).
"""
from __future__ import annotations

import json
import os
from typing import Optional, Sequence

import numpy as np

from repro.gofs.formats import Graph, PartitionedGraph, partition_graph

_TOPO_FIELDS = ["nbr", "wgt", "vmask", "out_degree", "global_id", "sg_id",
                "re_src", "re_wgt", "re_dst_part", "re_dst_local", "re_slot"]
# ELL is the DEVICE layout; on DISK the adjacency is compact CSR (the paper's
# Kryo slices don't pad either) — hub-padded ELL would bloat powerlaw slices
# ~20x. ELL is rebuilt vectorized at load.
_DENSE_FIELDS = [f for f in _TOPO_FIELDS if f not in ("nbr", "wgt")]


class GoFSStore:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    # ---------------- write path (the GoFS "build") ----------------
    def build(self, name: str, g: Graph, assign: np.ndarray, num_parts: int,
              lane_pad: int = 8) -> PartitionedGraph:
        pg = partition_graph(g, assign, num_parts, lane_pad=lane_pad)
        self.write(name, pg)
        return pg

    def write(self, name: str, pg: PartitionedGraph) -> None:
        gdir = os.path.join(self.root, name)
        os.makedirs(gdir, exist_ok=True)
        meta = dict(
            n_global=pg.n_global, num_parts=pg.num_parts, v_max=pg.v_max,
            d_max=pg.d_max, r_max=pg.r_max, mailbox_cap=pg.mailbox_cap,
            num_subgraphs=pg.num_subgraphs.tolist(),
            attrs=sorted(pg.attrs.keys()), version=pg.version,
        )
        with open(os.path.join(gdir, "meta.json"), "w") as f:
            json.dump(meta, f)
        np.savez(os.path.join(gdir, "global_maps.npz"),
                 part_of=pg.part_of, local_of=pg.local_of)
        for p in range(pg.num_parts):
            pdir = os.path.join(gdir, f"part_{p}")
            os.makedirs(pdir, exist_ok=True)
            nbr, wgt = pg.nbr[p], pg.wgt[p]
            valid = nbr != -1
            counts = valid.sum(1)
            indptr = np.zeros(pg.v_max + 1, np.int64)
            np.cumsum(counts, out=indptr[1:])
            np.savez(os.path.join(pdir, "topology.npz"),
                     csr_indptr=indptr, csr_indices=nbr[valid],
                     csr_weights=wgt[valid], d_pad=np.int64(pg.d_max),
                     **{k: getattr(pg, k)[p] for k in _DENSE_FIELDS})
            for aname, arr in pg.attrs.items():
                np.savez(os.path.join(pdir, f"attr_{aname}.npz"), value=arr[p])

    # ---------------- read path ----------------
    def meta(self, name: str) -> dict:
        with open(os.path.join(self.root, name, "meta.json")) as f:
            return json.load(f)

    def load_partition(self, name: str, p: int,
                       attrs: Optional[Sequence[str]] = None) -> dict:
        """Load ONE partition's slices — what a single worker reads at start.
        Rebuilds the device ELL layout from the compact CSR slice."""
        from repro.gofs.formats import ell_from_csr
        pdir = os.path.join(self.root, name, f"part_{p}")
        with np.load(os.path.join(pdir, "topology.npz")) as z:
            out = {k: z[k] for k in z.files
                   if not k.startswith("csr_") and k != "d_pad"}
            n_rows = out["vmask"].shape[0]
            nbr, wgt = ell_from_csr(z["csr_indptr"], z["csr_indices"],
                                    z["csr_weights"], n_rows,
                                    d_max=int(z["d_pad"]), lane_pad=1)
            out["nbr"], out["wgt"] = nbr, wgt
        for aname in (attrs or []):
            with np.load(os.path.join(pdir, f"attr_{aname}.npz")) as z:
                out[f"attr_{aname}"] = z["value"]
        return out

    def load_partitioned(self, name: str,
                         attrs: Optional[Sequence[str]] = None) -> PartitionedGraph:
        m = self.meta(name)
        P = m["num_parts"]
        parts = [self.load_partition(name, p, attrs) for p in range(P)]
        with np.load(os.path.join(self.root, name, "global_maps.npz")) as z:
            part_of, local_of = z["part_of"], z["local_of"]
        batch = {k: np.stack([pt[k] for pt in parts]) for k in _TOPO_FIELDS}
        a = {an: np.stack([pt[f"attr_{an}"] for pt in parts]) for an in (attrs or [])}
        return PartitionedGraph(
            n_global=m["n_global"], num_parts=P, v_max=m["v_max"],
            part_of=part_of, local_of=local_of,
            num_subgraphs=np.asarray(m["num_subgraphs"], np.int32),
            mailbox_cap=m["mailbox_cap"], attrs=a,
            version=m.get("version", 0), **batch)
