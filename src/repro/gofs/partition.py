"""Graph partitioners.

The paper uses METIS (balance vertices, minimize edge cut). METIS is not
available offline, so we implement:

- ``hash_partition``         — random hashing (what Giraph/HDFS does; baseline)
- ``bfs_grow_partition``     — multi-seed BFS region growing with vertex-count
                               balancing; a METIS-like heuristic that keeps
                               connected regions together (low edge cut, few
                               sub-graphs per partition)
- ``subgraph_balanced_partition`` — the paper's §7 "future work": balance the
                               NUMBER and SIZE of sub-graphs per partition to
                               kill stragglers. We pack whole WCCs with a
                               greedy longest-processing-time bin packer and
                               split WCCs larger than a partition via BFS.
"""
from __future__ import annotations

import numpy as np
import scipy.sparse as sp
import scipy.sparse.csgraph as csgraph

from repro.gofs.formats import Graph


def hash_partition(g: Graph, num_parts: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, num_parts, g.n).astype(np.int32)


def _bfs_grow(adj: sp.csr_matrix, num_parts: int, seed: int) -> np.ndarray:
    """Round-robin multi-seed BFS growth; each partition claims <= ceil(n/P)."""
    n = adj.shape[0]
    rng = np.random.default_rng(seed)
    cap = -(-n // num_parts)
    assign = np.full(n, -1, np.int32)
    sizes = np.zeros(num_parts, np.int64)
    frontiers = [list() for _ in range(num_parts)]
    unvisited = np.ones(n, bool)

    def new_seed(p):
        cand = np.flatnonzero(unvisited)
        if cand.size == 0:
            return False
        v = int(cand[rng.integers(0, cand.size)])
        frontiers[p].append(v)
        return True

    for p in range(num_parts):
        new_seed(p)
    active = True
    indptr, indices = adj.indptr, adj.indices
    while active:
        active = False
        for p in range(num_parts):
            if sizes[p] >= cap:
                continue
            if not frontiers[p] and not new_seed(p):
                continue
            nxt = []
            budget = cap - sizes[p]
            for v in frontiers[p]:
                if budget <= 0:
                    nxt.append(v)
                    continue
                if not unvisited[v]:
                    continue
                unvisited[v] = False
                assign[v] = p
                sizes[p] += 1
                budget -= 1
                nxt.extend(int(u) for u in indices[indptr[v]:indptr[v + 1]] if unvisited[u])
            frontiers[p] = nxt
            active = active or bool(nxt) or unvisited.any()
        if unvisited.any() and not any(frontiers):
            for p in range(num_parts):
                if sizes[p] < cap and new_seed(p):
                    active = True
                    break
            else:
                break
    # leftovers (cap-saturated partitions): spill to least-loaded
    left = np.flatnonzero(assign < 0)
    for v in left:
        p = int(np.argmin(sizes))
        assign[v] = p
        sizes[p] += 1
    return assign


def bfs_grow_partition(g: Graph, num_parts: int, seed: int = 0) -> np.ndarray:
    return _bfs_grow(g.undirected_csr(), num_parts, seed)


def subgraph_balanced_partition(g: Graph, num_parts: int, seed: int = 0) -> np.ndarray:
    """Balance WCC count AND size per partition (paper §7 proposal).

    Whole components are LPT-packed into partitions; any component bigger than
    the per-partition capacity is BFS-split first. This is the straggler fix
    the paper calls for after the PageRank-on-LJ result (Fig 5b).
    """
    adj = g.undirected_csr()
    ncc, lab = csgraph.connected_components(adj, directed=False)
    comp_sizes = np.bincount(lab, minlength=ncc)
    cap = -(-g.n // num_parts)
    assign = np.full(g.n, -1, np.int32)

    # split oversized components with BFS growing into ceil(size/cap) pieces
    pieces = []  # list of vertex-index arrays
    for c in np.argsort(comp_sizes)[::-1]:
        verts = np.flatnonzero(lab == c)
        if comp_sizes[c] <= cap:
            pieces.append(verts)
            continue
        k = -(-int(comp_sizes[c]) // cap)
        sub = adj[verts][:, verts]
        sub_assign = _bfs_grow(sub.tocsr(), k, seed)
        for p in range(k):
            pieces.append(verts[sub_assign == p])

    # LPT bin packing of pieces into partitions
    order = np.argsort([-p.size for p in pieces])
    sizes = np.zeros(num_parts, np.int64)
    npieces = np.zeros(num_parts, np.int64)
    for i in order:
        # least loaded by (size, piece-count) — balances both axes the paper names
        p = int(np.lexsort((npieces, sizes))[0])
        assign[pieces[i]] = p
        sizes[p] += pieces[i].size
        npieces[p] += 1
    return assign


def partition_quality(g: Graph, assign: np.ndarray, num_parts: int) -> dict:
    """Edge cut + balance metrics (used by tests and benchmarks)."""
    deg_in = np.diff(g.indptr)
    dst = np.repeat(np.arange(g.n, dtype=np.int64), deg_in)
    src = g.indices.astype(np.int64)
    cut = int((assign[src] != assign[dst]).sum())
    sizes = np.bincount(assign, minlength=num_parts)
    return dict(edge_cut=cut, cut_frac=cut / max(g.nnz, 1),
                max_part=int(sizes.max()), min_part=int(sizes.min()),
                imbalance=float(sizes.max() / max(sizes.mean(), 1e-9)))
