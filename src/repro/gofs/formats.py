"""Graph containers and TPU-friendly adjacency formats.

The global graph is a host-side CSR (scipy). Per-partition adjacency is
ELL-packed (``nbr[V_pad, D_max]`` int32, -1 padded) because a dense rectangular
layout is what VMEM tiling and the VPU want — this is the TPU analogue of the
paper's Kryo-serialized topology slices. Degree-skewed graphs (LJ-like) use
multi-bin ELL to bound padding waste (see repro.kernels).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import scipy.sparse as sp

PAD = -1  # sentinel neighbor index


def dedupe_edges_min(n: int, src: np.ndarray, dst: np.ndarray,
                     wgt: np.ndarray):
    """Collapse parallel (src, dst) edges to ONE edge keeping the MIN weight.

    This is the repo-wide duplicate-edge policy: under distance semantics
    (SSSP/BFS/reachability — the dominant workloads) the cheapest parallel
    edge dominates every shortest path, so min is the only lossless choice;
    summing (what a raw CSR constructor does) corrupts distances, and
    keep-first is input-order dependent. Returns (src, dst, wgt) deduped,
    in key-sorted order (deterministic regardless of input order).
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    wgt = np.asarray(wgt, np.float32)
    if src.size == 0:
        return src, dst, wgt
    key = src * n + dst
    order = np.lexsort((wgt, key))          # by key, then min weight first
    key_s = key[order]
    first = np.r_[True, key_s[1:] != key_s[:-1]]
    keep = order[first]
    return src[keep], dst[keep], wgt[keep]


def grow_last_axis(arr: np.ndarray, extra: int, fill) -> np.ndarray:
    """Pad the last axis by ``extra`` entries of ``fill`` — the lane-padded
    growth step shared by ELL rows, mailbox slot maps, and feed lists."""
    pad = [(0, 0)] * (arr.ndim - 1) + [(0, extra)]
    return np.pad(arr, pad, constant_values=fill)


def _cumcount(keys: np.ndarray) -> np.ndarray:
    """Position of each element within its key group (keys need not be sorted)."""
    if keys.size == 0:
        return np.zeros(0, np.int64)
    order = np.argsort(keys, kind="stable")
    sk = keys[order]
    starts = np.r_[0, np.flatnonzero(sk[1:] != sk[:-1]) + 1]
    grp = np.repeat(np.arange(starts.size), np.diff(np.r_[starts, sk.size]))
    pos_sorted = np.arange(sk.size) - starts[grp]
    pos = np.empty_like(pos_sorted)
    pos[order] = pos_sorted
    return pos


@dataclasses.dataclass
class Graph:
    """A host-side graph: CSR adjacency (in-edges for pull sweeps) + attributes.

    ``indptr/indices/weights`` describe, for each vertex v, its in-neighbors —
    a pull formulation works uniformly for CC/SSSP/PR sweeps. ``out_degree`` is
    kept separately (PageRank normalization). For undirected graphs in == out.
    """
    n: int
    indptr: np.ndarray        # (n+1,) int64 — in-edge CSR
    indices: np.ndarray       # (nnz,) int32 — in-neighbor ids
    weights: np.ndarray       # (nnz,) float32
    out_degree: np.ndarray    # (n,) int32
    directed: bool = False
    attrs: dict = dataclasses.field(default_factory=dict)  # name -> (n,) array

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @staticmethod
    def from_edges(n: int, src: np.ndarray, dst: np.ndarray,
                   weights: Optional[np.ndarray] = None,
                   directed: bool = False) -> "Graph":
        """Duplicate-edge policy: parallel (src, dst) pairs collapse to one
        edge with the MIN weight (``dedupe_edges_min``), identically on the
        directed and undirected paths. The directed path previously let the
        CSR constructor SUM duplicate weights (corrupting SSSP) while the
        undirected path kept an arbitrary first occurrence."""
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        if weights is None:
            weights = np.ones(src.shape[0], np.float32)
        weights = np.asarray(weights, np.float32)
        if not directed:
            src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
            weights = np.concatenate([weights, weights])
        src, dst, weights = dedupe_edges_min(n, src, dst, weights)
        adj = sp.csr_matrix((weights, (dst, src)), shape=(n, n))  # row v = in-nbrs of v
        out_deg = np.bincount(src, minlength=n).astype(np.int32)
        return Graph(n=n, indptr=adj.indptr.astype(np.int64),
                     indices=adj.indices.astype(np.int32),
                     weights=adj.data.astype(np.float32),
                     out_degree=out_deg, directed=directed)

    def csr(self) -> sp.csr_matrix:
        return sp.csr_matrix((self.weights, self.indices, self.indptr), shape=(self.n, self.n))

    def undirected_csr(self) -> sp.csr_matrix:
        """Symmetrized structure for weakly-connected-component discovery."""
        a = self.csr()
        return (a + a.T).tocsr()


def ell_from_csr(indptr: np.ndarray, indices: np.ndarray, weights: np.ndarray,
                 n_rows: int, d_max: Optional[int] = None, lane_pad: int = 8):
    """Pack CSR rows into ELL: (nbr, wgt) of shape (n_rows, D) with PAD fill.

    D is padded to a multiple of ``lane_pad`` (VPU lane alignment; real TPU
    kernels use 128 — tests use 8 to keep smoke shapes small). Vectorized —
    no per-row Python loop.
    """
    indptr = np.asarray(indptr, np.int64)
    deg = np.diff(indptr)
    d = int(deg.max()) if (d_max is None and deg.size) else int(d_max or 0)
    d = max(d, 1)
    d = ((d + lane_pad - 1) // lane_pad) * lane_pad
    if deg.size and int(deg.max()) > d:
        raise ValueError(f"max degree {int(deg.max())} exceeds d_max {d}")
    nbr = np.full((n_rows, d), PAD, np.int32)
    wgt = np.zeros((n_rows, d), np.float32)
    if indices.size:
        rows = np.repeat(np.arange(n_rows, dtype=np.int64), deg)
        pos = np.arange(indices.size, dtype=np.int64) - np.repeat(indptr[:-1], deg)
        nbr[rows, pos] = indices
        wgt[rows, pos] = weights
    return nbr, wgt


@dataclasses.dataclass
class PartitionedGraph:
    """The device-ready partitioned graph: uniform-padded per-partition arrays.

    All arrays carry a leading partition axis P so the batch shards cleanly
    over the mesh 'parts' axis (one partition per chip; virtual partitions
    fold extra partitions into the same device).
    """
    n_global: int
    num_parts: int
    v_max: int                     # padded local vertex count
    # topology (pull ELL over LOCAL in-edges only)
    nbr: np.ndarray                # (P, v_max, d_max) int32, local idx, PAD fill
    wgt: np.ndarray                # (P, v_max, d_max) float32
    vmask: np.ndarray              # (P, v_max) bool — valid vertex slots
    out_degree: np.ndarray         # (P, v_max) int32 — GLOBAL out degree
    # identity maps
    global_id: np.ndarray          # (P, v_max) int64 — local slot -> global vertex id
    part_of: np.ndarray            # (n_global,) int32 — global id -> partition
    local_of: np.ndarray           # (n_global,) int32 — global id -> local slot
    # sub-graph structure (paper §3.2: weakly connected components per partition)
    sg_id: np.ndarray              # (P, v_max) int32 — local sub-graph id, PAD for pad slots
    num_subgraphs: np.ndarray      # (P,) int32
    # remote (cut) edges, stored source-side: u local -> (dst_part, dst_local)
    re_src: np.ndarray             # (P, r_max) int32 local src slot, PAD fill
    re_wgt: np.ndarray             # (P, r_max) float32
    re_dst_part: np.ndarray        # (P, r_max) int32
    re_dst_local: np.ndarray       # (P, r_max) int32
    # mailbox routing plan: remote edge -> slot within its (src,dst) pair row
    re_slot: np.ndarray            # (P, r_max) int32
    mailbox_cap: int               # max messages any (src,dst) partition pair carries
    attrs: dict = dataclasses.field(default_factory=dict)  # name -> (P, v_max)
    # temporal lineage: 0 = the base GoFS build; each applied EdgeDelta batch
    # bumps it (gofs.temporal). Serving caches key results on (graph, version)
    # so stale answers die with the version they were computed at.
    version: int = 0

    @property
    def d_max(self) -> int:
        return int(self.nbr.shape[2])

    @property
    def r_max(self) -> int:
        return int(self.re_src.shape[1])

    def edge_cut(self) -> int:
        return int((self.re_src != PAD).sum())

    def stats(self) -> dict:
        local_edges = int((self.nbr != PAD).sum())
        return dict(
            n=self.n_global, parts=self.num_parts, v_max=self.v_max,
            d_max=self.d_max, r_max=self.r_max, cap=self.mailbox_cap,
            local_edges=local_edges, cut_edges=self.edge_cut(),
            subgraphs=self.num_subgraphs.tolist(),
        )


def partition_graph(g: Graph, assign: np.ndarray, num_parts: int,
                    lane_pad: int = 8) -> PartitionedGraph:
    """Materialize a PartitionedGraph from a global graph + vertex->part map.

    This is the GoFS build step: local ELL slices, sub-graph discovery (scipy
    connected components on the symmetrized local adjacency), remote-edge
    extraction, and the mailbox routing plan (fixed per-pair capacity — the
    TPU analogue of the paper's per-host message aggregation). Fully
    vectorized host-side numpy.
    """
    import scipy.sparse.csgraph as csgraph

    assign = np.asarray(assign, np.int32)
    P = num_parts
    part_of = assign
    counts = np.bincount(assign, minlength=P).astype(np.int64)
    v_max = max(int(counts.max()), 1)

    order = np.argsort(assign, kind="stable")
    offs = np.zeros(P + 1, np.int64)
    np.cumsum(counts, out=offs[1:])
    local_of = np.zeros(g.n, np.int32)
    local_of[order] = (np.arange(g.n, dtype=np.int64) -
                       np.repeat(offs[:-1], counts)).astype(np.int32)

    global_id = np.full((P, v_max), -1, np.int64)
    vmask = np.zeros((P, v_max), bool)
    out_degree = np.zeros((P, v_max), np.int32)
    prow = np.repeat(np.arange(P, dtype=np.int64), counts)
    lrow = local_of[order].astype(np.int64)
    global_id[prow, lrow] = order
    vmask[prow, lrow] = True
    out_degree[prow, lrow] = g.out_degree[order]

    # flatten all in-edges: (dst_global, src_global, w)
    deg_in = np.diff(g.indptr)
    dst_g = np.repeat(np.arange(g.n, dtype=np.int64), deg_in)
    src_g = g.indices.astype(np.int64)
    w_all = g.weights
    e_dst_part = part_of[dst_g]
    e_src_part = part_of[src_g]
    is_local = e_src_part == e_dst_part

    # ---- local in-ELL, packed per (partition, local row) ----
    l_part = e_dst_part[is_local].astype(np.int64)
    l_row = local_of[dst_g[is_local]].astype(np.int64)
    l_src = local_of[src_g[is_local]].astype(np.int32)
    l_w = w_all[is_local]
    rowkey = l_part * v_max + l_row
    pos = _cumcount(rowkey)
    d_max = int(pos.max()) + 1 if pos.size else 1
    d_pad = ((max(d_max, 1) + lane_pad - 1) // lane_pad) * lane_pad
    nbr = np.full((P, v_max, d_pad), PAD, np.int32)
    wgt = np.zeros((P, v_max, d_pad), np.float32)
    nbr[l_part, l_row, pos] = l_src
    wgt[l_part, l_row, pos] = l_w

    # ---- remote edges, stored at SOURCE partition ----
    r_sel = ~is_local
    r_src_part = e_src_part[r_sel].astype(np.int64)
    r_src_loc = local_of[src_g[r_sel]].astype(np.int32)
    r_dst_part = e_dst_part[r_sel].astype(np.int32)
    r_dst_loc = local_of[dst_g[r_sel]].astype(np.int32)
    r_wgt = w_all[r_sel]
    fillpos = _cumcount(r_src_part)
    r_max = int(fillpos.max()) + 1 if fillpos.size else 1
    re_src = np.full((P, r_max), PAD, np.int32)
    re_wgt = np.zeros((P, r_max), np.float32)
    re_dp = np.zeros((P, r_max), np.int32)
    re_dl = np.zeros((P, r_max), np.int32)
    re_slot = np.zeros((P, r_max), np.int32)
    re_src[r_src_part, fillpos] = r_src_loc
    re_wgt[r_src_part, fillpos] = r_wgt
    re_dp[r_src_part, fillpos] = r_dst_part
    re_dl[r_src_part, fillpos] = r_dst_loc
    pairkey = r_src_part * P + r_dst_part
    slot = _cumcount(pairkey)
    re_slot[r_src_part, fillpos] = slot.astype(np.int32)
    cap = int(slot.max()) + 1 if slot.size else 1

    # ---- sub-graph discovery: weakly connected components of LOCAL adjacency ----
    sg_id = np.full((P, v_max), PAD, np.int32)
    num_sg = np.zeros(P, np.int32)
    # one global sparse matrix in "partition-block" coordinates: since local
    # edges never cross partitions, components of the block-diagonal matrix
    # are exactly the per-partition components.
    gr = (l_part * v_max + l_row)
    gc = (l_part * v_max + l_src)
    size = P * v_max
    a = sp.csr_matrix((np.ones(gr.size, np.int8), (gr, gc)), shape=(size, size))
    ncc, lab = csgraph.connected_components(a + a.T, directed=False)
    lab = lab.reshape(P, v_max)
    for p in range(P):
        m = vmask[p]
        if not m.any():
            continue
        labs = lab[p][m]
        uniq, dense = np.unique(labs, return_inverse=True)
        sg_id[p, m] = dense.astype(np.int32)
        num_sg[p] = len(uniq)

    attrs = {}
    for name, arr in g.attrs.items():
        a2 = np.zeros((P, v_max), arr.dtype)
        a2[prow, lrow] = arr[order]
        attrs[name] = a2

    return PartitionedGraph(
        n_global=g.n, num_parts=P, v_max=v_max,
        nbr=nbr, wgt=wgt, vmask=vmask, out_degree=out_degree,
        global_id=global_id, part_of=part_of, local_of=local_of,
        sg_id=sg_id, num_subgraphs=num_sg,
        re_src=re_src, re_wgt=re_wgt, re_dst_part=re_dp, re_dst_local=re_dl,
        re_slot=re_slot, mailbox_cap=cap, attrs=attrs,
    )
