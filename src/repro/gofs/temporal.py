"""Temporal GoFS: versioned edge-delta batches + incremental graph update.

The paper co-designed GoFS for *time-series* graphs — a new snapshot per
time step — but a full GoFS build per snapshot throws away the fact that
consecutive snapshots share almost all structure. This module makes the
partitioned graph a versioned object:

    EdgeDelta       one batch of edge insertions/removals (global vertex ids)
    apply_delta     PartitionedGraph @ version k  ->  version k+1, IN PLACE
                    of the GoFS layout (ELL rows patched, remote-edge slots
                    reused, sub-graphs rediscovered only in touched
                    partitions) — no global rebuild — plus the per-partition
                    *dirty-vertex* seed sets the incremental algorithms
                    (algorithms.incremental) restart from
    TemporalStore   GoFSStore + an append-only chain of delta slices
                    (<graph>/delta_<v>.npz); materialize() replays the chain
                    to any version

Delta semantics (documented policy, same as ``Graph.from_edges``):
  - removals apply BEFORE insertions within one batch;
  - inserting an edge that already exists updates its weight to the MIN of
    old and new (the repo-wide duplicate policy — distance semantics);
  - removing an edge that doesn't exist is counted (``stats['remove_missed']``)
    and otherwise ignored;
  - on undirected graphs each delta edge is applied in both directions.

Vertex sets are fixed across versions (edge deltas only), so every identity
map (global_id / part_of / local_of) and all attribute slices are shared
between versions untouched.
"""
from __future__ import annotations

import dataclasses
import glob
import os
import re
from typing import Optional, Sequence

import numpy as np
import scipy.sparse as sp
import scipy.sparse.csgraph as csgraph

from repro.gofs.formats import (PAD, PartitionedGraph, dedupe_edges_min,
                                grow_last_axis)
from repro.gofs.store import GoFSStore


class DeltaValidationError(ValueError):
    """A malformed EdgeDelta batch. Raised BEFORE any state is touched, so
    rejection is atomic — the alternative (out-of-range ids indexing part_of,
    NaN weights poisoning min-reductions, an edge both inserted and removed
    racing the removals-first rule) silently corrupts the versioned layout."""


def validate_delta(pg, delta: "EdgeDelta", directed: bool = False,
                   weight_domain: str = "nonneg") -> None:
    """Gopher Shield input hardening for :func:`apply_delta`.

    Rejects (typed :class:`DeltaValidationError`):
      - vertex ids outside ``[0, pg.n_global)`` — they would index the
        part_of/local_of maps out of bounds or wrap negatively;
      - NaN insert weights — NaN is absorbing under min/⊕ and would poison
        every reduction it reaches;
      - negative insert weights under ``weight_domain='nonneg'`` (the
        repo-wide distance semantics: min_plus shortest paths assume
        nonnegative edges); semirings that allow them pass
        ``weight_domain='any'``;
      - an edge both inserted and removed in ONE batch (canonicalized for
        undirected graphs) — under the removals-first rule that nets to an
        insert, but callers that meant the opposite order get silent
        corruption, so contradictory batches must be split or netted by the
        caller.
    """
    n = pg.n_global
    for nm, arr in (("insert_src", delta.insert_src),
                    ("insert_dst", delta.insert_dst),
                    ("remove_src", delta.remove_src),
                    ("remove_dst", delta.remove_dst)):
        a = np.asarray(arr)
        if a.size and ((a < 0).any() or (a >= n).any()):
            bad = a[(a < 0) | (a >= n)]
            raise DeltaValidationError(
                f"{nm} vertex ids out of range [0, {n}): "
                f"{bad[:5].tolist()}")
    w = np.asarray(delta.insert_wgt)
    if w.size and np.isnan(w).any():
        raise DeltaValidationError("insert_wgt contains NaN")
    if weight_domain not in ("nonneg", "any"):
        raise DeltaValidationError(
            f"unknown weight_domain {weight_domain!r} "
            "(expected 'nonneg' or 'any')")
    if weight_domain == "nonneg" and w.size and (w < 0).any():
        raise DeltaValidationError(
            f"negative insert_wgt {w[w < 0][:5].tolist()} under the "
            "'nonneg' weight domain; pass weight_domain='any' for "
            "semirings that permit negative weights")
    if delta.insert_src.size and delta.remove_src.size:
        def keys(s, d):
            s = np.asarray(s, np.int64)
            d = np.asarray(d, np.int64)
            if not directed:
                s, d = np.minimum(s, d), np.maximum(s, d)
            return s * n + d
        both = np.intersect1d(keys(delta.insert_src, delta.insert_dst),
                              keys(delta.remove_src, delta.remove_dst))
        if both.size:
            pairs = [(int(k // n), int(k % n)) for k in both[:5]]
            raise DeltaValidationError(
                f"contradictory batch: edges both inserted and removed "
                f"in one delta: {pairs}")


@dataclasses.dataclass
class EdgeDelta:
    """One batch of edge mutations in GLOBAL vertex ids."""
    insert_src: np.ndarray          # (Ni,) int64
    insert_dst: np.ndarray          # (Ni,) int64
    insert_wgt: np.ndarray          # (Ni,) float32
    remove_src: np.ndarray          # (Nr,) int64
    remove_dst: np.ndarray          # (Nr,) int64

    @staticmethod
    def of(insert_src=(), insert_dst=(), insert_wgt=None,
           remove_src=(), remove_dst=()) -> "EdgeDelta":
        isrc = np.asarray(insert_src, np.int64).reshape(-1)
        idst = np.asarray(insert_dst, np.int64).reshape(-1)
        iwgt = (np.ones(isrc.shape[0], np.float32) if insert_wgt is None
                else np.asarray(insert_wgt, np.float32).reshape(-1))
        return EdgeDelta(
            insert_src=isrc, insert_dst=idst, insert_wgt=iwgt,
            remove_src=np.asarray(remove_src, np.int64).reshape(-1),
            remove_dst=np.asarray(remove_dst, np.int64).reshape(-1))

    @staticmethod
    def inserts(src, dst, wgt=None) -> "EdgeDelta":
        return EdgeDelta.of(insert_src=src, insert_dst=dst, insert_wgt=wgt)

    @staticmethod
    def removes(src, dst) -> "EdgeDelta":
        return EdgeDelta.of(remove_src=src, remove_dst=dst)

    @property
    def num_inserts(self) -> int:
        return int(self.insert_src.shape[0])

    @property
    def num_removes(self) -> int:
        return int(self.remove_src.shape[0])


@dataclasses.dataclass
class DeltaResult:
    """apply_delta's output: the next-version graph + incremental seeds."""
    pg: PartitionedGraph
    # (P, v_max) bool — SOURCE endpoints of inserted edges. Seeding these as
    # the frontier makes masked sweeps re-relax their out-rows and makes
    # their (possibly unchanged) values re-announce over new remote edges.
    dirty_insert: np.ndarray
    # (P, v_max) bool — DST endpoints of removed edges (their in-list
    # shrank, so their values may be stale-optimistic). The incremental
    # layer expands these to affected sub-graphs via the meta-graph.
    dirty_remove: np.ndarray
    stats: dict
    # zero-repack graph block (core.blocks.patch_host_block output): present
    # when the caller passed the previous version's HOST block — the derived
    # arrays (binned ELL, mailbox inverse maps) patched in O(|delta|)
    # instead of re-packed from scratch.
    block: Optional[dict] = None
    # the patch-event log (touched_rows, rdel, radd): replay it with
    # core.blocks.patch_host_block to patch FURTHER replicas of the previous
    # version's block (a fleet holding per-mesh copies patches each in
    # O(|delta|) from one apply_delta).
    events: Optional[tuple] = None


def _mirror(src, dst, wgt=None):
    s = np.concatenate([src, dst])
    d = np.concatenate([dst, src])
    if wgt is None:
        return s, d
    return s, d, np.concatenate([wgt, wgt])


def _local_subgraphs(nbr: np.ndarray, vmask: np.ndarray, parts):
    """Rediscover weakly-connected components (sub-graphs) of the given
    partitions in ONE scipy call: local edges never cross partitions, so the
    block-diagonal matrix over the touched partitions decomposes exactly
    into per-partition components (same trick as partition_graph).
    Yields (p, sg_id_p, num_sg_p)."""
    parts = list(parts)
    if not parts:
        return
    v_max = nbr.shape[1]
    sub = nbr[parts]
    valid = sub != PAD
    blk, rows, _ = np.nonzero(valid)
    cols = sub[valid]
    size = len(parts) * v_max
    a = sp.csr_matrix((np.ones(blk.size, np.int8),
                       (blk * v_max + rows, blk * v_max + cols)),
                      shape=(size, size))
    _, lab = csgraph.connected_components(a + a.T, directed=False)
    lab = lab.reshape(len(parts), v_max)
    for i, p in enumerate(parts):
        sg = np.full(v_max, PAD, np.int32)
        m = vmask[p]
        if m.any():
            uniq, dense = np.unique(lab[i][m], return_inverse=True)
            sg[m] = dense.astype(np.int32)
            yield p, sg, len(uniq)
        else:
            yield p, sg, 0


def apply_delta(pg: PartitionedGraph, delta: EdgeDelta,
                directed: bool = False, lane_pad: int = 8,
                block: Optional[dict] = None, validate: bool = True,
                weight_domain: str = "nonneg") -> DeltaResult:
    """Produce the next graph version WITHOUT re-running the GoFS build.

    Host-side O(|delta|) patching of the device layout: local inserts fill
    PAD holes in the destination's ELL row (rows grow by ``lane_pad`` lanes
    only when full), remote inserts reuse freed mailbox slots of their
    partition pair before widening the capacity, and sub-graph ids are
    rediscovered only in partitions whose local topology changed.

    ``block``: the previous version's HOST graph block
    (core.blocks.host_graph_block). When given, the derived engine arrays
    (binned ELL adjacency, mailbox inverse maps, outbox slot map) are
    patched in O(|delta|) too and returned as ``DeltaResult.block`` — the
    zero-repack versioned-block path. The mailbox cap then becomes STICKY
    (grows lane-padded on overflow, never shrinks) so the patched block's
    flat slot positions — and the compiled BSP loop keyed on its shapes —
    survive the version bump. The block's Gopher Mesh traffic profile
    (``wire_ewma``) is carried across the version and raised to the dirty
    frontier's expected per-pair slot counts (core.tiers.announce_frontier),
    so tier plans rebuilt from the patched block give freshly woken pairs
    enough width.
    """
    if validate:
        validate_delta(pg, delta, directed=directed,
                       weight_domain=weight_domain)
    n = pg.n_global
    P, v_max = pg.num_parts, pg.v_max
    part_of, local_of = pg.part_of, pg.local_of

    rsrc, rdst = delta.remove_src, delta.remove_dst
    isrc, idst, iwgt = delta.insert_src, delta.insert_dst, delta.insert_wgt
    if not directed:
        if rsrc.size:
            rsrc, rdst = _mirror(rsrc, rdst)
        if isrc.size:
            isrc, idst, iwgt = _mirror(isrc, idst, iwgt)
    if isrc.size:
        isrc, idst, iwgt = dedupe_edges_min(n, isrc, idst, iwgt)
    if rsrc.size:
        _, uniq = np.unique(rsrc * n + rdst, return_index=True)
        rsrc, rdst = rsrc[uniq], rdst[uniq]

    nbr = pg.nbr.copy()
    wgt = pg.wgt.copy()
    re_src = pg.re_src.copy()
    re_wgt = pg.re_wgt.copy()
    re_dp = pg.re_dst_part.copy()
    re_dl = pg.re_dst_local.copy()
    re_slot = pg.re_slot.copy()
    out_degree = pg.out_degree.copy()
    sg_id = pg.sg_id.copy()
    num_sg = pg.num_subgraphs.copy()

    dirty_ins = np.zeros((P, v_max), bool)
    dirty_rem = np.zeros((P, v_max), bool)
    touched_local = set()
    # zero-repack event log (consumed by core.blocks.patch_host_block)
    touched_mask = np.zeros((P, v_max), bool)  # local rows whose nbr/wgt changed
    ev_rdel = []                # [(src_p, dst_p, dst_v, slot)]
    ev_radd = []                # [(src_p, dst_p, dst_v, slot, edge_idx)]
    stats = dict(inserted=0, weight_updated=0, removed=0, remove_missed=0)

    # ---- removals first (an insert re-adding a removed edge nets to insert)
    for u, v in zip(rsrc, rdst):
        pu, lu = int(part_of[u]), int(local_of[u])
        pv, lv = int(part_of[v]), int(local_of[v])
        if pu == pv:
            j = np.flatnonzero(nbr[pv, lv] == lu)
            if j.size == 0:
                stats["remove_missed"] += 1
                continue
            nbr[pv, lv, j[0]] = PAD
            wgt[pv, lv, j[0]] = 0.0
            touched_local.add(pv)
            touched_mask[pv, lv] = True
        else:
            m = np.flatnonzero((re_src[pu] == lu) & (re_dp[pu] == pv)
                               & (re_dl[pu] == lv))
            if m.size == 0:
                stats["remove_missed"] += 1
                continue
            # free the slot; its (pair, slot) id becomes reusable by inserts
            ev_rdel.append((pu, pv, lv, int(re_slot[pu, m[0]])))
            re_src[pu, m[0]] = PAD
            re_wgt[pu, m[0]] = 0.0
        out_degree[pu, lu] -= 1
        dirty_rem[pv, lv] = True
        stats["removed"] += 1

    # ---- insertions
    for u, v, w in zip(isrc, idst, iwgt):
        pu, lu = int(part_of[u]), int(local_of[u])
        pv, lv = int(part_of[v]), int(local_of[v])
        dirty_ins[pu, lu] = True
        if pu == pv:
            j = np.flatnonzero(nbr[pv, lv] == lu)
            if j.size:                          # duplicate insert: min policy
                wgt[pv, lv, j[0]] = min(float(wgt[pv, lv, j[0]]), float(w))
                stats["weight_updated"] += 1
                touched_mask[pv, lv] = True
                continue
            free = np.flatnonzero(nbr[pv, lv] == PAD)
            if free.size == 0:
                nbr = grow_last_axis(nbr, lane_pad, PAD)
                wgt = grow_last_axis(wgt, lane_pad, 0.0)
                free = np.flatnonzero(nbr[pv, lv] == PAD)
            nbr[pv, lv, free[0]] = lu
            wgt[pv, lv, free[0]] = w
            touched_local.add(pv)
            touched_mask[pv, lv] = True
        else:
            m = np.flatnonzero((re_src[pu] == lu) & (re_dp[pu] == pv)
                               & (re_dl[pu] == lv))
            if m.size:
                re_wgt[pu, m[0]] = min(float(re_wgt[pu, m[0]]), float(w))
                stats["weight_updated"] += 1
                continue
            free = np.flatnonzero(re_src[pu] == PAD)
            if free.size == 0:
                re_src = grow_last_axis(re_src, lane_pad, PAD)
                re_wgt = grow_last_axis(re_wgt, lane_pad, 0.0)
                re_dp = grow_last_axis(re_dp, lane_pad, 0)
                re_dl = grow_last_axis(re_dl, lane_pad, 0)
                re_slot = grow_last_axis(re_slot, lane_pad, 0)
                free = np.flatnonzero(re_src[pu] == PAD)
            e = free[0]
            # smallest slot unused by live edges of the (pu, pv) pair —
            # freed slots are recycled so the mailbox doesn't creep wider
            pair = (re_src[pu] != PAD) & (re_dp[pu] == pv)
            used = np.zeros(int(pair.sum()) + 1, bool)
            in_range = re_slot[pu][pair]
            used[in_range[in_range < used.size]] = True
            slot = int(np.flatnonzero(~used)[0])
            re_src[pu, e] = lu
            re_wgt[pu, e] = w
            re_dp[pu, e] = pv
            re_dl[pu, e] = lv
            re_slot[pu, e] = slot
            ev_radd.append((pu, pv, lv, slot, int(e)))
        out_degree[pu, lu] += 1
        stats["inserted"] += 1

    # ---- mailbox capacity: exact fit over live remote edges; STICKY when
    # patching a block (flat slot positions must stay valid — growth is
    # lane-padded so one overflowing pair doesn't recompile every version)
    live = re_src != PAD
    cap = int(re_slot[live].max()) + 1 if live.any() else 1
    if block is not None:
        cap_block = block["ob_inv"].shape[1] // P
        if cap > cap_block:
            cap = ((cap + lane_pad - 1) // lane_pad) * lane_pad
        cap = max(cap, cap_block)

    # ---- sub-graph rediscovery, touched partitions only (one scipy call)
    for p, sg_p, n_p in _local_subgraphs(nbr, pg.vmask, sorted(touched_local)):
        sg_id[p], num_sg[p] = sg_p, n_p

    new_pg = PartitionedGraph(
        n_global=n, num_parts=P, v_max=v_max,
        nbr=nbr, wgt=wgt, vmask=pg.vmask, out_degree=out_degree,
        global_id=pg.global_id, part_of=part_of, local_of=local_of,
        sg_id=sg_id, num_subgraphs=num_sg,
        re_src=re_src, re_wgt=re_wgt, re_dst_part=re_dp, re_dst_local=re_dl,
        re_slot=re_slot, mailbox_cap=cap, attrs=pg.attrs,
        version=pg.version + 1,
    )
    stats["version"] = new_pg.version
    stats["touched_partitions"] = len(touched_local)
    touched_rows = np.argwhere(touched_mask)       # sorted (p, v) pairs
    new_block = None
    if block is not None:
        from repro.core.blocks import patch_host_block
        from repro.core.tiers import announce_frontier
        new_block = patch_host_block(block, new_pg, touched_rows,
                                     ev_rdel, ev_radd, lane_pad=lane_pad)
        # Gopher Mesh: patch the per-pair traffic profile through the
        # version bump — the dirty frontier IS the next run's prime-round
        # traffic, so the pairs this delta just woke are raised to at least
        # their expected slot counts before any tier plan is rebuilt
        announce_frontier(new_block, new_pg, dirty_ins | dirty_rem)
    return DeltaResult(pg=new_pg, dirty_insert=dirty_ins,
                       dirty_remove=dirty_rem, stats=stats, block=new_block,
                       events=(touched_rows, ev_rdel, ev_radd))


class TemporalStore(GoFSStore):
    """GoFSStore + an append-only chain of edge-delta slices per graph.

    Version 0 is the base GoFS build (``build``/``write``); each
    ``append_delta`` adds ``<graph>/delta_<v>.npz``. Readers reassemble any
    version with ``materialize`` — a base load plus O(sum |delta|) patching,
    never a re-partition.
    """

    def append_delta(self, name: str, delta: EdgeDelta,
                     directed: bool = False) -> int:
        v = self.latest_version(name) + 1
        path = os.path.join(self.root, name, f"delta_{v}.npz")
        np.savez(path, insert_src=delta.insert_src,
                 insert_dst=delta.insert_dst, insert_wgt=delta.insert_wgt,
                 remove_src=delta.remove_src, remove_dst=delta.remove_dst,
                 directed=np.bool_(directed))
        return v

    def latest_version(self, name: str) -> int:
        pat = os.path.join(self.root, name, "delta_*.npz")
        vs = [int(m.group(1)) for f in glob.glob(pat)
              if (m := re.search(r"delta_(\d+)\.npz$", f))]
        return max(vs, default=0)

    def load_delta(self, name: str, version: int):
        """Returns (EdgeDelta, directed)."""
        path = os.path.join(self.root, name, f"delta_{version}.npz")
        with np.load(path) as z:
            d = EdgeDelta(insert_src=z["insert_src"],
                          insert_dst=z["insert_dst"],
                          insert_wgt=z["insert_wgt"],
                          remove_src=z["remove_src"],
                          remove_dst=z["remove_dst"])
            return d, bool(z["directed"])

    def materialize(self, name: str, version: Optional[int] = None,
                    attrs: Optional[Sequence[str]] = None) -> PartitionedGraph:
        """Replay deltas 1..version over the base build. ``version=None``
        means latest. The returned graph's ``.version`` is the replay depth."""
        if version is None:
            version = self.latest_version(name)
        pg = self.load_partitioned(name, attrs=attrs)
        for v in range(1, version + 1):
            delta, directed = self.load_delta(name, v)
            pg = apply_delta(pg, delta, directed=directed).pg
        return pg
