"""Synthetic graph generators matching the paper's dataset shapes (Table 1).

RN  (California road network): high diameter (849), tiny degrees, 2,638 WCCs
    -> ``road_grid``: 2-D grid with random edge deletions (creates many
       components and a long diameter).
TR  (Internet traceroute):     powerlaw, diameter 25, ONE giant WCC with a
    few huge hubs (ISPs + a timeout vertex)
    -> ``trace_star``: preferential-attachment forest re-rooted at a handful
       of mega-hubs, plus one "timeout" hub wired broadly.
LJ  (LiveJournal social):      dense powerlaw, diameter ~16, 1,877 WCCs
    -> ``powerlaw_social``: Barabási–Albert-style preferential attachment
       with m>=5 plus a dust of small isolated components.

All generators are numpy-native (no networkx) so benchmark-scale graphs
(10^5..10^6 vertices) build in seconds on one CPU.
"""
from __future__ import annotations

import numpy as np

from repro.gofs.formats import Graph


def road_grid(rows: int, cols: int, drop_frac: float = 0.03,
              seed: int = 0, weighted: bool = False) -> Graph:
    """Grid graph with random deletions — RN analogue (long diameter, many WCCs)."""
    rng = np.random.default_rng(seed)
    n = rows * cols
    v = np.arange(n, dtype=np.int64).reshape(rows, cols)
    right = np.stack([v[:, :-1].ravel(), v[:, 1:].ravel()], 1)
    down = np.stack([v[:-1, :].ravel(), v[1:, :].ravel()], 1)
    e = np.concatenate([right, down])
    keep = rng.random(e.shape[0]) >= drop_frac
    e = e[keep]
    w = rng.uniform(1.0, 10.0, e.shape[0]).astype(np.float32) if weighted else None
    return Graph.from_edges(n, e[:, 0], e[:, 1], weights=w, directed=False)


def powerlaw_social(n: int, m: int = 5, dust_frac: float = 0.02,
                    seed: int = 0) -> Graph:
    """Preferential-attachment graph + small isolated 'dust' — LJ analogue.

    Vectorized BA approximation: new vertex t attaches to m targets sampled
    from the current edge-endpoint multiset (degree-proportional).
    """
    rng = np.random.default_rng(seed)
    n_dust = int(n * dust_frac)
    n_core = n - n_dust
    m = min(m, n_core - 1)
    # seed clique of m+1 vertices
    seed_v = np.arange(m + 1)
    si, sj = np.triu_indices(m + 1, 1)
    targets = np.concatenate([seed_v[si], seed_v[sj]])  # endpoint multiset
    srcs = [seed_v[si]]
    dsts = [seed_v[sj]]
    # grow in chunks for speed; sampling from the endpoint multiset of the
    # PREVIOUS chunk is a standard fast BA approximation
    t = m + 1
    while t < n_core:
        chunk = min(max(1024, t), n_core - t)
        news = np.arange(t, t + chunk, dtype=np.int64)
        tgt = targets[rng.integers(0, targets.size, size=(chunk, m))]
        src = np.repeat(news, m)
        dst = tgt.ravel()
        srcs.append(src)
        dsts.append(dst)
        targets = np.concatenate([targets, src, dst])
        if targets.size > 4_000_000:  # bound memory; degree dist already set
            targets = targets[rng.integers(0, targets.size, size=2_000_000)]
        t += chunk
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    # dust: tiny 2-3 vertex components
    if n_dust >= 2:
        dv = np.arange(n_core, n, dtype=np.int64)
        src = np.concatenate([src, dv[:-1:2]])
        dst = np.concatenate([dst, dv[1::2][: dv[:-1:2].size]])
    sel = src != dst
    return Graph.from_edges(n, src[sel], dst[sel], directed=False)


def trace_star(n: int, n_hubs: int = 8, seed: int = 0) -> Graph:
    """Traceroute-like: giant single WCC, powerlaw, few mega-hubs — TR analogue."""
    rng = np.random.default_rng(seed)
    hubs = np.arange(n_hubs, dtype=np.int64)
    rest = np.arange(n_hubs, n, dtype=np.int64)
    # each non-hub attaches to a random earlier vertex (tree => diameter ~log n)
    parent = rng.integers(0, np.maximum(rest - 1, 1))
    src = [rest]
    dst = [parent.astype(np.int64)]
    # the "timeout vertex": hub 0 connects to a broad random sample (paper: one
    # vertex with O(millions) degree that punishes naive vertex-balanced loads)
    fan = rng.choice(rest, size=max(n // 20, 1), replace=False)
    src.append(np.full(fan.size, hubs[0], np.int64))
    dst.append(fan)
    # remaining hubs get moderate fans
    for h in hubs[1:]:
        f = rng.choice(rest, size=max(n // 200, 1), replace=False)
        src.append(np.full(f.size, h, np.int64))
        dst.append(f)
    # hub backbone
    src.append(hubs[:-1])
    dst.append(hubs[1:])
    src = np.concatenate(src)
    dst = np.concatenate(dst)
    sel = src != dst
    return Graph.from_edges(n, src[sel], dst[sel], directed=False)


def random_graph(n: int, avg_degree: float = 4.0, seed: int = 0,
                 weighted: bool = False) -> Graph:
    """Erdős–Rényi-ish random graph for property tests."""
    rng = np.random.default_rng(seed)
    ne = int(n * avg_degree / 2)
    src = rng.integers(0, n, ne)
    dst = rng.integers(0, n, ne)
    sel = src != dst
    w = rng.uniform(1.0, 5.0, sel.sum()).astype(np.float32) if weighted else None
    return Graph.from_edges(n, src[sel], dst[sel], weights=w, directed=False)
