"""GoFS: Graph-oriented File System — distributed, sub-graph aware graph store.

Co-designed with the Gopher engine (repro.core): the on-disk layout is one
slice-bundle per partition (topology slice + attribute slices), so a worker
loads exactly its partition with zero network movement, mirroring the paper's
GoFS design (write-once / read-many, per-attribute lazy slices).
"""
from repro.gofs.formats import (Graph, PartitionedGraph, dedupe_edges_min,
                                ell_from_csr)
from repro.gofs.generators import road_grid, powerlaw_social, trace_star
from repro.gofs.partition import hash_partition, bfs_grow_partition, subgraph_balanced_partition
from repro.gofs.store import GoFSStore
from repro.gofs.temporal import (DeltaResult, DeltaValidationError, EdgeDelta,
                                 TemporalStore, apply_delta, validate_delta)

__all__ = [
    "Graph", "PartitionedGraph", "ell_from_csr", "dedupe_edges_min",
    "road_grid", "powerlaw_social", "trace_star",
    "hash_partition", "bfs_grow_partition", "subgraph_balanced_partition",
    "GoFSStore", "TemporalStore", "EdgeDelta", "DeltaResult", "apply_delta",
    "DeltaValidationError", "validate_delta",
]
