"""gemma3-4b [dense] — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt; unverified]. Sliding window => long_500k runs."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, d_head=256,
    d_ff=10240, vocab=262144,
    swa_window=1024, swa_pattern=(5, 1),   # 5 local : 1 global
    rope_theta=1_000_000.0, tie_embeddings=True, act="gelu",
    qk_norm=True,
    attn_batch_fold=True,   # h=8 < TP=16: fold attention over all axes (§Perf W2)
)
