"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Transformer BACKBONE only: the vision frontend is a stub — input_specs()
supplies precomputed patch embeddings + (3, B, S) M-RoPE position ids.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936,
    qkv_bias=True, rope_theta=1_000_000.0, mrope=True,
    tie_embeddings=True, embed_inputs=True,
)
