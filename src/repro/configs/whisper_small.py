"""whisper-small [audio] — enc-dec, conv frontend STUB [arXiv:2212.04356;
unverified]. input_specs() supplies precomputed frame embeddings (B, 1500, d);
12 encoder + 12 decoder layers, MHA, learned positions, GELU MLP.
Encoder-decoder: decode cells use the decoder with precomputed cross-KV;
long_500k skipped (full-attention decoder)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="encdec",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865,
    n_enc_layers=12, enc_seq=1500,
    qkv_bias=True, act="gelu", embed_inputs=False,
)
