"""Architecture registry: --arch <id> resolves here."""
from repro.configs.base import ArchConfig, MoECfg, SSMCfg
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2vl
from repro.configs.qwen1_5_110b import CONFIG as _qwen110b
from repro.configs.h2o_danube_1_8b import CONFIG as _danube
from repro.configs.llama3_8b import CONFIG as _llama3
from repro.configs.gemma3_4b import CONFIG as _gemma3
from repro.configs.whisper_small import CONFIG as _whisper
from repro.configs.deepseek_moe_16b import CONFIG as _dsmoe
from repro.configs.qwen3_moe_30b_a3b import CONFIG as _qwen3moe
from repro.configs.falcon_mamba_7b import CONFIG as _falcon
from repro.configs.zamba2_1_2b import CONFIG as _zamba

ARCHS = {c.name: c for c in [
    _qwen2vl, _qwen110b, _danube, _llama3, _gemma3,
    _whisper, _dsmoe, _qwen3moe, _falcon, _zamba,
]}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ArchConfig", "MoECfg", "SSMCfg", "ARCHS", "get_config"]
