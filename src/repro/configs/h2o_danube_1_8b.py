"""h2o-danube-1.8b [dense] — llama+mistral mix, sliding-window attention
[arXiv:2401.16818; hf]. SWA makes it long_500k-eligible."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab=32000,
    swa_window=4096, rope_theta=10000.0,
)
