"""zamba2-1.2b [hybrid] — Mamba2 backbone + SHARED attention block applied
every `attn_every` layers (weights reused — the paper-series parameter
sharing) [arXiv:2411.15242; hf]. ssm_state=64."""
from repro.configs.base import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000,
    attn_every=6,
    ssm=SSMCfg(d_state=64, d_conv=4, expand=2, version=2,
               n_heads=64, head_dim=64, chunk=64),
)
