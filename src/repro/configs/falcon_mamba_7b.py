"""falcon-mamba-7b [ssm] — pure Mamba1 (S6 selective scan), attention-free
[arXiv:2410.05355; unverified]. ssm_state=16, d_inner = 2*d_model."""
from repro.configs.base import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=65024,
    ssm=SSMCfg(d_state=16, d_conv=4, expand=2, version=1, chunk=64),
)
