"""Architecture config schema + shape suite for the assigned pool.

Every architecture in src/repro/configs/<id>.py instantiates ``ArchConfig``.
``reduced()`` returns the CPU-smoke-test variant (same family/topology, tiny
dims). Shape applicability (which of the four shape cells run) is derived
from the family per DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple



@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.001


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int
    d_conv: int = 4
    expand: int = 2
    version: int = 1            # 1 = Mamba1 (S6), 2 = Mamba2 (SSD)
    n_heads: int = 0            # Mamba2: #heads (d_inner = n_heads * head_dim)
    head_dim: int = 64
    chunk: int = 64             # scan chunk (activation-memory knob)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None           # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mrope: bool = False                    # qwen2-vl M-RoPE (3-section rotary)
    swa_window: Optional[int] = None       # sliding-window size
    swa_pattern: Optional[Tuple[int, int]] = None  # (local, global) per cycle, e.g. (5,1)
    tie_embeddings: bool = False
    qk_norm: bool = False                  # gemma3 / qwen3 RMS-norm on q,k
    # batch-fold attention over (pod,data,model) when n_heads < TP (§Perf W2).
    # Big roofline win where replicated attention dominates (gemma3); off by
    # default because the fold boundary costs f32 cotangent copies (whisper
    # regressed on memory capacity).
    attn_batch_fold: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"                      # mlp nonlinearity (swiglu gate)
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    # hybrid (zamba2): shared attention block applied every `attn_every` ssm layers
    attn_every: Optional[int] = None
    # encoder-decoder (whisper): n_layers = decoder layers; encoder below
    n_enc_layers: int = 0
    enc_seq: int = 1500                    # whisper frame count (stub frontend)
    # training
    dtype: str = "bfloat16"                # compute/param dtype (fp32 master in opt)
    remat: bool = True
    # modality stub: inputs are precomputed embeddings, not token ids
    embed_inputs: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (DESIGN.md §Arch-applicability)."""
        return self.family in ("ssm", "hybrid") or self.swa_window is not None

    @property
    def has_decoder(self) -> bool:
        return True  # all pool members autoregress (whisper via its decoder)

    def shapes(self) -> dict:
        """The four assigned input-shape cells; value None = skipped cell."""
        cells = {
            "train_4k": dict(kind="train", seq=4096, batch=256),
            "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
            "decode_32k": dict(kind="decode", seq=32768, batch=128),
            "long_500k": dict(kind="decode", seq=524288, batch=1),
        }
        if not self.sub_quadratic:
            cells["long_500k"] = None
        return cells

    def param_count(self) -> int:
        """Analytic parameter count (drives MODEL_FLOPS = 6·N·D)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "encdec"):
            h, kv, dh = self.n_heads, self.n_kv_heads, self.head_dim
            attn = d * h * dh + 2 * d * kv * dh + h * dh * d
            if self.qkv_bias:
                attn += (h + 2 * kv) * dh
            per_layer += attn + 2 * d  # norms
            if self.moe is not None:
                e = self.moe
                per_layer += (e.n_experts + e.n_shared) * 3 * d * e.d_expert
                per_layer += d * e.n_experts  # router
            else:
                per_layer += 3 * d * self.d_ff
        if self.family == "ssm":
            s = self.ssm
            di = s.expand * d
            per_layer += d * 2 * di + di * s.d_conv + di * (2 * s.d_state + 1) \
                + di * s.d_state + di + di * d + 2 * d
        if self.family == "hybrid":
            s = self.ssm
            di = s.expand * d
            per_layer += d * 2 * di + di * s.d_conv + s.n_heads * (2 * s.d_state) \
                + di + di * d + 2 * d
        n = emb + L * per_layer
        if self.family == "encdec":
            h, kv, dh = self.n_heads, self.n_kv_heads, self.head_dim
            enc_layer = d * h * dh * 2 + 2 * d * kv * dh + h * dh * d + 3 * d * self.d_ff + 3 * d
            n += self.n_enc_layers * enc_layer
        if self.family == "moe":
            pass
        return int(n)

    def active_param_count(self) -> int:
        """MoE: params touched per token (for 6·N_active·D)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        d, L = self.d_model, self.n_layers
        full_ffn = (e.n_experts + e.n_shared) * 3 * d * e.d_expert
        act_ffn = (e.top_k + e.n_shared) * 3 * d * e.d_expert
        return self.param_count() - L * (full_ffn - act_ffn)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dataclasses.asdict(self)
        kw.update(
            n_layers=min(self.n_layers, 2 if self.attn_every is None else (self.attn_every + 1)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_head=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_seq=16,
            dtype="float32",
            swa_window=8 if self.swa_window else None,
        )
        if self.moe is not None:
            kw["moe"] = MoECfg(n_experts=4, top_k=2, d_expert=32,
                               n_shared=self.moe.n_shared and 1)
        if self.ssm is not None:
            kw["ssm"] = SSMCfg(d_state=8, d_conv=4, expand=2,
                               version=self.ssm.version,
                               n_heads=2, head_dim=16, chunk=8)
        if self.attn_every is not None:
            kw["attn_every"] = 2
        # dataclasses.asdict turned nested configs into dicts for moe/ssm when unchanged
        if isinstance(kw.get("moe"), dict):
            kw["moe"] = MoECfg(**kw["moe"])
        if isinstance(kw.get("ssm"), dict):
            kw["ssm"] = SSMCfg(**kw["ssm"])
        return ArchConfig(**kw)
