"""Training substrate: optimizer, steps, data, checkpointing, sharding specs."""
