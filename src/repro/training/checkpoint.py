"""Sharded checkpoint/restore with async save and elastic re-shard.

Layout:
    <dir>/step_<N>/manifest.json        step, mesh shape+axes, tree structure
    <dir>/step_<N>/host_<i>.npz         this host's addressable shard data

Each leaf is stored as the set of its addressable shards (device index ->
array block). On restore, blocks are reassembled into the full array and
re-placed under the *target* mesh's shardings — which may have a different
shape than the mesh that saved it (elastic restart after node loss). BSP
checkpoints of the graph engine reuse the same functions (their state is just
a pytree).
"""
from __future__ import annotations

import json
import os
import threading
import zlib
from typing import Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


# numpy's npz cannot round-trip ml_dtypes (bfloat16 & friends): store a
# same-width integer view plus a dtype marker key.
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8}


def _encode(arr: np.ndarray):
    name = arr.dtype.name
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name]), name
    return arr, None


def _decode(arr: np.ndarray, dtype_name: Optional[str]):
    if dtype_name:
        import ml_dtypes
        return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))
    return arr


def _paths(tree):
    return [jax.tree_util.keystr(p)
            for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]


def _crc(arr) -> int:
    return zlib.crc32(np.ascontiguousarray(np.asarray(arr)).tobytes())


class Checkpointer:
    def __init__(self, directory: str, async_save: bool = False):
        self.dir = directory
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------ save
    def save(self, state, step: int, extra: Optional[dict] = None):
        """Snapshot `state` at `step`. With async_save, device->host copies
        happen synchronously (consistency) but file writes happen on a
        background thread (double-buffering)."""
        self.wait()
        leaves, treedef = _flatten(state)
        paths = _paths(state)
        host_blocks = {}
        for pth, leaf in zip(paths, leaves):
            arr = np.asarray(jax.device_get(leaf))
            arr, dtype_name = _encode(arr)
            host_blocks[pth] = arr
            if dtype_name:
                host_blocks[f"{pth}::dtype"] = np.str_(dtype_name)
        sdir = os.path.join(self.dir, f"step_{step}")
        os.makedirs(sdir, exist_ok=True)
        # Gopher Shield: per-leaf CRC32 over the encoded bytes, recorded in
        # the manifest BEFORE the commit marker — restore-side verification
        # detects bit-rot / truncation of a committed snapshot and falls
        # back to the previous good one (latest_good_step)
        checksums = {k: _crc(v) for k, v in host_blocks.items()}
        manifest = dict(step=step, paths=paths, extra=extra or {},
                        checksums=checksums,
                        process_index=jax.process_index(),
                        process_count=jax.process_count())

        def _write():
            np.savez(os.path.join(sdir, f"host_{jax.process_index()}.npz"),
                     **host_blocks)
            with open(os.path.join(sdir, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            # commit marker: restore ignores partially-written checkpoints
            with open(os.path.join(sdir, "COMMIT"), "w") as f:
                f.write("ok")

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------ restore
    def latest_step(self) -> Optional[int]:
        steps = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and \
                    os.path.exists(os.path.join(self.dir, d, "COMMIT")):
                steps.append(int(d.split("_")[1]))
        return max(steps) if steps else None

    def verify_step(self, step: int) -> bool:
        """Recompute every leaf's CRC32 from the files on disk and compare
        against the manifest. A pre-checksum snapshot (no ``checksums`` key)
        verifies vacuously; unreadable files or any mismatch fail."""
        sdir = os.path.join(self.dir, f"step_{step}")
        try:
            with open(os.path.join(sdir, "manifest.json")) as f:
                manifest = json.load(f)
            want = manifest.get("checksums")
            with np.load(os.path.join(
                    sdir, f"host_{jax.process_index()}.npz")) as z:
                if want is None:
                    return set(z.files) >= set(manifest["paths"])
                if set(want) != set(z.files):
                    return False
                return all(_crc(z[k]) == want[k] for k in z.files)
        except Exception:
            return False

    def latest_good_step(self) -> Optional[int]:
        """The newest committed snapshot that passes checksum verification —
        the automatic-fallback entry point: a corrupted/truncated latest
        snapshot is skipped and recovery restarts one (or more) snapshots
        earlier instead of crashing or silently restoring garbage."""
        steps = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and \
                    os.path.exists(os.path.join(self.dir, d, "COMMIT")):
                steps.append(int(d.split("_")[1]))
        for s in sorted(steps, reverse=True):
            if self.verify_step(s):
                return s
        return None

    def restore(self, state_like, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of `state_like` (arrays or shapes).
        `shardings`: optional pytree of NamedSharding for the TARGET mesh —
        pass a different mesh than at save time for elastic restart."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.dir}")
        sdir = os.path.join(self.dir, f"step_{step}")
        with np.load(os.path.join(sdir, f"host_{jax.process_index()}.npz")) as z:
            blocks = {k: z[k] for k in z.files}
        leaves, treedef = _flatten(state_like)
        paths = _paths(state_like)
        shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                        if shardings is not None else [None] * len(leaves))
        out = []
        for pth, like, shd in zip(paths, leaves, shard_leaves):
            dmark = blocks.get(f"{pth}::dtype")
            arr = _decode(blocks[pth], str(dmark) if dmark is not None else None)
            if shd is not None:
                out.append(jax.device_put(arr, shd))
            else:
                out.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out), step

    def extra(self, step: Optional[int] = None) -> dict:
        step = step if step is not None else self.latest_step()
        with open(os.path.join(self.dir, f"step_{step}", "manifest.json")) as f:
            return json.load(f)["extra"]
