"""Parameter / state / batch PartitionSpecs.

Param specs are derived from leaf NAMES (renamed where ambiguous), with extra
leading dims (layer-stacking) mapped to None. FSDP = 'data', TP = 'model';
the pod axis carries pure data parallelism (batch only), so parameters are
replicated across pods and gradients all-reduce across them once per step.
"""
from __future__ import annotations


import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.sharding import base_param_spec as _base_spec_impl
from repro.models.sharding import fit_axes

FSDP, TP = "data", "model"


def _leaf_name(path) -> str:
    for e in reversed(path):
        if isinstance(e, jax.tree_util.DictKey):
            return str(e.key)
    return ""


def _base_spec(name: str, ndim: int):
    return _base_spec_impl(name, ndim)


def _axis_sizes(mesh) -> dict:
    if mesh is None:
        return {}
    return {a: int(s) for a, s in zip(mesh.axis_names, mesh.devices.shape)}


def _fit(spec_entry, dim: int, sizes: dict):
    if spec_entry is None or not sizes:
        return spec_entry
    return fit_axes(spec_entry, dim, sizes)


def param_pspecs(params, mesh=None) -> object:
    """Pytree of PartitionSpec matching `params` (arrays or ShapeDtypeStructs).
    With `mesh`, specs are divisibility-checked per dim."""
    sizes = _axis_sizes(mesh)

    def spec(path, leaf):
        name = _leaf_name(path)
        nd = len(leaf.shape)
        base = _base_spec_impl(name, nd, leaf.shape, sizes)
        if base is None:
            return P()  # replicate (norm scales, misc)
        pad = nd - len(base)
        if pad < 0:  # unstacked variant of a rule written for stacked use
            base = base[-nd:] if nd else ()
            pad = 0
        full = (None,) * pad + tuple(base)
        full = tuple(_fit(e, d, sizes) for e, d in zip(full, leaf.shape))
        return P(*full)

    return jax.tree_util.tree_map_with_path(spec, params)


def state_pspecs(state, mesh=None) -> object:
    """Train-state specs: params/master/m/v mirror param specs; step replicated."""
    out = {}
    for k in ("params", "master", "m", "v"):
        if k in state:
            out[k] = param_pspecs(state[k], mesh)
    out["step"] = P()
    return out


def batch_pspecs(batch, mesh) -> object:
    """Batch dims shard over ('pod','data'); mrope positions keep their
    leading 3-axis replicated; everything else follows the batch dim."""
    names = mesh.axis_names if hasattr(mesh, "axis_names") else mesh
    sizes = _axis_sizes(mesh) if hasattr(mesh, "axis_names") else {}
    baxes = tuple(a for a in ("pod", "data") if a in names)
    b = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)

    def spec(path, leaf):
        nd = len(leaf.shape)
        name = _leaf_name(path)
        if name == "positions" and nd == 3:   # (3, B, S) mrope
            return P(None, _fit(b, leaf.shape[1], sizes), None)
        if not nd:
            return P()
        return P(*((_fit(b, leaf.shape[0], sizes),) + (None,) * (nd - 1)))

    return jax.tree_util.tree_map_with_path(spec, batch)


def cache_pspecs(cache, mesh) -> object:
    """Decode cache: batch dim shards over ('pod','data'), kv-heads over
    'model' where present (dim -2 of (L?, B, S, KV, dh) tensors)."""
    names = mesh.axis_names if hasattr(mesh, "axis_names") else mesh
    sizes = _axis_sizes(mesh) if hasattr(mesh, "axis_names") else {}
    baxes = tuple(a for a in ("pod", "data") if a in names)
    b = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    tp = "model" if "model" in names else None

    def spec(path, leaf):
        nd = len(leaf.shape)
        name = _leaf_name(path)
        if name == "len" or nd == 0:
            return P()
        if name in ("k", "v", "xk", "xv", "attn_k", "attn_v"):
            # (L|g, B, S, KV, dh) stacked or (B, S, KV, dh) unstacked.
            # kv-heads that don't divide TP (qwen2-vl kv=2) fall back to
            # sharding the HEAD DIM — a replicated 32k cache would be
            # tens of GB per device.
            kv_dim, dh_dim = leaf.shape[-2], leaf.shape[-1]
            tp_sz = sizes.get(tp, 1) if tp else 1
            if tp and kv_dim % tp_sz and dh_dim % tp_sz == 0:
                raw = ((None, b, None, None, tp) if nd == 5
                       else (b, None, None, tp))
            else:
                raw = ((None, b, None, tp, None) if nd == 5
                       else (b, None, tp, None))
        elif name == "ssm":
            # mamba1 (L, B, di, N) / mamba2 (L, B, H, Pd, N): channel/head on tp
            raw = (None, b, tp) + (None,) * (nd - 3)
        elif name == "conv":
            # (L, B, K-1, C): conv channels on tp
            raw = (None, b, None, tp)
        else:
            raw = (b,) + (None,) * (nd - 1)
        return P(*(_fit(e, d, sizes) for e, d in zip(raw, leaf.shape)))

    return jax.tree_util.tree_map_with_path(spec, cache)


def named(mesh, pspec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))
