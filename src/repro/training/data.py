"""Data pipeline: deterministic synthetic LM stream + memmap token files.

Host-side numpy producers; the launcher shards batches onto the mesh with
``jax.device_put(batch, NamedSharding(mesh, batch_pspecs(...)))``. Synthetic
tokens follow a Zipf distribution so losses are non-degenerate; the file
pipeline memory-maps a flat uint16/uint32 token file (the GoFS philosophy:
layout chosen so each host reads only its slice).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataCfg:
    batch: int
    seq: int
    vocab: int
    seed: int = 0
    zipf_a: float = 1.2
    path: Optional[str] = None           # token file (memmap) if set
    frames: Optional[tuple] = None       # (enc_seq, d_model) for encdec stubs
    mrope: bool = False


class SyntheticLM:
    """Deterministic, restartable synthetic token stream."""

    def __init__(self, cfg: DataCfg):
        self.cfg = cfg
        self.step = 0

    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def restore(self, state: dict):
        self.step = int(state["step"])

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        c = self.cfg
        rng = np.random.default_rng((c.seed, self.step))
        self.step += 1
        toks = rng.zipf(c.zipf_a, size=(c.batch, c.seq + 1)).astype(np.int64)
        toks = np.clip(toks, 1, c.vocab - 1).astype(np.int32)
        batch = {"inputs": toks[:, :-1], "labels": toks[:, 1:]}
        if c.frames is not None:
            es, d = c.frames
            batch["frames"] = rng.standard_normal((c.batch, es, d)).astype(np.float32)
        if c.mrope:
            pos = np.broadcast_to(np.arange(c.seq)[None, None],
                                  (3, c.batch, c.seq)).copy()
            batch["positions"] = pos.astype(np.int32)
        return batch


class TokenFile:
    """Memmap-backed contiguous token stream, host-sharded by offset."""

    def __init__(self, cfg: DataCfg, host_index: int = 0, host_count: int = 1,
                 dtype=np.uint16):
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=dtype, mode="r")
        span = len(self.data) // host_count
        self.lo = host_index * span
        self.hi = self.lo + span
        self.pos = self.lo
        self.step = 0

    def state(self) -> dict:
        return {"pos": int(self.pos), "step": self.step}

    def restore(self, state: dict):
        self.pos = int(state["pos"])
        self.step = int(state["step"])

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        c = self.cfg
        need = c.batch * (c.seq + 1)
        if self.pos + need >= self.hi:
            self.pos = self.lo
        chunk = np.asarray(self.data[self.pos:self.pos + need], np.int32)
        self.pos += need
        self.step += 1
        toks = np.clip(chunk.reshape(c.batch, c.seq + 1), 0, c.vocab - 1)
        return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}


def make_dataset(cfg: DataCfg, **kw):
    return TokenFile(cfg, **kw) if cfg.path else SyntheticLM(cfg)
