"""AdamW with mixed-precision master weights + schedules + grad clipping.

Memory layout per parameter (the large-model default):
    model param  bf16   (2 B)   — what the forward touches
    master       fp32   (4 B)
    m, v         fp32   (8 B)
All four shard identically (FSDP over 'data' × TP over 'model'), so the
110B config fits: 14 B/param × 111e9 / 256 chips ≈ 6.1 GB/chip.

``grad_compress_bf16`` casts gradients to bf16 before the cross-pod
data-parallel reduction (half the ICI traffic on the pod axis) and
accumulates the update in fp32 — the classic compression trick.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptCfg:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    mixed_precision: bool = True       # bf16 params + fp32 master
    grad_compress_bf16: bool = False   # compress DP gradient reduction


def schedule(step, cfg: OptCfg):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init_state(params, cfg: OptCfg):
    """params: fp32 pytree from model init. Returns the train state.
    Non-mixed mode stores NO separate master (params are fp32 already and a
    duplicate tree would alias buffers — donation forbids that)."""
    zeros = lambda t: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), t)
    if not cfg.mixed_precision:
        return {"params": params, "m": zeros(params), "v": zeros(params),
                "step": jnp.int32(0)}
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    model_params = jax.tree.map(lambda p: p.astype(jnp.bfloat16), params)
    return {
        "params": model_params,
        "master": master,
        "m": zeros(master),
        "v": zeros(master),
        "step": jnp.int32(0),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(state, grads, cfg: OptCfg):
    """One AdamW step. grads match state['params'] (bf16 or fp32)."""
    if cfg.grad_compress_bf16:
        grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)

    step = state["step"] + 1
    lr = schedule(step, cfg)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(m, v, g, p):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        new_p = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
        return m, v, new_p

    masters = state.get("master", state["params"])
    out = jax.tree.map(upd, state["m"], state["v"], grads, masters,
                       is_leaf=lambda x: isinstance(x, jax.Array))
    m = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": m, "v": v, "step": step}
    if cfg.mixed_precision:
        new_state["master"] = master
        new_state["params"] = jax.tree.map(lambda p: p.astype(jnp.bfloat16), master)
    else:
        new_state["params"] = master
    return new_state, dict(grad_norm=gn, lr=lr)
