"""Train / serve step builders: loss, grads, optimizer update, sharding glue.

``make_train_step(cfg, opt_cfg)`` returns a pure (state, batch) -> (state,
metrics) function; shardings are attached by the caller (launch/dryrun.py or
launch/train.py) via the specs in training.shardspec.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.training import optimizer as O

IGNORE = -1  # label ignore index


def cross_entropy(logits, labels):
    """Mean CE over non-ignored positions. logits (B,S,V), labels (B,S).

    The f32 upcast feeds ONLY the logsumexp reduction (fuses — no
    materialized f32 copy of the logits); the gold gather reads the original
    dtype directly."""
    mask = labels != IGNORE
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold.astype(jnp.float32)) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)


def make_loss_fn(cfg):
    def loss_fn(params, batch):
        kw = {}
        if cfg.family == "encdec" and "frames" in batch:
            kw["frames"] = batch["frames"]
        logits, aux = M.forward(params, batch["inputs"], cfg,
                                positions=batch.get("positions"), **kw)
        ce = cross_entropy(logits, batch["labels"])
        return ce + aux, {"ce": ce, "aux": aux}
    return loss_fn


def make_train_step(cfg, opt_cfg: O.OptCfg, accum_steps: int = 1):
    """accum_steps > 1 = gradient accumulation: the global batch is split
    into microbatches scanned sequentially, grads averaged in fp32. This is
    the capacity knob for cells whose per-device activations exceed HBM at
    the assigned global batch (EXPERIMENTS.md §Perf post-protocol notes):
    peak activation memory scales 1/accum_steps, FLOPs unchanged."""
    loss_fn = make_loss_fn(cfg)

    def train_step(state, batch):
        if accum_steps == 1:
            (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"], batch)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape((accum_steps, a.shape[0] // accum_steps)
                                    + a.shape[1:])
                if a.ndim and a.shape[0] % accum_steps == 0 else
                a.reshape((accum_steps, -1) + a.shape[2:]), batch)
            # mrope positions are (3, B, S): split on dim 1
            if "positions" in batch and batch["positions"].ndim == 3 \
                    and batch["positions"].shape[0] == 3:
                p = batch["positions"]
                micro["positions"] = p.reshape(
                    (3, accum_steps, p.shape[1] // accum_steps) + p.shape[2:]
                ).transpose(1, 0, 2, 3)

            def one(carry, mb):
                gacc, lacc, ce, aux = carry
                (l, parts), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state["params"], mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (gacc, lacc + l, ce + parts["ce"], aux + parts["aux"]), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state["params"])
            (gsum, lsum, cesum, auxsum), _ = jax.lax.scan(
                one, (g0, jnp.float32(0), jnp.float32(0), jnp.float32(0)), micro)
            k = float(accum_steps)
            grads = jax.tree.map(lambda g: g / k, gsum)
            loss = lsum / k
            parts = {"ce": cesum / k, "aux": auxsum / k}
        new_state, om = O.apply_updates(state, grads, opt_cfg)
        metrics = dict(loss=loss, **parts, **om)
        return new_state, metrics

    return train_step


def make_eval_step(cfg):
    loss_fn = make_loss_fn(cfg)

    def eval_step(params, batch):
        loss, parts = loss_fn(params, batch)
        return dict(loss=loss, **parts)

    return eval_step


# ------------------------------------------------------------- serving steps

def make_prefill_step(cfg, max_seq: Optional[int] = None):
    def prefill_step(params, batch):
        kw = {}
        if cfg.family == "encdec" and "frames" in batch:
            kw["frames"] = batch["frames"]
        logits, cache, _ = M.prefill(params, batch["inputs"], cfg,
                                     max_seq=max_seq,
                                     positions=batch.get("positions"), **kw)
        next_tok = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return next_tok.astype(jnp.int32), cache
    return prefill_step


def make_decode_step(cfg):
    """One token of greedy decode: (params, token, cache) -> (token, cache).
    This is the function the decode_32k / long_500k cells lower."""
    def serve_step(params, token, cache):
        logits, cache = M.decode_step(params, token, cache, cfg)
        nxt = jnp.argmax(logits.astype(jnp.float32), axis=-1).astype(jnp.int32)
        return nxt, cache
    return serve_step
