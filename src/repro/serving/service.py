"""Synchronous multi-tenant graph-query serving loop.

Request lifecycle:

    submit()  ->  pending queue (ticket + arrival timestamp)
    drain()   ->  1. exact-cache pass (ResultCache) — hits never touch the
                     engine and dedupe identical in-flight queries
                  2. planner: admit, group by (graph, family), pad to
                     power-of-two buckets
                  3. one batched BSP run per batch on a pooled engine —
                     engines are cached per (graph, family, bucket) and all
                     engines of a graph share ONE device graph block, so
                     steady state is: transfer query arrays, hit the jit
                     cache, run supersteps, gather
                  4. per-query Response with latency + the query's OWN
                     convergence superstep (telemetry.query_supersteps)

Aggregate telemetry (QPS, latency percentiles, cache hit rate, bucket fill)
accumulates in ServiceStats.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import numpy as np

import jax.numpy as jnp

from repro.core import (GopherEngine, PhasedTierPlan, device_block,
                        host_graph_block, update_changed_profile,
                        update_phase_profile, update_profile,
                        verify_host_block)
from repro.gofs.formats import PartitionedGraph
from repro.gofs.temporal import DeltaValidationError
from repro.obs import metrics as obs_metrics
from repro.resilience import faults as _faults
from repro.resilience.degrade import CircuitBreaker, backoff_delays
from repro.obs.skew import SkewTracker
from repro.serving import planner as pl
from repro.serving.batched import (BatchedPersonalizedPageRank,
                                   BatchedSemiringProgram,
                                   gather_query_results, ppr_query_seed,
                                   reachability_query_init)
from repro.serving.cache import LandmarkCache, ResultCache


@dataclasses.dataclass
class Request:
    ticket: int
    query: pl.Query
    t_submit: float


@dataclasses.dataclass
class Response:
    ticket: int
    query: pl.Query
    result: Optional[np.ndarray]   # (n,) values in global vertex order
    cached: bool = False
    error: Optional[str] = None
    latency_s: float = 0.0
    supersteps: int = 0            # the query's own convergence superstep


@dataclasses.dataclass
class ServiceStats:
    served: int = 0
    cache_hits: int = 0
    rejected: int = 0
    batches: int = 0
    engine_supersteps: int = 0
    landmark_rebootstraps: int = 0   # drift-triggered full re-selections
    busy_seconds: float = 0.0
    # Gopher Shield degradation counters
    deadline_misses: int = 0         # queries answered (or dropped) past SLO
    query_retries: int = 0           # batch-run retry attempts
    delta_retries: int = 0           # delta-apply retry attempts
    delta_failures: int = 0          # delta batches given up on (stale mode)
    recoveries: int = 0              # retry/stale episodes that healed
    stale_served: int = 0            # responses served at version v while a
                                     # failed delta left v+1 pending
    breaker_opens: int = 0           # circuit-breaker open transitions
    degraded_batches: int = 0        # batches answered with a typed error
                                     # instead of a client-facing exception
    # Gopher Balance live-migration counters
    migrations: int = 0              # skew-healing migrations installed
    migration_rollbacks: int = 0     # patched blocks that failed the audit
                                     # (pre-migration version kept serving)
    # bounded windows: long-running services must not grow without limit
    lane_fill: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=1024))
    latencies_s: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=8192))
    delta_apply_s: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=1024))
    # back-reference set by GraphQueryService so ``svc.stats()`` can fold in
    # per-graph skew and landmark state (Gopher Scope)
    _service: object = dataclasses.field(default=None, repr=False,
                                         compare=False)

    def qps(self) -> float:
        return self.served / self.busy_seconds if self.busy_seconds > 0 else 0.0

    def latency_ms(self, pct: float = 50.0) -> float:
        if not self.latencies_s:
            return 0.0
        return float(np.percentile(np.asarray(self.latencies_s), pct) * 1e3)

    def cache_hit_rate(self) -> float:
        return self.cache_hits / self.served if self.served > 0 else 0.0

    def summary(self) -> dict:
        return dict(served=self.served, cache_hits=self.cache_hits,
                    rejected=self.rejected, batches=self.batches,
                    qps=round(self.qps(), 1),
                    p50_ms=round(self.latency_ms(50), 2),
                    p99_ms=round(self.latency_ms(99), 2),
                    mean_fill=round(float(np.mean(self.lane_fill)), 2)
                    if self.lane_fill else 1.0)

    def __call__(self) -> dict:
        """The Gopher Scope serving report — ``svc.stats()``. Everything in
        :meth:`summary` plus the full latency tail, cache hit rate,
        delta-apply latency, per-graph partition imbalance (live
        SkewTracker) and landmark staleness."""
        out = self.summary()
        out.update(
            p95_ms=round(self.latency_ms(95), 2),
            cache_hit_rate=round(self.cache_hit_rate(), 4),
            engine_supersteps=self.engine_supersteps,
            landmark_rebootstraps=self.landmark_rebootstraps,
            delta_apply_p50_ms=round(
                float(np.percentile(np.asarray(self.delta_apply_s), 50) * 1e3),
                3) if self.delta_apply_s else 0.0,
            deadline_misses=self.deadline_misses,
            query_retries=self.query_retries,
            delta_retries=self.delta_retries,
            delta_failures=self.delta_failures,
            recoveries=self.recoveries,
            stale_served=self.stale_served,
            breaker_opens=self.breaker_opens,
            degraded_batches=self.degraded_batches,
            migrations=self.migrations,
            migration_rollbacks=self.migration_rollbacks)
        svc = self._service
        if svc is not None:
            out["imbalance"] = {g: t.imbalance()
                                for g, t in svc.skew.items()}
            out["skew"] = {g: t.report() for g, t in svc.skew.items()}
            out["result_cache"] = svc.cache.stats()
            lms = {g: svc.landmark_telemetry(g) for g in svc.landmark_caches}
            if lms:
                out["landmarks"] = lms
            if svc.breakers:
                out["breakers"] = {g: b.state
                                   for g, b in svc.breakers.items()}
            if svc._stale_graphs:
                out["stale_graphs"] = sorted(svc._stale_graphs)
        return out


class GraphQueryService:
    """Serves sssp / bfs / reach / ppr queries over registered graphs."""

    def __init__(self, graphs: Dict[str, PartitionedGraph],
                 backend: str = "local", mesh=None, max_batch: int = 64,
                 cache_capacity: int = 1024, ppr_iters: int = 30,
                 warm_start: bool = False,
                 metrics: Optional[obs_metrics.MetricsRegistry] = None,
                 deadline_s: Optional[float] = None, max_retries: int = 2,
                 retry_base_s: float = 0.05, breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 30.0, clock=time.monotonic):
        self.graphs = dict(graphs)
        self.backend = backend
        self.mesh = mesh
        self.max_batch = max_batch
        self.ppr_iters = ppr_iters
        self.warm_start = warm_start
        # Gopher Shield degradation policy: per-query deadline (None = no
        # SLO), bounded exponential-backoff retry on batch runs and delta
        # applies, and a per-graph circuit breaker. The clock is injectable
        # so tests drive deadlines/cooldowns without sleeping.
        self.deadline_s = deadline_s
        self.max_retries = int(max_retries)
        self.retry_base_s = float(retry_base_s)
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_cooldown_s = float(breaker_cooldown_s)
        self.clock = clock
        self.breakers: Dict[str, CircuitBreaker] = {}
        self._stale_graphs: set = set()  # graphs whose last delta FAILED:
                                         # still serving version v while
                                         # v+1 is pending (stale-serving)
        self.cache = ResultCache(cache_capacity)
        self.stats = ServiceStats()
        self.stats._service = self
        self._metrics = metrics
        # per-graph straggler picture, fed by every batch run (Gopher Scope)
        self.skew: Dict[str, SkewTracker] = {}
        self.landmark_caches: Dict[str, LandmarkCache] = {}
        self._gb: Dict[str, dict] = {}       # device graph blocks
        self._host_gb: Dict[str, dict] = {}  # patchable host twins (temporal)
        self._tier_plans: Dict[str, PhasedTierPlan] = {}  # Gopher Phases plans
        self._engines: Dict[tuple, GopherEngine] = {}
        self._pending: List[Request] = []
        self._next_ticket = 0
        if warm_start:
            for name in self.graphs:
                self.warm(name)

    @property
    def metrics(self) -> obs_metrics.MetricsRegistry:
        return (self._metrics if self._metrics is not None
                else obs_metrics.default_registry())

    # ---------------- graph lifecycle (temporal serving) ----------------
    def _cache_key(self, q: pl.Query) -> tuple:
        """Exact-cache key = query key + the target graph's VERSION, so a
        result computed at version k can never answer a query at k+1 (an
        unknown graph keys at version -1 and flows to admission rejection)."""
        pg = self.graphs.get(q.graph)
        return (q.cache_key(), pg.version if pg is not None else -1)

    def update_graph(self, name: str, pg: PartitionedGraph) -> None:
        """Swap in a new version of a registered graph and invalidate every
        per-graph derived artifact: cached results, pooled engines + their
        shared device block (shapes may have changed), and the landmark
        cache. Invalidation is UNCONDITIONAL for the graph name — the new
        graph may carry the same version number as the old one (e.g. two
        independent version-0 builds), so version equality proves nothing.
        (``apply_delta`` is the cheaper path for version bumps that came
        from an edge delta: it patches blocks and landmark vectors instead
        of dropping them.)"""
        self.graphs[name] = pg
        self.cache.invalidate(lambda k: k[0][0] == name)
        self._gb.pop(name, None)
        self._host_gb.pop(name, None)
        self._tier_plans.pop(name, None)
        self._engines = {k: e for k, e in self._engines.items()
                         if k[0] != name}
        self.landmark_caches.pop(name, None)

    def apply_delta(self, name: str, delta, directed: bool = False,
                    rebuild_landmarks: bool = False):
        """Ingest an edge-delta batch for a registered graph (gofs.temporal):
        bumps the graph version and invalidates the exact-result cache, but
        — unlike ``update_graph`` — keeps the derived state warm:

          - the graph block is ZERO-REPACK patched in O(|delta|)
            (core.blocks.patch_host_block via ``apply_delta(block=...)``)
            and re-installed, so freshly pooled engines skip the per-version
            re-pack AND, when no padded shape changed, re-enter the shared
            compiled BSP loop;
          - with ``rebuild_landmarks=True`` the landmark tier is MAINTAINED,
            not rebuilt: vectors the delta provably couldn't change stay
            valid (LandmarkCache.stale_landmarks), the rest resume from
            their previous fixpoints via the batched dirty-frontier restart
            — on a phased-exchange service that restart rides the
            NARROW-only single-phase plan (the refresh is exactly a
            narrow-frontier resume). When the cache's stale-refresh
            fraction EWMA crosses the drift threshold
            (LandmarkCache.drifted — the degree-chosen landmarks stopped
            being hubs), the tier is RE-BOOTSTRAPPED with fresh landmark
            selection instead, and ``stats.landmark_rebootstraps`` counts
            it.

        Returns the DeltaResult so callers can chain incremental analytics
        off the dirty seeds.

        Gopher Shield: the apply is retried ``max_retries`` times with
        exponential backoff. A corrupted patched block
        (verify_host_block / an injected BlockCorruptionFault) drops the
        cached block twins so the next attempt cold-rebuilds from the
        still-installed version v. A :class:`DeltaValidationError` is
        permanent — nothing was installed, retrying cannot help — and
        re-raises immediately. When every retry is spent the graph enters
        STALE-SERVING: version v keeps answering queries (its caches and
        engines were never touched) while v+1 stays pending; the next
        successful apply counts a recovery."""
        t0 = time.perf_counter()
        delays = backoff_delays(self.retry_base_s, self.max_retries)
        last: Optional[BaseException] = None
        for attempt in range(self.max_retries + 1):
            try:
                _faults.fire("svc.apply_delta", graph=name, attempt=attempt)
                res = self._apply_delta_once(name, delta, directed,
                                             rebuild_landmarks, t0)
            except DeltaValidationError:
                self.stats.delta_failures += 1
                self.metrics.counter(
                    "serving_delta_failures_total",
                    labels={"graph": name, "kind": "invalid"}).inc()
                raise
            except _faults.BlockCorruptionFault as e:
                last = e
                self.stats.delta_retries += 1
                self._host_gb.pop(name, None)
                self._gb.pop(name, None)
                self.metrics.counter("serving_delta_retries_total",
                                     labels={"graph": name}).inc()
            except Exception as e:  # serving-loop boundary: degrade, not leak
                last = e
                self.stats.delta_retries += 1
                self.metrics.counter("serving_delta_retries_total",
                                     labels={"graph": name}).inc()
            else:
                if attempt or name in self._stale_graphs:
                    self._stale_graphs.discard(name)
                    self.stats.recoveries += 1
                    self.metrics.counter(
                        "serving_recoveries_total",
                        labels={"graph": name, "site": "apply_delta"}).inc()
                return res
            if attempt < self.max_retries:
                time.sleep(delays[attempt])
        self._stale_graphs.add(name)
        self.stats.delta_failures += 1
        self.metrics.counter("serving_delta_failures_total",
                             labels={"graph": name, "kind": "exhausted"}).inc()
        raise last

    def _apply_delta_once(self, name: str, delta, directed: bool,
                          rebuild_landmarks: bool, t0: float):
        from repro.gofs.temporal import apply_delta as _apply
        from repro.serving.cache import LandmarkCache
        old_lc = self.landmark_caches.get(name)
        host_gb = self._host_gb.get(name)
        if host_gb is None:
            host_gb = host_graph_block(self.graphs[name])
        res = _apply(self.graphs[name], delta, directed=directed,
                     block=host_gb)
        # corrupted-block detection BEFORE install: a patched block that
        # fails the structural audit must never replace the serving twin
        if res.block is not None:
            problems = verify_host_block(res.block)
            if problems:
                raise _faults.BlockCorruptionFault(
                    "blocks.patch", "corrupt_block", -1, {},
                    {"problems": "; ".join(problems[:3])})
        self.update_graph(name, res.pg)
        self._host_gb[name] = res.block
        self._gb[name] = device_block(res.block)
        if rebuild_landmarks and old_lc is not None:
            if old_lc.drifted():
                self.landmark_caches[name] = LandmarkCache.build(
                    res.pg, num_landmarks=old_lc.num_landmarks,
                    strategy=old_lc.strategy, backend=self.backend,
                    mesh=self.mesh)
                self.stats.landmark_rebootstraps += 1
                self.metrics.counter("serving_landmark_rebootstraps_total",
                                     labels={"graph": name}).inc()
            else:
                exchange, plan = "auto", None
                if self._exchange_mode() == "phased":
                    exchange = "phased"
                    plan = PhasedTierPlan.narrow_resume(res.block)
                self.landmark_caches[name] = old_lc.refresh(
                    res.pg, res, delta, directed=directed,
                    backend=self.backend, mesh=self.mesh,
                    gb=self._gb[name], exchange=exchange, tier_plan=plan,
                    profile_block=res.block)
        if self.warm_start:
            # re-warm the serving loops for the new version: a delta that
            # changed no padded shape re-enters the shared compiled loops
            # (cache hit); one that grew a lane pays the compile HERE, off
            # the request path
            self.warm(name)
        dt = time.perf_counter() - t0
        self.stats.delta_apply_s.append(dt)
        reg = self.metrics
        reg.counter("serving_deltas_applied_total",
                    labels={"graph": name}).inc()
        reg.histogram("serving_delta_apply_seconds").observe(dt)
        lc = self.landmark_caches.get(name)
        if lc is not None:
            reg.gauge("serving_landmark_stale_frac",
                      labels={"graph": name}).set(lc.stale_frac_ewma)
        return res

    def rebalance(self, name: str, policy=None):
        """Gopher Balance on the serving path: read the graph's live
        :class:`SkewTracker`, ask ``launch.elastic.rebalance_hint`` whether
        the partition layout is worth healing, and if so migrate sub-graphs
        off the straggler partition through the same synthetic-delta
        machinery ``apply_delta`` uses — ``patch_host_block`` on the host
        twin, O(moved cut), no re-partition.

        The move rides the STALE-SERVING discipline: version v keeps
        answering every query until the patched block passes its
        ``verify_host_block`` audit; a failed audit installs NOTHING
        (``stats.migration_rollbacks`` counts it, the graph's circuit
        breaker records the failure) and v serves on. On success the
        patched version installs exactly like a delta (update_graph +
        block twins) and ``stats.migrations`` ticks.

        Returns the ``MigrationResult`` when a migration installed, else
        None (balanced graph, nothing movable, or rolled back)."""
        from repro.launch import elastic
        from repro.resilience.balance import (BalancePolicy, apply_migration,
                                              plan_migration)

        pol = policy or BalancePolicy()
        tracker = self.skew.get(name)
        pg = self.graphs.get(name)
        if tracker is None or pg is None:
            return None
        rep = tracker.report()
        hint = elastic.rebalance_hint(rep, threshold=pol.threshold,
                                      floor=pol.floor)
        if hint is None:
            return None
        load = (tracker.seconds
                if tracker.seconds is not None
                and np.any(tracker.seconds > 0) else tracker.liters)
        plan = plan_migration(pg, src=int(hint["migrate_from"]),
                              budget=pol.max_verts_per_step, load=load)
        if plan is None:
            return None
        host_gb = self._host_gb.get(name)
        if host_gb is None:
            host_gb = host_graph_block(pg)
        try:
            res = apply_migration(pg, plan, host_gb=host_gb)
            problems = verify_host_block(res.block)
        except _faults.BlockCorruptionFault as e:
            problems = [str(e)]
            res = None
        if problems:
            # rollback is free: nothing was installed, version v serves on
            self.stats.migration_rollbacks += 1
            br = self.breakers.get(name)
            if br is None:
                br = self.breakers[name] = CircuitBreaker(
                    threshold=self.breaker_threshold,
                    cooldown_s=self.breaker_cooldown_s, clock=self.clock)
            br.record_failure()
            self.metrics.counter("serving_migration_rollbacks_total",
                                 labels={"graph": name}).inc()
            return None
        self.update_graph(name, res.pg)
        self._host_gb[name] = res.block
        self._gb[name] = device_block(res.block)
        # the accumulated load picture described the PRE-move layout; reset
        # so the next decision reads post-move telemetry, not stale skew
        self.skew[name] = SkewTracker(num_parts=pg.num_parts,
                                      decay=tracker.decay)
        self.stats.migrations += 1
        self.metrics.counter(
            "serving_migrations_total",
            labels={"graph": name, "signal": hint.get("signal", "")}).inc()
        if self.warm_start:
            self.warm(name)
        return res

    def landmark_telemetry(self, name: str) -> Optional[dict]:
        """The landmark tier's drift signal for one graph: per-version
        stale-refresh fraction EWMA, refresh count, and whether the next
        maintained delta would trigger a re-bootstrap."""
        lc = self.landmark_caches.get(name)
        if lc is None:
            return None
        return dict(num_landmarks=lc.num_landmarks,
                    graph_version=lc.graph_version,
                    refreshed_landmarks=lc.refreshed_landmarks,
                    refreshes=lc.refreshes,
                    stale_frac_ewma=round(lc.stale_frac_ewma, 4),
                    drifted=lc.drifted(),
                    rebootstraps=self.stats.landmark_rebootstraps)

    # ---------------- request intake ----------------
    def submit(self, kind: str, graph: str, sources) -> int:
        """Enqueue a query; returns its ticket."""
        t = self._next_ticket
        self._next_ticket += 1
        self._pending.append(Request(ticket=t,
                                     query=pl.Query.make(kind, graph, sources),
                                     t_submit=time.perf_counter()))
        return t

    def query(self, kind: str, graph: str, sources) -> Response:
        """Convenience: submit one query and drain immediately."""
        t = self.submit(kind, graph, sources)
        return self.drain()[t]

    # ---------------- scheduler loop ----------------
    def drain(self) -> Dict[int, Response]:
        """Serve every pending request; returns {ticket: Response}."""
        t0 = time.perf_counter()
        reqs, self._pending = self._pending, []
        responses: Dict[int, Response] = {}

        # 1. per-query deadline admission (Gopher Shield): a request that
        # already overran its SLO is answered with a typed error instead of
        # occupying an engine lane, then exact-cache pass + dedupe of
        # identical in-flight queries
        by_key: Dict[tuple, List[Request]] = {}
        for r in reqs:
            if (self.deadline_s is not None
                    and t0 - r.t_submit > self.deadline_s):
                self.stats.deadline_misses += 1
                self.metrics.counter("serving_deadline_misses_total").inc()
                responses[r.ticket] = Response(
                    ticket=r.ticket, query=r.query, result=None,
                    error="deadline exceeded",
                    latency_s=t0 - r.t_submit)
                continue
            key = self._cache_key(r.query)
            hit = self.cache.get(key)
            if hit is not None:
                self.stats.cache_hits += 1
                responses[r.ticket] = Response(
                    ticket=r.ticket, query=r.query, result=hit, cached=True,
                    latency_s=time.perf_counter() - r.t_submit)
            else:
                by_key.setdefault(key, []).append(r)

        # 2. plan over unique uncached queries
        sizes = {name: pg.n_global for name, pg in self.graphs.items()}
        unique = [rs[0].query for rs in by_key.values()]
        batches, rejected = pl.plan(unique, sizes, max_batch=self.max_batch)
        for q, reason in rejected:
            self.stats.rejected += len(by_key[self._cache_key(q)])
            for r in by_key[self._cache_key(q)]:
                responses[r.ticket] = Response(
                    ticket=r.ticket, query=r.query, result=None, error=reason,
                    latency_s=time.perf_counter() - r.t_submit)

        # 3. one engine run per batch — a batch whose retries are exhausted
        # (or whose graph's breaker is open) DEGRADES to typed error
        # responses; the exception never reaches the client
        for batch in batches:
            try:
                results, qsteps = self._run_batch(batch)
            except Exception as e:
                self.stats.degraded_batches += 1
                self.metrics.counter("serving_degraded_batches_total",
                                     labels={"graph": batch.graph}).inc()
                err = f"degraded: {e}"
                for q in batch.queries:
                    for r in by_key[self._cache_key(q)]:
                        responses[r.ticket] = Response(
                            ticket=r.ticket, query=r.query, result=None,
                            error=err,
                            latency_s=time.perf_counter() - r.t_submit)
                continue
            for i, q in enumerate(batch.queries):
                # own copy — a row VIEW would pin the whole (Q, n) batch
                # array in the cache for its lifetime
                res = np.array(results[i])
                self.cache.put(self._cache_key(q), res)
                for r in by_key[self._cache_key(q)]:
                    responses[r.ticket] = Response(
                        ticket=r.ticket, query=r.query, result=res,
                        latency_s=time.perf_counter() - r.t_submit,
                        supersteps=int(qsteps[i]))

        # 4. aggregate telemetry
        done = [resp for resp in responses.values() if resp.error is None]
        if self._stale_graphs:
            stale = sum(1 for resp in done
                        if resp.query.graph in self._stale_graphs)
            if stale:
                self.stats.stale_served += stale
                self.metrics.counter(
                    "serving_stale_served_total").inc(stale)
        if self.deadline_s is not None:
            # delivered-but-late responses count as misses too (the client
            # got an answer; the SLO did not)
            self.stats.deadline_misses += sum(
                1 for resp in done if resp.latency_s > self.deadline_s)
        self.stats.served += len(done)
        self.stats.latencies_s.extend(resp.latency_s for resp in done)
        self.stats.busy_seconds += time.perf_counter() - t0
        reg = self.metrics
        hits = sum(1 for resp in done if resp.cached)
        reg.counter("serving_requests_total",
                    labels={"result": "hit"}).inc(hits)
        reg.counter("serving_requests_total",
                    labels={"result": "served"}).inc(len(done) - hits)
        reg.counter("serving_requests_total",
                    labels={"result": "rejected"}).inc(
                        len(responses) - len(done))
        lat = reg.histogram("serving_latency_seconds")
        for resp in done:
            lat.observe(resp.latency_s)
        reg.gauge("serving_cache_hit_rate").set(self.stats.cache_hit_rate())
        return responses

    # ---------------- batch execution ----------------
    def _run_batch(self, batch: pl.Batch):
        """Gopher Shield wrapper around one batched engine run: per-graph
        circuit breaker + bounded exponential-backoff retry. A graph whose
        breaker is OPEN refuses the run outright — queries degrade to typed
        error responses in drain() while caches and landmarks still answer
        — instead of burning retries on a broken graph; the cooldown's one
        HALF_OPEN trial re-closes it on success."""
        br = self.breakers.get(batch.graph)
        if br is None:
            br = self.breakers[batch.graph] = CircuitBreaker(
                threshold=self.breaker_threshold,
                cooldown_s=self.breaker_cooldown_s, clock=self.clock)
        delays = backoff_delays(self.retry_base_s, self.max_retries)
        last: Optional[BaseException] = None
        for attempt in range(self.max_retries + 1):
            if not br.allow():
                raise RuntimeError(f"circuit open for graph "
                                   f"{batch.graph!r} ({br.opens} opens)")
            try:
                _faults.fire("svc.query", graph=batch.graph,
                             family=batch.family, attempt=attempt)
                out = self._run_batch_once(batch)
            except Exception as e:
                last = e
                opens = br.opens
                br.record_failure()
                if br.opens > opens:
                    self.stats.breaker_opens += 1
                    self.metrics.counter("serving_breaker_opens_total",
                                         labels={"graph": batch.graph}).inc()
                self.stats.query_retries += 1
                self.metrics.counter("serving_query_retries_total",
                                     labels={"graph": batch.graph}).inc()
            else:
                br.record_ok()
                if attempt:
                    self.stats.recoveries += 1
                    self.metrics.counter(
                        "serving_recoveries_total",
                        labels={"graph": batch.graph, "site": "query"}).inc()
                return out
            if attempt < self.max_retries:
                time.sleep(delays[attempt])
        raise last

    def _run_batch_once(self, batch: pl.Batch):
        pg = self.graphs[batch.graph]
        Q = batch.padded_q
        # pad lanes replay query 0; their results are sliced away below
        lanes = batch.queries + [batch.queries[0]] * (Q - len(batch.queries))
        if batch.family == "ppr":
            extra = {"qseed": ppr_query_seed(pg, [q.sources[0] for q in lanes])}
            state_key = "r"
        else:
            extra = {"qinit": reachability_query_init(
                pg, [q.sources for q in lanes])}
            state_key = "x"
        eng = self._engine(batch.graph, batch.family, Q)
        state, tele = eng.run_queries(extra=extra)
        results = gather_query_results(pg, state[state_key])
        self.stats.batches += 1
        self.stats.engine_supersteps += tele.supersteps
        self.stats.lane_fill.append(batch.fill)
        # Gopher Scope: fold the run into the graph's live straggler picture
        tracker = self.skew.setdefault(batch.graph, SkewTracker())
        tracker.observe(tele)
        reg = self.metrics
        reg.counter("serving_batches_total",
                    labels={"graph": batch.graph,
                            "family": batch.family}).inc()
        reg.histogram("serving_batch_supersteps").observe(tele.supersteps)
        reg.gauge("serving_partition_imbalance",
                  labels={"graph": batch.graph}).set(tracker.imbalance())
        # Gopher Mesh/Phases feedback: fold this batch's per-pair wire
        # observation into the graph's traffic profile and its frontier
        # histogram into the changed-histogram EWMA (the next plan rebuild
        # tightens both the tiers and the phase boundaries), and propagate
        # any overflow escalation the engine applied so freshly pooled
        # engines start from the promoted plan
        if tele.pair_slots is not None and batch.graph in self._host_gb:
            update_profile(self._host_gb[batch.graph], tele.pair_slots,
                           tele.pair_rounds)
        if tele.count_hist is not None and batch.graph in self._host_gb:
            update_changed_profile(self._host_gb[batch.graph],
                                   tele.count_hist)
        # per-band pair observations (phased runs): each band's geometry
        # learns from the pairs that fired IN that band, not a global EWMA
        if (tele.phase_pair_slots is not None
                and batch.graph in self._host_gb):
            update_phase_profile(self._host_gb[batch.graph],
                                 tele.phase_pair_slots, tele.phase_hist)
        if tele.escalations:
            self._tier_plans[batch.graph] = eng.tier_plan
            for key, other in self._engines.items():
                if (key[0] == batch.graph
                        and other.exchange in ("tiered", "phased")):
                    other.tier_plan = eng.tier_plan
        return results[:len(batch.queries)], tele.query_supersteps

    def _graph_block(self, graph: str) -> dict:
        if graph not in self._gb:
            host = self._host_gb.get(graph)
            if host is None:
                host = host_graph_block(self.graphs[graph])
                self._host_gb[graph] = host   # keep the patchable twin for
                                              # the next apply_delta
            self._gb[graph] = device_block(host)
        return self._gb[graph]

    def _exchange_mode(self) -> str:
        """The exchange discipline pooled engines run: 'phased' (Gopher
        Phases) on a real multi-device shard_map mesh — the per-graph plans
        ride the host blocks' traffic + changed-histogram profiles — and
        'auto' everywhere else (which resolves to dense on 'local' and on a
        degenerate 1-device mesh, where compaction is pure overhead)."""
        if self.backend != "shard_map" or self.mesh is None:
            return "auto"
        # the size of the engines' PARTITION axis, not the whole mesh — the
        # same basis GopherEngine's auto resolution uses, so the service
        # never forces phased plans onto a single-chip partition axis
        D = int(dict(self.mesh.shape).get("parts", 1))
        return "phased" if D > 1 else "auto"

    def _tier_plan(self, graph: str) -> Optional[PhasedTierPlan]:
        """The graph's current Gopher Phases plan (multi-device shard_map
        only): built from the host block's traffic + changed-histogram
        profiles, cached until a version bump or an escalation replaces
        it. Engines on the local backend (or a 1-device mesh) resolve
        exchange='auto' to the dense path and take no plan."""
        if self._exchange_mode() != "phased":
            return None
        if graph not in self._tier_plans:
            host = self._host_gb.get(graph)
            if host is None:
                self._graph_block(graph)          # builds the host twin
                host = self._host_gb[graph]
            self._tier_plans[graph] = PhasedTierPlan.from_block(host)
        return self._tier_plans[graph]

    def _engine(self, graph: str, family: str, Q: int) -> GopherEngine:
        key = (graph, family, Q)
        if key not in self._engines:
            pg = self.graphs[graph]
            if family == "ppr":
                prog = BatchedPersonalizedPageRank(
                    n_global=pg.n_global, num_queries=Q,
                    num_iters=self.ppr_iters)
                max_ss = max(self.ppr_iters + 1, 64)
            else:
                prog = BatchedSemiringProgram(semiring="min_plus",
                                              num_queries=Q)
                max_ss = 4096
            self._engines[key] = GopherEngine(
                pg, prog, backend=self.backend, mesh=self.mesh,
                max_supersteps=max_ss, gb=self._graph_block(graph),
                exchange=self._exchange_mode(),
                tier_plan=self._tier_plan(graph))
        return self._engines[key]

    def warm(self, name: str, families=("reach",), qs=(1,)) -> int:
        """Pre-trace and AOT-compile the serving loops ``name`` will run —
        one per (family, query-bucket) pair — so the first real request of
        each shape skips the trace + XLA compile and pays only execution.
        On the local backend this pre-traces the megastep fused route
        (``exchange='auto'`` resolves there for the semiring families); on
        a phased shard_map service it additionally pre-traces the
        NARROW-RESUME single-phase loop at the same shapes, the loop the
        landmark refresh rides after every apply_delta. ``qs`` entries are
        the planner's padded bucket sizes (powers of two). Returns the
        number of loops compiled. Called at registration and after every
        delta when the service was built with ``warm_start=True`` (a delta
        that changes no padded shape re-enters the same compiled loops, so
        the re-warm is a cache hit)."""
        pg = self.graphs[name]
        done = 0
        for family in families:
            for Q in qs:
                eng = self._engine(name, family, Q)
                gb = dict(self._graph_block(name))
                if family == "ppr":
                    gb["qseed"] = jnp.asarray(ppr_query_seed(pg, [0] * Q))
                else:
                    gb["qinit"] = jnp.asarray(
                        reachability_query_init(pg, [[0]] * Q))
                plans = [eng.tier_plan]
                if self._exchange_mode() == "phased":
                    host = self._host_gb.get(name)
                    if host is not None:
                        plans.append(PhasedTierPlan.narrow_resume(host))
                saved = eng.tier_plan
                try:
                    for plan in plans:
                        eng.tier_plan = plan
                        fn = eng._runner(num_queries=Q, gb_example=gb)
                        try:
                            fn.lower(gb).compile()
                        except AttributeError:
                            fn(gb)   # runner isn't AOT-lowerable: one real
                                     # run primes the jit cache instead
                        done += 1
                finally:
                    eng.tier_plan = saved
        self.metrics.counter("serving_warm_compiles_total",
                             labels={"graph": name}).inc(done)
        return done

    # ---------------- landmark tier (approximate SSSP, zero supersteps) ----
    def enable_landmarks(self, graph: str, num_landmarks: int = 8,
                         strategy: str = "degree") -> LandmarkCache:
        """Bootstrap the landmark cache with one batched SSSP run."""
        lc = LandmarkCache.build(self.graphs[graph], num_landmarks=num_landmarks,
                                 strategy=strategy, backend=self.backend,
                                 mesh=self.mesh)
        self.landmark_caches[graph] = lc
        return lc

    def approx_sssp(self, graph: str, source: int) -> np.ndarray:
        """Triangle-inequality upper bounds on d(source, ·) — answered from
        the landmark cache without running the engine."""
        return self.landmark_caches[graph].approx_sssp(source)
