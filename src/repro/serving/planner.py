"""Admission + batching policy for the query-serving loop.

Queries are admitted (validated against the target graph), grouped by
compatibility key — (graph, program family) — and packed into batches whose
query count is padded UP to a power-of-two bucket. The padding trades a few
wasted query lanes for jit/XLA cache reuse: every batch of a given (graph,
family, bucket) triple re-enters the exact compiled BSP loop, so steady-state
serving never re-traces. Pad lanes replay the first real query and their
results are dropped (they add no supersteps: the batch halt is the max over
queries, and a duplicate finishes with its twin).

Families:
    traversal     min_plus over the graph's own weights — sssp, bfs (hop
                  counts on unit-weight graphs, per the bfs() convention),
                  and reach (multi-seed reachability) are all the SAME
                  program with different init rows, so they share one batch,
                  one engine, one compiled loop, and one cache namespace
    ppr           personalized PageRank (sum semiring, fixed supersteps)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

FAMILY_OF_KIND = {"sssp": "traversal", "bfs": "traversal",
                  "reach": "traversal", "ppr": "ppr"}
FAMILY_SEMIRING = {"traversal": "min_plus", "ppr": "sum"}


@dataclasses.dataclass(frozen=True)
class Query:
    """One graph query. ``sources`` is a tuple of global vertex ids — one
    entry for sssp/bfs/ppr, any number for reach (seed set)."""
    kind: str
    graph: str
    sources: Tuple[int, ...]

    @staticmethod
    def make(kind: str, graph: str, sources) -> "Query":
        if isinstance(sources, int):
            sources = (sources,)
        return Query(kind=kind, graph=graph, sources=tuple(int(s) for s in sources))

    @property
    def family(self) -> str:
        # unknown kinds map to themselves so cache_key()/grouping stay total;
        # validate() rejects them at admission
        return FAMILY_OF_KIND.get(self.kind, self.kind)

    def cache_key(self) -> tuple:
        return (self.graph, self.family, tuple(sorted(self.sources)))


@dataclasses.dataclass
class Batch:
    """A planned engine run: queries sharing (graph, family), padded to Q."""
    graph: str
    family: str
    queries: List[Query]
    padded_q: int                 # power-of-two bucket the batch runs at

    @property
    def fill(self) -> float:
        return len(self.queries) / self.padded_q


def bucket_size(n: int, max_batch: int = 64) -> int:
    """Smallest power of two >= n, clamped to max_batch."""
    assert n >= 1
    b = 1
    while b < n:
        b *= 2
    return min(b, max_batch)


def validate(q: Query, graphs: Dict[str, int]) -> Optional[str]:
    """Admission check. Returns a rejection reason or None."""
    if q.kind not in FAMILY_OF_KIND:
        return f"unknown query kind {q.kind!r}"
    if q.graph not in graphs:
        return f"unknown graph {q.graph!r}"
    if not q.sources:
        return "query has no source vertices"
    if q.kind != "reach" and len(q.sources) != 1:
        return f"{q.kind} takes exactly one source, got {len(q.sources)}"
    n = graphs[q.graph]
    for s in q.sources:
        if not (0 <= s < n):
            return f"source {s} out of range for graph {q.graph!r} (n={n})"
    return None


def plan(queries: Sequence[Query], graphs: Dict[str, int],
         max_batch: int = 64) -> Tuple[List[Batch], List[Tuple[Query, str]]]:
    """(batches, rejected) — rejected carries (query, reason).

    Grouping preserves arrival order within a group; groups larger than
    max_batch split into full max_batch chunks plus a padded tail.
    """
    rejected: List[Tuple[Query, str]] = []
    groups: Dict[Tuple[str, str], List[Query]] = {}
    for q in queries:
        reason = validate(q, graphs)
        if reason is not None:
            rejected.append((q, reason))
            continue
        groups.setdefault((q.graph, q.family), []).append(q)

    batches: List[Batch] = []
    for (graph, family), qs in groups.items():
        for i in range(0, len(qs), max_batch):
            chunk = qs[i:i + max_batch]
            batches.append(Batch(graph=graph, family=family, queries=chunk,
                                 padded_q=bucket_size(len(chunk), max_batch)))
    return batches, rejected
