"""Result caches: exact memoization + landmark triangle-inequality bounds.

Two tiers sit in front of the engine:

  ResultCache      exact (Q-query results memoized by (graph, family,
                   sources)); an LRU over full (n,) result vectors. Repeat
                   queries — the common case for popular sources — cost a
                   dict lookup, zero supersteps.

  LandmarkCache    approximate SSSP WITHOUT touching the engine: precompute
                   exact distance vectors from L landmark vertices (one
                   batched SSSP run — the serving subsystem bootstraps its
                   own cache), then answer any source by the triangle
                   inequality  d(s,t) <= min_l d(s,l) + d(l,t)  (upper bound)
                   and  d(s,t) >= max_l |d(s,l) - d(l,t)|  (lower bound).
                   Exact when s or t IS a landmark. Assumes an undirected
                   graph (d(s,l) = d(l,s) is read off the landmark vector).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional, Sequence

import numpy as np

from repro.gofs.formats import PartitionedGraph


class ResultCache:
    """LRU memo of exact per-query results keyed by Query.cache_key()."""

    def __init__(self, capacity: int = 1024):
        self.capacity = capacity
        self._d: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.invalidations = 0

    def get(self, key) -> Optional[np.ndarray]:
        if key in self._d:
            self._d.move_to_end(key)
            self.hits += 1
            return self._d[key]
        self.misses += 1
        return None

    def put(self, key, value: np.ndarray) -> None:
        if self.capacity <= 0:
            return
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)

    def invalidate(self, pred) -> int:
        """Drop every entry whose key satisfies ``pred``; returns the count.
        The service calls this on graph updates — version-tagged keys make
        stale hits impossible anyway, but eagerly dropping them returns the
        capacity to live entries instead of waiting for LRU churn."""
        dead = [k for k in self._d if pred(k)]
        for k in dead:
            del self._d[k]
        self.invalidations += len(dead)
        return len(dead)

    def __len__(self) -> int:
        return len(self._d)

    def hit_rate(self) -> float:
        """Hits / lookups over the cache's lifetime (0.0 before any get)."""
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    def stats(self) -> dict:
        return dict(entries=len(self._d), hits=self.hits, misses=self.misses,
                    invalidations=self.invalidations,
                    hit_rate=round(self.hit_rate(), 4))


def choose_landmarks(pg: PartitionedGraph, num: int,
                     strategy: str = "degree", seed: int = 0) -> np.ndarray:
    """Pick landmark vertex ids: highest global out-degree (good coverage on
    powerlaw graphs — hubs sit on many shortest paths) or uniform random."""
    if strategy == "degree":
        deg = np.zeros(pg.n_global, np.int64)
        for p in range(pg.num_parts):
            m = pg.vmask[p]
            deg[pg.global_id[p][m]] = pg.out_degree[p][m]
        return np.argsort(-deg, kind="stable")[:num].astype(np.int64)
    if strategy == "random":
        rng = np.random.default_rng(seed)
        return rng.choice(pg.n_global, size=num, replace=False).astype(np.int64)
    raise ValueError(f"unknown landmark strategy {strategy!r}")


# landmark drift: EWMA weight on the LATEST refresh's stale fraction, and
# the default re-bootstrap threshold (see LandmarkCache.drifted)
DRIFT_DECAY = 0.5
DRIFT_THRESHOLD = 0.6


@dataclasses.dataclass
class LandmarkCache:
    """L exact landmark distance vectors for one graph; answers approximate
    SSSP with O(L·n) numpy and no engine run. ``graph_version`` records the
    PartitionedGraph version the vectors were computed at. On a delta the
    service no longer flushes the tier: ``stale_landmarks`` proves which
    vectors a delta could have changed (O(L·|delta|) against the cached
    distances) and ``refresh`` recomputes ONLY those, resuming each from its
    previous fixpoint via the batched dirty-frontier restart.

    Re-selection drift: the degree-chosen landmarks can stop being hubs
    after many deltas, and the symptom is cheap to observe — the fraction of
    vectors each refresh proves stale. ``stale_frac_ewma`` tracks it across
    versions (EWMA, weight ``DRIFT_DECAY`` on the latest refresh);
    ``drifted()`` crossing ``DRIFT_THRESHOLD`` tells the service the
    maintenance path has degraded to near-full recomputes, at which point
    re-BOOTSTRAPPING (fresh landmark selection on the current degree
    distribution) is the better spend. The signal rides serving telemetry
    (GraphQueryService.landmark_telemetry)."""
    landmarks: np.ndarray          # (L,) global vertex ids
    dist: np.ndarray               # (L, n) exact distances from each landmark
    graph_version: int = 0
    queries_answered: int = 0
    refreshed_landmarks: int = 0   # vectors recomputed at the last refresh()
    strategy: str = "degree"       # selection strategy (re-bootstrap reuses it)
    stale_frac_ewma: float = 0.0   # EWMA of per-refresh stale fractions
    refreshes: int = 0             # maintenance refreshes since bootstrap

    @property
    def num_landmarks(self) -> int:
        return int(self.landmarks.shape[0])

    def drifted(self, threshold: float = DRIFT_THRESHOLD) -> bool:
        """True when the refresh path has degraded enough that fresh
        landmark selection beats maintaining the current set. Needs at
        least two refreshes of evidence — one removal-heavy delta marks
        everything stale without implying the LANDMARKS drifted."""
        return self.refreshes >= 2 and self.stale_frac_ewma > threshold

    @staticmethod
    def build(pg: PartitionedGraph, num_landmarks: int = 8,
              strategy: str = "degree", backend: str = "local", mesh=None,
              landmarks: Optional[Sequence[int]] = None) -> "LandmarkCache":
        """One batched SSSP run with the landmarks as the query batch."""
        from repro.core import GopherEngine
        from repro.serving.batched import (BatchedSemiringProgram,
                                           gather_query_results,
                                           sssp_query_init)
        lm = (np.asarray(landmarks, np.int64) if landmarks is not None
              else choose_landmarks(pg, num_landmarks, strategy=strategy))
        prog = BatchedSemiringProgram(semiring="min_plus",
                                      num_queries=int(lm.shape[0]))
        eng = GopherEngine(pg, prog, backend=backend, mesh=mesh)
        state, _ = eng.run_queries(extra={"qinit": sssp_query_init(pg, lm)})
        return LandmarkCache(landmarks=lm,
                             dist=gather_query_results(pg, state["x"]),
                             graph_version=pg.version, strategy=strategy)

    def stale_landmarks(self, delta, directed: bool = False,
                        removed: Optional[int] = None) -> np.ndarray:
        """(L,) bool: which landmark vectors ``delta`` may have changed.

        A landmark's SSSP fixpoint survives an insert-only delta iff no
        inserted edge relaxes under its CURRENT distances — the standard
        first-improved-vertex argument: if some distance strictly improved,
        the minimal improved endpoint's last path edge is an inserted edge
        whose tail kept its old distance, so that edge relaxes against the
        old vector. Checking every inserted edge against the cached vector
        is therefore exact (for non-negative weights), O(L·|delta|), and
        needs no engine run. An insert that only re-adds an edge at a
        higher weight can flag a false positive (the min duplicate policy
        keeps the old weight) — conservative, never wrong. Removals can
        lengthen paths in ways the cached vector cannot bound, so any
        REALIZED removal marks every landmark stale; ``removed`` (the
        applied count, ``DeltaResult.stats['removed']``) lets a delta whose
        removals all MISSED stay on the cheap insert-only test."""
        L = self.num_landmarks
        if (delta.num_removes if removed is None else removed) > 0:
            return np.ones(L, bool)
        if delta.num_inserts == 0:
            return np.zeros(L, bool)
        u = np.asarray(delta.insert_src, np.int64)
        v = np.asarray(delta.insert_dst, np.int64)
        w = np.asarray(delta.insert_wgt, np.float32)
        du, dv = self.dist[:, u], self.dist[:, v]          # (L, Ni)
        relax = du + w[None, :] < dv
        if not directed:
            relax |= dv + w[None, :] < du
        return np.any(relax, axis=1)

    def refresh(self, pg: PartitionedGraph, delta_result, delta,
                directed: bool = False, backend: str = "local", mesh=None,
                gb=None, exchange: str = "auto", tier_plan=None,
                profile_block=None) -> "LandmarkCache":
        """The post-delta maintenance path: keep every landmark vector the
        delta provably couldn't touch, and resume the stale ones from their
        previous fixpoints in one batched dirty-frontier restart
        (algorithms.incremental.incremental_sssp_batched) instead of
        re-running the full bootstrap SSSP. ``gb`` shares the serving
        fleet's (zero-repack-patched) device graph block;
        ``exchange``/``tier_plan`` route the restart — the service passes
        its narrow-only single-phase plan here (Gopher Phases), since the
        refresh is exactly a narrow-frontier resume. ``profile_block``: the
        graph's HOST block — when given, the restart's wire observation is
        folded into its traffic + changed profiles, which also CONSUMES the
        pending announce record (the restart is the run it pre-announced;
        without the fold, announce records would max-accumulate across
        versions on a service that only ever refreshes landmarks)."""
        from repro.algorithms.incremental import incremental_sssp_batched
        from repro.core import update_changed_profile, update_profile
        stale = self.stale_landmarks(
            delta, directed=directed,
            removed=delta_result.stats.get("removed"))
        dist = self.dist.copy()
        if stale.any():
            fresh, tele = incremental_sssp_batched(
                pg, self.landmarks[stale], self.dist[stale], delta_result,
                backend=backend, mesh=mesh, gb=gb, exchange=exchange,
                tier_plan=tier_plan)
            dist[stale] = fresh
            if profile_block is not None and tele.pair_slots is not None:
                update_profile(profile_block, tele.pair_slots,
                               tele.pair_rounds)
                update_changed_profile(profile_block, tele.count_hist)
        frac = float(stale.sum()) / max(self.num_landmarks, 1)
        ewma = ((1.0 - DRIFT_DECAY) * self.stale_frac_ewma
                + DRIFT_DECAY * frac)
        return LandmarkCache(landmarks=self.landmarks, dist=dist,
                             graph_version=pg.version,
                             queries_answered=self.queries_answered,
                             refreshed_landmarks=int(stale.sum()),
                             strategy=self.strategy,
                             stale_frac_ewma=ewma,
                             refreshes=self.refreshes + 1)

    def approx_sssp(self, source: int) -> np.ndarray:
        """(n,) UPPER bounds on d(source, ·): min over landmarks of the
        two-leg route through each landmark. inf where no landmark reaches
        both endpoints."""
        self.queries_answered += 1
        to_lm = self.dist[:, source]                   # (L,) d(source, l)
        return np.min(to_lm[:, None] + self.dist, axis=0)

    def lower_bound_sssp(self, source: int) -> np.ndarray:
        """(n,) LOWER bounds via |d(s,l) - d(l,t)| (finite legs only)."""
        to_lm = self.dist[:, source]
        diff = np.abs(to_lm[:, None] - self.dist)
        diff[~(np.isfinite(to_lm)[:, None] & np.isfinite(self.dist))] = 0.0
        return np.max(diff, axis=0)

    def bounds(self, s: int, t: int) -> tuple:
        """(lower, upper) on the single pair distance d(s, t)."""
        return (float(self.lower_bound_sssp(s)[t]),
                float(self.approx_sssp(s)[t]))
