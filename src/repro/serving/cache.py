"""Result caches: exact memoization + landmark triangle-inequality bounds.

Two tiers sit in front of the engine:

  ResultCache      exact (Q-query results memoized by (graph, family,
                   sources)); an LRU over full (n,) result vectors. Repeat
                   queries — the common case for popular sources — cost a
                   dict lookup, zero supersteps.

  LandmarkCache    approximate SSSP WITHOUT touching the engine: precompute
                   exact distance vectors from L landmark vertices (one
                   batched SSSP run — the serving subsystem bootstraps its
                   own cache), then answer any source by the triangle
                   inequality  d(s,t) <= min_l d(s,l) + d(l,t)  (upper bound)
                   and  d(s,t) >= max_l |d(s,l) - d(l,t)|  (lower bound).
                   Exact when s or t IS a landmark. Assumes an undirected
                   graph (d(s,l) = d(l,s) is read off the landmark vector).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional, Sequence

import numpy as np

from repro.gofs.formats import PartitionedGraph


class ResultCache:
    """LRU memo of exact per-query results keyed by Query.cache_key()."""

    def __init__(self, capacity: int = 1024):
        self.capacity = capacity
        self._d: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.invalidations = 0

    def get(self, key) -> Optional[np.ndarray]:
        if key in self._d:
            self._d.move_to_end(key)
            self.hits += 1
            return self._d[key]
        self.misses += 1
        return None

    def put(self, key, value: np.ndarray) -> None:
        if self.capacity <= 0:
            return
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)

    def invalidate(self, pred) -> int:
        """Drop every entry whose key satisfies ``pred``; returns the count.
        The service calls this on graph updates — version-tagged keys make
        stale hits impossible anyway, but eagerly dropping them returns the
        capacity to live entries instead of waiting for LRU churn."""
        dead = [k for k in self._d if pred(k)]
        for k in dead:
            del self._d[k]
        self.invalidations += len(dead)
        return len(dead)

    def __len__(self) -> int:
        return len(self._d)

    def stats(self) -> dict:
        return dict(entries=len(self._d), hits=self.hits, misses=self.misses,
                    invalidations=self.invalidations)


def choose_landmarks(pg: PartitionedGraph, num: int,
                     strategy: str = "degree", seed: int = 0) -> np.ndarray:
    """Pick landmark vertex ids: highest global out-degree (good coverage on
    powerlaw graphs — hubs sit on many shortest paths) or uniform random."""
    if strategy == "degree":
        deg = np.zeros(pg.n_global, np.int64)
        for p in range(pg.num_parts):
            m = pg.vmask[p]
            deg[pg.global_id[p][m]] = pg.out_degree[p][m]
        return np.argsort(-deg, kind="stable")[:num].astype(np.int64)
    if strategy == "random":
        rng = np.random.default_rng(seed)
        return rng.choice(pg.n_global, size=num, replace=False).astype(np.int64)
    raise ValueError(f"unknown landmark strategy {strategy!r}")


@dataclasses.dataclass
class LandmarkCache:
    """L exact landmark distance vectors for one graph; answers approximate
    SSSP with O(L·n) numpy and no engine run. ``graph_version`` records the
    PartitionedGraph version the vectors were computed at — the service
    drops (and optionally rebuilds) the cache when a delta bumps it."""
    landmarks: np.ndarray          # (L,) global vertex ids
    dist: np.ndarray               # (L, n) exact distances from each landmark
    graph_version: int = 0
    queries_answered: int = 0

    @property
    def num_landmarks(self) -> int:
        return int(self.landmarks.shape[0])

    @staticmethod
    def build(pg: PartitionedGraph, num_landmarks: int = 8,
              strategy: str = "degree", backend: str = "local", mesh=None,
              landmarks: Optional[Sequence[int]] = None) -> "LandmarkCache":
        """One batched SSSP run with the landmarks as the query batch."""
        from repro.core import GopherEngine
        from repro.serving.batched import (BatchedSemiringProgram,
                                           gather_query_results,
                                           sssp_query_init)
        lm = (np.asarray(landmarks, np.int64) if landmarks is not None
              else choose_landmarks(pg, num_landmarks, strategy=strategy))
        prog = BatchedSemiringProgram(semiring="min_plus",
                                      num_queries=int(lm.shape[0]))
        eng = GopherEngine(pg, prog, backend=backend, mesh=mesh)
        state, _ = eng.run_queries(extra={"qinit": sssp_query_init(pg, lm)})
        return LandmarkCache(landmarks=lm,
                             dist=gather_query_results(pg, state["x"]),
                             graph_version=pg.version)

    def approx_sssp(self, source: int) -> np.ndarray:
        """(n,) UPPER bounds on d(source, ·): min over landmarks of the
        two-leg route through each landmark. inf where no landmark reaches
        both endpoints."""
        self.queries_answered += 1
        to_lm = self.dist[:, source]                   # (L,) d(source, l)
        return np.min(to_lm[:, None] + self.dist, axis=0)

    def lower_bound_sssp(self, source: int) -> np.ndarray:
        """(n,) LOWER bounds via |d(s,l) - d(l,t)| (finite legs only)."""
        to_lm = self.dist[:, source]
        diff = np.abs(to_lm[:, None] - self.dist)
        diff[~(np.isfinite(to_lm)[:, None] & np.isfinite(self.dist))] = 0.0
        return np.max(diff, axis=0)

    def bounds(self, s: int, t: int) -> tuple:
        """(lower, upper) on the single pair distance d(s, t)."""
        return (float(self.lower_bound_sssp(s)[t]),
                float(self.approx_sssp(s)[t]))
