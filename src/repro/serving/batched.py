"""Query-batched programs: Q concurrent graph queries in one BSP run.

The state pytree and the inbox gain a query axis — per partition the leaves
are (v_max, Q) instead of (v_max,) — and the partition sweep becomes a
multi-vector semiring sweep over all Q queries at once. Q queries then share
ONE graph block, ONE jit cache entry, and ONE set of supersteps (the max
over queries, not the sum): the per-superstep fixed costs (dispatch, mailbox
slot addressing, halt all-reduce) are paid once per batch instead of once
per query.

Layout note: the query axis is TRAILING (minor-most) on device. Every
mailbox slot and every neighbor gather then pulls one CONTIGUOUS Q-vector —
index arithmetic amortizes over the batch and Q rides the SIMD/VPU lane
dimension. Hosts and results still speak "Q first": ``gather_query_results``
returns (Q, n_global).

Dynamic per-request inputs (SSSP sources, reachability seed sets, PPR
personalization vectors) arrive as extra graph-block entries (``qinit`` /
``qseed``), NOT baked into program closures — so the compiled BSP loop is
byte-identical across request batches of the same bucket size and XLA's
compile cache is hit every time after the first batch of a bucket.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.gofs.formats import PAD, PartitionedGraph
from repro.kernels import ops

QUERY_INIT_KEY = "qinit"   # (P, v_max, Q) float32 initial semiring state
QUERY_SEED_KEY = "qseed"   # (P, v_max, Q) float32 PPR personalization vectors
QUERY_X0_KEY = "qx0"       # (P, v_max, Q) float32 previous fixpoint (resume)
QUERY_FRONTIER_KEY = "qfrontier0"  # (P, v_max, Q) bool dirty seed (resume)


def _ew_combine(combine: str, a, b):
    return jnp.minimum(a, b) if combine == "min" else jnp.maximum(a, b)


@dataclasses.dataclass(frozen=True)
class BatchedSemiringProgram:
    """Q-query idempotent-semiring fixpoint: multi-source SSSP / BFS /
    multi-seed reachability, one query per lane of ``gb[qinit]``.

    Per-query trajectories are EXACTLY those of Q sequential SemiringProgram
    runs: the local fixpoint, the per-vertex changed flags and therefore the
    send masks factor over the query axis — queries only share the halt vote,
    and a quiesced query contributes no messages while the rest finish.
    """
    semiring: str                       # min_plus | max_first
    num_queries: int
    init_key: str = QUERY_INIT_KEY
    max_local_iters: Optional[int] = None
    fixpoint_unroll: int = 2            # sweeps fused per convergence check;
                                        # overshoot is a no-op for idempotent ⊕
    # resume=True restarts all Q lanes from a previous fixpoint:
    # gb["qx0"] carries the prior per-query states and gb["qfrontier0"] the
    # per-query dirty seeds (gofs.temporal / algorithms.incremental) — the
    # batched mirror of SemiringProgram's incremental restart, used for
    # landmark-cache maintenance after an apply_delta.
    resume: bool = False

    @property
    def combine(self) -> str:
        return "min" if self.semiring == "min_plus" else "max"

    @property
    def megastep_kind(self) -> Optional[str]:
        """Gopher Hot eligibility (see SemiringProgram.megastep_kind): the
        fused route replays the run-to-local-fixpoint schedule over the
        two-bin batched sweep."""
        return "batched_semiring" if self.max_local_iters is None else None

    def init(self, gb) -> dict:
        if self.resume:
            seed = gb[QUERY_FRONTIER_KEY] & gb["vmask"][:, None]
            return {"x": gb[QUERY_X0_KEY], "changed_v": seed, "frontier": seed}
        x0 = gb[self.init_key]                        # (v_max, Q)
        seed = jnp.broadcast_to(gb["vmask"][:, None], x0.shape)
        return {"x": x0, "changed_v": seed, "frontier": seed}

    def _sweep(self, x, gb):
        # two-bin multi-vector sweep: Q queries per contiguous gather; ⊕ is
        # order-insensitive here so results stay bitwise identical to the
        # scalar ELL sweep
        y = ops.binned_ell_spmv_multi(x, gb["nbr_lo"], gb["wgt_lo"],
                                      gb["adj_hub_idx"], gb["adj_hub_nbr"],
                                      gb["adj_hub_wgt"], self.semiring)
        return _ew_combine(self.combine, x, y)

    def _masked_sweep(self, x, f, gb):
        # frontier-masked variant: a (row, q) lane with no active in-neighbor
        # yields the identity, so quiesced queries/regions cost ~0 while the
        # rest of the batch keeps moving. Bitwise identical for idempotent ⊕.
        y = ops.binned_ell_spmv_multi_frontier(
            x, f, gb["nbr_lo"], gb["wgt_lo"], gb["adj_hub_idx"],
            gb["adj_hub_nbr"], gb["adj_hub_wgt"], self.semiring)
        x2 = _ew_combine(self.combine, x, y)
        return x2, (x2 != x) & gb["vmask"][:, None]

    def superstep(self, state, inbox, gb, step, axes=()):
        x0 = state["x"]                               # (v_max, Q)
        vmask = gb["vmask"]
        x = _ew_combine(self.combine, x0, inbox)
        improved = (x != x0) & vmask[:, None]
        f0 = state["frontier"] | improved
        max_it = self.max_local_iters
        if max_it == 1:
            x2 = self._sweep(x, gb)
            iters = jnp.int32(1)
            f_left = jnp.zeros_like(f0)
        else:
            cap = jnp.int32(max_it if max_it is not None else 2**30)

            def cond(c):
                _, f, it = c
                return jnp.any(f) & (it < cap)

            def body(c):
                xc, f, it = c
                for _ in range(self.fixpoint_unroll):
                    xc, f = self._masked_sweep(xc, f, gb)
                return xc, f, it + self.fixpoint_unroll

            x2, f_left, iters = jax.lax.while_loop(
                cond, body, (x, f0, jnp.int32(0)))
        # no step-0 seed override: the engine primes the first inbox from the
        # init state's messages, so seed values were already delivered
        changed_v = (x2 != x0) & vmask[:, None]
        changed_q = jnp.any(changed_v, axis=0)        # (Q,)
        return {"x": x2, "changed_v": changed_v, "frontier": f_left}, \
            changed_q, iters

    def messages(self, state, gb):
        src = gb["re_src"]
        valid = src != PAD
        safe = jnp.where(valid, src, 0)
        xv = state["x"][safe, :]                      # (r_max, Q)
        vals = (xv + gb["re_wgt"][:, None] if self.semiring == "min_plus"
                else xv)
        send = valid[:, None] & state["changed_v"][safe, :]
        return vals, send


@dataclasses.dataclass(frozen=True)
class BatchedPersonalizedPageRank:
    """Q personalized-PageRank queries per BSP run (pull Jacobi, fixed
    ``num_iters`` supersteps — identical per-query math to PageRankProgram
    with a one-hot teleport). ``gb[qseed]`` holds each query's teleport
    distribution (one-hot at the seed vertex, or any distribution)."""
    n_global: int
    num_queries: int
    num_iters: int = 30
    damping: float = 0.85
    seed_key: str = QUERY_SEED_KEY

    combine = "sum"

    def init(self, gb) -> dict:
        seed = gb[self.seed_key]                      # (v_max, Q)
        return {"r": jnp.where(gb["vmask"][:, None], seed, 0.0)}

    def _contrib(self, r, gb):
        deg = gb["out_degree"].astype(jnp.float32)[:, None]
        return jnp.where(deg > 0, r / jnp.maximum(deg, 1.0), 0.0)

    def superstep(self, state, inbox, gb, step, axes=()):
        vmask = gb["vmask"]
        r = state["r"]                                # (v_max, Q)
        # binned multi-vector sweep over UNIT weights (PR pulls rank shares,
        # not edge weights); padding contributes exact zeros, so this matches
        # the scalar full-ELL pull
        pull = ops.binned_ell_spmv_multi(
            self._contrib(r, gb), gb["nbr_lo"], jnp.ones_like(gb["wgt_lo"]),
            gb["adj_hub_idx"], gb["adj_hub_nbr"],
            jnp.ones_like(gb["adj_hub_wgt"]), "plus_times")
        # per-query GLOBAL dangling mass, redistributed by each query's
        # teleport distribution (same math as PageRankProgram — parity with
        # the scalar program is load-bearing for the serving tests)
        dangling = jnp.sum(
            jnp.where((vmask & (gb["out_degree"] == 0))[:, None], r, 0.0),
            axis=0)                                   # (Q,)
        if axes:
            dangling = jax.lax.psum(dangling, axes)
        r_new = jnp.where(
            vmask[:, None],
            (1.0 - self.damping) * gb[self.seed_key]
            + self.damping * (pull + inbox
                              + dangling[None, :] * gb[self.seed_key]), 0.0)
        active = step + 1 < self.num_iters
        changed_q = jnp.broadcast_to(active, (self.num_queries,))
        return {"r": r_new}, changed_q, jnp.int32(1)

    def messages(self, state, gb):
        src = gb["re_src"]
        valid = src != PAD
        safe = jnp.where(valid, src, 0)
        vals = self._contrib(state["r"], gb)[safe, :]
        send = jnp.broadcast_to(valid[:, None], vals.shape)
        return vals, send


# ---------------- host-side query-array builders ----------------

def sssp_query_init(pg: PartitionedGraph,
                    sources: Sequence[int]) -> np.ndarray:
    """(P, v_max, Q) initial distances: 0 at each query's source, inf else.
    Also the BFS init on unit-weight graphs."""
    Q = len(sources)
    x0 = np.full((pg.num_parts, pg.v_max, Q), np.inf, np.float32)
    for q, s in enumerate(sources):
        x0[int(pg.part_of[s]), int(pg.local_of[s]), q] = 0.0
    return x0


def reachability_query_init(pg: PartitionedGraph,
                            seed_sets: Sequence[Sequence[int]]) -> np.ndarray:
    """Multi-seed reachability = BFS from a seed SET per query: every seed
    starts at 0; a vertex is reachable iff its result is finite."""
    Q = len(seed_sets)
    x0 = np.full((pg.num_parts, pg.v_max, Q), np.inf, np.float32)
    for q, seeds in enumerate(seed_sets):
        for s in seeds:
            x0[int(pg.part_of[s]), int(pg.local_of[s]), q] = 0.0
    return x0


def ppr_query_seed(pg: PartitionedGraph,
                   sources: Sequence[int]) -> np.ndarray:
    """(P, v_max, Q) one-hot teleport distributions for personalized PR."""
    Q = len(sources)
    seed = np.zeros((pg.num_parts, pg.v_max, Q), np.float32)
    for q, s in enumerate(sources):
        seed[int(pg.part_of[s]), int(pg.local_of[s]), q] = 1.0
    return seed


def gather_query_results(pg: PartitionedGraph, xq: np.ndarray) -> np.ndarray:
    """(P, v_max, Q) engine state -> (Q, n_global) in global vertex order."""
    xq = np.asarray(xq)
    Q = xq.shape[2]
    out = np.zeros((Q, pg.n_global), xq.dtype)
    for p in range(pg.num_parts):
        m = pg.vmask[p]
        out[:, pg.global_id[p][m]] = xq[p][m, :].T
    return out
