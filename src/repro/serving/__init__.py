"""Gopher Serve: multi-tenant batched graph-query serving.

Turns the one-shot BSP engine into an interactive query service (the paper's
§6 "low enough latency for interactive analytics" claim, taken literally):
many concurrent SSSP / BFS / reachability / personalized-PageRank queries are
batched along a query axis and answered by ONE engine run, fronted by exact
and landmark caches and a batching planner.
"""
from repro.serving.batched import (BatchedPersonalizedPageRank,
                                   BatchedSemiringProgram,
                                   gather_query_results, ppr_query_seed,
                                   reachability_query_init, sssp_query_init)
from repro.serving.cache import LandmarkCache, ResultCache, choose_landmarks
from repro.serving.planner import Batch, Query, bucket_size, plan
from repro.serving.service import GraphQueryService, Response, ServiceStats

__all__ = [
    "BatchedSemiringProgram", "BatchedPersonalizedPageRank",
    "sssp_query_init", "reachability_query_init", "ppr_query_seed",
    "gather_query_results",
    "ResultCache", "LandmarkCache", "choose_landmarks",
    "Query", "Batch", "plan", "bucket_size",
    "GraphQueryService", "Response", "ServiceStats",
]
