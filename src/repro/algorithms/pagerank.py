"""PageRank (classic) and BlockRank (paper §5.3).

Classic PageRank maps to the engine with one Jacobi iteration per superstep —
as the paper notes, the sub-graph abstraction gives no superstep reduction
here (Gopher "simulates" the vertex iterations), so both modes run the same
``num_iters`` supersteps and the interesting comparison is per-superstep cost
and straggler skew (Fig 5).

BlockRank exploits the sub-graph structure the way the paper prescribes:
  phase 1  per-sub-graph LOCAL PageRank to convergence (zero messages —
           pure local fixpoint; one "costlier" superstep);
  phase 2  rank the blocks themselves (meta-graph PageRank — tiny, host-side);
  phase 3  seed classic PageRank with blockrank-weighted local ranks and run
           WITH a convergence tolerance -> far fewer global supersteps.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GopherEngine, PageRankProgram, meta_graph
from repro.gofs.formats import PAD, PartitionedGraph
from repro.kernels import ops


def pagerank(pg: PartitionedGraph, num_iters: int = 30, damping: float = 0.85,
             tol: Optional[float] = None, backend: str = "local", mesh=None,
             spmv_backend: Optional[str] = None, init_r: Optional[np.ndarray] = None):
    """Returns (ranks (P, v_max) float32, Telemetry)."""
    init_fn = None
    if init_r is not None:
        r0 = jnp.asarray(init_r)

        def init_fn(gb):  # noqa: E306
            return r0[gb["part_index"]]

    prog = PageRankProgram(n_global=pg.n_global, num_iters=num_iters,
                           damping=damping, tol=tol, spmv_backend=spmv_backend,
                           init_fn=init_fn)
    eng = GopherEngine(pg, prog, backend=backend, mesh=mesh,
                       max_supersteps=max(num_iters + 1, 64))
    state, tele = eng.run()
    r = np.array(state["r"])
    r[~pg.vmask] = 0.0
    return r, tele


def _local_pagerank(pg: PartitionedGraph, num_iters: int = 30,
                    damping: float = 0.85, spmv_backend: Optional[str] = None):
    """Phase 1: PageRank of each sub-graph in isolation (local edges only,
    per-sub-graph normalization). Pure local fixpoint — zero messages."""
    nbr = jnp.asarray(pg.nbr)
    wgt = jnp.ones_like(jnp.asarray(pg.wgt))
    vmask = jnp.asarray(pg.vmask)
    sg = jnp.asarray(pg.sg_id)
    v_max = pg.v_max

    # per-vertex LOCAL out-degree = how many local in-lists reference it
    def local_outdeg(nbr_p):
        idx = jnp.where(nbr_p == PAD, v_max, nbr_p).reshape(-1)
        return jax.ops.segment_sum(jnp.ones_like(idx, jnp.float32), idx,
                                   num_segments=v_max + 1)[:v_max]

    # per-sub-graph vertex counts -> per-vertex n_b
    def sg_size(sg_p, vmask_p):
        idx = jnp.where(vmask_p, sg_p, v_max).astype(jnp.int32)
        cnt = jax.ops.segment_sum(jnp.ones_like(idx, jnp.float32), idx,
                                  num_segments=v_max + 1)
        return cnt[jnp.clip(sg_p, 0, v_max - 1)]

    outdeg = jax.vmap(local_outdeg)(nbr)
    n_b = jax.vmap(sg_size)(sg, vmask)
    n_b = jnp.maximum(n_b, 1.0)

    def one_part(nbr_p, wgt_p, vmask_p, od_p, nb_p):
        r = jnp.where(vmask_p, 1.0 / nb_p, 0.0)

        def body(_, r):
            contrib = jnp.where(od_p > 0, r / jnp.maximum(od_p, 1.0), 0.0)
            pull = ops.semiring_spmv(contrib, nbr_p, wgt_p, "plus_times",
                                     backend=spmv_backend)
            return jnp.where(vmask_p, (1 - damping) / nb_p + damping * pull, 0.0)

        return jax.lax.fori_loop(0, num_iters, body, r)

    return np.asarray(jax.jit(jax.vmap(one_part))(nbr, wgt, vmask, outdeg, n_b))


def blockrank(pg: PartitionedGraph, damping: float = 0.85,
              tol: float = 1e-7, max_iters: int = 30,
              local_iters: int = 20, backend: str = "local", mesh=None,
              spmv_backend: Optional[str] = None):
    """Returns (ranks, Telemetry-of-phase-3, info dict)."""
    # phase 1: local per-block PageRank
    local_r = _local_pagerank(pg, num_iters=local_iters, damping=damping,
                              spmv_backend=spmv_backend)
    # phase 2: meta-graph PageRank (host-side; meta graph is tiny)
    num_meta, meta_adj, meta_of = meta_graph(pg)
    br = np.full(num_meta, 1.0 / max(num_meta, 1))
    deg = np.asarray(meta_adj.sum(1)).ravel()
    a = meta_adj.T.astype(np.float64)
    for _ in range(50):
        contrib = np.where(deg > 0, br / np.maximum(deg, 1), 0.0)
        br = (1 - damping) / max(num_meta, 1) + damping * (a @ contrib)
    # phase 3: seed classic PageRank with blockrank-weighted local ranks
    valid = pg.sg_id != PAD
    seed = np.zeros((pg.num_parts, pg.v_max), np.float32)
    seed[valid] = (local_r[valid] * br[meta_of[valid]]).astype(np.float32)
    s = seed[pg.vmask].sum()
    seed = seed / max(s, 1e-12)  # normalize to a distribution
    r, tele = pagerank(pg, num_iters=max_iters, damping=damping, tol=tol,
                       backend=backend, mesh=mesh, spmv_backend=spmv_backend,
                       init_r=seed)
    return r, tele, dict(num_meta=num_meta, blockrank=br)
