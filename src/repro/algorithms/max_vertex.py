"""Max Vertex (paper Algorithm 2) — the didactic example of the abstraction."""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import GopherEngine, SemiringProgram, init_max_vertex
from repro.gofs.formats import PartitionedGraph


def max_vertex(pg: PartitionedGraph, mode: str = "subgraph",
               backend: str = "local", mesh=None,
               spmv_backend: Optional[str] = None):
    """Returns (per-vertex max-reachable-value (P, v_max), Telemetry).

    mode='subgraph' -> Gopher (local fixpoint); mode='vertex' -> Giraph-like
    (one sweep per superstep).
    """
    prog = SemiringProgram(
        semiring="max_first", init_fn=init_max_vertex,
        max_local_iters=None if mode == "subgraph" else 1,
        spmv_backend=spmv_backend)
    eng = GopherEngine(pg, prog, backend=backend, mesh=mesh)
    state, tele = eng.run()
    x = np.array(state["x"])
    x[~pg.vmask] = -np.inf
    return x, tele
