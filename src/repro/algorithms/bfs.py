"""BFS levels = SSSP over unit weights (paper §5.4 traversal class)."""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import GopherEngine, SemiringProgram, make_bfs_init
from repro.gofs.formats import PartitionedGraph


def bfs(pg: PartitionedGraph, source_global: int, mode: str = "subgraph",
        backend: str = "local", mesh=None,
        spmv_backend: Optional[str] = None):
    """Returns (levels (P, v_max) float32 — hop counts, inf unreachable, Telemetry).
    Requires the graph to have been built with unit weights."""
    sp_ = int(pg.part_of[source_global])
    sl_ = int(pg.local_of[source_global])
    prog = SemiringProgram(
        semiring="min_plus", init_fn=make_bfs_init(sp_, sl_),
        max_local_iters=None if mode == "subgraph" else 1,
        spmv_backend=spmv_backend)
    eng = GopherEngine(pg, prog, backend=backend, mesh=mesh)
    state, tele = eng.run()
    lvl = np.array(state["x"])
    lvl[~pg.vmask] = np.inf
    return lvl, tele
