"""Connected Components via HCC label propagation (paper §5.1).

Sub-graph centric: each superstep propagates the largest vertex id through the
entire sub-graph (local fixpoint), so supersteps = meta-graph diameter + O(1)
instead of vertex diameter + O(1) — the paper's 554 -> 7 result on RN.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import GopherEngine, SemiringProgram, init_max_vertex
from repro.gofs.formats import PartitionedGraph


def connected_components(pg: PartitionedGraph, mode: str = "subgraph",
                         backend: str = "local", mesh=None,
                         spmv_backend: Optional[str] = None,
                         max_local_iters: Optional[int] = None):
    """Returns (labels (P, v_max) int64 — component id = max global vertex id
    in the component, -1 on pad slots —, num_components, Telemetry)."""
    prog = SemiringProgram(
        semiring="max_first", init_fn=init_max_vertex,
        max_local_iters=(max_local_iters if mode == "subgraph" else 1),
        spmv_backend=spmv_backend)
    eng = GopherEngine(pg, prog, backend=backend, mesh=mesh)
    state, tele = eng.run()
    x = np.asarray(state["x"])
    labels = np.where(pg.vmask, x, -1).astype(np.int64)
    ncc = len(np.unique(labels[pg.vmask]))
    return labels, ncc, tele
