"""Single-Source Shortest Path (paper §5.2, Algorithm 3).

The paper runs Dijkstra inside each sub-graph per superstep; priority queues
do not vectorize, so the TPU adaptation runs the min-plus relaxation to local
fixpoint — identical per-superstep semantics (all intra-sub-graph shortest
paths settle before messages go out), identical superstep count
(meta-graph-diameter-bounded), VPU-friendly inner loop (see DESIGN.md §2.1).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import GopherEngine, SemiringProgram, make_sssp_init
from repro.gofs.formats import PartitionedGraph


def sssp(pg: PartitionedGraph, source_global: int, mode: str = "subgraph",
         backend: str = "local", mesh=None,
         spmv_backend: Optional[str] = None,
         max_local_iters: Optional[int] = None):
    """Returns (distances (P, v_max) float32, inf = unreachable, Telemetry)."""
    sp_ = int(pg.part_of[source_global])
    sl_ = int(pg.local_of[source_global])
    prog = SemiringProgram(
        semiring="min_plus", init_fn=make_sssp_init(sp_, sl_),
        max_local_iters=(max_local_iters if mode == "subgraph" else 1),
        spmv_backend=spmv_backend)
    eng = GopherEngine(pg, prog, backend=backend, mesh=mesh)
    state, tele = eng.run()
    dist = np.array(state["x"])
    dist[~pg.vmask] = np.inf
    return dist, tele
