"""Incremental re-convergence for the monotone semiring algorithms.

After an ``gofs.temporal.apply_delta``, CC/BFS/SSSP do NOT restart from
scratch: the previous fixpoint is already correct almost everywhere, and the
idempotent-monotone semirings make partial restarts exact.

Insertions (values can only IMPROVE — min distances shrink, max labels grow):
    resume from the previous fixpoint with the frontier seeded at the
    inserted edges' source endpoints. The masked sweeps re-relax exactly the
    affected region; every other partition enters its superstep with an
    empty frontier and runs zero sweeps. The result is bitwise identical to
    a cold run on the new graph: the fixpoint of an idempotent ⊕ is the
    ⊕-reduction over all path values, which is schedule-independent.

    Boundary messaging note: seeding the inserted SOURCES (not destinations)
    is what makes this correct — sources re-announce their converged values
    at superstep 0 (`changed_v` includes the seed frontier there), so a new
    remote edge delivers its first message, and a new local edge's
    destination row re-relaxes because its in-neighbor is in the frontier.

Deletions (values may be stale-OPTIMISTIC — monotone resume can't fix them):
    fall back to recomputing only the AFFECTED SUB-GRAPHS: every sub-graph
    (partition-local WCC, the paper's meta-vertex) reachable in the new
    meta-graph from a deleted edge's destination sub-graph is reset to its
    cold-start values, and the frontier is seeded with the reset vertices
    plus the *boundary* sources — live remote edges entering the reset
    region, whose converged upstream values re-flow in at superstep 0.
    Any vertex whose old value depended on a deleted edge had a dependency
    path through that edge's destination; the path's surviving suffix makes
    it meta-reachable from a seed, so the reset set covers every stale
    vertex. Unaffected sub-graphs never sweep.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np
import scipy.sparse as sp

from repro.core import GopherEngine, SemiringProgram, meta_graph
from repro.gofs.formats import PAD, PartitionedGraph
from repro.gofs.temporal import DeltaResult


def _meta_reachable(pg: PartitionedGraph, seed_vertices: np.ndarray
                    ) -> np.ndarray:
    """(P, v_max) bool: vertices of every sub-graph reachable (along remote
    edge direction) from the sub-graphs containing ``seed_vertices``."""
    num_meta, _, meta_of = meta_graph(pg)
    if num_meta == 0:
        return np.zeros_like(pg.vmask)
    src_m, dst_m = [], []
    for p in range(pg.num_parts):
        m = pg.re_src[p] != PAD
        if not m.any():
            continue
        src_m.append(meta_of[p, pg.re_src[p][m]])
        dst_m.append(meta_of[pg.re_dst_part[p][m], pg.re_dst_local[p][m]])
    if src_m:
        src_m, dst_m = np.concatenate(src_m), np.concatenate(dst_m)
    else:
        src_m = dst_m = np.zeros(0, np.int64)
    adj = sp.csr_matrix((np.ones(src_m.size, np.int8), (src_m, dst_m)),
                        shape=(num_meta, num_meta))
    reach = np.zeros(num_meta, bool)
    seeds = meta_of[seed_vertices & pg.vmask]
    reach[seeds[seeds >= 0]] = True
    frontier = reach.copy()
    while frontier.any():                       # meta-graph BFS (tiny graph)
        nxt = (adj.T @ frontier) > 0
        nxt &= ~reach
        reach |= nxt
        frontier = nxt
    return reach[np.clip(meta_of, 0, num_meta - 1)] & (meta_of >= 0) & pg.vmask


def _boundary_sources(pg: PartitionedGraph, reset: np.ndarray) -> np.ndarray:
    """(P, v_max) bool: sources of live remote edges entering ``reset`` from
    outside it — they must re-announce their converged values."""
    out = np.zeros_like(reset)
    for p in range(pg.num_parts):
        m = pg.re_src[p] != PAD
        if not m.any():
            continue
        srcs = pg.re_src[p][m]
        into_reset = reset[pg.re_dst_part[p][m], pg.re_dst_local[p][m]]
        from_outside = ~reset[p, srcs]
        out[p, srcs[into_reset & from_outside]] = True
    return out


def _incremental_run(pg: PartitionedGraph, semiring: str, prev_x: np.ndarray,
                     delta: DeltaResult, init_values: np.ndarray,
                     backend: str = "local", mesh=None,
                     spmv_backend: Optional[str] = None,
                     max_local_iters: Optional[int] = None,
                     gb: Optional[dict] = None, exchange: str = "auto",
                     tier_plan=None):
    x0 = np.array(prev_x, np.float32, copy=True)
    frontier = np.asarray(delta.dirty_insert, bool).copy()
    if delta.dirty_remove.any():
        reset = _meta_reachable(pg, np.asarray(delta.dirty_remove, bool))
        x0[reset] = init_values[reset]
        frontier |= reset | _boundary_sources(pg, reset)
    frontier &= pg.vmask
    prog = SemiringProgram(semiring=semiring, resume=True,
                           spmv_backend=spmv_backend,
                           max_local_iters=max_local_iters)
    # gb: pass the zero-repack-patched device block (DeltaResult.block via
    # core.blocks.device_block) so the restart skips the per-version re-pack;
    # exchange/tier_plan: callers holding a taught profile can route the
    # restart over a tiered/phased wire (Gopher Mesh/Phases)
    eng = GopherEngine(pg, prog, backend=backend, mesh=mesh, gb=gb,
                       exchange=exchange, tier_plan=tier_plan)
    return eng.run(extra={"x0": x0, "frontier0": frontier})


def incremental_sssp(pg: PartitionedGraph, source_global: int,
                     prev_dist: np.ndarray, delta: DeltaResult,
                     backend: str = "local", mesh=None,
                     spmv_backend: Optional[str] = None,
                     gb: Optional[dict] = None, exchange: str = "auto",
                     tier_plan=None):
    """SSSP on graph version k+1 from version k's distances. Returns
    (distances (P, v_max), Telemetry) — bit-identical to a cold sssp()."""
    init = np.full((pg.num_parts, pg.v_max), np.inf, np.float32)
    init[int(pg.part_of[source_global]),
         int(pg.local_of[source_global])] = 0.0
    prev_x = np.where(pg.vmask, np.asarray(prev_dist, np.float32), np.inf)
    state, tele = _incremental_run(pg, "min_plus", prev_x, delta, init,
                                   backend=backend, mesh=mesh,
                                   spmv_backend=spmv_backend, gb=gb,
                                   exchange=exchange, tier_plan=tier_plan)
    dist = np.array(state["x"])
    dist[~pg.vmask] = np.inf
    return dist, tele


def incremental_bfs(pg: PartitionedGraph, source_global: int,
                    prev_levels: np.ndarray, delta: DeltaResult,
                    backend: str = "local", mesh=None,
                    spmv_backend: Optional[str] = None,
                    gb: Optional[dict] = None, exchange: str = "auto",
                    tier_plan=None):
    """BFS = SSSP over unit weights (graph must carry unit weights)."""
    return incremental_sssp(pg, source_global, prev_levels, delta,
                            backend=backend, mesh=mesh,
                            spmv_backend=spmv_backend, gb=gb,
                            exchange=exchange, tier_plan=tier_plan)


def incremental_sssp_batched(pg: PartitionedGraph, sources_global,
                             prev_dist: np.ndarray, delta: DeltaResult,
                             backend: str = "local", mesh=None,
                             gb: Optional[dict] = None,
                             exchange: str = "auto", tier_plan=None):
    """Q-source incremental SSSP: resume ALL query lanes from their previous
    fixpoints in ONE batched BSP run (the landmark-maintenance path —
    ROADMAP item 4). ``prev_dist`` is (Q, n_global) in global vertex order
    (LandmarkCache.dist layout); returns (dist (Q, n_global), Telemetry),
    bit-identical to a cold batched run on the new graph.

    The dirty seed is shared across lanes (an inserted edge can improve any
    lane; extra frontier on a converged lane just re-relaxes to the same
    values — idempotent ⊕ makes the overshoot a no-op), while removals
    reset each lane's meta-reachable region to its OWN cold init before the
    restart. ``gb`` lets the caller pass the (possibly zero-repack-patched)
    device graph block so the maintenance run shares the serving fleet's
    device copy; ``exchange``/``tier_plan`` let the serving layer route the
    refresh over its narrow-only phased plan
    (core.tiers.PhasedTierPlan.narrow_resume — this run IS a narrow-frontier
    resume from superstep 0, so it never needs the wide band's geometry)."""
    from repro.serving.batched import (BatchedSemiringProgram,
                                       gather_query_results, sssp_query_init)
    sources_global = np.asarray(sources_global, np.int64).reshape(-1)
    L = int(sources_global.shape[0])
    P, v_max = pg.num_parts, pg.v_max
    prev = np.asarray(prev_dist, np.float32)
    x0 = np.full((P, v_max, L), np.inf, np.float32)
    for p in range(P):
        m = pg.vmask[p]
        x0[p][m] = prev[:, pg.global_id[p][m]].T
    frontier = np.asarray(delta.dirty_insert, bool).copy()
    if delta.dirty_remove.any():
        reset = _meta_reachable(pg, np.asarray(delta.dirty_remove, bool))
        init = sssp_query_init(pg, sources_global)      # (P, v_max, L)
        x0[reset] = init[reset]
        frontier |= reset | _boundary_sources(pg, reset)
    frontier &= pg.vmask
    qf = np.broadcast_to(frontier[..., None], x0.shape)
    prog = BatchedSemiringProgram(semiring="min_plus", num_queries=L,
                                  resume=True)
    eng = GopherEngine(pg, prog, backend=backend, mesh=mesh, gb=gb,
                       exchange=exchange, tier_plan=tier_plan)
    state, tele = eng.run_queries(extra={"qx0": x0, "qfrontier0": qf})
    return gather_query_results(pg, state["x"]), tele


def incremental_connected_components(
        pg: PartitionedGraph, prev_labels: np.ndarray, delta: DeltaResult,
        backend: str = "local", mesh=None,
        spmv_backend: Optional[str] = None,
        gb: Optional[dict] = None, exchange: str = "auto",
        tier_plan=None) -> Tuple[np.ndarray, int, object]:
    """HCC labels on graph version k+1 from version k's labels. Returns
    (labels, num_components, Telemetry) — bit-identical to a cold run."""
    gid = pg.global_id.astype(np.float32)
    init = np.where(pg.vmask, gid, -np.inf).astype(np.float32)
    prev_x = np.where(pg.vmask, np.asarray(prev_labels, np.float32), -np.inf)
    state, tele = _incremental_run(pg, "max_first", prev_x, delta, init,
                                   backend=backend, mesh=mesh,
                                   spmv_backend=spmv_backend, gb=gb,
                                   exchange=exchange, tier_plan=tier_plan)
    x = np.asarray(state["x"])
    labels = np.where(pg.vmask, x, -1).astype(np.int64)
    ncc = len(np.unique(labels[pg.vmask]))
    return labels, ncc, tele
