"""Paper §5 algorithms, each in sub-graph centric AND vertex centric form,
plus incremental (delta-restart) variants of the monotone ones."""
from repro.algorithms.connected_components import connected_components
from repro.algorithms.sssp import sssp
from repro.algorithms.pagerank import blockrank, pagerank
from repro.algorithms.bfs import bfs
from repro.algorithms.max_vertex import max_vertex
from repro.algorithms.incremental import (incremental_bfs,
                                          incremental_connected_components,
                                          incremental_sssp,
                                          incremental_sssp_batched)

__all__ = ["connected_components", "sssp", "pagerank", "blockrank", "bfs",
           "max_vertex", "incremental_sssp", "incremental_bfs",
           "incremental_connected_components", "incremental_sssp_batched"]
