"""Paper §5 algorithms, each in sub-graph centric AND vertex centric form."""
from repro.algorithms.connected_components import connected_components
from repro.algorithms.sssp import sssp
from repro.algorithms.pagerank import blockrank, pagerank
from repro.algorithms.bfs import bfs
from repro.algorithms.max_vertex import max_vertex

__all__ = ["connected_components", "sssp", "pagerank", "blockrank", "bfs",
           "max_vertex"]
