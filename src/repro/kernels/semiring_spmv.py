"""Pallas TPU kernel: ELL semiring SpMV — the sub-graph sweep hotispot.

This is the compute kernel of the whole framework: every Gopher superstep is
one or more of these sweeps (min_plus = SSSP relaxation, max_first = connected
components label propagation, plus_times = PageRank pull).

TPU adaptation of the paper's "shared-memory traversal of the sub-graph":
the partition's vertex-state vector x stays resident in VMEM across the sweep
(sub-graphs fit fast memory — the paper's locality insight moved from
RAM-vs-disk down to VMEM-vs-HBM), while the ELL adjacency streams through in
row blocks. Row blocks are multiples of 8 sublanes; D is lane-padded by GoFS.
The gather from x is a dynamic VMEM gather (Mosaic `dynamic_gather` /
jnp.take); pad slots carry the ⊕-identity so no masking divergence exists —
the kernel is branch-free.

Grid: (V // block_v,). Working set per step: block_v*D*(4+4) bytes for
nbr+wgt + V*4 bytes for x, chosen so it stays well under VMEM (~16 MiB class).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.gofs.formats import PAD
from repro.kernels.ref import SEMIRINGS


def _combine(semiring: str, g, w, valid):
    if semiring == "min_plus":
        t = jnp.where(valid, g + w, jnp.inf)
        return jnp.min(t, axis=-1)
    if semiring == "max_first":
        t = jnp.where(valid, g, -jnp.inf)
        return jnp.max(t, axis=-1)
    if semiring == "plus_times":
        t = jnp.where(valid, g * w, 0.0)
        return jnp.sum(t, axis=-1)
    raise ValueError(semiring)


def _spmv_kernel(x_ref, nbr_ref, wgt_ref, y_ref, *, semiring: str):
    x = x_ref[...]                      # (V,) resident VMEM copy of vertex state
    idx = nbr_ref[...]                  # (BV, D) row block of ELL indices
    w = wgt_ref[...]                    # (BV, D)
    valid = idx != PAD
    safe = jnp.where(valid, idx, 0)
    g = jnp.take(x, safe.reshape(-1), axis=0).reshape(idx.shape)
    y_ref[...] = _combine(semiring, g, w, valid).astype(y_ref.dtype)


_IDENT = {"min_plus": float("inf"), "max_first": float("-inf"),
          "plus_times": 0.0}


def _spmv_frontier_kernel(x_ref, f_ref, nbr_ref, wgt_ref, y_ref, act_ref, *,
                          semiring: str):
    """Frontier-masked row block: the cheap frontier gather (f32 0/1) runs
    first; the expensive x-gather + semiring arithmetic is PREDICATED on the
    block containing at least one active row, so a quiesced region's blocks
    cost one small gather and a write — ~0 relative to the full sweep."""
    idx = nbr_ref[...]                  # (BV, D)
    valid = idx != PAD
    safe = jnp.where(valid, idx, 0)
    fg = jnp.take(f_ref[...], safe.reshape(-1), axis=0).reshape(idx.shape)
    row_active = jnp.any(valid & (fg > 0), axis=-1)     # (BV,)
    ident = _IDENT[semiring]

    @pl.when(jnp.any(row_active))
    def _compute():
        g = jnp.take(x_ref[...], safe.reshape(-1), axis=0).reshape(idx.shape)
        y = _combine(semiring, g, wgt_ref[...], valid)
        y_ref[...] = jnp.where(row_active, y, ident).astype(y_ref.dtype)

    @pl.when(~jnp.any(row_active))
    def _skip():
        y_ref[...] = jnp.full(y_ref.shape, ident, y_ref.dtype)

    act_ref[...] = row_active


@functools.partial(jax.jit, static_argnames=("semiring", "block_v", "interpret"))
def semiring_spmv_frontier_pallas(x: jnp.ndarray, frontier: jnp.ndarray,
                                  nbr: jnp.ndarray, wgt: jnp.ndarray,
                                  semiring: str, block_v: int = 256,
                                  interpret: bool = True):
    """Frontier-masked ELL sweep (idempotent semirings only): inactive rows
    return the ⊕-identity without paying the x-gather or the combine.
    Returns (y, row_active); see kernels.ref.semiring_spmv_frontier_ref for
    the exact contract."""
    assert semiring in ("min_plus", "max_first")
    v, d = nbr.shape
    bv = min(block_v, v)
    v_pad = -(-v // bv) * bv
    f = frontier.astype(jnp.float32)    # f32 0/1: TPU-friendly VMEM gather
    if v_pad != v:
        x_p = jnp.pad(x, (0, v_pad - v))
        f = jnp.pad(f, (0, v_pad - v))
        nbr = jnp.pad(nbr, ((0, v_pad - v), (0, 0)), constant_values=PAD)
        wgt = jnp.pad(wgt, ((0, v_pad - v), (0, 0)))
    else:
        x_p = x
    grid = (v_pad // bv,)
    y, act = pl.pallas_call(
        functools.partial(_spmv_frontier_kernel, semiring=semiring),
        grid=grid,
        in_specs=[
            pl.BlockSpec((v_pad,), lambda i: (0,)),        # x: VMEM-resident
            pl.BlockSpec((v_pad,), lambda i: (0,)),        # frontier bits
            pl.BlockSpec((bv, d), lambda i: (i, 0)),
            pl.BlockSpec((bv, d), lambda i: (i, 0)),
        ],
        out_specs=(pl.BlockSpec((bv,), lambda i: (i,)),
                   pl.BlockSpec((bv,), lambda i: (i,))),
        out_shape=(jax.ShapeDtypeStruct((v_pad,), x.dtype),
                   jax.ShapeDtypeStruct((v_pad,), jnp.bool_)),
        interpret=interpret,
    )(x_p, f, nbr, wgt)
    return y[:v], act[:v]


@functools.partial(jax.jit, static_argnames=("semiring", "block_v", "interpret"))
def semiring_spmv_pallas(x: jnp.ndarray, nbr: jnp.ndarray, wgt: jnp.ndarray,
                         semiring: str, block_v: int = 256,
                         interpret: bool = True) -> jnp.ndarray:
    """y[v] = ⊕_j ( x[nbr[v,j]] ⊗ wgt[v,j] ), Pallas ELL kernel.

    x: (V,) f32 — padded so V % block_v == 0 is NOT required (we pad here).
    """
    assert semiring in SEMIRINGS
    v, d = nbr.shape
    bv = min(block_v, v)
    v_pad = -(-v // bv) * bv
    if v_pad != v:
        x_p = jnp.pad(x, (0, v_pad - v))
        nbr = jnp.pad(nbr, ((0, v_pad - v), (0, 0)), constant_values=PAD)
        wgt = jnp.pad(wgt, ((0, v_pad - v), (0, 0)))
    else:
        x_p = x
    grid = (v_pad // bv,)
    y = pl.pallas_call(
        functools.partial(_spmv_kernel, semiring=semiring),
        grid=grid,
        in_specs=[
            pl.BlockSpec((v_pad,), lambda i: (0,)),        # x: full, VMEM-resident
            pl.BlockSpec((bv, d), lambda i: (i, 0)),       # nbr row block
            pl.BlockSpec((bv, d), lambda i: (i, 0)),       # wgt row block
        ],
        out_specs=pl.BlockSpec((bv,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((v_pad,), x.dtype),
        interpret=interpret,
    )(x_p, nbr, wgt)
    return y[:v]
