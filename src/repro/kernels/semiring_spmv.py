"""Pallas TPU kernel: ELL semiring SpMV — the sub-graph sweep hotispot.

This is the compute kernel of the whole framework: every Gopher superstep is
one or more of these sweeps (min_plus = SSSP relaxation, max_first = connected
components label propagation, plus_times = PageRank pull).

TPU adaptation of the paper's "shared-memory traversal of the sub-graph":
the partition's vertex-state vector x stays resident in VMEM across the sweep
(sub-graphs fit fast memory — the paper's locality insight moved from
RAM-vs-disk down to VMEM-vs-HBM), while the ELL adjacency streams through in
row blocks. Row blocks are multiples of 8 sublanes; D is lane-padded by GoFS.
The gather from x is a dynamic VMEM gather (Mosaic `dynamic_gather` /
jnp.take); pad slots carry the ⊕-identity so no masking divergence exists —
the kernel is branch-free.

Grid: (V // block_v,). Working set per step: block_v*D*(4+4) bytes for
nbr+wgt + V*4 bytes for x, chosen so it stays well under VMEM (~16 MiB class).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.gofs.formats import PAD
from repro.kernels.ref import SEMIRINGS


def _combine(semiring: str, g, w, valid):
    if semiring == "min_plus":
        t = jnp.where(valid, g + w, jnp.inf)
        return jnp.min(t, axis=-1)
    if semiring == "max_first":
        t = jnp.where(valid, g, -jnp.inf)
        return jnp.max(t, axis=-1)
    if semiring == "plus_times":
        t = jnp.where(valid, g * w, 0.0)
        return jnp.sum(t, axis=-1)
    raise ValueError(semiring)


def _spmv_kernel(x_ref, nbr_ref, wgt_ref, y_ref, *, semiring: str):
    x = x_ref[...]                      # (V,) resident VMEM copy of vertex state
    idx = nbr_ref[...]                  # (BV, D) row block of ELL indices
    w = wgt_ref[...]                    # (BV, D)
    valid = idx != PAD
    safe = jnp.where(valid, idx, 0)
    g = jnp.take(x, safe.reshape(-1), axis=0).reshape(idx.shape)
    y_ref[...] = _combine(semiring, g, w, valid).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("semiring", "block_v", "interpret"))
def semiring_spmv_pallas(x: jnp.ndarray, nbr: jnp.ndarray, wgt: jnp.ndarray,
                         semiring: str, block_v: int = 256,
                         interpret: bool = True) -> jnp.ndarray:
    """y[v] = ⊕_j ( x[nbr[v,j]] ⊗ wgt[v,j] ), Pallas ELL kernel.

    x: (V,) f32 — padded so V % block_v == 0 is NOT required (we pad here).
    """
    assert semiring in SEMIRINGS
    v, d = nbr.shape
    bv = min(block_v, v)
    v_pad = -(-v // bv) * bv
    if v_pad != v:
        x_p = jnp.pad(x, (0, v_pad - v))
        nbr = jnp.pad(nbr, ((0, v_pad - v), (0, 0)), constant_values=PAD)
        wgt = jnp.pad(wgt, ((0, v_pad - v), (0, 0)))
    else:
        x_p = x
    grid = (v_pad // bv,)
    y = pl.pallas_call(
        functools.partial(_spmv_kernel, semiring=semiring),
        grid=grid,
        in_specs=[
            pl.BlockSpec((v_pad,), lambda i: (0,)),        # x: full, VMEM-resident
            pl.BlockSpec((bv, d), lambda i: (i, 0)),       # nbr row block
            pl.BlockSpec((bv, d), lambda i: (i, 0)),       # wgt row block
        ],
        out_specs=pl.BlockSpec((bv,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((v_pad,), x.dtype),
        interpret=interpret,
    )(x_p, nbr, wgt)
    return y[:v]
