"""Gopher Hot — the fused superstep megakernel for the small-frontier tail.

BENCH_comm's standing embarrassment: the sparse exchange stack
(compact/tiered/phased) ships 100-300x fewer slots than dense yet LOSES
2-3x wall-clock on local small-frontier runs, because every superstep
dispatches separate sweep, pack, route, and halt-vote stages whose launch
overhead dwarfs the tiny frontier's actual work. That regime — 1-3
supersteps, frontiers of a few dozen vertices — is exactly where
incremental serving lives.

This module collapses the whole superstep into ONE dispatch over the flat
(P*v_max,) state:

- :func:`compose_mailbox` folds the graph block's THREE routing hops
  (remote edge -> outbox slot via ``ob_inv``, slot -> wire, wire -> inbox
  feed via ``ib_lo``/``ib_hub``) into direct gather maps from each
  destination vertex's feed lanes straight to the SOURCE vertex's flat
  state index — computed once per run, O(feed-table) work.
- :func:`megastep_semiring` runs one fused superstep: frontier-gated
  mailbox delivery (= the staged exchange's inbox combine, lane for lane),
  inbox ⊕-combine, the masked local-fixpoint sweep, and the changed/halt
  reduction — one traced stage, one kernel launch on the traced driver
  (vs sweep+pack+route = 3+ staged dispatches).
- :func:`megastep_semiring_pallas` / :func:`resident_megastep_pallas` are
  the Pallas TPU embodiments (``grid=(1,)``, whole problem VMEM-resident,
  the mailbox an on-chip buffer). The resident kernel runs MULTIPLE
  supersteps of a narrow phase inside a single launch, exiting on
  quiescence or the iteration bound — the on-chip-mailbox mode
  :func:`resident_enter_round` gates on the ``PhasedTierPlan`` band
  geometry fitting :data:`MEGASTEP_VMEM_BUDGET`.

Exactness: for idempotent ⊕ (min/max) every value either path produces is
a ⊕-fold of the same multiset of path sums, and float32 min/max are
order-independent bit-for-bit — so the fused superstep, the resident
multi-superstep schedule, and the staged dense exchange all converge to
bitwise-identical fixpoints (the same argument that makes the tiered
dense-retry exact; see analysis.semiring). PageRank's ``sum`` ⊕ folds the
dangling/delta reductions in a different association, so its parity class
is allclose, mirroring the existing cross-mode contract.

Delivery-order note: the staged engine exchanges AFTER superstep s and
primes round 0 from the init state. The fused loop instead delivers at
the TOP of superstep s from the previous superstep's ``changed_v`` — the
same messages, one loop-carried dependency shorter (and round 0 falls out
of init's ``changed_v`` seed with no special case). The wasted
final-round exchange the staged loop pays after the halt vote is simply
never launched.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.gofs.formats import PAD

# per-superstep VMEM footprint (predicted per-round wire slots * 4B) under
# which the resident narrow-phase loop may keep the mailbox on chip
MEGASTEP_VMEM_BUDGET = 4 * 2 ** 20

_IDENT = {"min": jnp.inf, "max": -jnp.inf, "sum": 0.0}
_KIDENT = {"min_plus": jnp.inf, "max_first": -jnp.inf}
_REDUCE = {"min": jnp.min, "max": jnp.max, "sum": jnp.sum}
_MAX_IT = 2 ** 30


def _default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def _ew(combine: str, a, b):
    if combine == "sum":
        return a + b
    return jnp.minimum(a, b) if combine == "min" else jnp.maximum(a, b)


# ---------------- composed routing maps ----------------

# the python-int entries of a composed mailbox — everything else is a
# device array. Callers that ship a mailbox through a jit boundary (the
# engine's pre-composed ``mcm_*`` graph-block entries) strip these and
# re-derive them from static shapes on the far side.
MAILBOX_STATICS = ("num_parts", "v_max", "cap", "n")


def compose_mailbox(gb: dict, adjacency: str = "full") -> dict:
    """Fold the staged mailbox's three routing hops into direct gather maps.

    For destination vertex (p, v), feed lane m of ``ib_lo[p, v]`` names a
    received slot ``src * cap + slot``; that slot's value on the staged path
    is ``x[src][re_src[src, ob_inv[src, p*cap + slot]]]`` (⊗ the edge
    weight) when the source vertex is in the send set. Composing the three
    maps once per run yields, per feed lane: the source's FLAT state index,
    a validity mask, and the edge weight — delivery becomes one gather +
    one lane reduce, bit-identical to the staged inbox combine because the
    lanes hold the same values in the same order.

    Also composed: the slot-activity map (``slot_src``/``slot_ok``) whose
    per-round counts equal the compact path's ``active_slots`` observation
    exactly (feeds the pair-profile EWMA), the edge-level send map
    (``edge_src``/``edge_ok``) for ``messages_sent``, and the flattened
    adjacency (``adjacency='full'`` for scalar programs, ``'binned'`` for
    the batched two-bin ELL, ``'none'`` for delivery-only callers).
    """
    ob_inv = gb["ob_inv"]
    P = ob_inv.shape[0]
    cap = ob_inv.shape[1] // P
    vmask = gb["vmask"]
    v_max = vmask.shape[1]
    n = P * v_max
    re_src = gb["re_src"]
    re_wgt = gb["re_wgt"]
    p1 = jnp.arange(P, dtype=jnp.int32)[:, None]
    p2 = jnp.arange(P, dtype=jnp.int32)[:, None, None]

    def feed_maps(feeds):
        # feeds (P, ..., m): flat received positions src*cap + slot per
        # destination-partition row; returns (src_flat, ok, w) same shape
        valid = feeds != PAD
        ms = jnp.where(valid, feeds, 0)
        src = ms // cap
        slot = ms % cap
        pidx = jnp.arange(P, dtype=jnp.int32).reshape(
            (P,) + (1,) * (feeds.ndim - 1))
        e = ob_inv[src, pidx * cap + slot]
        ev = e != PAD
        es = jnp.where(ev, e, 0)
        s_local = re_src[src, es]
        sv = s_local != PAD
        ok = valid & ev & sv
        src_flat = jnp.where(ok, src * v_max + jnp.where(sv, s_local, 0), 0)
        return src_flat.astype(jnp.int32), ok, re_wgt[src, es]

    lo_src, lo_ok, lo_w = feed_maps(gb["ib_lo"])            # (P, v_max, m_lo)
    m_lo = lo_src.shape[-1]
    hub_src, hub_ok, hub_w = feed_maps(gb["ib_hub"])        # (P, hr_max, m_hi)
    hr_max, m_hi = hub_src.shape[1], hub_src.shape[2]

    # inverse of ib_hub_idx: flat vertex -> its row in the flattened hub
    # feed table (each vertex receives through EITHER ib_lo or ONE hub row,
    # never both — blocks._mailbox_inverse's ⊕=sum no-double-count
    # invariant — so the hub merge is a pure gather, no scatter)
    hidx = gb["ib_hub_idx"]                                 # (P, hr_max)
    hv = hidx != PAD
    tgt = jnp.where(hv, p1 * v_max + hidx, n).reshape(-1)
    rows = jnp.arange(P * hr_max, dtype=jnp.int32)
    hub_row = jnp.full((n + 1,), PAD, jnp.int32) \
        .at[tgt].set(rows, mode="drop")[:n]
    hub_row_ok = hub_row != PAD
    hub_row = jnp.where(hub_row_ok, hub_row, 0)

    # slot-activity map: ob_inv slot -> source vertex flat id. Per-round
    # counts over it == messages.active_slots of the compact path.
    oe = ob_inv
    ov = oe != PAD
    oes = jnp.where(ov, oe, 0)
    o_local = re_src[p1, oes]
    slot_ok = ov & (o_local != PAD)
    slot_src = jnp.where(slot_ok, p1 * v_max
                         + jnp.where(o_local != PAD, o_local, 0), 0)

    # its vertex-level contraction: vdst[v, j] = 1 iff v occupies a slot to
    # destination j (at most one — the outbox dedupes per pair), so a
    # round's per-pair counts are one einsum over the send set instead of a
    # slot-table gather chain every superstep. Counts stay < 2^24, exact
    # in f32.
    dst_col = jnp.tile(jnp.repeat(jnp.arange(P, dtype=jnp.int32), cap),
                       (P, 1))
    vdst = jnp.zeros((n + 1, P), jnp.float32).at[
        jnp.where(slot_ok, slot_src, n).reshape(-1),
        dst_col.reshape(-1)].add(1.0, mode="drop")[:n]

    # edge-level send map (messages_sent), plus its per-vertex contraction:
    # edge_cnt[v] = how many replicated edges vertex v sources, so a round's
    # message count is one (n,)-reduce over the send set instead of a
    # gather over the padded edge table every superstep
    e_ok = re_src != PAD
    edge_src = jnp.where(e_ok, p1 * v_max + jnp.where(e_ok, re_src, 0), 0)
    n_edges = e_ok.size
    edge_cnt = jnp.zeros((n,), jnp.int32).at[
        jnp.where(e_ok, edge_src, n).reshape(-1)].add(
            jnp.ones((n_edges,), jnp.int32), mode="drop")

    cm = {
        "num_parts": P, "v_max": v_max, "cap": cap, "n": n,
        "vmask": vmask.reshape(-1),
        "lo_src": lo_src.reshape(n, m_lo),
        "lo_ok": lo_ok.reshape(n, m_lo),
        "lo_w": lo_w.reshape(n, m_lo),
        "hub_src": hub_src.reshape(P * hr_max, m_hi),
        "hub_ok": hub_ok.reshape(P * hr_max, m_hi),
        "hub_w": hub_w.reshape(P * hr_max, m_hi),
        "hub_row": hub_row, "hub_row_ok": hub_row_ok,
        "slot_src": slot_src.astype(jnp.int32), "slot_ok": slot_ok,
        "vdst": vdst,
        "edge_src": edge_src.astype(jnp.int32), "edge_ok": e_ok,
        "edge_cnt": edge_cnt.astype(jnp.float32),
    }

    if adjacency == "full":
        nbr = gb["nbr"]
        nok = nbr != PAD
        cm["nbr"] = jnp.where(nok, p2 * v_max + jnp.where(nok, nbr, 0), 0) \
            .reshape(n, -1).astype(jnp.int32)
        cm["nbr_ok"] = nok.reshape(n, -1)
        cm["wgt"] = gb["wgt"].reshape(n, -1)
    elif adjacency == "binned":
        lo = gb["nbr_lo"]
        lov = lo != PAD
        cm["nbr_lo"] = jnp.where(lov, p2 * v_max + jnp.where(lov, lo, 0), 0) \
            .reshape(n, -1).astype(jnp.int32)
        cm["nbr_lo_ok"] = lov.reshape(n, -1)
        cm["wgt_lo"] = gb["wgt_lo"].reshape(n, -1)
        ah = gb["adj_hub_idx"]                              # (P, ah_max)
        ahv = ah != PAD
        cm["ahub_dst"] = jnp.where(ahv, p1 * v_max + jnp.where(ahv, ah, 0),
                                   n).reshape(-1).astype(jnp.int32)
        an = gb["adj_hub_nbr"]
        anv = an != PAD
        cm["ahub_nbr"] = jnp.where(anv, p2 * v_max + jnp.where(anv, an, 0),
                                   0).reshape(an.shape[0] * an.shape[1], -1) \
            .astype(jnp.int32)
        cm["ahub_ok"] = anv.reshape(an.shape[0] * an.shape[1], -1)
        cm["ahub_wgt"] = gb["adj_hub_wgt"] \
            .reshape(an.shape[0] * an.shape[1], -1)
    return cm


# ---------------- fused mailbox delivery ----------------

def deliver_flat(vals, live, cm: dict, combine: str, with_weight: bool):
    """The staged exchange's pack -> route -> inbox-combine pipeline as one
    gather + lane reduce over the composed maps. ``vals`` is the (n,) or
    (n, Q) per-source message value (pre-⊗ except the edge weight); ``live``
    gates sends (None = unconditional, PageRank-style). Lane-for-lane equal
    to messages.combine_inbox_gather over the routed slot array, so the
    reduce is bitwise identical."""
    ident = _IDENT[combine]
    red = _REDUCE[combine]
    batched = vals.ndim == 2

    def pull(src, ok, w):
        g = vals[src]
        if batched:
            ok = ok[..., None]
            if with_weight:
                g = g + w[..., None]
        elif with_weight:
            g = g + w
        if live is not None:
            ok = ok & live[src]
        return jnp.where(ok, g, ident)

    axis = -2 if batched else -1
    y = red(pull(cm["lo_src"], cm["lo_ok"], cm["lo_w"]), axis=axis)
    yh = red(pull(cm["hub_src"], cm["hub_ok"], cm["hub_w"]), axis=axis)
    hro = cm["hub_row_ok"]
    hub = jnp.where(hro[:, None] if batched else hro, yh[cm["hub_row"]],
                    ident)
    return _ew(combine, y, hub)


def round_stats(changed, cm: dict):
    """One round's wire observation from the send set: the per-pair active
    slot counts (== messages.active_slots of the compact path, feeding the
    tier-profile EWMA) and the edge-level message count. ``changed=None``
    counts unconditional sends (PageRank). Batched send sets activate a
    slot when ANY query lane sends (the contiguous Q-vector ships as one
    unit) but count messages per lane."""
    P, v_max = cm["num_parts"], cm["v_max"]
    cnt, vdst = cm["edge_cnt"], cm["vdst"]
    if changed is None:
        pairs = vdst.reshape(P, v_max, P).sum(axis=1)
        return pairs.astype(jnp.int32), jnp.sum(cnt).astype(jnp.int32)
    if changed.ndim == 1:
        chf = changed.astype(jnp.float32)
        nsent = jnp.dot(chf, cnt)
    else:
        chf = jnp.any(changed, axis=1).astype(jnp.float32)
        nsent = jnp.dot(changed.astype(jnp.float32).sum(axis=1), cnt)
    pairs = jnp.einsum("pv,pvj->pj", chf.reshape(P, v_max),
                       vdst.reshape(P, v_max, P))
    return pairs.astype(jnp.int32), nsent.astype(jnp.int32)


# ---------------- flat frontier sweeps ----------------

def sweep_flat(x, f, cm: dict, semiring: str):
    """Frontier-masked ELL sweep over the flattened full adjacency —
    row-for-row the math of kernels.ref.semiring_spmv_frontier_ref, so the
    per-partition staged sweep and this flat one produce identical bits."""
    ident = _KIDENT[semiring]
    ok, idx = cm["nbr_ok"], cm["nbr"]
    g = x[idx]
    act = jnp.any(ok & f[idx], axis=1)
    if semiring == "min_plus":
        y = jnp.min(jnp.where(ok, g + cm["wgt"], jnp.inf), axis=1)
    else:
        y = jnp.max(jnp.where(ok, g, -jnp.inf), axis=1)
    return jnp.where(act, y, ident)


def sweep_flat_dense(x, cm: dict):
    """Unmasked plus_times sweep with unit weights over the flat adjacency
    (PageRank's pull) — mirrors semiring_spmv_ref lane for lane."""
    ok, idx = cm["nbr_ok"], cm["nbr"]
    g = x[idx]
    ones = jnp.ones_like(cm["wgt"])
    return jnp.sum(jnp.where(ok, g * ones, 0.0), axis=1)


def sweep_flat_batched(x, f, cm: dict, semiring: str):
    """Frontier-masked two-bin multi-query sweep over the flattened binned
    adjacency — mirrors ops.binned_ell_spmv_multi_frontier (lo bin + hub
    scatter merge) with flat indices."""
    assert semiring in ("min_plus", "max_first")
    ident = _KIDENT[semiring]

    def sweep(idx, ok, w):
        act = jnp.any(ok[..., None] & f[idx], axis=1)       # (rows, Q)
        g = x[idx]                                          # (rows, D, Q)
        if semiring == "min_plus":
            y = jnp.min(jnp.where(ok[..., None], g + w[..., None], jnp.inf),
                        axis=1)
        else:
            y = jnp.max(jnp.where(ok[..., None], g, -jnp.inf), axis=1)
        return jnp.where(act, y, ident)

    y = sweep(cm["nbr_lo"], cm["nbr_lo_ok"], cm["wgt_lo"])
    yh = sweep(cm["ahub_nbr"], cm["ahub_ok"], cm["ahub_wgt"])
    ref = y.at[cm["ahub_dst"]]
    if semiring == "min_plus":
        return ref.min(yh, mode="drop")
    return ref.max(yh, mode="drop")


# ---------------- fused supersteps (jnp oracles + dispatch) ----------------

def megastep_semiring(x, changed, frontier, cm: dict, semiring: str,
                      unroll: int = 1, backend: Optional[str] = None):
    """One fused superstep for scalar idempotent-semiring programs on flat
    state: deliver the previous round's messages, ⊕-combine, run the
    masked local fixpoint, emit the new send set. Returns
    ``(x2, changed2, f_left, liters)`` with liters per partition matching
    the staged vmapped while_loop's select semantics bit for bit.
    TPU dispatches the Pallas megakernel; CPU runs the jnp oracle (the
    kernel is still exercised in interpret mode by the parity tests)."""
    backend = backend or _default_backend()
    if backend == "pallas":
        return megastep_semiring_pallas(
            x, changed, frontier, cm, semiring, unroll=unroll,
            interpret=jax.default_backend() != "tpu")
    combine = "min" if semiring == "min_plus" else "max"
    vm = cm["vmask"]
    P = cm["num_parts"]
    inbox = deliver_flat(x, changed, cm, combine, semiring == "min_plus")
    x1 = _ew(combine, x, inbox)
    f0 = frontier | ((x1 != x) & vm)

    def cond(c):
        _, f, it, _ = c
        return jnp.any(f) & (it < jnp.int32(_MAX_IT))

    def body(c):
        xc, f, it, li = c
        li = li + jnp.int32(unroll) * jnp.any(f.reshape(P, -1), axis=1)
        for _ in range(unroll):
            y = sweep_flat(xc, f, cm, semiring)
            x2 = _ew(combine, xc, y)
            f = (x2 != xc) & vm
            xc = x2
        return xc, f, it + jnp.int32(unroll), li

    x2, f_left, _, liters = jax.lax.while_loop(
        cond, body, (x1, f0, jnp.int32(0), jnp.zeros((P,), jnp.int32)))
    changed2 = (x2 != x) & vm
    return x2, changed2, f_left, liters


def megastep_semiring_batched(x, changed, frontier, cm: dict, semiring: str,
                              unroll: int = 2):
    """Q-query fused superstep on flat (n, Q) state — the serving hot path.
    Mirrors serving.batched.BatchedSemiringProgram's superstep + the staged
    batched exchange lane for lane."""
    combine = "min" if semiring == "min_plus" else "max"
    vm = cm["vmask"][:, None]
    P = cm["num_parts"]
    inbox = deliver_flat(x, changed, cm, combine, semiring == "min_plus")
    x1 = _ew(combine, x, inbox)
    f0 = frontier | ((x1 != x) & vm)

    def cond(c):
        _, f, it, _ = c
        return jnp.any(f) & (it < jnp.int32(_MAX_IT))

    def body(c):
        xc, f, it, li = c
        li = li + jnp.int32(unroll) * jnp.any(f.reshape(P, -1), axis=1)
        for _ in range(unroll):
            y = sweep_flat_batched(xc, f, cm, semiring)
            x2 = _ew(combine, xc, y)
            f = (x2 != xc) & vm
            xc = x2
        return xc, f, it + jnp.int32(unroll), li

    x2, f_left, _, liters = jax.lax.while_loop(
        cond, body, (x1, f0, jnp.int32(0), jnp.zeros((P,), jnp.int32)))
    changed2 = (x2 != x) & vm
    return x2, changed2, f_left, liters


def megastep_pagerank(r, cm: dict, deg, tele, n_global: int, damping: float,
                      num_iters: int, step):
    """One fused PageRank superstep on flat state: contributions, pull
    sweep, unconditional mailbox delivery, dangling redistribution, rank
    update. The dangling-mass and delta reductions keep the staged path's
    per-partition-then-global association (sum over v_max, then over P —
    the shape the vmapped psum folds), so local parity is tight; the
    cross-mode contract stays allclose (⊕ = sum is not associative in
    float and collective lowering may re-associate)."""
    vm = cm["vmask"]
    P = cm["num_parts"]
    contrib = jnp.where(deg > 0, r / jnp.maximum(deg, 1.0), 0.0)
    pull = sweep_flat_dense(contrib, cm)
    inbox = deliver_flat(contrib, None, cm, "sum", False)
    dangling = jnp.sum(jnp.sum(
        jnp.where(vm & (deg == 0), r, 0.0).reshape(P, -1), axis=1))
    r_new = jnp.where(
        vm,
        (1.0 - damping) * tele + damping * (pull + inbox + dangling * tele),
        0.0)
    delta = jnp.sum(jnp.sum(jnp.abs(r_new - r).reshape(P, -1), axis=1))
    changed = step + 1 < num_iters
    return r_new, delta, changed


def resident_step_semiring(x, changed, frontier, cm: dict, semiring: str):
    """One relaxation round of the resident narrow-phase loop: deliver
    pending news, then a SINGLE masked sweep (local consequences settle
    across rounds instead of per-superstep fixpoints — chaotic relaxation).
    Every improvement is rebroadcast the following round, so the loop
    converges to the same unique ⊕-fixpoint as the BSP schedule, bitwise
    for idempotent ⊕. At exit ``changed2``/``frontier2`` are exactly the
    BSP state contract (pending sends / locally-unsettled rows), so a
    later staged superstep can take over mid-stream."""
    combine = "min" if semiring == "min_plus" else "max"
    vm = cm["vmask"]
    inbox = deliver_flat(x, changed, cm, combine, semiring == "min_plus")
    x1 = _ew(combine, x, inbox)
    f = frontier | ((x1 != x) & vm)
    y = sweep_flat(x1, f, cm, semiring)
    x2 = _ew(combine, x1, y)
    changed2 = (x2 != x) & vm
    frontier2 = (x2 != x1) & vm
    active_p = jnp.any(f.reshape(cm["num_parts"], -1), axis=1)
    return x2, changed2, frontier2, active_p


def resident_enter_round(phase_round_bytes, boundaries,
                         budget: int = MEGASTEP_VMEM_BUDGET):
    """Earliest superstep from which the resident narrow-phase mode may
    take over: the start of the first phase band such that EVERY remaining
    band's predicted per-round wire geometry fits the VMEM budget (the
    frontier only contracts across bands by construction, but a
    non-monotone profile keeps the conservative suffix rule honest).
    Returns None when no suffix fits."""
    k0 = None
    for k in range(len(phase_round_bytes) - 1, -1, -1):
        if phase_round_bytes[k] <= budget:
            k0 = k
        else:
            break
    if k0 is None:
        return None
    return 0 if k0 == 0 else int(boundaries[k0 - 1])


# ---------------- Pallas megakernels ----------------
# grid=(1,): the whole flat problem is VMEM-resident for the small-frontier
# tail this path is gated to (resident_enter_round budgets the geometry),
# so block index maps are trivial and every output store is unconditional.


def _take(v, i):
    return jnp.take(v, i.reshape(-1)).reshape(i.shape)


def _deliver_kernel_vals(x0, ch, lsrc, lok, lw, hsrc, hok, hw, hrow, hrok,
                         semiring):
    minp = semiring == "min_plus"
    ident = _KIDENT[semiring]
    lm = (lok > 0.0) & (_take(ch, lsrc) > 0.0)
    lg = _take(x0, lsrc)
    if minp:
        y = jnp.min(jnp.where(lm, lg + lw, ident), axis=1)
    else:
        y = jnp.max(jnp.where(lm, lg, ident), axis=1)
    hm = (hok > 0.0) & (_take(ch, hsrc) > 0.0)
    hg = _take(x0, hsrc)
    if minp:
        yh = jnp.min(jnp.where(hm, hg + hw, ident), axis=1)
    else:
        yh = jnp.max(jnp.where(hm, hg, ident), axis=1)
    hub = jnp.where(hrok > 0.0, jnp.take(yh, hrow), ident)
    return jnp.minimum(y, hub) if minp else jnp.maximum(y, hub)


def _sweep_kernel_vals(xc, f, nbr, nok, wgt, semiring):
    minp = semiring == "min_plus"
    ident = _KIDENT[semiring]
    act = jnp.max(jnp.where(nok, _take(f, nbr), 0.0), axis=1) > 0.0
    if minp:
        y = jnp.min(jnp.where(nok, _take(xc, nbr) + wgt, ident), axis=1)
        ys = jnp.where(act, y, ident)
        return jnp.minimum(xc, ys)
    y = jnp.max(jnp.where(nok, _take(xc, nbr), ident), axis=1)
    ys = jnp.where(act, y, ident)
    return jnp.maximum(xc, ys)


def _megastep_kernel(x_ref, ch_ref, fr_ref, vm_ref, nbr_ref, nok_ref,
                     wgt_ref, lsrc_ref, lok_ref, lw_ref, hsrc_ref, hok_ref,
                     hw_ref, hrow_ref, hrok_ref,
                     xo_ref, cho_ref, fro_ref, lit_ref,
                     *, semiring, num_parts, unroll):
    x0 = x_ref[...]
    vmb = vm_ref[...] > 0.0
    inbox = _deliver_kernel_vals(
        x0, ch_ref[...], lsrc_ref[...], lok_ref[...], lw_ref[...],
        hsrc_ref[...], hok_ref[...], hw_ref[...], hrow_ref[...],
        hrok_ref[...], semiring)
    minp = semiring == "min_plus"
    x1 = jnp.minimum(x0, inbox) if minp else jnp.maximum(x0, inbox)
    f0 = jnp.maximum(fr_ref[...], ((x1 != x0) & vmb).astype(jnp.float32))
    nbr = nbr_ref[...]
    nok = nok_ref[...] > 0.0
    wgt = wgt_ref[...]

    def cond(c):
        _, f, it, _ = c
        return jnp.any(f > 0.0) & (it < jnp.int32(_MAX_IT))

    def body(c):
        xc, f, it, li = c
        li = li + jnp.int32(unroll) * jnp.any(
            f.reshape(num_parts, -1) > 0.0, axis=1)
        for _ in range(unroll):
            x2 = _sweep_kernel_vals(xc, f, nbr, nok, wgt, semiring)
            f = ((x2 != xc) & vmb).astype(jnp.float32)
            xc = x2
        return xc, f, it + jnp.int32(unroll), li

    x2, f_left, _, li = jax.lax.while_loop(
        cond, body,
        (x1, f0, jnp.int32(0), jnp.zeros((num_parts,), jnp.int32)))
    xo_ref[...] = x2
    cho_ref[...] = ((x2 != x0) & vmb).astype(jnp.float32)
    fro_ref[...] = f_left
    lit_ref[...] = li


def _resident_kernel(x_ref, ch_ref, fr_ref, vm_ref, nbr_ref, nok_ref,
                     wgt_ref, lsrc_ref, lok_ref, lw_ref, hsrc_ref, hok_ref,
                     hw_ref, hrow_ref, hrok_ref,
                     xo_ref, cho_ref, fro_ref, it_ref, lit_ref,
                     *, semiring, num_parts, max_steps):
    vmb = vm_ref[...] > 0.0
    minp = semiring == "min_plus"
    lsrc, lok, lw = lsrc_ref[...], lok_ref[...], lw_ref[...]
    hsrc, hok, hw = hsrc_ref[...], hok_ref[...], hw_ref[...]
    hrow, hrok = hrow_ref[...], hrok_ref[...]
    nbr = nbr_ref[...]
    nok = nok_ref[...] > 0.0
    wgt = wgt_ref[...]

    def cond(c):
        _, ch, _, it, _ = c
        return jnp.any(ch > 0.0) & (it < jnp.int32(max_steps))

    def body(c):
        xc, ch, fr, it, li = c
        inbox = _deliver_kernel_vals(xc, ch, lsrc, lok, lw, hsrc, hok, hw,
                                     hrow, hrok, semiring)
        x1 = jnp.minimum(xc, inbox) if minp else jnp.maximum(xc, inbox)
        f = jnp.maximum(fr, ((x1 != xc) & vmb).astype(jnp.float32))
        li = li + jnp.any(f.reshape(num_parts, -1) > 0.0, axis=1)
        x2 = _sweep_kernel_vals(x1, f, nbr, nok, wgt, semiring)
        ch2 = ((x2 != xc) & vmb).astype(jnp.float32)
        fr2 = ((x2 != x1) & vmb).astype(jnp.float32)
        return x2, ch2, fr2, it + jnp.int32(1), li

    x2, ch2, fr2, it, li = jax.lax.while_loop(
        cond, body,
        (x_ref[...], ch_ref[...], fr_ref[...], jnp.int32(0),
         jnp.zeros((num_parts,), jnp.int32)))
    xo_ref[...] = x2
    cho_ref[...] = ch2
    fro_ref[...] = fr2
    it_ref[...] = jnp.full((1,), it, jnp.int32)
    lit_ref[...] = li


def _mega_operands(x, changed, frontier, cm):
    f32 = jnp.float32
    return (
        x, changed.astype(f32), frontier.astype(f32),
        cm["vmask"].astype(f32),
        cm["nbr"], cm["nbr_ok"].astype(f32), cm["wgt"],
        cm["lo_src"], cm["lo_ok"].astype(f32), cm["lo_w"],
        cm["hub_src"], cm["hub_ok"].astype(f32), cm["hub_w"],
        cm["hub_row"], cm["hub_row_ok"].astype(f32),
    )


def _full_specs(operands):
    return [pl.BlockSpec(op.shape, lambda *_, nd=op.ndim: (0,) * nd)
            for op in operands]


def megastep_semiring_pallas(x, changed, frontier, cm: dict, semiring: str,
                             unroll: int = 1, interpret: bool = False):
    """The fused superstep as ONE Pallas launch: mailbox delivery, inbox
    combine, masked local fixpoint, and the changed/halt partial reduction
    all execute against VMEM-resident state."""
    n = x.shape[0]
    P = cm["num_parts"]
    ops = _mega_operands(x, changed, frontier, cm)
    import functools
    kernel = functools.partial(_megastep_kernel, semiring=semiring,
                               num_parts=P, unroll=unroll)
    x2, ch, fr, li = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=_full_specs(ops),
        out_specs=[pl.BlockSpec((n,), lambda i: (0,)),
                   pl.BlockSpec((n,), lambda i: (0,)),
                   pl.BlockSpec((n,), lambda i: (0,)),
                   pl.BlockSpec((P,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((n,), x.dtype),
                   jax.ShapeDtypeStruct((n,), jnp.float32),
                   jax.ShapeDtypeStruct((n,), jnp.float32),
                   jax.ShapeDtypeStruct((P,), jnp.int32)],
        interpret=interpret,
    )(*ops)
    return x2, ch > 0.0, fr > 0.0, li


def resident_megastep_pallas(x, changed, frontier, cm: dict, semiring: str,
                             max_steps: int, interpret: bool = False):
    """The resident narrow-phase megakernel: MULTIPLE supersteps run inside
    one launch with the mailbox held on chip, exiting on quiescence or the
    ``max_steps`` bound. Returns ``(x2, changed2, frontier2, iters,
    liters)`` — the exit state keeps the BSP contract, so the caller can
    hand off to a staged superstep at a phase boundary."""
    n = x.shape[0]
    P = cm["num_parts"]
    ops = _mega_operands(x, changed, frontier, cm)
    import functools
    kernel = functools.partial(_resident_kernel, semiring=semiring,
                               num_parts=P, max_steps=max_steps)
    x2, ch, fr, it, li = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=_full_specs(ops),
        out_specs=[pl.BlockSpec((n,), lambda i: (0,)),
                   pl.BlockSpec((n,), lambda i: (0,)),
                   pl.BlockSpec((n,), lambda i: (0,)),
                   pl.BlockSpec((1,), lambda i: (0,)),
                   pl.BlockSpec((P,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((n,), x.dtype),
                   jax.ShapeDtypeStruct((n,), jnp.float32),
                   jax.ShapeDtypeStruct((n,), jnp.float32),
                   jax.ShapeDtypeStruct((1,), jnp.int32),
                   jax.ShapeDtypeStruct((P,), jnp.int32)],
        interpret=interpret,
    )(*ops)
    return x2, ch > 0.0, fr > 0.0, it[0], li
