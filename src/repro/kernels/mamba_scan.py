"""Pallas TPU kernel: fused Mamba1 (S6) selective scan — §Perf F5.

The XLA-level chunked scan (models.layers.mamba1_mixer) must materialize the
(B, Q, di, N) state expansion at fusion boundaries every chunk — measured as
the dominant memory term of falcon-mamba-7b train_4k even after F1–F4
(EXPERIMENTS.md). This kernel keeps the recurrent state h (BD, N) in VMEM for
the whole sequence: HBM traffic collapses to the δ/x/B/C input streams and
the y output stream, ≈ (3·L·BD + 2·L·N + L·BD) elements per block instead of
O(L·BD·N) — a ~2·N ≈ 32× traffic reduction.

Grid: (B, di/BD) — each program instance owns a channel block and loops the
sequence with `lax.fori_loop`, state resident. Forward only: the training
backward needs the reverse-sweep kernel (documented follow-up); the serving
path (prefill/decode) and inference-only deployments use it as-is.

Validated in interpret mode against a step-by-step recurrence oracle
(tests/test_kernels.py::test_mamba_scan_kernel*).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref):
    # blocks: x/dt (1, L, BD); b/c (1, L, N); a (BD, N); y (1, L, BD)
    L = x_ref.shape[1]
    A = a_ref[...].astype(jnp.float32)               # (BD, N)
    BD, N = A.shape

    def step(l, h):
        dt = dt_ref[0, l].astype(jnp.float32)        # (BD,)
        xv = x_ref[0, l].astype(jnp.float32)
        bv = b_ref[0, l].astype(jnp.float32)         # (N,)
        cv = c_ref[0, l].astype(jnp.float32)
        da = jnp.exp(dt[:, None] * A)                # (BD, N)
        h = da * h + (dt * xv)[:, None] * bv[None, :]
        y_ref[0, l] = (h @ cv).astype(y_ref.dtype)   # (BD,)
        return h

    jax.lax.fori_loop(0, L, step, jnp.zeros((BD, N), jnp.float32))


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def mamba1_scan_pallas(x, delta, Bv, Cv, A, block_d: int = 128,
                       interpret: bool = True):
    """y[b,l,d] = Σ_n h[b,l,d,n]·C[b,l,n] with
    h[b,l] = exp(δ[b,l]⊗A)·h[b,l-1] + (δ[b,l]·x[b,l])⊗B[b,l].

    x, delta: (B, L, D); Bv, Cv: (B, L, N); A: (D, N) (negative decays).
    """
    B, L, D = x.shape
    N = A.shape[1]
    bd = min(block_d, D)
    while D % bd:
        bd -= 1
    grid = (B, D // bd)
    return pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, bd), lambda b, d: (b, 0, d)),   # x
            pl.BlockSpec((1, L, bd), lambda b, d: (b, 0, d)),   # delta
            pl.BlockSpec((1, L, N), lambda b, d: (b, 0, 0)),    # B
            pl.BlockSpec((1, L, N), lambda b, d: (b, 0, 0)),    # C
            pl.BlockSpec((bd, N), lambda b, d: (d, 0)),         # A
        ],
        out_specs=pl.BlockSpec((1, L, bd), lambda b, d: (b, 0, d)),
        out_shape=jax.ShapeDtypeStruct((B, L, D), x.dtype),
        interpret=interpret,
    )(x, delta, Bv, Cv, A)


def mamba1_scan_ref(x, delta, Bv, Cv, A):
    """Step-by-step oracle (pure jnp)."""
    B, L, D = x.shape
    N = A.shape[1]

    def step(h, inp):
        xv, dt, bv, cv = inp
        da = jnp.exp(dt[:, :, None] * A)                        # (B, D, N)
        h = da * h + (dt * xv)[:, :, None] * bv[:, None, :]
        return h, jnp.einsum("bdn,bn->bd", h, cv)

    h0 = jnp.zeros((B, D, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0,
                         (x.transpose(1, 0, 2).astype(jnp.float32),
                          delta.transpose(1, 0, 2).astype(jnp.float32),
                          Bv.transpose(1, 0, 2).astype(jnp.float32),
                          Cv.transpose(1, 0, 2).astype(jnp.float32)))
    return ys.transpose(1, 0, 2).astype(x.dtype)
