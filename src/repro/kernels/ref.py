"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the Pallas kernels are validated against
(interpret=True on CPU), and they double as the fast XLA:CPU execution path
for the engine when no TPU is present — same math, fusion left to XLA.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.gofs.formats import PAD

SEMIRINGS = ("min_plus", "max_first", "plus_times")


def semiring_spmv_ref(x: jnp.ndarray, nbr: jnp.ndarray, wgt: jnp.ndarray,
                      semiring: str) -> jnp.ndarray:
    """ELL semiring sweep: y[v] = ⊕_j ( x[nbr[v,j]] ⊗ wgt[v,j] ).

    x: (V,) float32; nbr: (V, D) int32 with PAD fill; wgt: (V, D) float32.
    Semirings: min_plus (SSSP), max_first (CC/MaxVertex — ⊗ ignores wgt),
    plus_times (PageRank).
    """
    valid = nbr != PAD
    safe = jnp.where(valid, nbr, 0)
    g = x[safe]  # (V, D)
    if semiring == "min_plus":
        t = jnp.where(valid, g + wgt, jnp.inf)
        return jnp.min(t, axis=1)
    if semiring == "max_first":
        t = jnp.where(valid, g, -jnp.inf)
        return jnp.max(t, axis=1)
    if semiring == "plus_times":
        t = jnp.where(valid, g * wgt, 0.0)
        return jnp.sum(t, axis=1)
    raise ValueError(f"unknown semiring {semiring}")


def outbox_compact_plan_ref(active: jnp.ndarray):
    """Per-row compaction plan for the frontier-compacted outbox (Gopher
    Wire). ``active``: (R, cap) bool — mailbox slots whose source vertex is
    in the send set this superstep. Returns

      pfwd   (R, cap) int32  packed position j -> slot id (PAD past count):
                             the j-th ACTIVE slot in ascending slot order —
                             the sender gathers values through this to build
                             the dense prefix that travels
      pinv   (R, cap) int32  slot id -> packed position (PAD if inactive):
                             the receiver reconstructs fixed slot positions
                             through this with a pure gather (the O(count)
                             dual of scattering the prefix back)
      counts (R,)   int32    prefix length per destination row — the wire
                             header; Σ counts is the superstep's payload

    pfwd and pinv are inverse permutations restricted to the active set;
    both derive from the same stable order so the Pallas kernel and this
    oracle are bit-identical.
    """
    cap = active.shape[-1]
    counts = jnp.sum(active, axis=-1).astype(jnp.int32)
    # stable sort of ~active: active slots first, ascending slot id
    order = jnp.argsort(~active, axis=-1, stable=True).astype(jnp.int32)
    j = jnp.arange(cap, dtype=jnp.int32)[None, :]
    pfwd = jnp.where(j < counts[:, None], order, PAD)
    csum = jnp.cumsum(active.astype(jnp.int32), axis=-1)
    pinv = jnp.where(active, csum - 1, PAD)
    return pfwd, pinv, counts


def outbox_pack_ref(slot_vals: jnp.ndarray, active: jnp.ndarray,
                    limit: jnp.ndarray, ident: float):
    """Fused compaction plan + value pack (Gopher Mesh). One pass replaces
    PR 3's argsort plan + take_along_axis gather: the packed position of an
    active slot is just its mask prefix-sum minus one, so the pack is a
    single masked scatter — no sort runs at all.

    slot_vals: (R, cap) or (R, cap, Q) dense slot values (the gather-form
    outbox); active: (R, cap) bool; limit: (R,) int32 per-row slot budget
    (the pair's tier width — positions at or past it are TRUNCATED, which
    the tiered exchange detects via ``over`` and repairs with the dense
    fallback retry). Returns

      pvals  like slot_vals   packed prefix, ident-filled past min(count,
                              limit)
      sids   (R, cap) int32   packed position -> slot id (PAD past the
                              prefix) — the receiver's scatter addresses
      pinv   (R, cap) int32   slot id -> packed position (PAD if inactive
                              or truncated) — the compact exchange's
                              receiver gather map
      counts (R,)   int32     UNtruncated active count (the profile /
                              overflow signal)
      over   (R,)   int32     1 where counts > limit (messages were dropped)
    """
    R, cap = active.shape
    act = active.astype(jnp.int32)
    csum = jnp.cumsum(act, axis=-1)
    counts = csum[:, -1]
    pos = csum - 1
    keep = active & (pos < limit[:, None])
    dest = jnp.where(keep, pos, cap)                  # cap -> dropped
    rows = jnp.arange(R, dtype=jnp.int32)[:, None]
    slot = jnp.broadcast_to(jnp.arange(cap, dtype=jnp.int32)[None, :],
                            (R, cap))
    sids = jnp.full((R, cap), PAD, jnp.int32).at[rows, dest].set(
        slot, mode="drop")
    pinv = jnp.where(keep, pos, PAD).astype(jnp.int32)
    pv = jnp.full(slot_vals.shape, ident, slot_vals.dtype)
    pvals = pv.at[rows, dest].set(slot_vals, mode="drop")
    over = (counts > limit).astype(jnp.int32)
    return pvals, sids, pinv, counts, over


def semiring_spmv_frontier_ref(x: jnp.ndarray, frontier: jnp.ndarray,
                               nbr: jnp.ndarray, wgt: jnp.ndarray,
                               semiring: str):
    """Frontier-masked ELL sweep: rows with NO active in-neighbor yield the
    ⊕-identity (the caller's element-wise combine keeps their old state);
    rows WITH one reduce their full neighbor list, exactly like the unmasked
    sweep. Restricted to the idempotent semirings — for those, combine(x,
    identity) == x, so masked and unmasked fixpoints are bitwise identical
    as long as the initial frontier covers every vertex whose value differs
    from the previous fixpoint.

    frontier: (V,) bool. Returns (y, row_active) — row_active is the next
    sweep's candidate set before the caller intersects it with "changed".
    """
    assert semiring in ("min_plus", "max_first"), \
        "frontier masking requires an idempotent ⊕ (min/max)"
    valid = nbr != PAD
    safe = jnp.where(valid, nbr, 0)
    row_active = jnp.any(valid & frontier[safe], axis=1)
    g = x[safe]  # (V, D)
    if semiring == "min_plus":
        t = jnp.where(valid, g + wgt, jnp.inf)
        y = jnp.min(t, axis=1)
        return jnp.where(row_active, y, jnp.inf), row_active
    t = jnp.where(valid, g, -jnp.inf)
    y = jnp.max(t, axis=1)
    return jnp.where(row_active, y, -jnp.inf), row_active
