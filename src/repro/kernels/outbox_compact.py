"""Pallas TPU kernel: outbox compaction plan — the Gopher Wire pack stage.

Each mailbox pair row (one destination partition's cap slots) is compacted
to a dense prefix of its ACTIVE slots before the superstep exchange, so the
payload that travels scales with the frontier instead of P·cap. The plan is
two inverse permutations plus a count header per row (see
kernels.ref.outbox_compact_plan_ref for the exact contract).

TPU formulation: compaction is a data-dependent permutation, which Mosaic
has no sort primitive for — but the STABLE ascending order over a 0/1 mask
is fully determined by the mask's inclusive prefix sum, and a prefix sum
over the lane axis is one matmul against a triangular ones matrix (MXU
work, no scan). From ``csum``:

    pinv[r, i] = csum[r, i] - 1              (elementwise — slot -> position)
    pfwd[r, j] = Σ_i i · [pinv[r, i] == j]   (one-hot contraction — position
                                              -> slot; ≤1 term survives)

Row blocks are (block_r, cap); the one-hot tensor is (block_r, cap, cap), so
block_r stays small (8) to bound VMEM. The kernel is branch-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.gofs.formats import PAD


def _compact_plan_kernel(act_ref, pfwd_ref, pinv_ref, cnt_ref):
    a = act_ref[...]                                    # (BR, C) f32 0/1
    br, c = a.shape
    tri = (jax.lax.broadcasted_iota(jnp.float32, (c, c), 0)
           <= jax.lax.broadcasted_iota(jnp.float32, (c, c), 1)
           ).astype(jnp.float32)
    csum = jnp.dot(a, tri)                              # inclusive prefix sum
    cnt = csum[:, -1]
    act = a > 0
    pos = csum - 1.0                                    # slot -> packed pos
    pinv_ref[...] = jnp.where(act, pos, PAD).astype(jnp.int32)
    # one-hot contraction: match[r, i, j] = active slot i lands at position j
    jgrid = jax.lax.broadcasted_iota(jnp.float32, (br, c, c), 2)
    match = jnp.where(act[:, :, None], (pos[:, :, None] == jgrid)
                      .astype(jnp.float32), 0.0)
    slot = jax.lax.broadcasted_iota(jnp.float32, (br, c, c), 1)
    fwd = jnp.sum(match * slot, axis=1)                 # (BR, C)
    has = jax.lax.broadcasted_iota(jnp.float32, (br, c), 1) < cnt[:, None]
    pfwd_ref[...] = jnp.where(has, fwd, PAD).astype(jnp.int32)
    cnt_ref[...] = cnt.astype(jnp.int32)


def _pack_kernel(act_ref, val_ref, lim_ref, pvals_ref, sids_ref, pinv_ref,
                 cnt_ref, over_ref, *, ident):
    """Fused spill kernel (Gopher Mesh): compaction plan + tier-width
    truncation + value pack + overflow detection in ONE branch-free pass.

    The plan half reuses the triangular-matmul prefix sum of
    ``_compact_plan_kernel``; the pack half replaces the one-hot·slot-id
    contraction with a select-and-reduce over the same (BR, C, C) match
    tensor so packed VALUES come out of the kernel too — a multiply would
    turn an active ±inf message (a legal value under min/max ⊕) into NaN at
    every other position of its row, so the value path selects instead of
    scaling. Positions at or past the row's ``lim`` budget are dropped and
    the row's overflow flag is raised; the engine's dense fallback retry
    makes that loss invisible to results.
    """
    a = act_ref[...]                                    # (BR, C) f32 0/1
    vals = val_ref[...]                                 # (BR, C) f32
    lim = lim_ref[...].astype(jnp.float32)              # (BR,)
    br, c = a.shape
    tri = (jax.lax.broadcasted_iota(jnp.float32, (c, c), 0)
           <= jax.lax.broadcasted_iota(jnp.float32, (c, c), 1)
           ).astype(jnp.float32)
    csum = jnp.dot(a, tri)                              # inclusive prefix sum
    cnt = csum[:, -1]
    act = a > 0
    pos = csum - 1.0                                    # slot -> packed pos
    keep = act & (pos < lim[:, None])
    pinv_ref[...] = jnp.where(keep, pos, PAD).astype(jnp.int32)
    # match[r, i, j] = kept slot i lands at packed position j (<=1 i survives
    # per (r, j), so the reduces below are exact selections)
    jgrid = jax.lax.broadcasted_iota(jnp.float32, (br, c, c), 2)
    match = keep[:, :, None] & (pos[:, :, None] == jgrid)
    slot = jax.lax.broadcasted_iota(jnp.float32, (br, c, c), 1)
    has = (jax.lax.broadcasted_iota(jnp.float32, (br, c), 1)
           < jnp.minimum(cnt, lim)[:, None])
    sids = jnp.sum(jnp.where(match, slot, 0.0), axis=1)
    sids_ref[...] = jnp.where(has, sids, PAD).astype(jnp.int32)
    pv = jnp.sum(jnp.where(match, vals[:, :, None], 0.0), axis=1)
    pvals_ref[...] = jnp.where(has, pv, ident)
    cnt_ref[...] = cnt.astype(jnp.int32)
    over_ref[...] = (cnt > lim).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("ident", "block_r", "interpret"))
def outbox_pack_pallas(slot_vals: jnp.ndarray, active: jnp.ndarray,
                       limit: jnp.ndarray, ident: float, block_r: int = 8,
                       interpret: bool = True):
    """(R, cap) slot values + active mask + per-row budget ->
    (pvals, sids, pinv, counts, over); bit-identical to
    kernels.ref.outbox_pack_ref (single-query form)."""
    r, cap = active.shape
    br = min(block_r, r)
    r_pad = -(-r // br) * br
    a = active.astype(jnp.float32)
    v = slot_vals.astype(jnp.float32)
    lim = limit.astype(jnp.int32)
    if r_pad != r:
        a = jnp.pad(a, ((0, r_pad - r), (0, 0)))
        v = jnp.pad(v, ((0, r_pad - r), (0, 0)))
        lim = jnp.pad(lim, (0, r_pad - r))
    grid = (r_pad // br,)
    row = pl.BlockSpec((br, cap), lambda i: (i, 0))
    vec = pl.BlockSpec((br,), lambda i: (i,))
    pvals, sids, pinv, cnt, over = pl.pallas_call(
        functools.partial(_pack_kernel, ident=ident),
        grid=grid,
        in_specs=[row, row, vec],
        out_specs=(row, row, row, vec, vec),
        out_shape=(jax.ShapeDtypeStruct((r_pad, cap), jnp.float32),
                   jax.ShapeDtypeStruct((r_pad, cap), jnp.int32),
                   jax.ShapeDtypeStruct((r_pad, cap), jnp.int32),
                   jax.ShapeDtypeStruct((r_pad,), jnp.int32),
                   jax.ShapeDtypeStruct((r_pad,), jnp.int32)),
        interpret=interpret,
    )(a, v, lim)
    return (pvals[:r], sids[:r], pinv[:r], cnt[:r], over[:r])


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def outbox_compact_plan_pallas(active: jnp.ndarray, block_r: int = 8,
                               interpret: bool = True):
    """(R, cap) bool active mask -> (pfwd, pinv, counts); bit-identical to
    kernels.ref.outbox_compact_plan_ref."""
    r, cap = active.shape
    br = min(block_r, r)
    r_pad = -(-r // br) * br
    a = active.astype(jnp.float32)
    if r_pad != r:
        a = jnp.pad(a, ((0, r_pad - r), (0, 0)))
    grid = (r_pad // br,)
    pfwd, pinv, cnt = pl.pallas_call(
        _compact_plan_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, cap), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((br, cap), lambda i: (i, 0)),
                   pl.BlockSpec((br, cap), lambda i: (i, 0)),
                   pl.BlockSpec((br,), lambda i: (i,))),
        out_shape=(jax.ShapeDtypeStruct((r_pad, cap), jnp.int32),
                   jax.ShapeDtypeStruct((r_pad, cap), jnp.int32),
                   jax.ShapeDtypeStruct((r_pad,), jnp.int32)),
        interpret=interpret,
    )(a)
    return pfwd[:r], pinv[:r], cnt[:r]
