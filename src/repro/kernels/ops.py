"""jit'd dispatch wrappers for the kernels.

``semiring_spmv`` picks the execution path:
- TPU backend      -> Pallas kernel (compiled)
- CPU (this box)   -> the pure-jnp oracle (same math, XLA-fused); the Pallas
                      path is still fully exercised in interpret mode by the
                      kernel tests.

``multibin_spmv`` is the degree-binned variant for powerlaw graphs (LJ-like):
rows are bucketed by degree into <=3 ELL bins so padding waste stays bounded;
results scatter back by row index.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.gofs.formats import PAD
from repro.kernels.ref import SEMIRINGS, semiring_spmv_ref
from repro.kernels.semiring_spmv import semiring_spmv_pallas


def _default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def semiring_spmv(x: jnp.ndarray, nbr: jnp.ndarray, wgt: jnp.ndarray,
                  semiring: str, backend: Optional[str] = None,
                  block_v: int = 256) -> jnp.ndarray:
    backend = backend or _default_backend()
    if backend == "jnp":
        return semiring_spmv_ref(x, nbr, wgt, semiring)
    if backend == "pallas":
        return semiring_spmv_pallas(x, nbr, wgt, semiring, block_v=block_v,
                                    interpret=jax.default_backend() != "tpu")
    raise ValueError(f"unknown backend {backend}")


# ---------------- multi-bin ELL (degree-skew mitigation) ----------------

def bin_rows_by_degree(nbr: np.ndarray, wgt: np.ndarray,
                       boundaries: Sequence[int] = (8, 64)) -> list:
    """Host-side: split ELL rows into degree bins [(rows, nbr_b, wgt_b), ...].

    Each bin's width is its own max degree (lane-padded), so a powerlaw graph
    pays mega-hub padding only for the handful of hub rows.
    """
    deg = (nbr != PAD).sum(1)
    edges = [0, *boundaries, nbr.shape[1] + 1]
    bins = []
    for lo, hi in zip(edges[:-1], edges[1:]):
        rows = np.flatnonzero((deg >= lo) & (deg < hi))
        if rows.size == 0:
            continue
        w = max(int(deg[rows].max()), 1)
        w = -(-w // 8) * 8
        bins.append((rows.astype(np.int32),
                     np.ascontiguousarray(nbr[rows, :w]),
                     np.ascontiguousarray(wgt[rows, :w])))
    return bins


def multibin_spmv(x: jnp.ndarray, bins: list, v_out: int, semiring: str,
                  backend: Optional[str] = None) -> jnp.ndarray:
    """Semiring sweep over degree-binned ELL; scatter bin results to rows."""
    from repro.core.messages import COMBINE_IDENTITY
    ident = {"min_plus": jnp.inf, "max_first": -jnp.inf, "plus_times": 0.0}[semiring]
    y = jnp.full((v_out,), ident, x.dtype)
    for rows, nbr_b, wgt_b in bins:
        yb = semiring_spmv(x, jnp.asarray(nbr_b), jnp.asarray(wgt_b), semiring,
                           backend=backend)
        y = y.at[rows].set(yb)
    return y
