"""jit'd dispatch wrappers for the kernels.

``semiring_spmv`` picks the execution path:
- TPU backend      -> Pallas kernel (compiled)
- CPU (this box)   -> the pure-jnp oracle (same math, XLA-fused); the Pallas
                      path is still fully exercised in interpret mode by the
                      kernel tests.

``multibin_spmv`` is the degree-binned variant for powerlaw graphs (LJ-like):
rows are bucketed by degree into <=3 ELL bins so padding waste stays bounded;
results scatter back by row index.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.gofs.formats import PAD
from repro.kernels.outbox_compact import (outbox_compact_plan_pallas,
                                          outbox_pack_pallas)
from repro.kernels.ref import (outbox_compact_plan_ref,
                               outbox_pack_ref, semiring_spmv_frontier_ref,
                               semiring_spmv_ref)
from repro.kernels.semiring_spmv import (semiring_spmv_frontier_pallas,
                                         semiring_spmv_pallas)


def _default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def semiring_spmv(x: jnp.ndarray, nbr: jnp.ndarray, wgt: jnp.ndarray,
                  semiring: str, backend: Optional[str] = None,
                  block_v: int = 256) -> jnp.ndarray:
    backend = backend or _default_backend()
    if backend == "jnp":
        return semiring_spmv_ref(x, nbr, wgt, semiring)
    if backend == "pallas":
        return semiring_spmv_pallas(x, nbr, wgt, semiring, block_v=block_v,
                                    interpret=jax.default_backend() != "tpu")
    raise ValueError(f"unknown backend {backend}")


def semiring_spmv_frontier(x: jnp.ndarray, frontier: jnp.ndarray,
                           nbr: jnp.ndarray, wgt: jnp.ndarray, semiring: str,
                           backend: Optional[str] = None,
                           block_v: int = 256):
    """Frontier-masked ELL sweep (idempotent ⊕ only): rows with no active
    in-neighbor yield the identity at ~0 cost (the Pallas path predicates the
    gather+combine per row block on the frontier). Returns (y, row_active)."""
    backend = backend or _default_backend()
    if backend == "jnp":
        return semiring_spmv_frontier_ref(x, frontier, nbr, wgt, semiring)
    if backend == "pallas":
        return semiring_spmv_frontier_pallas(
            x, frontier, nbr, wgt, semiring, block_v=block_v,
            interpret=jax.default_backend() != "tpu")
    raise ValueError(f"unknown backend {backend}")


def outbox_compact_plan(active: jnp.ndarray, backend: Optional[str] = None,
                        block_r: int = 8):
    """Frontier-compaction plan for the sparse mailbox exchange (Gopher
    Wire): (R, cap) active-slot mask -> (pfwd, pinv, counts). See
    kernels.ref.outbox_compact_plan_ref for the contract; the Pallas path
    is bit-identical (stable ascending order both ways)."""
    backend = backend or _default_backend()
    if backend == "jnp":
        return outbox_compact_plan_ref(active)
    if backend == "pallas":
        return outbox_compact_plan_pallas(
            active, block_r=block_r,
            interpret=jax.default_backend() != "tpu")
    raise ValueError(f"unknown backend {backend}")


def outbox_pack(slot_vals: jnp.ndarray, active: jnp.ndarray,
                limit: jnp.ndarray, ident: float,
                backend: Optional[str] = None, block_r: int = 8):
    """Fused compaction plan + value pack + spill detection (Gopher Mesh):
    (R, cap[, Q]) slot values + (R, cap) active mask + (R,) tier budget ->
    (pvals, sids, pinv, counts, over). See kernels.ref.outbox_pack_ref for
    the contract. This replaces PR 3's separate argsort/one-hot plan pass:
    the jnp path is one cumsum + one masked scatter, the Pallas path is the
    single fused spill kernel (kernels.outbox_compact.outbox_pack_pallas).

    Q-batched values keep the fused kernel for the plan half (the plan is
    query-independent) and pack the contiguous Q-vectors with the same
    masked scatter the jnp path uses — the per-lane value DMA dominates
    there, not the plan.
    """
    backend = backend or _default_backend()
    if backend == "jnp":
        return outbox_pack_ref(slot_vals, active, limit, ident)
    if backend == "pallas":
        interp = jax.default_backend() != "tpu"
        if slot_vals.ndim == 2:
            return outbox_pack_pallas(slot_vals, active, limit, ident,
                                      block_r=block_r, interpret=interp)
        # Q-batched: plan (+ per-row truncation/overflow) from the fused
        # kernel, Q-vector pack as a masked scatter through pinv
        _, sids, pinv, counts, over = outbox_pack_pallas(
            jnp.zeros(active.shape, jnp.float32), active, limit, ident,
            block_r=block_r, interpret=interp)
        r, cap = active.shape
        rows = jnp.arange(r, dtype=jnp.int32)[:, None]
        dest = jnp.where(pinv != PAD, pinv, cap)
        pvals = jnp.full(slot_vals.shape, ident, slot_vals.dtype
                         ).at[rows, dest].set(slot_vals, mode="drop")
        return pvals, sids, pinv, counts, over
    raise ValueError(f"unknown backend {backend}")


def binned_ell_spmv_multi(x: jnp.ndarray, nbr_lo: jnp.ndarray,
                          wgt_lo: jnp.ndarray, hub_idx: jnp.ndarray,
                          hub_nbr: jnp.ndarray, hub_wgt: jnp.ndarray,
                          semiring: str) -> jnp.ndarray:
    """Multi-vector two-bin ELL sweep: x is (V, Q) — Q problem instances over
    one topology, QUERY-TRAILING so every neighbor gather pulls a contiguous
    Q-vector (index arithmetic and bounds checks amortize Q-fold; Q rides the
    SIMD/VPU lane dimension). The serving hot path.
    """
    v_max = x.shape[0]

    def sweep(nbr, wgt):
        valid = nbr != PAD
        g = x[jnp.where(valid, nbr, 0), :]               # (rows, D, Q)
        if semiring == "min_plus":
            t = jnp.where(valid[..., None], g + wgt[..., None], jnp.inf)
            return jnp.min(t, axis=1)
        if semiring == "max_first":
            t = jnp.where(valid[..., None], g, -jnp.inf)
            return jnp.max(t, axis=1)
        if semiring == "plus_times":
            t = jnp.where(valid[..., None], g * wgt[..., None], 0.0)
            return jnp.sum(t, axis=1)
        raise ValueError(f"unknown semiring {semiring}")

    y = sweep(nbr_lo, wgt_lo)                            # (V, Q)
    yh = sweep(hub_nbr, hub_wgt)                         # (H, Q)
    idx = jnp.where(hub_idx != PAD, hub_idx, v_max)
    ref = y.at[idx]
    if semiring == "min_plus":
        return ref.min(yh, mode="drop")
    if semiring == "max_first":
        return ref.max(yh, mode="drop")
    return ref.add(yh, mode="drop")


def binned_ell_spmv_multi_frontier(x: jnp.ndarray, frontier: jnp.ndarray,
                                   nbr_lo: jnp.ndarray, wgt_lo: jnp.ndarray,
                                   hub_idx: jnp.ndarray, hub_nbr: jnp.ndarray,
                                   hub_wgt: jnp.ndarray,
                                   semiring: str) -> jnp.ndarray:
    """Frontier-masked two-bin multi-vector sweep: frontier is (V, Q) bool,
    per query lane. A (row, q) pair with no active in-neighbor in lane q
    yields the ⊕-identity (the caller's combine keeps its old state), so a
    query whose region has quiesced stops paying for that region's rows.
    Idempotent semirings only — see semiring_spmv_frontier_ref."""
    assert semiring in ("min_plus", "max_first")
    v_max = x.shape[0]
    ident = jnp.inf if semiring == "min_plus" else -jnp.inf

    def sweep(nbr, wgt):
        valid = nbr != PAD
        safe = jnp.where(valid, nbr, 0)
        act = jnp.any(valid[..., None] & frontier[safe, :], axis=1)  # (rows, Q)
        g = x[safe, :]                                   # (rows, D, Q)
        if semiring == "min_plus":
            t = jnp.where(valid[..., None], g + wgt[..., None], jnp.inf)
            y = jnp.min(t, axis=1)
        else:
            t = jnp.where(valid[..., None], g, -jnp.inf)
            y = jnp.max(t, axis=1)
        return jnp.where(act, y, ident)

    y = sweep(nbr_lo, wgt_lo)                            # (V, Q)
    yh = sweep(hub_nbr, hub_wgt)                         # (H, Q)
    idx = jnp.where(hub_idx != PAD, hub_idx, v_max)
    ref = y.at[idx]
    if semiring == "min_plus":
        return ref.min(yh, mode="drop")
    return ref.max(yh, mode="drop")


# ---------------- multi-bin ELL (degree-skew mitigation) ----------------

def bin_rows_by_degree(nbr: np.ndarray, wgt: np.ndarray,
                       boundaries: Sequence[int] = (8, 64)) -> list:
    """Host-side: split ELL rows into degree bins [(rows, nbr_b, wgt_b), ...].

    Each bin's width is its own max degree (lane-padded), so a powerlaw graph
    pays mega-hub padding only for the handful of hub rows.
    """
    deg = (nbr != PAD).sum(1)
    edges = [0, *boundaries, nbr.shape[1] + 1]
    bins = []
    for lo, hi in zip(edges[:-1], edges[1:]):
        rows = np.flatnonzero((deg >= lo) & (deg < hi))
        if rows.size == 0:
            continue
        w = max(int(deg[rows].max()), 1)
        w = -(-w // 8) * 8
        bins.append((rows.astype(np.int32),
                     np.ascontiguousarray(nbr[rows, :w]),
                     np.ascontiguousarray(wgt[rows, :w])))
    return bins


def multibin_spmv(x: jnp.ndarray, bins: list, v_out: int, semiring: str,
                  backend: Optional[str] = None) -> jnp.ndarray:
    """Semiring sweep over degree-binned ELL; scatter bin results to rows."""
    ident = {"min_plus": jnp.inf, "max_first": -jnp.inf, "plus_times": 0.0}[semiring]
    y = jnp.full((v_out,), ident, x.dtype)
    for rows, nbr_b, wgt_b in bins:
        yb = semiring_spmv(x, jnp.asarray(nbr_b), jnp.asarray(wgt_b), semiring,
                           backend=backend)
        y = y.at[rows].set(yb)
    return y
