"""Pallas TPU kernels: the sub-graph semiring sweep (paper hot-spot) and the
fused flash attention (LM-substrate hot-spot), each with jnp oracles."""
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mamba_scan import mamba1_scan_pallas, mamba1_scan_ref
from repro.kernels.ops import (bin_rows_by_degree, binned_ell_spmv_multi,
                               binned_ell_spmv_multi_frontier, multibin_spmv,
                               outbox_compact_plan, semiring_spmv,
                               semiring_spmv_frontier)
from repro.kernels.outbox_compact import outbox_compact_plan_pallas
from repro.kernels.ref import (outbox_compact_plan_ref,
                               semiring_spmv_frontier_ref, semiring_spmv_ref)
from repro.kernels.semiring_spmv import (semiring_spmv_frontier_pallas,
                                         semiring_spmv_pallas)

__all__ = ["semiring_spmv", "semiring_spmv_ref", "semiring_spmv_pallas",
           "semiring_spmv_frontier", "semiring_spmv_frontier_ref",
           "semiring_spmv_frontier_pallas",
           "outbox_compact_plan", "outbox_compact_plan_ref",
           "outbox_compact_plan_pallas",
           "binned_ell_spmv_multi", "binned_ell_spmv_multi_frontier",
           "bin_rows_by_degree", "multibin_spmv", "flash_attention_pallas",
           "mamba1_scan_pallas", "mamba1_scan_ref"]
