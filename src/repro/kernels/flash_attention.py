"""Pallas TPU flash attention (fwd) — the fused-attention hot-spot kernel.

The dry-run roofline (EXPERIMENTS.md §Perf, llama3 iterations) shows the
XLA-level flash formulation is bound by score-block streaming: every
(qb × kb) f32 score tile crosses the fusion boundary to HBM ~3× (fwd + bwd
recompute + grads) — ~6.5 TB/device/step on llama3-8b train_4k. This kernel
keeps the running-softmax state and score tiles in VMEM: HBM traffic becomes
just Q/K/V/O streams (arithmetic intensity ≈ d_head · intensity of a matmul).

Grid: (batch·kv_heads, nq) — one program instance owns one q block for one
(batch, kv-head) pair and loops the kv blocks with `lax.fori_loop`, exactly
the kernelized version of layers.flash_attention's scan. GQA handled by the
g = H/KV query-group dim riding along in the block.

Validated in interpret mode against layers.flash_attention / the naive oracle
(tests/test_kernels.py::test_flash_kernel_*). On CPU boxes the model code
dispatches to the jnp flash path; on TPU this kernel is selected.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool,
                  window: Optional[int], q_offset: int, kb: int,
                  scale: float):
    # q_ref: (1, qb, g, dh); k_ref/v_ref: (1, Sk, dh); o_ref: (1, qb, g, dh)
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # (qb, g, dh)
    qb, g, dh = q.shape
    sk = k_ref.shape[1]
    nkb = sk // kb
    qpos = q_offset + qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, 1), 0)

    def body(ki, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], ki * kb, kb).astype(jnp.float32)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], ki * kb, kb).astype(jnp.float32)
        kpos = ki * kb + jax.lax.broadcasted_iota(jnp.int32, (1, kb), 1)
        s = jnp.einsum("qgd,sd->gqs", q, k)           # (g, qb, kb)
        mask = jnp.ones((qb, kb), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask[None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = corr * l + jnp.sum(p, axis=-1)
        acc_new = corr[..., None] * acc + jnp.einsum("gqs,sd->gqd", p, v)
        return m_new, l_new, acc_new

    m0 = jnp.full((g, qb), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((g, qb), jnp.float32)
    a0 = jnp.zeros((g, qb, dh), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nkb, body, (m0, l0, a0))
    out = acc / jnp.maximum(l[..., None], 1e-30)      # (g, qb, dh)
    o_ref[0] = out.transpose(1, 0, 2).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "q_block", "kv_block", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None, q_offset: int = 0,
                           q_block: int = 256, kv_block: int = 256,
                           interpret: bool = True):
    """q: (B, Sq, H, dh); k, v: (B, Sk, KV, dh). Returns (B, Sq, H, dh)."""
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    g = H // KV
    qb = min(q_block, Sq)
    while Sq % qb:
        qb -= 1
    kb = min(kv_block, Sk)
    while Sk % kb:
        kb -= 1
    nq = Sq // qb
    scale = 1.0 / math.sqrt(dh)

    # layout: fold (B, KV) into the grid's first axis
    qr = q.reshape(B, Sq, KV, g, dh).transpose(0, 2, 1, 3, 4) \
          .reshape(B * KV, Sq, g, dh)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, dh)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, dh)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, causal=causal, window=window,
                          q_offset=q_offset, kb=kb, scale=scale),
        grid=(B * KV, nq),
        in_specs=[
            pl.BlockSpec((1, qb, g, dh), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, Sk, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, Sk, dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, g, dh), lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, Sq, g, dh), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, KV, Sq, g, dh).transpose(0, 2, 1, 3, 4) \
              .reshape(B, Sq, H, dh)
