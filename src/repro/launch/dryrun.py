import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first — jax locks the device count on first
init, and the production meshes need 512 placeholder host devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCHS, get_config
from repro.launch import roofline as R
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.models.sharding import set_rules
from repro.training import optimizer as O
from repro.training.shardspec import batch_pspecs, cache_pspecs, param_pspecs, state_pspecs
from repro.training.train_step import make_decode_step, make_prefill_step, make_train_step


def _drop_batch_axes(spec):
    """Replicate batch-sharded dims (long_500k batch=1 can't shard batch)."""
    from jax.sharding import PartitionSpec as P
    ents = []
    for ax in spec:
        if ax is None:
            ents.append(None)
            continue
        axs = ax if isinstance(ax, tuple) else (ax,)
        axs = tuple(x for x in axs if x not in ("pod", "data"))
        ents.append(axs if len(axs) > 1 else (axs[0] if axs else None))
    return P(*ents)


def lower_cell(cfg, cell, mesh, opt_cfg=None, donate=True, accum_steps=1):
    """Lower + compile one cell on `mesh`. Returns (compiled, lowered)."""
    from jax.sharding import PartitionSpec as P
    opt_cfg = opt_cfg or O.OptCfg()
    set_rules(mesh)
    jax.set_mesh(mesh)
    kind, args = input_specs(cfg, cell, opt_cfg)
    axis_names = mesh.axis_names
    if kind == "train":
        state, batch = args
        fn = make_train_step(cfg, opt_cfg, accum_steps=accum_steps)
        in_sh = (state_pspecs(state, mesh), batch_pspecs(batch, mesh))
        jfn = jax.jit(fn, in_shardings=in_sh,
                      donate_argnums=(0,) if donate else ())
    elif kind == "prefill":
        params, batch = args
        fn = make_prefill_step(cfg, max_seq=cell["seq"])
        in_sh = (param_pspecs(params, mesh), batch_pspecs(batch, mesh))
        jfn = jax.jit(fn, in_shardings=in_sh)
    else:  # decode
        params, token, cache = args
        fn = make_decode_step(cfg)
        baxes = tuple(a for a in ("pod", "data") if a in axis_names)
        b = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
        n_batch_devs = 1
        for a in baxes:
            n_batch_devs *= mesh.shape[a]
        cache_sh = cache_pspecs(cache, mesh)
        tok_sh = P(b) if not cfg.embed_inputs else P(b, None)
        if cell["batch"] < n_batch_devs:  # long_500k batch=1: replicate batch
            tok_sh = P() if not cfg.embed_inputs else P(None, None)
            cache_sh = jax.tree.map(_drop_batch_axes, cache_sh,
                                    is_leaf=lambda x: isinstance(x, P))
        in_sh = (param_pspecs(params, mesh), tok_sh, cache_sh)
        jfn = jax.jit(fn, in_shardings=in_sh,
                      donate_argnums=(2,) if donate else ())
    lowered = jfn.lower(*args)
    compiled = lowered.compile()
    return compiled, lowered


def run_cell(arch: str, shape: str, multi_pod: bool, opt_cfg=None,
             verbose: bool = True, accum_steps: int = 1):
    cfg = get_config(arch)
    cell = cfg.shapes()[shape]
    if cell is None:
        return dict(arch=arch, shape=shape, skipped=True,
                    reason="long_500k needs sub-quadratic attention "
                           "(DESIGN.md §Arch-applicability)")
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    compiled, lowered = lower_cell(cfg, cell, mesh, opt_cfg,
                                   accum_steps=accum_steps)
    dt = time.time() - t0
    rf = R.analyze(compiled, cfg, cell, arch, shape, mesh_name, chips)
    out = rf.to_dict()
    out.update(compile_seconds=dt, skipped=False)
    if verbose:
        mem = out["mem_stats"] or {}
        print(f"[{arch} × {shape} × {mesh_name}] compiled in {dt:.1f}s")
        print(f"  memory/device: args={mem.get('argument', 0)/2**30:.2f}GiB "
              f"temp={mem.get('temp', 0)/2**30:.2f}GiB")
        print(f"  flops/dev={out['flops_per_device']:.3e} "
              f"bytes/dev={out['bytes_per_device']:.3e} "
              f"coll/dev={out['coll_bytes_per_device']:.3e}")
        print(f"  t_comp={out['t_compute']*1e3:.2f}ms t_mem={out['t_memory']*1e3:.2f}ms "
              f"t_coll={out['t_collective']*1e3:.2f}ms -> {out['bottleneck']}"
              f"  useful={out['useful_flops_ratio']:.2f} "
              f"roofline={out['roofline_fraction']:.2f}")
    return out


def graph_dryrun(multi_pod: bool = False, n_vertices: int = 262_144,
                 verbose: bool = True):
    """Lower + compile one Gopher BSP superstep at production scale: one
    partition per chip (256 or 512), synthetic road-grid graph, CC program.
    The paper-side §Dry-run / §Roofline artifact."""
    import numpy as np
    from repro.core import GopherEngine, SemiringProgram, init_max_vertex
    from repro.gofs import road_grid, bfs_grow_partition
    from repro.gofs.formats import partition_graph
    from repro.launch.mesh import make_mesh

    chips = 512 if multi_pod else 256
    side = int(np.sqrt(n_vertices))
    g = road_grid(side, side, drop_frac=0.03, seed=0)
    assign = bfs_grow_partition(g, chips, seed=0)
    pg = partition_graph(g, assign, chips, lane_pad=8)
    mesh = make_mesh((chips,), ("parts",))
    prog = SemiringProgram(semiring="max_first", init_fn=init_max_vertex,
                           spmv_backend="jnp")
    eng = GopherEngine(pg, prog, backend="shard_map", mesh=mesh)
    fn, specs = eng.lowerable_superstep()
    t0 = time.time()
    lowered = jax.jit(fn).lower(*specs)
    compiled = lowered.compile()
    dt = time.time() - t0
    from repro.launch import hloparse as hp
    parsed = hp.analyze_text(compiled.as_text())
    mem = compiled.memory_analysis()
    sweeps_per_superstep = 4  # representative local-fixpoint depth (road grid)
    local_edges = int((pg.nbr != -1).sum())
    model_flops = 2.0 * local_edges * sweeps_per_superstep  # ⊕+⊗ per edge
    out = dict(
        arch="goffish-graph-engine", shape=f"cc_superstep_{n_vertices}v",
        mesh="2x16x16" if multi_pod else "16x16", chips=chips,
        flops_per_device=parsed["flops"], hbm_bytes_per_device=parsed["hbm"],
        coll_bytes_per_device=parsed["coll_bytes_total"],
        coll_detail={"bytes": parsed["coll"], "counts": parsed["coll_counts"]},
        model_flops_total=model_flops,
        graph=pg.stats(), compile_seconds=dt, skipped=False,
        mem_stats=dict(argument=getattr(mem, "argument_size_in_bytes", 0),
                       temp=getattr(mem, "temp_size_in_bytes", 0)) if mem else None,
    )
    if verbose:
        cnts = {k: int(v) for k, v in parsed["coll_counts"].items() if v}
        print(f"[graph-engine × {out['shape']} × {out['mesh']}] "
              f"compiled in {dt:.1f}s")
        print(f"  hbm/dev={parsed['hbm']:.3e}B coll/dev="
              f"{parsed['coll_bytes_total']:.3e}B ({cnts})")
        print(f"  graph: v_max={pg.v_max} d_max={pg.d_max} cap={pg.mailbox_cap} "
              f"cut={pg.edge_cut()}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--graph", action="store_true",
                    help="dry-run the Gopher graph engine instead of LM cells")
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches (train cells)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.graph:
        results = []
        for mp in ([False, True] if args.both_meshes else [args.multi_pod]):
            results.append(graph_dryrun(multi_pod=mp))
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
        return

    cells = []
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    for a in archs:
        shapes = (list(ARCHS[a].shapes()) if (args.all or not args.shape)
                  else [args.shape])
        for s in shapes:
            cells.append((a, s))

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    results = []
    failures = 0
    for mp in meshes:
        for a, s in cells:
            try:
                results.append(run_cell(a, s, mp, accum_steps=args.accum))
            except Exception as e:
                failures += 1
                traceback.print_exc()
                results.append(dict(arch=a, shape=s,
                                    mesh="2x16x16" if mp else "16x16",
                                    error=f"{type(e).__name__}: {e}"))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out} ({len(results)} cells, {failures} failures)")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
