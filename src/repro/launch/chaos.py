"""Gopher Shield chaos CLI — deterministic fault scenarios with parity gates.

    PYTHONPATH=src python -m repro.launch.chaos [--quick] [--devices 4] \
        [--parts 8] [--out BENCH_chaos.json] [--scenarios a,b,...]

Each scenario injects a seeded :class:`repro.resilience.faults.FaultPlan`
into a real run and asserts BOTH recovery and parity (recovered results
bit-identical to the fault-free reference for idempotent ⊕ programs,
allclose for PageRank):

    device_loss       mid-run device loss on a D-device 'parts' mesh:
                      elastic mesh shrink + announce-floor plan rebuild +
                      checkpoint resume (resilience.run_with_failover)
    corrupt_snapshot  the newest checkpoint is bit-flipped on disk; resume
                      must fall back to the previous checksum-verified one
    failed_delta      a delta-apply attempt fails; the service retries with
                      backoff and reports the recovery, clients never error
    corrupt_block     the zero-repack block patch is corrupted; the service
                      cold-rebuilds from the installed version and retries
    straggler         injected superstep stalls; the run completes with
                      bit-identical results (stalls cost time, never math)
    poisoned_query    a batch run is poisoned; the retry serves the batch
                      with no client-visible error
    skew_heal         a load-proportional straggler pins one partition; the
                      Gopher Balance actuator migrates its sub-graphs off,
                      the imbalance score drops >=2x, only the PLANNED
                      sub-graphs move (no full re-partition), and results
                      match the fault-free run (also writes
                      BENCH_balance.json next to the main report)

Writes a machine-readable BENCH_chaos.json and exits non-zero if any
scenario failed its recovery or parity gate — the CI ``chaos-smoke`` job
runs ``--quick``.

``--devices`` forces host devices via XLA_FLAGS, so it must take effect
before jax initializes — this module parses argv at import time when run
as __main__ (same pattern as launch/scope.py).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

_ALL = ("device_loss", "corrupt_snapshot", "failed_delta", "corrupt_block",
        "straggler", "poisoned_query", "skew_heal")


def _parse(argv=None):
    ap = argparse.ArgumentParser(description="Gopher Shield chaos scenarios")
    ap.add_argument("--quick", action="store_true",
                    help="smaller matrix (CI smoke)")
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--parts", type=int, default=8)
    ap.add_argument("--rows", type=int, default=9)
    ap.add_argument("--cols", type=int, default=9)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--out", default="BENCH_chaos.json")
    ap.add_argument("--scenarios", default=",".join(_ALL),
                    help="comma-separated subset of: " + ", ".join(_ALL))
    return ap.parse_args(argv)


def _graph(args):
    from repro.gofs import bfs_grow_partition, road_grid
    from repro.gofs.formats import partition_graph
    g = road_grid(args.rows, args.cols, drop_frac=0.05, seed=args.seed,
                  weighted=True)
    return g, partition_graph(g, bfs_grow_partition(g, args.parts, seed=0),
                              args.parts)


def _program(algo, pg):
    from repro.core import (PageRankProgram, SemiringProgram,
                            init_max_vertex, make_sssp_init)
    if algo == "cc":
        return SemiringProgram(semiring="max_first", init_fn=init_max_vertex)
    if algo == "sssp":
        sp, sl = int(pg.part_of[0]), int(pg.local_of[0])
        return SemiringProgram(semiring="min_plus",
                               init_fn=make_sssp_init(sp, sl))
    return PageRankProgram(n_global=pg.n_global, num_iters=10)


def _state_parity(a, b, exact):
    import jax
    import numpy as np
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    if len(la) != len(lb):
        return False
    if exact:
        return all(np.array_equal(np.asarray(x), np.asarray(y))
                   for x, y in zip(la, lb))
    return all(np.allclose(np.asarray(x), np.asarray(y), rtol=1e-6,
                           atol=1e-6) for x, y in zip(la, lb))


# ---------------------------------------------------------------- scenarios

def scenario_device_loss(args):
    """Mid-run device loss on a D-device mesh -> shrink + resume, parity."""
    import jax
    from repro.core import (GopherEngine, PhasedTierPlan, host_graph_block)
    from repro.core import compat
    from repro.resilience import faults, run_with_failover
    from repro.training.checkpoint import Checkpointer
    D = args.devices
    if jax.device_count() < D:
        return {"ok": False,
                "error": f"needs {D} devices, have {jax.device_count()}"}
    _, pg = _graph(args)
    mesh = compat.make_mesh((D,), ("parts",))
    algos = ("cc", "pagerank") if args.quick else ("cc", "sssp", "pagerank")
    out = {"ok": True, "algos": {}}
    for algo in algos:
        prog = _program(algo, pg)
        ref, _ = GopherEngine(pg, prog, backend="local",
                              exchange="dense").run()
        hb = host_graph_block(pg)
        eng = GopherEngine(pg, prog, backend="shard_map", mesh=mesh,
                           exchange="phased",
                           tier_plan=PhasedTierPlan.from_block(hb))
        plan = faults.FaultPlan([faults.FaultSpec(
            "engine.superstep", "device_loss", at=2,
            payload={"lost": [1]})], seed=args.seed)
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            with faults.inject(plan):
                eng2, state, tele, rep = run_with_failover(
                    eng, ck, every=1, host_gb=hb)
        parity = _state_parity(state, ref, exact=algo != "pagerank")
        shrank = (rep.new_num_devices is not None
                  and rep.new_num_devices < rep.old_num_devices)
        out["algos"][algo] = {
            "parity": parity, "shrank": shrank,
            "old_devices": rep.old_num_devices,
            "new_devices": rep.new_num_devices,
            "lost_partitions": rep.lost_partitions,
            "restarts": rep.restarts, "supersteps": int(tele.supersteps),
            "fired": plan.record(),
        }
        out["ok"] = out["ok"] and parity and shrank
    return out


def scenario_corrupt_snapshot(args):
    """Bit-flip the newest snapshot; resume must fall back one step."""
    from repro.core import GopherEngine
    from repro.training.checkpoint import Checkpointer
    _, pg = _graph(args)
    prog = _program("cc", pg)
    ref, _ = GopherEngine(pg, prog, backend="local", exchange="dense").run()
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        eng = GopherEngine(pg, prog, backend="local", exchange="compact",
                           max_supersteps=3)
        eng.run(checkpointer=ck, checkpoint_every=1)
        latest = ck.latest_step()
        npz = os.path.join(d, f"step_{latest}", "host_0.npz")
        with open(npz, "r+b") as f:      # flip bytes mid-file: truncation
            f.seek(200)                   # and bit-rot look the same to CRC
            f.write(b"\xde\xad\xbe\xef")
        good = ck.latest_good_step()
        eng2 = GopherEngine(pg, prog, backend="local", exchange="compact")
        state, tele = eng2.run(checkpointer=ck, checkpoint_every=1,
                               resume=True)
    parity = _state_parity(state, ref, exact=True)
    fell_back = good is not None and latest is not None and good < latest
    return {"ok": parity and fell_back, "parity": parity,
            "latest_step": latest, "fallback_step": good,
            "fell_back": fell_back, "supersteps": int(tele.supersteps)}


def _service(args, **kw):
    from repro.serving.service import GraphQueryService
    _, pg = _graph(args)
    return pg, GraphQueryService({"g": pg}, retry_base_s=0.001, **kw)


def _delta(pg, seed):
    import numpy as np
    from repro.gofs import EdgeDelta
    rng = np.random.default_rng(seed)
    n = pg.n_global
    iu = rng.integers(0, n, 6)
    iv = (iu + rng.integers(1, n, 6)) % n
    return EdgeDelta.of(insert_src=iu, insert_dst=iv,
                        insert_wgt=rng.uniform(0.2, 2.0, 6)
                        .astype(np.float32))


def scenario_failed_delta(args):
    """Delta-apply fault: retry with backoff, recovery in svc.stats(),
    clients keep getting version-v answers with no errors."""
    from repro.resilience import faults
    pg, svc = _service(args)
    r0 = svc.query("sssp", "g", [0])
    v0 = svc.graphs["g"].version
    plan = faults.FaultPlan([faults.FaultSpec(
        "svc.apply_delta", "failed_delta", at=0)], seed=args.seed)
    with faults.inject(plan):
        svc.apply_delta("g", _delta(pg, args.seed))
    r1 = svc.query("sssp", "g", [1])
    st = svc.stats()
    ok = (r0.error is None and r1.error is None
          and svc.graphs["g"].version == v0 + 1
          and st["delta_retries"] >= 1 and st["recoveries"] >= 1)
    return {"ok": ok, "version_before": v0,
            "version_after": svc.graphs["g"].version,
            "delta_retries": st["delta_retries"],
            "recoveries": st["recoveries"],
            "client_errors": int(r0.error is not None)
            + int(r1.error is not None), "fired": plan.record()}


def scenario_corrupt_block(args):
    """Corrupted zero-repack patch: cold rebuild + retry; patched-serving
    results match an independently built service at the same version."""
    import numpy as np
    from repro.gofs.temporal import apply_delta as _apply
    from repro.resilience import faults
    from repro.serving.service import GraphQueryService
    pg, svc = _service(args)
    svc.query("sssp", "g", [0])           # build the patchable host twin
    delta = _delta(pg, args.seed + 1)
    plan = faults.FaultPlan([faults.FaultSpec(
        "blocks.patch", "corrupt_block", at=0)], seed=args.seed)
    v0 = svc.graphs["g"].version
    with faults.inject(plan):
        svc.apply_delta("g", delta)
    got = svc.query("sssp", "g", [5])
    ref_pg = _apply(pg, delta, directed=False).pg
    ref = GraphQueryService({"g": ref_pg}).query("sssp", "g", [5])
    st = svc.stats()
    parity = (got.error is None and ref.error is None
              and np.array_equal(got.result, ref.result))
    ok = (parity and svc.graphs["g"].version == v0 + 1
          and st["delta_retries"] >= 1 and st["recoveries"] >= 1)
    return {"ok": ok, "parity": parity,
            "delta_retries": st["delta_retries"],
            "recoveries": st["recoveries"], "fired": plan.record()}


def scenario_straggler(args):
    """Injected superstep stalls: completion + bit-identical results."""
    from repro.core import GopherEngine
    from repro.resilience import faults
    from repro.training.checkpoint import Checkpointer
    _, pg = _graph(args)
    prog = _program("cc", pg)
    ref, _ = GopherEngine(pg, prog, backend="local", exchange="dense").run()
    plan = faults.FaultPlan([faults.FaultSpec(
        "engine.superstep", "straggler", prob=0.5, times=3,
        delay_s=0.05)], seed=args.seed)
    with tempfile.TemporaryDirectory() as d:
        eng = GopherEngine(pg, prog, backend="local", exchange="compact")
        t0 = time.perf_counter()
        with faults.inject(plan):
            state, tele = eng.run(checkpointer=Checkpointer(d),
                                  checkpoint_every=2)
        wall_s = time.perf_counter() - t0
    parity = _state_parity(state, ref, exact=True)
    stalls = len(plan.record())
    return {"ok": parity and stalls >= 1, "parity": parity,
            "stalls": stalls, "wall_s": round(wall_s, 3),
            "supersteps": int(tele.supersteps)}


def scenario_poisoned_query(args):
    """Poisoned batch run: the retry serves it, no client-visible error."""
    from repro.resilience import faults
    _, svc = _service(args)
    plan = faults.FaultPlan([faults.FaultSpec(
        "svc.query", "poisoned_query", at=0)], seed=args.seed)
    with faults.inject(plan):
        r = svc.query("sssp", "g", [3])
    st = svc.stats()
    ok = (r.error is None and st["query_retries"] >= 1
          and st["recoveries"] >= 1 and st["degraded_batches"] == 0)
    return {"ok": ok, "client_error": r.error,
            "query_retries": st["query_retries"],
            "recoveries": st["recoveries"], "fired": plan.record()}


def _skew_graph(args):
    """A deliberately skewed layout the actuator can actually heal:
    partition 0 holds TWO non-adjacent 2-column strips of a road grid
    (two whole local sub-graphs with real cut edges), partitions 1 and 2
    are half-full (free slots = migration headroom), partition 3 is full
    — so healing means draining partition 0 into 1 and 2, one sub-graph
    per move, and nothing else is allowed to change."""
    import numpy as np
    from repro.gofs import road_grid
    from repro.gofs.formats import partition_graph
    rows, cols = 6, 12
    g = road_grid(rows, cols, drop_frac=0.0, seed=args.seed, weighted=True)
    strip = (np.arange(rows * cols) % cols) // 2
    assign = np.asarray([0, 1, 2, 0, 3, 3], np.int32)[strip]
    return g, partition_graph(g, assign, 4)


def scenario_skew_heal(args):
    """Straggler pins partition 0 -> live migration drains it; gates:
    imbalance drops >=2x, results match the fault-free run, and ONLY the
    planned sub-graphs moved (no full re-partition)."""
    import numpy as np
    from repro.core import GopherEngine
    from repro.resilience import faults
    from repro.resilience.balance import (BalancePolicy, run_with_rebalance,
                                          to_global)
    from repro.training.checkpoint import Checkpointer
    _, pg = _skew_graph(args)
    part0 = np.asarray(pg.part_of).copy()
    algos = ("cc",) if args.quick else ("cc", "pagerank")
    out = {"ok": True, "algos": {}}
    for algo in algos:
        prog = _program(algo, pg)
        ref, _ = GopherEngine(pg, prog, backend="local",
                              exchange="dense").run()
        ref_g = to_global(ref, pg)
        plan = faults.FaultPlan([faults.FaultSpec(
            "engine.superstep", "straggler", prob=1.0, times=9999,
            delay_s=0.008, payload={"part": 0})], seed=args.seed)
        eng = GopherEngine(pg, prog, backend="local", exchange="compact")
        # sub-graph-centric cc converges in quotient-graph-diameter
        # supersteps (~5 here), so decide EVERY superstep: two moves drain
        # partition 0 early enough that the final segment runs stall-free
        pol = BalancePolicy(threshold=1.3, floor=1.05,
                            max_verts_per_step=12, check_every=1,
                            cooldown_segments=0)
        with tempfile.TemporaryDirectory() as d:
            with faults.inject(plan):
                eng2, state, tele, rep = run_with_rebalance(
                    eng, Checkpointer(d), every=1, policy=pol)
        parity = _state_parity(to_global(state, eng2.pg), ref_g,
                               exact=algo != "pagerank")
        # only the planned sub-graphs moved, along the planned routes
        part1 = np.asarray(eng2.pg.part_of)
        changed = np.nonzero(part0 != part1)[0]
        routes = {(m["src"], m["dst"]) for m in rep.migrations}
        moved_ok = (len(changed) == rep.moved_verts()
                    and all((int(part0[g]), int(part1[g])) in routes
                            for g in changed))
        ratio = rep.imbalance_before / max(rep.imbalance_after, 1e-9)
        drained = int(np.sum(part1 == 0)) == 0
        ok = (parity and moved_ok and rep.rollbacks == 0
              and len(rep.migrations) >= 1 and ratio >= 2.0
              and eng2.pg.num_parts == pg.num_parts)
        out["algos"][algo] = {
            "parity": parity, "migrations": rep.migrations,
            "rollbacks": rep.rollbacks, "segments": rep.segments,
            "moved_verts": rep.moved_verts(),
            "moved_only_planned": moved_ok, "victim_drained": drained,
            "imbalance_before": round(rep.imbalance_before, 3),
            "imbalance_after": round(rep.imbalance_after, 3),
            "imbalance_drop": round(ratio, 3),
            "supersteps": int(tele.supersteps), "stalls": len(plan.record()),
        }
        out["ok"] = out["ok"] and ok
    bench = os.path.join(
        os.path.dirname(os.path.abspath(args.out)), "BENCH_balance.json")
    with open(bench, "w") as f:
        json.dump({"scenario": "skew_heal", "quick": bool(args.quick),
                   "gates": {"min_imbalance_drop": 2.0,
                             "parity": "exact (cc) / allclose (pagerank)",
                             "moved_only_planned": True},
                   "algos": out["algos"]}, f, indent=1)
    out["bench"] = bench
    return out


_SCENARIOS = {
    "device_loss": scenario_device_loss,
    "corrupt_snapshot": scenario_corrupt_snapshot,
    "failed_delta": scenario_failed_delta,
    "corrupt_block": scenario_corrupt_block,
    "straggler": scenario_straggler,
    "poisoned_query": scenario_poisoned_query,
    "skew_heal": scenario_skew_heal,
}


def main(argv=None) -> int:
    args = _parse(argv)
    names = [s for s in str(args.scenarios).split(",") if s]
    unknown = [s for s in names if s not in _SCENARIOS]
    if unknown:
        print(f"unknown scenarios: {unknown}", file=sys.stderr)
        return 2
    report = {"quick": bool(args.quick), "devices": args.devices,
              "parts": args.parts, "seed": args.seed, "scenarios": {}}
    for name in names:
        t0 = time.perf_counter()
        try:
            res = _SCENARIOS[name](args)
        except Exception as e:  # a scenario crash is a failed gate
            res = {"ok": False, "error": f"{type(e).__name__}: {e}"}
        res["seconds"] = round(time.perf_counter() - t0, 2)
        report["scenarios"][name] = res
        print(f"chaos[{name}]: {'OK' if res['ok'] else 'FAIL'} "
              f"({res['seconds']}s)"
              + (f" — {res.get('error')}" if not res["ok"] else ""))
    passed = sum(1 for r in report["scenarios"].values() if r["ok"])
    report["summary"] = {"total": len(names), "passed": passed,
                         "failed": len(names) - passed}
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"# gopher chaos — {passed}/{len(names)} scenarios recovered "
          f"with parity -> {args.out}")
    return 0 if passed == len(names) else 1


if __name__ == "__main__":
    _args = _parse()
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_args.devices}"
    ).strip()
    sys.exit(main(sys.argv[1:]))
