"""Elastic scaling: re-derive the mesh from surviving chip count and restart
from the last committed checkpoint.

Policy: keep TP ('model') fixed at the per-arch value (it is matched to head /
expert divisibility), shrink/grow DP ('data'); the pod axis absorbs whole-pod
losses. Partitions-per-device for the graph engine re-balance because the
GoFS partition count is decoupled from the device count (virtual partitions).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax


@dataclasses.dataclass
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    def make(self):
        from repro.core import compat
        devs = jax.devices()
        n = 1
        for s in self.shape:
            n *= s
        return compat.make_mesh(self.shape, self.axes, devices=devs[:n])


def plan_mesh(n_chips: int, model_parallel: int = 16,
              pods: int = 1) -> MeshPlan:
    """Largest (pod, data, model) mesh that fits n_chips with fixed TP."""
    per_pod = n_chips // pods
    data = max(per_pod // model_parallel, 1)
    if pods > 1:
        return MeshPlan((pods, data, model_parallel), ("pod", "data", "model"))
    return MeshPlan((data, model_parallel), ("data", "model"))


def shrink_after_failure(old: MeshPlan, lost_chips: int) -> MeshPlan:
    """Drop whole DP rows to cover the loss — TP groups stay intact, so
    parameter shards remain co-resident and restore is a pure re-shard.

    A 1-axis ``('parts',)`` mesh (the graph engine's) shrinks to the
    surviving device count directly: GoFS virtual partitions are decoupled
    from devices, so ANY surviving count re-tiles the same partitions. The
    engine-facing wrapper (resilience.failover.shrink_parts_mesh)
    additionally clamps to a divisor of the partition count so the
    P % D == 0 tiling invariant holds."""
    if old.axes == ("parts",):
        return MeshPlan((max(old.shape[0] - lost_chips, 1),), ("parts",))
    shape = dict(zip(old.axes, old.shape))
    model = shape.get("model", 1)
    pods = shape.get("pod", 1)
    total = 1
    for s in old.shape:
        total *= s
    survivors = total - lost_chips
    rows_needed = -(-lost_chips // (model))
    data = shape.get("data", 1) - rows_needed
    if data < 1:
        # fall back to fewer pods
        pods = max(pods - 1, 1)
        data = max(survivors // (pods * model), 1)
    if pods > 1:
        return MeshPlan((pods, data, model), ("pod", "data", "model"))
    return MeshPlan((data, model), ("data", "model"))


def rebalance_hint(skew: dict, threshold: float = 1.5,
                   floor: float = 1.1,
                   acting: bool = False) -> Optional[dict]:
    """Gopher Scope feedback for the elastic layer: given a live skew report
    (``Telemetry.skew()`` / ``SkewTracker.report()``), decide whether the
    virtual-partition layout is worth re-balancing and which partition to
    shed load FROM. GoFS partition count is decoupled from device count, so
    acting on the hint is a repartition/migration, not a mesh change.

    Two load signals are read and the WORSE one wins: the iteration channel
    (``imbalance``/``straggler`` — structural compute skew) and the wall-
    clock channel (``time_imbalance``/``time_straggler`` — a physically
    slow device shows up here even when iteration counts stay flat).

    Hysteresis so an actuator driven by this hint cannot oscillate: an IDLE
    caller trips only above ``threshold``; a caller that is already
    migrating (``acting=True``) keeps getting a hint until the score falls
    to the ``floor`` — the balanced band — so a heal drains fully instead
    of stopping the moment it dips under the trip point and re-tripping
    next window. On a balanced mesh (score at or below the floor) the hint
    is ALWAYS ``None``: no victim partition is named when there is nothing
    to shed."""
    imb_it = float(skew.get("imbalance", 0.0))
    imb_t = float(skew.get("time_imbalance", 0.0))
    use_time = imb_t > imb_it
    imb = imb_t if use_time else imb_it
    gate = max(float(floor), 1.0) if acting else max(float(threshold),
                                                     float(floor))
    if imb <= gate:
        return None
    src = int(skew.get("time_straggler", -1) if use_time
              else skew.get("straggler", -1))
    if src < 0:
        return None
    return dict(migrate_from=src, imbalance=imb,
                signal="time" if use_time else "iters",
                wasted_speedup_pct=round((1.0 - 1.0 / imb) * 100.0, 1))


def restart(checkpointer, state_like, plan: MeshPlan, pspecs):
    """Re-shard the last committed checkpoint onto the new mesh."""
    from repro.training.shardspec import named
    mesh = plan.make()
    shardings = named(mesh, pspecs)
    state, step = checkpointer.restore(state_like, shardings=shardings)
    return mesh, state, step
