"""Launchers: mesh builders, multi-pod dry-run, roofline, train/serve drivers."""
