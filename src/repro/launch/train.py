"""End-to-end LM training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On real hardware drop --reduced and pass --mesh data,model=16,16 (the
launcher shards state/batches with training.shardspec). On this CPU box the
reduced config exercises the identical code path end-to-end.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import init_params
from repro.models.sharding import set_rules
from repro.training import optimizer as O
from repro.training.checkpoint import Checkpointer
from repro.training.data import DataCfg, make_dataset
from repro.training.shardspec import batch_pspecs, named, state_pspecs
from repro.training.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 'data,model=4,2' (default: single device)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opt_cfg = O.OptCfg(lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
                       total_steps=args.steps,
                       grad_compress_bf16=args.grad_compress,
                       mixed_precision=not args.reduced)

    mesh = None
    if args.mesh:
        names, shape = args.mesh.split("=")
        mesh = make_mesh(tuple(int(x) for x in shape.split(",")),
                         tuple(names.split(",")))
        set_rules(mesh)
        jax.set_mesh(mesh)

    params = init_params(jax.random.PRNGKey(0), cfg, max_seq=args.seq)
    state = O.init_state(params, opt_cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"mesh={mesh.shape if mesh else 'single-device'}")

    dcfg = DataCfg(batch=args.batch, seq=args.seq, vocab=cfg.vocab,
                   frames=(cfg.enc_seq, cfg.d_model) if cfg.family == "encdec" else None,
                   mrope=cfg.mrope)
    data = make_dataset(dcfg)

    ck = Checkpointer(args.ckpt_dir, async_save=True) if args.ckpt_dir else None
    start = 0
    if ck and args.resume and ck.latest_step() is not None:
        like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
        shardings = named(mesh, state_pspecs(state, mesh)) if mesh else None
        state, start = ck.restore(like, shardings=shardings)
        data.restore(ck.extra()["data"])
        print(f"resumed from step {start}")

    step_fn = make_train_step(cfg, opt_cfg)
    if mesh:
        ex_batch = next(data)
        step_fn = jax.jit(step_fn,
                          in_shardings=(state_pspecs(state, mesh),
                                        batch_pspecs(ex_batch, mesh)),
                          donate_argnums=(0,))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    t0 = time.time()
    for i in range(start, args.steps):
        batch = {k: jax.numpy.asarray(v) for k, v in next(data).items()}
        if cfg.embed_inputs:  # vlm stub: tokens -> fake patch embeddings
            rng = np.random.default_rng(i)
            batch["inputs"] = jax.numpy.asarray(
                rng.standard_normal((args.batch, args.seq, cfg.d_model),
                                    ).astype(np.float32))
        state, metrics = step_fn(state, batch)
        if (i + 1) % args.log_every == 0 or i == start:
            dt = (time.time() - t0) / max(i + 1 - start, 1)
            print(f"step {i+1:5d} loss={float(metrics['loss']):.4f} "
                  f"ce={float(metrics['ce']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f} ms/step")
        if ck and (i + 1) % args.ckpt_every == 0:
            ck.save(state, i + 1, extra={"data": data.state()})
    if ck:
        ck.save(state, args.steps, extra={"data": data.state()})
        ck.wait()
    print("done.")


if __name__ == "__main__":
    main()
