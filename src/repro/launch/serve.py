"""Batched serving driver: prefill a batch of prompts, then greedy-decode.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import init_params
from repro.models.sharding import set_rules
from repro.training.train_step import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.mesh:
        names, shape = args.mesh.split("=")
        mesh = make_mesh(tuple(int(x) for x in shape.split(",")),
                         tuple(names.split(",")))
        set_rules(mesh)
        jax.set_mesh(mesh)

    max_seq = args.prompt_len + args.gen
    params = init_params(jax.random.PRNGKey(0), cfg, max_seq=max_seq)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    batch = {"inputs": prompts}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.enc_seq, cfg.d_model))

    prefill = jax.jit(make_prefill_step(cfg, max_seq=max_seq))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

    t0 = time.time()
    tok, cache = prefill(params, batch)
    tok.block_until_ready()
    t_prefill = time.time() - t0
    out = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        tok, cache = decode(params, tok, cache)
        out.append(tok)
    out[-1].block_until_ready()
    t_dec = time.time() - t0
    gen = jnp.stack(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   decode: "
          f"{t_dec/max(args.gen-1,1)*1e3:.1f} ms/token")
    print("generated token ids (first sequence):", gen[0].tolist())


if __name__ == "__main__":
    main()
