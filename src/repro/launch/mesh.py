"""Production mesh builders.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax

from repro.core import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    return compat.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_mesh(shape, axes):
    """General helper (tests, elastic restarts, graph-engine meshes)."""
    return compat.make_mesh(shape, axes)
