"""Roofline analysis from the compiled dry-run artifact.

Three terms per (arch × shape × mesh), in SECONDS per step on TPU v5e:
    compute    = HLO_FLOPs_total      / (chips × 197e12 FLOP/s bf16)
    memory     = HLO_bytes_total      / (chips × 819e9  B/s HBM)
    collective = collective_bytes     / (chips × 2 links × 50e9 B/s ICI)

cost_analysis() on a partitioned executable reports PER-DEVICE numbers —
totals are per-device × chips, so the per-chip seconds are just per-device /
peak. collective_bytes is parsed out of the compiled HLO text: the summed
result sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (per device, one execution each).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link
ICI_LINKS = 2                # effective concurrent links per chip (2D torus dir pairs)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\][^ ]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective kind (result-size proxy)."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        if "-start" in line:  # avoid double counting start/done pairs
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
        counts[m.group(2)] += 1
    return {"bytes": out, "counts": counts}


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_detail: dict
    model_flops_total: float
    mem_stats: Optional[dict] = None

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / (ICI_LINKS * ICI_BW)

    @property
    def bottleneck(self) -> str:
        t = {"compute": self.t_compute, "memory": self.t_memory,
             "collective": self.t_collective}
        return max(t, key=t.get)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_total = self.flops_per_device * self.chips
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-term step time that is useful compute:
        (MODEL_FLOPS / chips / peak) / max(term). The score axis."""
        t_star = self.model_flops_total / self.chips / PEAK_FLOPS
        t_dom = max(self.t_compute, self.t_memory, self.t_collective)
        return t_star / t_dom if t_dom else 0.0

    def to_dict(self) -> dict:
        return dict(
            arch=self.arch, shape=self.shape, mesh=self.mesh, chips=self.chips,
            flops_per_device=self.flops_per_device,
            bytes_per_device=self.bytes_per_device,
            coll_bytes_per_device=self.coll_bytes_per_device,
            coll_detail=self.coll_detail,
            model_flops_total=self.model_flops_total,
            t_compute=self.t_compute, t_memory=self.t_memory,
            t_collective=self.t_collective, bottleneck=self.bottleneck,
            useful_flops_ratio=self.useful_flops_ratio,
            roofline_fraction=self.roofline_fraction,
            mem_stats=self.mem_stats,
        )


def model_flops(cfg, cell: dict) -> float:
    """Analytic MODEL_FLOPS: 6·N·D train, 2·N·D forward-only (MoE: N_active)."""
    n = cfg.active_param_count()
    kind, seq, batch = cell["kind"], cell["seq"], cell["batch"]
    if kind == "train":
        return 6.0 * n * seq * batch
    if kind == "prefill":
        return 2.0 * n * seq * batch
    return 2.0 * n * batch  # decode: one token per sequence


def analyze(compiled, cfg, cell: dict, arch: str, shape: str, mesh_name: str,
            chips: int) -> Roofline:
    """Scan-aware HLO-text analysis (launch.hloparse) — XLA's own
    cost_analysis counts lax.scan bodies once, so we parse the partitioned
    module with while-trip multipliers; raw XLA numbers kept for reference."""
    from repro.launch import hloparse
    parsed = hloparse.analyze_text(compiled.as_text())
    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    mem_stats = None
    if mem is not None:
        mem_stats = dict(
            argument=getattr(mem, "argument_size_in_bytes", 0),
            output=getattr(mem, "output_size_in_bytes", 0),
            temp=getattr(mem, "temp_size_in_bytes", 0),
            alias=getattr(mem, "alias_size_in_bytes", 0),
        )
    coll_detail = {"bytes": parsed["coll"], "counts": parsed["coll_counts"],
                   "xla_flops_scan_once": float(cost.get("flops", 0.0)),
                   "xla_bytes_scan_once": float(cost.get("bytes accessed", 0.0))}
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=float(parsed["flops"]),
        bytes_per_device=float(parsed["hbm"]),
        coll_bytes_per_device=float(parsed["coll_bytes_total"]),
        coll_detail=coll_detail,
        model_flops_total=model_flops(cfg, cell),
        mem_stats=mem_stats,
    )
