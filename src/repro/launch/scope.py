"""Gopher Scope CLI: trace a BSP run and render the observability report.

    PYTHONPATH=src python -m repro.launch.scope [--algo cc|sssp] \
        [--rows 40 --cols 40] [--parts 4] [--exchange auto|dense|compact| \
        tiered|phased] [--backend local|shard_map] [--devices 4] \
        [--boundary-sync] [--profile-dir DIR] [--out DIR]

Self-contained demo: builds a road-grid graph, runs CC or SSSP with the
Gopher Scope tracer enabled, then

  * prints the TEXT TIMELINE — the nested run -> phase -> superstep ->
    {plan, pack, exchange, sweep, halt-vote} spans with wall-clock;
  * prints the metrics snapshot (engine counters, tier-plan builds,
    profile drift) and the per-partition skew report;
  * writes scope_trace.json (load in Perfetto / chrome://tracing),
    scope_trace.jsonl and scope_metrics.json into --out.

``--backend shard_map`` forces ``--devices`` host devices via XLA_FLAGS,
so it must take effect before jax initializes — this module therefore
parses argv at import time when run as __main__.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _parse(argv=None):
    ap = argparse.ArgumentParser(description="Gopher Scope trace report")
    ap.add_argument("--algo", choices=("cc", "sssp"), default="cc")
    ap.add_argument("--rows", type=int, default=40)
    ap.add_argument("--cols", type=int, default=40)
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--backend", choices=("local", "shard_map"),
                    default="local")
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--exchange", default="auto",
                    choices=("auto", "dense", "compact", "tiered", "phased"))
    ap.add_argument("--boundary-sync", action="store_true",
                    help="block_until_ready per stage: honest per-stage "
                         "wall-clock instead of dispatch time")
    ap.add_argument("--profile-dir", default=None,
                    help="also capture a device-side jax.profiler trace")
    ap.add_argument("--out", default=".",
                    help="directory for scope_trace.json[l] + "
                         "scope_metrics.json")
    return ap.parse_args(argv)


def text_timeline(tracer, file=None) -> None:
    """Indented span tree with wall-clock — the terminal half of the
    Perfetto file."""
    file = file or sys.stdout
    show = ("supersteps", "wire_slots", "step", "phase", "nchanged",
            "spills", "dispatches")
    for s in sorted(tracer.spans, key=lambda s: (s.t0_ns, -s.dur_ns)):
        args = " ".join(f"{k}={s.args[k]}" for k in show if k in s.args)
        print(f"{'  ' * s.depth}{s.name:<{24 - 2 * min(s.depth, 8)}} "
              f"{s.dur_ns / 1e6:9.3f} ms  {args}", file=file)


def _build(args):
    from repro.core import (GopherEngine, PhasedTierPlan, SemiringProgram,
                            init_max_vertex, make_sssp_init)
    from repro.core import compat
    from repro.gofs import bfs_grow_partition, road_grid
    from repro.gofs.formats import partition_graph
    from repro.obs import Tracer

    g = road_grid(args.rows, args.cols, seed=1)
    pg = partition_graph(g, bfs_grow_partition(g, args.parts, seed=0),
                         args.parts)
    if args.algo == "cc":
        prog = SemiringProgram(semiring="max_first", init_fn=init_max_vertex)
    else:
        prog = SemiringProgram(
            semiring="min_plus",
            init_fn=make_sssp_init(int(pg.part_of[0]), int(pg.local_of[0])))
    mesh = None
    if args.backend == "shard_map":
        mesh = compat.make_mesh((args.devices,), ("parts",))
    plan = (PhasedTierPlan.from_graph(pg)
            if args.exchange == "phased" else None)
    tracer = Tracer(enabled=True, boundary_sync=args.boundary_sync,
                    jax_profiler_dir=args.profile_dir)
    eng = GopherEngine(pg, prog, backend=args.backend, mesh=mesh,
                       exchange=args.exchange, tier_plan=plan, tracer=tracer)
    return eng, tracer


def main(argv=None) -> None:
    args = _parse(argv)
    eng, tracer = _build(args)
    state, tele = eng.run()
    from repro.obs import metrics as obs_metrics

    print(f"# gopher scope — {args.algo} on {args.rows}x{args.cols} road "
          f"grid, {args.parts} parts, backend={args.backend} "
          f"exchange={eng.exchange}")
    print(f"# supersteps={tele.supersteps} wire_slots={tele.wire_slots} "
          f"messages={tele.messages_sent}\n")
    text_timeline(tracer)
    print("\n# skew")
    print(json.dumps(tele.skew(), indent=1))
    print("\n# metrics")
    snap = obs_metrics.default_registry().snapshot()
    print(json.dumps(snap, indent=1))

    os.makedirs(args.out, exist_ok=True)
    tp = tracer.write_chrome_trace(os.path.join(args.out, "scope_trace.json"))
    lp = tracer.write_jsonl(os.path.join(args.out, "scope_trace.jsonl"))
    mp = obs_metrics.default_registry().write_json(
        os.path.join(args.out, "scope_metrics.json"))
    print(f"\n# wrote {tp}  {lp}  {mp}", file=sys.stderr)


if __name__ == "__main__":
    _args = _parse()
    if _args.backend == "shard_map":
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={_args.devices}"
        ).strip()
    main(sys.argv[1:])
