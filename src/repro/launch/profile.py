import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
"""Dry-run profiler: rank the HBM-traffic and collective hotspots of a cell's
compiled HLO, with while-trip multipliers (the §Perf iteration workflow).

    PYTHONPATH=src python -m repro.launch.profile --arch llama3-8b \
        --shape train_4k [--multi-pod] [--top 20]
"""
import argparse
import re



def walk_multipliers(analyzer):
    """comp name -> (multiplier, reached_via_fusion)."""
    mults = {}

    def walk(name, mult, via_fusion):
        key = name
        prev = mults.get(key)
        if prev is not None and prev[0] >= mult:
            return
        mults[key] = (mult, via_fusion)
        comp = analyzer.comps[name]
        for op in comp.ops.values():
            subs, m2, sub_fus = [], mult, via_fusion
            if op.opcode == "while":
                mc = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                mb = re.search(r"body=%?([\w.\-]+)", op.attrs)
                if mb:
                    subs = [mb.group(1)]
                m2 = mult * (analyzer.trip_count(mc.group(1)) if mc else 1)
            elif op.opcode in ("fusion", "call", "conditional"):
                subs = analyzer._called(op)
                sub_fus = via_fusion or (op.opcode == "fusion")
            for s in subs:
                if s in analyzer.comps:
                    walk(s, m2, sub_fus)

    walk(analyzer.entry, 1, False)
    return mults


def hotspots(compiled, top: int = 20):
    from repro.launch import hloparse
    a = hloparse.Analyzer(compiled.as_text())
    mults = walk_multipliers(a)
    hbm_rows, coll_rows = [], []
    for cname, comp in a.comps.items():
        entry = mults.get(cname)
        if entry is None:
            continue
        mult, via_fusion = entry
        for op in comp.ops.values():
            oc = op.opcode
            base = oc.split("-start")[0].split("-done")[0]
            if base in hloparse._COLLECTIVES and not oc.endswith("-start"):
                coll_rows.append((op.result_bytes * mult, base,
                                  op.result_bytes, mult, op.type_str[:60],
                                  cname[:32]))
            if via_fusion:
                continue  # interior of a fusion: not an HBM boundary
            if oc in hloparse._FREE_OPS or oc in ("while", "call", "conditional"):
                continue
            if oc in ("dynamic-slice", "gather"):
                b = 2 * op.result_bytes
            elif oc == "dynamic-update-slice":
                upd = comp.ops.get(op.operands[1]) if len(op.operands) > 1 else None
                b = 2 * (upd.result_bytes if upd else op.result_bytes)
            elif oc == "fusion":
                subs = a._called(op)
                w = a._dus_write_bytes(subs[0]) if subs else None
                reads = a._fusion_operand_reads(op, comp)
                if w is not None:
                    big = max((comp.ops[o].result_bytes for o in op.operands
                               if o in comp.ops), default=0)
                    b = 2 * w + max(reads - big, 0)
                else:
                    b = op.result_bytes + reads
            else:
                b = op.result_bytes + sum(
                    comp.ops[o].result_bytes for o in op.operands
                    if o in comp.ops and comp.ops[o].opcode != "constant")
            hbm_rows.append((b * mult, oc, b, mult, op.type_str[:60], cname[:32]))
    hbm_rows.sort(reverse=True)
    coll_rows.sort(reverse=True)
    return hbm_rows[:top], coll_rows[:top]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(args.arch)
    cell = cfg.shapes()[args.shape]
    if cell is None:
        print("cell skipped (see DESIGN.md §Arch-applicability)")
        return
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    compiled, _ = lower_cell(cfg, cell, mesh)
    hbm, coll = hotspots(compiled, args.top)
    print(f"== top HBM traffic (per device) — {args.arch} × {args.shape} ==")
    for t, oc, b, m, ty, cn in hbm:
        print(f"  {t/1e9:9.2f} GB  {oc:22s} {b/1e6:9.1f} MB x{m:<6d} {ty}  [{cn}]")
    print("== top collectives (per device) ==")
    for t, base, b, m, ty, cn in coll:
        print(f"  {t/1e9:9.2f} GB  {base:22s} {b/1e6:9.1f} MB x{m:<6d} {ty}  [{cn}]")


if __name__ == "__main__":
    main()
