"""Scan-aware cost analysis over compiled (partitioned, per-device) HLO text.

XLA's HloCostAnalysis counts while-loop bodies ONCE — a lax.scan over 80
layers under-reports FLOPs/bytes/collectives by 80×. This parser rebuilds the
numbers with trip-count multipliers:

  flops       Σ dot ops: 2 × prod(result_dims) × prod(contracting_dims),
              recursively through fusions/calls, × enclosing while trip counts
  hbm_bytes   fusion-boundary traffic model: every non-free top-level op reads
              its operands and writes its result once (the TPU HBM model at
              fusion granularity), × trip counts
  collectives result-size bytes per op kind, × trip counts

Trip counts come from the while condition's `compare(_, constant(N)), LT`.
Validated against unrolled-vs-scanned toy modules in tests/test_hloparse.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "partition-id", "replica-id", "domain",
    "opt-barrier", "optimization-barrier",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)*)\}")
_GROUPS_RE = re.compile(r"replica_groups=(\{(?:\{[\d,]*\},?)*\}|\[[\d,]*\]<=\[[\d,]*\])")


def _balanced(s: str, start: int) -> int:
    """Index just past the paren group opening at s[start]."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def _parse_op_line(line: str):
    """'%name = TYPE opcode(args), attrs' -> (name, type, opcode, args, attrs).
    Handles tuple types with embedded '/*index=N*/' comments."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq].strip()
    rest = s[eq + 3:]
    if rest.startswith("("):
        end = _balanced(rest, 0)
        type_str, rem = rest[:end], rest[end:].strip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rem = rest[:sp], rest[sp + 1:].strip()
    par = rem.find("(")
    if par <= 0:
        return None
    opcode = rem[:par].strip()
    end = _balanced(rem, par)
    args = rem[par + 1:end - 1]
    attrs = rem[end:]
    return name, type_str, opcode, args, attrs
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _shape_info(type_str: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """Returns (total_bytes, [(dtype, dims), ...]) for possibly-tuple types."""
    shapes = []
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        dl = [int(d) for d in dims.split(",") if d] if dims else []
        n = 1
        for d in dl:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        shapes.append((dt, dl))
    return total, shapes


def _parse_pairs(attrs: str) -> Optional[Tuple[Tuple[int, int], ...]]:
    """collective-permute ``source_target_pairs={{0,1},{1,2}}`` -> tuples."""
    m = _PAIRS_RE.search(attrs)
    if not m:
        return None
    return tuple((int(a), int(b))
                 for a, b in re.findall(r"\{(\d+),(\d+)\}", m.group(1)))


def _parse_groups(attrs: str) -> Optional[str]:
    """``replica_groups=`` in either the brace or iota (``[2,2]<=[4]``)
    form, kept as the raw string (group topology is compared textually)."""
    m = _GROUPS_RE.search(attrs)
    return m.group(1) if m else None


@dataclasses.dataclass
class CollectiveInstr:
    """One collective instruction in the compiled module, with the while
    trip-count multiplier it executes under (the sentinel↔HLO cross-check
    compares these against the jaxpr-level CollectiveSummary)."""
    kind: str                     # all-reduce | all-to-all | collective-permute | ...
    name: str                     # HLO instruction name
    computation: str              # enclosing computation
    result_bytes: int
    mult: int                     # product of enclosing while trip counts
    source_target_pairs: Optional[Tuple[Tuple[int, int], ...]] = None
    replica_groups: Optional[str] = None

    @property
    def total_bytes(self) -> int:
        return self.result_bytes * self.mult


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    type_str: str
    operands: List[str]
    attrs: str
    result_bytes: int
    args_str: str = ""


@dataclasses.dataclass
class Computation:
    name: str
    ops: Dict[str, Op]
    order: List[str]


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and "{" in line:
            cur = Computation(mc.group(1), {}, [])
            comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mo = _parse_op_line(line)
        if not mo:
            continue
        name, type_str, opcode, args, attrs = mo
        operands = re.findall(r"%([\w.\-]+)", args)
        rbytes, _ = _shape_info(type_str)
        op = Op(name, opcode, type_str, operands, attrs, rbytes, args)
        cur.ops[name] = op
        cur.order.append(name)
    return comps


class Analyzer:
    def __init__(self, text: str):
        self.text = text
        self.comps = parse_module(text)
        self.const_vals = self._parse_constants(text)
        self._cache: Dict[str, dict] = {}
        self.entry = self._find_entry(text)

    @staticmethod
    def _find_entry(text: str) -> Optional[str]:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        return m.group(1) if m else None

    @staticmethod
    def _parse_constants(text: str) -> Dict[str, int]:
        """op name -> integer constant value (s32 scalars used in loop bounds)."""
        vals = {}
        for m in re.finditer(
                r"%?([\w.\-]+)\s*=\s*[su]\d+\[\]\s*constant\((\-?\d+)\)", text):
            vals[m.group(1)] = int(m.group(2))
        return vals

    def trip_count(self, cond_name: str) -> int:
        cond = self.comps.get(cond_name)
        if cond is None:
            return 1
        for op in cond.ops.values():
            if op.opcode == "compare" and "direction=LT" in op.attrs:
                for o in op.operands:
                    if o in self.const_vals:
                        return max(int(self.const_vals[o]), 1)
        # constants may live in the parent via while init tuple; fall back to
        # any scalar int constant referenced inside the condition
        cands = [self.const_vals[o.name] for o in cond.ops.values()
                 if o.name in self.const_vals]
        return max(cands) if cands else 1

    @staticmethod
    def _dot_flops(op: Op, comp: Computation) -> float:
        _, rshapes = _shape_info(op.type_str)
        rdims = rshapes[0][1] if rshapes else []
        n = 1
        for d in rdims:
            n *= d
        # contracting dims from lhs shape
        mcd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
        if not mcd or not op.operands:
            return 2.0 * n  # degenerate
        lhs = comp.ops.get(op.operands[0])
        k = 1
        if lhs is not None:
            _, lshapes = _shape_info(lhs.type_str)
            ldims = lshapes[0][1] if lshapes else []
            for idx in (int(i) for i in mcd.group(1).split(",") if i):
                if idx < len(ldims):
                    k *= ldims[idx]
        return 2.0 * n * k

    def _dus_write_bytes(self, comp_name: str) -> Optional[int]:
        """If `comp_name`'s root is a dynamic-update-slice (or a tuple of
        them), return the written update bytes; else None."""
        comp = self.comps.get(comp_name)
        if comp is None or not comp.order:
            return None
        root = comp.ops[comp.order[-1]]
        roots = [root]
        if root.opcode == "tuple":
            roots = [comp.ops[o] for o in root.operands if o in comp.ops]
        total = 0
        found = False
        for r in roots:
            if r.opcode == "dynamic-update-slice" and len(r.operands) > 1:
                upd = comp.ops.get(r.operands[1])
                total += upd.result_bytes if upd else r.result_bytes
                found = True
        return total if found else None

    def _fusion_operand_reads(self, op: Op, comp: Computation) -> int:
        """Read bytes for a fusion's operands: operands whose callee parameter
        is consumed ONLY by dynamic-slice ops count the slice sizes (streamed
        window), not the whole buffer (residual stacks read per loop step)."""
        subs = self._called(op)
        callee = self.comps.get(subs[0]) if subs else None
        # map param index -> param op name, and param name -> user slice bytes
        param_reads = {}
        if callee is not None:
            for pop in callee.ops.values():
                if pop.opcode != "parameter":
                    continue
                try:
                    idx = int(pop.args_str.strip())
                except ValueError:
                    continue
                users = [u for u in callee.ops.values()
                         if pop.name in u.operands]
                if users and all(u.opcode == "dynamic-slice" for u in users):
                    param_reads[idx] = sum(u.result_bytes for u in users)
        total = 0
        for idx, oname in enumerate(op.operands):
            src = comp.ops.get(oname)
            if src is None or src.opcode == "constant":
                continue
            if idx in param_reads:
                total += param_reads[idx]
            else:
                total += src.result_bytes
        return total

    def _called(self, op: Op) -> List[str]:
        names = _CALL_ATTR_RE.findall(op.attrs)
        mb = _BRANCHES_RE.search(op.attrs)
        if mb:
            names += re.findall(r"%?([\w.\-]+)", mb.group(1))
        return [n for n in names if n in self.comps]

    def analyze_comp(self, name: str) -> dict:
        if name in self._cache:
            return self._cache[name]
        comp = self.comps[name]
        tot = dict(flops=0.0, hbm=0.0,
                   coll={k: 0.0 for k in _COLLECTIVES},
                   coll_counts={k: 0.0 for k in _COLLECTIVES})
        self._cache[name] = tot  # cycle guard
        for opn in comp.order:
            op = comp.ops[opn]
            oc = op.opcode
            if oc in _FREE_OPS:
                continue
            mult = 1
            sub_names = []
            if oc == "while":
                body = cond = None
                mb = re.search(r"body=%?([\w.\-]+)", op.attrs)
                mc = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                if mb:
                    body = mb.group(1)
                if mc:
                    cond = mc.group(1)
                mult = self.trip_count(cond) if cond else 1
                if body:
                    sub_names = [body]
            elif oc in ("fusion", "call", "conditional", "custom-call",
                        "async-start", "reduce", "map", "scatter", "select-and-scatter",
                        "reduce-window", "sort"):
                sub_names = self._called(op)
                if oc in ("reduce", "map", "scatter", "select-and-scatter",
                          "reduce-window", "sort"):
                    sub_names = []  # tiny scalar computations — ignore

            # own cost (async pairs: count the -done result once, skip -start)
            base = oc.split("-start")[0].split("-done")[0]
            if base in _COLLECTIVES and not oc.endswith("-start"):
                tot["coll"][base] += op.result_bytes
                tot["coll_counts"][base] += 1
            if oc in ("dot", "dot-general"):
                tot["flops"] += self._dot_flops(op, comp)

            # HBM traffic model (fusion-boundary):
            #  - while/call/conditional: body accounting covers it, skip own
            #  - fusion: boundary = operands + result; innards are VMEM/regs
            #  - dynamic-slice/gather read only the slice (2x result)
            #  - dynamic-update-slice touches only the update region
            if oc in ("while", "call", "conditional"):
                pass
            elif oc in ("dynamic-slice", "gather"):
                tot["hbm"] += 2 * op.result_bytes
            elif oc == "dynamic-update-slice":
                upd = comp.ops.get(op.operands[1]) if len(op.operands) > 1 else None
                tot["hbm"] += 2 * (upd.result_bytes if upd else op.result_bytes)
            elif oc == "fusion":
                w = self._dus_write_bytes(sub_names[0]) if sub_names else None
                reads = self._fusion_operand_reads(op, comp)
                if w is not None:
                    # in-place residual-stack update: write only the update
                    # region, and don't re-read the whole aliased buffer
                    big = max((comp.ops[o].result_bytes for o in op.operands
                               if o in comp.ops), default=0)
                    tot["hbm"] += 2 * w + max(reads - big, 0)
                else:
                    tot["hbm"] += op.result_bytes + reads
            else:
                opnd_bytes = 0
                for o in op.operands:
                    src = comp.ops.get(o)
                    if src is not None and src.opcode not in ("constant",):
                        opnd_bytes += src.result_bytes
                tot["hbm"] += op.result_bytes + opnd_bytes

            for s in sub_names:
                sub = self.analyze_comp(s)
                tot["flops"] += mult * sub["flops"]
                for k in _COLLECTIVES:
                    tot["coll"][k] += mult * sub["coll"][k]
                    tot["coll_counts"][k] += mult * sub["coll_counts"][k]
                if oc != "fusion":
                    tot["hbm"] += mult * sub["hbm"]
        return tot

    def collective_trace(self, name: Optional[str] = None,
                         _mult: int = 1) -> List[CollectiveInstr]:
        """Every collective instruction reachable from ``name`` (default:
        the entry computation), each with its while trip-count multiplier,
        permutation table (collective-permute) and replica groups. Async
        pairs are recorded once, on the ``-start`` (that's where XLA keeps
        the attrs); the ``-done`` half is skipped."""
        if name is None:
            name = (self.entry if self.entry in self.comps
                    else max(self.comps,
                             key=lambda c: len(self.comps[c].order)))
        comp = self.comps.get(name)
        out: List[CollectiveInstr] = []
        if comp is None:
            return out
        for opn in comp.order:
            op = comp.ops[opn]
            oc = op.opcode
            base = oc.split("-start")[0].split("-done")[0]
            if base in _COLLECTIVES:
                if oc.endswith("-done"):
                    continue
                out.append(CollectiveInstr(
                    kind=base, name=op.name, computation=name,
                    result_bytes=op.result_bytes, mult=_mult,
                    source_target_pairs=_parse_pairs(op.attrs),
                    replica_groups=_parse_groups(op.attrs)))
                continue
            if oc == "while":
                mb = re.search(r"body=%?([\w.\-]+)", op.attrs)
                mc = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                trips = self.trip_count(mc.group(1)) if mc else 1
                if mb:
                    out += self.collective_trace(mb.group(1), _mult * trips)
            elif oc in ("fusion", "call", "conditional", "custom-call",
                        "async-start"):
                for s in self._called(op):
                    out += self.collective_trace(s, _mult)
        return out

    def collective_report(self) -> Dict[str, dict]:
        """Per-kind byte/count rollup of :meth:`collective_trace` —
        ``{kind: {count, bytes, instrs}}`` with trip multipliers applied."""
        rep: Dict[str, dict] = {}
        for ci in self.collective_trace():
            slot = rep.setdefault(ci.kind,
                                  {"count": 0, "bytes": 0, "instrs": []})
            slot["count"] += ci.mult
            slot["bytes"] += ci.total_bytes
            slot["instrs"].append(ci)
        return rep

    def analyze(self) -> dict:
        # entry computation name in post-opt HLO text
        if self.entry and self.entry in self.comps:
            return self.analyze_comp(self.entry)
        # fallback: the computation with the most ops
        name = max(self.comps, key=lambda c: len(self.comps[c].order))
        return self.analyze_comp(name)


def analyze_text(text: str) -> dict:
    a = Analyzer(text)
    out = a.analyze()
    out["coll_bytes_total"] = sum(out["coll"].values())
    return out


def collective_trace(text: str) -> List[CollectiveInstr]:
    return Analyzer(text).collective_trace()


def collective_report(text: str) -> Dict[str, dict]:
    return Analyzer(text).collective_report()
