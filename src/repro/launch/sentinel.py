"""Gopher Sentinel CLI — the full static-verification matrix.

    PYTHONPATH=src python -m repro.launch.sentinel --matrix full \
        [--devices 1,2,4] [--out sentinel_report.json] [--no-hlo]

Runs the three sentinel passes (see repro.analysis) over the whole
exchange × algorithm × mesh matrix:

  * **Pass 1** (SPMD collective verifier) traces every engine
    configuration's compiled BSP loop on :class:`jax.sharding.AbstractMesh`
    shapes — 5 shard_map exchange modes × {cc, bfs, sssp, pagerank} ×
    D ∈ {1,2,4} with NO subprocess and no real devices, plus the LOCAL
    backend where ``exchange='auto'`` resolves eligible programs to the
    Gopher Hot megastep route — and checks cond-branch collective
    agreement, axis binding, tier-plan staticness, and that the fused
    megastep loop issues no collectives at all.
  * **Pass 2** (semiring laws) probes each program's ⊕/⊗ algebra.
  * **Pass 3** (Pallas linter) lints the kernel modules (megastep.py
    included).
  * **HLO cross-check**: for every tiered/phased loop at D > 1 the loop is
    actually compiled (host platform forced to the max requested device
    count) and the post-compile collective instructions parsed by
    launch/hloparse must agree with the jaxpr-level trace — kind sets
    strictly (error on mismatch), per-kind counts recorded and compared
    (warning on mismatch, to stay robust across XLA versions), and every
    wire collective's byte size checked against the tier plan's predicted
    per-device round geometry (error past the budget).

Emits a machine-readable JSON report and exits non-zero on any
error-severity violation — the CI ``sentinel-gate`` job runs exactly this.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _parse(argv=None):
    ap = argparse.ArgumentParser(description="Gopher Sentinel static checks")
    ap.add_argument("--matrix", choices=("full", "quick"), default="full")
    ap.add_argument("--devices", default="1,2,4",
                    help="comma-separated mesh sizes to verify")
    ap.add_argument("--parts", type=int, default=8)
    ap.add_argument("--rows", type=int, default=10)
    ap.add_argument("--cols", type=int, default=10)
    ap.add_argument("--out", default="sentinel_report.json")
    ap.add_argument("--hlo", dest="hlo", action="store_true", default=True)
    ap.add_argument("--no-hlo", dest="hlo", action="store_false",
                    help="skip the post-compile HLO cross-check")
    return ap.parse_args(argv)


_ALGOS = ("cc", "bfs", "sssp", "pagerank")
_MODES = ("dense", "compact", "tiered", "phased", "auto")


def _build_graph(args):
    from repro.gofs import bfs_grow_partition, road_grid
    from repro.gofs.formats import partition_graph
    g = road_grid(args.rows, args.cols, drop_frac=0.05, seed=1,
                  weighted=True)
    return partition_graph(g, bfs_grow_partition(g, args.parts, seed=0),
                           args.parts)


def _program(algo: str, pg):
    from repro.core import (PageRankProgram, SemiringProgram,
                            init_max_vertex, make_bfs_init, make_sssp_init)
    sp, sl = int(pg.part_of[0]), int(pg.local_of[0])
    if algo == "cc":
        return SemiringProgram(semiring="max_first", init_fn=init_max_vertex)
    if algo == "bfs":
        return SemiringProgram(semiring="min_plus",
                               init_fn=make_bfs_init(sp, sl))
    if algo == "sssp":
        return SemiringProgram(semiring="min_plus",
                               init_fn=make_sssp_init(sp, sl))
    return PageRankProgram(n_global=pg.n_global, num_iters=12)


def _plan(mode: str, pg):
    from repro.core import PhasedTierPlan, TierPlan
    from repro.core.tiers import _NO_BOUNDARY
    if mode == "tiered":
        return TierPlan.from_graph(pg)
    if mode == "phased":
        base = TierPlan.from_graph(pg)
        return PhasedTierPlan(
            num_parts=base.num_parts, cap=base.cap, warm_cap=base.warm_cap,
            phase_tier_bytes=(base.tier_bytes, base.tier_bytes),
            boundaries=(3, _NO_BOUNDARY))
    return None


def _jaxpr_hlo_counts(summary) -> dict:
    """jaxpr collective counts folded onto HLO opcodes (psum/pmax/pmin all
    lower to all-reduce)."""
    from repro.analysis import HLO_KIND
    out: dict = {}
    for kind, n in summary.counts.items():
        hk = HLO_KIND[kind]
        out[hk] = out.get(hk, 0) + n
    return out


def _hlo_cross_check(entry, eng, summary, violations):
    """Compile the loop for real and demand the HLO collective trace agree
    with the jaxpr-level one."""
    import jax

    from repro.analysis import ERROR, WARNING, Violation
    from repro.core import graph_block
    from repro.launch.hloparse import Analyzer

    D = entry["D"]
    if jax.device_count() < D:
        entry["hlo"] = {"skipped": f"needs {D} devices, have "
                                   f"{jax.device_count()}"}
        return
    from repro.core import GopherEngine, compat
    mesh = compat.make_mesh((D,), ("parts",))
    real = GopherEngine(eng.pg, eng.program, backend="shard_map", mesh=mesh,
                        exchange=eng.exchange_requested,
                        tier_plan=eng.tier_plan)
    text = real._sharded_fn().lower(graph_block(eng.pg, as_spec=True)) \
        .compile().as_text()
    rep = Analyzer(text).collective_report()
    hlo_counts = {k: v["count"] for k, v in rep.items()}
    hlo_bytes = {k: v["bytes"] for k, v in rep.items()}
    # per-KIND byte budgets: no wire collective may ship more than the tier
    # plan's predicted per-device geometry FOR ITS OWN KIND — the hot
    # uniform block bounds every all_to_all, the round's summed shifts
    # bound every ppermute (summed, not per-shift, so the ceiling holds
    # when XLA combines a round's ppermutes into one instruction). An
    # instruction over its kind budget means the compiled loop ships
    # traffic the plan's wire geometry never predicted.
    from repro.core import PhasedTierPlan
    plan = eng.tier_plan
    plans = (plan.phase_plans() if isinstance(plan, PhasedTierPlan)
             else (plan,))
    budgets: dict = {}
    for p in plans:
        for k, b in p.schedule(D).kind_byte_budgets(None).items():
            budgets[k] = max(budgets.get(k, 0), b)
    if isinstance(plan, PhasedTierPlan):
        # the phased loop carries a per-superstep dense-retry cond branch;
        # its all_to_all legitimately ships the DENSE round, so the
        # all-to-all ceiling for a phased loop is the dense per-device
        # geometry
        P = plan.num_parts
        budgets["all-to-all"] = max(budgets.get("all-to-all", 0),
                                    (P // D) * P * plan.cap * 4)
    over = [(ci.name, ci.result_bytes, k, budgets[k])
            for k in ("all-to-all", "collective-permute") if k in rep
            for ci in rep[k]["instrs"] if ci.result_bytes > budgets.get(k, 0)]
    if over:
        violations.append(Violation(
            pass_name="collectives", code="HLO_BYTE_BUDGET",
            where=f"{entry['algo']}/{entry['exchange']}/D={D}",
            detail=(f"wire collectives {over} exceed the tier plan's "
                    "per-device per-kind byte budgets (name, bytes, kind, "
                    "budget) — the compiled loop ships traffic the plan's "
                    "wire geometry never predicted"),
            severity=ERROR))
    want_kinds = set(summary.expected_hlo_kinds())
    got_kinds = set(rep)
    want_counts = _jaxpr_hlo_counts(summary)
    agrees_kinds = want_kinds == got_kinds
    agrees_counts = want_counts == hlo_counts
    where = (f"{entry['algo']}/{entry['exchange']}/D={D}")
    if not agrees_kinds:
        violations.append(Violation(
            pass_name="collectives", code="HLO_KIND_MISMATCH", where=where,
            detail=(f"post-compile HLO collectives {sorted(got_kinds)} "
                    "disagree with the jaxpr-level trace "
                    f"{sorted(want_kinds)}: either the walker missed a "
                    "collective or XLA synthesized one the sentinel "
                    "never verified"),
            severity=ERROR))
    elif not agrees_counts:
        violations.append(Violation(
            pass_name="collectives", code="HLO_COUNT_MISMATCH", where=where,
            detail=(f"per-kind HLO collective counts {hlo_counts} != "
                    f"jaxpr-level {want_counts} (kind sets agree; XLA may "
                    "have split/merged collectives — verify manually)"),
            severity=WARNING))
    entry["hlo"] = {
        "kinds": sorted(got_kinds), "counts": hlo_counts,
        "bytes": hlo_bytes, "jaxpr_counts": want_counts,
        "byte_budgets": dict(budgets), "within_byte_budget": not over,
        "agrees_kinds": agrees_kinds, "agrees_counts": agrees_counts,
    }


def run_matrix(args) -> dict:
    import jax

    from repro.analysis import (check_program, check_semiring, errors,
                                lint_kernels, verify_collectives)
    from repro.analysis.semiring import REGISTRY
    from repro.core import GopherEngine

    pg = _build_graph(args)
    devices = tuple(int(d) for d in str(args.devices).split(",") if d)
    algos = _ALGOS if args.matrix == "full" else ("cc", "pagerank")
    modes = _MODES if args.matrix == "full" else ("dense", "tiered",
                                                  "phased")
    violations = []
    configs = []

    kern = lint_kernels()
    violations += kern
    semi = {}
    for name in REGISTRY:
        vs = check_semiring(name)
        violations += vs
        semi[name] = {"violations": [v.to_json() for v in vs]}

    checked_programs = set()
    for D in devices:
        mesh = jax.sharding.AbstractMesh((("parts", D),))
        for algo in algos:
            for mode in modes:
                prog = _program(algo, pg)
                eng = GopherEngine(pg, prog, backend="shard_map", mesh=mesh,
                                   exchange=mode, tier_plan=_plan(mode, pg))
                pkey = (algo, eng.exchange)
                if pkey not in checked_programs:
                    checked_programs.add(pkey)
                    violations += check_program(prog, eng.exchange)
                summary, vs = verify_collectives(eng)
                violations += vs
                entry = {
                    "algo": algo, "requested_exchange": mode,
                    "exchange": eng.exchange, "D": D,
                    "counts": summary.counts,
                    "expected_hlo_kinds": list(summary.expected_hlo_kinds()),
                    "conds": summary.to_json()["conds"],
                    "errors": len(errors(vs)),
                }
                if (args.hlo and D > 1
                        and eng.exchange in ("tiered", "phased")):
                    _hlo_cross_check(entry, eng, summary, violations)
                configs.append(entry)

    # local-backend coverage: exchange='auto' resolves the eligible
    # programs to the Gopher Hot megastep route there. Pass 1 walks the
    # fused loop like any other — and a megastep loop that issues ANY
    # collective is broken by construction (the whole point of the route
    # is that nothing crosses the wire)
    from repro.analysis import ERROR, Violation
    for algo in algos:
        prog = _program(algo, pg)
        eng = GopherEngine(pg, prog, exchange="auto")
        pkey = (algo, eng.exchange)
        if pkey not in checked_programs:
            checked_programs.add(pkey)
            violations += check_program(prog, eng.exchange)
        summary, vs = verify_collectives(eng)
        violations += vs
        if eng.exchange == "megastep" and summary.counts:
            violations.append(Violation(
                pass_name="collectives", code="MEGASTEP_COLLECTIVE",
                where=f"{algo}/megastep/local",
                detail=(f"fused megastep loop issues collectives "
                        f"{summary.counts} — the single-launch route must "
                        "never touch the wire"),
                severity=ERROR))
        configs.append({
            "algo": algo, "requested_exchange": "auto",
            "exchange": eng.exchange, "D": 1, "backend": "local",
            "counts": summary.counts,
            "expected_hlo_kinds": list(summary.expected_hlo_kinds()),
            "conds": summary.to_json()["conds"],
            "errors": len(errors(vs)),
        })

    # Gopher Shield coverage — Pass 1 over (a) the STAGED STEPPED DRIVER
    # (init/sweep/pack/route), the loop every checkpoint/replay recovery
    # resumes through, per mesh size; (b) the batched multi-query SERVING
    # loops a GraphQueryService pools, at the exact query shapes drain()
    # dispatches
    from repro.analysis import (ERROR, SentinelError, Violation,
                                validate_service, validate_stage_fns)
    staged = []
    for D in devices:
        mesh = jax.sharding.AbstractMesh((("parts", D),))
        eng = GopherEngine(pg, _program("sssp", pg), backend="shard_map",
                           mesh=mesh, exchange="compact")
        entry = {"driver": "staged", "D": D}
        try:
            summaries, vs = validate_stage_fns(eng)
            violations += vs
            entry["stages"] = {k: s.counts for k, s in summaries.items()}
            entry["errors"] = len(errors(vs))
        except SentinelError as e:
            violations.append(Violation(
                pass_name="collectives", code="STAGED_DRIVER",
                where=f"staged/D={D}", detail=str(e), severity=ERROR))
            entry["errors"] = 1
        staged.append(entry)

    from repro.serving.service import GraphQueryService
    svc = GraphQueryService({"sentinel": pg})
    families = ("reach", "ppr") if args.matrix == "full" else ("reach",)
    qs = (1, 2) if args.matrix == "full" else (1,)
    serving = {}
    try:
        res = validate_service(svc, families=families, qs=qs)
        serving = {f"{g}/{fam}/Q={q}": len(vs)
                   for (g, fam, q), vs in res.items()}
    except SentinelError as e:
        violations.append(Violation(
            pass_name="collectives", code="SERVING_LOOP",
            where="serving", detail=str(e), severity=ERROR))

    errs = errors(violations)
    return {
        "matrix": args.matrix,
        "devices": list(devices),
        "configs": configs,
        "staged_driver": staged,
        "serving": serving,
        "kernel_lint": [v.to_json() for v in kern],
        "semirings": semi,
        "violations": [v.to_json() for v in violations],
        "summary": {
            "configs": len(configs),
            "violations": len(violations),
            "errors": len(errs),
            "warnings_infos": len(violations) - len(errs),
            "hlo_checked": sum(1 for c in configs
                               if c.get("hlo", {}).get("agrees_kinds")),
        },
    }


def main(argv=None) -> int:
    args = _parse(argv)
    report = run_matrix(args)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    s = report["summary"]
    print(f"# gopher sentinel — matrix={report['matrix']} "
          f"configs={s['configs']} hlo_checked={s['hlo_checked']}")
    for v in report["violations"]:
        sev = v["severity"]
        print(f"  [{v['pass_name']}:{v['code']}] ({sev}) {v['where']}: "
              f"{v['detail']}")
    print(f"# errors={s['errors']} warnings/infos={s['warnings_infos']} "
          f"-> {args.out}")
    return 1 if s["errors"] else 0


if __name__ == "__main__":
    _args = _parse()
    if _args.hlo:
        _dmax = max(int(d) for d in str(_args.devices).split(",") if d)
        if _dmax > 1:
            os.environ["XLA_FLAGS"] = (
                os.environ.get("XLA_FLAGS", "")
                + f" --xla_force_host_platform_device_count={_dmax}"
            ).strip()
    sys.exit(main(sys.argv[1:]))
