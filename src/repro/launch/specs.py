"""ShapeDtypeStruct stand-ins for every model input — no device allocation.

``input_specs(cfg, cell)`` returns the abstract arguments for the function the
cell lowers: train_step (train), prefill_step (prefill), decode_step (decode).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.training import optimizer as O

S = jax.ShapeDtypeStruct


def batch_specs(cfg, seq: int, batch: int, with_labels: bool = True) -> dict:
    if cfg.embed_inputs:
        specs = {"inputs": S((batch, seq, cfg.d_model), np.float32)}
    else:
        specs = {"inputs": S((batch, seq), np.int32)}
    if with_labels:
        specs["labels"] = S((batch, seq), np.int32)
    if cfg.mrope:
        specs["positions"] = S((3, batch, seq), np.int32)
    if cfg.family == "encdec":
        specs["frames"] = S((batch, cfg.enc_seq, cfg.d_model), np.float32)
    return specs


def state_specs(cfg, opt_cfg: O.OptCfg, max_seq: int = 4096):
    """Abstract train state via eval_shape — params never materialize."""
    def build():
        params = M.init_params(jax.random.PRNGKey(0), cfg, max_seq=max_seq)
        return O.init_state(params, opt_cfg)
    return jax.eval_shape(build)


def params_specs(cfg, max_seq: int = 4096, dtype: Optional[str] = None):
    def build():
        p = M.init_params(jax.random.PRNGKey(0), cfg, max_seq=max_seq)
        if (dtype or cfg.dtype) == "bfloat16":
            p = jax.tree.map(lambda a: a.astype(jnp.bfloat16), p)
        return p
    return jax.eval_shape(build)


def cache_specs(cfg, batch: int, max_seq: int):
    return jax.eval_shape(
        lambda: M.init_cache(cfg, batch, max_seq,
                             jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32))


def token_specs(cfg, batch: int):
    if cfg.embed_inputs:
        return S((batch, cfg.d_model), np.float32)
    return S((batch,), np.int32)


def input_specs(cfg, cell: dict, opt_cfg: Optional[O.OptCfg] = None):
    """Returns (kind, args_tuple) for the cell's entry function."""
    kind, seq, batch = cell["kind"], cell["seq"], cell["batch"]
    if kind == "train":
        opt_cfg = opt_cfg or O.OptCfg()
        state = state_specs(cfg, opt_cfg, max_seq=seq)
        return "train", (state, batch_specs(cfg, seq, batch))
    if kind == "prefill":
        params = params_specs(cfg, max_seq=seq)
        return "prefill", (params, batch_specs(cfg, seq, batch, with_labels=False))
    if kind == "decode":
        params = params_specs(cfg, max_seq=seq)
        cache = cache_specs(cfg, batch, seq)
        return "decode", (params, token_specs(cfg, batch), cache)
    raise ValueError(kind)
