"""PageRank + BlockRank on a social-network-like powerlaw graph (paper §5.3,
§6.5): classic PageRank gets NO benefit from the sub-graph abstraction — the
fix is BlockRank, which uses the blocks (sub-graphs) to seed convergence.

    PYTHONPATH=src python examples/pagerank_social.py
"""
import numpy as np

from repro.algorithms import blockrank, pagerank
from repro.core.subgraph import subgraph_sizes
from repro.gofs import powerlaw_social, subgraph_balanced_partition, hash_partition
from repro.gofs.formats import partition_graph


def main():
    g = powerlaw_social(5000, m=5, seed=2)
    pg = partition_graph(g, hash_partition(g, 8, seed=0), 8)

    r_classic, t_classic = pagerank(pg, num_iters=60, tol=1e-7)
    r_block, t_block, info = blockrank(pg, tol=1e-7, max_iters=60)
    top = np.argsort(r_classic[pg.vmask])[-3:]
    print(f"classic PageRank: {t_classic.supersteps} supersteps")
    print(f"BlockRank seeded: {t_block.supersteps} supersteps "
          f"({info['num_meta']} blocks)")

    # straggler telemetry (paper Fig 5): sub-graph size skew per partition
    sizes = subgraph_sizes(pg)
    biggest = [int(s.max()) if len(s) else 0 for s in sizes]
    print(f"largest sub-graph per partition (hash): {biggest}")
    pg_bal = partition_graph(g, subgraph_balanced_partition(g, 8, seed=0), 8)
    sizes_b = [int(s.max()) if len(s) else 0 for s in subgraph_sizes(pg_bal)]
    print(f"largest sub-graph per partition (balanced, paper §7 fix): {sizes_b}")


if __name__ == "__main__":
    main()
