"""SSSP on a road network — the paper's flagship case (81x on RN).

Shows the local-fixpoint sweep ("Dijkstra inside the sub-graph, one
superstep") vs single-relaxation vertex-centric execution, and the bounded
local-iteration straggler knob.

    PYTHONPATH=src python examples/sssp_roadnetwork.py
"""
import numpy as np

from repro.algorithms import sssp
from repro.gofs import bfs_grow_partition, road_grid
from repro.gofs.formats import partition_graph


def main():
    g = road_grid(60, 60, drop_frac=0.02, seed=1, weighted=True)
    pg = partition_graph(g, bfs_grow_partition(g, 8, seed=0), 8)
    src = 0

    dist_sub, t_sub = sssp(pg, src, mode="subgraph")
    dist_vert, t_vert = sssp(pg, src, mode="vertex")
    assert np.allclose(dist_sub[pg.vmask], dist_vert[pg.vmask])

    print(f"sub-graph centric: {t_sub.supersteps} supersteps, "
          f"{t_sub.local_iters.sum()} local sweeps")
    print(f"vertex centric:    {t_vert.supersteps} supersteps")
    print(f"superstep reduction: {t_vert.supersteps / t_sub.supersteps:.1f}x")

    # bounded local work (beyond-paper straggler mitigation, DESIGN.md §7)
    dist_cap, t_cap = sssp(pg, src, mode="subgraph", max_local_iters=8)
    assert np.allclose(dist_cap[pg.vmask], dist_sub[pg.vmask])
    print(f"capped (8 sweeps/superstep): {t_cap.supersteps} supersteps — "
          f"same answer, bounded per-superstep tail")

    reach = np.isfinite(dist_sub[pg.vmask]).mean()
    print(f"reachable fraction from v{src}: {reach:.2%}")


if __name__ == "__main__":
    main()
