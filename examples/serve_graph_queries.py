"""Gopher Serve walkthrough: a multi-tenant graph-query service on two graphs.

Registers a road network and a powerlaw social graph, then serves a mixed
stream of SSSP / BFS / reachability / personalized-PageRank queries through
the batching scheduler, the exact-result cache, and the landmark
(triangle-inequality) tier.

    PYTHONPATH=src python examples/serve_graph_queries.py
"""
import numpy as np

from repro.gofs import bfs_grow_partition, powerlaw_social, road_grid
from repro.gofs.formats import partition_graph
from repro.serving import GraphQueryService


def main():
    rng = np.random.default_rng(0)
    graphs = {}
    for name, g in [("road", road_grid(24, 24, drop_frac=0.05, seed=1)),
                    ("social", powerlaw_social(2000, m=4, seed=2))]:
        pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
        graphs[name] = pg
        print(f"graph {name}: n={pg.n_global} parts={pg.num_parts} "
              f"cut_edges={pg.edge_cut()}")

    svc = GraphQueryService(graphs, max_batch=32)

    # warm the jit caches (one throwaway batch per family/bucket)
    for kind in ("sssp", "ppr"):
        svc.query(kind, "social", 0)

    # a burst of mixed-tenant traffic
    for _ in range(24):
        svc.submit("sssp", "social", int(rng.integers(2000)))
    for _ in range(8):
        svc.submit("ppr", "social", int(rng.integers(2000)))
    for _ in range(8):
        svc.submit("sssp", "road", int(rng.integers(576)))
    svc.submit("reach", "road", tuple(int(s) for s in rng.integers(576, size=3)))
    out = svc.drain()
    print(f"\ndrained {len(out)} responses; stats: {svc.stats.summary()}")

    # repeat traffic hits the exact cache — no supersteps
    hot = svc.query("sssp", "social", 0)
    print(f"repeat query cached={hot.cached} latency={hot.latency_s*1e3:.2f} ms")

    # landmark tier: approximate SSSP with zero engine work
    lc = svc.enable_landmarks("social", num_landmarks=8)
    src = 77
    approx = svc.approx_sssp("social", src)
    exact = svc.query("sssp", "social", src).result
    finite = np.isfinite(exact)
    gap = approx[finite] - exact[finite]
    print(f"landmarks={lc.num_landmarks}: upper bound holds "
          f"{bool(np.all(gap >= -1e-5))}, mean slack "
          f"{float(gap.mean()):.2f} hops, exact on "
          f"{int((gap < 1e-5).sum())}/{int(finite.sum())} vertices")


if __name__ == "__main__":
    main()
