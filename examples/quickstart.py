"""Quickstart: build a graph, store it in GoFS, run sub-graph centric
Connected Components, and inspect the telemetry.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import numpy as np

from repro.algorithms import connected_components
from repro.core import meta_diameter, vertex_diameter
from repro.gofs import GoFSStore, bfs_grow_partition, road_grid


def main():
    # 1. a graph — a road-network-like grid with dropped edges (many WCCs)
    g = road_grid(40, 40, drop_frac=0.08, seed=0)
    print(f"graph: {g.n} vertices, {g.nnz} directed edges")

    # 2. partition + store it GoFS-style (write once)
    with tempfile.TemporaryDirectory() as td:
        store = GoFSStore(td)
        assign = bfs_grow_partition(g, num_parts=4, seed=0)
        pg = store.build("roads", g, assign, num_parts=4)
        print("partition stats:", pg.stats())

        # 3. a worker loads ONLY its partition (the GoFS co-design point)
        part0 = store.load_partition("roads", 0)
        print(f"worker 0 sees {int(part0['vmask'].sum())} vertices, "
              f"{int(pg.num_subgraphs[0])} sub-graphs")

        # 4. run sub-graph centric Connected Components (Gopher)
        labels, ncc, tele = connected_components(pg, mode="subgraph")
        print(f"\nconnected components: {ncc}")
        print(f"supersteps: {tele.supersteps} "
              f"(vertex diameter={vertex_diameter(g)}, "
              f"meta diameter={meta_diameter(pg)})")

        # 5. compare with the vertex centric execution model (Giraph-style)
        _, _, tele_v = connected_components(pg, mode="vertex")
        print(f"vertex-centric would take {tele_v.supersteps} supersteps "
              f"-> {tele_v.supersteps / tele.supersteps:.1f}x more")


if __name__ == "__main__":
    main()
