"""Batched LM-serving example: prefill + greedy decode on the reduced llama3.
(For GRAPH-query serving — the Gopher Serve subsystem — see
``examples/serve_graph_queries.py``.)

    PYTHONPATH=src python examples/serve_lm_batched.py
"""
import sys

from repro.launch import serve

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "llama3-8b", "--reduced",
                "--batch", "4", "--prompt-len", "32", "--gen", "16"]
    serve.main()
