"""End-to-end LM training driver example: a few hundred steps on synthetic
data with checkpoint/restart (kill it mid-run and re-run — it resumes).

    PYTHONPATH=src python examples/train_lm.py
is equivalent to:
    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
        --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/repro_ckpt --resume
"""
import sys

from repro.launch import train

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "llama3-8b", "--reduced",
                "--steps", "200", "--batch", "8", "--seq", "64",
                "--ckpt-dir", "/tmp/repro_ckpt", "--resume"]
    train.main()
