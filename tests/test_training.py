"""Training substrate: optimizer math, loss descent, checkpoint/restore
(+elastic), data pipeline determinism, sharding specs."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models import init_params
from repro.training import optimizer as O
from repro.training.checkpoint import Checkpointer
from repro.training.data import DataCfg, SyntheticLM, make_dataset
from repro.training.shardspec import param_pspecs
from repro.training.train_step import IGNORE, cross_entropy, make_train_step

KEY = jax.random.PRNGKey(0)


def test_adamw_matches_reference():
    """Our AdamW vs a hand-rolled numpy reference on a tiny problem."""
    opt = O.OptCfg(lr=1e-2, warmup_steps=0, total_steps=100, b1=0.9, b2=0.99,
                   weight_decay=0.0, clip_norm=1e9, mixed_precision=False)
    p0 = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    state = O.init_state(p0, opt)
    g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    state, m1 = O.apply_updates(state, g, opt)
    # reference
    lr = float(O.schedule(1, opt))
    gn = np.asarray([0.1, 0.2, -0.3])
    m = 0.1 * gn
    v = 0.01 * gn ** 2
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.99)
    want = np.asarray([1.0, -2.0, 3.0]) - lr * mh / (np.sqrt(vh) + opt.eps)
    np.testing.assert_allclose(np.asarray(state["params"]["w"]), want, rtol=1e-5)


def test_cross_entropy_ignore_index():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.asarray([[1, 2, IGNORE, IGNORE]])
    ce = cross_entropy(logits, labels)
    np.testing.assert_allclose(float(ce), np.log(8), rtol=1e-5)


@pytest.mark.parametrize("mixed", [False, True])
def test_loss_decreases(mixed):
    """A few steps on a tiny llama must reduce loss on a FIXED batch."""
    cfg = ARCHS["llama3-8b"].reduced()
    opt = O.OptCfg(lr=5e-3, warmup_steps=0, total_steps=50,
                   mixed_precision=mixed, clip_norm=1.0)
    params = init_params(KEY, cfg, max_seq=16)
    state = O.init_state(params, opt)
    step = jax.jit(make_train_step(cfg, opt))
    toks = jax.random.randint(KEY, (4, 17), 0, cfg.vocab)
    batch = {"inputs": toks[:, :-1], "labels": toks[:, 1:]}
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


def test_grad_compression_mode_runs():
    cfg = ARCHS["llama3-8b"].reduced()
    opt = O.OptCfg(lr=1e-3, grad_compress_bf16=True, mixed_precision=True)
    params = init_params(KEY, cfg, max_seq=8)
    state = O.init_state(params, opt)
    step = jax.jit(make_train_step(cfg, opt))
    toks = jax.random.randint(KEY, (2, 9), 0, cfg.vocab)
    state, metrics = step(state, {"inputs": toks[:, :-1], "labels": toks[:, 1:]})
    assert np.isfinite(float(metrics["loss"]))


def test_checkpoint_roundtrip(tmp_path):
    cfg = ARCHS["h2o-danube-1.8b"].reduced()
    opt = O.OptCfg(mixed_precision=True)
    state = O.init_state(init_params(KEY, cfg, max_seq=8), opt)
    ck = Checkpointer(str(tmp_path))
    ck.save(state, step=7, extra={"data": {"step": 3}})
    assert ck.latest_step() == 7
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    restored, step = ck.restore(like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert ck.extra()["data"]["step"] == 3


def test_checkpoint_async_and_commit_marker(tmp_path):
    state = {"w": jnp.arange(8.0), "step": jnp.int32(1)}
    ck = Checkpointer(str(tmp_path), async_save=True)
    ck.save(state, step=1)
    ck.wait()
    assert ck.latest_step() == 1
    # a partially-written checkpoint (no COMMIT) must be ignored
    import os
    os.makedirs(tmp_path / "step_9")
    assert ck.latest_step() == 1


def test_elastic_restore_new_mesh(tmp_path):
    """Restore re-shards onto a different mesh (here: trivial 1-dev mesh but
    through the NamedSharding path — the elastic mechanism)."""
    from repro.launch.elastic import plan_mesh
    from repro.training.shardspec import named
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck = Checkpointer(str(tmp_path))
    ck.save(state, step=1)
    plan = plan_mesh(n_chips=1, model_parallel=1)
    mesh = plan.make()
    from jax.sharding import PartitionSpec as P
    shardings = named(mesh, {"w": P(None, None)})
    like = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    restored, _ = ck.restore(like, shardings=shardings)
    assert np.array_equal(np.asarray(restored["w"]), np.arange(16.0).reshape(4, 4))


def test_mesh_plans():
    from repro.launch.elastic import plan_mesh, shrink_after_failure
    plan = plan_mesh(512, model_parallel=16, pods=2)
    assert plan.shape == (2, 16, 16)
    smaller = shrink_after_failure(plan, lost_chips=16)
    assert np.prod(smaller.shape) <= 512 - 16
    assert smaller.shape[-1] == 16  # TP preserved


def test_data_determinism_and_restore():
    cfg = DataCfg(batch=2, seq=8, vocab=100, seed=3)
    it1 = SyntheticLM(cfg)
    b1 = [next(it1) for _ in range(3)]
    it2 = SyntheticLM(DataCfg(batch=2, seq=8, vocab=100, seed=3))
    it2.restore({"step": 2, "seed": 3})
    b2 = next(it2)
    assert np.array_equal(b1[2]["inputs"], b2["inputs"])
    assert (b1[0]["inputs"] != b1[1]["inputs"]).any()
    assert np.array_equal(b1[0]["inputs"][:, 1:], b1[0]["labels"][:, :-1])


def test_token_file_pipeline(tmp_path):
    toks = (np.arange(10_000) % 250).astype(np.uint16)
    path = tmp_path / "tokens.bin"
    toks.tofile(path)
    cfg = DataCfg(batch=2, seq=16, vocab=256, path=str(path))
    ds = make_dataset(cfg)
    b = next(ds)
    assert b["inputs"].shape == (2, 16)
    assert np.array_equal(b["inputs"][:, 1:], b["labels"][:, :-1])


def test_param_pspecs_divisibility():
    """Specs never request a non-dividing axis (GQA kv=8 on TP=16 etc.)."""
    import os
    from repro.launch.mesh import make_mesh
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    cfg = ARCHS["llama3-8b"].reduced()
    params = jax.eval_shape(lambda: init_params(KEY, cfg, max_seq=8))
    mesh = make_mesh((1,), ("model",))
    specs = param_pspecs(params, mesh)
    # every spec entry must divide the corresponding dim
    def check(path, leaf, spec):
        for d, e in zip(leaf.shape, spec):
            if e is None:
                continue
            axes = e if isinstance(e, tuple) else (e,)
            prod = 1
            for a in axes:
                prod *= mesh.shape[a]
            assert d % prod == 0
    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), params, specs)


def test_grad_accumulation_matches_full_batch():
    """accum_steps=k on a batch must produce the same update as the full
    batch in one shot (same loss gradient, fp32 accumulation)."""
    cfg = ARCHS["llama3-8b"].reduced()
    opt = O.OptCfg(lr=1e-3, warmup_steps=0, clip_norm=1e9,
                   mixed_precision=False)
    params = init_params(KEY, cfg, max_seq=8)
    toks = jax.random.randint(KEY, (4, 9), 0, cfg.vocab)
    batch = {"inputs": toks[:, :-1], "labels": toks[:, 1:]}
    s1 = O.init_state(params, opt)
    s2 = jax.tree.map(lambda a: a, s1)
    step1 = jax.jit(make_train_step(cfg, opt, accum_steps=1))
    step2 = jax.jit(make_train_step(cfg, opt, accum_steps=2))
    s1, m1 = step1(s1, batch)
    s2, m2 = step2(s2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-6)
