"""Gopher Sentinel: the three passes must (a) pass clean on the real
engine/kernels across the exchange matrix, and (b) catch each seeded
violation — a mismatched-collective cond branch, a tracer-leaked tier
table, an unmasked partial Pallas block — with a diagnostic that NAMES the
offending equation/field/kernel, not just a boolean."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    REGISTRY,
    SentinelError,
    Violation,
    assert_clean,
    check_plan_static,
    check_program,
    check_semiring,
    errors,
    lint_kernels,
    lint_source,
    probe_laws,
    verify_collectives,
    verify_jaxpr,
)
from repro.core import (
    GopherEngine,
    PageRankProgram,
    PhasedTierPlan,
    SemiringProgram,
    TierPlan,
    compat,
    init_max_vertex,
    make_sssp_init,
)
from repro.core.tiers import _NO_BOUNDARY
from repro.gofs import bfs_grow_partition, road_grid
from repro.gofs.formats import partition_graph

P = jax.sharding.PartitionSpec


@pytest.fixture(scope="module")
def pg8():
    g = road_grid(10, 10, drop_frac=0.05, seed=1, weighted=True)
    return partition_graph(g, bfs_grow_partition(g, 8, seed=0), 8)


def _phased_plan(pg):
    base = TierPlan.from_graph(pg)
    return PhasedTierPlan(
        num_parts=base.num_parts, cap=base.cap, warm_cap=base.warm_cap,
        phase_tier_bytes=(base.tier_bytes, base.tier_bytes),
        boundaries=(3, _NO_BOUNDARY))


# ---------------- Pass 1: positives ----------------

@pytest.mark.parametrize("mode", ["dense", "compact", "tiered", "phased"])
def test_collectives_clean_on_real_engine(pg8, mode):
    mesh = jax.sharding.AbstractMesh((("parts", 4),))
    prog = SemiringProgram(semiring="max_first", init_fn=init_max_vertex)
    plan = _phased_plan(pg8) if mode == "phased" else None
    eng = GopherEngine(pg8, prog, backend="shard_map", mesh=mesh,
                       exchange=mode, tier_plan=plan)
    summary, violations = verify_collectives(eng)
    assert errors(violations) == [], [str(v) for v in violations]
    assert summary.mesh_axes == {"parts": 4}
    # every mode moves data across the 4-device mesh — but the two-level
    # hot schedule sizes the uniform all_to_all block to the MINIMUM
    # per-device-pair hot count, so a skewed mesh (zero hot rows on some
    # pair, as here) may route everything through residual ppermutes
    moved = (summary.counts.get("all_to_all", 0)
             + summary.counts.get("ppermute", 0))
    assert moved > 0
    if mode in ("dense", "compact"):
        assert summary.counts.get("all_to_all", 0) > 0


def test_local_backend_has_no_collectives(pg8):
    prog = SemiringProgram(semiring="max_first", init_fn=init_max_vertex)
    eng = GopherEngine(pg8, prog, backend="local", exchange="compact")
    summary, violations = verify_collectives(eng)
    assert violations == []
    assert summary.counts == {}


def test_engine_validate_hook_runs_clean(pg8):
    prog = SemiringProgram(semiring="max_first", init_fn=init_max_vertex)
    eng = GopherEngine(pg8, prog, exchange="compact", validate=True)
    state, _ = eng.run()
    ref = GopherEngine(pg8, prog, exchange="dense").run()[0]
    assert np.array_equal(np.asarray(state["x"]), np.asarray(ref["x"]))


# ---------------- Pass 1 negative: mismatched cond branches ----------------

def test_cond_collective_mismatch_caught():
    """Branches issuing different collectives under a NON-replicated
    predicate (derived from axis_index) is the SPMD deadlock shape — the
    diagnostic must name the cond equation and show both branch traces."""
    mesh = jax.sharding.AbstractMesh((("parts", 4),))

    def body(x):
        i = jax.lax.axis_index("parts")

        def with_psum(v):
            return jax.lax.psum(v, "parts")

        def without(v):
            return v * 2.0

        return jax.lax.cond(i > 0, with_psum, without, x)

    f = compat.shard_map(body, mesh=mesh, in_specs=(P("parts"),),
                         out_specs=P("parts"))
    jaxpr = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((4, 8), jnp.float32))
    _, violations = verify_jaxpr(jaxpr)
    errs = errors(violations)
    assert len(errs) == 1
    v = errs[0]
    assert v.code == "COND_COLLECTIVE_MISMATCH"
    assert "cond" in v.where                      # names the equation path
    assert "psum" in v.detail and "deadlock" in v.detail
    with pytest.raises(SentinelError) as ei:
        assert_clean(violations)
    assert "COND_COLLECTIVE_MISMATCH" in str(ei.value)


def test_cond_mismatch_allowed_when_predicate_replicated():
    """The phased dense-retry shape: branches differ but the predicate
    rides a full mesh-axis psum — provably uniform, so no violation."""
    mesh = jax.sharding.AbstractMesh((("parts", 4),))

    def body(x):
        flag = jax.lax.psum((x.sum() > 0).astype(jnp.int32), "parts")

        def with_psum(v):
            return jax.lax.psum(v, "parts")

        def without(v):
            return v * 2.0

        return jax.lax.cond(flag > 0, with_psum, without, x)

    f = compat.shard_map(body, mesh=mesh, in_specs=(P("parts"),),
                         out_specs=P(None))
    jaxpr = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((4, 8), jnp.float32))
    summary, violations = verify_jaxpr(jaxpr)
    assert violations == []
    assert len(summary.conds) == 1
    assert not summary.conds[0].branches_equal
    assert summary.conds[0].predicate_uniform


def test_phased_engine_retry_conds_proven_safe(pg8):
    mesh = jax.sharding.AbstractMesh((("parts", 4),))
    prog = SemiringProgram(
        semiring="min_plus",
        init_fn=make_sssp_init(int(pg8.part_of[0]), int(pg8.local_of[0])))
    eng = GopherEngine(pg8, prog, backend="shard_map", mesh=mesh,
                       exchange="phased", tier_plan=_phased_plan(pg8))
    summary, violations = verify_collectives(eng)
    assert violations == []
    assert summary.conds and all(c.predicate_uniform and not c.branches_equal
                                 for c in summary.conds)


# ---------------- Pass 1 negative: non-static tier plans ----------------

def test_tracer_leaked_plan_caught():
    base = TierPlan(num_parts=2, cap=4, warm_cap=2, tier_bytes=bytes(4))
    captured = {}

    def build_inside_jit(t):
        bad = dataclasses.replace(base)
        object.__setattr__(bad, "cap", t)      # a tracer smuggled in
        captured["violations"] = check_plan_static(bad)
        return t

    jax.make_jaxpr(build_inside_jit)(1)
    errs = errors(captured["violations"])
    assert len(errs) == 1
    v = errs[0]
    assert v.code == "PLAN_TRACER_LEAK"
    assert v.where == "tier_plan.cap"             # names the field
    assert "tracer" in v.detail and "cache" in v.detail


def test_array_valued_plan_field_caught():
    base = TierPlan(num_parts=2, cap=4, warm_cap=2, tier_bytes=bytes(4))
    bad = dataclasses.replace(base)
    object.__setattr__(bad, "tier_bytes", np.zeros(4, np.uint8))
    errs = errors(check_plan_static(bad))
    assert [v.code for v in errs] == ["PLAN_UNHASHABLE_FIELD"]
    assert "tier_bytes" in errs[0].where
    assert "unhashable" in errs[0].detail


def test_plan_geometry_checked():
    bad = TierPlan(num_parts=3, cap=4, warm_cap=2, tier_bytes=bytes(4))
    errs = errors(check_plan_static(bad))
    assert [v.code for v in errs] == ["PLAN_BAD_GEOMETRY"]
    ok = TierPlan(num_parts=2, cap=4, warm_cap=2, tier_bytes=bytes(4))
    assert check_plan_static(ok) == []


def test_validate_hook_rejects_bad_plan(pg8):
    """engine.validate=True refuses to compile a loop whose plan cannot
    key the cache — raised before tracing, naming the field."""
    plan = TierPlan.from_graph(pg8)
    bad = dataclasses.replace(plan)
    object.__setattr__(bad, "tier_bytes", np.frombuffer(plan.tier_bytes,
                                                        np.uint8).copy())
    prog = SemiringProgram(semiring="max_first", init_fn=init_max_vertex)
    eng = GopherEngine(pg8, prog, exchange="tiered", tier_plan=bad,
                       validate=True)
    with pytest.raises(SentinelError) as ei:
        eng.run()
    assert "tier_bytes" in str(ei.value)


# ---------------- Pass 2: semiring laws ----------------

def test_registered_semirings_clean():
    for name in REGISTRY:
        assert check_semiring(name) == [], name


def test_overclaimed_idempotence_caught():
    bad = dataclasses.replace(REGISTRY["plus_times"], name="bad_sum",
                              declares_idempotent=True)
    errs = errors(probe_laws(bad))
    assert any(v.code == "PLUS_NOT_IDEMPOTENT" for v in errs)
    v = next(v for v in errs if v.code == "PLUS_NOT_IDEMPOTENT")
    # the diagnostic carries the counterexample and the retry consequence
    assert "⊕" in v.detail and "a=" in v.detail
    assert "dense-retry" in v.detail


def test_wrong_identity_caught():
    bad = dataclasses.replace(REGISTRY["min_plus"], plus_identity=0.0)
    codes = {v.code for v in errors(probe_laws(bad))}
    assert "PLUS_IDENTITY_WRONG" in codes
    assert "IDENTITY_NOT_ANNIHILATING" in codes


def test_pagerank_flagged_allclose_only(pg8):
    prog = PageRankProgram(n_global=pg8.n_global, num_iters=5)
    vs = check_program(prog, "phased")
    assert errors(vs) == []
    infos = [v for v in vs if v.code == "ALLCLOSE_ONLY"]
    assert len(infos) == 1 and infos[0].severity == "info"
    # on the dense path there is no retry, so no flag
    assert check_program(prog, "dense") == []


def test_idempotent_programs_not_flagged():
    prog = SemiringProgram(semiring="max_first", init_fn=init_max_vertex)
    assert check_program(prog, "phased") == []


# ---------------- Pass 3: Pallas kernel linter ----------------

def test_repo_kernels_lint_clean():
    assert lint_kernels() == [], [str(v) for v in lint_kernels()]


_UNMASKED_PARTIAL_BLOCK = '''
import jax, jax.numpy as jnp
from jax.experimental import pallas as pl

def _half_masked_kernel(x_ref, y_ref):
    x = x_ref[...]
    cond = jnp.any(x > 0)
    @pl.when(cond)
    def _go():
        y_ref[...] = x * 2.0

def wrapper(x, block=8):
    r, = x.shape
    grid = (r // block,)
    return pl.pallas_call(_half_masked_kernel, grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), x.dtype))(x)
'''


def test_unmasked_partial_block_caught():
    vs = lint_source(_UNMASKED_PARTIAL_BLOCK, "seeded.py")
    codes = {v.code for v in errors(vs)}
    assert codes == {"PALLAS_UNMASKED_STORE", "PALLAS_GRID_DIVISIBILITY"}
    store = next(v for v in vs if v.code == "PALLAS_UNMASKED_STORE")
    # names the kernel AND the output ref, with the actionable fix
    assert "_half_masked_kernel" in store.where
    assert "y_ref" in store.where
    assert "complementary" in store.detail
    grid = next(v for v in vs if v.code == "PALLAS_GRID_DIVISIBILITY")
    assert "wrapper" in grid.where
    assert "r // block" in grid.detail


def test_mask_multiply_on_ref_values_caught():
    src = '''
import jax.numpy as jnp
def _mul_kernel(v_ref, m_ref, o_ref):
    vals = v_ref[...]
    mask = m_ref[...] > 0
    o_ref[...] = jnp.sum(mask * vals, axis=-1)
'''
    vs = lint_source(src, "seeded.py")
    errs = errors(vs)
    assert [v.code for v in errs] == ["PALLAS_MASK_MULTIPLY"]
    assert "_mul_kernel" in errs[0].where
    assert "jnp.where" in errs[0].detail          # tells you the fix
    # the unselected reduction is also flagged, as a warning
    assert any(v.code == "REDUCE_UNMASKED" and v.severity == "warning"
               for v in vs)


def test_mask_multiply_iota_exempt():
    """The real pack kernels multiply masks into IOTA-derived slot ids —
    finite by construction, must stay clean."""
    src = '''
import jax, jax.numpy as jnp
def _plan_kernel(a_ref, o_ref):
    act = a_ref[...] > 0
    slot = jax.lax.broadcasted_iota(jnp.float32, (8, 8), 1)
    o_ref[...] = jnp.sum(act * slot, axis=-1)
'''
    assert errors(lint_source(src, "ok.py")) == []


def test_io_alias_race_caught():
    src = '''
import jax
from jax.experimental import pallas as pl
def _alias_kernel(a_ref, o_ref):
    o_ref[...] = a_ref[...] * 2.0
    o_ref[...] = o_ref[...] + a_ref[...]
def wrapper(x):
    return pl.pallas_call(_alias_kernel, grid=(4,),
        in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
        out_specs=pl.BlockSpec((8,), lambda i: (i,)),
        input_output_aliases={0: 0},
        out_shape=jax.ShapeDtypeStruct((32,), x.dtype))(x)
'''
    errs = errors(lint_source(src, "seeded.py"))
    assert [v.code for v in errs] == ["IO_ALIAS"]
    assert "_alias_kernel" in errs[0].where
    assert "clobbered" in errs[0].detail


def test_complementary_when_and_ceil_pad_clean():
    """The repo's own idiom (mirrored): complementary pl.when branches +
    ceil-pad grid must produce zero findings."""
    src = '''
import jax, jax.numpy as jnp
from jax.experimental import pallas as pl
def _ok_kernel(x_ref, y_ref):
    x = x_ref[...]
    cond = jnp.any(x > 0)
    @pl.when(cond)
    def _go():
        y_ref[...] = x * 2.0
    @pl.when(~cond)
    def _skip():
        y_ref[...] = jnp.zeros_like(x)
def wrapper(x, block=8):
    r, = x.shape
    br = min(block, r)
    r_pad = -(-r // br) * br
    grid = (r_pad // br,)
    return pl.pallas_call(_ok_kernel, grid=grid,
        in_specs=[pl.BlockSpec((br,), lambda i: (i,))],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r_pad,), x.dtype))(x)[:r]
'''
    assert lint_source(src, "ok.py") == []


# ---------------- report plumbing ----------------

def test_violation_json_roundtrip():
    v = Violation(pass_name="kernels", code="X", where="w", detail="d")
    assert v.to_json() == {"pass_name": "kernels", "code": "X", "where": "w",
                           "detail": "d", "severity": "error"}


def test_sentinel_cli_quick_matrix(tmp_path):
    """The CLI end to end (quick matrix, no HLO compile): report written,
    zero errors on the real engine."""
    import json

    from repro.launch.sentinel import main
    out = tmp_path / "report.json"
    rc = main(["--matrix", "quick", "--no-hlo", "--out", str(out)])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["summary"]["errors"] == 0
    assert rep["summary"]["configs"] > 0
    assert all(c["errors"] == 0 for c in rep["configs"])
