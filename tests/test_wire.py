"""Gopher Wire: frontier-compacted sparse exchange + zero-repack versioned
graph blocks.

Parity contract under test:
  - the compact exchange is BIT-IDENTICAL to the dense mailbox (the packed
    prefix reconstructs the exact dense slot array) for CC / SSSP /
    PageRank on both backends, while shipping fewer slots;
  - a zero-repack-patched graph block produces the same results as a cold
    host_graph_block of the same PartitionedGraph (bit-identical for
    idempotent ⊕; PageRank's float sums may differ in feed-list order, so
    allclose there), across random delta chains (hypothesis);
  - the landmark tier survives deltas per-landmark: provably-untouched
    vectors are kept, stale ones resume from their fixpoints and match a
    cold rebuild exactly.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (GopherEngine, PageRankProgram, SemiringProgram,
                        compat, device_block, host_graph_block,
                        init_max_vertex, make_sssp_init)
from repro.core import messages as msg
from repro.gofs import (EdgeDelta, apply_delta, bfs_grow_partition,
                        powerlaw_social, road_grid)
from repro.gofs.formats import PAD, partition_graph
from repro.gofs.generators import random_graph
from repro.gofs.partition import hash_partition
from repro.kernels import ops


@pytest.fixture(scope="module")
def road():
    g = road_grid(22, 22, drop_frac=0.08, seed=3, weighted=True)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    return g, pg


def _mesh1():
    return compat.make_mesh((1,), ("parts",))


# ---------------- compaction plan: oracle vs Pallas, edge cases ----------------

@pytest.mark.parametrize("shape,density", [((5, 9), 0.3), ((8, 64), 0.05),
                                           ((3, 17), 1.0), ((4, 24), 0.0),
                                           ((1, 1), 0.5)])
def test_compact_plan_pallas_matches_ref(shape, density):
    rng = np.random.default_rng(hash(shape) % 2**31)
    act = jnp.asarray(rng.random(shape) < density)
    ref = ops.outbox_compact_plan(act, backend="jnp")
    pal = ops.outbox_compact_plan(act, backend="pallas", block_r=4)
    for a, b, name in zip(ref, pal, ["pfwd", "pinv", "counts"]):
        assert np.array_equal(np.asarray(a), np.asarray(b)), name


def test_compact_plan_invariants():
    rng = np.random.default_rng(7)
    act = rng.random((6, 40)) < 0.4
    pfwd, pinv, counts = map(np.asarray,
                             ops.outbox_compact_plan(jnp.asarray(act),
                                                     backend="jnp"))
    assert np.array_equal(counts, act.sum(1))
    for r in range(act.shape[0]):
        c = counts[r]
        # forward: ascending active slot ids in the prefix, PAD after
        assert np.array_equal(pfwd[r, :c], np.flatnonzero(act[r]))
        assert np.all(pfwd[r, c:] == PAD)
        # inverse: active slots point at their prefix position
        assert np.array_equal(np.flatnonzero(pinv[r] != PAD),
                              np.flatnonzero(act[r]))
        assert np.array_equal(pinv[r][act[r]], np.arange(c))


# ---------------- pack/unpack round trip vs the dense outbox ----------------

def test_compact_roundtrip_matches_dense_outbox(road):
    g, pg = road
    gb = host_graph_block(pg)
    rng = np.random.default_rng(0)
    r_max = pg.r_max
    vals = jnp.asarray(rng.uniform(0.0, 9.0, r_max).astype(np.float32))
    send = jnp.asarray(rng.random(r_max) < 0.3)
    for p in range(pg.num_parts):
        ob = jnp.asarray(gb["ob_inv"][p])
        dense = msg.build_outbox_gather(vals, send, ob, pg.num_parts,
                                        pg.mailbox_cap, "min")
        pvals, pinv, counts = msg.build_outbox_compact(
            vals, send, ob, pg.num_parts, pg.mailbox_cap, "min")
        rebuilt = msg.unpack_slots(pvals, pinv, "min")
        assert np.array_equal(np.asarray(rebuilt), np.asarray(dense))
        # payload really is the frontier's slots, prefix-packed
        assert int(jnp.sum(counts)) <= int(jnp.sum(send))
        has = np.asarray(pinv) != PAD
        assert np.array_equal(has.sum(1), np.asarray(counts))


def test_compact_roundtrip_batched(road):
    g, pg = road
    gb = host_graph_block(pg)
    rng = np.random.default_rng(1)
    Q, r_max = 3, pg.r_max
    vals = jnp.asarray(rng.uniform(0.0, 9.0, (r_max, Q)).astype(np.float32))
    send = jnp.asarray(rng.random((r_max, Q)) < 0.3)
    for p in range(pg.num_parts):
        ob = jnp.asarray(gb["ob_inv"][p])
        dense = msg.build_outbox_gather_batched(vals, send, ob, pg.num_parts,
                                                pg.mailbox_cap, "min")
        pvals, pinv, _ = msg.build_outbox_compact_batched(
            vals, send, ob, pg.num_parts, pg.mailbox_cap, "min")
        rebuilt = msg.unpack_slots_batched(pvals, pinv, "min")
        assert np.array_equal(np.asarray(rebuilt), np.asarray(dense))


# ---------------- engine: compact == dense, both backends, 3 programs --------

def _programs(pg, n):
    return [
        ("cc", SemiringProgram(semiring="max_first", init_fn=init_max_vertex),
         "x"),
        ("sssp", SemiringProgram(
            semiring="min_plus",
            init_fn=make_sssp_init(int(pg.part_of[0]), int(pg.local_of[0]))),
         "x"),
        ("pagerank", PageRankProgram(n_global=n, num_iters=12), "r"),
    ]


@pytest.mark.parametrize("backend", ["local", "shard_map"])
def test_compact_exchange_bit_identical_to_dense(backend, road):
    g, pg = road
    mesh = _mesh1() if backend == "shard_map" else None
    for name, prog, key in _programs(pg, g.n):
        sd, td = GopherEngine(pg, prog, backend=backend, mesh=mesh,
                              exchange="dense").run()
        sc, tc = GopherEngine(pg, prog, backend=backend, mesh=mesh,
                              exchange="compact").run()
        assert np.array_equal(np.asarray(sd[key]), np.asarray(sc[key])), name
        assert td.supersteps == tc.supersteps
        # wire telemetry: dense ships P²·cap every round; compact tracks the
        # frontier and can never ship more
        assert tc.wire_slots <= td.wire_slots
        assert tc.bytes_on_wire < td.bytes_on_wire
        # round-indexed: supersteps + 1 entries, slot 0 = the inbox prime,
        # and the histogram fully accounts the run's shipped slots
        assert tc.wire_hist is not None
        assert len(tc.wire_hist) == tc.supersteps + 1
        assert int(np.sum(tc.wire_hist)) == tc.wire_slots
        assert int(np.sum(td.wire_hist)) == td.wire_slots
        P, cap = pg.num_parts, pg.mailbox_cap
        assert np.all(np.asarray(td.wire_hist) == P * P * cap)
        assert np.all(np.asarray(tc.wire_hist) <= P * P * cap)


def test_compact_exchange_query_batched(road):
    """Batched serving programs run the compacted exchange too: Q-lane
    results must match the dense exchange lane-for-lane."""
    from repro.serving.batched import (BatchedSemiringProgram,
                                      gather_query_results, sssp_query_init)
    g, pg = road
    sources = [0, 5, g.n // 2, g.n - 1]
    prog = BatchedSemiringProgram(semiring="min_plus",
                                  num_queries=len(sources))
    extra = {"qinit": sssp_query_init(pg, sources)}
    sd, td = GopherEngine(pg, prog, exchange="dense").run_queries(extra=extra)
    sc, tc = GopherEngine(pg, prog, exchange="compact").run_queries(extra=extra)
    assert np.array_equal(gather_query_results(pg, sd["x"]),
                          gather_query_results(pg, sc["x"]))
    assert np.array_equal(td.query_supersteps, tc.query_supersteps)
    assert tc.wire_slots <= td.wire_slots


def test_quiesced_run_ships_zero_slots(road):
    """VoteToHalt on the wire: resuming a converged fixpoint with an empty
    frontier must ship NOTHING (the whole point of the sparse exchange)."""
    from repro.algorithms import bfs
    g, pg = road
    d_prev, _ = bfs(pg, 3)
    prog = SemiringProgram(semiring="min_plus", resume=True)
    eng = GopherEngine(pg, prog, exchange="compact")
    x0 = np.where(pg.vmask, d_prev, np.inf).astype(np.float32)
    _, tele = eng.run(extra={"x0": x0,
                             "frontier0": np.zeros_like(pg.vmask)})
    assert tele.supersteps == 1
    assert tele.wire_slots == 0
    assert tele.messages_sent == 0


# ---------------- zero-repack blocks: cold == patched ----------------

def _run_all(pg, gb_dev, n):
    out = {}
    for name, prog, key in _programs(pg, n):
        state, _ = GopherEngine(pg, prog, gb=gb_dev).run()
        out[name] = np.asarray(state[key])
    return out


@pytest.mark.parametrize("backend", ["local", "shard_map"])
def test_patched_block_matches_cold_block(backend, road):
    g, pg0 = road
    mesh = _mesh1() if backend == "shard_map" else None
    rng = np.random.default_rng(4)
    iu = rng.integers(0, g.n, 60)
    iv = rng.integers(0, g.n, 60)
    keep = iu != iv
    iw = rng.uniform(0.5, 5.0, keep.sum()).astype(np.float32)
    res = apply_delta(pg0, EdgeDelta.inserts(iu[keep], iv[keep], iw),
                      directed=False, block=host_graph_block(pg0))
    pg1 = res.pg
    assert res.block is not None
    cold = host_graph_block(pg1)
    for name, prog, key in _programs(pg1, g.n):
        s_cold, _ = GopherEngine(pg1, prog, backend=backend, mesh=mesh,
                                 gb=device_block(cold)).run()
        s_pat, _ = GopherEngine(pg1, prog, backend=backend, mesh=mesh,
                                gb=device_block(res.block)).run()
        a, b = np.asarray(s_cold[key]), np.asarray(s_pat[key])
        if name == "pagerank":   # ⊕ = float sum: feed order may differ
            assert np.allclose(a, b, rtol=1e-6, atol=1e-9), name
        else:
            assert np.array_equal(a, b), name


def test_patched_block_chain_with_removals_and_hubs():
    """A powerlaw graph (hub promotion on both block sides) through a chain
    of mixed insert/remove deltas; every version's patched block must agree
    with a cold pack of the same graph."""
    g = powerlaw_social(500, m=4, seed=2)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    hb = host_graph_block(pg)
    rng = np.random.default_rng(5)
    for v in range(1, 5):
        # removals sampled from CURRENT remote+local edges via the pg layout
        srcs, dsts = [], []
        for p in range(pg.num_parts):
            m = pg.re_src[p] != PAD
            if m.any():
                srcs.append(pg.global_id[p][pg.re_src[p][m]])
                dsts.append(pg.global_id[pg.re_dst_part[p][m],
                                         pg.re_dst_local[p][m]])
        el = np.stack([np.concatenate(srcs), np.concatenate(dsts)], 1)
        el = el[el[:, 0] < el[:, 1]]
        pick = rng.choice(el.shape[0], min(8, el.shape[0]), replace=False)
        rs, rd = el[pick, 0], el[pick, 1]
        iu = rng.integers(0, g.n, 20)
        iv = (iu + rng.integers(1, g.n, 20)) % g.n
        # keep the batch well-formed: validate_delta rejects an edge that is
        # both inserted and removed in one delta (rs < rd already canonical)
        ok = ~np.isin(np.minimum(iu, iv) * g.n + np.maximum(iu, iv),
                      rs * g.n + rd)
        delta = EdgeDelta.of(
            insert_src=iu[ok], insert_dst=iv[ok],
            insert_wgt=rng.uniform(0.5, 4.0, 20).astype(np.float32)[ok],
            remove_src=rs, remove_dst=rd)
        res = apply_delta(pg, delta, directed=False, block=hb)
        pg, hb = res.pg, res.block
        assert pg.version == v
        cold = host_graph_block(pg)
        got = _run_all(pg, device_block(hb), g.n)
        want = _run_all(pg, device_block(cold), g.n)
        assert np.array_equal(want["cc"], got["cc"]), v
        assert np.array_equal(want["sssp"], got["sssp"]), v
        assert np.allclose(want["pagerank"], got["pagerank"],
                           rtol=1e-6, atol=1e-9), v


# ---------------- landmark tier: per-landmark survival + exact refresh -------

def test_landmark_stale_filter_and_refresh(road):
    from repro.serving.cache import LandmarkCache
    g, pg0 = road
    lc0 = LandmarkCache.build(pg0, num_landmarks=4)

    # an insert that can't relax any landmark vector: all vectors survive
    hb = host_graph_block(pg0)
    d_noop = EdgeDelta.inserts([0], [5], [1e6])
    res = apply_delta(pg0, d_noop, directed=False, block=hb)
    assert not lc0.stale_landmarks(d_noop).any()
    lc1 = lc0.refresh(res.pg, res, d_noop, gb=device_block(res.block))
    assert lc1.refreshed_landmarks == 0
    assert np.array_equal(lc1.dist, lc0.dist)
    assert lc1.graph_version == 1

    # a shortcut insert: stale subset resumes and matches a cold rebuild
    d_cut = EdgeDelta.inserts([0], [g.n - 1], [0.25])
    res2 = apply_delta(res.pg, d_cut, directed=False, block=res.block)
    lc2 = lc1.refresh(res2.pg, res2, d_cut, gb=device_block(res2.block))
    cold = LandmarkCache.build(res2.pg, landmarks=lc2.landmarks)
    assert np.array_equal(lc2.dist, cold.dist)

    # removals invalidate everything (paths may LENGTHEN) but the resumed
    # vectors still match a cold rebuild bit-for-bit
    # a removal that MISSES (edge not present) applies nothing: with the
    # realized count from the apply, every vector survives untouched
    d_miss = EdgeDelta.removes([0], [g.n - 2])
    res_m = apply_delta(res2.pg, d_miss, directed=False, block=res2.block)
    assert res_m.stats["removed"] == 0 and res_m.stats["remove_missed"] > 0
    lc_m = lc2.refresh(res_m.pg, res_m, d_miss, gb=device_block(res_m.block))
    assert lc_m.refreshed_landmarks == 0
    assert np.array_equal(lc_m.dist, lc2.dist)
    res2, lc2 = res_m, lc_m

    src = int(pg0.global_id[0][pg0.vmask[0]][0])
    j = np.flatnonzero(pg0.nbr[0, int(pg0.local_of[src])] != PAD)
    dst = int(pg0.global_id[0][pg0.nbr[0, int(pg0.local_of[src]), j[0]]])
    d_rm = EdgeDelta.removes([dst], [src])
    assert lc2.stale_landmarks(d_rm).all()
    res3 = apply_delta(res2.pg, d_rm, directed=False, block=res2.block)
    lc3 = lc2.refresh(res3.pg, res3, d_rm, gb=device_block(res3.block))
    assert lc3.refreshed_landmarks == lc3.num_landmarks
    cold3 = LandmarkCache.build(res3.pg, landmarks=lc3.landmarks)
    assert np.array_equal(lc3.dist, cold3.dist)


def test_incremental_sssp_batched_bit_identical(road):
    from repro.algorithms import incremental_sssp_batched
    from repro.serving.cache import LandmarkCache
    g, pg0 = road
    lm = np.asarray([0, 7, g.n // 3, g.n - 2], np.int64)
    prev = LandmarkCache.build(pg0, landmarks=lm).dist
    rng = np.random.default_rng(9)
    iu = rng.integers(0, g.n, 25)
    iv = rng.integers(0, g.n, 25)
    keep = iu != iv
    res = apply_delta(pg0, EdgeDelta.inserts(
        iu[keep], iv[keep],
        rng.uniform(0.2, 3.0, keep.sum()).astype(np.float32)),
        directed=False)
    got, tele = incremental_sssp_batched(res.pg, lm, prev, res)
    want = LandmarkCache.build(res.pg, landmarks=lm).dist
    assert np.array_equal(got, want)
    assert tele.query_supersteps is not None


def test_cold_block_keeps_spilled_entries_after_shrink():
    """Regression: a row that grew past w_lo (entry parked at a column >=
    w_lo) and then shrank back under it by removals must still bin as a hub
    in a COLD build — truncating it to [:w_lo] silently dropped the spilled
    neighbors."""
    g = road_grid(16, 16, drop_frac=0.05, seed=9)
    pg = partition_graph(g, bfs_grow_partition(g, 2, seed=0), 2)
    hb = host_graph_block(pg)
    w_lo = hb["nbr_lo"].shape[2]
    # pick a local-heavy vertex and stuff its in-row past w_lo with
    # same-partition neighbors, then remove early ones so degree <= w_lo
    p, v = 0, int(np.flatnonzero(pg.vmask[0])[0])
    tgt = int(pg.global_id[p][v])
    same = [int(x) for x in pg.global_id[p][pg.vmask[p]]
            if int(x) != tgt][:w_lo + 2]
    cur = apply_delta(pg, EdgeDelta.inserts([tgt] * len(same), same),
                      directed=False)
    old = [int(cur.pg.global_id[p][n]) for n in
           cur.pg.nbr[p, v][:3] if n != PAD]
    cur2 = apply_delta(cur.pg, EdgeDelta.removes([tgt] * len(old), old),
                       directed=False)
    pg2 = cur2.pg
    row = pg2.nbr[p, v]
    assert np.any(row[w_lo:] != PAD), "fixture must spill past w_lo"
    assert (row != PAD).sum() <= w_lo, "fixture must shrink under w_lo"
    cold = host_graph_block(pg2)
    # every live in-edge of the row must appear in exactly one bin
    live = set(row[row != PAD].tolist())
    hrow = np.flatnonzero(cold["adj_hub_idx"][p] == v)
    got = set(cold["adj_hub_nbr"][p, hrow[0]][
        cold["adj_hub_nbr"][p, hrow[0]] != PAD].tolist()) if hrow.size \
        else set(cold["nbr_lo"][p, v][cold["nbr_lo"][p, v] != PAD].tolist())
    assert got == live


def test_patch_hub_promotion_when_feed_widths_equal():
    """Regression: promoting a destination vertex to hub receiver when the
    hub feed width equals m_lo must widen ib_hub instead of writing out of
    bounds (IndexError killed the zero-repack ingest path)."""
    g = random_graph(60, avg_degree=3.0, seed=28, weighted=True)
    pg = partition_graph(g, hash_partition(g, 3, seed=28), 3)
    hb = host_graph_block(pg)
    m_lo, m_hi = hb["ib_lo"].shape[2], hb["ib_hub"].shape[2]
    # drive one vertex's remote in-feed past m_lo: insert edges from
    # other-partition sources (directed so only (u -> tgt) lands remotely)
    tgt = int(pg.global_id[0][np.flatnonzero(pg.vmask[0])[0]])
    others = [int(x) for p in (1, 2)
              for x in pg.global_id[p][pg.vmask[p]]][:m_hi + 3]
    res = apply_delta(pg, EdgeDelta.inserts(others, [tgt] * len(others)),
                      directed=True, block=hb)
    prog = SemiringProgram(semiring="min_plus",
                           init_fn=make_sssp_init(int(res.pg.part_of[tgt]),
                                                  int(res.pg.local_of[tgt])))
    s_cold, _ = GopherEngine(res.pg, prog,
                             gb=device_block(host_graph_block(res.pg))).run()
    s_pat, _ = GopherEngine(res.pg, prog, gb=device_block(res.block)).run()
    assert np.array_equal(np.asarray(s_cold["x"]), np.asarray(s_pat["x"]))


# The hypothesis property over random delta batches lives in
# tests/test_property.py (test_random_delta_patched_block_parity) with the
# repo's importorskip convention — this file must run without hypothesis.
