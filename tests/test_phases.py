"""Gopher Phases: frontier-phased tier schedules.

Contract under test:
  - phase bands derive deterministically from the changed-histogram EWMA
    (suffix-max thresholds: a frontier that briefly dips doesn't end a
    band), and the expected-horizon helper reads the same history;
  - PhasedTierPlan: cold blocks degenerate to ONE structural phase (same
    geometry as the static TierPlan — never overflows); taught blocks give
    monotone boundaries, a wide phase at least as wide as the static plan,
    and a narrow tail strictly under it; the plan is hashable (the
    compiled-loop cache keys on it);
  - the phased engine is BIT-IDENTICAL to the dense mailbox for idempotent
    ⊕ on both backends, single and query-batched; PageRank matches to
    allclose (⊕ = float sum reassociates across fused loops);
  - the DEMOTION trigger jumps to the next segment after DEMOTE_STREAK
    supersteps whose observed counts fit the next phase's caps — well
    before a wrong predicted boundary;
  - quiescing EXACTLY at the predicted switch superstep runs zero
    supersteps of the next phase (the boundary off-by-one regression);
  - per-superstep overflow falls back to the dense route INSIDE the loop
    (results exact unconditionally, no whole-run retry) and escalates only
    the spilling phase;
  - update_changed_profile zero-extends past convergence and the announce
    floor warms only pairs within the expected superstep horizon;
  - the landmark tier tracks re-selection drift and the service
    re-bootstraps when it crosses the threshold.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (GopherEngine, PageRankProgram, PhasedTierPlan,
                        SemiringProgram, TierPlan, compat, device_block,
                        expected_horizon, host_graph_block, init_max_vertex,
                        make_sssp_init, update_changed_profile,
                        update_profile)
from repro.core.tiers import (COLD, DEMOTE_STREAK, EXCLUDED, PHASE_HIST_LEN,
                              _NO_BOUNDARY, occupancy_from_graph, phase_bands)
from repro.gofs import EdgeDelta, apply_delta, bfs_grow_partition, road_grid
from repro.gofs.formats import partition_graph


@pytest.fixture(scope="module")
def road():
    g = road_grid(22, 22, drop_frac=0.08, seed=3, weighted=True)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    return g, pg


@pytest.fixture(scope="module")
def taught(road):
    """A host block whose pair + changed profiles were taught by one cold
    compact CC run (the version-k history a deployment accumulates)."""
    g, pg = road
    hb = host_graph_block(pg)
    prog = SemiringProgram(semiring="max_first", init_fn=init_max_vertex)
    _, tele = GopherEngine(pg, prog, gb=device_block(hb),
                           exchange="compact").run()
    update_profile(hb, tele.pair_slots, tele.pair_rounds)
    update_changed_profile(hb, tele.count_hist)
    return hb


def _mesh1():
    return compat.make_mesh((1,), ("parts",))


def _structural_two_phase(pg, boundaries):
    """Two phases at the SAME structural (never-overflowing) table — the
    harness for boundary/demotion tests where geometry must not interfere."""
    base = TierPlan.from_graph(pg)
    return PhasedTierPlan(num_parts=base.num_parts, cap=base.cap,
                          warm_cap=base.warm_cap,
                          phase_tier_bytes=(base.tier_bytes, base.tier_bytes),
                          boundaries=boundaries)


# ---------------- derivation ----------------

def test_phase_bands_deterministic():
    ch = np.array([100.0, 80.0, 30.0, 10.0, 2.0, 0.3, 0.0, 0.0])
    bands = phase_bands(ch, max_phases=3)
    # wide ends where the suffix max stays under 25% of peak, mid under 5%;
    # the horizon ends at the last superstep >= CHANGED_EPS (index 4)
    assert bands == ((3, 3, pytest.approx(70.0)),
                     (4, 1, pytest.approx(10.0)),
                     (_NO_BOUNDARY, 1, pytest.approx(2.0)))
    # a dip-and-rebound does NOT end the wide band early
    ch2 = np.array([100.0, 3.0, 90.0, 1.0, 0.0])
    b2 = phase_bands(ch2, max_phases=3)
    assert b2[0][0] == 3
    # no usable history -> one unbounded band
    assert phase_bands(None) == ((_NO_BOUNDARY, _NO_BOUNDARY, 1.0),)
    assert phase_bands(np.zeros(8)) == ((_NO_BOUNDARY, _NO_BOUNDARY, 1.0),)


def test_expected_horizon():
    assert expected_horizon(None) is None
    assert expected_horizon(np.zeros(16)) is None
    assert expected_horizon(np.array([3.0, 1.0, 0.2, 0.0])) == 2
    assert expected_horizon(np.array([0.0, 0.0, 7.0])) == 3


def test_update_changed_profile_zero_extends(road):
    g, pg = road
    hb = host_graph_block(pg)
    assert np.all(hb["changed_ewma"] == 0.0)
    out = update_changed_profile(hb, [40, 8], decay=0.25)
    assert out.shape == (PHASE_HIST_LEN,)
    assert out[0] == pytest.approx(30.0) and out[1] == pytest.approx(6.0)
    assert np.all(out[2:] == 0.0)
    # a quiesced run (empty histogram) decays the whole profile
    out2 = update_changed_profile(hb, [], decay=0.25)
    assert out2[0] == pytest.approx(7.5)
    # blocks without the field are left untouched
    assert update_changed_profile({}, [1, 2]) is None


def test_phased_plan_cold_block_is_single_structural_phase(road):
    g, pg = road
    hb = host_graph_block(pg)
    plan = PhasedTierPlan.from_block(hb)
    assert plan.num_phases == 1
    assert plan.boundaries == (_NO_BOUNDARY,)
    static = TierPlan.from_block(hb)
    assert plan.phase_plans()[0] == static
    assert PhasedTierPlan.from_graph(pg).phase_plans()[0] == \
        TierPlan.from_graph(pg)


def test_phased_plan_from_taught_block(road, taught):
    g, pg = road
    plan = PhasedTierPlan.from_block(taught)
    assert plan.num_phases >= 2
    bounds = np.asarray(plan.boundaries)
    assert np.all(np.diff(bounds) > 0) and bounds[-1] == _NO_BOUNDARY
    phases = plan.phase_plans()
    static = TierPlan.from_block(taught)
    # the wide phase covers at least the static plan's widths; the narrow
    # tail routes strictly less geometry
    assert np.all(phases[0].limits() >= static.limits())
    assert (phases[-1].schedule(1).round_slots()
            < phases[0].schedule(1).round_slots())
    # excluded pairs are structural — identical across phases
    for p in phases:
        assert np.array_equal(p.tiers == EXCLUDED,
                              phases[0].tiers == EXCLUDED)
    # hashable: equal plans are one compiled-loop cache key
    assert {plan: 1}[PhasedTierPlan.from_block(taught)] == 1


def test_plan_mode_normalization(road):
    """Plan/mode mismatches normalize instead of crashing at trace time: a
    PhasedTierPlan under exchange='tiered' (e.g. a narrow_resume plan handed
    to an auto engine that resolved tiered) upgrades the mode to 'phased';
    a plain TierPlan under 'phased' wraps as a single phase."""
    g, pg = road
    prog = SemiringProgram(semiring="max_first", init_fn=init_max_vertex)
    sd, _ = GopherEngine(pg, prog, exchange="dense").run()
    up = GopherEngine(pg, prog, exchange="tiered",
                      tier_plan=PhasedTierPlan.from_graph(pg))
    assert up.exchange == "phased"
    st, tt = up.run()
    assert np.array_equal(np.asarray(sd["x"]), np.asarray(st["x"]))
    assert tt.exchange == "phased"
    wrapped = GopherEngine(pg, prog, exchange="phased",
                           tier_plan=TierPlan.from_graph(pg))
    assert wrapped.tier_plan.num_phases == 1
    s2, _ = wrapped.run()
    assert np.array_equal(np.asarray(sd["x"]), np.asarray(s2["x"]))


def test_narrow_resume_plan(road, taught):
    g, pg = road
    # no announce pending: the narrow plan is the profile plan's tail
    full = PhasedTierPlan.from_block(taught)
    narrow = PhasedTierPlan.narrow_resume(taught)
    assert narrow.num_phases == 1
    assert narrow.boundaries == (_NO_BOUNDARY,)
    assert narrow.phase_tier_bytes[0] == full.phase_tier_bytes[-1]


def test_for_resume_announce_informed(road, taught):
    """After a delta, for_resume builds phase 0 from the EXACT announced
    prime-round expectation — on an UNTAUGHT replica that is orders of
    magnitude narrower than the structural prior, and the restart provably
    fits it (prime counts are the announce), so a cold block's restart
    rides narrow geometry with zero spills."""
    g, pg = road
    hb = host_graph_block(pg)                    # fresh replica: structural
    update_changed_profile(hb, np.asarray(taught["changed_ewma"]))
    rng = np.random.default_rng(4)
    iu = rng.integers(0, g.n, 6)
    iv = rng.integers(0, g.n, 6)
    keep = iu != iv
    res = apply_delta(pg, EdgeDelta.inserts(
        iu[keep], iv[keep],
        rng.uniform(40.0, 50.0, int(keep.sum())).astype(np.float32)),
        directed=False, block=hb)
    assert np.any(res.block["announce_ewma"] > 0)
    plan = PhasedTierPlan.for_resume(res.block)
    static = TierPlan.from_block(res.block)      # structural on a replica
    assert (plan.phase_plans()[0].schedule(1).round_slots()
            < static.schedule(1).round_slots())
    # the restart itself: exact + spill-free on the announce-informed plan
    prog = SemiringProgram(semiring="max_first", init_fn=init_max_vertex)
    prev, _ = GopherEngine(pg, prog, exchange="dense").run()
    x0 = np.where(res.pg.vmask, np.asarray(prev["x"], np.float32), -np.inf)
    extra = {"x0": x0, "frontier0": res.dirty_insert & res.pg.vmask}
    gbd = device_block(res.block)
    rprog = SemiringProgram(semiring="max_first", resume=True)
    sd, td = GopherEngine(res.pg, rprog, gb=gbd, exchange="dense").run(
        extra=extra)
    sp_, tp = GopherEngine(res.pg, rprog, gb=gbd, exchange="phased",
                           tier_plan=plan).run(extra=extra)
    assert np.array_equal(np.asarray(sd["x"]), np.asarray(sp_["x"]))
    assert tp.spills == 0 and tp.dense_retry_steps == 0
    assert tp.wire_slots < td.wire_slots
    # a run's profile fold CONSUMES the pending announce
    update_profile(res.block, tp.pair_slots, tp.pair_rounds)
    assert not np.any(res.block["announce_ewma"] > 0)
    assert PhasedTierPlan.narrow_resume(res.block).num_phases == 1


# ---------------- engine: phased == dense ----------------

def _programs(pg, n):
    return [
        ("cc", SemiringProgram(semiring="max_first", init_fn=init_max_vertex),
         "x", True),
        ("sssp", SemiringProgram(
            semiring="min_plus",
            init_fn=make_sssp_init(int(pg.part_of[0]), int(pg.local_of[0]))),
         "x", True),
        ("pagerank", PageRankProgram(n_global=n, num_iters=12), "r", False),
    ]


@pytest.mark.parametrize("backend", ["local", "shard_map"])
def test_phased_matches_dense(backend, road, taught):
    g, pg = road
    mesh = _mesh1() if backend == "shard_map" else None
    plan = PhasedTierPlan.from_block(taught)
    K = plan.num_phases
    for name, prog, key, exact in _programs(pg, g.n):
        sd, td = GopherEngine(pg, prog, backend=backend, mesh=mesh,
                              exchange="dense").run()
        sp_, tp = GopherEngine(pg, prog, backend=backend, mesh=mesh,
                               exchange="phased", tier_plan=plan).run()
        a, b = np.asarray(sd[key]), np.asarray(sp_[key])
        if exact:
            assert np.array_equal(a, b), name
        else:
            assert np.allclose(a, b, rtol=1e-6, atol=1e-9), name
        assert td.supersteps == tp.supersteps
        assert tp.exchange == "phased" and not tp.retried
        P = pg.num_parts
        assert tp.phase_hist is not None
        # round-indexed: supersteps + 1 entries, round 0 = the inbox prime
        assert tp.phase_hist.shape == (tp.supersteps + 1,)
        assert tp.phase_hist[0] == 0                     # prime rides phase 0
        assert np.all(np.diff(tp.phase_hist) >= 0)       # phases only advance
        assert tp.phase_hist.max() < K if tp.supersteps else True
        assert tp.count_hist is not None
        assert tp.phase_pair_slots.shape == (K, P, P)
        assert tp.pair_slots.shape == (P, P)
        assert tp.phase_wire.shape == (K,)
        assert tp.phase_wire.sum() == tp.wire_slots
        # the run rode the contraction: total routed geometry under dense
        assert tp.wire_slots < td.wire_slots, name
        assert tp.bytes_on_wire < td.bytes_on_wire, name


def test_phased_query_batched_matches_dense(road, taught):
    from repro.serving.batched import (BatchedSemiringProgram,
                                       gather_query_results, sssp_query_init)
    g, pg = road
    sources = [0, 5, g.n // 2, g.n - 1]
    prog = BatchedSemiringProgram(semiring="min_plus",
                                  num_queries=len(sources))
    extra = {"qinit": sssp_query_init(pg, sources)}
    sd, td = GopherEngine(pg, prog, exchange="dense").run_queries(extra=extra)
    plan = PhasedTierPlan.from_block(taught)
    sp_, tp = GopherEngine(pg, prog, exchange="phased",
                           tier_plan=plan).run_queries(extra=extra)
    assert np.array_equal(gather_query_results(pg, sd["x"]),
                          gather_query_results(pg, sp_["x"]))
    assert np.array_equal(td.query_supersteps, tp.query_supersteps)
    assert tp.wire_slots < td.wire_slots


# ---------------- segment control flow ----------------

def test_demotion_trigger_jumps_to_next_segment(road):
    """A wildly wrong predicted boundary must not pin the run in the wide
    phase: observed counts fitting the next phase's caps for DEMOTE_STREAK
    consecutive supersteps jump the segment immediately. (Both phases use
    the structural table, so counts always fit and results can't differ.)"""
    g, pg = road
    prog = SemiringProgram(semiring="max_first", init_fn=init_max_vertex)
    sd, td = GopherEngine(pg, prog, exchange="dense").run()
    plan = _structural_two_phase(pg, boundaries=(1000, _NO_BOUNDARY))
    st, tt = GopherEngine(pg, prog, exchange="phased", tier_plan=plan).run()
    assert np.array_equal(np.asarray(sd["x"]), np.asarray(st["x"]))
    assert tt.supersteps == td.supersteps
    if tt.supersteps > DEMOTE_STREAK:
        assert np.array_equal(tt.phase_switch_steps, [DEMOTE_STREAK])
        # rounds 0..DEMOTE_STREAK (prime + the streak supersteps) ride the
        # wide phase; every later round is in the demoted segment
        assert np.all(tt.phase_hist[:DEMOTE_STREAK + 1] == 0)
        assert np.all(tt.phase_hist[DEMOTE_STREAK + 1:] == 1)


def test_quiesce_exactly_at_predicted_switch(road):
    """The boundary off-by-one regression: a run that quiesces EXACTLY at
    the predicted switch superstep must run ZERO supersteps of the next
    phase. The next phase is all-width-1 here, so a single leaked
    superstep would truncate and show up as a dense-retry/spill."""
    g, pg = road
    prog = SemiringProgram(semiring="max_first", init_fn=init_max_vertex)
    sd, td = GopherEngine(pg, prog, exchange="dense").run()
    S = td.supersteps
    base = TierPlan.from_graph(pg)
    allcold = np.where(base.tiers == EXCLUDED, EXCLUDED, COLD).astype(np.int8)
    # boundaries are in ROUND units: the run's last exchange is round S
    # (superstep S - 1 ships it), so the wide band must cover rounds < S + 1
    plan = PhasedTierPlan(num_parts=base.num_parts, cap=base.cap,
                          warm_cap=base.warm_cap,
                          phase_tier_bytes=(base.tier_bytes,
                                            allcold.tobytes()),
                          boundaries=(S + 1, _NO_BOUNDARY))
    st, tt = GopherEngine(pg, prog, exchange="phased", tier_plan=plan).run()
    assert np.array_equal(np.asarray(sd["x"]), np.asarray(st["x"]))
    assert tt.supersteps == S                      # no leaked supersteps
    assert np.all(tt.phase_hist == 0)              # phase 1 never ran
    assert tt.spills == 0 and tt.dense_retry_steps == 0
    # one round earlier and the LAST live superstep crosses into the
    # all-cold phase: the in-loop dense retry absorbs it, results exact
    plan2 = dataclasses.replace(plan, boundaries=(S, _NO_BOUNDARY))
    st2, tt2 = GopherEngine(pg, prog, exchange="phased",
                            tier_plan=plan2).run()
    assert np.array_equal(np.asarray(sd["x"]), np.asarray(st2["x"]))
    assert tt2.supersteps == S
    assert tt2.phase_hist[-1] == 1


def test_overflow_dense_retry_escalates_only_spilling_phase(road):
    """Sabotage ONLY the tail phase (busiest pair demoted to cold). The
    overflowing supersteps route dense inside the loop — results exact,
    no whole-run retry — and the escalation promotes the tail phase's
    pair while the wide phase keeps its geometry."""
    g, pg = road
    prog = SemiringProgram(
        semiring="min_plus",
        init_fn=make_sssp_init(int(pg.part_of[0]), int(pg.local_of[0])))
    sd, _ = GopherEngine(pg, prog, exchange="dense").run()
    base = TierPlan.from_graph(pg)
    occ = occupancy_from_graph(pg)
    s, d = np.unravel_index(np.argmax(occ), occ.shape)
    assert occ[s, d] > 1
    t = base.tiers.copy()
    t[s, d] = COLD
    plan = PhasedTierPlan(num_parts=base.num_parts, cap=base.cap,
                          warm_cap=base.warm_cap,
                          phase_tier_bytes=(base.tier_bytes, t.tobytes()),
                          boundaries=(1, _NO_BOUNDARY))
    eng = GopherEngine(pg, prog, exchange="phased", tier_plan=plan)
    st, tt = eng.run()
    assert np.array_equal(np.asarray(sd["x"]), np.asarray(st["x"]))
    assert not tt.retried                          # no whole-run retry
    assert tt.dense_retry_steps > 0 and tt.spills > 0
    assert tt.pair_overflow[s, d] > 0
    assert tt.escalations >= 1
    new = eng.tier_plan.phase_plans()
    assert new[0] == base                          # wide phase untouched
    assert new[1].tiers[s, d] > COLD               # tail phase promoted
    # escalation converges: the repaired plan goes back to pure phased runs
    for _ in range(3):
        st, tt = eng.run()
        if tt.dense_retry_steps == 0:
            break
    assert tt.dense_retry_steps == 0 and tt.spills == 0
    assert np.array_equal(np.asarray(sd["x"]), np.asarray(st["x"]))


def test_phased_multi_device_collectives_static():
    """Gopher Sentinel replaces the old D=4 subprocess collective check:
    trace the phased shard_map loop on an ABSTRACT 4-device mesh (no real
    devices, no subprocess) and statically verify the SPMD invariants the
    subprocess run could only sample — the per-superstep lax.cond picks
    between two genuinely DIFFERENT collective routes (dense all_to_all
    vs tiered all_to_all + ppermute), which is deadlock-free only because
    its predicate is replicated by a full mesh-axis psum."""
    import jax

    from repro.analysis import verify_collectives
    # P=8 over D=4 so the tier schedule has warm (ppermute) lanes, not
    # just the hot all_to_all — same shape as the subprocess smoke below
    g = road_grid(10, 10, drop_frac=0.05, seed=1, weighted=True)
    pg = partition_graph(g, bfs_grow_partition(g, 8, seed=0), 8)
    mesh = jax.sharding.AbstractMesh((("parts", 4),))
    prog = SemiringProgram(semiring="min_plus",
                           init_fn=make_sssp_init(int(pg.part_of[0]),
                                                  int(pg.local_of[0])))
    eng = GopherEngine(pg, prog, backend="shard_map", mesh=mesh,
                       exchange="phased",
                       tier_plan=_structural_two_phase(pg, (2, _NO_BOUNDARY)))
    summary, violations = verify_collectives(eng)
    assert violations == [], [str(v) for v in violations]
    # both routes' collectives are present in the traced loop
    counts = summary.counts
    assert counts.get("all_to_all", 0) > 0
    assert counts.get("ppermute", 0) > 0
    assert counts.get("psum", 0) > 0
    # every retry cond has mismatched branch traces (the two routes) yet is
    # proven safe by predicate replication — the exact property the old
    # subprocess test could only witness indirectly via bit-parity
    assert summary.conds, "phased loop must contain the retry conds"
    for cond in summary.conds:
        assert not cond.branches_equal
        assert cond.predicate_uniform and cond.safe


def test_phased_multi_device_smoke():
    """One end-to-end D=4 subprocess smoke (the static sentinel check above
    covers the collective structure): a sabotaged narrow phase forces the
    replicated cond to flip to the dense route mid-loop on every device at
    once, and the result stays bit-identical to dense."""
    import os
    import subprocess
    import sys
    prog = r"""
import numpy as np
from repro.core import (GopherEngine, PhasedTierPlan, SemiringProgram,
                        TierPlan, compat, init_max_vertex, make_sssp_init)
from repro.core.tiers import COLD, _NO_BOUNDARY, occupancy_from_graph
from repro.gofs import bfs_grow_partition, road_grid
from repro.gofs.formats import partition_graph
g = road_grid(10, 10, drop_frac=0.05, seed=1, weighted=True)
pg = partition_graph(g, bfs_grow_partition(g, 8, seed=0), 8)
mesh = compat.make_mesh((4,), ("parts",))
prog = SemiringProgram(semiring="min_plus",
                       init_fn=make_sssp_init(int(pg.part_of[0]),
                                              int(pg.local_of[0])))
sd, td = GopherEngine(pg, prog, backend="shard_map", mesh=mesh,
                      exchange="dense").run()
base = TierPlan.from_graph(pg)
# sabotaged tail: busiest pair at width 1 -> replicated cond flips to the
# dense all_to_all mid-loop on every device at once
occ = occupancy_from_graph(pg)
s, d = np.unravel_index(np.argmax(occ), occ.shape)
t = base.tiers.copy(); t[s, d] = COLD
bad = PhasedTierPlan(num_parts=base.num_parts, cap=base.cap,
                     warm_cap=base.warm_cap,
                     phase_tier_bytes=(base.tier_bytes, t.tobytes()),
                     boundaries=(1, _NO_BOUNDARY))
st2, tt2 = GopherEngine(pg, prog, backend="shard_map", mesh=mesh,
                        exchange="phased", tier_plan=bad).run()
assert np.array_equal(np.asarray(sd["x"]), np.asarray(st2["x"]))
assert tt2.dense_retry_steps > 0 and not tt2.retried
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


# ---------------- announce-floor horizon ----------------

def test_announce_floor_bounded_by_horizon():
    """On a partition chain, a 1-hop horizon warms only the dirty
    partition's neighborhood; the unbounded (no-history) floor warms the
    whole meta-closure."""
    from repro.gofs.formats import PAD
    # a 2x80 strip partitions into a CHAIN-shaped meta-graph (partition 0
    # touches only partition 3), so depth actually bounds the closure
    g = road_grid(2, 80, drop_frac=0.0, seed=0, weighted=False)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    # a partition-0-LOCAL edge straight off the ELL rows (guaranteed local)
    lu = int(np.flatnonzero((pg.nbr[0] != PAD).any(1))[0])
    lv = int(pg.nbr[0][lu][pg.nbr[0][lu] != PAD][0])
    u = int(pg.global_id[0][lu])
    v = int(pg.global_id[0][lv])

    def floor_pairs(horizon_hist):
        hb = host_graph_block(pg)
        # silence the taught profile so only the announce floor shows
        P = pg.num_parts
        update_profile(hb, np.zeros((P, P)), rounds=1, decay=0.0)
        if horizon_hist is not None:
            hb["changed_ewma"][:len(horizon_hist)] = horizon_hist
        res = apply_delta(pg, EdgeDelta.inserts([u], [v]), directed=False,
                          block=hb)
        return (res.block["wire_ewma"] > 0).sum(), res.block["wire_ewma"]

    warmed_full, _ = floor_pairs(None)                   # unbounded closure
    warmed_h1, ew1 = floor_pairs([10.0])                 # horizon = 1 hop
    assert warmed_h1 < warmed_full
    # far partitions' pairs stayed cold under the bounded floor
    occ = occupancy_from_graph(pg)
    far = [p for p in range(pg.num_parts) if occ[0, p] == 0 and p != 0]
    assert far, "chain fixture must have non-adjacent partitions"
    for p in far:
        assert np.all(ew1[p] == 0.0)


# ---------------- landmark drift (serving) ----------------

def test_landmark_drift_tracks_and_rebootstraps(road):
    from repro.serving.service import GraphQueryService
    g, pg = road
    svc = GraphQueryService({"rn": pg})
    lc = svc.enable_landmarks("rn", num_landmarks=4)
    assert lc.stale_frac_ewma == 0.0 and not lc.drifted()
    rng = np.random.default_rng(0)
    # low-weight inserts relax every landmark vector -> stale fraction 1.0
    for _ in range(2):
        iu = rng.integers(0, g.n, 4)
        iv = rng.integers(0, g.n, 4)
        keep = iu != iv
        svc.apply_delta("rn", EdgeDelta.inserts(
            iu[keep], iv[keep],
            np.full(int(keep.sum()), 0.01, np.float32)),
            rebuild_landmarks=True)
    tele = svc.landmark_telemetry("rn")
    assert tele["refreshes"] == 2 and tele["stale_frac_ewma"] > 0.6
    assert tele["drifted"]
    # the next maintained delta re-bootstraps with fresh selection
    iu = rng.integers(0, g.n, 2)
    iv = (iu + 1) % g.n
    svc.apply_delta("rn", EdgeDelta.inserts(iu, iv), rebuild_landmarks=True)
    tele = svc.landmark_telemetry("rn")
    assert tele["rebootstraps"] == 1
    assert tele["refreshes"] == 0 and tele["stale_frac_ewma"] == 0.0
    # results still served correctly after the re-bootstrap
    resp = svc.query("sssp", "rn", [0])
    assert resp.error is None
    # re-inserting EXISTING edges at a huge weight provably relaxes nothing
    # (min duplicate policy; endpoints share every landmark's component), so
    # quiet versions keep the drift EWMA at/below its level
    lc2 = svc.landmark_caches["rn"]
    coo = g.undirected_csr().tocoo()
    pick = rng.integers(0, coo.nnz, 2)
    for _ in range(2):
        svc.apply_delta("rn", EdgeDelta.inserts(
            coo.row[pick], coo.col[pick],
            np.full(2, 900.0, np.float32)), rebuild_landmarks=True)
    lc3 = svc.landmark_caches["rn"]
    assert lc3.stale_frac_ewma <= lc2.stale_frac_ewma + 1e-9
