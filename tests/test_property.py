"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest
import scipy.sparse.csgraph as csgraph

hypothesis = pytest.importorskip(
    "hypothesis", reason="property sweeps need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.algorithms import connected_components, max_vertex, sssp
from repro.core import meta_diameter
from repro.gofs.formats import PAD, partition_graph
from repro.gofs.generators import random_graph
from repro.gofs.partition import bfs_grow_partition, hash_partition


def _pg(n, deg, parts, seed, partitioner=hash_partition, weighted=False):
    g = random_graph(n, avg_degree=deg, seed=seed, weighted=weighted)
    return g, partition_graph(g, partitioner(g, parts, seed=seed), parts)


def _gather(pg, per_part):
    out = np.zeros(pg.n_global, per_part.dtype)
    for p in range(pg.num_parts):
        m = pg.vmask[p]
        out[pg.global_id[p][m]] = per_part[p][m]
    return out


@settings(max_examples=15, deadline=None)
@given(st.integers(10, 120), st.floats(1.0, 5.0), st.integers(2, 6),
       st.integers(0, 10_000))
def test_cc_count_invariant(n, deg, parts, seed):
    """#components from the engine == scipy, for any graph/partitioning."""
    g, pg = _pg(n, deg, parts, seed)
    ncc_true, _ = csgraph.connected_components(g.undirected_csr(), directed=False)
    _, ncc, _ = connected_components(pg, mode="subgraph")
    assert ncc == ncc_true


@settings(max_examples=15, deadline=None)
@given(st.integers(10, 100), st.integers(2, 5), st.integers(0, 10_000))
def test_sssp_equals_scipy(n, parts, seed):
    g, pg = _pg(n, 3.0, parts, seed, weighted=True)
    d_true = csgraph.shortest_path(g.csr().T, indices=[0])[0]
    dist, _ = sssp(pg, 0, mode="subgraph")
    ours = _gather(pg, dist)
    finite = np.isfinite(d_true)
    assert np.array_equal(np.isfinite(ours), finite)
    np.testing.assert_allclose(ours[finite], d_true[finite], rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(10, 80), st.integers(2, 4), st.integers(0, 10_000))
def test_subgraph_never_more_supersteps_than_vertex(n, parts, seed):
    """Paper §3.3: worst case the sub-graph model degenerates to vertex
    centric — it can never take MORE supersteps."""
    _, pg = _pg(n, 2.5, parts, seed)
    _, _, t_sub = connected_components(pg, mode="subgraph")
    _, _, t_vert = connected_components(pg, mode="vertex")
    assert t_sub.supersteps <= t_vert.supersteps


@settings(max_examples=10, deadline=None)
@given(st.integers(12, 80), st.integers(2, 4), st.integers(0, 10_000))
def test_max_vertex_is_global_max_per_component(n, parts, seed):
    g, pg = _pg(n, 3.0, parts, seed)
    x, _ = max_vertex(pg, mode="subgraph")
    vals = _gather(pg, x)
    _, lab = csgraph.connected_components(g.undirected_csr(), directed=False)
    for c in np.unique(lab):
        comp = np.flatnonzero(lab == c)
        assert np.all(vals[comp] == comp.max())


@settings(max_examples=10, deadline=None)
@given(st.integers(10, 80), st.integers(2, 4), st.integers(0, 10_000))
def test_supersteps_bounded_by_meta_diameter(n, parts, seed):
    _, pg = _pg(n, 2.5, parts, seed)
    _, _, tele = connected_components(pg, mode="subgraph")
    dm = meta_diameter(pg, sample=128)
    assert tele.supersteps <= dm + 3


@settings(max_examples=10, deadline=None)
@given(st.integers(16, 64), st.integers(2, 4), st.integers(0, 2**16))
def test_partitioners_cover_all_vertices(n, parts, seed):
    g = random_graph(n, avg_degree=3.0, seed=seed)
    for fn in (hash_partition, bfs_grow_partition):
        a = fn(g, parts, seed=seed)
        assert a.shape == (n,)
        assert a.min() >= 0 and a.max() < parts


@settings(max_examples=8, deadline=None)
@given(st.integers(16, 64), st.integers(0, 2**16))
def test_mamba2_vs_mamba1_style_recurrence(S, seed):
    """SSD chunked output is invariant to the chunk size (algebraic identity)."""
    import jax, jax.numpy as jnp
    from repro.configs import ARCHS
    from repro.models import layers as L
    cfg = ARCHS["zamba2-1.2b"].reduced()
    key = jax.random.PRNGKey(seed)
    p = L.mamba2_params(key, cfg)
    x = jax.random.normal(key, (1, S, cfg.d_model)) * 0.2
    y1, _ = L.mamba2_mixer(x, p, cfg, chunk=4)
    y2, _ = L.mamba2_mixer(x, p, cfg, chunk=S)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-3, atol=2e-4)
