"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest
import scipy.sparse.csgraph as csgraph

hypothesis = pytest.importorskip(
    "hypothesis", reason="property sweeps need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.algorithms import connected_components, max_vertex, sssp
from repro.core import meta_diameter
from repro.gofs.formats import PAD, Graph, partition_graph
from repro.gofs.generators import random_graph
from repro.gofs.partition import bfs_grow_partition, hash_partition


def _pg(n, deg, parts, seed, partitioner=hash_partition, weighted=False):
    g = random_graph(n, avg_degree=deg, seed=seed, weighted=weighted)
    return g, partition_graph(g, partitioner(g, parts, seed=seed), parts)


def _gather(pg, per_part):
    out = np.zeros(pg.n_global, per_part.dtype)
    for p in range(pg.num_parts):
        m = pg.vmask[p]
        out[pg.global_id[p][m]] = per_part[p][m]
    return out


@settings(max_examples=15, deadline=None)
@given(st.integers(10, 120), st.floats(1.0, 5.0), st.integers(2, 6),
       st.integers(0, 10_000))
def test_cc_count_invariant(n, deg, parts, seed):
    """#components from the engine == scipy, for any graph/partitioning."""
    g, pg = _pg(n, deg, parts, seed)
    ncc_true, _ = csgraph.connected_components(g.undirected_csr(), directed=False)
    _, ncc, _ = connected_components(pg, mode="subgraph")
    assert ncc == ncc_true


@settings(max_examples=15, deadline=None)
@given(st.integers(10, 100), st.integers(2, 5), st.integers(0, 10_000))
def test_sssp_equals_scipy(n, parts, seed):
    g, pg = _pg(n, 3.0, parts, seed, weighted=True)
    d_true = csgraph.shortest_path(g.csr().T, indices=[0])[0]
    dist, _ = sssp(pg, 0, mode="subgraph")
    ours = _gather(pg, dist)
    finite = np.isfinite(d_true)
    assert np.array_equal(np.isfinite(ours), finite)
    np.testing.assert_allclose(ours[finite], d_true[finite], rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(10, 80), st.integers(2, 4), st.integers(0, 10_000))
def test_subgraph_never_more_supersteps_than_vertex(n, parts, seed):
    """Paper §3.3: worst case the sub-graph model degenerates to vertex
    centric — it can never take MORE supersteps."""
    _, pg = _pg(n, 2.5, parts, seed)
    _, _, t_sub = connected_components(pg, mode="subgraph")
    _, _, t_vert = connected_components(pg, mode="vertex")
    assert t_sub.supersteps <= t_vert.supersteps


@settings(max_examples=10, deadline=None)
@given(st.integers(12, 80), st.integers(2, 4), st.integers(0, 10_000))
def test_max_vertex_is_global_max_per_component(n, parts, seed):
    g, pg = _pg(n, 3.0, parts, seed)
    x, _ = max_vertex(pg, mode="subgraph")
    vals = _gather(pg, x)
    _, lab = csgraph.connected_components(g.undirected_csr(), directed=False)
    for c in np.unique(lab):
        comp = np.flatnonzero(lab == c)
        assert np.all(vals[comp] == comp.max())


@settings(max_examples=10, deadline=None)
@given(st.integers(10, 80), st.integers(2, 4), st.integers(0, 10_000))
def test_supersteps_bounded_by_meta_diameter(n, parts, seed):
    _, pg = _pg(n, 2.5, parts, seed)
    _, _, tele = connected_components(pg, mode="subgraph")
    dm = meta_diameter(pg, sample=128)
    assert tele.supersteps <= dm + 3


@settings(max_examples=10, deadline=None)
@given(st.integers(16, 64), st.integers(2, 4), st.integers(0, 2**16))
def test_partitioners_cover_all_vertices(n, parts, seed):
    g = random_graph(n, avg_degree=3.0, seed=seed)
    for fn in (hash_partition, bfs_grow_partition):
        a = fn(g, parts, seed=seed)
        assert a.shape == (n,)
        assert a.min() >= 0 and a.max() < parts


@settings(max_examples=8, deadline=None)
@given(st.integers(150, 500), st.integers(2, 5), st.integers(0, 10_000),
       st.integers(1, 5),
       st.sampled_from(["min_plus", "max_first", "plus_times"]))
def test_binned_multi_sweep_matches_ref_on_powerlaw(n, parts, seed, Q,
                                                    semiring):
    """The serving hot path (two-bin multi-vector ELL sweep) against the
    scalar oracle, on graphs with guaranteed mega-hub rows (star + ring,
    powerlaw-extreme) so the hub bin is actually exercised."""
    import jax.numpy as jnp
    from repro.core import graph_block
    from repro.kernels import ops
    star_dst = np.arange(1, 1 + n // 2)
    src = np.concatenate([np.zeros(star_dst.size, np.int64),
                          np.arange(n - 1)])
    dst = np.concatenate([star_dst, np.arange(1, n)])
    g = Graph.from_edges(n, src, dst, directed=False)
    pg = partition_graph(g, hash_partition(g, parts, seed=seed), parts)
    gb = graph_block(pg)
    assert (np.asarray(gb["adj_hub_idx"]) != PAD).any(), \
        "star fixture must produce hub rows"
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(0.0, 5.0, (pg.v_max, Q)).astype(np.float32))
    from repro.kernels import semiring_spmv_ref
    for p in range(pg.num_parts):
        got = ops.binned_ell_spmv_multi(
            x, gb["nbr_lo"][p], gb["wgt_lo"][p], gb["adj_hub_idx"][p],
            gb["adj_hub_nbr"][p], gb["adj_hub_wgt"][p], semiring)
        for q in range(Q):
            ref = semiring_spmv_ref(x[:, q], gb["nbr"][p], gb["wgt"][p],
                                    semiring)
            if semiring == "plus_times":   # ⊕=+ reassociates across bins
                np.testing.assert_allclose(np.asarray(got[:, q]),
                                           np.asarray(ref), rtol=1e-5,
                                           atol=1e-6)
            else:                          # idempotent ⊕: exact
                assert np.array_equal(np.asarray(got[:, q]), np.asarray(ref))


@settings(max_examples=10, deadline=None)
@given(st.integers(30, 150), st.integers(2, 5), st.integers(0, 10_000))
def test_pagerank_dangling_mass_sums_to_one(n, parts, seed):
    """PageRank must conserve rank mass on graphs with sinks: dangling
    vertices redistribute through the teleport distribution, so ranks sum
    to 1 (the old code dropped their mass every iteration)."""
    from repro.algorithms import pagerank
    rng = np.random.default_rng(seed)
    ne = max(4, 3 * n)
    sinks = max(2, n // 8)                 # vertices [0, sinks) never source
    src = rng.integers(sinks, n, ne)
    dst = rng.integers(0, n, ne)           # ...but do receive mass
    keep = src != dst
    g = Graph.from_edges(n, src[keep], dst[keep], directed=True)
    assert (g.out_degree == 0).any(), "fixture needs dangling vertices"
    pg = partition_graph(g, hash_partition(g, parts, seed=seed), parts)
    r, _ = pagerank(pg, num_iters=40)
    total = _gather(pg, r).sum()
    np.testing.assert_allclose(total, 1.0, rtol=1e-4)


@settings(max_examples=8, deadline=None)
@given(st.integers(16, 64), st.integers(0, 2**16))
def test_mamba2_vs_mamba1_style_recurrence(S, seed):
    """SSD chunked output is invariant to the chunk size (algebraic identity)."""
    import jax, jax.numpy as jnp
    from repro.configs import ARCHS
    from repro.models import layers as L
    cfg = ARCHS["zamba2-1.2b"].reduced()
    key = jax.random.PRNGKey(seed)
    p = L.mamba2_params(key, cfg)
    x = jax.random.normal(key, (1, S, cfg.d_model)) * 0.2
    y1, _ = L.mamba2_mixer(x, p, cfg, chunk=4)
    y2, _ = L.mamba2_mixer(x, p, cfg, chunk=S)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-3, atol=2e-4)


@settings(max_examples=12, deadline=None)
@given(st.integers(20, 90), st.floats(1.5, 4.0), st.integers(2, 5),
       st.integers(0, 10_000), st.integers(1, 25), st.integers(0, 12))
def test_random_delta_patched_block_parity(n, deg, parts, seed, n_ins, n_rm):
    """Gopher Wire/Mesh/Phases/Hot: any random delta batch over any random
    graph — the compacted, tiered, auto (which resolves to the fused
    megastep route on local), PHASED and resident-megastep exchanges on
    the zero-repack-patched block give bit-identical SSSP/CC results to
    the dense exchange on a cold-packed block of the same graph version
    (tiered may route through its dense fallback — and phased through its
    per-superstep dense retry — when the delta overflows a tier; the
    resident narrow-phase schedule relaxes chaotically but converges to
    the same ⊕-fixpoint; the result contract is unconditional)."""
    from repro.core import (GopherEngine, PhasedTierPlan, SemiringProgram,
                            TierPlan, device_block, host_graph_block,
                            init_max_vertex, make_sssp_init,
                            update_changed_profile)
    from repro.gofs import EdgeDelta, apply_delta
    rng = np.random.default_rng(seed)
    g = random_graph(n, avg_degree=deg, seed=seed, weighted=True)
    pg0 = partition_graph(g, hash_partition(g, parts, seed=seed), parts)
    iu = rng.integers(0, n, n_ins)
    iv = rng.integers(0, n, n_ins)
    keep = iu != iv
    # removals sampled from existing edges (misses are exercised too)
    a = g.csr().tocoo()
    if a.nnz and n_rm:
        pick = rng.integers(0, a.nnz, n_rm)
        rs, rd = a.col[pick], a.row[pick]
    else:
        rs = rd = np.zeros(0, np.int64)
    # validate_delta rejects a contradictory batch (same undirected edge both
    # inserted and removed), so generate a well-formed net batch
    keep &= ~np.isin(np.minimum(iu, iv) * n + np.maximum(iu, iv),
                     np.minimum(rs, rd) * n + np.maximum(rs, rd))
    delta = EdgeDelta.of(
        insert_src=iu[keep], insert_dst=iv[keep],
        insert_wgt=rng.uniform(0.1, 5.0, int(keep.sum())).astype(np.float32),
        remove_src=rs, remove_dst=rd)
    hb = host_graph_block(pg0)
    # teach the changed-histogram EWMA with an arbitrary contraction so the
    # phased mode exercises real multi-phase segmentation, not just the
    # single-phase degenerate case
    update_changed_profile(hb, [8 * n, n, max(n // 8, 1), 0])
    res = apply_delta(pg0, delta, directed=False, block=hb)
    pg1 = res.pg
    cold = host_graph_block(pg1)
    gb_patched = device_block(res.block)
    for sr, init in [("max_first", init_max_vertex),
                     ("min_plus", make_sssp_init(int(pg1.part_of[0]),
                                                 int(pg1.local_of[0])))]:
        prog = SemiringProgram(semiring=sr, init_fn=init)
        s_ref, _ = GopherEngine(pg1, prog, gb=device_block(cold),
                                exchange="dense").run()
        for mode in ("compact", "tiered", "auto", "phased", "megastep"):
            # a PhasedTierPlan on the megastep route gates the resident
            # narrow-phase schedule (auto already covers the plain fused BSP)
            plan = (TierPlan.from_block(res.block) if mode == "tiered"
                    else PhasedTierPlan.from_block(res.block)
                    if mode in ("phased", "megastep") else None)
            s_new, _ = GopherEngine(pg1, prog, gb=gb_patched, exchange=mode,
                                    tier_plan=plan).run()
            assert np.array_equal(np.asarray(s_ref["x"]),
                                  np.asarray(s_new["x"])), (sr, mode)
