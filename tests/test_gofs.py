"""GoFS store, partitioners, formats, sub-graph discovery."""
import numpy as np
import scipy.sparse as sp
import scipy.sparse.csgraph as csgraph

from repro.gofs import (GoFSStore, bfs_grow_partition, hash_partition,
                        powerlaw_social, road_grid, subgraph_balanced_partition,
                        trace_star)
from repro.gofs.formats import PAD, Graph, ell_from_csr, partition_graph
from repro.gofs.partition import partition_quality
from repro.core.subgraph import meta_graph, subgraph_sizes


def test_ell_pack_roundtrip():
    indptr = np.array([0, 2, 2, 5])
    indices = np.array([1, 2, 0, 1, 2], np.int32)
    w = np.arange(5, dtype=np.float32)
    nbr, wgt = ell_from_csr(indptr, indices, w, 3, lane_pad=4)
    assert nbr.shape == (3, 4)
    assert list(nbr[0]) == [1, 2, PAD, PAD]
    assert list(nbr[1]) == [PAD] * 4
    assert list(nbr[2, :3]) == [0, 1, 2]
    np.testing.assert_allclose(wgt[2, :3], [2, 3, 4])


def test_from_edges_duplicate_min_policy():
    """Regression: duplicate (src, dst) pairs must collapse to the MIN
    weight on BOTH build paths. The directed path used to silently SUM
    duplicates through the CSR constructor (corrupting SSSP distances); the
    undirected path kept an arbitrary first occurrence."""
    g = Graph.from_edges(4, [0, 0, 0], [1, 1, 1], [3.0, 1.0, 2.0],
                         directed=True)
    assert g.nnz == 1
    assert g.csr()[1, 0] == 1.0          # min, not 6.0 (sum) or 3.0 (first)
    assert g.out_degree[0] == 1          # dedup counted once, not thrice

    gu = Graph.from_edges(4, [0, 2, 0], [2, 0, 2], [5.0, 1.5, 3.0],
                          directed=False)
    au = gu.csr()
    assert au[2, 0] == 1.5 and au[0, 2] == 1.5
    assert gu.out_degree[0] == 1 and gu.out_degree[2] == 1

    # end-to-end: the duplicate must not corrupt shortest paths
    from repro.algorithms import sssp
    g2 = Graph.from_edges(3, [0, 0, 1], [1, 1, 2], [2.0, 5.0, 1.0],
                          directed=True)
    pg = partition_graph(g2, np.zeros(3, np.int32), 1)
    dist, _ = sssp(pg, 0)
    assert dist[0, int(pg.local_of[1])] == 2.0
    assert dist[0, int(pg.local_of[2])] == 3.0


def test_partition_graph_edge_conservation():
    g = road_grid(12, 12, drop_frac=0.1, seed=0)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    local = int((pg.nbr != PAD).sum())
    cut = pg.edge_cut()
    assert local + cut == g.nnz  # every directed in-edge is local XOR remote


def test_subgraph_discovery_matches_scipy_per_partition():
    g = powerlaw_social(200, m=3, seed=1)
    assign = hash_partition(g, 4, seed=0)
    pg = partition_graph(g, assign, 4)
    for p in range(4):
        m = pg.vmask[p]
        c = int(m.sum())
        if c == 0:
            continue
        # rebuild local adjacency from ELL
        rows, cols = [], []
        for v in range(c):
            for u in pg.nbr[p, v]:
                if u != PAD:
                    rows.append(v)
                    cols.append(u)
        a = sp.csr_matrix((np.ones(len(rows)), (rows, cols)), shape=(c, c))
        ncc, lab = csgraph.connected_components(a + a.T, directed=False)
        assert ncc == pg.num_subgraphs[p]
        # label partitions agree
        ours = pg.sg_id[p][:c]
        for l in range(ncc):
            assert len(np.unique(ours[lab == l])) == 1


def test_mailbox_slots_unique():
    """Routing plan: (dst_part, slot) unique per source partition — no
    mailbox collisions."""
    g = trace_star(300, n_hubs=4, seed=2)
    pg = partition_graph(g, hash_partition(g, 5, seed=1), 5)
    for p in range(5):
        m = pg.re_src[p] != PAD
        key = pg.re_dst_part[p][m] * pg.mailbox_cap + pg.re_slot[p][m]
        assert len(np.unique(key)) == m.sum()
        assert pg.re_slot[p][m].max(initial=0) < pg.mailbox_cap


def test_partitioner_quality_ordering():
    """BFS-grow should cut fewer edges than random hashing on a road grid."""
    g = road_grid(20, 20, drop_frac=0.02, seed=3)
    qh = partition_quality(g, hash_partition(g, 4, seed=0), 4)
    qb = partition_quality(g, bfs_grow_partition(g, 4, seed=0), 4)
    assert qb["edge_cut"] < qh["edge_cut"]


def test_subgraph_balanced_partitioner_balances():
    """Paper §7 fix: balanced partitioner evens out sub-graph counts/sizes."""
    g = road_grid(16, 16, drop_frac=0.25, seed=4)  # many components
    P = 4
    pg_b = partition_graph(g, subgraph_balanced_partition(g, P, seed=0), P)
    sizes_b = [s.max() if len(s) else 0 for s in subgraph_sizes(pg_b)]
    pg_h = partition_graph(g, hash_partition(g, P, seed=0), P)
    # balanced: vertex counts even
    cb = pg_b.vmask.sum(1)
    assert cb.max() - cb.min() <= max(2, int(0.2 * cb.mean()))
    # and the largest sub-graph per partition is no worse than hash's worst
    sizes_h = [s.max() if len(s) else 0 for s in subgraph_sizes(pg_h)]
    assert max(sizes_b) <= max(max(sizes_h), int(np.ceil(g.n / P)))


def test_store_roundtrip(tmp_path):
    g = road_grid(10, 10, seed=5)
    g.attrs["color"] = np.arange(g.n).astype(np.float32)
    st_ = GoFSStore(str(tmp_path))
    pg = st_.build("g", g, bfs_grow_partition(g, 3, seed=0), 3)
    pg2 = st_.load_partitioned("g", attrs=["color"])
    for k in ["nbr", "wgt", "vmask", "sg_id", "re_src", "re_dst_part",
              "re_dst_local", "re_slot", "global_id", "out_degree"]:
        assert np.array_equal(getattr(pg, k), getattr(pg2, k)), k
    assert np.array_equal(pg.attrs["color"], pg2.attrs["color"])
    assert pg2.mailbox_cap == pg.mailbox_cap
    # partial load: topology only (paper's per-attribute slice point)
    part0 = st_.load_partition("g", 0)
    assert "nbr" in part0 and "attr_color" not in part0


def test_meta_graph_structure():
    g = road_grid(10, 10, drop_frac=0.0, seed=6)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    num_meta, adj, meta_of = meta_graph(pg)
    assert num_meta == int(pg.num_subgraphs.sum())
    assert adj.shape == (num_meta, num_meta)
    # every valid vertex maps to a meta node
    assert (meta_of[pg.vmask] >= 0).all()


def test_store_attribute_subset_lazy_load(tmp_path, monkeypatch):
    """Paper's per-attribute slice point, enforced at the file level: loading
    one of two attributes must never OPEN the other attribute's slice file."""
    g = road_grid(8, 8, seed=7)
    g.attrs["color"] = np.arange(g.n).astype(np.float32)
    g.attrs["heat"] = np.linspace(0, 1, g.n).astype(np.float32)
    st_ = GoFSStore(str(tmp_path))
    pg = st_.build("g", g, bfs_grow_partition(g, 2, seed=0), 2)

    opened = []
    real_load = np.load

    def spy_load(path, *a, **kw):
        opened.append(str(path))
        return real_load(path, *a, **kw)

    monkeypatch.setattr(np, "load", spy_load)
    part = st_.load_partition("g", 0, attrs=["color"])
    assert "attr_color" in part and "attr_heat" not in part
    np.testing.assert_array_equal(part["attr_color"],
                                  pg.attrs["color"][0])
    assert any(p.endswith("attr_color.npz") for p in opened)
    assert not any("attr_heat" in p for p in opened)
