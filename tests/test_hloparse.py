"""Scan-aware HLO analyzer: trip-count multipliers must make scanned and
unrolled modules agree; collective parsing must find psums."""
import jax
import jax.numpy as jnp

from repro.launch.hloparse import analyze_text


def _body(x, w):
    return jnp.tanh(x @ w), None


def test_scan_equals_unroll_flops():
    xs = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)

    def scanned(x, ws):
        x, _ = jax.lax.scan(_body, x, ws)
        return x

    def unrolled(x, ws):
        for i in range(8):
            x, _ = _body(x, ws[i])
        return x

    fs = analyze_text(jax.jit(scanned).lower(xs, ws).compile().as_text())
    fu = analyze_text(jax.jit(unrolled).lower(xs, ws).compile().as_text())
    expect = 8 * 2 * 64 * 256 * 256
    assert fs["flops"] == expect
    assert fu["flops"] == expect
    # hbm same order of magnitude (scan counts streamed xs slices; unroll
    # counts whole-array reads at each static slice)
    assert 0.1 < fs["hbm"] / fu["hbm"] < 3.0


def test_nested_scan_multipliers():
    def inner(x, w):
        x, _ = jax.lax.scan(_body, x, w)
        return x

    def outer(x, ws):
        def ob(x, w3):
            return inner(x, w3), None
        x, _ = jax.lax.scan(ob, x, ws)
        return x

    xs = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 5, 64, 64), jnp.float32)
    r = analyze_text(jax.jit(outer).lower(xs, ws).compile().as_text())
    assert r["flops"] == 3 * 5 * 2 * 16 * 64 * 64


def test_collectives_parsed_with_trip_count():
    if len(jax.devices()) < 2:
        # single-device CI: the psum lowers away; just check no crash
        def f(x):
            return jnp.sum(x * x)
        r = analyze_text(jax.jit(f).lower(
            jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile().as_text())
        assert r["coll_bytes_total"] >= 0
        return


# ---------------- collective trace (Gopher Sentinel cross-check) ----------------

# Hand-written module: a while loop (trip count 5) whose body issues a
# collective-permute, an all-to-all and an all-reduce — the three opcodes the
# tiered/phased exchange lowers to. Deterministic on any device count.
_COLLECTIVE_HLO = """\
HloModule sentinel_fixture

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add = f32[] add(f32[] %a, f32[] %b)
}

%cond (pc: (s32[], f32[4,8])) -> pred[] {
  %pc = (s32[], f32[4,8]) parameter(0)
  %ic = s32[] get-tuple-element((s32[], f32[4,8]) %pc), index=0
  %c5 = s32[] constant(5)
  ROOT %lt = pred[] compare(s32[] %ic, s32[] %c5), direction=LT
}

%body (pb: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %pb = (s32[], f32[4,8]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[4,8]) %pb), index=0
  %x = f32[4,8] get-tuple-element((s32[], f32[4,8]) %pb), index=1
  %c1 = s32[] constant(1)
  %ni = s32[] add(s32[] %i, s32[] %c1)
  %cp = f32[4,8] collective-permute(f32[4,8] %x), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
  %a2a = f32[4,8] all-to-all(f32[4,8] %cp), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[4,8] all-reduce(f32[4,8] %a2a), replica_groups={}, to_apply=%sum
  ROOT %t = (s32[], f32[4,8]) tuple(s32[] %ni, f32[4,8] %ar)
}

ENTRY %main (x: f32[4,8]) -> f32[4,8] {
  %px = f32[4,8] parameter(0)
  %c0 = s32[] constant(0)
  %init = (s32[], f32[4,8]) tuple(s32[] %c0, f32[4,8] %px)
  %w = (s32[], f32[4,8]) while((s32[], f32[4,8]) %init), condition=%cond, body=%body
  ROOT %out = f32[4,8] get-tuple-element((s32[], f32[4,8]) %w), index=1
}
"""


def test_collective_trace_permute_and_all_to_all():
    from repro.launch.hloparse import collective_report, collective_trace
    trace = collective_trace(_COLLECTIVE_HLO)
    by_kind = {c.kind: c for c in trace}
    assert set(by_kind) == {"collective-permute", "all-to-all", "all-reduce"}
    cp = by_kind["collective-permute"]
    # permutation table parsed, trip-count multiplier applied
    assert cp.source_target_pairs == ((0, 1), (1, 2), (2, 3), (3, 0))
    assert cp.mult == 5 and cp.result_bytes == 4 * 8 * 4
    assert cp.total_bytes == 5 * 128
    assert by_kind["all-to-all"].replica_groups == "{{0,1,2,3}}"
    rep = collective_report(_COLLECTIVE_HLO)
    assert rep["collective-permute"]["count"] == 5
    assert rep["collective-permute"]["bytes"] == 5 * 128
    assert rep["all-to-all"]["bytes"] == 5 * 128
    assert rep["all-reduce"]["count"] == 5


def test_collective_trace_async_counted_once():
    from repro.launch.hloparse import collective_trace
    text = """\
HloModule async_fixture

ENTRY %main (x: f32[8]) -> f32[8] {
  %px = f32[8] parameter(0)
  %cps = (f32[8], f32[8]) collective-permute-start(f32[8] %px), source_target_pairs={{0,1},{1,0}}
  ROOT %cpd = f32[8] collective-permute-done((f32[8], f32[8]) %cps)
}
"""
    trace = collective_trace(text)
    # the -start/-done pair is one logical collective, attrs live on -start
    assert len(trace) == 1
    assert trace[0].kind == "collective-permute"
    assert trace[0].source_target_pairs == ((0, 1), (1, 0))
