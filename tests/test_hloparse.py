"""Scan-aware HLO analyzer: trip-count multipliers must make scanned and
unrolled modules agree; collective parsing must find psums."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.launch.hloparse import analyze_text


def _body(x, w):
    return jnp.tanh(x @ w), None


def test_scan_equals_unroll_flops():
    xs = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)

    def scanned(x, ws):
        x, _ = jax.lax.scan(_body, x, ws)
        return x

    def unrolled(x, ws):
        for i in range(8):
            x, _ = _body(x, ws[i])
        return x

    fs = analyze_text(jax.jit(scanned).lower(xs, ws).compile().as_text())
    fu = analyze_text(jax.jit(unrolled).lower(xs, ws).compile().as_text())
    expect = 8 * 2 * 64 * 256 * 256
    assert fs["flops"] == expect
    assert fu["flops"] == expect
    # hbm same order of magnitude (scan counts streamed xs slices; unroll
    # counts whole-array reads at each static slice)
    assert 0.1 < fs["hbm"] / fu["hbm"] < 3.0


def test_nested_scan_multipliers():
    def inner(x, w):
        x, _ = jax.lax.scan(_body, x, w)
        return x

    def outer(x, ws):
        def ob(x, w3):
            return inner(x, w3), None
        x, _ = jax.lax.scan(ob, x, ws)
        return x

    xs = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 5, 64, 64), jnp.float32)
    r = analyze_text(jax.jit(outer).lower(xs, ws).compile().as_text())
    assert r["flops"] == 3 * 5 * 2 * 16 * 64 * 64


def test_collectives_parsed_with_trip_count():
    import os
    if len(jax.devices()) < 2:
        # single-device CI: the psum lowers away; just check no crash
        def f(x):
            return jnp.sum(x * x)
        r = analyze_text(jax.jit(f).lower(
            jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile().as_text())
        assert r["coll_bytes_total"] >= 0
        return
