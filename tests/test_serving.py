"""Gopher Serve: batched query execution must EXACTLY reproduce per-query
sequential results (both backends), and the planner/cache/service layers
must behave as specified."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.algorithms import bfs as bfs_single
from repro.algorithms import sssp as sssp_single
from repro.core import GopherEngine, PageRankProgram, compat
from repro.core import messages as msg
from repro.core.engine import graph_block
from repro.gofs import bfs_grow_partition, powerlaw_social, road_grid
from repro.gofs.formats import PAD, partition_graph
from repro.kernels import ops
from repro.serving import (BatchedPersonalizedPageRank, BatchedSemiringProgram,
                           GraphQueryService, LandmarkCache, Query,
                           ResultCache, bucket_size, gather_query_results,
                           plan, ppr_query_seed, reachability_query_init,
                           sssp_query_init)


def _gather1(pg, per_part):
    out = np.zeros(pg.n_global, per_part.dtype)
    for p in range(pg.num_parts):
        m = pg.vmask[p]
        out[pg.global_id[p][m]] = per_part[p][m]
    return out


@pytest.fixture(scope="module")
def social_pg():
    g = powerlaw_social(600, m=4, seed=2)
    return partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)


@pytest.fixture(scope="module")
def road_pg():
    g = road_grid(14, 14, drop_frac=0.05, seed=1)  # unit weights -> BFS-able
    return partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)


SOURCES = [0, 7, 113, 200, 341]


# ---------------- batched == sequential, both backends ----------------

@pytest.mark.parametrize("backend", ["local", "shard_map"])
def test_batched_sssp_matches_sequential(social_pg, backend):
    pg = social_pg
    mesh = compat.make_mesh((1,), ("parts",)) if backend == "shard_map" else None
    prog = BatchedSemiringProgram(semiring="min_plus",
                                  num_queries=len(SOURCES))
    eng = GopherEngine(pg, prog, backend=backend, mesh=mesh)
    state, tele = eng.run_queries(
        extra={"qinit": sssp_query_init(pg, SOURCES)})
    batched = gather_query_results(pg, state["x"])
    assert tele.query_supersteps is not None
    for q, s in enumerate(SOURCES):
        d_ref, t_ref = sssp_single(pg, s, backend=backend, mesh=mesh)
        ref = _gather1(pg, d_ref)
        ref[~np.isfinite(ref)] = np.inf
        got = batched[q]
        assert np.array_equal(got, ref), f"query {q} (source {s}) mismatch"
        # a query's own convergence point never exceeds the batch's
        assert tele.query_supersteps[q] <= tele.supersteps


@pytest.mark.parametrize("backend", ["local", "shard_map"])
def test_batched_bfs_matches_sequential(road_pg, backend):
    pg = road_pg
    mesh = compat.make_mesh((1,), ("parts",)) if backend == "shard_map" else None
    srcs = [0, 5, 60, 120]
    prog = BatchedSemiringProgram(semiring="min_plus", num_queries=len(srcs))
    eng = GopherEngine(pg, prog, backend=backend, mesh=mesh)
    state, _ = eng.run_queries(extra={"qinit": sssp_query_init(pg, srcs)})
    batched = gather_query_results(pg, state["x"])
    for q, s in enumerate(srcs):
        lvl, _ = bfs_single(pg, s, backend=backend, mesh=mesh)
        assert np.array_equal(batched[q], _gather1(pg, lvl))


@pytest.mark.parametrize("backend", ["local", "shard_map"])
def test_batched_ppr_matches_sequential(social_pg, backend):
    """Batched personalized PageRank vs the scalar program with a one-hot
    teleport — same math, same iteration count, per query."""
    pg = social_pg
    mesh = compat.make_mesh((1,), ("parts",)) if backend == "shard_map" else None
    srcs = [3, 77, 240]
    iters = 15
    bp = BatchedPersonalizedPageRank(n_global=pg.n_global,
                                     num_queries=len(srcs), num_iters=iters)
    eng = GopherEngine(pg, bp, backend=backend, mesh=mesh, max_supersteps=64)
    state, tele = eng.run_queries(extra={"qseed": ppr_query_seed(pg, srcs)})
    batched = gather_query_results(pg, state["r"])
    assert tele.supersteps == iters
    for q, s in enumerate(srcs):
        seed = jnp.asarray(ppr_query_seed(pg, [s])[:, :, 0])
        prog = PageRankProgram(
            n_global=pg.n_global, num_iters=iters,
            init_fn=lambda gb: seed[gb["part_index"]],
            teleport_fn=lambda gb: seed[gb["part_index"]])
        st, _ = GopherEngine(pg, prog, backend=backend, mesh=mesh,
                             max_supersteps=64).run()
        np.testing.assert_allclose(batched[q], _gather1(pg, st["r"]),
                                   rtol=1e-6, atol=1e-9)


def test_multi_seed_reachability_is_min_over_bfs(road_pg):
    pg = road_pg
    seeds = (0, 77, 150)
    prog = BatchedSemiringProgram(semiring="min_plus", num_queries=1)
    eng = GopherEngine(pg, prog)
    state, _ = eng.run_queries(
        extra={"qinit": reachability_query_init(pg, [seeds])})
    got = gather_query_results(pg, state["x"])[0]
    refs = np.stack([_gather1(pg, bfs_single(pg, s)[0]) for s in seeds])
    assert np.array_equal(got, refs.min(0))


# ---------------- gather-form mailbox vs scatter oracle ----------------

def test_gather_mailbox_matches_scatter_oracle(social_pg):
    pg = social_pg
    gb = graph_block(pg)
    rng = np.random.default_rng(0)
    p = 1
    vals = jnp.asarray(rng.random(pg.r_max).astype(np.float32))
    send = jnp.asarray(rng.random(pg.r_max) < 0.6)
    ov_ref, oi_ref = msg.build_outbox(
        vals, gb["re_src"][p], gb["re_dst_part"][p], gb["re_dst_local"][p],
        gb["re_slot"][p], send & (gb["re_src"][p] != PAD),
        num_parts=pg.num_parts, cap=pg.mailbox_cap, combine="min")
    ov = msg.build_outbox_gather(vals, send, gb["ob_inv"][p],
                                 num_parts=pg.num_parts, cap=pg.mailbox_cap,
                                 combine="min")
    assert np.array_equal(np.asarray(ov), np.asarray(ov_ref))
    # inbox side: deliver partition p's outbox row d to destination d and
    # compare the gather combine against the segment-combine oracle
    for d in range(pg.num_parts):
        iv = jnp.full((pg.num_parts, pg.mailbox_cap), jnp.inf)
        iv = iv.at[p].set(ov_ref[d])
        ii = jnp.full((pg.num_parts, pg.mailbox_cap), PAD, jnp.int32)
        ii = ii.at[p].set(oi_ref[d])
        inbox_ref = msg.combine_inbox(iv, ii, v_max=pg.v_max, combine="min")
        inbox = msg.combine_inbox_gather(iv, gb["ib_lo"][d],
                                         gb["ib_hub_idx"][d], gb["ib_hub"][d],
                                         v_max=pg.v_max, combine="min")
        assert np.array_equal(np.asarray(inbox), np.asarray(inbox_ref))


@pytest.mark.parametrize("semiring", ["min_plus", "max_first", "plus_times"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_binned_sweep_matches_ref_on_hub_rows(semiring, seed):
    """The serving hot path vs the scalar oracle on graphs with GUARANTEED
    mega-hub rows (a star wired into a ring, powerlaw-extreme), so the hub
    bin is actually exercised — exact for idempotent ⊕, allclose for the
    reassociated sum.

    Deterministic twin of test_property.py::
    test_binned_multi_sweep_matches_ref_on_powerlaw — that module skips
    entirely when hypothesis isn't installed, so this copy keeps the hot
    path under oracle coverage in minimal environments."""
    from repro.gofs import hash_partition
    from repro.gofs.formats import Graph
    from repro.kernels import semiring_spmv_ref
    n = 400
    star_dst = np.arange(1, 1 + n // 2)
    src = np.concatenate([np.zeros(star_dst.size, np.int64),
                          np.arange(n - 1)])
    dst = np.concatenate([star_dst, np.arange(1, n)])
    g = Graph.from_edges(n, src, dst, directed=False)
    pg = partition_graph(g, hash_partition(g, 4, seed=seed), 4)
    gb = graph_block(pg)
    assert (np.asarray(gb["adj_hub_idx"]) != PAD).any(), \
        "star fixture must produce hub rows"
    rng = np.random.default_rng(seed)
    Q = 4
    x = jnp.asarray(rng.uniform(0.0, 5.0, (pg.v_max, Q)).astype(np.float32))
    for p in range(pg.num_parts):
        got = ops.binned_ell_spmv_multi(
            x, gb["nbr_lo"][p], gb["wgt_lo"][p], gb["adj_hub_idx"][p],
            gb["adj_hub_nbr"][p], gb["adj_hub_wgt"][p], semiring)
        for q in range(Q):
            ref = semiring_spmv_ref(x[:, q], gb["nbr"][p], gb["wgt"][p],
                                    semiring)
            if semiring == "plus_times":
                np.testing.assert_allclose(np.asarray(got[:, q]),
                                           np.asarray(ref), rtol=1e-5,
                                           atol=1e-6)
            else:
                assert np.array_equal(np.asarray(got[:, q]), np.asarray(ref))


@pytest.mark.parametrize("semiring", ["min_plus", "max_first", "plus_times"])
def test_binned_sweep_matches_ell(social_pg, semiring):
    pg = social_pg
    gb = graph_block(pg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.random((pg.v_max, 3)).astype(np.float32))
    for p in range(pg.num_parts):
        got = ops.binned_ell_spmv_multi(
            x, gb["nbr_lo"][p], gb["wgt_lo"][p], gb["adj_hub_idx"][p],
            gb["adj_hub_nbr"][p], gb["adj_hub_wgt"][p], semiring)
        for q in range(3):
            ref = ops.semiring_spmv(x[:, q], gb["nbr"][p], gb["wgt"][p],
                                    semiring, backend="jnp")
            np.testing.assert_allclose(np.asarray(got[:, q]), np.asarray(ref),
                                       rtol=1e-6, atol=0)


# ---------------- planner ----------------

def test_bucket_sizes():
    assert [bucket_size(n) for n in (1, 2, 3, 5, 9, 33)] == [1, 2, 4, 8, 16, 64]
    assert bucket_size(100, max_batch=64) == 64


def test_planner_groups_and_rejects():
    graphs = {"g": 100, "h": 50}
    qs = [Query.make("sssp", "g", 1), Query.make("sssp", "g", 2),
          Query.make("bfs", "g", 3), Query.make("reach", "g", (4, 5)),
          Query.make("ppr", "h", 6), Query.make("sssp", "MISSING", 0),
          Query.make("sssp", "g", 999), Query.make("unknown", "g", 1)]
    batches, rejected = plan(qs, graphs, max_batch=8)
    assert len(rejected) == 3
    keys = {(b.graph, b.family): len(b.queries) for b in batches}
    # sssp + bfs + reach are one min_plus program -> one traversal batch
    assert keys == {("g", "traversal"): 4, ("h", "ppr"): 1}
    for b in batches:
        assert b.padded_q == bucket_size(len(b.queries), 8)


def test_planner_splits_oversize_groups():
    graphs = {"g": 1000}
    qs = [Query.make("sssp", "g", i) for i in range(11)]
    batches, rejected = plan(qs, graphs, max_batch=4)
    assert not rejected
    assert [len(b.queries) for b in batches] == [4, 4, 3]
    assert [b.padded_q for b in batches] == [4, 4, 4]


# ---------------- caches ----------------

def test_result_cache_lru():
    c = ResultCache(capacity=2)
    c.put("a", np.zeros(1))
    c.put("b", np.ones(1))
    assert c.get("a") is not None          # refresh 'a'
    c.put("c", np.ones(1))                 # evicts 'b'
    assert c.get("b") is None
    assert c.get("a") is not None and c.get("c") is not None
    assert c.stats()["entries"] == 2


def test_landmark_cache_bounds(road_pg):
    pg = road_pg
    lc = LandmarkCache.build(pg, num_landmarks=6, strategy="degree")
    src = 30
    exact = _gather1(pg, sssp_single(pg, src)[0])
    upper = lc.approx_sssp(src)
    lower = lc.lower_bound_sssp(src)
    finite = np.isfinite(exact)
    assert np.all(upper[finite] >= exact[finite] - 1e-5)
    assert np.all(lower[finite] <= exact[finite] + 1e-5)
    # exact when the source IS a landmark
    lm = int(lc.landmarks[0])
    np.testing.assert_allclose(lc.approx_sssp(lm),
                               _gather1(pg, sssp_single(pg, lm)[0]),
                               atol=1e-5)


# ---------------- service ----------------

def test_service_end_to_end(social_pg, road_pg):
    svc = GraphQueryService({"social": social_pg, "road": road_pg},
                            max_batch=8)
    for s in (1, 50, 200):
        svc.submit("sssp", "social", s)
    svc.submit("bfs", "road", 0)
    svc.submit("reach", "road", (0, 100))
    svc.submit("ppr", "social", 9)
    out = svc.drain()
    assert len(out) == 6
    for resp in out.values():
        assert resp.error is None
        assert resp.result is not None
        assert resp.latency_s > 0
    d = next(r for r in out.values()
             if r.query.kind == "sssp" and r.query.sources == (50,))
    assert np.array_equal(d.result, _gather1(social_pg,
                                             sssp_single(social_pg, 50)[0]))
    # repeat -> exact-cache hit, no extra engine batch
    batches_before = svc.stats.batches
    again = svc.query("sssp", "social", 50)
    assert again.cached and svc.stats.batches == batches_before
    assert np.array_equal(again.result, d.result)
    # rejection paths: out-of-range source and unknown kind (the latter must
    # reject at admission, not crash the cache pass)
    bad = svc.query("sssp", "social", 10**6)
    assert bad.error is not None and bad.result is None
    bad2 = svc.query("walk", "social", 0)
    assert bad2.error is not None and "unknown query kind" in bad2.error
    # telemetry accumulated
    s = svc.stats.summary()
    assert s["served"] == 7 and s["cache_hits"] == 1 and s["qps"] > 0


def test_service_dedupes_identical_inflight(social_pg):
    svc = GraphQueryService({"social": social_pg}, max_batch=8)
    t1 = svc.submit("sssp", "social", 5)
    t2 = svc.submit("sssp", "social", 5)
    out = svc.drain()
    assert np.array_equal(out[t1].result, out[t2].result)
    assert svc.stats.batches == 1


def test_telemetry_hist_truncated(social_pg):
    """Regression: changed_hist must be cut to the realized superstep count,
    not the max_supersteps-length zero-padded buffer."""
    dist, tele = sssp_single(social_pg, 0)
    assert tele.changed_hist.shape == (tele.supersteps,)
    # every superstep but the final quiescence-confirming one saw changes
    assert np.all(tele.changed_hist[:-1] > 0)
