"""Gopher Shield tests: deterministic fault injection, checkpoint/replay
recovery, checksum fallback, mesh-shrink failover, serving degradation,
and the delta/block validation that guards the zero-repack path."""
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

try:
    from hypothesis import assume, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                             # CI installs it (dev reqs);
    HAVE_HYPOTHESIS = False                     # everything else still runs

import jax  # noqa: E402

from repro.core import (  # noqa: E402
    GopherEngine,
    SemiringProgram,
    compat,
    host_graph_block,
    init_max_vertex,
    make_sssp_init,
    verify_host_block,
)
from repro.gofs.formats import PAD, partition_graph  # noqa: E402
from repro.gofs.generators import random_graph, road_grid  # noqa: E402
from repro.gofs.partition import bfs_grow_partition  # noqa: E402
from repro.launch.elastic import rebalance_hint  # noqa: E402
from repro.resilience.balance import (  # noqa: E402
    BalancePolicy,
    apply_migration,
    migrate_and_resume,
    plan_migration,
    run_with_rebalance,
    to_global,
)
from repro.gofs.temporal import (  # noqa: E402
    DeltaValidationError,
    EdgeDelta,
    apply_delta,
    validate_delta,
)
from repro.resilience import (  # noqa: E402
    RecoveryExhausted,
    faults,
    run_with_recovery,
)
from repro.resilience.degrade import CircuitBreaker, backoff_delays  # noqa: E402
from repro.resilience.failover import _largest_divisor_at_most  # noqa: E402
from repro.serving.service import GraphQueryService  # noqa: E402
from repro.training.checkpoint import Checkpointer  # noqa: E402


def _pg(n=100, deg=4.0, parts=8, seed=3):
    g = random_graph(n, avg_degree=deg, seed=seed, weighted=True)
    return g, partition_graph(g, bfs_grow_partition(g, parts, seed=0), parts)


def _prog(algo, pg):
    if algo == "cc":
        return SemiringProgram(semiring="max_first", init_fn=init_max_vertex)
    return SemiringProgram(
        semiring="min_plus",
        init_fn=make_sssp_init(int(pg.part_of[0]), int(pg.local_of[0])))


def _eq(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return (len(la) == len(lb)
            and all(np.array_equal(np.asarray(x), np.asarray(y))
                    for x, y in zip(la, lb)))


# ------------------------------------------------------------ fault plans

def test_fault_plan_is_deterministic_and_replayable():
    spec = faults.FaultSpec("svc.query", "poisoned_query", prob=0.5, times=3)
    plan = faults.FaultPlan([spec], seed=11)

    def drive():
        hits = []
        for v in range(40):
            try:
                plan.fire("svc.query")
            except faults.PoisonedQueryFault as e:
                hits.append(e.visit)
        return hits

    first = drive()
    assert len(first) == 3                      # times= disarms the spec
    plan.reset()
    assert drive() == first                     # same seed -> same visits


def test_fault_plan_exact_visit_and_noop_when_unarmed():
    plan = faults.FaultPlan(
        [faults.FaultSpec("engine.superstep", "crash", at=2)])
    plan.fire("engine.superstep")               # visit 0
    plan.fire("engine.superstep")               # visit 1
    with pytest.raises(faults.CrashFault):
        plan.fire("engine.superstep")           # visit 2 fires
    plan.fire("engine.superstep")               # visit 3: shot already spent
    assert [f["visit"] for f in plan.fired] == [2]
    faults.fire("engine.superstep")             # no plan armed -> no-op


# --------------------------------------------- crash-at-any-superstep gate

_REF = {}


def _reference(algo):
    if algo not in _REF:
        _, pg = _pg()
        state, _ = GopherEngine(pg, _prog(algo, pg), backend="local",
                                exchange="dense").run()
        _REF[algo] = (pg, state)
    return _REF[algo]


def _crash_case(algo, mode, backend, k):
    """Kill the run at superstep k, restore from the last committed
    snapshot, finish — the final state must be bit-identical to the
    fault-free run (recovery replays megastep over its compact staged
    fallback)."""
    pg, ref = _reference(algo)
    kw = {}
    if backend == "shard_map":
        kw = dict(mesh=compat.make_mesh((1,), ("parts",)))
    eng = GopherEngine(pg, _prog(algo, pg), backend=backend, exchange=mode,
                       **kw)
    plan = faults.FaultPlan(
        [faults.FaultSpec("engine.superstep", "crash", at=k)])
    with tempfile.TemporaryDirectory() as d:
        with faults.inject(plan):
            state, tele, rep = run_with_recovery(eng, Checkpointer(d),
                                                 every=1)
    assert _eq(state, ref)
    # at= either fired (crash really happened, then recovered) or the run
    # finished before visit k — both end bit-identical
    assert rep.restarts == len(plan.fired)


@pytest.mark.parametrize("algo,mode,backend,k", [
    ("cc", "dense", "local", 0),
    ("cc", "compact", "shard_map", 2),
    ("cc", "megastep", "local", 1),
    ("sssp", "compact", "local", 3),
    ("sssp", "dense", "shard_map", 1),
    ("sssp", "megastep", "local", 4),
])
def test_crash_superstep_corners(algo, mode, backend, k):
    """Deterministic corners of the crash-at-any-superstep property —
    always runs, even without hypothesis installed."""
    _crash_case(algo, mode, backend, k)


@pytest.mark.skipif(not HAVE_HYPOTHESIS,
                    reason="property sweep needs hypothesis "
                           "(requirements-dev.txt)")
def test_crash_at_any_superstep_recovers_bit_identical():
    """Gopher Shield acceptance property: for ANY superstep k, exchange
    mode, backend, and idempotent-⊕ program, crash + recover ends
    bit-identical to the fault-free run."""

    @settings(max_examples=12, deadline=None)
    @given(algo=st.sampled_from(["cc", "sssp"]),
           mode=st.sampled_from(["dense", "compact", "megastep"]),
           backend=st.sampled_from(["local", "shard_map"]),
           k=st.integers(0, 5))
    def prop(algo, mode, backend, k):
        assume(not (mode == "megastep" and backend == "shard_map"))
        _crash_case(algo, mode, backend, k)

    prop()


def test_recovery_exhaustion_raises_with_report():
    _, pg = _pg(n=60, parts=4)
    eng = GopherEngine(pg, _prog("cc", pg), backend="local",
                       exchange="compact")
    plan = faults.FaultPlan(
        [faults.FaultSpec("engine.superstep", "crash", prob=1.0, times=99)])
    with tempfile.TemporaryDirectory() as d:
        with faults.inject(plan):
            with pytest.raises(RecoveryExhausted) as ei:
                run_with_recovery(eng, Checkpointer(d), every=1,
                                  max_restarts=2)
    rep = ei.value.report
    # max_restarts=2 -> 3 attempts, every one downed by an injected crash
    assert rep.attempts == 3 and rep.restarts == 3
    assert all(f["kind"] == "crash" for f in rep.faults)


# --------------------------------------------------- checksum fallback

def test_checkpoint_checksum_fallback_past_corrupt_snapshot():
    """Bit-rot in the newest snapshot: latest_good_step skips it and the
    resumed run still finishes bit-identical to the fault-free reference."""
    pg, ref = _reference("cc")
    prog = _prog("cc", pg)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        GopherEngine(pg, prog, backend="local", exchange="compact",
                     max_supersteps=3).run(checkpointer=ck,
                                           checkpoint_every=1)
        latest = ck.latest_step()
        with open(os.path.join(d, f"step_{latest}", "host_0.npz"),
                  "r+b") as f:
            f.seek(200)
            f.write(b"\xde\xad\xbe\xef")
        assert not ck.verify_step(latest)
        good = ck.latest_good_step()
        assert good is not None and good < latest
        state, _ = GopherEngine(pg, prog, backend="local",
                                exchange="compact").run(
            checkpointer=ck, checkpoint_every=1, resume=True)
    assert _eq(state, ref)


# --------------------------------------------------- mesh-shrink failover

def test_largest_divisor_clamp():
    assert _largest_divisor_at_most(8, 3) == 2
    assert _largest_divisor_at_most(8, 4) == 4
    assert _largest_divisor_at_most(12, 5) == 4
    assert _largest_divisor_at_most(7, 6) == 1


def test_failover_device_loss_subprocess():
    """Mid-run device loss on a real 4-device host mesh: the engine is
    rebuilt on the shrunken mesh (announce-floor plan), resumes from the
    snapshot, and finishes bit-identical — then serves a plain run too."""
    prog = r"""
import tempfile
import numpy as np
import jax
from repro.core import (GopherEngine, PhasedTierPlan, SemiringProgram,
                        compat, host_graph_block, make_sssp_init)
from repro.gofs.formats import partition_graph
from repro.gofs.generators import random_graph
from repro.gofs.partition import bfs_grow_partition
from repro.resilience import faults, run_with_failover
from repro.training.checkpoint import Checkpointer
g = random_graph(120, avg_degree=4.0, seed=3, weighted=True)
pg = partition_graph(g, bfs_grow_partition(g, 8, seed=0), 8)
prog = SemiringProgram(semiring="min_plus",
                       init_fn=make_sssp_init(int(pg.part_of[0]),
                                              int(pg.local_of[0])))
ref, _ = GopherEngine(pg, prog, backend="local", exchange="compact").run()
def eq(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))
hb = host_graph_block(pg)
eng = GopherEngine(pg, prog, backend="shard_map",
                   mesh=compat.make_mesh((4,), ("parts",)),
                   exchange="phased", tier_plan=PhasedTierPlan.from_block(hb))
plan = faults.FaultPlan([faults.FaultSpec("engine.superstep", "device_loss",
                                          at=2, payload={"lost": [1]})])
with tempfile.TemporaryDirectory() as d:
    with faults.inject(plan):
        eng2, state, tele, rep = run_with_failover(eng, Checkpointer(d),
                                                   every=1, host_gb=hb)
    assert eq(state, ref), "failover parity"
    assert rep.old_num_devices == 4 and rep.new_num_devices == 2, rep
    assert rep.lost_partitions == [2, 3], rep
    assert int(eng2.mesh.shape["parts"]) == 2
    st2, _ = eng2.run()
    assert eq(st2, ref), "post-failover run parity"
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


# --------------------------------------------------- serving degradation

def _svc(**kw):
    _, pg = _pg(parts=4)
    kw.setdefault("retry_base_s", 0.001)
    return pg, GraphQueryService({"g": pg}, **kw)


def test_serving_delta_fault_keeps_answering_and_recovers():
    """The serving degradation gate: a delta-apply fault never reaches a
    client — the service retries with backoff, installs v+1, and reports
    the recovery in svc.stats()."""
    pg, svc = _svc()
    r0 = svc.query("sssp", "g", [0])
    v0 = svc.graphs["g"].version
    plan = faults.FaultPlan(
        [faults.FaultSpec("svc.apply_delta", "failed_delta", at=0)])
    with faults.inject(plan):
        svc.apply_delta("g", EdgeDelta.of(insert_src=[1], insert_dst=[50],
                                          insert_wgt=[0.5]))
    r1 = svc.query("sssp", "g", [1])
    st = svc.stats()
    assert r0.error is None and r1.error is None
    assert svc.graphs["g"].version == v0 + 1
    assert st["delta_retries"] == 1 and st["recoveries"] == 1


def test_serving_delta_exhaustion_serves_stale_then_heals():
    pg, svc = _svc()
    svc.query("sssp", "g", [0])
    v0 = svc.graphs["g"].version
    delta = EdgeDelta.of(insert_src=[2], insert_dst=[60], insert_wgt=[0.3])
    plan = faults.FaultPlan(
        [faults.FaultSpec("svc.apply_delta", "failed_delta", prob=1.0,
                          times=10)])
    with faults.inject(plan):
        with pytest.raises(faults.DeltaApplyFault):
            svc.apply_delta("g", delta)
    # degraded, not down: version-v answers keep flowing, flagged stale
    r = svc.query("sssp", "g", [3])
    st = svc.stats()
    assert r.error is None and svc.graphs["g"].version == v0
    assert st["delta_failures"] == 1 and st["stale_served"] >= 1
    assert st["stale_graphs"] == ["g"]
    svc.apply_delta("g", delta)                 # heal
    st = svc.stats()
    assert svc.graphs["g"].version == v0 + 1
    assert st["recoveries"] >= 1 and "stale_graphs" not in st


def test_serving_corrupt_block_patch_cold_rebuilds():
    """verify_host_block catches a corrupted zero-repack patch; the retry
    cold-rebuilds and the served result matches an independent service at
    the same version."""
    pg, svc = _svc()
    svc.query("sssp", "g", [0])                 # build the patchable twin
    delta = EdgeDelta.of(insert_src=[4, 9], insert_dst=[70, 33],
                         insert_wgt=[0.7, 1.1])
    plan = faults.FaultPlan(
        [faults.FaultSpec("blocks.patch", "corrupt_block", at=0)])
    with faults.inject(plan):
        svc.apply_delta("g", delta)
    got = svc.query("sssp", "g", [5])
    ref_pg = apply_delta(pg, delta, directed=False).pg
    ref = GraphQueryService({"g": ref_pg}).query("sssp", "g", [5])
    st = svc.stats()
    assert got.error is None and np.array_equal(got.result, ref.result)
    assert st["delta_retries"] >= 1 and st["recoveries"] >= 1


def test_serving_poisoned_query_retries_then_breaker_opens():
    _, svc = _svc()
    plan = faults.FaultPlan(
        [faults.FaultSpec("svc.query", "poisoned_query", at=0)])
    with faults.inject(plan):
        r = svc.query("sssp", "g", [7])
    st = svc.stats()
    assert r.error is None
    assert st["query_retries"] >= 1 and st["recoveries"] >= 1

    _, svc2 = _svc(max_retries=1, breaker_threshold=2,
                   breaker_cooldown_s=1e9)
    plan2 = faults.FaultPlan(
        [faults.FaultSpec("svc.query", "poisoned_query", prob=1.0,
                          times=99)])
    with faults.inject(plan2):
        r2 = svc2.query("sssp", "g", [9])
    assert r2.error and r2.error.startswith("degraded:")
    st2 = svc2.stats()
    assert st2["degraded_batches"] == 1 and st2["breaker_opens"] == 1
    assert st2["breakers"]["g"] == "open"
    r3 = svc2.query("sssp", "g", [11])          # open breaker: cheap refusal
    assert r3.error and "circuit open" in r3.error


def test_serving_deadline_is_a_typed_error():
    _, svc = _svc(deadline_s=0.0)
    t = svc.submit("sssp", "g", [0])
    import time
    time.sleep(0.01)
    r = svc.drain()[t]
    assert r.error == "deadline exceeded" and r.result is None
    assert svc.stats()["deadline_misses"] >= 1


def test_circuit_breaker_state_machine_and_backoff():
    now = [0.0]
    br = CircuitBreaker(threshold=2, cooldown_s=10.0, clock=lambda: now[0])
    assert br.allow() and br.state == "closed"
    br.record_failure()
    assert br.state == "closed"
    br.record_failure()
    assert br.state == "open" and br.opens == 1 and not br.allow()
    now[0] = 10.0                               # cooldown elapsed
    assert br.allow() and br.state == "half_open"
    br.record_failure()                         # trial failed -> reopen
    assert br.state == "open" and br.opens == 2
    now[0] = 20.0
    assert br.allow()
    br.record_ok()                              # trial succeeded -> close
    assert br.state == "closed" and br.allow()
    assert backoff_delays(0.05, 4) == [0.05, 0.1, 0.2, 0.4]
    assert backoff_delays(3.0, 3, cap_s=5.0) == [3.0, 5.0, 5.0]
    assert backoff_delays(0.05, 0) == []


# --------------------------------------------------- delta validation

def _delta_pg():
    _, pg = _pg(n=40, parts=4)
    return pg


def test_validate_delta_rejects_out_of_range_ids():
    pg = _delta_pg()
    with pytest.raises(DeltaValidationError, match="out of range"):
        validate_delta(pg, EdgeDelta.of(insert_src=[pg.n_global],
                                        insert_dst=[0]))
    with pytest.raises(DeltaValidationError, match="out of range"):
        validate_delta(pg, EdgeDelta.of(remove_src=[0], remove_dst=[-1]))


def test_validate_delta_rejects_nan_and_negative_weights():
    pg = _delta_pg()
    with pytest.raises(DeltaValidationError, match="NaN"):
        validate_delta(pg, EdgeDelta.of(insert_src=[0], insert_dst=[1],
                                        insert_wgt=[np.nan]))
    with pytest.raises(DeltaValidationError, match="negative"):
        validate_delta(pg, EdgeDelta.of(insert_src=[0], insert_dst=[1],
                                        insert_wgt=[-2.0]))
    # the "any" domain admits negative weights (min_plus over ℝ)
    validate_delta(pg, EdgeDelta.of(insert_src=[0], insert_dst=[1],
                                    insert_wgt=[-2.0]),
                   weight_domain="any")
    with pytest.raises(DeltaValidationError, match="weight_domain"):
        validate_delta(pg, EdgeDelta.of(insert_src=[0], insert_dst=[1]),
                       weight_domain="bogus")


def test_validate_delta_rejects_contradictory_batches():
    pg = _delta_pg()
    # undirected: (7, 3) insert collides with (3, 7) removal
    bad = EdgeDelta.of(insert_src=[7], insert_dst=[3], insert_wgt=[1.0],
                      remove_src=[3], remove_dst=[7])
    with pytest.raises(DeltaValidationError, match="both inserted and"):
        validate_delta(pg, bad)
    with pytest.raises(DeltaValidationError):
        apply_delta(pg, bad, directed=False)    # strict by default
    # directed: opposite arcs are DIFFERENT edges -> fine
    validate_delta(pg, bad, directed=True)


def test_apply_delta_fires_validation_before_any_work():
    pg = _delta_pg()
    bad = EdgeDelta.of(insert_src=[pg.n_global + 5], insert_dst=[0])
    with pytest.raises(DeltaValidationError):
        apply_delta(pg, bad, directed=False)
    assert pg.version == 0                      # nothing was installed


# --------------------------------------------------- host block verifier

def test_verify_host_block_clean_and_corrupt():
    _, pg = _pg(n=60, parts=4)
    hb = host_graph_block(pg)
    assert verify_host_block(hb) == []
    # out-of-bounds neighbor id on a live lane
    bad = dict(hb)
    nbr = np.array(hb["nbr"], copy=True)
    live = np.argwhere(nbr != PAD)
    i = tuple(live[0])
    nbr[i] = pg.v_max + 5
    bad["nbr"] = nbr
    assert any("nbr" in p for p in verify_host_block(bad))
    # NaN weight on a live lane
    bad2 = dict(hb)
    wgt = np.array(hb["wgt"], np.float32, copy=True)
    wgt[i] = np.nan
    bad2["wgt"] = wgt
    assert any("non-finite" in p for p in verify_host_block(bad2))
    # truncated block
    bad3 = dict(hb)
    del bad3["ob_inv"]
    assert any("ob_inv" in p for p in verify_host_block(bad3))


# --------------------------------------------- Gopher Balance: migration

def _strip_pg(rows=6, cols=12, weighted=True, seed=0):
    """road_grid in 2-column vertical strips; strips 0 and 3 (NOT adjacent)
    fold into partition 0, so it holds TWO local sub-graphs with real cut
    edges, while partitions 1 and 2 run half-full — v_max slack to migrate
    into (bfs_grow layouts are single-sub-graph and slack-free, useless for
    migration tests)."""
    g = road_grid(rows, cols, drop_frac=0.0, seed=seed, weighted=weighted)
    strip = (np.arange(rows * cols) % cols) // 2
    assign = np.asarray([0, 1, 2, 0, 3, 3], np.int32)[strip]
    return partition_graph(g, assign, 4)


_MREF = {}


def _strip_ref(algo):
    """Fault-free, migration-free reference in GLOBAL vertex order."""
    if algo not in _MREF:
        pg = _strip_pg()
        state, _ = GopherEngine(pg, _prog(algo, pg), backend="local",
                                exchange="dense").run()
        _MREF[algo] = to_global(state, pg)
    return _MREF[algo]


def _geq(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return (len(la) == len(lb)
            and all(np.array_equal(np.asarray(x), np.asarray(y))
                    for x, y in zip(la, lb)))


def test_rebalance_hint_threshold_and_hysteresis():
    base = dict(imbalance=1.3, straggler=0, time_imbalance=0.0,
                time_straggler=-1)
    assert rebalance_hint(base) is None             # under the trip point
    h = rebalance_hint(dict(base, imbalance=1.8))
    assert h["migrate_from"] == 0 and h["signal"] == "iters"
    # hysteresis: while acting, the band between floor and threshold still
    # hints, so a heal drains fully instead of re-tripping next window
    assert rebalance_hint(base, acting=True)["migrate_from"] == 0
    # balanced mesh (at/below floor): ALWAYS None, even while acting
    assert rebalance_hint(dict(base, imbalance=1.05), acting=True) is None
    assert rebalance_hint(dict(base, imbalance=1.0)) is None
    # the worse channel wins: wall-clock straggler beats flat iterations
    h2 = rebalance_hint(dict(base, time_imbalance=2.5, time_straggler=3))
    assert h2["migrate_from"] == 3 and h2["signal"] == "time"
    # tripped but no victim named -> no hint
    assert rebalance_hint(dict(imbalance=9.9, straggler=-1)) is None


def test_targeted_straggler_lands_in_part_seconds():
    """The upgraded straggler fault: a {'part': p} payload stalls delay_s
    per live vertex of p, and the checkpointed driver charges the stall to
    p's wall-clock channel — visible in Telemetry.skew()."""
    pg = _strip_pg()
    eng = GopherEngine(pg, _prog("cc", pg), backend="local",
                       exchange="compact")
    plan = faults.FaultPlan(
        [faults.FaultSpec("engine.superstep", "straggler", prob=1.0,
                          times=9999, delay_s=0.001, payload={"part": 2})])
    with tempfile.TemporaryDirectory() as d:
        with faults.inject(plan):
            state, tele = eng.run(checkpointer=Checkpointer(d),
                                  checkpoint_every=1)
    assert tele.part_seconds is not None
    assert int(np.argmax(tele.part_seconds)) == 2
    skew = tele.skew()
    assert skew["time_straggler"] == 2 and skew["time_imbalance"] > 1.5
    assert _geq(to_global(state, pg), _strip_ref("cc"))
    assert len(plan.fired) == tele.supersteps  # one stall per superstep


def test_plan_migration_budget_and_capacity():
    pg = _strip_pg()                    # sub-graphs of 12; parts 1,2 half-full
    assert plan_migration(pg, src=0, budget=11) is None   # atomic sub-graph
    p = plan_migration(pg, src=0, budget=12)
    assert p is not None and p.verts == 12 and len(p.subgraphs) == 1
    assert p.dst in (1, 2)              # lightest partitions with free slots
    # budget 24 but only 12 free slots at any dst: still one sub-graph
    assert plan_migration(pg, src=0, budget=24).verts == 12
    # a FULL destination can absorb nothing
    assert plan_migration(pg, src=0, budget=12, dst=3) is None
    assert plan_migration(pg, src=0, budget=12, dst=0) is None
    assert plan_migration(pg, src=9, budget=12) is None   # no such partition


def test_apply_migration_audits_and_moves_only_planned():
    """Non-adjacent destination: out-edges re-allocate at dst, in-edges
    retarget in place, and ONLY the planned sub-graph's vertices change
    owner. The patched block passes the structural audit and both cc and
    sssp converge bit-identical in global order."""
    pg = _strip_pg()
    hb = host_graph_block(pg)
    plan = plan_migration(pg, src=0, budget=12, dst=2)
    res = apply_migration(pg, plan, host_gb=hb)
    assert verify_host_block(res.block) == []
    assert res.stats["out_moved"] > 0 and res.stats["in_retargeted"] > 0
    changed = np.flatnonzero(np.asarray(pg.part_of)
                             != np.asarray(res.pg.part_of))
    assert set(changed.tolist()) == set(res.moved_gids.tolist())
    assert res.pg.version == pg.version + 1
    # fresh runs on the migrated layout: sssp's init bakes the source
    # vertex's (part, slot), so the program is RE-DERIVED from res.pg
    for algo in ("cc", "sssp"):
        state, _ = GopherEngine(res.pg, _prog(algo, res.pg),
                                backend="local", exchange="compact").run()
        assert _geq(to_global(state, res.pg), _strip_ref(algo))


def _migration_case(algo, mode, backend, k, budget, dst):
    """Run to superstep k, migrate (when a bounded plan exists), resume —
    the final state must be bit-identical IN GLOBAL ORDER to the
    migration-free run."""
    pg = _strip_pg()
    kw = {}
    if backend == "shard_map":
        kw = dict(mesh=compat.make_mesh((1,), ("parts",)))
    eng = GopherEngine(pg, _prog(algo, pg), backend=backend, exchange=mode,
                       **kw)
    plan = plan_migration(pg, src=0, budget=budget, dst=dst)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        eng.run(checkpointer=ck, checkpoint_every=1, superstep_budget=k)
        if plan is not None:
            eng, res, at = migrate_and_resume(eng, ck, plan)
        state, tele = eng.run(checkpointer=ck, checkpoint_every=1,
                              resume=True)
    assert _geq(to_global(state, eng.pg), _strip_ref(algo))
    return plan


@pytest.mark.parametrize("algo,mode,backend,k,budget,dst", [
    ("cc", "dense", "local", 1, 12, None),
    ("cc", "compact", "shard_map", 3, 12, 2),
    ("cc", "megastep", "local", 2, 24, None),
    ("cc", "tiered", "local", 4, 12, 1),
    ("sssp", "compact", "local", 2, 12, 2),
    ("sssp", "tiered", "shard_map", 1, 12, None),
    ("sssp", "megastep", "local", 5, 12, 1),
])
def test_migration_superstep_corners(algo, mode, backend, k, budget, dst):
    """Deterministic corners of the migrate-at-any-superstep property —
    always run, even without hypothesis installed."""
    plan = _migration_case(algo, mode, backend, k, budget, dst)
    assert plan is not None             # corners are chosen to really move


@pytest.mark.skipif(not HAVE_HYPOTHESIS,
                    reason="property sweep needs hypothesis "
                           "(requirements-dev.txt)")
def test_migration_at_any_superstep_is_bit_identical():
    """Gopher Balance acceptance property: ANY bounded migration plan at
    ANY superstep, across exchange modes and backends, converges
    bit-identical (global order) to the migration-free run."""

    @settings(max_examples=12, deadline=None)
    @given(algo=st.sampled_from(["cc", "sssp"]),
           mode=st.sampled_from(["dense", "compact", "megastep", "tiered"]),
           backend=st.sampled_from(["local", "shard_map"]),
           k=st.integers(1, 6),
           budget=st.integers(8, 24),
           dst=st.sampled_from([None, 1, 2, 3]))
    def prop(algo, mode, backend, k, budget, dst):
        assume(not (mode == "megastep" and backend == "shard_map"))
        _migration_case(algo, mode, backend, k, budget, dst)

    prop()


def test_run_with_rebalance_heals_straggler_bit_identical():
    """The closed loop: a load-proportional straggler on partition 0 trips
    the hint, the actuator migrates sub-graphs off it between segments, and
    the final state still matches the fault-free run bit-identically."""
    pg = _strip_pg()
    eng = GopherEngine(pg, _prog("cc", pg), backend="local",
                       exchange="compact")
    plan = faults.FaultPlan(
        [faults.FaultSpec("engine.superstep", "straggler", prob=1.0,
                          times=9999, delay_s=0.002, payload={"part": 0})])
    with tempfile.TemporaryDirectory() as d:
        with faults.inject(plan):
            eng2, state, tele, rep = run_with_rebalance(
                eng, Checkpointer(d), every=1,
                policy=BalancePolicy(threshold=1.3, floor=1.05,
                                     max_verts_per_step=12, check_every=2))
    assert _geq(to_global(state, eng2.pg), _strip_ref("cc"))
    assert rep.migrations and rep.rollbacks == 0
    assert all(m["src"] == 0 for m in rep.migrations)
    assert rep.final_step == tele.supersteps
    # the migrated engine serves fresh runs on the new layout too
    st2, _ = eng2.run()
    assert _geq(to_global(st2, eng2.pg), _strip_ref("cc"))


def test_migration_rollback_on_corrupt_patch():
    """An injected corrupt patch rolls back for free: nothing installs, the
    pre-migration engine finishes from its own snapshot, parity holds."""
    pg = _strip_pg()
    eng = GopherEngine(pg, _prog("cc", pg), backend="local",
                       exchange="compact")
    plan = faults.FaultPlan(
        [faults.FaultSpec("engine.superstep", "straggler", prob=1.0,
                          times=9999, delay_s=0.002, payload={"part": 0}),
         faults.FaultSpec("blocks.patch", "corrupt_block", prob=1.0,
                          times=9999)])
    with tempfile.TemporaryDirectory() as d:
        with faults.inject(plan):
            eng2, state, tele, rep = run_with_rebalance(
                eng, Checkpointer(d), every=1,
                policy=BalancePolicy(threshold=1.3, floor=1.05,
                                     max_verts_per_step=12, check_every=2))
    assert rep.rollbacks >= 1 and not rep.migrations
    assert all(f["kind"] == "corrupt_block" for f in rep.faults)
    assert eng2 is eng and eng2.pg.version == pg.version
    assert _geq(to_global(state, eng2.pg), _strip_ref("cc"))


def test_service_rebalance_rides_stale_serving():
    """svc.rebalance: a skewed tracker triggers a live migration behind the
    serving path — answers are identical across the move, a corrupt patch
    rolls back (version v keeps serving), and the counters tick."""
    from repro.obs.skew import SkewTracker

    pg = _strip_pg()
    svc = GraphQueryService({"g": pg}, retry_base_s=0.001)
    r0 = svc.query("sssp", "g", [0])
    assert r0.error is None
    skewed = type("T", (), {})()
    skewed.local_iters = np.array([40.0, 10.0, 10.0, 10.0])
    skewed.pair_slots = None
    skewed.part_seconds = np.array([4.0, 0.5, 0.5, 0.5])
    tr = svc.skew.setdefault("g", SkewTracker(num_parts=4))
    tr.observe(skewed)
    # corrupt patch first: rollback, version unchanged, still answering
    fplan = faults.FaultPlan(
        [faults.FaultSpec("blocks.patch", "corrupt_block", at=0)])
    with faults.inject(fplan):
        assert svc.rebalance("g") is None
    st = svc.stats()
    assert st["migration_rollbacks"] == 1 and st["migrations"] == 0
    assert svc.graphs["g"].version == pg.version
    assert svc.query("sssp", "g", [0]).error is None
    # clean attempt installs; answers match bit-for-bit across the move
    res = svc.rebalance("g")
    assert res is not None and svc.graphs["g"].version == pg.version + 1
    st = svc.stats()
    assert st["migrations"] == 1
    r1 = svc.query("sssp", "g", [0])
    assert r1.error is None and np.array_equal(r0.result, r1.result)
    # tracker was reset to the post-move layout: balanced -> no-op
    assert svc.rebalance("g") is None
